"""Masked softmax kernel vs oracle: normalization, masking, stability."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import masked_softmax
from compile.kernels import ref as R

from .conftest import assert_close, rand_mask, randn


@pytest.mark.parametrize("n,m", [(32, 32), (64, 64), (32, 128), (128, 64)])
@pytest.mark.parametrize("density", [0.05, 0.1, 0.5, 1.0])
def test_matches_ref(n, m, density):
    s = randn(0, n, m)
    mask = rand_mask(1, n, m, density)
    assert_close(masked_softmax(s, mask), R.masked_softmax_ref(s, mask), rtol=1e-5)


def test_rows_sum_to_one_or_zero():
    s = randn(2, 64, 64)
    mask = rand_mask(3, 64, 64, 0.1)
    p = np.asarray(masked_softmax(s, mask))
    sums = p.sum(axis=-1)
    active = np.asarray(mask).sum(axis=-1) > 0
    np.testing.assert_allclose(sums[active], 1.0, rtol=1e-5)
    np.testing.assert_allclose(sums[~active], 0.0, atol=0)


def test_masked_positions_zero():
    s = randn(4, 64, 64)
    mask = rand_mask(5, 64, 64, 0.2)
    p = np.asarray(masked_softmax(s, mask))
    assert (p[np.asarray(mask) == 0] == 0).all()


def test_full_mask_equals_plain_softmax():
    s = randn(6, 32, 64)
    ones = jnp.ones_like(s)
    p = masked_softmax(s, ones)
    expect = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    expect = expect / jnp.sum(expect, -1, keepdims=True)
    assert_close(p, expect, rtol=1e-5)


def test_numerically_stable_large_values():
    s = randn(7, 32, 32) * 1e4
    mask = rand_mask(8, 32, 32, 0.3)
    p = np.asarray(masked_softmax(s, mask))
    assert np.isfinite(p).all()


def test_single_active_entry_gets_full_mass():
    n = 32
    s = randn(9, n, n)
    mask = jnp.zeros((n, n), jnp.float32).at[:, 5].set(1.0)
    p = np.asarray(masked_softmax(s, mask))
    np.testing.assert_allclose(p[:, 5], 1.0, rtol=1e-6)


def test_invariant_to_row_shift():
    # softmax(x + c) == softmax(x) per row
    s = randn(10, 32, 64)
    mask = rand_mask(11, 32, 64, 0.4)
    p1 = masked_softmax(s, mask)
    p2 = masked_softmax(s + 42.0, mask)
    assert_close(p1, p2, rtol=1e-5)


@pytest.mark.parametrize("block_rows", [8, 16, 32, 64])
def test_block_rows_equivalent(block_rows):
    s = randn(12, 64, 64)
    mask = rand_mask(13, 64, 64, 0.15)
    assert_close(
        masked_softmax(s, mask, block_rows=block_rows),
        R.masked_softmax_ref(s, mask),
        rtol=1e-5,
    )
