"""Hypothesis sweeps: kernel == oracle over random shapes/densities/scales.

These are the L1 property tests the architecture calls for — shapes and
dtypes drawn by hypothesis, asserted allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    block_mask_counts,
    masked_sddmm,
    masked_softmax,
    masked_spmm,
    quant_roundtrip,
)
from compile.kernels import ref as R

SETTINGS = dict(max_examples=25, deadline=None)

dims = st.sampled_from([32, 64, 96, 128])
small_dims = st.sampled_from([32, 64])
densities = st.floats(min_value=0.0, max_value=1.0)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
gammas = st.floats(min_value=0.25, max_value=32.0)


def _randn(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def _mask(rng, n, m, density):
    return jnp.asarray(rng.random((n, m)) < density, jnp.float32)


@given(n=dims, d=dims, m=dims, density=densities, seed=seeds)
@settings(**SETTINGS)
def test_sddmm_property(n, d, m, density, seed):
    rng = np.random.default_rng(seed)
    a, b = _randn(rng, n, d), _randn(rng, d, m)
    mask = _mask(rng, n, m, density)
    np.testing.assert_allclose(
        masked_sddmm(a, b, mask), R.masked_sddmm_ref(a, b, mask), rtol=1e-4, atol=1e-4
    )


@given(n=dims, m=dims, dv=small_dims, density=densities, seed=seeds)
@settings(**SETTINGS)
def test_spmm_property(n, m, dv, density, seed):
    rng = np.random.default_rng(seed)
    mask = _mask(rng, n, m, density)
    s = _randn(rng, n, m) * mask
    v = _randn(rng, m, dv)
    np.testing.assert_allclose(
        masked_spmm(s, v, mask), R.masked_spmm_ref(s, v, mask), rtol=1e-4, atol=1e-4
    )


@given(n=dims, m=dims, density=densities, seed=seeds, scale=st.floats(0.1, 100.0))
@settings(**SETTINGS)
def test_softmax_property(n, m, density, seed, scale):
    rng = np.random.default_rng(seed)
    s = _randn(rng, n, m) * scale
    mask = _mask(rng, n, m, density)
    got = np.asarray(masked_softmax(s, mask))
    want = np.asarray(R.masked_softmax_ref(s, mask))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert np.isfinite(got).all()


@given(n=dims, m=dims, gamma=gammas, seed=seeds, bits=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_quant_property(n, m, gamma, seed, bits):
    rng = np.random.default_rng(seed)
    x = _randn(rng, n, m)
    np.testing.assert_allclose(
        quant_roundtrip(x, gamma, bits=bits),
        R.quant_roundtrip_ref(x, gamma, bits),
        rtol=1e-6,
        atol=1e-6,
    )


@given(n=dims, m=dims, density=densities, seed=seeds)
@settings(**SETTINGS)
def test_block_counts_conserve_mass(n, m, density, seed):
    rng = np.random.default_rng(seed)
    mask = _mask(rng, n, m, density)
    c = block_mask_counts(mask, 32, 32)
    assert int(np.asarray(c).sum()) == int(np.asarray(mask).sum())


@given(n=small_dims, density=st.floats(0.01, 0.5), seed=seeds)
@settings(**SETTINGS)
def test_sparse_attention_composition(n, density, seed):
    """SDDMM -> softmax -> SpMM composes to masked attention exactly."""
    rng = np.random.default_rng(seed)
    d = 64
    m_mat = _randn(rng, n, d)
    xt = _randn(rng, d, n)
    v = _randn(rng, n, d)
    mask = _mask(rng, n, n, density)
    s = masked_sddmm(m_mat, xt, mask) / np.sqrt(d)
    p = masked_softmax(s, mask)
    z = masked_spmm(p, v, mask)
    s_ref = R.masked_sddmm_ref(m_mat, xt, mask) / np.sqrt(d)
    p_ref = R.masked_softmax_ref(s_ref, mask)
    z_ref = R.masked_spmm_ref(p_ref, v, mask)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=1e-4, atol=1e-4)
