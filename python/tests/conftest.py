import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Allow `pytest python/tests` from the repo root too.
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

jax.config.update("jax_platform_name", "cpu")


def rng(seed: int):
    return jax.random.PRNGKey(seed)


def randn(seed: int, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def rand_mask(seed: int, n: int, m: int, density: float):
    u = jax.random.uniform(jax.random.PRNGKey(seed), (n, m))
    return (u < density).astype(jnp.float32)


def assert_close(a, b, rtol=1e-4, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


@pytest.fixture(scope="session")
def tiny_cfg():
    from compile.model import ModelConfig

    return ModelConfig(seq_len=32, d_model=64, d_k=64, d_ff=128).validate()


@pytest.fixture(scope="session")
def small_cfg():
    from compile.model import ModelConfig

    return ModelConfig(seq_len=64, d_model=128, d_k=64, d_ff=256).validate()
