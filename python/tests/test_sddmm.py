"""Masked SDDMM kernel vs oracle, plus block-skipping semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import block_mask_counts, masked_sddmm
from compile.kernels import ref as R

from .conftest import assert_close, rand_mask, randn


@pytest.mark.parametrize("n,d,m", [(32, 32, 32), (64, 96, 64), (32, 256, 128), (128, 64, 32)])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.1, 0.5, 1.0])
def test_matches_ref(n, d, m, density):
    a = randn(0, n, d)
    b = randn(1, d, m)
    mask = rand_mask(2, n, m, density)
    assert_close(masked_sddmm(a, b, mask), R.masked_sddmm_ref(a, b, mask), rtol=1e-4)


def test_empty_mask_gives_zero():
    a = randn(3, 64, 64)
    b = randn(4, 64, 64)
    z = np.asarray(masked_sddmm(a, b, jnp.zeros((64, 64), jnp.float32)))
    assert (z == 0).all()


def test_full_mask_equals_matmul():
    a = randn(5, 64, 96)
    b = randn(6, 96, 64)
    assert_close(masked_sddmm(a, b, jnp.ones((64, 64), jnp.float32)), a @ b, rtol=1e-4)


def test_off_mask_positions_exactly_zero():
    a = randn(7, 64, 64)
    b = randn(8, 64, 64)
    mask = rand_mask(9, 64, 64, 0.2)
    s = np.asarray(masked_sddmm(a, b, mask))
    assert (s[np.asarray(mask) == 0] == 0).all()


def test_block_diag_mask_only_diag_blocks():
    # Blocks fully off the mask must be exactly 0 (skipped, not just gated).
    n = 64
    blk = 32
    mask = jnp.zeros((n, n), jnp.float32)
    mask = mask.at[:blk, :blk].set(1.0).at[blk:, blk:].set(1.0)
    a = randn(10, n, 48)
    b = randn(11, 48, n)
    s = np.asarray(masked_sddmm(a, b, mask, block=blk))
    assert (s[:blk, blk:] == 0).all() and (s[blk:, :blk] == 0).all()
    assert_close(s[:blk, :blk], (a @ b)[:blk, :blk], rtol=1e-4)


@pytest.mark.parametrize("block", [16, 32, 64])
def test_block_size_invariance(block):
    a = randn(12, 64, 64)
    b = randn(13, 64, 64)
    mask = rand_mask(14, 64, 64, 0.1)
    assert_close(
        masked_sddmm(a, b, mask, block=block), R.masked_sddmm_ref(a, b, mask), rtol=1e-4
    )


class TestBlockMaskCounts:
    def test_counts_total(self):
        mask = rand_mask(15, 64, 96, 0.3)
        c = block_mask_counts(mask, 32, 32)
        assert int(np.asarray(c).sum()) == int(np.asarray(mask).sum())

    def test_counts_shape(self):
        c = block_mask_counts(jnp.ones((64, 128)), 32, 32)
        assert c.shape == (2, 4)
        assert (np.asarray(c) == 32 * 32).all()

    def test_zero_blocks_detected(self):
        mask = jnp.zeros((64, 64), jnp.float32).at[:32, :32].set(1.0)
        c = np.asarray(block_mask_counts(mask, 32, 32))
        assert c[0, 0] == 1024 and c[0, 1] == 0 and c[1, 0] == 0 and c[1, 1] == 0

    def test_rejects_misaligned(self):
        with pytest.raises(AssertionError):
            block_mask_counts(jnp.ones((33, 64)), 32, 32)
