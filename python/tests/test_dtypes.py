"""Dtype sweeps: the Pallas kernels must hold up in bf16 (the MXU-native
dtype the DESIGN.md hardware adaptation targets) as well as f32."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import masked_sddmm, masked_softmax, masked_spmm
from compile.kernels import ref as R

from .conftest import rand_mask, randn

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-5)


def _cast(x, dtype):
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", DTYPES)
def test_sddmm_dtype(dtype):
    a = _cast(randn(0, 64, 64), dtype)
    b = _cast(randn(1, 64, 64), dtype)
    mask = rand_mask(2, 64, 64, 0.2)
    got = np.asarray(masked_sddmm(a, b, mask), np.float32)
    want = np.asarray(
        R.masked_sddmm_ref(a.astype(jnp.float32), b.astype(jnp.float32), mask), np.float32
    )
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_softmax_dtype(dtype):
    s = _cast(randn(3, 64, 64), dtype)
    mask = rand_mask(4, 64, 64, 0.3)
    got = np.asarray(masked_softmax(s, _cast(mask, dtype)), np.float32)
    want = np.asarray(R.masked_softmax_ref(s.astype(jnp.float32), mask), np.float32)
    np.testing.assert_allclose(got, want, **_tol(dtype))
    # probability mass conserved regardless of dtype
    active = np.asarray(mask).sum(axis=-1) > 0
    np.testing.assert_allclose(got.sum(-1)[active], 1.0, rtol=2e-2)


@pytest.mark.parametrize("dtype", DTYPES)
def test_spmm_dtype(dtype):
    mask = rand_mask(5, 64, 64, 0.15)
    s = _cast(randn(6, 64, 64) * np.asarray(mask), dtype)
    v = _cast(randn(7, 64, 32), dtype)
    got = np.asarray(masked_spmm(s, v, mask), np.float32)
    want = np.asarray(
        R.masked_spmm_ref(s.astype(jnp.float32), v.astype(jnp.float32), mask), np.float32
    )
    np.testing.assert_allclose(got, want, **_tol(dtype))


def test_bf16_outputs_finite_at_scale():
    # bf16's narrow mantissa must not overflow through the exp/normalize.
    s = _cast(randn(8, 32, 128) * 30.0, jnp.bfloat16)
    mask = rand_mask(9, 32, 128, 0.5)
    p = np.asarray(masked_softmax(s, _cast(mask, jnp.bfloat16)), np.float32)
    assert np.isfinite(p).all()


def test_mixed_precision_pipeline():
    # bf16 operands through the whole SDDMM -> softmax -> SpMM chain stay
    # within a few percent of the f32 oracle chain.
    n, d = 64, 64
    m_mat = randn(10, n, d)
    xt = randn(11, d, n)
    v = randn(12, n, d)
    mask = rand_mask(13, n, n, 0.2)
    s16 = masked_sddmm(_cast(m_mat, jnp.bfloat16), _cast(xt, jnp.bfloat16), mask)
    p16 = masked_softmax(s16 / jnp.sqrt(jnp.float32(d)), mask)
    z16 = np.asarray(masked_spmm(p16, _cast(v, jnp.bfloat16), mask), np.float32)
    s32 = R.masked_sddmm_ref(m_mat, xt, mask) / jnp.sqrt(jnp.float32(d))
    p32 = R.masked_softmax_ref(s32, mask)
    z32 = np.asarray(R.masked_spmm_ref(p32, v, mask), np.float32)
    rel = np.linalg.norm(z16 - z32) / max(np.linalg.norm(z32), 1e-9)
    assert rel < 0.05, rel
