"""Quantization kernel vs oracle: grids, clipping, idempotence."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import quantize, dequantize, quant_roundtrip
from compile.kernels import ref as R

from .conftest import assert_close, randn


@pytest.mark.parametrize("shape", [(32, 32), (64, 32), (32, 96), (128, 128)])
@pytest.mark.parametrize("gamma", [1.0, 4.0, 16.0])
def test_quantize_matches_ref(shape, gamma):
    x = randn(0, *shape)
    assert_close(quantize(x, gamma), R.quantize_ref(x, gamma), rtol=0, atol=0)


@pytest.mark.parametrize("bits", [2, 3, 4, 6, 8])
def test_quantize_grid_bounds(bits):
    x = randn(1, 64, 64) * 100.0
    q = np.asarray(quantize(x, 4.0, bits=bits))
    hi = 2 ** (bits - 1) - 1
    assert q.max() <= hi and q.min() >= -hi


def test_quantize_values_are_integers():
    q = np.asarray(quantize(randn(2, 64, 64), 7.3))
    np.testing.assert_array_equal(q, np.round(q))


@pytest.mark.parametrize("gamma", [0.5, 2.0, 8.0])
def test_dequantize_matches_ref(gamma):
    x = randn(3, 64, 64)
    assert_close(dequantize(x, gamma), R.dequantize_ref(x, gamma), rtol=1e-6)


def test_roundtrip_matches_ref():
    x = randn(4, 96, 64)
    assert_close(quant_roundtrip(x, 4.0), R.quant_roundtrip_ref(x, 4.0), rtol=1e-6)


def test_roundtrip_error_bounded():
    # |Q^-1(Q(x)) - x| <= 0.5/gamma inside the representable range.
    gamma = 8.0
    # 4-bit grid at gamma=8 represents [-7/8, 7/8]; clip inputs inside it.
    x = jnp.clip(randn(5, 64, 64) * 0.5, -0.8, 0.8)
    err = np.abs(np.asarray(quant_roundtrip(x, gamma)) - np.asarray(x))
    assert err.max() <= 0.5 / gamma + 1e-6


def test_quantize_idempotent_on_grid():
    x = randn(6, 64, 64)
    q1 = quantize(x, 4.0)
    # quantizing the de-quantized grid value reproduces the same grid point
    q2 = quantize(dequantize(q1, 4.0), 4.0)
    assert_close(q1, q2, rtol=0, atol=0)


def test_quantize_zero_preserved():
    z = jnp.zeros((32, 32), jnp.float32)
    assert float(np.abs(np.asarray(quantize(z, 4.0))).max()) == 0.0


def test_quantize_monotone():
    # Rounding is monotone: x <= y  =>  Q(x) <= Q(y), elementwise over a ramp.
    x = jnp.linspace(-3, 3, 32 * 32).reshape(32, 32)
    q = np.asarray(quantize(x, 4.0)).reshape(-1)
    assert (np.diff(q) >= 0).all()


def test_quantize_rejects_misaligned():
    with pytest.raises(AssertionError):
        quantize(randn(7, 33, 32), 4.0)
