"""AOT lowering tests: every artifact lowers to parseable HLO text with the
declared entry layout, and the emitted fixtures reproduce under re-execution.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def cfg():
    return M.ModelConfig(seq_len=32, d_model=64, d_k=64, d_ff=128).validate()


@pytest.fixture(scope="module")
def emitted(cfg, tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.emit(cfg, out)
    return out, manifest


ARTIFACT_NAMES = ["mask_gen", "attention", "sparse_attention", "dense_attention", "encoder"]


@pytest.mark.parametrize("name", ARTIFACT_NAMES)
def test_artifact_is_hlo_text(emitted, name):
    out, manifest = emitted
    path = os.path.join(out, manifest["artifacts"][name]["file"])
    text = open(path).read()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text


@pytest.mark.parametrize("name", ARTIFACT_NAMES)
def test_manifest_params_match_graphs(emitted, cfg, name):
    _, manifest = emitted
    graphs = aot.build_graphs(cfg)
    _, specs = graphs[name]
    assert manifest["artifacts"][name]["params"] == [list(s.shape) for s in specs]


def test_no_custom_calls(emitted):
    # interpret=True must lower to plain HLO — a Mosaic custom-call would be
    # unloadable by the CPU PJRT client.
    out, manifest = emitted
    for meta in manifest["artifacts"].values():
        text = open(os.path.join(out, meta["file"])).read()
        assert "custom-call" not in text, meta["file"]


def test_weights_json_shapes(emitted, cfg):
    out, _ = emitted
    w = json.load(open(os.path.join(out, "weights.json")))
    assert w["w_s"]["shape"] == [cfg.d_model, cfg.d_model]
    assert w["w_v"]["shape"] == [cfg.d_model, cfg.d_model]
    assert len(w["w_s"]["data"]) == cfg.d_model * cfg.d_model


def test_fixtures_reproduce(emitted, cfg):
    out, _ = emitted
    fix = json.load(open(os.path.join(out, "fixtures.json")))
    w = M.init_weights(cfg, seed=0)
    x = np.asarray(fix["x"]["data"], np.float32).reshape(fix["x"]["shape"])
    z, mask = M.sparse_attention(jax.numpy.asarray(x), w["w_s"], w["w_v"], cfg)
    want_z = np.asarray(fix["outputs"]["sparse_attention"][0]["data"], np.float32)
    np.testing.assert_allclose(np.asarray(z).reshape(-1), want_z, rtol=1e-5, atol=1e-6)
    want_mask = np.asarray(fix["outputs"]["sparse_attention"][1]["data"], np.float32)
    np.testing.assert_allclose(np.asarray(mask).reshape(-1), want_mask, atol=0)


def test_fixture_mask_consistent_with_mask_gen(emitted):
    out, _ = emitted
    fix = json.load(open(os.path.join(out, "fixtures.json")))
    m1 = fix["outputs"]["mask_gen"][0]["data"]
    m2 = fix["outputs"]["sparse_attention"][1]["data"]
    assert m1 == m2


def test_attention_fixture_consistent(emitted):
    # attention(x, ws, wv, mask_gen(x, ws)) == sparse_attention(x, ws, wv).z
    out, _ = emitted
    fix = json.load(open(os.path.join(out, "fixtures.json")))
    za = np.asarray(fix["outputs"]["attention"][0]["data"], np.float32)
    zs = np.asarray(fix["outputs"]["sparse_attention"][0]["data"], np.float32)
    np.testing.assert_allclose(za, zs, rtol=1e-5, atol=1e-6)
