"""Masked SpMM kernel vs oracle, plus reduction-tile skipping semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import masked_spmm
from compile.kernels import ref as R

from .conftest import assert_close, rand_mask, randn


def _sparse(seed, n, m, density):
    s = randn(seed, n, m)
    mask = rand_mask(seed + 100, n, m, density)
    return s * mask, mask


@pytest.mark.parametrize("n,m,dv", [(32, 32, 32), (64, 64, 64), (64, 128, 32), (128, 64, 96)])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.1, 0.5, 1.0])
def test_matches_ref(n, m, dv, density):
    s, mask = _sparse(0, n, m, density)
    v = randn(1, m, dv)
    assert_close(masked_spmm(s, v, mask), R.masked_spmm_ref(s, v, mask), rtol=1e-4)


def test_empty_mask_gives_zero():
    s = randn(2, 64, 64)
    v = randn(3, 64, 32)
    z = np.asarray(masked_spmm(s, v, jnp.zeros((64, 64), jnp.float32)))
    assert (z == 0).all()


def test_full_mask_equals_matmul():
    s = randn(4, 64, 64)
    v = randn(5, 64, 64)
    assert_close(masked_spmm(s, v, jnp.ones((64, 64), jnp.float32)), s @ v, rtol=1e-4)


def test_skipped_tiles_do_not_contribute():
    # Put garbage in s where the mask is 0: a correct kernel never reads it.
    n = 64
    mask = jnp.zeros((n, n), jnp.float32).at[:32, :32].set(1.0)
    s = randn(6, n, n) + 1e6 * (1 - mask)  # huge garbage off-mask
    v = randn(7, n, 32)
    z = np.asarray(masked_spmm(s, v, mask))
    expect = np.asarray(R.masked_spmm_ref(s, v, mask))
    # rows >= 32 have empty mask rows -> exactly zero, garbage never touched
    assert (z[32:] == 0).all()
    np.testing.assert_allclose(z[:32], expect[:32], rtol=1e-4, atol=1e-4)


def test_identity_sparse_matrix():
    n = 64
    eye = jnp.eye(n, dtype=jnp.float32)
    v = randn(8, n, 64)
    assert_close(masked_spmm(eye, v, eye), v, rtol=1e-6)


@pytest.mark.parametrize("block", [16, 32, 64])
def test_block_size_invariance(block):
    s, mask = _sparse(9, 64, 64, 0.1)
    v = randn(10, 64, 64)
    assert_close(
        masked_spmm(s, v, mask, block=block), R.masked_spmm_ref(s, v, mask), rtol=1e-4
    )


def test_linearity_in_v():
    s, mask = _sparse(11, 64, 64, 0.2)
    v1 = randn(12, 64, 32)
    v2 = randn(13, 64, 32)
    z = masked_spmm(s, v1 + 2.0 * v2, mask)
    z12 = masked_spmm(s, v1, mask) + 2.0 * masked_spmm(s, v2, mask)
    assert_close(z, z12, rtol=1e-4)
