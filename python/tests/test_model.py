"""L2 model tests: calculation-mode equivalence, mask quality, encoder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref as R

from .conftest import assert_close, randn


def _x(cfg, seed=9):
    return randn(seed, cfg.seq_len, cfg.d_model)


class TestCalculationMode:
    """Eq. (2) == Eq. (3): the W_S folding is exact."""

    def test_ws_folding_matches_qk(self, tiny_cfg):
        w = M.init_weights(tiny_cfg)
        x = _x(tiny_cfg)
        s_qk = (x @ w["w_q"]) @ (x @ w["w_k"]).T
        s_ws = x @ w["w_s"] @ x.T
        assert_close(s_qk, s_ws, rtol=1e-3, atol=1e-3)

    def test_dense_mode_matches_vanilla_attention(self, tiny_cfg):
        # CPDAA (all-ones mask) must equal Fig. 1a vanilla attention with
        # the caveat that CPSAA scales by sqrt(d_k) like the paper.
        w = M.init_weights(tiny_cfg)
        x = _x(tiny_cfg)
        z_cpdaa = M.dense_attention(x, w["w_s"], w["w_v"], tiny_cfg)
        q, k, v = x @ w["w_q"], x @ w["w_k"], x @ w["w_v"]
        s = q @ k.T / jnp.sqrt(jnp.float32(tiny_cfg.d_k))
        p = jax.nn.softmax(s, axis=-1)
        assert_close(z_cpdaa, p @ v, rtol=5e-3, atol=5e-4)

    def test_attention_matches_oracle(self, tiny_cfg):
        w = M.init_weights(tiny_cfg)
        x = _x(tiny_cfg)
        mask, _ = M.mask_gen(x, w["w_s"], tiny_cfg), None
        z = M.cpsaa_attention(x, w["w_s"], w["w_v"], mask, tiny_cfg)
        zr = R.cpsaa_attention_ref(x, w["w_s"], w["w_v"], mask, tiny_cfg.d_k)
        assert_close(z, zr, rtol=1e-4, atol=1e-4)


class TestMaskGen:
    def test_mask_is_binary(self, tiny_cfg):
        w = M.init_weights(tiny_cfg)
        mask = np.asarray(M.mask_gen(_x(tiny_cfg), w["w_s"], tiny_cfg))
        assert set(np.unique(mask)) <= {0.0, 1.0}

    def test_matches_oracle(self, tiny_cfg):
        w = M.init_weights(tiny_cfg)
        x = _x(tiny_cfg)
        mask = M.mask_gen(x, w["w_s"], tiny_cfg)
        w_s_q = R.quantize_ref(w["w_s"], tiny_cfg.gamma, tiny_cfg.quant_bits)
        ref = R.mask_gen_ref(
            x, w_s_q, tiny_cfg.gamma, tiny_cfg.d_k, tiny_cfg.theta, tiny_cfg.quant_bits
        )
        assert_close(mask, ref, rtol=0, atol=0)

    def test_mask_density_in_sparse_regime(self, small_cfg):
        # Paper: attention sparsity around 0.1 (i.e., mask keeps ~10%).
        w = M.init_weights(small_cfg)
        mask = np.asarray(M.mask_gen(_x(small_cfg), w["w_s"], small_cfg))
        assert 0.005 < mask.mean() < 0.6

    def test_mask_keeps_largest_scores(self, tiny_cfg):
        # Every kept entry's approximate probability >= every dropped one's,
        # row-wise — binarization is a per-row threshold on one score.
        w = M.init_weights(tiny_cfg)
        x = _x(tiny_cfg)
        mask = np.asarray(M.mask_gen(x, w["w_s"], tiny_cfg))
        w_s_q = R.quantize_ref(w["w_s"], tiny_cfg.gamma, tiny_cfg.quant_bits)
        qx = R.quantize_ref(x, tiny_cfg.gamma, tiny_cfg.quant_bits)
        g3 = tiny_cfg.gamma**3
        s_hat = np.asarray(
            R.masked_softmax_ref(
                (qx @ w_s_q @ qx.T) / g3 / np.sqrt(tiny_cfg.d_k),
                jnp.ones((tiny_cfg.seq_len, tiny_cfg.seq_len)),
            )
        )
        for i in range(tiny_cfg.seq_len):
            kept = s_hat[i][mask[i] == 1]
            dropped = s_hat[i][mask[i] == 0]
            if kept.size and dropped.size:
                assert kept.min() >= dropped.max()

    def test_mask_output_fidelity(self, tiny_cfg):
        # Fig. 16 "Accuracy": masked attention output stays close to the
        # full-precision dense output (relative Frobenius error small).
        w = M.init_weights(tiny_cfg)
        x = _x(tiny_cfg)
        z_sparse, _ = M.sparse_attention(x, w["w_s"], w["w_v"], tiny_cfg)
        z_dense = M.dense_attention(x, w["w_s"], w["w_v"], tiny_cfg)
        rel = float(
            jnp.linalg.norm(z_sparse - z_dense) / jnp.linalg.norm(z_dense)
        )
        assert rel < 0.15, rel


class TestEncoder:
    def test_shapes(self, tiny_cfg):
        w = M.init_weights(tiny_cfg)
        out, mask = M.encoder_layer(_x(tiny_cfg), w, tiny_cfg)
        assert out.shape == (tiny_cfg.seq_len, tiny_cfg.d_model)
        assert mask.shape == (tiny_cfg.seq_len, tiny_cfg.seq_len)

    def test_finite(self, tiny_cfg):
        w = M.init_weights(tiny_cfg)
        out, _ = M.encoder_layer(_x(tiny_cfg), w, tiny_cfg)
        assert np.isfinite(np.asarray(out)).all()

    def test_deterministic(self, tiny_cfg):
        w = M.init_weights(tiny_cfg)
        a, _ = M.encoder_layer(_x(tiny_cfg), w, tiny_cfg)
        b, _ = M.encoder_layer(_x(tiny_cfg), w, tiny_cfg)
        assert_close(a, b, rtol=0, atol=0)

    def test_stackable(self, tiny_cfg):
        # Multi-encoder stacking (§4.5): output feeds next layer cleanly.
        w = M.init_weights(tiny_cfg)
        h = _x(tiny_cfg)
        for _ in range(3):
            h, _ = M.encoder_layer(h, w, tiny_cfg)
        assert np.isfinite(np.asarray(h)).all()


class TestConfig:
    def test_validate_rejects_misaligned(self):
        with pytest.raises(ValueError):
            M.ModelConfig(seq_len=33).validate()

    def test_validate_rejects_bad_theta(self):
        with pytest.raises(ValueError):
            M.ModelConfig(theta=1.5).validate()

    def test_defaults_valid(self):
        M.ModelConfig().validate()
