"""AOT compile path: lower the L2 model to HLO text for the rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Outputs (under --out-dir, default ../artifacts):
    mask_gen.hlo.txt          f(x, w_s)               -> (mask,)
    attention.hlo.txt         f(x, w_s, w_v, mask)    -> (z,)
    sparse_attention.hlo.txt  f(x, w_s, w_v)          -> (z, mask)
    dense_attention.hlo.txt   f(x, w_s, w_v)          -> (z,)   [CPDAA]
    encoder.hlo.txt           f(x, w_s, w_v, fc1, fc2)-> (out, mask)
    weights.json              deterministic synthetic weights (seed 0)
    fixtures.json             sample inputs + expected outputs for rust tests
    manifest.json             shapes / parameter order per artifact

Python runs ONCE here; the rust binary is self-contained afterwards.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_graphs(cfg: M.ModelConfig):
    """Named (fn, example_arg_specs) pairs, one per artifact."""
    n, d, dk = cfg.seq_len, cfg.d_model, cfg.d_k

    def mask_gen(x, w_s):
        return (M.mask_gen(x, w_s, cfg),)

    def attention(x, w_s, w_v, mask):
        return (M.cpsaa_attention(x, w_s, w_v, mask, cfg),)

    def sparse_attention(x, w_s, w_v):
        z, mask = M.sparse_attention(x, w_s, w_v, cfg)
        return (z, mask)

    def dense_attention(x, w_s, w_v):
        return (M.dense_attention(x, w_s, w_v, cfg),)

    def encoder(x, w_s, w_v, w_fc1, w_fc2):
        weights = {"w_s": w_s, "w_v": w_v, "w_fc1": w_fc1, "w_fc2": w_fc2}
        out, mask = M.encoder_layer(x, weights, cfg)
        return (out, mask)

    x = _spec(n, d)
    w_s = _spec(d, d)
    w_v = _spec(d, d)
    return {
        "mask_gen": (mask_gen, (x, w_s)),
        "attention": (attention, (x, w_s, w_v, _spec(n, n))),
        "sparse_attention": (sparse_attention, (x, w_s, w_v)),
        "dense_attention": (dense_attention, (x, w_s, w_v)),
        "encoder": (
            encoder,
            (x, w_s, w_v, _spec(d, cfg.d_ff), _spec(cfg.d_ff, d)),
        ),
    }


def _tolist(a) -> list:
    return np.asarray(a, dtype=np.float32).reshape(-1).tolist()


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def emit(cfg: M.ModelConfig, out_dir: str, seed: int = 0) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    graphs = build_graphs(cfg)
    weights = M.init_weights(cfg, seed=seed)

    manifest = {
        "config": {
            "seq_len": cfg.seq_len,
            "d_model": cfg.d_model,
            "d_k": cfg.d_k,
            "d_ff": cfg.d_ff,
            "gamma": cfg.gamma,
            "quant_bits": cfg.quant_bits,
            "theta": cfg.theta,
            "block": cfg.block,
            "seed": seed,
        },
        "artifacts": {},
    }

    for name, (fn, specs) in graphs.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "params": [list(s.shape) for s in specs],
            "sha256_16": _sha(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "weights.json"), "w") as f:
        json.dump(
            {k: {"shape": list(v.shape), "data": _tolist(v)} for k, v in weights.items()},
            f,
        )

    # Fixtures: concrete inputs + expected outputs so rust integration
    # tests can assert numerics end-to-end through PJRT.
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (cfg.seq_len, cfg.d_model))
    fix = {"x": {"shape": list(x.shape), "data": _tolist(x)}, "outputs": {}}
    args = {
        "mask_gen": (x, weights["w_s"]),
        "sparse_attention": (x, weights["w_s"], weights["w_v"]),
        "dense_attention": (x, weights["w_s"], weights["w_v"]),
        "encoder": (
            x,
            weights["w_s"],
            weights["w_v"],
            weights["w_fc1"],
            weights["w_fc2"],
        ),
    }
    mask = None
    for name, a in args.items():
        fn, _ = graphs[name]
        outs = jax.jit(fn)(*a)
        fix["outputs"][name] = [
            {"shape": list(o.shape), "data": _tolist(o)} for o in outs
        ]
        if name == "mask_gen":
            mask = outs[0]
    fn, _ = graphs["attention"]
    outs = jax.jit(fn)(x, weights["w_s"], weights["w_v"], mask)
    fix["outputs"]["attention"] = [
        {"shape": list(o.shape), "data": _tolist(o)} for o in outs
    ]

    with open(os.path.join(out_dir, "fixtures.json"), "w") as f:
        json.dump(fix, f)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest + weights + fixtures to {out_dir}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    p.add_argument("--seq-len", type=int, default=M.ModelConfig.seq_len)
    p.add_argument("--d-model", type=int, default=M.ModelConfig.d_model)
    p.add_argument("--d-k", type=int, default=M.ModelConfig.d_k)
    p.add_argument("--d-ff", type=int, default=M.ModelConfig.d_ff)
    p.add_argument("--gamma", type=float, default=M.ModelConfig.gamma)
    p.add_argument("--theta", type=float, default=M.ModelConfig.theta)
    p.add_argument("--block", type=int, default=M.ModelConfig.block)
    p.add_argument("--seed", type=int, default=0)
    a = p.parse_args()
    cfg = M.ModelConfig(
        seq_len=a.seq_len,
        d_model=a.d_model,
        d_k=a.d_k,
        d_ff=a.d_ff,
        gamma=a.gamma,
        theta=a.theta,
        block=a.block,
    ).validate()
    emit(cfg, a.out_dir, seed=a.seed)


if __name__ == "__main__":
    main()
