"""Masked SpMM Pallas kernel: Z = S @ V with S sparse (paper §4.4).

The paper replicates V rows across crossbars according to the mask so each
output row finishes in one VMM cycle. The TPU analogue: iterate reduction
tiles (k) innermost and skip every k-tile whose mask tile (i, k) is empty —
those are exactly the V rows the paper never maps into an input register.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .sddmm import block_mask_counts


def _spmm_kernel(cnt_ref, s_ref, v_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(cnt_ref[0, 0] > 0)
    def _():
        o_ref[...] += jnp.dot(
            s_ref[...], v_ref[...], preferred_element_type=jnp.float32
        )


def masked_spmm(s, v, mask, block: int = 32):
    """Sparse-dense matmul ``s @ v`` skipping reduction tiles masked empty.

    s: (n, m) — the post-softmax sparse score matrix (zeros off-mask)
    v: (m, dv) — dense value matrix resident in crossbars
    mask: (n, m) — the same pruning mask that shaped ``s``
    """
    n, m = s.shape
    m2, dv = v.shape
    assert m == m2, (s.shape, v.shape)
    assert mask.shape == (n, m), (mask.shape, n, m)
    bm = min(block, n)
    bk = min(block, m)
    bn = min(block, dv)
    assert n % bm == 0 and m % bk == 0 and dv % bn == 0, (n, m, dv, block)
    counts = block_mask_counts(mask, bm, bk)
    return pl.pallas_call(
        _spmm_kernel,
        out_shape=jax.ShapeDtypeStruct((n, dv), jnp.float32),
        grid=(n // bm, dv // bn, m // bk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k)),  # mask tile summary
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=True,
    )(counts, s, v)
