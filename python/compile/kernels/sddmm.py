"""Masked SDDMM Pallas kernel: S = mask . (M @ X^T)  (paper §4.3).

Hardware mapping (DESIGN.md §Hardware-Adaptation): the paper stores each
32-element K^T vector in one 32x32 crossbar and lets a ReCAM scheduler
dispatch only the <alpha, beta_i> coordinates whose mask bit is 1. Here each
(bm, bn) output tile is one "crossbar dispatch"; a per-tile population count
(the ReCAM row-search result) gates the whole tile with ``pl.when`` so fully
masked tiles cost no MXU work — the same irrelevant-token-pair skipping the
ReCAM scheduler performs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def block_mask_counts(mask, bm: int, bn: int):
    """Per-tile nonzero counts of ``mask`` — the ReCAM scheduler summary.

    Returns an (n//bm, m//bn) int32 array; entry (i, j) is the number of
    active mask bits in tile (i, j). Computed once per mask (the ReCAM
    row-search pass) and reused by every SDDMM/SpMM dispatch.
    """
    n, m = mask.shape
    assert n % bm == 0 and m % bn == 0, (mask.shape, bm, bn)
    t = mask.reshape(n // bm, bm, m // bn, bn)
    return jnp.sum((t > 0).astype(jnp.int32), axis=(1, 3))


def _sddmm_kernel(cnt_ref, a_ref, b_ref, mask_ref, o_ref):
    # Zero first: skipped tiles must still produce defined output.
    o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(cnt_ref[0, 0] > 0)
    def _():
        acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] = acc * (mask_ref[...] > 0)


def masked_sddmm(a, b, mask, block: int = 32):
    """Sampled dense-dense matmul: ``mask . (a @ b)`` with tile skipping.

    a: (n, d)   — the M = X @ W_S matrix (rows of Q in the paper's Fig. 8b)
    b: (d, m)   — X^T resident in the write-enable arrays
    mask: (n, m) — binary mask from the pruning phase (ReCAM contents)
    """
    n, d = a.shape
    d2, m = b.shape
    assert d == d2, (a.shape, b.shape)
    assert mask.shape == (n, m), (mask.shape, n, m)
    bm = min(block, n)
    bn = min(block, m)
    assert n % bm == 0 and m % bn == 0, (n, m, block)
    counts = block_mask_counts(mask, bm, bn)
    return pl.pallas_call(
        _sddmm_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        grid=(n // bm, m // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),  # ReCAM tile summary
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),  # full-K row panel
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),  # full-K col panel
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(counts, a, b, mask)
