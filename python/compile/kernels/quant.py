"""Quantization kernels: the paper's Q(x) = round(gamma * x) operator.

SANGER-style prediction pruning (CPSAA eq. 4) computes the approximate score
matrix in low precision. ``quantize`` maps f32 to a small signed integer grid
(kept in f32 storage so the whole pruning graph stays a single HLO module);
``dequantize`` is the inverse scaling Q^-1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BITS = 4


def _quant_kernel(x_ref, o_ref, *, gamma: float, lo: float, hi: float):
    x = x_ref[...]
    q = jnp.clip(jnp.round(x * gamma), lo, hi)
    o_ref[...] = q


def _dequant_kernel(x_ref, o_ref, *, gamma: float):
    o_ref[...] = x_ref[...] / gamma


def _grid_levels(bits: int) -> tuple[float, float]:
    # Symmetric signed grid, e.g. 4-bit -> [-7, 7].
    hi = float(2 ** (bits - 1) - 1)
    return -hi, hi


def quantize(x, gamma: float, bits: int = DEFAULT_BITS, block: int = 32):
    """Q(x): round-and-clip ``x`` onto a ``bits``-bit integer grid.

    Values stay f32 (the integer grid is a subset of f32) so that the
    quantized pruning matmul lowers to ordinary dot ops.
    """
    lo, hi = _grid_levels(bits)
    n, m = x.shape
    bm = min(block, n)
    bn = min(block, m)
    assert n % bm == 0 and m % bn == 0, (x.shape, block)
    kern = functools.partial(_quant_kernel, gamma=gamma, lo=lo, hi=hi)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        grid=(n // bm, m // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x)


def dequantize(x, gamma: float, block: int = 32):
    """Q^-1(x): undo the ``gamma`` scaling of :func:`quantize`."""
    n, m = x.shape
    bm = min(block, n)
    bn = min(block, m)
    assert n % bm == 0 and m % bn == 0, (x.shape, block)
    kern = functools.partial(_dequant_kernel, gamma=gamma)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        grid=(n // bm, m // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x)


def quant_roundtrip(x, gamma: float, bits: int = DEFAULT_BITS, block: int = 32):
    """Q^-1(Q(x)) — the value actually seen by the pruning matmul."""
    return dequantize(quantize(x, gamma, bits=bits, block=block), gamma, block=block)
