"""Masked row-softmax Pallas kernel.

The paper's Softmax Unit (SU, Fig. 6b) normalizes each row of the sparse
score matrix. Masked-out entries must not contribute probability mass, so
they are driven to -inf before the exp; rows whose mask is entirely zero
produce an all-zero row (the corresponding output token attends nowhere,
matching the hardware behaviour of skipping the row entirely).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _masked_softmax_kernel(s_ref, m_ref, o_ref):
    s = s_ref[...]
    mask = m_ref[...]
    gated = jnp.where(mask > 0, s, _NEG_INF)
    row_max = jnp.max(gated, axis=-1, keepdims=True)
    # Rows with no active entries: keep exp argument finite, zero them later.
    safe = jnp.where(row_max <= _NEG_INF / 2, 0.0, row_max)
    e = jnp.exp(gated - safe) * (mask > 0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.where(denom > 0, e / denom, 0.0)


def masked_softmax(s, mask, block_rows: int = 32):
    """Row-wise softmax of ``s`` restricted to positions where ``mask > 0``.

    ``s`` and ``mask`` are (n, m); each grid step owns a full row-block so
    the reduction never crosses blocks (the SU processes a row at a time).
    """
    n, m = s.shape
    assert mask.shape == (n, m), (s.shape, mask.shape)
    bm = min(block_rows, n)
    assert n % bm == 0, (n, block_rows)
    return pl.pallas_call(
        _masked_softmax_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), s.dtype),
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, m), lambda i: (i, 0)),
            pl.BlockSpec((bm, m), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, m), lambda i: (i, 0)),
        interpret=True,
    )(s, mask)
