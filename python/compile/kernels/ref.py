"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

No Pallas, no tiling, no skipping: just the mathematical definition each
kernel must match bit-for-bit (up to float tolerance). pytest/hypothesis
sweeps assert ``kernel(x) == ref(x)`` across shapes, dtypes, and sparsities.
"""

import jax.numpy as jnp


def quantize_ref(x, gamma: float, bits: int = 4):
    hi = float(2 ** (bits - 1) - 1)
    return jnp.clip(jnp.round(x * gamma), -hi, hi)


def dequantize_ref(x, gamma: float):
    return x / gamma


def quant_roundtrip_ref(x, gamma: float, bits: int = 4):
    return dequantize_ref(quantize_ref(x, gamma, bits), gamma)


def masked_softmax_ref(s, mask):
    neg = jnp.float32(-1e30)
    gated = jnp.where(mask > 0, s, neg)
    row_max = jnp.max(gated, axis=-1, keepdims=True)
    safe = jnp.where(row_max <= neg / 2, 0.0, row_max)
    e = jnp.exp(gated - safe) * (mask > 0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.where(denom > 0, e / denom, 0.0)


def masked_sddmm_ref(a, b, mask):
    return (a @ b) * (mask > 0)


def masked_spmm_ref(s, v, mask):
    # The mask only *describes* the sparsity of s; the product is s @ v.
    # Zeroing s off-mask first makes the oracle insensitive to garbage
    # values that a correct kernel would have skipped.
    return jnp.where(mask > 0, s, 0.0) @ v


def dense_attention_ref(x, w_q, w_k, w_v):
    """Vanilla attention (Fig. 1a): softmax(Q K^T / sqrt(d)) V."""
    q = x @ w_q
    k = x @ w_k
    v = x @ w_v
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def cpsaa_attention_ref(x, w_s, w_v, mask, d_k: int):
    """CPSAA calculation mode (eq. 3): S = X W_S X^T, masked softmax, @V."""
    m = x @ w_s
    s = (m @ x.T) / jnp.sqrt(jnp.float32(d_k))
    p = masked_softmax_ref(s, mask)
    v = x @ w_v
    return p @ v


def mask_gen_ref(x, w_s_q, gamma: float, d_k: int, theta: float, bits: int = 4):
    """Pruning mask oracle (eq. 4), given pre-quantized Q(W_S)."""
    qx = quantize_ref(x, gamma, bits)
    s_hat = (qx @ w_s_q @ qx.T) / (gamma * gamma * gamma)
    s_hat = s_hat / jnp.sqrt(jnp.float32(d_k))
    p = masked_softmax_ref(s_hat, jnp.ones_like(s_hat))
    return (p >= theta).astype(jnp.float32)
