"""Layer-1 Pallas kernels for CPSAA sparse attention.

Every kernel is authored for TPU-style tiling (32x32 blocks, mirroring the
paper's 32x32 ReRAM crossbar arrays) but lowered with ``interpret=True`` so
the resulting HLO runs on any PJRT backend, including the rust CPU client.

The mask-gated block skipping in :mod:`sddmm` / :mod:`spmm` is the TPU
analogue of the paper's ReCAM scheduler: the ReCAM row-search that dispatches
only non-zero <alpha, beta_i> coordinates to crossbar input registers becomes
a ``pl.when`` guard on per-block mask population counts.
"""

from .quant import quantize, dequantize, quant_roundtrip
from .softmax import masked_softmax
from .sddmm import masked_sddmm, block_mask_counts
from .spmm import masked_spmm

__all__ = [
    "quantize",
    "dequantize",
    "quant_roundtrip",
    "masked_softmax",
    "masked_sddmm",
    "block_mask_counts",
    "masked_spmm",
]
