"""Build-time compile path for the CPSAA reproduction.

Everything under ``python/compile`` runs exactly once (``make artifacts``):
it authors the Layer-2 JAX model and Layer-1 Pallas kernels, checks them
against pure-jnp oracles, and AOT-lowers them to HLO text the rust Layer-3
coordinator loads via PJRT. Nothing here is imported at serving time.
"""
