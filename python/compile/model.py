"""Layer-2 JAX model: CPSAA-mode sparse attention + encoder graphs.

Implements the paper's calculation mode (§3, Fig. 4c):

    W_S = W_Q @ W_K^T            (pre-folded offline, stored read-only)
    M   = X @ W_S                (one VMM step instead of Q then R)
    S   = mask . (M @ X^T) / sqrt(d_k)     <- SDDMM (L1 kernel)
    P   = masked_softmax(S)                <- SU   (L1 kernel)
    V   = X @ W_V
    Z   = P @ V                            <- SpMM (L1 kernel)

and the PIM pruning phase (§4.2 Step 1, eq. 4):

    mask = Bina(Soft(Q^-1(Q(X) Q(W_S) Q(X^T)) / sqrt(d)))

Every function is pure and jit-lowerable; aot.py turns each into an
artifacts/*.hlo.txt module for the rust runtime.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import (
    masked_sddmm,
    masked_softmax,
    masked_spmm,
    quantize,
)
from .kernels import ref as kref


@dataclass(frozen=True)
class ModelConfig:
    """Shapes and pruning hyper-parameters of one attention layer.

    Defaults follow the paper's evaluation setup: d_model = 512,
    d_k = d_q = 64, batches of 320 embeddings (we default smaller for
    artifact compile time; the rust side treats shapes as config).
    """

    seq_len: int = 128
    d_model: int = 256
    d_k: int = 64
    d_ff: int = 512
    gamma: float = 4.0  # quantization scale for Q(.)
    quant_bits: int = 4
    theta: float = 0.01  # binarization threshold (eq. 1)
    sharpness: float = 4.0  # synthetic-weight attention-logit scale (see init_weights)
    block: int = 32  # crossbar-analogue tile edge

    def validate(self) -> "ModelConfig":
        for name in ("seq_len", "d_model", "d_k", "d_ff"):
            v = getattr(self, name)
            if v % self.block != 0:
                raise ValueError(f"{name}={v} not a multiple of block={self.block}")
        if not 0.0 < self.theta < 1.0:
            raise ValueError(f"theta={self.theta} outside (0, 1)")
        return self


def fold_ws(w_q, w_k):
    """Offline pre-computation W_S = W_Q @ W_K^T (the paper's 4x space /
    N-fold time trade, §3)."""
    return w_q @ w_k.T


def init_weights(cfg: ModelConfig, seed: int = 0):
    """Deterministic synthetic weights standing in for fine-tuned BERT
    weights (see DESIGN.md substitutions).

    ``cfg.sharpness`` scales W_Q so attention logits have std ~ sharpness:
    trained attention is peaked (few relevant token pairs — the very premise
    of sparse attention), whereas raw Gaussian weights would give near-flat
    softmax rows where pruning is meaningless. sharpness=4 reproduces the
    paper's ~0.1 mask density at the default theta.
    """
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(seed), 5)
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_model))
    w_q = jax.random.normal(k1, (cfg.d_model, cfg.d_k), jnp.float32) * scale * cfg.sharpness
    w_k = jax.random.normal(k2, (cfg.d_model, cfg.d_k), jnp.float32) * scale
    w_v = jax.random.normal(k3, (cfg.d_model, cfg.d_model), jnp.float32) * scale
    w_fc1 = jax.random.normal(k4, (cfg.d_model, cfg.d_ff), jnp.float32) * scale
    w_fc2 = jax.random.normal(k5, (cfg.d_ff, cfg.d_model), jnp.float32) * scale
    return {
        "w_q": w_q,
        "w_k": w_k,
        "w_v": w_v,
        "w_s": fold_ws(w_q, w_k),
        "w_fc1": w_fc1,
        "w_fc2": w_fc2,
    }


def mask_gen(x, w_s, cfg: ModelConfig):
    """Pruning phase (Step 1): low-precision score -> softmax -> binarize.

    Uses quantized X and quantized W_S directly (no Q/K intermediates), the
    property that lets Step 1 run concurrently with Step 2 on the hardware.
    Returns the binary mask as f32 {0., 1.}.
    """
    g = cfg.gamma
    qx = quantize(x, g, bits=cfg.quant_bits, block=cfg.block)
    qws = kref.quantize_ref(w_s, g, cfg.quant_bits)  # offline constant
    qxt = qx.T
    # Three quantized factors -> de-quant divides by gamma^3 (Q^-1).
    s_hat = (qx @ qws @ qxt) / (g * g * g)
    s_hat = s_hat / jnp.sqrt(jnp.float32(cfg.d_k))
    p = masked_softmax(s_hat, jnp.ones_like(s_hat), block_rows=cfg.block)
    return (p >= cfg.theta).astype(jnp.float32)


def cpsaa_attention(x, w_s, w_v, mask, cfg: ModelConfig):
    """Attention calculation phase (Steps 2-4) under a given mask."""
    m = x @ w_s  # Step 2: M = X W_S  (ROA VMM)
    v = x @ w_v  # Step 2: V = X W_V  (runs concurrently on hardware)
    s = masked_sddmm(m, x.T, mask, block=cfg.block)  # Step 3
    s = s / jnp.sqrt(jnp.float32(cfg.d_k))
    p = masked_softmax(s, mask, block_rows=cfg.block)
    return masked_spmm(p, v, mask, block=cfg.block)  # Step 4


def sparse_attention(x, w_s, w_v, cfg: ModelConfig):
    """Full CPSAA layer: pruning + masked attention (Steps 1-4)."""
    mask = mask_gen(x, w_s, cfg)
    return cpsaa_attention(x, w_s, w_v, mask, cfg), mask


def dense_attention(x, w_s, w_v, cfg: ModelConfig):
    """CPDAA: the dense-version calculation mode (Fig. 4c without mask)."""
    ones = jnp.ones((x.shape[0], x.shape[0]), jnp.float32)
    return cpsaa_attention(x, w_s, w_v, ones, cfg)


def encoder_layer(x, weights, cfg: ModelConfig):
    """One BERT-style encoder: sparse attention + ISAAC-style FC block,
    each wrapped in residual + RMS normalization (§4.5)."""
    z, mask = sparse_attention(x, weights["w_s"], weights["w_v"], cfg)
    h = _rms_norm(x + z)
    ff = jax.nn.gelu(h @ weights["w_fc1"]) @ weights["w_fc2"]
    return _rms_norm(h + ff), mask


def _rms_norm(x, eps: float = 1e-6):
    scale = jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return x * scale
