//! End-to-end driver: serve batched requests through a BERT-style encoder
//! stack running on the PJRT engine, with per-batch hardware cost from the
//! cycle simulator. This is the full three-layer stack composing:
//!
//!   Pallas kernels (L1) → JAX encoder graph (L2, AOT HLO) → rust
//!   coordinator + PJRT runtime + CPSAA chip simulator (L3).
//!
//! Requires artifacts: `make artifacts` first. Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example bert_inference -- [requests] [layers]`

use std::time::Instant;

use cpsaa::config::SystemConfig;
use cpsaa::coordinator::{Service, ServiceConfig};
use cpsaa::runtime::ArtifactSet;
use cpsaa::tensor::SeededRng;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let requests: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(64);
    let layers: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(4);

    let cfg = SystemConfig::paper();
    let artifact_dir = std::path::PathBuf::from("artifacts");
    let set = ArtifactSet::open(&artifact_dir)?;
    let m = &set.manifest.config;
    println!(
        "== bert_inference: {requests} requests through {layers} encoder layers ==\n\
         artifact shape: seq {} x d_model {} (theta {}, gamma {})",
        m.seq_len, m.d_model, m.theta, m.gamma
    );
    let seq_len = m.seq_len;
    let d_model = m.d_model;
    drop(set);

    let svc = Service::start(
        artifact_dir,
        cfg.hardware.clone(),
        cfg.model.clone(),
        ServiceConfig { layers, ..Default::default() },
    )?;

    // Closed-loop load: 8 caller threads, variable-length requests
    // (mimicking mixed GLUE sequences packed into 320-embedding batches).
    let start = Instant::now();
    let callers = 8usize;
    let mut handles = Vec::new();
    for c in 0..callers {
        let svc = svc.clone();
        let n = requests / callers + usize::from(c < requests % callers);
        handles.push(std::thread::spawn(move || -> anyhow::Result<(usize, f64)> {
            let mut rng = SeededRng::new(c as u64 + 7);
            let mut latency_sum = 0.0;
            for i in 0..n {
                let rows = 8 + rng.gen_range_usize(0, seq_len / 2);
                let x = rng.normal_matrix(rows, d_model, 1.0);
                let resp = svc.infer((c * 10_000 + i) as u64, x)?;
                anyhow::ensure!(resp.hidden.all_finite(), "non-finite output");
                anyhow::ensure!(resp.hidden.rows() == rows, "row mismatch");
                latency_sum += resp.latency.as_secs_f64();
            }
            Ok((n, latency_sum))
        }));
    }
    let mut completed = 0usize;
    for h in handles {
        let (n, _) = h.join().expect("caller panicked")?;
        completed += n;
    }
    let wall = start.elapsed();

    let met = svc.metrics();
    let tokens = met.used_rows;
    println!("\n== results ==");
    println!(
        "completed {completed} requests ({tokens} tokens) in {wall:.2?} → {:.1} req/s, {:.0} tokens/s",
        completed as f64 / wall.as_secs_f64(),
        tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "batches: {} (utilization {:.1}%)",
        met.batches,
        met.batch_utilization() * 100.0
    );
    println!(
        "host latency: mean {:.2?}  p50 {:.2?}  p99 {:.2?}",
        met.latency.mean(),
        met.latency.quantile(0.5),
        met.latency.quantile(0.99)
    );
    println!(
        "simulated CPSAA chip: {:.3} ms total, {:.3} mJ — {:.0} GOPS dense-equivalent",
        met.sim_ns / 1e6,
        met.sim_pj * 1e-9,
        // dense-equivalent flops of every simulated layer-batch
        {
            let model = cpsaa::config::ModelConfig {
                seq_len,
                d_model,
                ..cfg.model.clone()
            };
            model.attention_flops() as f64 * (met.batches as f64) * layers as f64
                / 1e9
                / (met.sim_ns * 1e-9)
        }
    );
    Ok(())
}
