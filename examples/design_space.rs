//! Design-space exploration: the ablations DESIGN.md calls out.
//!
//! Sweeps the architectural knobs the paper discusses — crossbar size
//! (Fig. 19a), ADCs per AG (Fig. 18c), write ports, mask density (the
//! sparsity the pruning threshold θ buys), and ReCAM size — and prints
//! latency/energy/area for each point, demonstrating the config system.
//!
//! Run: `cargo run --release --example design_space`

use cpsaa::config::{HardwareConfig, SystemConfig};
use cpsaa::sim::area::AreaModel;
use cpsaa::sim::ChipSim;
use cpsaa::sparse::MaskMatrix;
use cpsaa::tensor::SeededRng;

fn batch_mask(n: usize, density: f64) -> MaskMatrix {
    MaskMatrix::from_dense(&SeededRng::new(9).mask_matrix(n, n, density))
}

fn main() {
    let cfg = SystemConfig::paper();
    let n = cfg.model.seq_len;
    let mask = batch_mask(n, 0.1);

    println!("== crossbar size (Fig. 19a axis) ==");
    println!("{:>8} {:>12} {:>12} {:>12}", "size", "latency_us", "energy_uJ", "area_mm2");
    for c in [32usize, 64, 128, 256] {
        let hw = HardwareConfig { crossbar_size: c, ..cfg.hardware.clone() };
        let sim = ChipSim::new(hw.clone(), cfg.model.clone());
        let r = sim.simulate_batch(&mask);
        let area = AreaModel::build(&hw);
        println!(
            "{:>8} {:>12.2} {:>12.2} {:>12.2}",
            format!("{c}x{c}"),
            r.breakdown.total_ns / 1e3,
            r.energy_pj / 1e6,
            area.chip_area_mm2
        );
    }

    println!("\n== ADCs per arrays-group (Fig. 18c axis) ==");
    println!("{:>8} {:>12} {:>12}", "adcs", "latency_us", "GOPS");
    for adcs in [1usize, 2, 4, 12] {
        let hw = HardwareConfig { adcs_per_ag: adcs, ..cfg.hardware.clone() };
        let sim = ChipSim::new(hw, cfg.model.clone());
        let r = sim.simulate_batch(&mask);
        println!("{:>8} {:>12.2} {:>12.0}", adcs, r.breakdown.total_ns / 1e3, r.gops);
    }

    println!("\n== mask density (what the pruning threshold buys) ==");
    println!("{:>8} {:>12} {:>12} {:>12}", "density", "latency_us", "energy_uJ", "GOPS");
    for d in [0.02, 0.05, 0.1, 0.2, 0.5, 1.0] {
        let sim = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
        let r = sim.simulate_batch(&batch_mask(n, d));
        println!(
            "{:>8.2} {:>12.2} {:>12.2} {:>12.0}",
            d,
            r.breakdown.total_ns / 1e3,
            r.energy_pj / 1e6,
            r.gops
        );
    }

    println!("\n== tiles (chip scale-out) ==");
    println!("{:>8} {:>12} {:>12} {:>12}", "tiles", "latency_us", "area_mm2", "power_W");
    for tiles in [16usize, 32, 64, 128] {
        let hw = HardwareConfig { tiles, ..cfg.hardware.clone() };
        let sim = ChipSim::new(hw.clone(), cfg.model.clone());
        let r = sim.simulate_batch(&mask);
        let area = AreaModel::build(&hw);
        println!(
            "{:>8} {:>12.2} {:>12.2} {:>12.2}",
            tiles,
            r.breakdown.total_ns / 1e3,
            area.chip_area_mm2,
            area.chip_power_w()
        );
    }
}
