//! GLUE/SQuAD sweep: the paper's §6.1 headline experiment as an example.
//!
//! Simulates every evaluation dataset on CPSAA and all five comparison
//! platforms, printing the Fig. 11/12 normalized factors plus absolute
//! GOPS / GOPS/W — the numbers behind the paper's abstract.
//!
//! Run: `cargo run --release --example glue_sweep`

use cpsaa::baselines::{asic, device, pim, Platform};
use cpsaa::config::SystemConfig;
use cpsaa::sim::ChipSim;
use cpsaa::workload::TraceGenerator;

fn main() {
    let cfg = SystemConfig::paper();
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let cpsaa = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
    let platforms: Vec<Box<dyn Platform>> = vec![
        Box::new(device::Gpu::default()),
        Box::new(device::Fpga::default()),
        Box::new(asic::Sanger::default()),
        Box::new(asic::Dota::default()),
        Box::new(pim::ReBert::new(cfg.hardware.clone())),
        Box::new(pim::ReTransformer::new(cfg.hardware.clone())),
    ];

    println!(
        "{:<8} {:>10} {:>10} | {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "dataset", "GOPS", "GOPS/W", "GPU", "FPGA", "SANGER", "DOTA", "ReBERT", "ReTran"
    );
    let mut mean = vec![0.0f64; platforms.len()];
    let n_ds = cfg.workload.datasets.len() as f64;
    for ds in &cfg.workload.datasets {
        let trace = gen.generate(ds);
        let batch = &trace.batches[0];
        let c = cpsaa.simulate_batch(&batch.mask);
        let mut factors = Vec::new();
        for (i, p) in platforms.iter().enumerate() {
            let r = p.run_batch(&cfg.model, &batch.stats());
            let f = r.total_ns / c.breakdown.total_ns;
            mean[i] += f / n_ds;
            factors.push(f);
        }
        print!("{:<8} {:>10.0} {:>10.1} |", ds.name, c.gops, c.gops_per_watt);
        for f in factors {
            print!(" {f:>8.1}");
        }
        println!();
    }
    print!("{:<8} {:>10} {:>10} |", "MEAN", "", "");
    for f in &mean {
        print!(" {f:>8.1}");
    }
    println!();
    println!("\npaper means (time, Fig. 11): GPU 89.6, FPGA 32.2, SANGER 17.8, ReBERT 3.39, ReTransformer 3.84");
}
