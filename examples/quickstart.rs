//! Quickstart: the 60-second tour of the CPSAA reproduction.
//!
//! 1. Build a chip with the paper's Table 2 configuration.
//! 2. Generate a pruning mask with the golden model (eq. 4).
//! 3. Run one batch through the Step 1–4 pipeline simulator.
//! 4. Compare against the dense mode and two baselines.
//!
//! Run: `cargo run --release --example quickstart`
//! (no artifacts needed — this example is simulator-only; see
//! `bert_inference` for the PJRT path.)

use cpsaa::attention::{self, Weights};
use cpsaa::baselines::{pim, Platform};
use cpsaa::config::SystemConfig;
use cpsaa::sim::ChipSim;
use cpsaa::tensor::SeededRng;
use cpsaa::workload::BatchStats;

fn main() {
    let cfg = SystemConfig::paper();
    println!("== CPSAA quickstart ==");
    println!(
        "chip: {} tiles / {}x{} crossbars / {} arrays",
        cfg.hardware.tiles,
        cfg.hardware.crossbar_size,
        cfg.hardware.crossbar_size,
        cfg.hardware.total_arrays()
    );

    // --- Step 1 (functional): generate a pruning mask ----------------------
    let model = cpsaa::config::ModelConfig { seq_len: 128, d_model: 256, ..cfg.model.clone() };
    let weights = Weights::synthetic(&model, 0);
    let x = SeededRng::new(42).normal_matrix(model.seq_len, model.d_model, 1.0);
    let mask = attention::generate_mask(&x, &weights.w_s, &model);
    println!(
        "pruning mask: {}x{} density {:.3} (paper regime ~0.1)",
        mask.rows(),
        mask.cols(),
        mask.density()
    );

    // --- functional sparse attention vs dense ------------------------------
    let z_sparse = attention::cpsaa_attention(&x, &weights.w_s, &weights.w_v, &mask, &model);
    let z_dense = attention::dense_attention(&x, &weights.w_s, &weights.w_v, &model);
    println!("output fidelity vs dense: rel err {:.4}", z_sparse.rel_err(&z_dense));

    // --- cycle simulation ----------------------------------------------------
    let sim = ChipSim::new(cfg.hardware.clone(), model.clone());
    let sparse = sim.simulate_batch(&mask);
    let dense = ChipSim::new(cfg.hardware.clone(), model.clone()).dense().simulate_batch(&mask);
    println!("\n== simulated batch latency ==");
    println!("CPSAA (sparse): {:>10.2} us  {:>8.0} GOPS", sparse.breakdown.total_ns / 1e3, sparse.gops);
    println!("CPDAA (dense):  {:>10.2} us  {:>8.0} GOPS", dense.breakdown.total_ns / 1e3, dense.gops);

    // --- two baselines --------------------------------------------------------
    let stats = BatchStats {
        seq_len: model.seq_len,
        d_model: model.d_model,
        mask_nnz: mask.nnz(),
        mask_density: mask.density(),
    };
    println!("\n== baselines (same batch) ==");
    for p in [
        &pim::ReBert::new(cfg.hardware.clone()) as &dyn Platform,
        &pim::ReTransformer::new(cfg.hardware.clone()),
    ] {
        let r = p.run_batch(&model, &stats);
        println!(
            "{:<14} {:>10.2} us  {:>8.0} GOPS  ({:.2}x slower than CPSAA)",
            r.name,
            r.total_ns / 1e3,
            r.gops,
            r.total_ns / sparse.breakdown.total_ns
        );
    }
    println!("\nNext: `cargo run --release --example bert_inference` (end-to-end PJRT).");
}
