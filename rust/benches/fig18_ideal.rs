//! Bench: Fig. 18 regeneration (ideal-situation study).

use cpsaa::bench_harness::fig18;
use cpsaa::config::SystemConfig;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("fig18");
    b.run("ideal_knobs", || fig18::run(&cfg));
    println!("{}", fig18::run(&cfg));
    b.finish();
}
