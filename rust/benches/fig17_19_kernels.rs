//! Bench: Figs. 17 & 19 regeneration (SDDMM/SpMM engine studies).

use cpsaa::bench_harness::{fig17, fig19};
use cpsaa::config::SystemConfig;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("fig17_19");
    b.run("fig17_vs_ddmm", || fig17::run(&cfg));
    b.run("fig19a_crossbar_sweep", || fig19::run_a(&cfg));
    b.run("fig19b_spmm_tradeoff", || fig19::run_b(&cfg));
    println!("{}", fig17::run(&cfg));
    println!("{}", fig19::run_a(&cfg));
    println!("{}", fig19::run_b(&cfg));
    b.finish();
}
