//! Bench: Figs. 11 & 12 regeneration (platform comparison, 9 datasets).

use cpsaa::bench_harness::fig11_12;
use cpsaa::config::SystemConfig;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("fig11_12");
    b.run("time_normalized", || fig11_12::run_time(&cfg));
    b.run("energy_normalized", || fig11_12::run_energy(&cfg));
    println!("{}", fig11_12::run_time(&cfg));
    println!("{}", fig11_12::run_energy(&cfg));
    b.finish();
}
