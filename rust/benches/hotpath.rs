//! Bench: L3 hot paths — the targets of the §Perf optimization pass.
//!
//! Measures the simulator primitives (mask scan, SDDMM/SpMM dispatch,
//! full pipeline), the golden-model matmul, and — when artifacts exist —
//! the PJRT execute path the coordinator runs per batch.

use cpsaa::attention::{self, Weights};
use cpsaa::config::{ModelConfig, SystemConfig};
use cpsaa::runtime::{ArtifactSet, Engine};
use cpsaa::sim::{sddmm, spmm, ChipSim};
use cpsaa::sparse::MaskMatrix;
use cpsaa::tensor::SeededRng;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("hotpath");
    let n = cfg.model.seq_len;
    let mask = MaskMatrix::from_dense(&SeededRng::new(1).mask_matrix(n, n, 0.1));

    // -- simulator primitives ------------------------------------------------
    b.run("mask_row_coords_320", || {
        let mut total = 0usize;
        for i in 0..mask.rows() {
            total += mask.row_coords(i).len();
        }
        total
    });
    b.run("mask_block_counts_320", || mask.block_counts(32, 32).nonzero_tiles());
    b.run("sddmm_dispatch_320x512", || sddmm::simulate(&cfg.hardware, &mask, 512).cycles);
    b.run("spmm_dispatch_320x512", || spmm::simulate(&cfg.hardware, &mask, 512).cycles);

    let sim = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
    b.run("pipeline_batch_sparse", || sim.simulate_batch(&mask).breakdown.total_ns);

    // -- golden model ----------------------------------------------------------
    let model = ModelConfig { seq_len: 128, d_model: 256, ..cfg.model.clone() };
    let w = Weights::synthetic(&model, 0);
    let x = SeededRng::new(2).normal_matrix(model.seq_len, model.d_model, 1.0);
    b.run("golden_mask_gen_128x256", || attention::generate_mask(&x, &w.w_s, &model).nnz());
    let gmask = attention::generate_mask(&x, &w.w_s, &model);
    b.run("golden_sparse_attention_128x256", || {
        attention::cpsaa_attention(&x, &w.w_s, &w.w_v, &gmask, &model).norm()
    });
    b.run("golden_dense_attention_128x256", || {
        attention::dense_attention(&x, &w.w_s, &w.w_v, &model).norm()
    });

    // -- PJRT path (needs artifacts) --------------------------------------------
    let dir = std::path::PathBuf::from("artifacts");
    if let Ok(set) = ArtifactSet::open(&dir) {
        let engine = Engine::load(&set).expect("engine");
        let fix = set.fixtures().expect("fixtures");
        let wj = Weights::from_json_file(&set.dir.join("weights.json")).expect("weights");
        b.run("pjrt_mask_gen", || engine.execute("mask_gen", &[&fix.x, &wj.w_s]).unwrap().len());
        b.run("pjrt_sparse_attention", || {
            engine.execute("sparse_attention", &[&fix.x, &wj.w_s, &wj.w_v]).unwrap().len()
        });
        b.run("pjrt_encoder_layer", || {
            engine
                .execute("encoder", &[&fix.x, &wj.w_s, &wj.w_v, &wj.w_fc1, &wj.w_fc2])
                .unwrap()
                .len()
        });
    } else {
        println!("(artifacts missing — skipping PJRT benches; run `make artifacts`)");
    }
    b.finish();
}
