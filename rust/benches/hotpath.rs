//! Bench: L3 hot paths — the targets of the perf optimization pass.
//!
//! Centerpiece: the DispatchPlan economics. `plan_build` prices the one
//! ReCAM scan; the `*_scan_per_call` / `*_plan_reuse` pairs show every
//! consumer (attention kernel, SDDMM/SpMM dispatch simulators, full
//! pipeline) with and without plan amortization on the paper workload
//! (320×320 mask @ 0.1 density). Numbers land in `target/bench/hotpath.json`.

use cpsaa::attention::{self, ops, MultiHeadWeights, QuantizedRows, Weights};
use cpsaa::config::{ModelConfig, SystemConfig};
use cpsaa::coordinator::{EncoderStack, Service, ServiceConfig};
use cpsaa::runtime::{executor, ArtifactSet, Engine};
use cpsaa::sim::{pipeline, sddmm, spmm, ChipSim};
use cpsaa::sparse::{CsrMatrix, DispatchPlan, MaskMatrix, PlanSet, PruneConfig};
use cpsaa::tensor::{simd, Matrix, SeededRng};
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("hotpath");
    let n = cfg.model.seq_len; // 320
    let d = cfg.model.d_model; // 512
    let mask = MaskMatrix::from_dense(&SeededRng::new(1).mask_matrix(n, n, 0.1));

    // -- the plan itself -----------------------------------------------------
    b.run("plan_build_320", || mask.plan().nnz());
    let plan = mask.plan();
    b.run("plan_stats_read_320", || {
        plan.grouped_max_queue(1) + plan.blocks().nonzero_tiles() as u64
    });

    // -- simulator primitives: per-call scan vs. plan reuse ------------------
    b.run("sddmm_dispatch_scan_per_call", || sddmm::simulate(&cfg.hardware, &mask, d).cycles);
    b.run("sddmm_dispatch_plan_reuse", || sddmm::simulate_plan(&cfg.hardware, &plan, d).cycles);
    b.run("spmm_dispatch_scan_per_call", || spmm::simulate(&cfg.hardware, &mask, d).cycles);
    b.run("spmm_dispatch_plan_reuse", || spmm::simulate_plan(&cfg.hardware, &plan, d).cycles);

    let sim = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
    b.run("pipeline_batch_scan_per_call", || sim.simulate_batch(&mask).breakdown.total_ns);
    b.run("pipeline_batch_plan_reuse", || sim.simulate_batch_planned(&plan).breakdown.total_ns);

    // -- golden attention kernel on the paper workload -----------------------
    // Three rungs of the same computation:
    //   * per-call dense round-trip — the *shape* of the seed algorithm:
    //     x-transpose copy, dense S buffer, dense scale, separate CSR
    //     compression, and two throwaway plan builds standing in for the
    //     seed's two per-call mask walks (the original scan code is gone);
    //   * scan-per-call — today's kernel building its plan inside the call;
    //   * plan-reuse — what the coordinator runs per layer after building
    //     the batch plan once.
    let w = Weights::synthetic(&cfg.model, 0);
    let x = SeededRng::new(2).normal_matrix(n, d, 1.0);
    let dense_roundtrip = || {
        let m = x.matmul(&w.w_s);
        let v = x.matmul(&w.w_v);
        let s = ops::masked_sddmm(&m, &x.transpose(), &mask)
            .scale(1.0 / (cfg.model.d_k as f32).sqrt());
        let mut p = CsrMatrix::from_dense_masked(&s, &mask);
        p.softmax_rows();
        p.spmm(&v).norm()
    };
    let seed_shape = b.run("attention_320x512_per_call_dense_roundtrip", dense_roundtrip);
    b.run("attention_320x512_scan_per_call", || {
        attention::cpsaa_attention(&x, &w.w_s, &w.w_v, &mask, &cfg.model).norm()
    });
    let reuse = b.run("attention_320x512_plan_reuse", || {
        ops::cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg.model).norm()
    });
    println!(
        "attention plan reuse vs seed-shaped per-call dense round-trip: {:.2}x",
        seed_shape.as_secs_f64() / reuse.as_secs_f64().max(1e-12)
    );
    let m_for_csr = x.matmul(&w.w_s);
    b.run("csr_from_plan_320", || CsrMatrix::from_plan(&plan, &m_for_csr).nnz());

    // -- fused row-streaming kernel vs the unfused four-pass chain -----------
    // Same plan, same workload: the fused rung streams SDDMM → scale →
    // softmax → SpMM per row (zero-copy CsrView topology, workspace
    // buffers); the unfused rung is the pre-fusion chain over an owned
    // CSR. Bit-identical outputs (property-tested); CI asserts the
    // fused median beats the unfused one in the same run
    // (`cpsaa bench-assert-faster`).
    let fused_t = b.run("attention_320x512_fused_plan_reuse", || {
        ops::cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg.model).norm()
    });
    let unfused_t = b.run("attention_320x512_unfused_plan_reuse", || {
        ops::cpsaa_attention_unfused(&x, &w.w_s, &w.w_v, &plan, &cfg.model).norm()
    });
    println!(
        "fused row-streaming vs unfused 4-pass attention: {:.2}x",
        unfused_t.as_secs_f64() / fused_t.as_secs_f64().max(1e-12)
    );
    let enc_fused = b.run("encoder_layer_320x512_fused", || {
        ops::encoder_layer_planned(&x, &w, &plan, &cfg.model).norm()
    });
    let enc_unfused = b.run("encoder_layer_320x512_unfused", || {
        ops::encoder_layer_unfused(&x, &w, &plan, &cfg.model).norm()
    });
    println!(
        "fused+workspace vs unfused encoder layer: {:.2}x",
        enc_unfused.as_secs_f64() / enc_fused.as_secs_f64().max(1e-12)
    );

    // -- SIMD row primitives vs their bit-identical scalar twins -------------
    // The same fused plan-reuse kernel with the `tensor::simd` lane
    // switch flipped both ways: the `simd` rung runs the 8-lane unrolled
    // primitives, the `scalar` rung forces the element-at-a-time twins
    // (same FP operation DAG, so same bits — only throughput moves). CI
    // asserts the simd rung beats the scalar one same-run
    // (`cpsaa bench-assert-faster`).
    simd::set_force_scalar(false);
    let simd_t = b.run("attention_320x512_simd", || {
        ops::cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg.model).norm()
    });
    simd::set_force_scalar(true);
    let scalar_t = b.run("attention_320x512_scalar", || {
        ops::cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg.model).norm()
    });
    simd::set_force_scalar(simd::env_force_scalar());
    println!(
        "8-lane simd vs forced-scalar attention: {:.2}x",
        scalar_t.as_secs_f64() / simd_t.as_secs_f64().max(1e-12)
    );

    // -- i8-storage / i32-accumulate SDDMM vs the f32 path -------------------
    // Same plan topology, operands pre-quantized outside the timer (the
    // serving stack quantizes once per batch): the i8 rung moves a
    // quarter of the bytes per dot and accumulates exactly in i32. CI
    // asserts the i8 rung beats the f32 one same-run.
    let qa = QuantizedRows::from_matrix(&m_for_csr);
    let qx = QuantizedRows::from_matrix(&x);
    let f32_sddmm = b.run("sddmm_f32_320x512", || ops::sddmm_csr(&m_for_csr, &x, &plan).nnz());
    let i8_sddmm =
        b.run("sddmm_i8_320x512", || ops::sddmm_csr_i8_quantized(&qa, &qx, &plan).nnz());
    println!(
        "i8-storage/i32-accumulate vs f32 SDDMM: {:.2}x",
        f32_sddmm.as_secs_f64() / i8_sddmm.as_secs_f64().max(1e-12)
    );

    // -- u32 vs usize coordinate stream --------------------------------------
    // The plan's native u32 ⟨α, βᵢ⟩ stream against the same stream
    // widened to usize (the pre-narrowing layout, built outside the
    // timer): one gather per coordinate, so the delta is pure
    // memory-traffic width. Denser 512×512 mask so the stream spills L2.
    let wide_mask = MaskMatrix::from_dense(&SeededRng::new(7).mask_matrix(512, 512, 0.5));
    let wide_plan = wide_mask.plan();
    let widened: Vec<usize> = wide_plan.col_idx().iter().map(|&j| j as usize).collect();
    let probe: Vec<f32> = (0..512).map(|j| (j as f32).sin()).collect();
    b.run("coord_stream_u32_gather", || {
        wide_plan.col_idx().iter().map(|&j| probe[j as usize]).sum::<f32>()
    });
    b.run("coord_stream_usize_gather", || widened.iter().map(|&j| probe[j]).sum::<f32>());

    // -- multi-head fan-out (plan-reuse mode): 1 vs 8 heads ------------------
    // Same paper workload; the 8-head rung runs 8 concurrent per-head
    // kernels over a prebuilt PlanSet (one plan per head), the 1-head
    // rung is the degenerate set. CI asserts both rungs exist in the
    // JSON dump so head-fan-out regressions stay visible per-PR.
    let cfg1 = ModelConfig { heads: 1, ..cfg.model.clone() };
    let cfg8 = ModelConfig { heads: 8, ..cfg.model.clone() };
    let mh1 = MultiHeadWeights::synthetic(&cfg1, 0);
    let mh8 = MultiHeadWeights::synthetic(&cfg8, 0);
    let plans1 = PlanSet::build(&attention::generate_head_masks(&x, &mh1, &cfg1));
    let plans8 = PlanSet::build(&attention::generate_head_masks(&x, &mh8, &cfg8));
    let t1 = b.run("attention_320x512_heads1_plan_reuse", || {
        ops::multi_head_attention_planned(&x, &mh1, &plans1, &cfg1).norm()
    });
    let t8 = b.run("attention_320x512_heads8_plan_reuse", || {
        ops::multi_head_attention_planned(&x, &mh8, &plans8, &cfg8).norm()
    });
    println!(
        "8-head fan-out vs 1 head (8x the kernel work, concurrent heads): {:.2}x wall",
        t8.as_secs_f64() / t1.as_secs_f64().max(1e-12)
    );

    // -- batch-parallel sharding (plan-reuse mode): 1 vs 4 logical chips -----
    // Same paper workload and plan set; the shards4 rung partitions the
    // 320 batch rows into 4 nnz-balanced slices (PlanSet::shard) and
    // runs them concurrently against the full keys — the serving
    // layer's `--shards` fan-out. The shards1 rung is the degenerate
    // single-chip partition. CI asserts both rungs exist in the JSON
    // dump so batch-parallel regressions stay visible per-PR.
    let sharded1 = plans1.shard(1);
    let sharded4 = plans1.shard(4);
    let s1 = b.run("attention_320x512_shards1_plan_reuse", || {
        ops::multi_head_attention_sharded(&x, &mh1, &sharded1, &cfg1).norm()
    });
    let s4 = b.run("attention_320x512_shards4_plan_reuse", || {
        ops::multi_head_attention_sharded(&x, &mh1, &sharded4, &cfg1).norm()
    });
    println!(
        "4-shard batch parallelism vs 1 shard (same work, 4 concurrent row slices): {:.2}x wall",
        s4.as_secs_f64() / s1.as_secs_f64().max(1e-12)
    );

    // -- persistent executor pool vs per-call scoped spawns ------------------
    // The same (head × row-range) task grid — 8 heads × 4 nnz-balanced
    // row slices, each an independent serial SDDMM over its sliced plan
    // — dispatched two ways: the `pool` rung claims tasks from the
    // long-lived executor (what every kernel now does), the `spawn`
    // rung re-creates the pre-executor nested model per call (one
    // scoped OS thread per head, each scope-spawning one thread per row
    // range: 40 thread creations per call, oversubscribed). Identical
    // kernels and work on both sides; the delta is pure
    // thread-creation + oversubscription cost, which the persistent
    // pool deletes. CI asserts the pool rung beats the spawn rung
    // same-run (`cpsaa bench-assert-faster`).
    struct GridTask {
        m_block: Matrix,
        plan: DispatchPlan,
    }
    let spawn_fanout = 4usize;
    let grid: Vec<Vec<GridTask>> = (0..8)
        .map(|h| {
            let m_h = x.matmul(&mh8.heads[h].w_s);
            let plan_h = plans8.plan(h);
            plan_h
                .partition_rows(spawn_fanout)
                .into_iter()
                .map(|r| GridTask {
                    m_block: m_h.row_block(r.start, r.end),
                    plan: plan_h.slice_rows(r.clone()),
                })
                .collect()
        })
        .collect();
    let flat: Vec<&GridTask> = grid.iter().flatten().collect();
    let exec = executor::global();
    let pool_t = b.run("attention_320x512_pool", || {
        exec.map(&flat, |t| ops::sddmm_csr(&t.m_block, &x, &t.plan).nnz())
            .iter()
            .sum::<usize>()
    });
    let spawn_t = b.run("attention_320x512_spawn", || {
        let xr = &x;
        std::thread::scope(|s| {
            let heads: Vec<_> = grid
                .iter()
                .map(|head_tasks| {
                    s.spawn(move || {
                        std::thread::scope(|s2| {
                            let ranges: Vec<_> = head_tasks
                                .iter()
                                .map(|t| {
                                    s2.spawn(move || ops::sddmm_csr(&t.m_block, xr, &t.plan).nnz())
                                })
                                .collect();
                            ranges.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
                        })
                    })
                })
                .collect();
            heads.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
        })
    });
    println!(
        "persistent pool vs nested scoped spawns (same task grid): {:.2}x",
        spawn_t.as_secs_f64() / pool_t.as_secs_f64().max(1e-12)
    );

    // -- serving: 1 vs 4 leader threads --------------------------------------
    // End-to-end serve throughput on synthesized artifacts: 8 concurrent
    // single-batch requests against a 1-leader and a 4-leader service
    // (both feeding the one executor pool). CI asserts both rungs exist
    // so multi-leader regressions stay visible per-PR.
    let serve_model = ModelConfig {
        seq_len: 32,
        d_model: 64,
        d_k: 8,
        d_ff: 128,
        heads: 2,
        ..cfg.model.clone()
    };
    let serve_dir =
        std::env::temp_dir().join(format!("cpsaa-bench-leaders-{}", std::process::id()));
    ArtifactSet::synthesize(&serve_dir, &serve_model, 3).expect("synthesize serve artifacts");
    let leaders_svc = |leaders: usize| {
        Service::start(
            serve_dir.clone(),
            cfg.hardware.clone(),
            serve_model.clone(),
            ServiceConfig {
                layers: 1,
                leaders,
                max_wait: std::time::Duration::from_millis(1),
                ..Default::default()
            },
        )
        .expect("start bench service")
    };
    let svc1 = leaders_svc(1);
    let svc4 = leaders_svc(4);
    let fire = |svc: &Service| {
        let mut clients = Vec::new();
        for id in 0..8u64 {
            let svc = svc.clone();
            clients.push(std::thread::spawn(move || {
                let mut rng = SeededRng::new(id + 1);
                let x = rng.normal_matrix(32, 64, 1.0);
                svc.infer(id, x).expect("bench request").hidden.norm()
            }));
        }
        clients.into_iter().map(|c| c.join().unwrap()).sum::<f32>()
    };
    let l1 = b.run("serve_leaders1", || fire(&svc1));
    let l4 = b.run("serve_leaders4", || fire(&svc4));
    println!(
        "4 leader threads vs 1 (8 concurrent single-batch requests): {:.2}x wall",
        l4.as_secs_f64() / l1.as_secs_f64().max(1e-12)
    );

    // -- serving: plan prefetch + content-addressed cache on vs off ----------
    // The PR-10 tentpole gate: a repeated-shape stream of full-seq_len
    // payloads (each request seals its own batch, so window composition
    // is identical on both sides) served with the stage-overlapped plan
    // pipeline on and off. With prefetch on, every repeat is a plan-cache
    // hit — mask generation and the ReCAM scan never run; with it off,
    // every batch rebuilds its plans inline. Responses are bit-identical
    // either way; CI asserts the on rung beats the off rung same-run
    // (`cpsaa bench-assert-faster`).
    let pf_svc = |prefetch: bool| {
        Service::start(
            serve_dir.clone(),
            cfg.hardware.clone(),
            serve_model.clone(),
            ServiceConfig {
                layers: 1,
                prefetch,
                max_wait: std::time::Duration::from_millis(1),
                ..Default::default()
            },
        )
        .expect("start prefetch bench service")
    };
    let svc_on = pf_svc(true);
    let svc_off = pf_svc(false);
    let x_rep = SeededRng::new(21).normal_matrix(32, 64, 1.0);
    let stream = |svc: &Service| {
        let mut acc = 0.0f32;
        for id in 0..4u64 {
            acc += svc.infer(id, x_rep.clone()).expect("bench request").hidden.norm();
        }
        acc
    };
    let pf_on = b.run("serve_prefetch_on", || stream(&svc_on));
    let pf_off = b.run("serve_prefetch_off", || stream(&svc_off));
    println!(
        "plan prefetch + cache vs inline plan builds (repeated-shape stream): {:.2}x",
        pf_off.as_secs_f64() / pf_on.as_secs_f64().max(1e-12)
    );
    drop(svc_on);
    drop(svc_off);
    std::fs::remove_dir_all(&serve_dir).ok();

    // -- cascade plan narrowing: 4-layer stack, static vs cascade:0.5 --------
    // The PR-9 tentpole gate: the same 4-layer encoder stack run twice,
    // once on static per-batch plans (every layer pays full nnz) and
    // once under `--prune cascade:0.5` (layer 0 scans, deeper layers run
    // on the top-k narrowed coordinate stream with half the tokens and
    // half the heads — fully-pruned heads skip their dense projections
    // too). Distinct per-head weights so the static side pays the real
    // per-head score passes it would serve with. CI asserts the cascade
    // rung beats the static rung same-run (`cpsaa bench-assert-faster`).
    let casc_model = ModelConfig {
        seq_len: 256,
        d_model: 64,
        d_k: 16,
        d_ff: 64,
        heads: 4,
        ..cfg.model.clone()
    };
    let casc_dir =
        std::env::temp_dir().join(format!("cpsaa-bench-cascade-{}", std::process::id()));
    let casc_set =
        ArtifactSet::synthesize(&casc_dir, &casc_model, 9).expect("synthesize cascade artifacts");
    let casc_engine = Engine::load(&casc_set).expect("load cascade engine");
    let casc_w = MultiHeadWeights::synthetic(&casc_model, 4);
    let static_stack = EncoderStack::new(
        &casc_engine,
        casc_w.clone(),
        cfg.hardware.clone(),
        casc_model.clone(),
        4,
    );
    let cascade_stack =
        EncoderStack::new(&casc_engine, casc_w, cfg.hardware.clone(), casc_model.clone(), 4)
            .with_prune(PruneConfig::cascade(0.5));
    let xs = SeededRng::new(11).normal_matrix(256, 64, 1.0);
    let stat_t = b.run("encoder_stack4_static", || {
        static_stack.forward(&xs).unwrap().last().unwrap().hidden.norm()
    });
    let casc_t = b.run("encoder_stack4_cascade50", || {
        cascade_stack.forward(&xs).unwrap().last().unwrap().hidden.norm()
    });
    println!(
        "cascade:0.5 narrowed plans vs static plans (4-layer stack): {:.2}x",
        stat_t.as_secs_f64() / casc_t.as_secs_f64().max(1e-12)
    );
    std::fs::remove_dir_all(&casc_dir).ok();

    // -- golden model end-to-end (pruning + attention) -----------------------
    let model = cpsaa::config::ModelConfig { seq_len: 128, d_model: 256, ..cfg.model.clone() };
    let wm = Weights::synthetic(&model, 0);
    let xm = SeededRng::new(3).normal_matrix(model.seq_len, model.d_model, 1.0);
    b.run("golden_mask_gen_128x256", || attention::generate_mask(&xm, &wm.w_s, &model).nnz());
    b.run("golden_dense_attention_128x256", || {
        attention::dense_attention(&xm, &wm.w_s, &wm.w_v, &model).norm()
    });

    // -- dense-mode pipeline sanity point ------------------------------------
    b.run("pipeline_batch_dense_mode", || {
        pipeline::simulate_batch(&cfg.hardware, &cfg.model, &mask, pipeline::Mode::Dense)
            .breakdown
            .total_ns
    });

    b.finish();
}
