//! Bench: Fig. 20 regeneration (scalability studies).

use cpsaa::bench_harness::fig20;
use cpsaa::config::SystemConfig;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("fig20");
    b.run("fig20a_dataset_size", || fig20::run_a(&cfg));
    b.run("fig20b_encoder_layers", || fig20::run_b(&cfg));
    println!("{}", fig20::run_a(&cfg));
    println!("{}", fig20::run_b(&cfg));
    b.finish();
}
