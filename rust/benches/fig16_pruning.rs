//! Bench: Fig. 16 regeneration (PIM pruning vs SANGER).

use cpsaa::bench_harness::fig16;
use cpsaa::config::SystemConfig;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("fig16");
    b.run("pruning_comparison", || fig16::run(&cfg));
    println!("{}", fig16::run(&cfg));
    b.finish();
}
