//! Bench: Fig. 3 regeneration (SANGER/DOTA response-time breakdown).

use cpsaa::bench_harness::fig03;
use cpsaa::config::SystemConfig;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("fig03");
    b.run("sanger_dota_breakdown", || fig03::run(&cfg));
    println!("{}", fig03::run(&cfg));
    b.finish();
}
