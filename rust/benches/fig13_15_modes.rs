//! Bench: Figs. 13–15 regeneration (calculation-mode studies).

use cpsaa::bench_harness::fig13_15;
use cpsaa::config::SystemConfig;
use cpsaa::util::bench::Bencher;

fn main() {
    let cfg = SystemConfig::paper();
    let mut b = Bencher::new("fig13_15");
    b.run("fig13_hybrids", || fig13_15::run_fig13(&cfg));
    b.run("fig14_cpdaa", || fig13_15::run_fig14(&cfg));
    b.run("fig15_w4w_parallelism", || fig13_15::run_fig15(&cfg));
    println!("{}", fig13_15::run_fig13(&cfg));
    println!("{}", fig13_15::run_fig14(&cfg));
    println!("{}", fig13_15::run_fig15(&cfg));
    b.finish();
}
