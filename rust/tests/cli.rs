//! CLI smoke tests: every subcommand runs against the built binary.

use std::process::Command;

fn cpsaa(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cpsaa"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cpsaa");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn info_prints_table2_budget() {
    let (ok, text) = cpsaa(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("64 tiles"), "{text}");
    assert!(text.contains("Table 2"), "{text}");
}

#[test]
fn simulate_one_dataset() {
    let (ok, text) = cpsaa(&["simulate", "WNLI", "--batches", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("WNLI"), "{text}");
    assert!(text.contains("GOPS"), "{text}");
}

#[test]
fn bench_figure_table2() {
    let (ok, text) = cpsaa(&["bench-figure", "table2"]);
    assert!(ok, "{text}");
    assert!(text.contains("CPSAA"), "{text}");
    assert!(text.contains("PC Total"), "{text}");
}

#[test]
fn bench_figure_unknown_fails() {
    let (ok, text) = cpsaa(&["bench-figure", "fig99"]);
    assert!(!ok);
    assert!(text.contains("unknown figure"), "{text}");
}

#[test]
fn sweep_crossbar() {
    let (ok, text) = cpsaa(&["sweep", "crossbar_size", "32", "64"]);
    assert!(ok, "{text}");
    assert!(text.contains("32") && text.contains("64"), "{text}");
}

#[test]
fn sweep_rejects_bad_param() {
    let (ok, text) = cpsaa(&["sweep", "bogus_knob", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown sweep parameter"), "{text}");
}

#[test]
fn inference_reports_endurance() {
    let (ok, text) = cpsaa(&["inference", "CoLA", "--layers", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("endurance"), "{text}");
    assert!(text.contains("2-encoder"), "{text}");
}

#[test]
fn unknown_command_shows_usage() {
    let (ok, text) = cpsaa(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn config_file_round_trips_through_cli() {
    let (ok, text) = cpsaa(&["--config", "configs/paper.toml", "info"]);
    assert!(ok, "{text}");
    assert!(text.contains("32x32 crossbars"), "{text}");
}

/// Synthesize a small multi-head artifact directory for serve tests.
fn synth_artifacts(tag: &str, heads: usize) -> std::path::PathBuf {
    use cpsaa::config::ModelConfig;
    use cpsaa::runtime::ArtifactSet;
    let dir = std::env::temp_dir().join(format!("cpsaa-cli-{tag}-{}", std::process::id()));
    let model = ModelConfig {
        seq_len: 32,
        d_model: 64,
        d_k: 8,
        d_ff: 128,
        heads,
        ..ModelConfig::default()
    };
    ArtifactSet::synthesize(&dir, &model, 3).unwrap();
    dir
}

#[test]
fn serve_heads_from_config_file_end_to_end() {
    // Config-loader path: [model] heads flows from the TOML through
    // SystemConfig into the served stack.
    let art = synth_artifacts("cfg", 2);
    let cfg_path = std::env::temp_dir()
        .join(format!("cpsaa-cli-heads-{}.toml", std::process::id()));
    std::fs::write(
        &cfg_path,
        "[model]\nseq_len = 32\nd_model = 64\nd_k = 8\nd_ff = 128\nheads = 2\n",
    )
    .unwrap();
    let (ok, text) = cpsaa(&[
        "--config",
        cfg_path.to_str().unwrap(),
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("2 heads"), "{text}");
    assert!(text.contains("served 2 requests"), "{text}");
    std::fs::remove_file(&cfg_path).ok();
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_heads_flag_overrides_config() {
    let art = synth_artifacts("flag", 8);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
        "--heads",
        "8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("8 heads"), "{text}");
    // per-head accounting is printed for multi-head serving
    assert!(text.contains("head 0:"), "{text}");
    assert!(text.contains("head 7:"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_heads_invalid_value_errors() {
    let art = synth_artifacts("bad", 2);
    // heads = 0 is rejected by config validation before serving starts
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--heads",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("heads"), "{text}");
    // heads = 5 does not divide d_model = 64
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--heads",
        "5",
    ]);
    assert!(!ok);
    assert!(text.contains("divide"), "{text}");
    // non-numeric values fail flag parsing
    let (ok, text) = cpsaa(&["--artifacts", art.to_str().unwrap(), "serve", "--heads", "many"]);
    assert!(!ok, "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn check_verifies_artifacts_when_present() {
    let has_artifacts =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists();
    let (ok, text) = cpsaa(&["check"]);
    if has_artifacts {
        assert!(ok, "{text}");
        assert!(text.contains("check OK"), "{text}");
    } else {
        assert!(!ok);
    }
}
