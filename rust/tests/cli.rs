//! CLI smoke tests: every subcommand runs against the built binary.

use std::process::Command;

fn cpsaa(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cpsaa"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cpsaa");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn info_prints_table2_budget() {
    let (ok, text) = cpsaa(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("64 tiles"), "{text}");
    assert!(text.contains("Table 2"), "{text}");
}

#[test]
fn simulate_one_dataset() {
    let (ok, text) = cpsaa(&["simulate", "WNLI", "--batches", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("WNLI"), "{text}");
    assert!(text.contains("GOPS"), "{text}");
}

#[test]
fn bench_figure_table2() {
    let (ok, text) = cpsaa(&["bench-figure", "table2"]);
    assert!(ok, "{text}");
    assert!(text.contains("CPSAA"), "{text}");
    assert!(text.contains("PC Total"), "{text}");
}

#[test]
fn bench_figure_unknown_fails() {
    let (ok, text) = cpsaa(&["bench-figure", "fig99"]);
    assert!(!ok);
    assert!(text.contains("unknown figure"), "{text}");
}

#[test]
fn sweep_crossbar() {
    let (ok, text) = cpsaa(&["sweep", "crossbar_size", "32", "64"]);
    assert!(ok, "{text}");
    assert!(text.contains("32") && text.contains("64"), "{text}");
}

#[test]
fn sweep_rejects_bad_param() {
    let (ok, text) = cpsaa(&["sweep", "bogus_knob", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown sweep parameter"), "{text}");
}

#[test]
fn inference_reports_endurance() {
    let (ok, text) = cpsaa(&["inference", "CoLA", "--layers", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("endurance"), "{text}");
    assert!(text.contains("2-encoder"), "{text}");
}

#[test]
fn unknown_command_shows_usage() {
    let (ok, text) = cpsaa(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn config_file_round_trips_through_cli() {
    let (ok, text) = cpsaa(&["--config", "configs/paper.toml", "info"]);
    assert!(ok, "{text}");
    assert!(text.contains("32x32 crossbars"), "{text}");
}

#[test]
fn check_verifies_artifacts_when_present() {
    let has_artifacts =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists();
    let (ok, text) = cpsaa(&["check"]);
    if has_artifacts {
        assert!(ok, "{text}");
        assert!(text.contains("check OK"), "{text}");
    } else {
        assert!(!ok);
    }
}
