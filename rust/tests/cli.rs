//! CLI smoke tests: every subcommand runs against the built binary.

use std::process::Command;

fn cpsaa(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cpsaa"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cpsaa");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn info_prints_table2_budget() {
    let (ok, text) = cpsaa(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("64 tiles"), "{text}");
    assert!(text.contains("Table 2"), "{text}");
}

#[test]
fn simulate_one_dataset() {
    let (ok, text) = cpsaa(&["simulate", "WNLI", "--batches", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("WNLI"), "{text}");
    assert!(text.contains("GOPS"), "{text}");
}

#[test]
fn bench_figure_table2() {
    let (ok, text) = cpsaa(&["bench-figure", "table2"]);
    assert!(ok, "{text}");
    assert!(text.contains("CPSAA"), "{text}");
    assert!(text.contains("PC Total"), "{text}");
}

#[test]
fn bench_figure_unknown_fails() {
    let (ok, text) = cpsaa(&["bench-figure", "fig99"]);
    assert!(!ok);
    assert!(text.contains("unknown figure"), "{text}");
}

#[test]
fn sweep_crossbar() {
    let (ok, text) = cpsaa(&["sweep", "crossbar_size", "32", "64"]);
    assert!(ok, "{text}");
    assert!(text.contains("32") && text.contains("64"), "{text}");
}

#[test]
fn sweep_rejects_bad_param() {
    let (ok, text) = cpsaa(&["sweep", "bogus_knob", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown sweep parameter"), "{text}");
}

#[test]
fn inference_reports_endurance() {
    let (ok, text) = cpsaa(&["inference", "CoLA", "--layers", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("endurance"), "{text}");
    assert!(text.contains("2-encoder"), "{text}");
}

#[test]
fn unknown_command_shows_usage() {
    let (ok, text) = cpsaa(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("USAGE"), "{text}");
}

#[test]
fn config_file_round_trips_through_cli() {
    let (ok, text) = cpsaa(&["--config", "configs/paper.toml", "info"]);
    assert!(ok, "{text}");
    assert!(text.contains("32x32 crossbars"), "{text}");
}

/// Synthesize a small multi-head artifact directory for serve tests.
fn synth_artifacts(tag: &str, heads: usize) -> std::path::PathBuf {
    use cpsaa::config::ModelConfig;
    use cpsaa::runtime::ArtifactSet;
    let dir = std::env::temp_dir().join(format!("cpsaa-cli-{tag}-{}", std::process::id()));
    let model = ModelConfig {
        seq_len: 32,
        d_model: 64,
        d_k: 8,
        d_ff: 128,
        heads,
        ..ModelConfig::default()
    };
    ArtifactSet::synthesize(&dir, &model, 3).unwrap();
    dir
}

#[test]
fn serve_heads_from_config_file_end_to_end() {
    // Config-loader path: [model] heads flows from the TOML through
    // SystemConfig into the served stack.
    let art = synth_artifacts("cfg", 2);
    let cfg_path = std::env::temp_dir()
        .join(format!("cpsaa-cli-heads-{}.toml", std::process::id()));
    std::fs::write(
        &cfg_path,
        "[model]\nseq_len = 32\nd_model = 64\nd_k = 8\nd_ff = 128\nheads = 2\n",
    )
    .unwrap();
    let (ok, text) = cpsaa(&[
        "--config",
        cfg_path.to_str().unwrap(),
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("2 heads"), "{text}");
    assert!(text.contains("served 2 requests"), "{text}");
    std::fs::remove_file(&cfg_path).ok();
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_heads_flag_overrides_config() {
    let art = synth_artifacts("flag", 8);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
        "--heads",
        "8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("8 heads"), "{text}");
    // per-head accounting is printed for multi-head serving
    assert!(text.contains("head 0:"), "{text}");
    assert!(text.contains("head 7:"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_heads_invalid_value_errors() {
    let art = synth_artifacts("bad", 2);
    // heads = 0 is rejected by config validation before serving starts
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--heads",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("heads"), "{text}");
    // heads = 5 does not divide d_model = 64
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--heads",
        "5",
    ]);
    assert!(!ok);
    assert!(text.contains("divide"), "{text}");
    // non-numeric values fail flag parsing
    let (ok, text) = cpsaa(&["--artifacts", art.to_str().unwrap(), "serve", "--heads", "many"]);
    assert!(!ok, "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_shards_flag_end_to_end() {
    // Acceptance: `serve --shards 4 --heads 8` serves with per-shard
    // metrics lines (aggregates + batch-attributed tail).
    let art = synth_artifacts("shards", 8);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
        "--heads",
        "8",
        "--shards",
        "4",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("8 heads"), "{text}");
    assert!(text.contains("4 shards"), "{text}");
    assert!(text.contains("served 2 requests"), "{text}");
    // per-shard aggregate metrics printed
    assert!(text.contains("shard 0:"), "{text}");
    // batch-attributed shard lines carry their batch id
    assert!(text.contains("batch "), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_leaders_flag_end_to_end() {
    // Acceptance: `serve --leaders 4` serves every request through the
    // multi-leader loop (all leaders feeding the one executor pool).
    let art = synth_artifacts("leaders", 2);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "6",
        "--layers",
        "1",
        "--heads",
        "2",
        "--leaders",
        "4",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("4 leaders"), "{text}");
    assert!(text.contains("served 6 requests"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_leaders_invalid_value_errors() {
    let art = synth_artifacts("leaders-bad", 2);
    // leaders = 0 is rejected at startup, like shards
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--leaders",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("leaders"), "{text}");
    let (ok, _) = cpsaa(&["--artifacts", art.to_str().unwrap(), "serve", "--leaders", "many"]);
    assert!(!ok);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_shards_invalid_value_errors() {
    let art = synth_artifacts("shards-bad", 2);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--shards",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("shards"), "{text}");
    let (ok, _) = cpsaa(&["--artifacts", art.to_str().unwrap(), "serve", "--shards", "lots"]);
    assert!(!ok);
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn bench_compare_gate_passes_and_fails() {
    let dir = std::env::temp_dir().join(format!("cpsaa-cli-bcmp-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("base.json");
    let good = dir.join("good.json");
    let bad = dir.join("bad.json");
    let dump = |entries: &[(&str, u64)]| {
        let rows: Vec<String> = entries
            .iter()
            .map(|(n, m)| format!("{{\"name\": {n:?}, \"median_ns\": {m}}}"))
            .collect();
        format!(
            "{{\"group\": \"hotpath\", \"iters\": 3, \"benchmarks\": [{}]}}",
            rows.join(",")
        )
    };
    std::fs::write(&base, dump(&[("a", 1000), ("b", 2000), ("seeded", 0)])).unwrap();
    std::fs::write(&good, dump(&[("a", 1100), ("b", 1800), ("seeded", 5), ("new", 7)])).unwrap();
    std::fs::write(&bad, dump(&[("a", 2000), ("b", 1800)])).unwrap();

    let (ok, text) =
        cpsaa(&["bench-compare", base.to_str().unwrap(), good.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("bench-compare OK"), "{text}");
    assert!(text.contains("| a |"), "{text}");
    assert!(text.contains("seed"), "{text}");

    let (ok, text) = cpsaa(&[
        "bench-compare",
        base.to_str().unwrap(),
        bad.to_str().unwrap(),
        "--tolerance",
        "1.25",
    ]);
    assert!(!ok, "2.0x regression must fail the gate: {text}");
    assert!(text.contains("regressed") && text.contains("a"), "{text}");

    // missing args is a usage error
    let (ok, text) = cpsaa(&["bench-compare", base.to_str().unwrap()]);
    assert!(!ok);
    assert!(text.contains("BASELINE"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_compare_accepts_committed_baseline() {
    // The committed baseline must parse and pass the gate against
    // itself — true both while it is seeded (every rung skipped) and
    // after a refresh with real medians (every ratio exactly 1.0), so
    // the documented refresh workflow cannot break this test. It must
    // also name the CI-asserted shard rungs.
    let baseline = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_BASELINE.json");
    let body = std::fs::read_to_string(&baseline).unwrap();
    assert!(body.contains("attention_320x512_shards1_plan_reuse"), "baseline lost shard rungs");
    assert!(body.contains("attention_320x512_shards4_plan_reuse"), "baseline lost shard rungs");
    assert!(body.contains("attention_320x512_fused_plan_reuse"), "baseline lost fused rung");
    assert!(body.contains("attention_320x512_unfused_plan_reuse"), "baseline lost unfused rung");
    assert!(body.contains("encoder_layer_320x512_fused"), "baseline lost encoder rungs");
    assert!(body.contains("coord_stream_u32_gather"), "baseline lost u32-stream rung");
    assert!(body.contains("coord_stream_usize_gather"), "baseline lost usize-stream rung");
    assert!(body.contains("attention_320x512_pool"), "baseline lost executor-pool rung");
    assert!(body.contains("attention_320x512_spawn"), "baseline lost scoped-spawn rung");
    assert!(body.contains("serve_leaders1"), "baseline lost single-leader serve rung");
    assert!(body.contains("serve_leaders4"), "baseline lost multi-leader serve rung");
    assert!(body.contains("serve_prefetch_on"), "baseline lost prefetch-on serve rung");
    assert!(body.contains("serve_prefetch_off"), "baseline lost prefetch-off serve rung");
    assert!(body.contains("attention_320x512_simd"), "baseline lost simd-lane rung");
    assert!(body.contains("attention_320x512_scalar"), "baseline lost scalar-twin rung");
    assert!(body.contains("sddmm_f32_320x512"), "baseline lost f32 sddmm rung");
    assert!(body.contains("sddmm_i8_320x512"), "baseline lost i8 sddmm rung");
    let (ok, text) = cpsaa(&[
        "bench-compare",
        baseline.to_str().unwrap(),
        baseline.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("bench-compare OK"), "{text}");
}

#[test]
fn bench_assert_faster_orders_rungs() {
    let dir = std::env::temp_dir().join(format!("cpsaa-cli-baf-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let json = dir.join("run.json");
    std::fs::write(
        &json,
        r#"{"group": "hotpath", "iters": 3, "benchmarks": [
            {"name": "fused", "median_ns": 900},
            {"name": "unfused", "median_ns": 2100}
        ]}"#,
    )
    .unwrap();
    let (ok, text) = cpsaa(&["bench-assert-faster", json.to_str().unwrap(), "fused", "unfused"]);
    assert!(ok, "{text}");
    assert!(text.contains("bench-assert-faster OK"), "{text}");
    assert!(text.contains("2.33x"), "{text}");
    // reversed ordering fails the gate
    let (ok, text) = cpsaa(&["bench-assert-faster", json.to_str().unwrap(), "unfused", "fused"]);
    assert!(!ok, "reversed ordering must fail: {text}");
    assert!(text.contains("did not beat"), "{text}");
    // a wide-enough margin absorbs the inversion; a bad margin errors
    let (ok, text) = cpsaa(&[
        "bench-assert-faster",
        json.to_str().unwrap(),
        "unfused",
        "fused",
        "--margin",
        "3.0",
    ]);
    assert!(ok, "{text}");
    let (ok, text) =
        cpsaa(&["bench-assert-faster", json.to_str().unwrap(), "fused", "unfused", "--margin", "0"]);
    assert!(!ok);
    assert!(text.contains("margin"), "{text}");
    // unknown rung is an error, not a pass
    let (ok, text) = cpsaa(&["bench-assert-faster", json.to_str().unwrap(), "fused", "nope"]);
    assert!(!ok, "{text}");
    // missing args is a usage error
    let (ok, text) = cpsaa(&["bench-assert-faster", json.to_str().unwrap(), "fused"]);
    assert!(!ok);
    assert!(text.contains("FAST"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_max_workers_flag_end_to_end() {
    // The worker-cap knob must be accepted and serve correctly (values
    // are worker-count invariant, so only liveness is observable here).
    let art = synth_artifacts("maxworkers", 2);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
        "--heads",
        "2",
        "--max-workers",
        "3",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("served 2 requests"), "{text}");
    // zero is rejected at startup, like shards
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--max-workers",
        "0",
    ]);
    assert!(!ok);
    assert!(text.contains("max_kernel_workers"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_precision_flag_end_to_end() {
    // Acceptance: `serve --precision i8` serves the quantized score
    // path end to end and the banner + summary carry the precision.
    let art = synth_artifacts("precision", 2);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
        "--heads",
        "2",
        "--precision",
        "i8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("i8 precision"), "{text}");
    assert!(text.contains("served 2 requests"), "{text}");
    // the default spelled out explicitly also serves
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--layers",
        "1",
        "--heads",
        "2",
        "--precision",
        "f32",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("f32 precision"), "{text}");
    // unknown precisions fail flag parsing with a pointed message
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--precision",
        "fp16",
    ]);
    assert!(!ok, "{text}");
    assert!(text.contains("precision"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_force_scalar_flag_end_to_end() {
    // The scalar-lane escape hatch must be accepted and announced;
    // outputs are lane-invariant so only liveness is observable here.
    let art = synth_artifacts("scalar", 2);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
        "--heads",
        "2",
        "--force-scalar",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("scalar lanes"), "{text}");
    assert!(text.contains("served 2 requests"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

fn cpsaa_env(args: &[&str], env: &[(&str, &str)]) -> (bool, String) {
    let mut c = Command::new(env!("CARGO_BIN_EXE_cpsaa"));
    c.args(args).current_dir(env!("CARGO_MANIFEST_DIR"));
    for (k, v) in env {
        c.env(k, v);
    }
    let out = c.output().expect("spawn cpsaa");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn record_then_replay_across_topologies_end_to_end() {
    // Acceptance: a capture recorded under one {workers, leaders,
    // shards} topology replays byte-identically under a different one.
    let art = synth_artifacts("record", 2);
    let cap = std::env::temp_dir().join(format!("cpsaa-cli-cap-{}.json", std::process::id()));
    let trace = std::env::temp_dir().join(format!("cpsaa-cli-trc-{}.json", std::process::id()));
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "4",
        "--layers",
        "1",
        "--heads",
        "2",
        "--record",
        cap.to_str().unwrap(),
        "--trace",
        trace.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("recorded"), "{text}");
    assert!(text.contains("batch timelines"), "{text}");
    // the trace dump is non-empty, well-formed JSON with stage events
    let trace_text = std::fs::read_to_string(&trace).unwrap();
    assert!(trace_text.contains("cpsaa-sim-trace"), "{trace_text}");
    assert!(trace_text.contains("step3_sddmm"), "{trace_text}");

    // Replay at a different worker/leader/shard topology: exit 0.
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "replay",
        cap.to_str().unwrap(),
        "--leaders",
        "3",
        "--shards",
        "2",
        "--max-workers",
        "3",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("replay OK"), "{text}");
    assert!(text.contains("sim costs skipped"), "{text}");

    // Replay at the recorded topology compares the sim fields too —
    // and stays bit-identical under forced-scalar kernels.
    let (ok, text) = cpsaa_env(
        &["--artifacts", art.to_str().unwrap(), "replay", cap.to_str().unwrap()],
        &[("CPSAA_FORCE_SCALAR", "1")],
    );
    assert!(ok, "{text}");
    assert!(text.contains("replay OK"), "{text}");
    assert!(text.contains("sim costs compared"), "{text}");

    // The capture was recorded with the plan pipeline on (the default);
    // replaying with it forced off must stay bit-identical too.
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "replay",
        cap.to_str().unwrap(),
        "--prefetch",
        "off",
        "--leaders",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("replay OK"), "{text}");

    std::fs::remove_file(&cap).ok();
    std::fs::remove_file(&trace).ok();
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn serve_prefetch_flag_and_cascade_schedule_end_to_end() {
    // `--prefetch off` disables the stage-overlapped plan pipeline (the
    // summary's counters stay zero) and `--prune cascade:K1,K2,...`
    // applies a per-layer keep schedule; bad values for either flag are
    // startup errors, not mid-serve surprises.
    let art = synth_artifacts("prefetch", 2);
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "3",
        "--heads",
        "2",
        "--prune",
        "cascade:0.9,0.7,0.5",
        "--prefetch",
        "off",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("cascade:0.9,0.7,0.5 plans"), "{text}");
    assert!(text.contains("plan pipeline: 0 cache hits / 0 misses"), "{text}");
    assert!(text.contains("plan narrowing"), "{text}");

    // Prefetch on (the default): every batch is accounted as a cache
    // hit or a miss.
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
        "--heads",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("plan pipeline:"), "{text}");
    assert!(!text.contains("plan pipeline: 0 cache hits / 0 misses"), "{text}");

    // Bad values are usage errors.
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--prefetch",
        "maybe",
    ]);
    assert!(!ok, "{text}");
    assert!(text.contains("--prefetch"), "{text}");
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--prune",
        "cascade:0.5,oops",
    ]);
    assert!(!ok, "{text}");
    assert!(text.contains("--prune"), "{text}");
    let (ok, text) = cpsaa(&[
        "--artifacts",
        art.to_str().unwrap(),
        "serve",
        "--requests",
        "1",
        "--prune",
        "cascade:0.5,0.0",
    ]);
    assert!(!ok, "{text}");
    assert!(text.contains("prune"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn replay_rejects_corrupted_captures() {
    let art = synth_artifacts("corrupt", 2);
    let cap = std::env::temp_dir().join(format!("cpsaa-cli-bad-{}.json", std::process::id()));
    // not a capture at all
    std::fs::write(&cap, "{\"format\": \"something-else\", \"version\": 1}").unwrap();
    let (ok, text) = cpsaa(&["--artifacts", art.to_str().unwrap(), "replay", cap.to_str().unwrap()]);
    assert!(!ok, "corrupt capture must fail: {text}");
    assert!(text.contains("capture"), "{text}");
    // truncated JSON
    std::fs::write(&cap, "{\"format\": \"cpsaa-capt").unwrap();
    let (ok, _) = cpsaa(&["--artifacts", art.to_str().unwrap(), "replay", cap.to_str().unwrap()]);
    assert!(!ok);
    // missing file
    let (ok, _) = cpsaa(&["--artifacts", art.to_str().unwrap(), "replay", "/nonexistent/cap.json"]);
    assert!(!ok);
    std::fs::remove_file(&cap).ok();
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn synth_artifacts_subcommand_serves() {
    // The CI path: synthesize servable artifacts from a [model] config,
    // no Python needed, then serve against them.
    let dir = std::env::temp_dir().join(format!("cpsaa-cli-synth-{}", std::process::id()));
    let cfg_path = std::env::temp_dir().join(format!("cpsaa-cli-synth-{}.toml", std::process::id()));
    std::fs::write(
        &cfg_path,
        "[model]\nseq_len = 32\nd_model = 64\nd_k = 8\nd_ff = 128\nheads = 2\n",
    )
    .unwrap();
    let (ok, text) = cpsaa(&[
        "--config",
        cfg_path.to_str().unwrap(),
        "synth-artifacts",
        dir.to_str().unwrap(),
        "--seed",
        "11",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("synthesized artifacts"), "{text}");
    assert!(dir.join("manifest.json").exists());
    let (ok, text) = cpsaa(&[
        "--config",
        cfg_path.to_str().unwrap(),
        "--artifacts",
        dir.to_str().unwrap(),
        "serve",
        "--requests",
        "2",
        "--layers",
        "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("served 2 requests"), "{text}");
    std::fs::remove_file(&cfg_path).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Like `cpsaa`, but keeps stdout and stderr apart — loadgen promises a
/// clean machine-readable stream on stdout.
fn cpsaa_split(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cpsaa"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cpsaa");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn loadgen_csv_is_seed_deterministic_end_to_end() {
    let art = synth_artifacts("loadgen", 2);
    let args = [
        "--artifacts",
        art.to_str().unwrap(),
        "loadgen",
        "--seed",
        "7",
        "--rps",
        "150",
        "--duration",
        "0.3",
        "--layers",
        "1",
        "--heads",
        "2",
    ];
    let (ok, csv_a, err_a) = cpsaa_split(&args);
    assert!(ok, "{csv_a}{err_a}");
    assert!(csv_a.starts_with("id,at_ms,rows,lane,outcome,latency_ms,leader"), "{csv_a}");
    assert!(csv_a.lines().count() > 10, "{csv_a}");
    // the human-readable summary stays on stderr
    assert!(err_a.contains("latency"), "{err_a}");
    assert!(err_a.contains("offered"), "{err_a}");
    let (ok, csv_b, err_b) = cpsaa_split(&args);
    assert!(ok, "{csv_b}{err_b}");
    // Same --seed, same schedule: the id/at_ms/rows/lane columns are
    // byte-identical run to run. Outcome, latency, and leader columns
    // are wall-clock- and scheduling-dependent, so only the schedule
    // prefix is compared.
    let sched = |csv: &str| -> Vec<String> {
        csv.lines()
            .map(|l| l.split(',').take(4).collect::<Vec<_>>().join(","))
            .collect()
    };
    assert_eq!(sched(&csv_a), sched(&csv_b));
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn loadgen_json_junit_and_slo_gate() {
    use cpsaa::util::json::Json;
    let art = synth_artifacts("loadgen-slo", 2);
    let junit = std::env::temp_dir().join(format!("cpsaa-cli-junit-{}.xml", std::process::id()));
    let base = [
        "--artifacts",
        art.to_str().unwrap(),
        "loadgen",
        "--seed",
        "7",
        "--rps",
        "120",
        "--duration",
        "0.25",
        "--layers",
        "1",
        "--heads",
        "2",
        "--interactive",
        "0.5",
        "--deadline-ms",
        "5000",
        "--json",
        "--junit",
        junit.to_str().unwrap(),
        "--slo-p99-ms",
    ];
    // A generous SLO passes and emits one JSON document instead of CSV.
    let mut args: Vec<&str> = base.to_vec();
    args.push("60000");
    let (ok, stdout, stderr) = cpsaa_split(&args);
    assert!(ok, "{stdout}{stderr}");
    assert!(!stdout.contains("id,at_ms"), "CSV must be suppressed under --json: {stdout}");
    let doc = Json::parse(&stdout).unwrap();
    assert!(doc.get("offered").unwrap().as_usize().unwrap() > 0);
    assert!(doc.get("p99_ms").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(doc.get("slo_ok").unwrap(), &Json::Bool(true));
    // Plan-pipeline counters ride along in the JSON document. Payloads are
    // random, so every batch is a cache miss; the hit count merely has to
    // be present (and the overlap clock non-negative).
    assert!(doc.get("plan_cache_misses").unwrap().as_usize().unwrap() >= 1, "{stdout}");
    assert!(doc.get("plan_cache_hits").is_some(), "{stdout}");
    assert!(doc.get("prefetch_overlapped_ms").unwrap().as_f64().unwrap() >= 0.0, "{stdout}");
    let xml = std::fs::read_to_string(&junit).unwrap();
    assert!(xml.contains("<testsuite name=\"loadgen-slo-smoke\""), "{xml}");
    assert!(xml.contains("failures=\"0\""), "{xml}");
    assert!(xml.contains("p99_slo"), "{xml}");

    // An impossible SLO exits nonzero — and the JUnit verdict written
    // just before the gate carries the failure for CI to upload.
    let mut args: Vec<&str> = base.to_vec();
    args.push("0.000001");
    let (ok, stdout, stderr) = cpsaa_split(&args);
    assert!(!ok, "sub-microsecond SLO must fail: {stdout}{stderr}");
    assert!(stderr.contains("exceeds the SLO"), "{stderr}");
    let doc = Json::parse(&stdout).unwrap();
    assert_eq!(doc.get("slo_ok").unwrap(), &Json::Bool(false));
    let xml = std::fs::read_to_string(&junit).unwrap();
    assert!(xml.contains("failures=\"1\""), "{xml}");
    assert!(xml.contains("<failure message=\"p99"), "{xml}");

    std::fs::remove_file(&junit).ok();
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn loadgen_sheds_everything_under_zero_queue_cap() {
    // --queue-cap 0 is the drain drill: every live request sheds with
    // the typed queue-full status, which is backpressure, not failure —
    // the run still exits 0.
    let art = synth_artifacts("loadgen-shed", 2);
    let (ok, stdout, stderr) = cpsaa_split(&[
        "--artifacts",
        art.to_str().unwrap(),
        "loadgen",
        "--seed",
        "3",
        "--rps",
        "200",
        "--duration",
        "0.2",
        "--layers",
        "1",
        "--heads",
        "2",
        "--queue-cap",
        "0",
    ]);
    assert!(ok, "sheds are not failures: {stdout}{stderr}");
    let rows: Vec<&str> = stdout.lines().skip(1).collect();
    assert!(!rows.is_empty(), "{stdout}");
    for row in &rows {
        assert!(row.contains(",shed-queue-full,,"), "{row}");
    }
    assert!(stderr.contains("queue-full"), "{stderr}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn loadgen_rejects_bad_parameters() {
    let art = synth_artifacts("loadgen-bad", 2);
    let (ok, text) = cpsaa(&["--artifacts", art.to_str().unwrap(), "loadgen", "--rps", "0"]);
    assert!(!ok);
    assert!(text.contains("--rps"), "{text}");
    let (ok, text) = cpsaa(&["--artifacts", art.to_str().unwrap(), "loadgen", "--duration", "-1"]);
    assert!(!ok);
    assert!(text.contains("--duration"), "{text}");
    let (ok, text) =
        cpsaa(&["--artifacts", art.to_str().unwrap(), "loadgen", "--interactive", "1.5"]);
    assert!(!ok);
    assert!(text.contains("--interactive"), "{text}");
    std::fs::remove_dir_all(&art).ok();
}

#[test]
fn check_verifies_artifacts_when_present() {
    let has_artifacts =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists();
    let (ok, text) = cpsaa(&["check"]);
    if has_artifacts {
        assert!(ok, "{text}");
        assert!(text.contains("check OK"), "{text}");
    } else {
        assert!(!ok);
    }
}
