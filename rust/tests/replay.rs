//! Capture/replay determinism properties.
//!
//! The serving determinism contract, executable: a capture recorded
//! under one `{workers, leaders, shards}` topology must replay
//! byte-identically under any other, because batch composition — the
//! only timing-dependent input — is recorded as atomic groups and
//! resubmitted through `Service::submit_group`. These tests drive the
//! library API directly; `tests/cli.rs` covers the `serve --record` /
//! `replay` binary path.

use std::path::PathBuf;
use std::time::Duration;

use cpsaa::attention::Precision;
use cpsaa::config::{HardwareConfig, ModelConfig, SystemConfig};
use cpsaa::coordinator::{ServeHooks, Service, ServiceConfig, SubmitOptions};
use cpsaa::runtime::{ArtifactSet, Lane};
use cpsaa::sparse::PruneConfig;
use cpsaa::tensor::{Matrix, SeededRng};
use cpsaa::workload::capture::{
    self, Capture, CaptureConfig, CaptureRecorder, ReplayOverrides, SimTracer,
};

fn model() -> ModelConfig {
    ModelConfig {
        seq_len: 32,
        d_model: 64,
        d_k: 8,
        d_ff: 128,
        heads: 2,
        ..ModelConfig::default()
    }
}

/// Record a small capture at the minimal topology: 1 kernel worker,
/// 1 leader, 1 shard. Three deterministic batch groups (2, 1, and 3
/// requests) fix the packing compositions once and for all.
fn record_capture(tag: &str, seed: u64, precision: Precision) -> (PathBuf, Capture) {
    let dir = std::env::temp_dir().join(format!("cpsaa-replay-{tag}-{}", std::process::id()));
    let m = model();
    ArtifactSet::synthesize(&dir, &m, seed).unwrap();
    let recorder = CaptureRecorder::new();
    let svc = Service::start_with_hooks(
        dir.clone(),
        HardwareConfig::paper(),
        m,
        ServiceConfig {
            layers: 2,
            shards: 1,
            leaders: 1,
            max_kernel_workers: Some(1),
            precision,
            ..Default::default()
        },
        ServeHooks { recorder: Some(recorder.clone()), tracer: None },
    )
    .unwrap();
    let mut rng = SeededRng::new(seed + 100);
    let mut next_id = 0u64;
    for group_size in [2usize, 1, 3] {
        let reqs: Vec<(u64, Matrix)> = (0..group_size)
            .map(|_| {
                let id = next_id;
                next_id += 1;
                (id, rng.normal_matrix(8, 64, 1.0))
            })
            .collect();
        let rxs = svc.submit_group(reqs).unwrap();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    }
    let capture = recorder.into_capture(CaptureConfig {
        model: svc.model().clone(),
        layers: 2,
        shards: 1,
        leaders: 1,
        max_kernel_workers: Some(1),
        precision,
        prune: PruneConfig::Static,
        force_scalar: false,
        artifact_seed: seed,
        system_toml: SystemConfig::paper().to_toml_string(),
    });
    (dir, capture)
}

#[test]
fn capture_replays_bit_identically_across_topologies() {
    let (dir, capture) = record_capture("f32", 41, Precision::F32);
    // one batch per atomic group, in submission order
    assert_eq!(capture.batches.len(), 3);
    assert_eq!(capture.requests(), 6);
    assert_eq!(
        capture.batches.iter().map(|b| b.requests.len()).collect::<Vec<_>>(),
        vec![2, 1, 3]
    );

    // The acceptance property: recorded at {workers 1, leaders 1,
    // shards 1}, replayed at {workers 3, leaders 4, shards 2} — every
    // functional field must still match to the bit (sim fields are
    // shard-topology functions, so they are skipped here).
    let tracer = SimTracer::new();
    let report = capture::replay(
        &capture,
        &dir,
        ReplayOverrides { max_workers: Some(3), leaders: Some(4), shards: Some(2), prefetch: None },
        Some(tracer.clone()),
    )
    .unwrap();
    assert_eq!((report.batches, report.requests), (3, 6));
    assert!(!report.strict_sim);
    assert_eq!((report.leaders, report.shards), (4, 2));
    // replay can trace too: one timeline record per replayed batch
    assert_eq!(tracer.batches_recorded(), 3);

    // Identity replay additionally holds every simulated-cost field to
    // the bit.
    let report = capture::replay(&capture, &dir, ReplayOverrides::default(), None).unwrap();
    assert!(report.strict_sim);
    assert_eq!(report.requests, 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn capture_file_roundtrip_then_replay() {
    let (dir, capture) = record_capture("disk", 43, Precision::F32);
    let path = std::env::temp_dir().join(format!("cpsaa-replay-cap-{}.json", std::process::id()));
    capture.save(&path).unwrap();
    let loaded = Capture::load(&path).unwrap();
    // the file round-trip is lossless, down to the payload bits
    assert_eq!(loaded, capture);
    let report = capture::replay(
        &loaded,
        &dir,
        ReplayOverrides { leaders: Some(2), ..Default::default() },
        None,
    )
    .unwrap();
    assert_eq!(report.requests, 6);
    assert!(report.strict_sim, "shards unchanged, sim fields must be compared");
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn i8_capture_replays_bit_identically() {
    let (dir, capture) = record_capture("i8", 47, Precision::I8);
    let report = capture::replay(
        &capture,
        &dir,
        ReplayOverrides { max_workers: Some(2), leaders: Some(3), shards: Some(2), prefetch: None },
        None,
    )
    .unwrap();
    assert_eq!(report.requests, 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replay_detects_tampered_bits() {
    let (dir, capture) = record_capture("tamper", 53, Precision::F32);
    // flip the lowest mantissa bit of one recorded hidden value
    let mut bad = capture.clone();
    {
        let r = &mut bad.batches[0].requests[0].response;
        let mut data: Vec<f32> = r.hidden.data().to_vec();
        data[0] = f32::from_bits(data[0].to_bits() ^ 1);
        r.hidden = Matrix::from_vec(r.hidden.rows(), r.hidden.cols(), data);
    }
    let err = capture::replay(&bad, &dir, ReplayOverrides::default(), None).unwrap_err();
    assert!(err.to_string().contains("hidden"), "{err}");

    // a tampered sim cost is caught when the shard topology matches...
    let mut bad = capture.clone();
    bad.batches[0].requests[0].response.sim_ns += 1.0;
    let err = capture::replay(&bad, &dir, ReplayOverrides::default(), None).unwrap_err();
    assert!(err.to_string().contains("sim_ns"), "{err}");

    // ...and deliberately ignored when the topology changed (sim lines
    // are functions of the shard partition, not of the requests).
    let mut bad = capture.clone();
    bad.batches[0].requests[0].response.sim_ns += 1.0;
    capture::replay(&bad, &dir, ReplayOverrides { shards: Some(2), ..Default::default() }, None)
        .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance property for continuous batching: batch composition
/// under live admission is decided by arrival timing and window
/// formation — inherently nondeterministic — but whatever composition
/// was *realized* is recorded as atomic groups, so the capture still
/// replays bit-identically at a completely different topology.
#[test]
fn live_continuous_batching_capture_replays_across_topologies() {
    let dir = std::env::temp_dir().join(format!("cpsaa-replay-live-{}", std::process::id()));
    let m = model();
    ArtifactSet::synthesize(&dir, &m, 61).unwrap();
    let recorder = CaptureRecorder::new();
    let svc = Service::start_with_hooks(
        dir.clone(),
        HardwareConfig::paper(),
        m,
        ServiceConfig {
            layers: 2,
            shards: 1,
            leaders: 2,
            max_wait: Duration::from_millis(5),
            max_kernel_workers: Some(2),
            ..Default::default()
        },
        ServeHooks { recorder: Some(recorder.clone()), tracer: None },
    )
    .unwrap();
    // Live traffic through the continuous-batching admission path: a
    // mix of normal and high-lane requests, submitted open-loop so
    // several can share (or split across) windows however the two
    // leaders' timing falls out.
    let mut rng = SeededRng::new(161);
    let mut rxs = Vec::new();
    for id in 0..10u64 {
        let rows = 4 + rng.gen_range_usize(0, 8);
        let x = rng.normal_matrix(rows, 64, 1.0);
        let lane = if id % 3 == 0 { Lane::High } else { Lane::Normal };
        rxs.push(svc.submit_with(id, x, SubmitOptions { deadline: None, lane }).unwrap());
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let capture = recorder.into_capture(CaptureConfig {
        model: svc.model().clone(),
        layers: 2,
        shards: 1,
        leaders: 2,
        max_kernel_workers: Some(2),
        precision: Precision::F32,
        prune: PruneConfig::Static,
        force_scalar: false,
        artifact_seed: 61,
        system_toml: SystemConfig::paper().to_toml_string(),
    });
    drop(svc);
    assert_eq!(capture.requests(), 10);
    assert!(!capture.batches.is_empty());
    let report = capture::replay(
        &capture,
        &dir,
        ReplayOverrides { max_workers: Some(3), leaders: Some(3), shards: Some(2), prefetch: None },
        None,
    )
    .unwrap();
    assert_eq!(report.requests, 10);
    assert_eq!((report.leaders, report.shards), (3, 2));
    std::fs::remove_dir_all(&dir).ok();
}

/// The cascade acceptance property: a capture recorded with
/// `--prune cascade:0.5` at the minimal topology must replay
/// bit-identically at a different worker/leader/shard topology. That
/// covers both the functional outputs *and* the per-layer plan-evolution
/// stats (nnz, rows/heads kept), which are request-stream functions —
/// importance accumulation and top-k narrowing are topology-invariant —
/// so the comparator holds them to the bit even when sim fields are
/// relaxed.
#[test]
fn cascade_pruned_capture_replays_across_topologies() {
    let dir = std::env::temp_dir().join(format!("cpsaa-replay-cascade-{}", std::process::id()));
    let m = model();
    ArtifactSet::synthesize(&dir, &m, 67).unwrap();
    let prune = PruneConfig::cascade(0.5);
    let recorder = CaptureRecorder::new();
    let svc = Service::start_with_hooks(
        dir.clone(),
        HardwareConfig::paper(),
        m,
        ServiceConfig {
            layers: 3,
            shards: 1,
            leaders: 1,
            max_kernel_workers: Some(1),
            prune: prune.clone(),
            ..Default::default()
        },
        ServeHooks { recorder: Some(recorder.clone()), tracer: None },
    )
    .unwrap();
    let mut rng = SeededRng::new(167);
    let mut next_id = 0u64;
    for group_size in [2usize, 3] {
        let reqs: Vec<(u64, Matrix)> = (0..group_size)
            .map(|_| {
                let id = next_id;
                next_id += 1;
                (id, rng.normal_matrix(8, 64, 1.0))
            })
            .collect();
        for rx in svc.submit_group(reqs).unwrap() {
            let resp = rx.recv().unwrap().unwrap();
            // the served responses already carry the cascade evidence
            assert_eq!(resp.prune, prune);
            assert_eq!(resp.layer_nnz.len(), 3);
            assert!(resp.layer_nnz[1] < resp.layer_nnz[0], "plans must narrow");
            assert!(resp.narrow_ns > 0.0 && resp.narrow_ns < resp.rescan_ns);
        }
    }
    let capture = recorder.into_capture(CaptureConfig {
        model: svc.model().clone(),
        layers: 3,
        shards: 1,
        leaders: 1,
        max_kernel_workers: Some(1),
        precision: Precision::F32,
        prune: prune.clone(),
        force_scalar: false,
        artifact_seed: 67,
        system_toml: SystemConfig::paper().to_toml_string(),
    });
    drop(svc);
    assert_eq!(capture.requests(), 5);

    // The file round-trip keeps the prune config and plan stats...
    let path =
        std::env::temp_dir().join(format!("cpsaa-replay-cascade-cap-{}.json", std::process::id()));
    capture.save(&path).unwrap();
    let loaded = Capture::load(&path).unwrap();
    assert_eq!(loaded, capture);
    assert_eq!(loaded.config.prune, prune);

    // ...and the replay holds them to the bit at another topology.
    let report = capture::replay(
        &loaded,
        &dir,
        ReplayOverrides { max_workers: Some(3), leaders: Some(2), shards: Some(2), prefetch: None },
        None,
    )
    .unwrap();
    assert_eq!(report.requests, 5);
    assert!(!report.strict_sim);

    // Tampering with a recorded plan stat is caught even under a
    // topology change — plan evolution is not a sim-only field.
    let mut bad = loaded.clone();
    bad.batches[0].requests[0].response.layer_nnz[1] += 1;
    let err = capture::replay(
        &bad,
        &dir,
        ReplayOverrides { shards: Some(2), ..Default::default() },
        None,
    )
    .unwrap_err();
    assert!(err.to_string().contains("layer_nnz"), "{err}");
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// The stage-overlap acceptance property: the plan prefetch pipeline
/// and the content-addressed plan cache change only *when* plans are
/// built, never their bits. A capture recorded with the pipeline on
/// (the service default) must replay bit-identically with it forced
/// off, and one recorded with it off must replay with it on — both
/// under a simultaneous worker/leader/shard topology change.
#[test]
fn prefetch_direction_is_bit_invisible_to_replay() {
    // Recorded with prefetch on (the default)...
    let (dir, capture) = record_capture("prefetch-on", 71, Precision::F32);
    // ...replayed with the pipeline disabled, at another topology.
    let report = capture::replay(
        &capture,
        &dir,
        ReplayOverrides {
            max_workers: Some(3),
            leaders: Some(3),
            shards: Some(2),
            prefetch: Some(false),
        },
        None,
    )
    .unwrap();
    assert_eq!(report.requests, 6);
    // The identity-topology replay with prefetch off additionally holds
    // every simulated-cost field to the bit.
    let report = capture::replay(
        &capture,
        &dir,
        ReplayOverrides { prefetch: Some(false), ..Default::default() },
        None,
    )
    .unwrap();
    assert!(report.strict_sim);
    assert_eq!(report.requests, 6);
    std::fs::remove_dir_all(&dir).ok();

    // The reverse direction: recorded with the pipeline off, with
    // repeated identical payloads so the prefetch-on replay exercises
    // real plan-cache hits rather than only cold builds...
    let dir =
        std::env::temp_dir().join(format!("cpsaa-replay-prefetch-off-{}", std::process::id()));
    let m = model();
    ArtifactSet::synthesize(&dir, &m, 73).unwrap();
    let recorder = CaptureRecorder::new();
    let svc = Service::start_with_hooks(
        dir.clone(),
        HardwareConfig::paper(),
        m,
        ServiceConfig {
            layers: 2,
            max_kernel_workers: Some(1),
            prefetch: false,
            ..Default::default()
        },
        ServeHooks { recorder: Some(recorder.clone()), tracer: None },
    )
    .unwrap();
    let x = SeededRng::new(173).normal_matrix(8, 64, 1.0);
    // Two groups with identical payload bits: the second replayed batch
    // packs the exact matrix the first did, so it is a plan-cache hit.
    for group in [vec![(0u64, x.clone()), (1, x.clone())], vec![(2, x.clone()), (3, x.clone())]] {
        for rx in svc.submit_group(group).unwrap() {
            rx.recv().unwrap().unwrap();
        }
    }
    let capture = recorder.into_capture(CaptureConfig {
        model: svc.model().clone(),
        layers: 2,
        shards: 1,
        leaders: 1,
        max_kernel_workers: Some(1),
        precision: Precision::F32,
        prune: PruneConfig::Static,
        force_scalar: false,
        artifact_seed: 73,
        system_toml: SystemConfig::paper().to_toml_string(),
    });
    drop(svc);
    assert_eq!(capture.requests(), 4);
    // ...replayed with it on, across a topology change.
    let report = capture::replay(
        &capture,
        &dir,
        ReplayOverrides {
            max_workers: Some(3),
            leaders: Some(3),
            shards: Some(2),
            prefetch: Some(true),
        },
        None,
    )
    .unwrap();
    assert_eq!(report.requests, 4);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replay_refuses_mismatched_artifacts() {
    let (dir, capture) = record_capture("mismatch", 59, Precision::F32);
    let other = std::env::temp_dir().join(format!("cpsaa-replay-other-{}", std::process::id()));
    // same shapes, different seed → different weights → refuse up front
    ArtifactSet::synthesize(&other, &model(), 1234).unwrap();
    let err = capture::replay(&capture, &other, ReplayOverrides::default(), None).unwrap_err();
    assert!(err.to_string().contains("artifact mismatch"), "{err}");
    std::fs::remove_dir_all(&other).ok();
    std::fs::remove_dir_all(&dir).ok();
}
