//! Property tests over the L3 substrates and simulator invariants
//! (the in-tree `util::prop` driver replaces proptest in this offline
//! build — N seeded cases per property, failing seed reported).

use cpsaa::attention::{
    self, ops, MultiHeadWeights, Precision, QuantizedRows, Weights, WorkspacePool,
};
use cpsaa::config::{HardwareConfig, ModelConfig};
use cpsaa::coordinator::Batcher;
use cpsaa::prop_assert;
use cpsaa::runtime::Executor;
use cpsaa::sim::{pipeline, sddmm, spmm};
use cpsaa::sparse::{CsrMatrix, DispatchPlan, MaskMatrix, PlanSet};
use cpsaa::tensor::{simd, Matrix, SeededRng};
use cpsaa::util::prop::{check, default_cases};

fn rand_mask(rng: &mut SeededRng, n: usize) -> MaskMatrix {
    let density = 0.02 + rng.uniform() as f64 * 0.5;
    MaskMatrix::from_dense(&rng.mask_matrix(n, n, density))
}

/// Mask whose density sweeps the full 0.0–1.0 range, hitting the exact
/// empty and full endpoints often (the plan's edge cases).
fn full_range_mask(rng: &mut SeededRng, rows: usize, cols: usize) -> MaskMatrix {
    match rng.gen_range_usize(0, 8) {
        0 => MaskMatrix::zeros(rows, cols),
        1 => MaskMatrix::ones(rows, cols),
        _ => {
            let density = rng.uniform() as f64;
            MaskMatrix::from_dense(&rng.mask_matrix(rows, cols, density))
        }
    }
}

#[test]
fn prop_mask_roundtrip_and_counts() {
    check("mask_roundtrip", default_cases(), |rng| {
        let n = 8 + rng.gen_range_usize(0, 120);
        let mask = rand_mask(rng, n);
        let dense = mask.to_dense();
        prop_assert!(MaskMatrix::from_dense(&dense) == mask, "roundtrip failed n={n}");
        let plan = mask.plan();
        let total: usize = (0..n).map(|i| plan.row_nnz(i)).sum();
        prop_assert!(total == mask.nnz(), "plan rows {total} != nnz {}", mask.nnz());
        let bc = mask.block_counts(32, 32);
        prop_assert!(bc.total() == mask.nnz() as u64, "block counts lose mass");
        Ok(())
    });
}

#[test]
fn prop_plan_sddmm_equals_dense_reference() {
    // Plan-driven masked SDDMM ≡ dense `mask ⊙ (A·B)` across the whole
    // density range, empty and full masks included.
    check("plan_sddmm_vs_dense", default_cases(), |rng| {
        let n = 4 + rng.gen_range_usize(0, 44);
        let m = 4 + rng.gen_range_usize(0, 44);
        let k = 4 + rng.gen_range_usize(0, 28);
        let mask = full_range_mask(rng, n, m);
        let a = rng.normal_matrix(n, k, 1.0);
        let b = rng.normal_matrix(k, m, 1.0);
        let plan = mask.plan();
        let got = ops::sddmm_csr(&a, &b.transpose(), &plan).to_dense();
        let full = a.matmul(&b);
        for i in 0..n {
            for j in 0..m {
                let want = if mask.get(i, j) { full.get(i, j) } else { 0.0 };
                prop_assert!(
                    (got.get(i, j) - want).abs() < 1e-3,
                    "({i},{j}): {} vs {want} (density {})",
                    got.get(i, j),
                    mask.density()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_from_plan_equals_from_dense_masked() {
    check("csr_from_plan", default_cases(), |rng| {
        let n = 4 + rng.gen_range_usize(0, 60);
        let m = 4 + rng.gen_range_usize(0, 60);
        let mask = full_range_mask(rng, n, m);
        let dense = rng.normal_matrix(n, m, 1.0);
        let plan = mask.plan();
        let a = CsrMatrix::from_plan(&plan, &dense);
        let b = CsrMatrix::from_dense_masked(&dense, &mask);
        prop_assert!(a == b, "CSR-from-plan diverged (nnz {} vs {})", a.nnz(), b.nnz());
        prop_assert!(a.nnz() == mask.nnz(), "nnz {} != mask {}", a.nnz(), mask.nnz());
        Ok(())
    });
}

#[test]
fn prop_plan_column_queues_match_brute_force() {
    check("plan_col_queues", default_cases(), |rng| {
        let n = 4 + rng.gen_range_usize(0, 92);
        let m = 4 + rng.gen_range_usize(0, 92);
        let mask = full_range_mask(rng, n, m);
        let plan = mask.plan();
        for j in 0..m {
            let want = (0..n).filter(|&i| mask.get(i, j)).count() as u32;
            prop_assert!(
                plan.col_queue_depths()[j] == want,
                "column {j}: plan {} vs brute-force {want}",
                plan.col_queue_depths()[j]
            );
        }
        let brute_max = (0..m)
            .map(|j| (0..n).filter(|&i| mask.get(i, j)).count() as u64)
            .max()
            .unwrap_or(0);
        prop_assert!(
            plan.max_col_queue() == brute_max,
            "max queue {} vs {brute_max}",
            plan.max_col_queue()
        );
        Ok(())
    });
}

#[test]
fn prop_attention_planned_equals_unplanned() {
    // The plan-reuse hot path computes exactly what the scan-per-call
    // path does.
    check("planned_attention", 16, |rng| {
        let cfg = ModelConfig { seq_len: 24, d_model: 32, ..Default::default() };
        let w = Weights::synthetic(&cfg, rng.gen_range_usize(0, 1000) as u64);
        let x = rng.normal_matrix(24, 32, 1.0);
        let mask = full_range_mask(rng, 24, 24);
        let plan = mask.plan();
        let a = attention::cpsaa_attention(&x, &w.w_s, &w.w_v, &mask, &cfg);
        let b = ops::cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg);
        prop_assert!(a.max_abs_diff(&b) < 1e-6, "planned path diverged");
        Ok(())
    });
}

#[test]
fn prop_one_head_fanout_bit_identical_to_single_head() {
    // The multi-head serving path with heads = 1 must be *bit-identical*
    // to the single-head path — attention and full encoder layer — across
    // the whole 0.0–1.0 density range, empty and full masks included.
    check("one_head_fanout", 32, |rng| {
        let cfg = ModelConfig { seq_len: 24, d_model: 32, ..Default::default() };
        let w = Weights::synthetic(&cfg, rng.gen_range_usize(0, 1000) as u64);
        let mh = MultiHeadWeights::from_single(&w);
        let x = rng.normal_matrix(24, 32, 1.0);
        let mask = full_range_mask(rng, 24, 24);
        let plan = mask.plan();
        let plans = PlanSet::single(plan.clone());
        let za = ops::cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg);
        let zb = ops::multi_head_attention_planned(&x, &mh, &plans, &cfg);
        prop_assert!(za == zb, "attention diverged at density {}", mask.density());
        let ea = ops::encoder_layer_planned(&x, &w, &plan, &cfg);
        let eb = ops::encoder_layer_heads(&x, &mh, &plans, &cfg);
        prop_assert!(ea == eb, "encoder layer diverged at density {}", mask.density());
        Ok(())
    });
}

#[test]
fn partition_rows_degenerate_masks() {
    // All-empty mask: one range, exactly tiling 0..n.
    let empty = MaskMatrix::zeros(64, 64).plan();
    let ranges = empty.partition_rows(4);
    assert_eq!(ranges, vec![0..64]);

    // Single dense row carrying all the mass: the partition still tiles
    // 0..n with non-empty contiguous ranges, at most `parts` of them.
    for hot in [0usize, 31, 63] {
        let mut m = MaskMatrix::zeros(64, 64);
        for j in 0..64 {
            m.set(hot, j, true);
        }
        let p = m.plan();
        let ranges = p.partition_rows(4);
        assert!(!ranges.is_empty() && ranges.len() <= 4, "hot {hot}: {ranges:?}");
        let mut cursor = 0;
        for r in &ranges {
            assert_eq!(r.start, cursor, "hot {hot}: gap at {r:?}");
            assert!(r.end > r.start, "hot {hot}: empty range");
            cursor = r.end;
        }
        assert_eq!(cursor, 64, "hot {hot}: ranges must tile 0..64");
    }

    // Empty rows interspersed with occupied ones (every third row
    // cleared).
    let mut rng = SeededRng::new(40);
    let dense = rng.mask_matrix(96, 96, 0.2);
    let mut m = MaskMatrix::zeros(96, 96);
    for i in 0..96 {
        if i % 3 != 0 {
            for j in 0..96 {
                if dense.get(i, j) != 0.0 {
                    m.set(i, j, true);
                }
            }
        }
    }
    let p = m.plan();
    let ranges = p.partition_rows(4);
    let mut cursor = 0;
    for r in &ranges {
        assert_eq!(r.start, cursor);
        cursor = r.end;
    }
    assert_eq!(cursor, 96);
    let total: usize = ranges.iter().map(|r| r.clone().map(|i| p.row_nnz(i)).sum::<usize>()).sum();
    assert_eq!(total, p.nnz(), "partition must conserve nnz");
}

#[test]
fn prop_partition_rows_nnz_imbalance_bounded() {
    // On random masks the greedy nnz partition must stay within 10%
    // imbalance across 4 shards (the serving fan-out's balance claim);
    // deterministic seeds keep this reproducible.
    check("partition_imbalance", 12, |rng| {
        let density = 0.1 + rng.uniform() as f64 * 0.2;
        let seed = rng.gen_range_usize(0, 1 << 20) as u64;
        let mask =
            MaskMatrix::from_dense(&SeededRng::new(seed).mask_matrix(320, 320, density));
        let plan = mask.plan();
        let ranges = plan.partition_rows(4);
        prop_assert!(ranges.len() == 4, "expected 4 shards, got {:?}", ranges.len());
        let loads: Vec<usize> =
            ranges.iter().map(|r| r.clone().map(|i| plan.row_nnz(i)).sum()).collect();
        let max = *loads.iter().max().unwrap() as f64;
        let min = *loads.iter().min().unwrap() as f64;
        let imbalance = (max - min) / max.max(1.0);
        prop_assert!(
            imbalance <= 0.10,
            "shard nnz imbalance {imbalance:.3} > 10% (loads {loads:?}, density {density:.2})"
        );
        // and the ranges exactly tile 0..320
        let mut cursor = 0usize;
        for r in &ranges {
            prop_assert!(r.start == cursor, "gap at {r:?}");
            cursor = r.end;
        }
        prop_assert!(cursor == 320, "ranges end at {cursor}");
        Ok(())
    });
}

#[test]
fn prop_plan_slice_rows_matches_subplan_rebuild() {
    // A sliced plan must equal the plan built from scratch on the same
    // row block — across the full density range, empty/full included.
    check("plan_slice_rows", default_cases(), |rng| {
        let n = 8 + rng.gen_range_usize(0, 80);
        let m = 8 + rng.gen_range_usize(0, 80);
        let mask = full_range_mask(rng, n, m);
        let plan = mask.plan();
        prop_assert!(plan.slice_rows(0..n) == plan, "full-range slice must be identity");
        let lo = rng.gen_range_usize(0, n);
        let hi = lo + 1 + rng.gen_range_usize(0, n - lo);
        let sliced = plan.slice_rows(lo..hi);
        let rebuilt = MaskMatrix::from_dense(&mask.to_dense().row_block(lo, hi)).plan();
        prop_assert!(sliced == rebuilt, "slice {lo}..{hi} diverged (n={n}, m={m})");
        Ok(())
    });
}

#[test]
fn prop_sharded_serving_kernels_bit_identical() {
    // The acceptance grid: heads × shards, full density sweep. The
    // sharded encoder layer must produce bit-identical hidden states to
    // the unsharded PR 2 path at every point, shards=1 included.
    check("sharded_equivalence", 12, |rng| {
        let heads = [1, 2, 4][rng.gen_range_usize(0, 3)];
        let shards = 1 + rng.gen_range_usize(0, 5);
        let cfg = ModelConfig {
            seq_len: 24,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            heads,
            ..Default::default()
        };
        let w = MultiHeadWeights::synthetic(&cfg, rng.gen_range_usize(0, 1000) as u64);
        let x = rng.normal_matrix(24, 32, 1.0);
        let masks: Vec<MaskMatrix> =
            (0..heads).map(|_| full_range_mask(rng, 24, 24)).collect();
        let plans = PlanSet::build(&masks);
        let want_z = ops::multi_head_attention_planned(&x, &w, &plans, &cfg);
        let want_h = ops::encoder_layer_heads(&x, &w, &plans, &cfg);
        let sharded = plans.shard(shards);
        let z = ops::multi_head_attention_sharded(&x, &w, &sharded, &cfg);
        prop_assert!(z == want_z, "attention diverged at {heads} heads x {shards} shards");
        let h = ops::encoder_layer_heads_sharded(&x, &w, &sharded, &cfg);
        prop_assert!(h == want_h, "encoder diverged at {heads} heads x {shards} shards");
        Ok(())
    });
}

/// The unfused multi-head reference: every head through the four-pass
/// owned-CSR chain, serially, then concat + optional W_O — the oracle
/// the fused row-streaming path must match bit-for-bit.
fn unfused_multi_head(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
) -> Matrix {
    let zs: Vec<Matrix> = w
        .heads
        .iter()
        .zip(plans.plans())
        .map(|(h, p)| ops::cpsaa_attention_unfused(x, &h.w_s, &h.w_v, p, cfg))
        .collect();
    let blocks: Vec<&Matrix> = zs.iter().collect();
    let z = Matrix::concat_cols(&blocks);
    match &w.w_o {
        Some(o) => z.matmul(o),
        None => z,
    }
}

#[test]
fn prop_fused_bit_identical_to_unfused_grid() {
    // The acceptance grid: density sweep × heads {1,4,8} × shards
    // {1,2,4} × executor axis, exhaustively. The fused row-streaming
    // kernel (with workspace reuse and the zero-copy CsrView) must
    // reproduce the unfused four-pass reference to the last bit at
    // every point — on the crate-wide pool AND on injected pools of 1
    // (strictly serial: the determinism leg) and 3 workers.
    let mut rng = SeededRng::new(4242);
    let serial = Executor::new(1);
    let narrow = Executor::new(3);
    for &heads in &[1usize, 4, 8] {
        for &density in &[0.0, 0.1, 0.5, 1.0] {
            let cfg = ModelConfig {
                seq_len: 24,
                d_model: 32,
                d_k: 8,
                d_ff: 64,
                heads,
                ..Default::default()
            };
            let w = MultiHeadWeights::synthetic(&cfg, 100 + heads as u64);
            let x = rng.normal_matrix(24, 32, 1.0);
            let masks: Vec<MaskMatrix> = (0..heads)
                .map(|_| MaskMatrix::from_dense(&rng.mask_matrix(24, 24, density)))
                .collect();
            let plans = PlanSet::build(&masks);
            let want = unfused_multi_head(&x, &w, &plans, &cfg);
            let fused = ops::multi_head_attention_planned(&x, &w, &plans, &cfg);
            assert!(fused == want, "fused diverged at {heads} heads, density {density}");
            for exec in [&serial, &narrow] {
                let got = ops::multi_head_attention_planned_ws(
                    &x,
                    &w,
                    &plans,
                    &cfg,
                    &WorkspacePool::new(),
                    exec,
                );
                assert!(
                    got == want,
                    "fused diverged at {heads} heads, density {density}, {} executor workers",
                    exec.workers()
                );
            }
            for &shards in &[1usize, 2, 4] {
                let got =
                    ops::multi_head_attention_sharded(&x, &w, &plans.shard(shards), &cfg);
                assert!(
                    got == want,
                    "fused diverged at {heads} heads x {shards} shards, density {density}"
                );
                let got_serial = ops::multi_head_attention_sharded_ws(
                    &x,
                    &w,
                    &plans.shard(shards),
                    &cfg,
                    &WorkspacePool::new(),
                    &serial,
                );
                assert!(
                    got_serial == want,
                    "fused diverged at {heads} heads x {shards} shards, density {density} on \
                     the serial executor"
                );
            }
        }
    }
}

#[test]
fn fused_degenerate_rows_bit_identical() {
    // One mask holding every row shape the streaming kernel must handle:
    // empty rows (zero output, no softmax), single-nnz rows (softmax of
    // one logit = 1.0 exactly), and full rows, plus a mixed stripe.
    let n = 16;
    let mut mask = MaskMatrix::zeros(n, n);
    mask.set(1, 7, true); // single-nnz row
    for j in 0..n {
        mask.set(2, j, true); // full row
    }
    for i in 4..n {
        for j in 0..n {
            if (i * 31 + j * 17) % 3 == 0 {
                mask.set(i, j, true);
            }
        }
    }
    let plan = mask.plan();
    let cfg = ModelConfig { seq_len: n, d_model: 32, d_k: 8, ..Default::default() };
    let w = Weights::synthetic(&cfg, 3);
    let x = SeededRng::new(5).normal_matrix(n, 32, 1.0);
    let fused = ops::cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg);
    let unfused = ops::cpsaa_attention_unfused(&x, &w.w_s, &w.w_v, &plan, &cfg);
    assert!(fused == unfused, "degenerate rows diverged");
    // empty rows 0 and 3 produce exactly-zero output rows
    assert!(fused.row(0).iter().all(|&v| v == 0.0));
    assert!(fused.row(3).iter().all(|&v| v == 0.0));
    // the single-logit softmax row is the selected V row exactly
    let v = x.matmul(&w.w_v);
    assert_eq!(fused.row(1), v.row(7), "single-nnz row must copy V row 7");
}

#[test]
fn prop_planset_stats_match_independent_plans() {
    // Per-head PlanSet statistics (nnz, queue depths, block counts, CSR
    // topology) must match a DispatchPlan built independently from each
    // head's mask, across the full density range.
    check("planset_stats", default_cases(), |rng| {
        let heads = 1 + rng.gen_range_usize(0, 8);
        let n = 4 + rng.gen_range_usize(0, 60);
        let m = 4 + rng.gen_range_usize(0, 60);
        let masks: Vec<MaskMatrix> = (0..heads).map(|_| full_range_mask(rng, n, m)).collect();
        let set = PlanSet::build(&masks);
        prop_assert!(set.heads() == heads, "head count {}", set.heads());
        let mut total = 0usize;
        for (h, mask) in masks.iter().enumerate() {
            let independent = DispatchPlan::build(mask);
            let p = set.plan(h);
            prop_assert!(p.nnz() == independent.nnz(), "head {h} nnz");
            prop_assert!(
                p.col_queue_depths() == independent.col_queue_depths(),
                "head {h} queue depths"
            );
            prop_assert!(
                p.blocks().counts == independent.blocks().counts,
                "head {h} block counts"
            );
            prop_assert!(p.row_ptr() == independent.row_ptr(), "head {h} row_ptr");
            prop_assert!(p.col_idx() == independent.col_idx(), "head {h} col_idx");
            prop_assert!(
                p.max_col_queue() == independent.max_col_queue(),
                "head {h} max queue"
            );
            total += independent.nnz();
        }
        prop_assert!(set.total_nnz() == total, "total nnz {}", set.total_nnz());
        Ok(())
    });
}

#[test]
fn prop_csr_spmm_equals_dense() {
    check("csr_spmm", default_cases(), |rng| {
        let n = 8 + rng.gen_range_usize(0, 56);
        let mask = rand_mask(rng, n);
        let s = rng.normal_matrix(n, n, 1.0);
        let v = rng.normal_matrix(n, 16, 1.0);
        let csr = CsrMatrix::from_dense_masked(&s, &mask);
        let got = csr.spmm(&v);
        let want = csr.to_dense().matmul(&v);
        prop_assert!(got.max_abs_diff(&want) < 1e-4, "spmm mismatch n={n}");
        Ok(())
    });
}

#[test]
fn prop_masked_attention_equals_dense_under_full_mask() {
    check("full_mask_dense", 24, |rng| {
        let cfg = ModelConfig { seq_len: 32, d_model: 64, ..Default::default() };
        let w = Weights::synthetic(&cfg, rng.gen_range_usize(0, 1000) as u64);
        let x = rng.normal_matrix(32, 64, 1.0);
        let ones = MaskMatrix::ones(32, 32);
        let zs = attention::cpsaa_attention(&x, &w.w_s, &w.w_v, &ones, &cfg);
        let zd = attention::dense_attention(&x, &w.w_s, &w.w_v, &cfg);
        prop_assert!(zs.rel_err(&zd) < 1e-4, "rel err {}", zs.rel_err(&zd));
        Ok(())
    });
}

#[test]
fn prop_sddmm_cycles_never_exceed_dense() {
    let hw = HardwareConfig::paper();
    check("sddmm_vs_dense", default_cases(), |rng| {
        let n = 32 + rng.gen_range_usize(0, 288);
        let mask = rand_mask(rng, n);
        let r = sddmm::simulate(&hw, &mask, 512);
        prop_assert!(
            r.cycles <= r.dense_cycles,
            "sparse {} > dense {} (density {})",
            r.cycles,
            r.dense_cycles,
            mask.density()
        );
        Ok(())
    });
}

#[test]
fn prop_spmm_beats_baseline_cycles() {
    let hw = HardwareConfig::paper();
    check("spmm_vs_baseline", default_cases(), |rng| {
        let n = 32 + rng.gen_range_usize(0, 288);
        let mask = rand_mask(rng, n);
        let r = spmm::simulate(&hw, &mask, 64);
        prop_assert!(
            r.cycles <= r.baseline_cycles,
            "replicated {} > baseline {}",
            r.cycles,
            r.baseline_cycles
        );
        prop_assert!(r.replication_factor >= 0.0, "negative replication");
        Ok(())
    });
}

#[test]
fn prop_pipeline_monotone_in_density() {
    // More mask density ⇒ no less total time and no less energy.
    let hw = HardwareConfig::paper();
    let model = ModelConfig { seq_len: 128, ..ModelConfig::paper() };
    check("pipeline_monotone", 16, |rng| {
        let seed = rng.gen_range_usize(0, 1 << 30) as u64;
        let mut mk = |d: f64| {
            MaskMatrix::from_dense(&SeededRng::new(seed).mask_matrix(128, 128, d))
        };
        let lo = pipeline::simulate_batch(&hw, &model, &mk(0.05), pipeline::Mode::Sparse);
        let hi = pipeline::simulate_batch(&hw, &model, &mk(0.6), pipeline::Mode::Sparse);
        prop_assert!(
            hi.breakdown.total_ns >= lo.breakdown.total_ns * 0.99,
            "density not monotone: {} vs {}",
            hi.breakdown.total_ns,
            lo.breakdown.total_ns
        );
        Ok(())
    });
}

#[test]
fn prop_pipeline_phase_sums_bound_total() {
    let hw = HardwareConfig::paper();
    let model = ModelConfig::paper();
    check("phase_bounds", 16, |rng| {
        let mask = rand_mask(rng, model.seq_len);
        let r = pipeline::simulate_batch(&hw, &model, &mask, pipeline::Mode::Sparse);
        let b = r.breakdown;
        let serial = b.prune_ns
            + b.step2_ns
            + b.step3_ns
            + b.softmax_ns
            + b.step4_ns
            + b.wait_for_write_ns
            + b.transfer_ns
            + b.ctrl_ns;
        prop_assert!(b.total_ns <= serial + 1.0, "total {} > serial {serial}", b.total_ns);
        for (name, v) in [
            ("prune", b.prune_ns),
            ("step2", b.step2_ns),
            ("step3", b.step3_ns),
            ("step4", b.step4_ns),
        ] {
            prop_assert!(b.total_ns >= v, "{name} {v} exceeds total {}", b.total_ns);
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_no_loss_no_overlap() {
    check("batcher", default_cases(), |rng| {
        let seq = 16 + rng.gen_range_usize(0, 112);
        let d = 4;
        let mut b = Batcher::new(seq, d);
        let count = 1 + rng.gen_range_usize(0, 24);
        let mut sizes = Vec::new();
        for id in 0..count {
            let rows = 1 + rng.gen_range_usize(0, seq);
            sizes.push((id as u64, rows));
            b.push(id as u64, Matrix::zeros(rows, d)).map_err(|e| e.to_string())?;
        }
        let plans = b.drain();
        // every request appears exactly once with its size
        let mut seen = std::collections::HashMap::new();
        for p in &plans {
            prop_assert!(p.used_rows <= seq, "overfull batch");
            let mut cursor = 0usize;
            for e in &p.entries {
                prop_assert!(e.offset == cursor, "gap/overlap at {}", e.id);
                cursor += e.rows;
                prop_assert!(seen.insert(e.id, e.rows).is_none(), "dup {}", e.id);
            }
        }
        for (id, rows) in sizes {
            prop_assert!(seen.get(&id) == Some(&rows), "lost request {id}");
        }
        Ok(())
    });
}

#[test]
fn prop_binarize_monotone_in_theta() {
    check("binarize_monotone", default_cases(), |rng| {
        let n = 8 + rng.gen_range_usize(0, 56);
        let p = rng.normal_matrix(n, n, 1.0).map(|v| v.abs() / 4.0);
        let t1 = 0.05 + rng.uniform() * 0.2;
        let t2 = t1 + 0.1;
        let loose = attention::mask::binarize(&p, t1);
        let tight = attention::mask::binarize(&p, t2);
        prop_assert!(tight.nnz() <= loose.nnz(), "not monotone");
        let tight_plan = tight.plan();
        for i in 0..n {
            for &j in tight_plan.row_cols(i) {
                prop_assert!(loose.get(i, j as usize), "tight not subset at ({i},{j})");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quant_error_bounded() {
    check("quant_bound", default_cases(), |rng| {
        let x = rng.normal_matrix(16, 16, 0.2);
        let gamma = 4.0 + rng.uniform() * 12.0;
        let r = attention::quant::roundtrip(&x, gamma, 8);
        let bound = 0.5 / gamma + 1e-5;
        let in_range = attention::quant::grid_bound(8) / gamma;
        for (a, b) in x.data().iter().zip(r.data()) {
            if a.abs() < in_range {
                prop_assert!((a - b).abs() <= bound, "err {} > {bound}", (a - b).abs());
            }
        }
        Ok(())
    });
}

/// One (planned, sharded-2) pair at a given precision under whatever
/// lane mode is currently forced — the unit the bit-identity grid
/// compares across the `set_force_scalar` flip.
fn mh_prec(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
    p: Precision,
) -> (Matrix, Matrix) {
    let planned = ops::multi_head_attention_planned_prec(x, w, plans, cfg, p);
    let sharded = ops::multi_head_attention_sharded_prec(x, w, &plans.shard(2), cfg, p);
    (planned, sharded)
}

#[test]
fn prop_simd_scalar_bit_identical_grid() {
    // The lane switch must never change a bit: the scalar twins perform
    // the identical FP operation DAG (same 8-accumulator splits, same
    // pairwise reduction tree, same sequential tail), so flipping
    // `set_force_scalar` mid-process is always value-safe — at every
    // precision, density, head count, and shard count.
    let mut rng = SeededRng::new(777);
    for &heads in &[1usize, 4, 8] {
        for &density in &[0.0, 0.1, 0.5, 1.0] {
            let cfg = ModelConfig {
                seq_len: 24,
                d_model: 32,
                d_k: 8,
                d_ff: 64,
                heads,
                ..Default::default()
            };
            let w = MultiHeadWeights::synthetic(&cfg, 200 + heads as u64);
            let x = rng.normal_matrix(24, 32, 1.0);
            let masks: Vec<MaskMatrix> = (0..heads)
                .map(|_| MaskMatrix::from_dense(&rng.mask_matrix(24, 24, density)))
                .collect();
            let plans = PlanSet::build(&masks);
            for &precision in &[Precision::F32, Precision::I8] {
                simd::set_force_scalar(false);
                let (laned, laned_sharded) = mh_prec(&x, &w, &plans, &cfg, precision);
                simd::set_force_scalar(true);
                let (scalar, scalar_sharded) = mh_prec(&x, &w, &plans, &cfg, precision);
                simd::set_force_scalar(simd::env_force_scalar());
                assert!(
                    laned == scalar,
                    "scalar twin diverged at {heads} heads, density {density}, {precision}"
                );
                assert!(
                    laned_sharded == scalar_sharded,
                    "sharded scalar twin diverged at {heads} heads, density {density}, {precision}"
                );
                assert!(
                    laned_sharded == laned,
                    "2 shards diverged at {heads} heads, density {density}, {precision}"
                );
            }
        }
    }
}

/// Per-row analytic logit-error budget of the i8 score path for one
/// head: quantizing m (per-row γ_m) and kv (per-row γ_k) perturbs each
/// scaled logit by at most
/// `ε_i = scale · d · (max|m_i|·e_k + max|kv|·e_m_i + e_m_i·e_k)` with
/// `e = 0.5/γ` the half-grid-step dequantization error, taking the
/// worst kv row. A uniform logit shift of ±ε multiplies every softmax
/// weight by at most e^{±2ε}, so the output row is off by at most
/// `(e^{2ε_i} − 1) · max|V|` per component.
fn i8_row_bounds(m: &Matrix, kv: &Matrix, v: &Matrix, scale: f64) -> (Vec<f64>, f64) {
    let qm = QuantizedRows::from_matrix(m);
    let qk = QuantizedRows::from_matrix(kv);
    let d = m.cols() as f64;
    let row_max = |mat: &Matrix, i: usize| {
        mat.row(i).iter().fold(0.0f64, |a, &v| a.max(f64::from(v).abs()))
    };
    let e_k = (0..kv.rows()).map(|j| 0.5 / f64::from(qk.scale(j))).fold(0.0, f64::max);
    let kv_max = (0..kv.rows()).map(|j| row_max(kv, j)).fold(0.0, f64::max);
    let v_max = v.data().iter().fold(0.0f64, |a, &x| a.max(f64::from(x).abs()));
    let bounds = (0..m.rows())
        .map(|i| {
            let e_m = 0.5 / f64::from(qm.scale(i));
            let eps = scale * d * (row_max(m, i) * e_k + kv_max * e_m + e_m * e_k);
            ((2.0 * eps).exp() - 1.0) * v_max
        })
        .collect();
    (bounds, v_max)
}

#[test]
fn prop_i8_attention_error_bounded_grid() {
    // The i8 path against the f32 oracle across the acceptance grid:
    // every output row stays inside its analytic quantization budget
    // (per-row γs, softmax amplification, f32 slop), and the i8 result
    // itself is bit-identical across shard counts (per-row γ is row-
    // slice invariant).
    let mut rng = SeededRng::new(31337);
    for &heads in &[1usize, 4, 8] {
        for &density in &[0.0, 0.1, 0.5, 1.0] {
            let cfg = ModelConfig {
                seq_len: 24,
                d_model: 32,
                d_k: 8,
                d_ff: 64,
                heads,
                ..Default::default()
            };
            let w = MultiHeadWeights::synthetic(&cfg, 300 + heads as u64);
            let x = rng.normal_matrix(24, 32, 1.0);
            let masks: Vec<MaskMatrix> = (0..heads)
                .map(|_| MaskMatrix::from_dense(&rng.mask_matrix(24, 24, density)))
                .collect();
            let plans = PlanSet::build(&masks);
            let oracle = unfused_multi_head(&x, &w, &plans, &cfg);
            let got = ops::multi_head_attention_planned_prec(&x, &w, &plans, &cfg, Precision::I8);
            assert_eq!(got.shape(), oracle.shape());
            assert!(got.all_finite(), "i8 output not finite at {heads} heads, {density}");

            // Per-row worst-head z budget, then through the optional W_O
            // mixing (row inf-norm: |Δ(z·W_O)| ≤ d_model·maxΔz·max|W_O|).
            let scale = 1.0 / f64::from(cfg.d_k as u32).sqrt();
            let per_head: Vec<(Vec<f64>, f64)> = w
                .heads
                .iter()
                .map(|h| i8_row_bounds(&x.matmul(&h.w_s), &x, &x.matmul(&h.w_v), scale))
                .collect();
            // W_O mixes the concat row: |Δ(z·W_O)|∞ ≤ width(z)·maxΔz·max|W_O|.
            let wo_mix = w.w_o.as_ref().map(|o| {
                let om = o.data().iter().fold(0.0f64, |a, &v| a.max(f64::from(v).abs()));
                o.rows() as f64 * om
            });
            for i in 0..24 {
                let z_bound = per_head.iter().map(|(b, _)| b[i]).fold(0.0, f64::max);
                let bound = match wo_mix {
                    Some(mix) => mix * z_bound,
                    None => z_bound,
                } + 1e-3;
                let err = got
                    .row(i)
                    .iter()
                    .zip(oracle.row(i))
                    .map(|(&a, &b)| (f64::from(a) - f64::from(b)).abs())
                    .fold(0.0, f64::max);
                assert!(
                    err <= bound,
                    "row {i}: i8 error {err} > budget {bound} at {heads} heads, density {density}"
                );
            }

            // Shard invariance of the i8 result itself.
            for &shards in &[1usize, 2] {
                let sharded = ops::multi_head_attention_sharded_prec(
                    &x,
                    &w,
                    &plans.shard(shards),
                    &cfg,
                    Precision::I8,
                );
                assert!(
                    sharded == got,
                    "i8 diverged at {heads} heads x {shards} shards, density {density}"
                );
            }

            // The quantized path must actually quantize: on a dense-ish
            // mask the score grid error is far above f32 ulps.
            if density >= 0.5 {
                assert!(
                    got != oracle,
                    "i8 output bit-identical to f32 at {heads} heads, density {density} — \
                     the precision knob is not reaching the kernel"
                );
            }
        }
    }
}
