//! Cross-layer integration tests: PJRT artifacts × golden model ×
//! simulator × coordinator. These are the "all layers compose" checks —
//! they are skipped (with a notice) when `make artifacts` has not run.

use std::path::PathBuf;
use std::time::Duration;

use cpsaa::attention::{self, MultiHeadWeights, Weights};
use cpsaa::config::{HardwareConfig, ModelConfig, SystemConfig};
use cpsaa::coordinator::{EncoderStack, Service, ServiceConfig};
use cpsaa::runtime::{ArtifactSet, Engine};
use cpsaa::sim::ChipSim;
use cpsaa::sparse::{MaskMatrix, PlanSet};
use cpsaa::tensor::{Matrix, SeededRng};

fn artifacts() -> Option<ArtifactSet> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactSet::open(&dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping integration test: {e} (run `make artifacts`)");
            None
        }
    }
}

fn model_of(set: &ArtifactSet) -> ModelConfig {
    let c = &set.manifest.config;
    ModelConfig {
        seq_len: c.seq_len,
        d_model: c.d_model,
        d_k: c.d_k,
        d_ff: c.d_ff,
        gamma: c.gamma,
        quant_bits: c.quant_bits,
        theta: c.theta,
        ..ModelConfig::default()
    }
}

#[test]
fn pjrt_matches_rust_golden_model() {
    // The same computation three ways: JAX fixtures (via file), PJRT
    // execution (via the native engine), and the pure-rust golden model.
    let Some(set) = artifacts() else { return };
    let engine = Engine::load(&set).unwrap();
    let weights = Weights::from_json_file(&set.dir.join("weights.json")).unwrap();
    let fix = set.fixtures().unwrap();
    let model = model_of(&set);

    // PJRT mask == golden mask (binarization is exact, so identical).
    let pjrt_mask = &engine.execute("mask_gen", &[&fix.x, &weights.w_s]).unwrap()[0];
    let golden_mask = attention::generate_mask(&fix.x, &weights.w_s, &model);
    assert_eq!(
        MaskMatrix::from_dense(pjrt_mask),
        golden_mask,
        "PJRT and golden pruning masks disagree"
    );

    // PJRT attention == golden attention under the same mask.
    let pjrt_z =
        &engine.execute("attention", &[&fix.x, &weights.w_s, &weights.w_v, pjrt_mask]).unwrap()[0];
    let golden_z =
        attention::cpsaa_attention(&fix.x, &weights.w_s, &weights.w_v, &golden_mask, &model);
    let err = pjrt_z.rel_err(&golden_z);
    assert!(err < 1e-4, "PJRT vs golden attention rel err {err}");
}

#[test]
fn dense_attention_artifact_matches_golden() {
    let Some(set) = artifacts() else { return };
    let engine = Engine::load(&set).unwrap();
    let weights = Weights::from_json_file(&set.dir.join("weights.json")).unwrap();
    let fix = set.fixtures().unwrap();
    let model = model_of(&set);
    let pjrt = &engine.execute("dense_attention", &[&fix.x, &weights.w_s, &weights.w_v]).unwrap()[0];
    let golden = attention::dense_attention(&fix.x, &weights.w_s, &weights.w_v, &model);
    let err = pjrt.rel_err(&golden);
    assert!(err < 1e-4, "dense attention rel err {err}");
}

#[test]
fn encoder_stack_simulates_while_executing() {
    let Some(set) = artifacts() else { return };
    let engine = Engine::load(&set).unwrap();
    let weights = MultiHeadWeights::load(&set.dir.join("weights.json"), 1).unwrap();
    let model = model_of(&set);
    let stack = EncoderStack::new(&engine, weights, HardwareConfig::paper(), model.clone(), 3);
    let fix = set.fixtures().unwrap();
    let outs = stack.forward(&fix.x).unwrap();
    assert_eq!(outs.len(), 3);
    // hardware accounting must be live for every layer, densities sane
    for (i, o) in outs.iter().enumerate() {
        assert!(o.sim_ns > 0.0 && o.sim_pj > 0.0, "layer {i} has no sim cost");
        assert!(o.mask_density > 0.0 && o.mask_density < 1.0, "layer {i} density {}", o.mask_density);
        assert!(o.hidden.all_finite());
    }
}

#[test]
fn service_end_to_end_with_simulated_cost() {
    let Some(set) = artifacts() else { return };
    let d_model = set.manifest.config.d_model;
    drop(set);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let svc = Service::start(
        dir,
        HardwareConfig::paper(),
        ModelConfig::paper(),
        ServiceConfig { layers: 2, ..Default::default() },
    )
    .unwrap();
    let mut rng = SeededRng::new(77);
    for id in 0..3u64 {
        let rows = 8 + rng.gen_range_usize(0, 48);
        let x = rng.normal_matrix(rows, d_model, 1.0);
        let resp = svc.infer(id, x).unwrap();
        assert_eq!(resp.hidden.rows(), rows);
        assert!(resp.sim_ns > 0.0);
        assert!(resp.mask_density > 0.0);
    }
    let m = svc.metrics();
    assert_eq!(m.requests, 3);
    assert!(m.sim_pj > 0.0);
    assert!(m.batch_utilization() > 0.0);
}

/// Small 8-head model every multi-head integration test shares.
fn heads8_model() -> ModelConfig {
    ModelConfig {
        seq_len: 32,
        d_model: 64,
        d_k: 8,
        d_ff: 128,
        heads: 8,
        ..ModelConfig::default()
    }
}

#[test]
fn served_heads8_matches_golden_multihead_reference() {
    // Acceptance: a served request with heads = 8 must produce the same
    // hidden states as the golden model's multi-head reference, and its
    // simulated cost must be max-over-heads latency / sum-over-heads
    // energy. Artifacts are synthesized, so this runs everywhere.
    let dir = std::env::temp_dir()
        .join(format!("cpsaa-it-heads8-golden-{}", std::process::id()));
    let model = heads8_model();
    ArtifactSet::synthesize(&dir, &model, 42).unwrap();
    let layers = 2usize;
    let svc = Service::start(
        dir.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig { layers, ..Default::default() },
    )
    .unwrap();
    let rows = 20usize;
    let x = SeededRng::new(99).normal_matrix(rows, model.d_model, 1.0);
    let resp = svc.infer(7, x.clone()).unwrap();
    assert_eq!(resp.id, 7);
    assert_eq!(resp.hidden.shape(), (rows, model.d_model));
    assert_eq!(resp.heads(), 8);

    // Golden multi-head reference over the same padded batch.
    let w = MultiHeadWeights::load(&dir.join("weights.json"), 8).unwrap();
    let mut h = Matrix::zeros(model.seq_len, model.d_model);
    h.data_mut()[..rows * model.d_model].copy_from_slice(x.data());
    for _ in 0..layers {
        let masks = attention::generate_head_masks(&h, &w, &model);
        let plans = PlanSet::build(&masks);
        h = attention::ops::encoder_layer_heads(&h, &w, &plans, &model);
    }
    let want = Matrix::from_vec(
        rows,
        model.d_model,
        h.data()[..rows * model.d_model].to_vec(),
    );
    // Same code path on both sides ⇒ the served result is bit-identical.
    assert_eq!(resp.hidden, want, "served hidden != golden multi-head reference");

    // Cost attribution: latency is the slowest head, energy sums.
    assert_eq!(resp.head_sim_ns.len(), 8);
    let max_head = resp.head_sim_ns.iter().copied().fold(0.0, f64::max);
    assert_eq!(resp.sim_ns, max_head, "sim latency must be max over heads");
    assert!(resp.head_sim_ns.iter().all(|&v| v > 0.0));
    let resp_pj_sum: f64 = resp.head_sim_pj.iter().sum();
    assert!(
        (resp_pj_sum - resp.sim_pj).abs() < 1e-6 * resp.sim_pj.max(1.0),
        "response energy must sum over heads: {resp_pj_sum} vs {}",
        resp.sim_pj
    );
    let m = svc.metrics();
    assert_eq!(m.heads.len(), 8);
    let head_pj_sum: f64 = m.heads.iter().map(|h| h.sim_pj).sum();
    assert!(
        (head_pj_sum - m.sim_pj).abs() < 1e-6 * m.sim_pj.max(1.0),
        "sim energy must sum over heads: {head_pj_sum} vs {}",
        m.sim_pj
    );
    // per-head densities are finite and sane
    for &d in &resp.head_density {
        assert!(d.is_finite() && (0.0..=1.0).contains(&d), "density {d}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn served_shards4_bit_identical_to_shards1_with_shard_lines() {
    // Acceptance: a served request with shards = 4 must produce exactly
    // the hidden states of the unsharded (PR 2) path, carry per-shard
    // cost lines that merge as max-ns / sum-pJ, and leave per-shard
    // metrics behind. Artifacts are synthesized, so this runs anywhere.
    let model = heads8_model();
    let dir1 = std::env::temp_dir()
        .join(format!("cpsaa-it-shards1-{}", std::process::id()));
    let dir4 = std::env::temp_dir()
        .join(format!("cpsaa-it-shards4-{}", std::process::id()));
    ArtifactSet::synthesize(&dir1, &model, 42).unwrap();
    ArtifactSet::synthesize(&dir4, &model, 42).unwrap();
    let svc1 = Service::start(
        dir1.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig { layers: 2, shards: 1, ..Default::default() },
    )
    .unwrap();
    let svc4 = Service::start(
        dir4.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig { layers: 2, shards: 4, ..Default::default() },
    )
    .unwrap();
    let x = SeededRng::new(123).normal_matrix(20, model.d_model, 1.0);
    let r1 = svc1.infer(1, x.clone()).unwrap();
    let r4 = svc4.infer(1, x).unwrap();

    // shards=1 responses stay exactly the unsharded shape: no shard lines
    assert!(r1.shard_sim_ns.is_empty());
    assert_eq!(r1.shards(), 1);

    // functional equivalence to the bit
    assert_eq!(r4.hidden, r1.hidden, "sharded serving changed the results");
    assert_eq!(r4.heads(), 8);
    assert!(!r4.shard_sim_ns.is_empty() && r4.shard_sim_ns.len() <= 4);
    assert_eq!(r4.shards(), r4.shard_sim_ns.len());
    assert_eq!(r4.shard_rows.iter().sum::<usize>(), model.seq_len, "shards tile the batch");

    // cost merge: latency is the slowest chip, energy sums over chips
    let max_shard = r4.shard_sim_ns.iter().copied().fold(0.0, f64::max);
    assert_eq!(r4.sim_ns, max_shard, "sim latency must be max over shards");
    let shard_pj: f64 = r4.shard_sim_pj.iter().sum();
    assert!(
        (shard_pj - r4.sim_pj).abs() < 1e-6 * r4.sim_pj.max(1.0),
        "energy must sum over shards: {shard_pj} vs {}",
        r4.sim_pj
    );
    // per-head lines survive sharding and still bound the batch
    assert_eq!(r4.head_sim_ns.len(), 8);
    let max_head = r4.head_sim_ns.iter().copied().fold(0.0, f64::max);
    assert_eq!(r4.sim_ns, max_head, "head and shard roll-ups must agree");
    // densities are batch properties, identical across modes
    assert_eq!(r4.head_density, r1.head_density);

    // per-shard metrics recorded, attributed to this batch
    let m = svc4.metrics();
    assert!(!m.shards.is_empty() && m.shards.len() <= 4);
    assert_eq!(m.shards.iter().map(|s| s.rows).sum::<u64>(), model.seq_len as u64);
    assert!(!m.shard_lines.is_empty());
    assert!(m.shard_lines.iter().all(|l| l.batch == 0), "first batch id must be 0");
    std::fs::remove_dir_all(&dir1).ok();
    std::fs::remove_dir_all(&dir4).ok();
}

#[test]
fn metric_lines_attributable_across_batches() {
    // Two sequential requests → two packed batches; every per-head and
    // per-shard line must name its batch so interleaved logs stay
    // attributable.
    let model = heads8_model();
    let dir = std::env::temp_dir()
        .join(format!("cpsaa-it-batchid-{}", std::process::id()));
    ArtifactSet::synthesize(&dir, &model, 9).unwrap();
    let svc = Service::start(
        dir.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig { layers: 1, shards: 2, ..Default::default() },
    )
    .unwrap();
    let mut rng = SeededRng::new(55);
    for id in 0..2u64 {
        let x = rng.normal_matrix(12, model.d_model, 1.0);
        svc.infer(id, x).unwrap();
    }
    let m = svc.metrics();
    assert_eq!(m.batches, 2);
    let head_batches: std::collections::BTreeSet<u64> =
        m.head_lines.iter().map(|l| l.batch).collect();
    assert_eq!(head_batches, std::collections::BTreeSet::from([0u64, 1]));
    let shard_batches: std::collections::BTreeSet<u64> =
        m.shard_lines.iter().map(|l| l.batch).collect();
    assert_eq!(shard_batches, std::collections::BTreeSet::from([0u64, 1]));
    // within one batch, head lines cover every head exactly once
    let batch0_heads: Vec<usize> =
        m.head_lines.iter().filter(|l| l.batch == 0).map(|l| l.head).collect();
    assert_eq!(batch0_heads, (0..8).collect::<Vec<usize>>());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn served_leaders4_bit_identical_to_leaders1() {
    // Acceptance: multi-leader serving must not change a single bit of
    // any response. Requests are submitted one at a time so both
    // services pack identical batches; whichever leader picks a batch
    // up, the hidden states must match the single-leader service
    // exactly, and leader metrics must account for every batch.
    let model = heads8_model();
    let dir1 = std::env::temp_dir()
        .join(format!("cpsaa-it-leaders1-{}", std::process::id()));
    let dir4 = std::env::temp_dir()
        .join(format!("cpsaa-it-leaders4-{}", std::process::id()));
    ArtifactSet::synthesize(&dir1, &model, 42).unwrap();
    ArtifactSet::synthesize(&dir4, &model, 42).unwrap();
    let svc1 = Service::start(
        dir1.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig { layers: 2, leaders: 1, shards: 2, ..Default::default() },
    )
    .unwrap();
    let svc4 = Service::start(
        dir4.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig { layers: 2, leaders: 4, shards: 2, ..Default::default() },
    )
    .unwrap();
    let mut rng = SeededRng::new(321);
    for id in 0..4u64 {
        let x = rng.normal_matrix(20, model.d_model, 1.0);
        let r1 = svc1.infer(id, x.clone()).unwrap();
        let r4 = svc4.infer(id, x).unwrap();
        assert_eq!(r4.hidden, r1.hidden, "request {id}: multi-leader serving changed bits");
        assert_eq!(r1.leader, 0, "single-leader service has one leader");
        assert!(r4.leader < 4, "leader index out of range");
        // cost attribution is a pure function of the packed batch —
        // identical whichever leader executed it
        assert_eq!(r4.sim_ns, r1.sim_ns);
        assert_eq!(r4.head_density, r1.head_density);
    }
    let m4 = svc4.metrics();
    assert_eq!(m4.requests, 4);
    let leader_batches: u64 = m4.leaders.iter().map(|l| l.batches).sum();
    assert_eq!(leader_batches, m4.batches, "every batch must be attributed to a leader");
    let leader_requests: u64 = m4.leaders.iter().map(|l| l.requests).sum();
    assert_eq!(leader_requests, m4.requests);
    // batch ids stay unique across leaders (shared monotonic source)
    let mut ids: Vec<u64> = m4.head_lines.iter().map(|l| l.batch).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len() as u64, m4.batches, "batch ids reused across leaders");
    std::fs::remove_dir_all(&dir1).ok();
    std::fs::remove_dir_all(&dir4).ok();
}

#[test]
fn multi_leader_concurrent_load_loses_nothing() {
    // 8 client threads hammering a 3-leader service: every reply
    // arrives, routed to the right caller, finite, and the leader
    // roll-up covers all batches.
    let model = heads8_model();
    let dir = std::env::temp_dir()
        .join(format!("cpsaa-it-leaders-conc-{}", std::process::id()));
    ArtifactSet::synthesize(&dir, &model, 13).unwrap();
    let svc = Service::start(
        dir.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig {
            layers: 1,
            leaders: 3,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .unwrap();
    const CLIENTS: u64 = 8;
    const PER_CLIENT: u64 = 3;
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let svc = svc.clone();
        let d_model = model.d_model;
        let seq_len = model.seq_len;
        handles.push(std::thread::spawn(move || {
            let mut rng = SeededRng::new(5000 + c);
            let mut got = Vec::new();
            for r in 0..PER_CLIENT {
                let id = c * PER_CLIENT + r;
                let rows = 1 + rng.gen_range_usize(0, seq_len);
                let x = rng.normal_matrix(rows, d_model, 1.0);
                let resp = svc.infer(id, x).expect("infer failed");
                assert_eq!(resp.id, id, "reply routed to the wrong caller");
                assert_eq!(resp.hidden.shape(), (rows, d_model));
                assert!(resp.hidden.all_finite());
                assert!(resp.leader < 3);
                got.push(id);
            }
            got
        }));
    }
    let mut ids: Vec<u64> =
        handles.into_iter().flat_map(|h| h.join().expect("client panicked")).collect();
    ids.sort();
    assert_eq!(ids, (0..CLIENTS * PER_CLIENT).collect::<Vec<u64>>(), "lost replies");
    let m = svc.metrics();
    assert_eq!(m.requests, CLIENTS * PER_CLIENT);
    let leader_batches: u64 = m.leaders.iter().map(|l| l.batches).sum();
    assert_eq!(leader_batches, m.batches);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn service_rejects_zero_layers_at_startup() {
    let dir = std::env::temp_dir()
        .join(format!("cpsaa-it-layers0-{}", std::process::id()));
    let model = heads8_model();
    ArtifactSet::synthesize(&dir, &model, 5).unwrap();
    // (Service is not Debug, so no unwrap_err.)
    let err = match Service::start(
        dir.clone(),
        HardwareConfig::paper(),
        model,
        ServiceConfig { layers: 0, ..Default::default() },
    ) {
        Ok(_) => panic!("layers = 0 must be rejected at startup"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("layers"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn service_concurrent_mixed_lengths_heads8() {
    // N client threads × mixed-length requests against an 8-head stack:
    // every reply arrives, ids and shapes match, densities are finite.
    let dir = std::env::temp_dir()
        .join(format!("cpsaa-it-heads8-conc-{}", std::process::id()));
    let model = heads8_model();
    ArtifactSet::synthesize(&dir, &model, 17).unwrap();
    let svc = Service::start(
        dir.clone(),
        HardwareConfig::paper(),
        model.clone(),
        ServiceConfig { layers: 1, max_wait: Duration::from_millis(5), ..Default::default() },
    )
    .unwrap();
    const CLIENTS: u64 = 6;
    const PER_CLIENT: u64 = 3;
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let svc = svc.clone();
        let d_model = model.d_model;
        let seq_len = model.seq_len;
        handles.push(std::thread::spawn(move || {
            let mut rng = SeededRng::new(1000 + c);
            let mut got = Vec::new();
            for r in 0..PER_CLIENT {
                let id = c * PER_CLIENT + r;
                let rows = 1 + rng.gen_range_usize(0, seq_len);
                let x = rng.normal_matrix(rows, d_model, 1.0);
                let resp = svc.infer(id, x).expect("infer failed");
                assert_eq!(resp.id, id, "reply routed to the wrong caller");
                assert_eq!(resp.hidden.shape(), (rows, d_model));
                assert!(resp.hidden.all_finite());
                assert!(resp.mask_density.is_finite());
                assert_eq!(resp.heads(), 8);
                assert!(resp.head_density.iter().all(|d| d.is_finite()));
                let max_head = resp.head_sim_ns.iter().copied().fold(0.0, f64::max);
                assert_eq!(resp.sim_ns, max_head);
                got.push(id);
            }
            got
        }));
    }
    let mut ids: Vec<u64> =
        handles.into_iter().flat_map(|h| h.join().expect("client panicked")).collect();
    ids.sort();
    assert_eq!(ids, (0..CLIENTS * PER_CLIENT).collect::<Vec<u64>>(), "lost replies");
    let m = svc.metrics();
    assert_eq!(m.requests, CLIENTS * PER_CLIENT);
    assert!(m.batches >= 1 && m.batches <= m.requests);
    assert_eq!(m.heads.len(), 8);
    assert!(m.head_mean_densities().iter().all(|d| d.is_finite()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn simulator_consistent_with_artifact_masks() {
    // Use the real (JAX-produced) pruning mask to drive the cycle
    // simulator: sparse must beat dense on the same mask, and the figure
    // harness must run on the artifact-shaped config too.
    let Some(set) = artifacts() else { return };
    let fix = set.fixtures().unwrap();
    let mask = MaskMatrix::from_dense(&fix.outputs["mask_gen"][0]);
    let model = model_of(&set);
    let sparse = ChipSim::new(HardwareConfig::paper(), model.clone()).simulate_batch(&mask);
    let dense = ChipSim::new(HardwareConfig::paper(), model).dense().simulate_batch(&mask);
    assert!(
        sparse.breakdown.total_ns < dense.breakdown.total_ns,
        "sparse {} >= dense {}",
        sparse.breakdown.total_ns,
        dense.breakdown.total_ns
    );
    assert!(sparse.gops > dense.gops);
}

#[test]
fn figures_run_on_artifact_config() {
    // Every figure harness must also run on a non-paper config (the
    // artifact shape) without panicking — config generality check.
    let cfg = SystemConfig {
        model: ModelConfig::artifact_default(),
        ..SystemConfig::paper()
    };
    for id in cpsaa::bench_harness::ALL_FIGURES {
        let tables = cpsaa::bench_harness::run_figure(id, &cfg)
            .unwrap_or_else(|| panic!("missing figure {id}"));
        for t in tables {
            assert!(!t.rows.is_empty(), "figure {id} empty");
            for (label, vals) in &t.rows {
                for v in vals {
                    assert!(v.is_finite(), "figure {id} row {label} not finite");
                }
            }
        }
    }
}
