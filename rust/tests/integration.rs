//! Cross-layer integration tests: PJRT artifacts × golden model ×
//! simulator × coordinator. These are the "all layers compose" checks —
//! they are skipped (with a notice) when `make artifacts` has not run.

use std::path::PathBuf;

use cpsaa::attention::{self, Weights};
use cpsaa::config::{HardwareConfig, ModelConfig, SystemConfig};
use cpsaa::coordinator::{EncoderStack, Service, ServiceConfig};
use cpsaa::runtime::{ArtifactSet, Engine};
use cpsaa::sim::ChipSim;
use cpsaa::sparse::MaskMatrix;
use cpsaa::tensor::SeededRng;

fn artifacts() -> Option<ArtifactSet> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactSet::open(&dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping integration test: {e} (run `make artifacts`)");
            None
        }
    }
}

fn model_of(set: &ArtifactSet) -> ModelConfig {
    let c = &set.manifest.config;
    ModelConfig {
        seq_len: c.seq_len,
        d_model: c.d_model,
        d_k: c.d_k,
        d_ff: c.d_ff,
        gamma: c.gamma,
        quant_bits: c.quant_bits,
        theta: c.theta,
        ..ModelConfig::default()
    }
}

#[test]
fn pjrt_matches_rust_golden_model() {
    // The same computation three ways: JAX fixtures (via file), PJRT
    // execution (via the native engine), and the pure-rust golden model.
    let Some(set) = artifacts() else { return };
    let engine = Engine::load(&set).unwrap();
    let weights = Weights::from_json_file(&set.dir.join("weights.json")).unwrap();
    let fix = set.fixtures().unwrap();
    let model = model_of(&set);

    // PJRT mask == golden mask (binarization is exact, so identical).
    let pjrt_mask = &engine.execute("mask_gen", &[&fix.x, &weights.w_s]).unwrap()[0];
    let golden_mask = attention::generate_mask(&fix.x, &weights.w_s, &model);
    assert_eq!(
        MaskMatrix::from_dense(pjrt_mask),
        golden_mask,
        "PJRT and golden pruning masks disagree"
    );

    // PJRT attention == golden attention under the same mask.
    let pjrt_z =
        &engine.execute("attention", &[&fix.x, &weights.w_s, &weights.w_v, pjrt_mask]).unwrap()[0];
    let golden_z =
        attention::cpsaa_attention(&fix.x, &weights.w_s, &weights.w_v, &golden_mask, &model);
    let err = pjrt_z.rel_err(&golden_z);
    assert!(err < 1e-4, "PJRT vs golden attention rel err {err}");
}

#[test]
fn dense_attention_artifact_matches_golden() {
    let Some(set) = artifacts() else { return };
    let engine = Engine::load(&set).unwrap();
    let weights = Weights::from_json_file(&set.dir.join("weights.json")).unwrap();
    let fix = set.fixtures().unwrap();
    let model = model_of(&set);
    let pjrt = &engine.execute("dense_attention", &[&fix.x, &weights.w_s, &weights.w_v]).unwrap()[0];
    let golden = attention::dense_attention(&fix.x, &weights.w_s, &weights.w_v, &model);
    let err = pjrt.rel_err(&golden);
    assert!(err < 1e-4, "dense attention rel err {err}");
}

#[test]
fn encoder_stack_simulates_while_executing() {
    let Some(set) = artifacts() else { return };
    let engine = Engine::load(&set).unwrap();
    let weights = Weights::from_json_file(&set.dir.join("weights.json")).unwrap();
    let model = model_of(&set);
    let stack = EncoderStack::new(&engine, weights, HardwareConfig::paper(), model.clone(), 3);
    let fix = set.fixtures().unwrap();
    let outs = stack.forward(&fix.x).unwrap();
    assert_eq!(outs.len(), 3);
    // hardware accounting must be live for every layer, densities sane
    for (i, o) in outs.iter().enumerate() {
        assert!(o.sim_ns > 0.0 && o.sim_pj > 0.0, "layer {i} has no sim cost");
        assert!(o.mask_density > 0.0 && o.mask_density < 1.0, "layer {i} density {}", o.mask_density);
        assert!(o.hidden.all_finite());
    }
}

#[test]
fn service_end_to_end_with_simulated_cost() {
    let Some(set) = artifacts() else { return };
    let d_model = set.manifest.config.d_model;
    drop(set);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let svc = Service::start(
        dir,
        HardwareConfig::paper(),
        ModelConfig::paper(),
        ServiceConfig { layers: 2, ..Default::default() },
    )
    .unwrap();
    let mut rng = SeededRng::new(77);
    for id in 0..3u64 {
        let rows = 8 + rng.gen_range_usize(0, 48);
        let x = rng.normal_matrix(rows, d_model, 1.0);
        let resp = svc.infer(id, x).unwrap();
        assert_eq!(resp.hidden.rows(), rows);
        assert!(resp.sim_ns > 0.0);
        assert!(resp.mask_density > 0.0);
    }
    let m = svc.metrics();
    assert_eq!(m.requests, 3);
    assert!(m.sim_pj > 0.0);
    assert!(m.batch_utilization() > 0.0);
}

#[test]
fn simulator_consistent_with_artifact_masks() {
    // Use the real (JAX-produced) pruning mask to drive the cycle
    // simulator: sparse must beat dense on the same mask, and the figure
    // harness must run on the artifact-shaped config too.
    let Some(set) = artifacts() else { return };
    let fix = set.fixtures().unwrap();
    let mask = MaskMatrix::from_dense(&fix.outputs["mask_gen"][0]);
    let model = model_of(&set);
    let sparse = ChipSim::new(HardwareConfig::paper(), model.clone()).simulate_batch(&mask);
    let dense = ChipSim::new(HardwareConfig::paper(), model).dense().simulate_batch(&mask);
    assert!(
        sparse.breakdown.total_ns < dense.breakdown.total_ns,
        "sparse {} >= dense {}",
        sparse.breakdown.total_ns,
        dense.breakdown.total_ns
    );
    assert!(sparse.gops > dense.gops);
}

#[test]
fn figures_run_on_artifact_config() {
    // Every figure harness must also run on a non-paper config (the
    // artifact shape) without panicking — config generality check.
    let cfg = SystemConfig {
        model: ModelConfig::artifact_default(),
        ..SystemConfig::paper()
    };
    for id in cpsaa::bench_harness::ALL_FIGURES {
        let tables = cpsaa::bench_harness::run_figure(id, &cfg)
            .unwrap_or_else(|| panic!("missing figure {id}"));
        for t in tables {
            assert!(!t.rows.is_empty(), "figure {id} empty");
            for (label, vals) in &t.rows {
                for v in vals {
                    assert!(v.is_finite(), "figure {id} row {label} not finite");
                }
            }
        }
    }
}
