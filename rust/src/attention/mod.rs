//! Functional golden model of the CPSAA dataflow, in pure rust.
//!
//! Mirrors `python/compile/model.py` op-for-op so the simulator and the
//! coordinator can validate numerics without touching PJRT, and so the
//! PJRT integration tests have a second, independent oracle. The paper's
//! phases map to:
//!
//! * [`mask::generate`] — Step 1, eq. 4 (PIM pruning)
//! * [`ops::cpsaa_attention`] — Steps 2–4, eq. 3 (SDDMM → softmax → SpMM)
//! * [`ops::multi_head_attention_planned`] — the §4.5 head fan-out:
//!   per-head masks/plans, heads concurrent on disjoint tile slices,
//!   concat + optional W_O
//! * [`ops::dense_attention`] — the CPDAA dense mode of Fig. 14
//! * [`ops::vanilla_attention`] — Fig. 1a, used to prove eq. 2 ≡ eq. 3
//!
//! The hot path runs *fused*: [`fused`] streams SDDMM → scale → softmax
//! → SpMM one query row at a time over the plan topology (bit-identical
//! to the unfused reference chain, which [`ops::cpsaa_attention_unfused`]
//! keeps alive for property tests and benches), with every large
//! intermediate drawn from a [`workspace::KernelWorkspace`].

pub(crate) mod fused;
pub mod mask;
pub mod ops;
pub mod quant;
pub mod softmax;
pub mod weights;
pub mod workspace;

pub use mask::generate as generate_mask;
pub use mask::generate_heads as generate_head_masks;
pub use ops::{cpsaa_attention, dense_attention, vanilla_attention};
pub use quant::{Precision, QuantizedRows};
pub use weights::{HeadWeights, MultiHeadWeights, Weights};
pub use workspace::{KernelWorkspace, WorkspacePool};
