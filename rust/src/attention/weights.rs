//! Synthetic model weights mirroring `python/compile/model.py::init_weights`.
//!
//! Not bit-identical to the JAX weights (different RNG); numerical
//! cross-checks against the python side go through `artifacts/weights.json`
//! (see [`Weights::from_json_file`]). The seeded constructor exists so the
//! simulator and benches can run without artifacts.

use std::path::Path;

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::config::ModelConfig;
use crate::tensor::{Matrix, SeededRng};
use crate::util::json::Json;

/// One attention layer's weights in the CPSAA storage layout:
/// the *folded* `w_s = w_q @ w_k^T` plus `w_v` (ROA contents) and the
/// FC block (the ISAAC-style encoder tail, §4.5).
#[derive(Clone, Debug)]
pub struct Weights {
    pub w_s: Matrix,
    pub w_v: Matrix,
    pub w_fc1: Matrix,
    pub w_fc2: Matrix,
}

impl Weights {
    /// Deterministic synthetic weights (see ModelConfig::sharpness for why
    /// the attention logits are scaled).
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Self {
        let d = cfg.d_model;
        let dk = cfg.d_k;
        let scale = 1.0 / (d as f32).sqrt();
        let mut rng = SeededRng::new(seed);
        let w_q = rng.normal_matrix(d, dk, scale * cfg.sharpness);
        let w_k = rng.normal_matrix(d, dk, scale);
        Self {
            w_s: w_q.matmul(&w_k.transpose()),
            w_v: rng.normal_matrix(d, d, scale),
            w_fc1: rng.normal_matrix(d, cfg.d_ff, scale),
            w_fc2: rng.normal_matrix(cfg.d_ff, d, scale),
        }
    }

    /// Load the exact weights the AOT pass emitted (artifacts/weights.json)
    /// so PJRT executions reproduce the python fixtures bit-for-bit.
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let raw = Json::parse(&text).context("parsing weights.json")?;
        Self::from_json(&raw)
    }

    /// Extract the base fields from an already-parsed weights.json (so
    /// multi-head loading parses the file once).
    pub(crate) fn from_json(raw: &Json) -> Result<Self> {
        Ok(Self {
            w_s: matrix_field(raw, "w_s")?,
            w_v: matrix_field(raw, "w_v")?,
            w_fc1: matrix_field(raw, "w_fc1")?,
            w_fc2: matrix_field(raw, "w_fc2")?,
        })
    }
}

/// One attention head's slice of the ROA contents: the folded per-head
/// score weights `w_s = w_q·w_kᵀ` (d×d) and the head's value projection
/// `w_v` (d × d_head). Heads own disjoint crossbar-tile slices (§4.5),
/// so each head's pair loads into its own slice.
#[derive(Clone, Debug)]
pub struct HeadWeights {
    pub w_s: Matrix,
    pub w_v: Matrix,
}

/// Multi-head layer weights: per-head Q/K/V projections (folded), an
/// optional output projection over the concatenated head outputs, and
/// the shared FC tail. The single-head layout ([`Weights`]) stays the
/// artifact interchange format; this is the serving-path fan-out of it.
#[derive(Clone, Debug)]
pub struct MultiHeadWeights {
    /// Head order matches the V-column blocks: head h's output lands in
    /// columns `h·d_head .. (h+1)·d_head` of the concat.
    pub heads: Vec<HeadWeights>,
    /// Output projection W_O (d×d) applied after the concat. `None` is
    /// the identity — the single-head layout has no W_O, and skipping
    /// the matmul keeps the 1-head path bit-identical to [`Weights`].
    pub w_o: Option<Matrix>,
    pub w_fc1: Matrix,
    pub w_fc2: Matrix,
}

impl MultiHeadWeights {
    pub fn heads(&self) -> usize {
        self.heads.len()
    }

    pub fn d_model(&self) -> usize {
        self.heads[0].w_s.rows()
    }

    /// True when every head carries the same folded W_S (the
    /// single-head-file fan-out): all heads then score and prune
    /// identically, and the mask/kernel paths collapse the redundant
    /// per-head work. O(heads·d²) equality probe, short-circuiting on
    /// the first differing element — negligible against the matmuls it
    /// saves, and the single definition keeps the two fast paths
    /// (mask generation, attention kernel) agreeing.
    pub fn shared_w_s(&self) -> bool {
        self.heads.len() > 1 && self.heads.iter().skip(1).all(|h| h.w_s == self.heads[0].w_s)
    }

    /// Wrap a single-head layout as a 1-head set (no W_O): the fan-out
    /// path then computes exactly what the single-head path computes.
    pub fn from_single(w: &Weights) -> Self {
        Self {
            heads: vec![HeadWeights { w_s: w.w_s.clone(), w_v: w.w_v.clone() }],
            w_o: None,
            w_fc1: w.w_fc1.clone(),
            w_fc2: w.w_fc2.clone(),
        }
    }

    /// Fan a folded single-head layout out to `heads` heads: W_V splits
    /// into column blocks; W_S replicates (the folded product cannot be
    /// re-factored into per-head Q/K). With the replicated W_S every
    /// head prunes identically, and the concat of the per-head outputs
    /// equals the single-head output in exact arithmetic.
    pub fn split(w: &Weights, heads: usize) -> Result<Self> {
        if heads == 0 {
            return Err(anyhow!("heads must be positive"));
        }
        if heads == 1 {
            return Ok(Self::from_single(w));
        }
        let d = w.w_v.cols();
        if d % heads != 0 {
            return Err(anyhow!("heads {heads} does not divide d_model {d}"));
        }
        let dh = d / heads;
        let heads_v = (0..heads)
            .map(|h| HeadWeights {
                w_s: w.w_s.clone(),
                w_v: w.w_v.col_block(h * dh, (h + 1) * dh),
            })
            .collect();
        Ok(Self { heads: heads_v, w_o: None, w_fc1: w.w_fc1.clone(), w_fc2: w.w_fc2.clone() })
    }

    /// Deterministic synthetic multi-head weights: distinct per-head
    /// Q/K (folded) and V blocks plus an output projection. `cfg.heads
    /// == 1` delegates to the single-head constructor so the two paths
    /// share weights exactly.
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Self {
        let heads = cfg.heads.max(1);
        if heads == 1 {
            return Self::from_single(&Weights::synthetic(cfg, seed));
        }
        let d = cfg.d_model;
        assert_eq!(d % heads, 0, "heads {heads} must divide d_model {d}");
        let dh = d / heads;
        let dk = cfg.d_k;
        let scale = 1.0 / (d as f32).sqrt();
        let mut rng = SeededRng::new(seed);
        let heads_v = (0..heads)
            .map(|_| {
                let w_q = rng.normal_matrix(d, dk, scale * cfg.sharpness);
                let w_k = rng.normal_matrix(d, dk, scale);
                HeadWeights {
                    w_s: w_q.matmul(&w_k.transpose()),
                    w_v: rng.normal_matrix(d, dh, scale),
                }
            })
            .collect();
        Self {
            heads: heads_v,
            w_o: Some(rng.normal_matrix(d, d, scale)),
            w_fc1: rng.normal_matrix(d, cfg.d_ff, scale),
            w_fc2: rng.normal_matrix(cfg.d_ff, d, scale),
        }
    }

    /// Load `heads` heads from a weights.json. Native multi-head files
    /// carry the per-head score weights row-stacked under `w_s_heads`
    /// (file_heads·d × d) plus an optional `w_o`, and must be loaded at
    /// exactly their stored head count — silently dropping true
    /// per-head W_S would serve a model that never existed. Single-head
    /// files (the AOT format, no `w_s_heads`) fan out to any head
    /// count via the [`MultiHeadWeights::split`] replication, which is
    /// numerically exact. Per-head W_V is always the column blocks of
    /// the stored full-width `w_v`; a stored `w_o` always applies.
    pub fn load(path: &Path, heads: usize) -> Result<Self> {
        if heads == 0 {
            return Err(anyhow!("heads must be positive"));
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let raw = Json::parse(&text).context("parsing weights.json")?;
        let base = Weights::from_json(&raw)?;
        let d = base.w_v.cols();
        if d == 0 || d % heads != 0 {
            return Err(anyhow!("heads {heads} does not divide d_model {d}"));
        }
        let stacked = match raw.opt("w_s_heads") {
            Some(v) => {
                let m = json_matrix(v).context("field w_s_heads")?;
                if m.cols() != d || m.rows() == 0 || m.rows() % d != 0 {
                    return Err(anyhow!(
                        "malformed w_s_heads: shape {:?} is not a (k*{d}, {d}) stack",
                        m.shape()
                    ));
                }
                if m.rows() != heads * d {
                    return Err(anyhow!(
                        "weights.json stores {} heads; requested {heads} \
                         (refusing to silently drop per-head W_S)",
                        m.rows() / d
                    ));
                }
                Some(m)
            }
            None => None,
        };
        let w_o = match raw.opt("w_o") {
            Some(v) => {
                let m = json_matrix(v).context("field w_o")?;
                if m.shape() != (d, d) {
                    return Err(anyhow!("w_o shape {:?} != ({d}, {d})", m.shape()));
                }
                Some(m)
            }
            None => None,
        };
        let dh = d / heads;
        let heads_v = (0..heads)
            .map(|h| HeadWeights {
                w_s: match &stacked {
                    Some(s) => s.row_block(h * d, (h + 1) * d),
                    None => base.w_s.clone(),
                },
                w_v: if heads == 1 {
                    base.w_v.clone()
                } else {
                    base.w_v.col_block(h * dh, (h + 1) * dh)
                },
            })
            .collect();
        Ok(Self { heads: heads_v, w_o, w_fc1: base.w_fc1, w_fc2: base.w_fc2 })
    }

    /// Serialize to the weights.json layout [`MultiHeadWeights::load`]
    /// reads: the base single-head fields (head 0's W_S, the concat W_V)
    /// plus, for >1 head, `w_s_heads` and `w_o`.
    pub fn to_json_string(&self) -> String {
        let d = self.d_model();
        let w_v_full = {
            let blocks: Vec<&Matrix> = self.heads.iter().map(|h| &h.w_v).collect();
            Matrix::concat_cols(&blocks)
        };
        let mut s = String::from("{\n");
        write_matrix_field(&mut s, "w_s", &self.heads[0].w_s);
        s.push_str(",\n");
        write_matrix_field(&mut s, "w_v", &w_v_full);
        s.push_str(",\n");
        write_matrix_field(&mut s, "w_fc1", &self.w_fc1);
        s.push_str(",\n");
        write_matrix_field(&mut s, "w_fc2", &self.w_fc2);
        if self.heads.len() > 1 {
            let mut stacked = Matrix::zeros(self.heads.len() * d, d);
            for (h, hw) in self.heads.iter().enumerate() {
                let dst = h * d * d;
                stacked.data_mut()[dst..dst + d * d].copy_from_slice(hw.w_s.data());
            }
            s.push_str(",\n");
            write_matrix_field(&mut s, "w_s_heads", &stacked);
        }
        if let Some(o) = &self.w_o {
            s.push_str(",\n");
            write_matrix_field(&mut s, "w_o", o);
        }
        s.push_str("\n}\n");
        s
    }

    /// Structural invariants: square per-head W_S over one d_model, V
    /// blocks concatenating back to d_model, W_O square when present,
    /// and an FC tail that composes (d → d_ff → d) — everything the
    /// encoder layer would otherwise only catch as a matmul panic.
    pub fn validate(&self) -> Result<(), String> {
        if self.heads.is_empty() {
            return Err("no heads".into());
        }
        let d = self.heads[0].w_s.rows();
        let mut dv = 0;
        for (h, hw) in self.heads.iter().enumerate() {
            if hw.w_s.shape() != (d, d) {
                return Err(format!("head {h} w_s shape {:?} != ({d}, {d})", hw.w_s.shape()));
            }
            if hw.w_v.rows() != d {
                return Err(format!("head {h} w_v rows {} != {d}", hw.w_v.rows()));
            }
            dv += hw.w_v.cols();
        }
        if dv != d {
            return Err(format!("head V blocks concat to {dv}, want d_model {d}"));
        }
        if let Some(o) = &self.w_o {
            if o.shape() != (d, d) {
                return Err(format!("w_o shape {:?} != ({d}, {d})", o.shape()));
            }
        }
        if self.w_fc1.rows() != d {
            return Err(format!("w_fc1 rows {} != d_model {d}", self.w_fc1.rows()));
        }
        if self.w_fc2.rows() != self.w_fc1.cols() {
            return Err(format!(
                "FC tail does not compose: w_fc1 is {:?}, w_fc2 is {:?}",
                self.w_fc1.shape(),
                self.w_fc2.shape()
            ));
        }
        if self.w_fc2.cols() != d {
            return Err(format!("w_fc2 cols {} != d_model {d}", self.w_fc2.cols()));
        }
        Ok(())
    }
}

/// Append `"name": {"shape": [r, c], "data": [...]}` with shortest
/// round-trip float formatting (the `{:?}` repr re-parses exactly).
fn write_matrix_field(out: &mut String, name: &str, m: &Matrix) {
    use std::fmt::Write;
    let _ = write!(out, "  \"{name}\": {{\"shape\": [{}, {}], \"data\": [", m.rows(), m.cols());
    for (i, v) in m.data().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{v:?}");
    }
    out.push_str("]}");
}

/// Parse one `{"shape": [r, c], "data": [...]}` entry.
pub(crate) fn matrix_field(obj: &Json, name: &str) -> Result<Matrix> {
    let a = obj.get(name).with_context(|| format!("weights.json missing {name}"))?;
    json_matrix(a).with_context(|| format!("field {name}"))
}

/// Convert a `{"shape": [r, c], "data": [...]}` JSON object to a Matrix.
pub(crate) fn json_matrix(a: &Json) -> Result<Matrix> {
    let shape = a.get("shape")?.as_arr()?;
    if shape.len() != 2 {
        return Err(anyhow!("not 2-D: {shape:?}"));
    }
    let rows = shape[0].as_usize()?;
    let cols = shape[1].as_usize()?;
    Ok(Matrix::from_vec(rows, cols, a.get("data")?.as_f32_vec()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shapes() {
        let cfg = ModelConfig { seq_len: 32, d_model: 64, d_k: 16, d_ff: 128, ..Default::default() };
        let w = Weights::synthetic(&cfg, 0);
        assert_eq!(w.w_s.shape(), (64, 64));
        assert_eq!(w.w_v.shape(), (64, 64));
        assert_eq!(w.w_fc1.shape(), (64, 128));
        assert_eq!(w.w_fc2.shape(), (128, 64));
    }

    #[test]
    fn synthetic_deterministic() {
        let cfg = ModelConfig::default();
        let a = Weights::synthetic(&cfg, 5);
        let b = Weights::synthetic(&cfg, 5);
        assert_eq!(a.w_s, b.w_s);
    }

    #[test]
    fn multihead_split_concat_identity() {
        // Split W_V into head blocks and concat back: exact identity.
        let cfg = ModelConfig { seq_len: 16, d_model: 32, d_k: 8, d_ff: 64, ..Default::default() };
        let w = Weights::synthetic(&cfg, 2);
        let mh = MultiHeadWeights::split(&w, 4).unwrap();
        mh.validate().unwrap();
        assert_eq!(mh.heads(), 4);
        for h in &mh.heads {
            assert_eq!(h.w_s, w.w_s, "split replicates the folded W_S");
            assert_eq!(h.w_v.shape(), (32, 8));
        }
        let blocks: Vec<&Matrix> = mh.heads.iter().map(|h| &h.w_v).collect();
        assert_eq!(Matrix::concat_cols(&blocks), w.w_v);
        assert!(MultiHeadWeights::split(&w, 5).is_err(), "5 does not divide 32");
        assert!(MultiHeadWeights::split(&w, 0).is_err());
    }

    #[test]
    fn multihead_synthetic_heads_differ() {
        let cfg = ModelConfig { seq_len: 16, d_model: 32, d_k: 8, d_ff: 64, heads: 4, ..Default::default() };
        let mh = MultiHeadWeights::synthetic(&cfg, 3);
        mh.validate().unwrap();
        assert_eq!(mh.heads(), 4);
        assert!(mh.w_o.is_some());
        assert!(mh.heads[0].w_s.max_abs_diff(&mh.heads[1].w_s) > 0.0, "heads must differ");
        // heads == 1 delegates to the single-head constructor exactly
        let one = MultiHeadWeights::synthetic(&ModelConfig { heads: 1, ..cfg }, 3);
        let single = Weights::synthetic(&ModelConfig { seq_len: 16, d_model: 32, d_k: 8, d_ff: 64, ..Default::default() }, 3);
        assert_eq!(one.heads[0].w_s, single.w_s);
        assert_eq!(one.heads[0].w_v, single.w_v);
        assert!(one.w_o.is_none());
    }

    #[test]
    fn multihead_json_roundtrip() {
        let cfg = ModelConfig { seq_len: 16, d_model: 32, d_k: 8, d_ff: 64, heads: 4, ..Default::default() };
        let mh = MultiHeadWeights::synthetic(&cfg, 7);
        let path = std::env::temp_dir().join(format!("cpsaa-mhw-{}.json", std::process::id()));
        std::fs::write(&path, mh.to_json_string()).unwrap();
        let back = MultiHeadWeights::load(&path, 4).unwrap();
        back.validate().unwrap();
        for h in 0..4 {
            assert_eq!(back.heads[h].w_s, mh.heads[h].w_s, "head {h} w_s");
            assert_eq!(back.heads[h].w_v, mh.heads[h].w_v, "head {h} w_v");
        }
        assert_eq!(back.w_o.as_ref().unwrap(), mh.w_o.as_ref().unwrap());
        assert_eq!(back.w_fc1, mh.w_fc1);
        // a native multi-head file must be fanned at its stored head
        // count — anything else would silently drop per-head W_S
        let err = MultiHeadWeights::load(&path, 2).unwrap_err();
        assert!(err.to_string().contains("stores 4 heads"), "{err}");
        assert!(MultiHeadWeights::load(&path, 1).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_head_file_fans_to_any_count_and_keeps_w_o() {
        // The AOT format (no w_s_heads) fans out by exact V-splitting;
        // a stored w_o applies at every head count.
        let cfg = ModelConfig { seq_len: 16, d_model: 32, d_k: 8, d_ff: 64, ..Default::default() };
        let single = Weights::synthetic(&cfg, 9);
        let w_o = SeededRng::new(10).normal_matrix(32, 32, 0.2);
        let mut mh = MultiHeadWeights::from_single(&single);
        mh.w_o = Some(w_o.clone());
        let path = std::env::temp_dir().join(format!("cpsaa-mhw-1h-{}.json", std::process::id()));
        std::fs::write(&path, mh.to_json_string()).unwrap();
        for heads in [1usize, 2, 4] {
            let fanned = MultiHeadWeights::load(&path, heads).unwrap();
            fanned.validate().unwrap();
            assert_eq!(fanned.heads(), heads);
            assert_eq!(fanned.heads[0].w_s, single.w_s, "replicates the base w_s");
            assert_eq!(fanned.w_o.as_ref().unwrap(), &w_o, "w_o applies at {heads} heads");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_w_s_heads_rejected() {
        // A w_s_heads block that is not a (k·d × d) stack is corruption,
        // not a head-count fallback: 24 rows at d = 16 is ragged.
        let cfg = ModelConfig { seq_len: 8, d_model: 16, d_k: 4, d_ff: 32, heads: 2, ..Default::default() };
        let mh = MultiHeadWeights::synthetic(&cfg, 1);
        let mut s = String::from("{\n");
        write_matrix_field(&mut s, "w_s", &mh.heads[0].w_s);
        s.push_str(",\n");
        let blocks: Vec<&Matrix> = mh.heads.iter().map(|h| &h.w_v).collect();
        write_matrix_field(&mut s, "w_v", &Matrix::concat_cols(&blocks));
        s.push_str(",\n");
        write_matrix_field(&mut s, "w_fc1", &mh.w_fc1);
        s.push_str(",\n");
        write_matrix_field(&mut s, "w_fc2", &mh.w_fc2);
        s.push_str(",\n");
        write_matrix_field(&mut s, "w_s_heads", &Matrix::full(24, 16, 0.5));
        s.push_str("\n}\n");
        let path = std::env::temp_dir().join(format!("cpsaa-mhw-bad-{}.json", std::process::id()));
        std::fs::write(&path, &s).unwrap();
        let err = MultiHeadWeights::load(&path, 2).unwrap_err();
        assert!(err.to_string().contains("w_s_heads"), "{err}");
        // a well-formed stack with a *different* head count is a clean
        // head-count error, not a silent fallback
        let four = MultiHeadWeights::synthetic(&ModelConfig { heads: 4, ..cfg }, 2);
        std::fs::write(&path, four.to_json_string()).unwrap();
        let err = MultiHeadWeights::load(&path, 2).unwrap_err();
        assert!(err.to_string().contains("stores 4 heads"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ws_rank_bounded_by_dk() {
        // w_s = w_q @ w_k^T has rank <= d_k: column space dimension check
        // via a cheap proxy — w_s columns are combinations of w_q columns.
        let cfg = ModelConfig { d_model: 32, d_k: 4, ..Default::default() };
        let w = Weights::synthetic(&cfg, 1);
        assert_eq!(w.w_s.shape(), (32, 32));
        // Frobenius norm of w_s must be finite and nonzero.
        assert!(w.w_s.norm() > 0.0 && w.w_s.norm().is_finite());
    }
}
