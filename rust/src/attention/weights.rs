//! Synthetic model weights mirroring `python/compile/model.py::init_weights`.
//!
//! Not bit-identical to the JAX weights (different RNG); numerical
//! cross-checks against the python side go through `artifacts/weights.json`
//! (see [`Weights::from_json_file`]). The seeded constructor exists so the
//! simulator and benches can run without artifacts.

use std::path::Path;

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::config::ModelConfig;
use crate::tensor::{Matrix, SeededRng};
use crate::util::json::Json;

/// One attention layer's weights in the CPSAA storage layout:
/// the *folded* `w_s = w_q @ w_k^T` plus `w_v` (ROA contents) and the
/// FC block (the ISAAC-style encoder tail, §4.5).
#[derive(Clone, Debug)]
pub struct Weights {
    pub w_s: Matrix,
    pub w_v: Matrix,
    pub w_fc1: Matrix,
    pub w_fc2: Matrix,
}

impl Weights {
    /// Deterministic synthetic weights (see ModelConfig::sharpness for why
    /// the attention logits are scaled).
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> Self {
        let d = cfg.d_model;
        let dk = cfg.d_k;
        let scale = 1.0 / (d as f32).sqrt();
        let mut rng = SeededRng::new(seed);
        let w_q = rng.normal_matrix(d, dk, scale * cfg.sharpness);
        let w_k = rng.normal_matrix(d, dk, scale);
        Self {
            w_s: w_q.matmul(&w_k.transpose()),
            w_v: rng.normal_matrix(d, d, scale),
            w_fc1: rng.normal_matrix(d, cfg.d_ff, scale),
            w_fc2: rng.normal_matrix(cfg.d_ff, d, scale),
        }
    }

    /// Load the exact weights the AOT pass emitted (artifacts/weights.json)
    /// so PJRT executions reproduce the python fixtures bit-for-bit.
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let raw = Json::parse(&text).context("parsing weights.json")?;
        Ok(Self {
            w_s: matrix_field(&raw, "w_s")?,
            w_v: matrix_field(&raw, "w_v")?,
            w_fc1: matrix_field(&raw, "w_fc1")?,
            w_fc2: matrix_field(&raw, "w_fc2")?,
        })
    }
}

/// Parse one `{"shape": [r, c], "data": [...]}` entry.
pub(crate) fn matrix_field(obj: &Json, name: &str) -> Result<Matrix> {
    let a = obj.get(name).with_context(|| format!("weights.json missing {name}"))?;
    json_matrix(a).with_context(|| format!("field {name}"))
}

/// Convert a `{"shape": [r, c], "data": [...]}` JSON object to a Matrix.
pub(crate) fn json_matrix(a: &Json) -> Result<Matrix> {
    let shape = a.get("shape")?.as_arr()?;
    if shape.len() != 2 {
        return Err(anyhow!("not 2-D: {shape:?}"));
    }
    let rows = shape[0].as_usize()?;
    let cols = shape[1].as_usize()?;
    Ok(Matrix::from_vec(rows, cols, a.get("data")?.as_f32_vec()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shapes() {
        let cfg = ModelConfig { seq_len: 32, d_model: 64, d_k: 16, d_ff: 128, ..Default::default() };
        let w = Weights::synthetic(&cfg, 0);
        assert_eq!(w.w_s.shape(), (64, 64));
        assert_eq!(w.w_v.shape(), (64, 64));
        assert_eq!(w.w_fc1.shape(), (64, 128));
        assert_eq!(w.w_fc2.shape(), (128, 64));
    }

    #[test]
    fn synthetic_deterministic() {
        let cfg = ModelConfig::default();
        let a = Weights::synthetic(&cfg, 5);
        let b = Weights::synthetic(&cfg, 5);
        assert_eq!(a.w_s, b.w_s);
    }

    #[test]
    fn ws_rank_bounded_by_dk() {
        // w_s = w_q @ w_k^T has rank <= d_k: column space dimension check
        // via a cheap proxy — w_s columns are combinations of w_q columns.
        let cfg = ModelConfig { d_model: 32, d_k: 4, ..Default::default() };
        let w = Weights::synthetic(&cfg, 1);
        assert_eq!(w.w_s.shape(), (32, 32));
        // Frobenius norm of w_s must be finite and nonzero.
        assert!(w.w_s.norm() > 0.0 && w.w_s.norm().is_finite());
    }
}
