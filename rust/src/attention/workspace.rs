//! Kernel workspaces: the scratch memory of the attention hot path.
//!
//! CPSAA's pipelines never spill the score matrix to memory — Steps 2–4
//! stream through on-chip buffers (§4.5). The golden model's analogue is
//! a [`KernelWorkspace`]: every large intermediate of one encoder layer
//! (the Q/V projections, the plan-ordered score values, the residual /
//! RMS-norm / FC ping-pong matrices) lives in one reusable bundle, so
//! the encoder stack stops allocating fresh `Vec`s per layer per head
//! per shard.
//!
//! ## Lifecycle and thread-safety contract
//!
//! * **Who allocates:** buffers start empty and grow on first use
//!   (`Matrix::reset` / `Vec::resize` reuse capacity after that). A pool
//!   reaches steady state after one batch: no hot-path allocation from
//!   then on.
//! * **Who resets:** the *consumer* — every kernel reshapes/zeroes the
//!   buffers it writes before reading them, so stale contents can never
//!   leak between calls. A workspace needs no cleanup between uses.
//! * **Thread safety:** a `KernelWorkspace` is exclusive (`&mut`) to one
//!   worker for the duration of one kernel. Concurrent workers (per-head
//!   / per-shard executor tasks) each check a workspace out of a
//!   shared [`WorkspacePool`] — the pool's mutex is held only for the
//!   pop/push, never across kernel work, so workers never serialize on
//!   it. The pool grows to the high-water concurrency and then recycles.

use std::sync::{Mutex, MutexGuard};

use crate::tensor::Matrix;

/// One worker's scratch bundle for the fused attention + encoder-tail
/// kernels. Field meanings are fixed by the ops layer; all buffers are
/// reshaped by their writer before use.
#[derive(Default)]
pub struct KernelWorkspace {
    /// Q-side projection `M = X·W_S` (rows × d_model).
    pub(crate) m: Matrix,
    /// Value projection `V = X·W_V` (rows × d_v).
    pub(crate) v: Matrix,
    /// Encoder-tail ping buffer (residual sums, FC2 output).
    pub(crate) t: Matrix,
    /// Encoder-tail pong buffer (RMS-norm output `h`).
    pub(crate) h: Matrix,
    /// FC1 output (rows × d_ff) — the widest tail buffer.
    pub(crate) ff: Matrix,
    /// Plan-ordered score values (the shared-scores softmax path);
    /// recycled through [`crate::sparse::CsrView::into_values`].
    pub(crate) scores: Vec<f32>,
    /// Per-row score scratch of the serial fused kernel (≤ max row nnz).
    pub(crate) row: Vec<f32>,
}

impl KernelWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A checkout pool of [`KernelWorkspace`]s shared by concurrent kernel
/// workers. `with` pops a workspace (or makes a fresh one on first use /
/// above the high-water mark), runs the closure, and returns the
/// workspace for reuse.
#[derive(Default)]
pub struct WorkspacePool {
    slots: Mutex<Vec<KernelWorkspace>>,
}

impl WorkspacePool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the slot list, recovering from poison: the lock only guards
    /// a `Vec` pop/push, so a worker that panicked while holding it
    /// cannot have left the slots inconsistent — cascading the panic
    /// into every surviving worker would turn one dead request into a
    /// dead service.
    fn slots(&self) -> MutexGuard<'_, Vec<KernelWorkspace>> {
        self.slots.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Run `f` with an exclusive workspace checked out of the pool.
    pub fn with<T>(&self, f: impl FnOnce(&mut KernelWorkspace) -> T) -> T {
        let mut ws = self.slots().pop().unwrap_or_default();
        let out = f(&mut ws);
        self.slots().push(ws);
        out
    }

    /// Workspaces currently idle in the pool (tests / introspection).
    pub fn idle(&self) -> usize {
        self.slots().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_workspaces() {
        let pool = WorkspacePool::new();
        assert_eq!(pool.idle(), 0);
        pool.with(|ws| ws.m.reset(8, 8));
        assert_eq!(pool.idle(), 1);
        // The recycled workspace keeps its grown buffers.
        pool.with(|ws| assert_eq!(ws.m.shape(), (8, 8)));
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn pool_grows_under_concurrency() {
        // A private 4-worker executor gives the concurrent checkout
        // pattern deterministically, independent of the global pool size.
        let exec = crate::runtime::executor::Executor::new(4);
        let pool = WorkspacePool::new();
        let idx: Vec<usize> = (0..4).collect();
        exec.map(&idx, |_| {
            pool.with(|ws| {
                ws.row.resize(16, 0.0);
                std::thread::sleep(std::time::Duration::from_millis(10));
            })
        });
        let idle = pool.idle();
        assert!(idle >= 1 && idle <= 4, "pool holds {idle} workspaces");
        // Steady state: serial reuse never grows the pool further.
        for _ in 0..8 {
            pool.with(|_| {});
        }
        assert_eq!(pool.idle(), idle);
    }

    #[test]
    fn pool_survives_a_poisoned_lock() {
        let pool = std::sync::Arc::new(WorkspacePool::new());
        pool.with(|ws| ws.m.reset(4, 4));
        // A worker dying while holding the slot lock poisons it...
        let p = pool.clone();
        let died = std::thread::spawn(move || {
            let _guard = p.slots.lock().unwrap();
            panic!("worker dies holding the pool lock");
        })
        .join();
        assert!(died.is_err());
        // ...but the pool keeps serving checkouts, and still recycles.
        pool.with(|ws| assert_eq!(ws.m.shape(), (4, 4)));
        assert_eq!(pool.idle(), 1);
    }
}
