//! Fused row-streaming attention kernel — Steps 2–4 (SDDMM → scale →
//! softmax → SpMM) as one pass per query row.
//!
//! CPSAA's §4.5 pipelines never spill the score matrix: a row's SDDMM
//! dots, the 1/√d_k scale, the streaming max/exp/normalize softmax, and
//! the SpMM output-row accumulation all happen while the row's scores
//! sit in a scratch that never leaves L1 — one pass per row instead of
//! four passes per matrix. Rows dispatch over the plan's nnz-balanced
//! [`DispatchPlan::partition_rows`] ranges, same as the unfused kernel.
//!
//! **Bit-identity contract:** every stage applies exactly the per-row
//! operation order of the unfused chain (`sddmm_csr` → `scale_values` →
//! `softmax_rows` → `spmm`): dots accumulate left-to-right, the scale is
//! a single elementwise multiply, softmax and the SpMM row accumulation
//! are the literal shared row kernels ([`softmax_row`],
//! [`spmm_row_into`]). Fusion therefore changes *when* values are
//! computed, never *what* — fused == unfused to the last bit at any
//! worker count (property-tested over the density × heads × shards
//! grid in `tests/properties.rs`).

use crate::attention::quant::QuantizedRows;
use crate::runtime::executor::Executor;
use crate::sparse::{softmax_row, spmm_row_into, DispatchPlan};
use crate::tensor::{simd, Matrix};

/// One coordinate's SDDMM dot product (shared with the unfused kernel):
/// the laned `tensor::simd` dot, so fused and unfused keep accumulating
/// in the one shared order.
pub(crate) fn dot(x: &[f32], y: &[f32]) -> f32 {
    simd::dot(x, y)
}

/// Split an optional plan-ordered nnz buffer into per-range slices
/// aligned with `tasks`' row ranges (cascade importance retention: each
/// task writes its own disjoint span, so contents are identical at any
/// worker count).
fn split_probs<'a>(
    plan: &DispatchPlan,
    ranges: &[std::ops::Range<usize>],
    probs: Option<&'a mut Vec<f32>>,
) -> Vec<Option<&'a mut [f32]>> {
    match probs {
        None => ranges.iter().map(|_| None).collect(),
        Some(buf) => {
            buf.clear();
            buf.resize(plan.nnz(), 0.0);
            let mut tail: &mut [f32] = buf.as_mut_slice();
            let mut offset = 0usize;
            ranges
                .iter()
                .map(|range| {
                    let hi = plan.row_ptr()[range.end] as usize;
                    let (head, rest) = std::mem::take(&mut tail).split_at_mut(hi - offset);
                    tail = rest;
                    offset = hi;
                    Some(head)
                })
                .collect()
        }
    }
}

/// Fused attention over precomputed projections: `out[i] = softmax(scale
/// · (m[i] · kvᵀ restricted to plan row i)) · v`, one streaming pass per
/// row. `out` is reshaped/zeroed in place (workspace reuse); `scratch`
/// is the serial path's per-row score buffer. Parallel pool tasks
/// allocate their own small row scratch (≤ widest row) per call — the
/// one hot-path allocation fusion does not eliminate. When `probs` is
/// set, the post-softmax rows are also retained into it in plan order
/// (the cascade-narrowing importance feed — values the kernel computed
/// anyway; `None` costs nothing).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_rows_into(
    exec: &Executor,
    m: &Matrix,
    kv: &Matrix,
    v: &Matrix,
    plan: &DispatchPlan,
    scale: f32,
    workers: usize,
    scratch: &mut Vec<f32>,
    out: &mut Matrix,
    probs: Option<&mut Vec<f32>>,
) {
    assert_eq!(m.rows(), plan.rows(), "projection rows != plan rows");
    assert_eq!(m.cols(), kv.cols(), "inner dims");
    assert_eq!(kv.rows(), plan.cols(), "key rows != plan cols");
    assert_eq!(v.rows(), plan.cols(), "value rows != plan cols");
    let d_v = v.cols();
    out.reset(plan.rows(), d_v);
    let ranges = plan.partition_rows(workers.max(1));
    if ranges.len() <= 1 {
        let probs = split_probs(plan, &[0..plan.rows()], probs).pop().unwrap_or(None);
        fuse_range(m, kv, v, plan, scale, 0..plan.rows(), scratch, out.data_mut(), probs);
        return;
    }
    // Contiguous row ranges own disjoint output slices; each pool task
    // streams its rows independently (values worker-count invariant).
    let mut prob_slices = split_probs(plan, &ranges, probs).into_iter();
    let mut tasks: Vec<(std::ops::Range<usize>, &mut [f32], Option<&mut [f32]>)> =
        Vec::with_capacity(ranges.len());
    let mut tail: &mut [f32] = out.data_mut();
    let mut offset = 0usize;
    for range in ranges {
        let (head, rest) = std::mem::take(&mut tail).split_at_mut((range.end - offset) * d_v);
        tail = rest;
        offset = range.end;
        tasks.push((range, head, prob_slices.next().unwrap_or(None)));
    }
    exec.map_consume(tasks, |(range, out_slice, p_slice)| {
        let mut scratch = Vec::new();
        fuse_range(m, kv, v, plan, scale, range, &mut scratch, out_slice, p_slice);
    });
}

/// The per-row fusion loop over one contiguous row range. `out` is the
/// range's zeroed output slice (`range.len() × v.cols()`); `probs`, when
/// present, is the range's span of the plan-ordered probability stream.
#[allow(clippy::too_many_arguments)]
fn fuse_range(
    m: &Matrix,
    kv: &Matrix,
    v: &Matrix,
    plan: &DispatchPlan,
    scale: f32,
    rows: std::ops::Range<usize>,
    scratch: &mut Vec<f32>,
    out: &mut [f32],
    mut probs: Option<&mut [f32]>,
) {
    let d_v = v.cols();
    let start = rows.start;
    let base = plan.row_ptr()[start] as usize;
    for i in rows {
        let cols = plan.row_cols(i);
        if cols.is_empty() {
            continue; // empty row: output stays zero, like the unfused SU
        }
        scratch.clear();
        scratch.resize(cols.len(), 0.0);
        let mrow = m.row(i);
        for (k, &j) in cols.iter().enumerate() {
            scratch[k] = dot(mrow, kv.row(j as usize));
        }
        simd::scale(scratch, scale);
        softmax_row(scratch);
        if let Some(p) = probs.as_deref_mut() {
            let r = plan.row_range(i);
            p[r.start - base..r.end - base].copy_from_slice(scratch);
        }
        spmm_row_into(cols, scratch, v, &mut out[(i - start) * d_v..(i - start + 1) * d_v]);
    }
}

/// The i8 twin of [`attention_rows_into`]: score-side operands arrive
/// pre-quantized ([`QuantizedRows`]: i8 codes + per-row γ), each
/// coordinate's dot accumulates in i32, and the score dequantizes at the
/// softmax boundary — `s = (Σ q_m·q_k) / (γ_m·γ_k)` — exactly where
/// SPRINT recomputes. Softmax and the SpMM over the f32 V reuse the
/// literal shared row kernels, so everything downstream of the
/// dequantized logits is the f32 path bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_rows_into_i8(
    exec: &Executor,
    qm: &QuantizedRows,
    qkv: &QuantizedRows,
    v: &Matrix,
    plan: &DispatchPlan,
    scale: f32,
    workers: usize,
    scratch: &mut Vec<f32>,
    out: &mut Matrix,
    probs: Option<&mut Vec<f32>>,
) {
    assert_eq!(qm.rows(), plan.rows(), "projection rows != plan rows");
    assert_eq!(qm.cols(), qkv.cols(), "inner dims");
    assert_eq!(qkv.rows(), plan.cols(), "key rows != plan cols");
    assert_eq!(v.rows(), plan.cols(), "value rows != plan cols");
    let d_v = v.cols();
    out.reset(plan.rows(), d_v);
    let ranges = plan.partition_rows(workers.max(1));
    if ranges.len() <= 1 {
        let probs = split_probs(plan, &[0..plan.rows()], probs).pop().unwrap_or(None);
        fuse_range_i8(qm, qkv, v, plan, scale, 0..plan.rows(), scratch, out.data_mut(), probs);
        return;
    }
    let mut prob_slices = split_probs(plan, &ranges, probs).into_iter();
    let mut tasks: Vec<(std::ops::Range<usize>, &mut [f32], Option<&mut [f32]>)> =
        Vec::with_capacity(ranges.len());
    let mut tail: &mut [f32] = out.data_mut();
    let mut offset = 0usize;
    for range in ranges {
        let (head, rest) = std::mem::take(&mut tail).split_at_mut((range.end - offset) * d_v);
        tail = rest;
        offset = range.end;
        tasks.push((range, head, prob_slices.next().unwrap_or(None)));
    }
    exec.map_consume(tasks, |(range, out_slice, p_slice)| {
        let mut scratch = Vec::new();
        fuse_range_i8(qm, qkv, v, plan, scale, range, &mut scratch, out_slice, p_slice);
    });
}

/// The per-row i8 fusion loop over one contiguous row range.
#[allow(clippy::too_many_arguments)]
fn fuse_range_i8(
    qm: &QuantizedRows,
    qkv: &QuantizedRows,
    v: &Matrix,
    plan: &DispatchPlan,
    scale: f32,
    rows: std::ops::Range<usize>,
    scratch: &mut Vec<f32>,
    out: &mut [f32],
    mut probs: Option<&mut [f32]>,
) {
    let d_v = v.cols();
    let start = rows.start;
    let base = plan.row_ptr()[start] as usize;
    for i in rows {
        let cols = plan.row_cols(i);
        if cols.is_empty() {
            continue;
        }
        scratch.clear();
        scratch.resize(cols.len(), 0.0);
        let mrow = qm.row(i);
        let gm = qm.scale(i);
        for (k, &j) in cols.iter().enumerate() {
            let j = j as usize;
            // i32-accumulated integer dot, dequantized at the softmax
            // boundary (exact f32 conversion: |dot| < 2^24).
            scratch[k] = simd::dot_i8(mrow, qkv.row(j)) as f32 / (gm * qkv.scale(j));
        }
        simd::scale(scratch, scale);
        softmax_row(scratch);
        if let Some(p) = probs.as_deref_mut() {
            let r = plan.row_range(i);
            p[r.start - base..r.end - base].copy_from_slice(scratch);
        }
        spmm_row_into(cols, scratch, v, &mut out[(i - start) * d_v..(i - start + 1) * d_v]);
    }
}

/// Fused SDDMM + scale + softmax producing plan-ordered probability
/// values — the shared-scores multi-head path (replicated W_S): P is
/// computed once here, then only the per-head V-block SpMM fans out.
/// Reuses `values` (cleared/resized; workspace recycling).
pub(crate) fn scores_softmax(
    exec: &Executor,
    m: &Matrix,
    kv: &Matrix,
    plan: &DispatchPlan,
    scale: f32,
    workers: usize,
    mut values: Vec<f32>,
) -> Vec<f32> {
    assert_eq!(m.rows(), plan.rows(), "projection rows != plan rows");
    assert_eq!(m.cols(), kv.cols(), "inner dims");
    assert_eq!(kv.rows(), plan.cols(), "key rows != plan cols");
    values.clear();
    values.resize(plan.nnz(), 0.0);
    let ranges = plan.partition_rows(workers.max(1));
    if ranges.len() <= 1 {
        score_range(m, kv, plan, scale, 0..plan.rows(), &mut values);
        return values;
    }
    let mut tasks: Vec<(std::ops::Range<usize>, &mut [f32])> = Vec::with_capacity(ranges.len());
    let mut tail: &mut [f32] = &mut values;
    let mut offset = 0usize;
    for range in ranges {
        let hi = plan.row_ptr()[range.end] as usize;
        let (head, rest) = std::mem::take(&mut tail).split_at_mut(hi - offset);
        tail = rest;
        offset = hi;
        tasks.push((range, head));
    }
    exec.map_consume(tasks, |(range, out_slice)| score_range(m, kv, plan, scale, range, out_slice));
    values
}

/// Score + scale + softmax one contiguous row range into its slice of
/// the plan-ordered value stream.
fn score_range(
    m: &Matrix,
    kv: &Matrix,
    plan: &DispatchPlan,
    scale: f32,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let base = plan.row_ptr()[rows.start] as usize;
    for i in rows {
        let r = plan.row_range(i);
        let s = &mut out[r.start - base..r.end - base];
        let mrow = m.row(i);
        for (k, &j) in plan.row_cols(i).iter().enumerate() {
            s[k] = dot(mrow, kv.row(j as usize));
        }
        simd::scale(s, scale);
        softmax_row(s);
    }
}
