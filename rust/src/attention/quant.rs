//! The paper's quantization operator Q(x) = round(γx) and its inverse,
//! plus the i8-storage row format and the serve-selectable [`Precision`]
//! knob of the quantized SDDMM path (SPRINT-style low-bitwidth score
//! compute: approximate in-memory dots, exact everything after).

use crate::tensor::Matrix;

/// Symmetric grid bound for a signed `bits`-bit quantizer (e.g. 4 → ±7).
///
/// Callers are expected to pass 2..=16 bits — [`ModelConfig::validate`]
/// [crate::config::ModelConfig::validate] rejects anything else at
/// config load — but the function is total anyway: `bits - 1` would
/// underflow at 0 and overflow the shift at ≥ 32, so out-of-range
/// widths clamp to the nearest representable grid instead of panicking.
pub fn grid_bound(bits: u32) -> f32 {
    debug_assert!((2..=16).contains(&bits), "grid_bound: {bits} bits outside 2..=16");
    let bits = bits.clamp(2, 31);
    (2u32.pow(bits - 1) - 1) as f32
}

/// Quantize one value to the γ grid. Non-finite inputs clamp instead of
/// poisoning the grid: NaN carries no magnitude and maps to 0, ±∞ clamp
/// to the grid edges.
fn quantize_value(v: f32, gamma: f32, hi: f32) -> f32 {
    if v.is_nan() {
        return 0.0;
    }
    (v * gamma).round_ties_even().clamp(-hi, hi)
}

/// Q(x): round to the γ-scaled integer grid, clipped to `bits` bits.
/// Values stay f32 — exactly the convention of the L1 kernel.
///
/// γ must be finite and positive (a zero/negative/non-finite scale has
/// no inverse grid and is rejected); non-finite *inputs* clamp — NaN to
/// 0, ±∞ to the grid edge — instead of silently producing NaN grids.
pub fn quantize(x: &Matrix, gamma: f32, bits: u32) -> Matrix {
    assert!(
        gamma.is_finite() && gamma > 0.0,
        "quantize: gamma must be finite and positive, got {gamma}"
    );
    let hi = grid_bound(bits);
    x.map(|v| quantize_value(v, gamma, hi))
}

/// Q⁻¹(x): undo the γ scaling.
pub fn dequantize(x: &Matrix, gamma: f32) -> Matrix {
    x.map(|v| v / gamma)
}

/// Q⁻¹(Q(x)) — the effective value entering the pruning matmul.
pub fn roundtrip(x: &Matrix, gamma: f32, bits: u32) -> Matrix {
    dequantize(&quantize(x, gamma, bits), gamma)
}

/// Kernel arithmetic mode, threaded from `serve --precision` through
/// `ServiceConfig` → `EncoderStack` → `Engine` down to the row kernels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 storage and accumulation (the reference path).
    #[default]
    F32,
    /// i8 storage / i32 accumulation for the SDDMM score dots,
    /// dequantized at the softmax boundary; V stays f32.
    I8,
}

impl std::str::FromStr for Precision {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f32" => Ok(Self::F32),
            "i8" => Ok(Self::I8),
            other => Err(format!("unknown precision '{other}' (expected f32 or i8)")),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::F32 => "f32",
            Self::I8 => "i8",
        })
    }
}

/// A matrix quantized row-wise to i8 storage: flat row-major codes plus
/// one γ scale per row, γᵢ = 127 / max|rowᵢ| (γ = 1 for all-zero rows, so
/// dequantization is always defined). Per-row scaling keeps the grid
/// matched to each row's dynamic range *and* makes the codes independent
/// of any row slicing — a sharded kernel quantizing its row block
/// produces exactly the rows of the unsharded quantization.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedRows {
    codes: Vec<i8>,
    scales: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl QuantizedRows {
    /// Quantize every row of `x` to the signed 8-bit grid.
    pub fn from_matrix(x: &Matrix) -> Self {
        let (rows, cols) = x.shape();
        let hi = grid_bound(8);
        let mut codes = Vec::with_capacity(rows * cols);
        let mut scales = Vec::with_capacity(rows);
        for i in 0..rows {
            let row = x.row(i);
            let mut max_abs = 0.0f32;
            for &v in row {
                if v.is_finite() {
                    max_abs = max_abs.max(v.abs());
                }
            }
            let gamma = if max_abs > 0.0 { hi / max_abs } else { 1.0 };
            scales.push(gamma);
            for &v in row {
                codes.push(quantize_value(v, gamma, hi) as i8);
            }
        }
        Self { codes, scales, rows, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i`'s i8 codes.
    pub fn row(&self, i: usize) -> &[i8] {
        &self.codes[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i`'s γ scale.
    pub fn scale(&self, i: usize) -> f32 {
        self.scales[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    #[test]
    fn grid_bounds() {
        assert_eq!(grid_bound(4), 7.0);
        assert_eq!(grid_bound(8), 127.0);
        assert_eq!(grid_bound(2), 1.0);
    }

    #[test]
    fn grid_bound_is_total_in_release() {
        // Config validation rejects these widths upstream; the grid
        // itself must still not underflow/overflow if one leaks through.
        if !cfg!(debug_assertions) {
            assert_eq!(grid_bound(0), 1.0);
            assert_eq!(grid_bound(1), 1.0);
            assert!(grid_bound(40).is_finite());
        }
    }

    #[test]
    fn values_are_clipped_integers() {
        let x = SeededRng::new(0).normal_matrix(32, 32, 10.0);
        let q = quantize(&x, 4.0, 4);
        for &v in q.data() {
            assert_eq!(v, v.round());
            assert!((-7.0..=7.0).contains(&v));
        }
    }

    #[test]
    fn roundtrip_error_bounded_in_range() {
        let x = SeededRng::new(1).normal_matrix(32, 32, 0.1); // well inside range
        let r = roundtrip(&x, 8.0, 4);
        assert!(x.max_abs_diff(&r) <= 0.5 / 8.0 + 1e-6);
    }

    #[test]
    fn zero_preserved() {
        let z = Matrix::zeros(8, 8);
        assert_eq!(quantize(&z, 4.0, 4), z);
    }

    #[test]
    fn idempotent_on_grid() {
        let x = SeededRng::new(2).normal_matrix(16, 16, 1.0);
        let q1 = quantize(&x, 4.0, 4);
        let q2 = quantize(&dequantize(&q1, 4.0), 4.0, 4);
        assert_eq!(q1, q2);
    }

    #[test]
    fn non_finite_inputs_clamp_not_nan() {
        let x = Matrix::from_vec(
            1,
            4,
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.25],
        );
        let q = quantize(&x, 4.0, 4);
        assert_eq!(q.data(), &[0.0, 7.0, -7.0, 1.0]);
        assert!(q.all_finite(), "no NaN may survive quantization");
    }

    #[test]
    #[should_panic(expected = "gamma must be finite and positive")]
    fn zero_gamma_rejected() {
        quantize(&Matrix::zeros(2, 2), 0.0, 8);
    }

    #[test]
    #[should_panic(expected = "gamma must be finite and positive")]
    fn negative_gamma_rejected() {
        quantize(&Matrix::zeros(2, 2), -3.0, 8);
    }

    #[test]
    #[should_panic(expected = "gamma must be finite and positive")]
    fn non_finite_gamma_rejected() {
        quantize(&Matrix::zeros(2, 2), f32::NAN, 8);
    }

    #[test]
    fn precision_parses_and_displays() {
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!("i8".parse::<Precision>().unwrap(), Precision::I8);
        assert!("fp16".parse::<Precision>().is_err());
        assert_eq!(Precision::F32.to_string(), "f32");
        assert_eq!(Precision::I8.to_string(), "i8");
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn quantized_rows_roundtrip_error_per_row() {
        let x = SeededRng::new(3).normal_matrix(12, 24, 1.5);
        let q = QuantizedRows::from_matrix(&x);
        assert_eq!((q.rows(), q.cols()), (12, 24));
        for i in 0..12 {
            let g = q.scale(i);
            assert!(g.is_finite() && g > 0.0);
            for (&code, &v) in q.row(i).iter().zip(x.row(i)) {
                // dequantized code within half a grid step of the value
                assert!(
                    (f32::from(code) / g - v).abs() <= 0.5 / g + 1e-6,
                    "row {i}: code {code} vs {v} (gamma {g})"
                );
            }
        }
    }

    #[test]
    fn quantized_rows_zero_row_has_unit_scale() {
        let mut x = SeededRng::new(4).normal_matrix(4, 8, 1.0);
        for v in x.row_mut(2) {
            *v = 0.0;
        }
        let q = QuantizedRows::from_matrix(&x);
        assert_eq!(q.scale(2), 1.0);
        assert!(q.row(2).iter().all(|&c| c == 0));
    }

    #[test]
    fn quantized_rows_slice_invariant() {
        // Per-row γ ⇒ quantizing a row block reproduces the block of the
        // full quantization (the sharding-invariance the i8 kernel
        // relies on).
        let x = SeededRng::new(5).normal_matrix(10, 16, 1.0);
        let full = QuantizedRows::from_matrix(&x);
        let block = QuantizedRows::from_matrix(&x.row_block(3, 7));
        for i in 0..4 {
            assert_eq!(block.row(i), full.row(3 + i));
            assert_eq!(block.scale(i), full.scale(3 + i));
        }
    }
}
