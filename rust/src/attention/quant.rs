//! The paper's quantization operator Q(x) = round(γx) and its inverse.

use crate::tensor::Matrix;

/// Symmetric grid bound for a signed `bits`-bit quantizer (e.g. 4 → ±7).
pub fn grid_bound(bits: u32) -> f32 {
    (2u32.pow(bits - 1) - 1) as f32
}

/// Q(x): round to the γ-scaled integer grid, clipped to `bits` bits.
/// Values stay f32 — exactly the convention of the L1 kernel.
pub fn quantize(x: &Matrix, gamma: f32, bits: u32) -> Matrix {
    let hi = grid_bound(bits);
    x.map(|v| (v * gamma).round_ties_even().clamp(-hi, hi))
}

/// Q⁻¹(x): undo the γ scaling.
pub fn dequantize(x: &Matrix, gamma: f32) -> Matrix {
    x.map(|v| v / gamma)
}

/// Q⁻¹(Q(x)) — the effective value entering the pruning matmul.
pub fn roundtrip(x: &Matrix, gamma: f32, bits: u32) -> Matrix {
    dequantize(&quantize(x, gamma, bits), gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    #[test]
    fn grid_bounds() {
        assert_eq!(grid_bound(4), 7.0);
        assert_eq!(grid_bound(8), 127.0);
        assert_eq!(grid_bound(2), 1.0);
    }

    #[test]
    fn values_are_clipped_integers() {
        let x = SeededRng::new(0).normal_matrix(32, 32, 10.0);
        let q = quantize(&x, 4.0, 4);
        for &v in q.data() {
            assert_eq!(v, v.round());
            assert!((-7.0..=7.0).contains(&v));
        }
    }

    #[test]
    fn roundtrip_error_bounded_in_range() {
        let x = SeededRng::new(1).normal_matrix(32, 32, 0.1); // well inside range
        let r = roundtrip(&x, 8.0, 4);
        assert!(x.max_abs_diff(&r) <= 0.5 / 8.0 + 1e-6);
    }

    #[test]
    fn zero_preserved() {
        let z = Matrix::zeros(8, 8);
        assert_eq!(quantize(&z, 4.0, 4), z);
    }

    #[test]
    fn idempotent_on_grid() {
        let x = SeededRng::new(2).normal_matrix(16, 16, 1.0);
        let q1 = quantize(&x, 4.0, 4);
        let q2 = quantize(&dequantize(&q1, 4.0), 4.0, 4);
        assert_eq!(q1, q2);
    }
}
