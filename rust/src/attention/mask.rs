//! Pruning-phase mask generation — Step 1 of the dataflow (eq. 4).

use crate::config::ModelConfig;
use crate::runtime::executor::{self, Executor};
use crate::sparse::MaskMatrix;
use crate::tensor::Matrix;

use super::quant;
use super::softmax;
use super::weights::MultiHeadWeights;

/// mask = Bina(Soft(Q⁻¹(Q(X)·Q(W_S)·Q(Xᵀ)) / √d)) — the PIM pruning
/// algorithm. Uses only `X` and the pre-quantized `W_S`, never `Q`/`K`:
/// that independence is what lets Step 1 run concurrently with Step 2.
pub fn generate(x: &Matrix, w_s: &Matrix, cfg: &ModelConfig) -> MaskMatrix {
    let g = cfg.gamma;
    let qx = quant::quantize(x, g, cfg.quant_bits);
    let qws = quant::quantize(w_s, g, cfg.quant_bits);
    let qxt = qx.transpose();
    // Three quantized factors ⇒ de-quantization divides by γ³.
    let s_hat = qx.matmul(&qws).matmul(&qxt).scale(1.0 / (g * g * g));
    let s_hat = s_hat.scale(1.0 / (cfg.d_k as f32).sqrt());
    let p = softmax::softmax(&s_hat);
    binarize(&p, cfg.theta)
}

/// Per-head Step 1: one pruning mask per head from the head's folded
/// `w_s`. Head prunes are independent (each head's ReCAM slice searches
/// its own mask, §4.5), so they run concurrently — one pool task per
/// head on the global executor, head order preserved.
pub fn generate_heads(x: &Matrix, w: &MultiHeadWeights, cfg: &ModelConfig) -> Vec<MaskMatrix> {
    generate_heads_in(&executor::global(), x, w, cfg)
}

/// [`generate_heads`] on a caller-owned [`Executor`] — the engine's
/// injectable dispatch path.
pub fn generate_heads_in(
    exec: &Executor,
    x: &Matrix,
    w: &MultiHeadWeights,
    cfg: &ModelConfig,
) -> Vec<MaskMatrix> {
    // Replicated-W_S fan-out (a single-head weights file served with
    // heads > 1) prunes identically per head: one quantized matmul
    // chain instead of `heads`.
    if w.shared_w_s() {
        return vec![generate(x, &w.heads[0].w_s, cfg); w.heads.len()];
    }
    exec.map(&w.heads, |h| generate(x, &h.w_s, cfg))
}

/// Eq. 1: G[i,j] = 1 iff S̃[i,j] ≥ θ — the Binarization Unit.
pub fn binarize(p: &Matrix, theta: f32) -> MaskMatrix {
    let mut mask = MaskMatrix::zeros(p.rows(), p.cols());
    for i in 0..p.rows() {
        for j in 0..p.cols() {
            if p.get(i, j) >= theta {
                mask.set(i, j, true);
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Weights;
    use crate::tensor::SeededRng;

    fn setup() -> (Matrix, Weights, ModelConfig) {
        let cfg = ModelConfig { seq_len: 64, d_model: 64, ..Default::default() };
        let w = Weights::synthetic(&cfg, 0);
        let x = SeededRng::new(9).normal_matrix(cfg.seq_len, cfg.d_model, 1.0);
        (x, w, cfg)
    }

    #[test]
    fn mask_shape_and_binary() {
        let (x, w, cfg) = setup();
        let mask = generate(&x, &w.w_s, &cfg);
        assert_eq!((mask.rows(), mask.cols()), (64, 64));
    }

    #[test]
    fn density_in_sparse_regime() {
        // Paper evaluation regime: ~0.1 density. Synthetic weights with the
        // default sharpness land near it.
        let (x, w, cfg) = setup();
        let d = generate(&x, &w.w_s, &cfg).density();
        assert!(d > 0.01 && d < 0.6, "density {d}");
    }

    #[test]
    fn theta_monotone() {
        // Larger theta ⇒ sparser mask (binarization threshold, eq. 1).
        let (x, w, cfg) = setup();
        let loose = generate(&x, &w.w_s, &ModelConfig { theta: 0.005, ..cfg.clone() });
        let tight = generate(&x, &w.w_s, &ModelConfig { theta: 0.05, ..cfg });
        assert!(tight.nnz() <= loose.nnz());
        // And tight ⊆ loose:
        for i in 0..tight.rows() {
            for j in 0..tight.cols() {
                if tight.get(i, j) {
                    assert!(loose.get(i, j));
                }
            }
        }
    }

    #[test]
    fn every_row_keeps_something_at_tiny_theta() {
        // theta below 1/seq_len keeps at least the argmax of every row
        // (softmax rows sum to 1 over seq_len entries).
        let (x, w, cfg) = setup();
        let mask = generate(&x, &w.w_s, &ModelConfig { theta: 1.0 / 64.0 / 2.0, ..cfg });
        for i in 0..mask.rows() {
            assert!(mask.row_nnz(i) >= 1, "row {i} empty");
        }
    }

    #[test]
    fn head_masks_match_per_head_generation() {
        use crate::attention::weights::MultiHeadWeights;
        let cfg = ModelConfig { seq_len: 32, d_model: 64, d_k: 8, d_ff: 128, heads: 4, ..Default::default() };
        let w = MultiHeadWeights::synthetic(&cfg, 5);
        let x = SeededRng::new(6).normal_matrix(32, 64, 1.0);
        let masks = generate_heads(&x, &w, &cfg);
        assert_eq!(masks.len(), 4);
        for (h, m) in masks.iter().enumerate() {
            assert_eq!(m, &generate(&x, &w.heads[h].w_s, &cfg), "head {h} mask diverged");
        }
        // distinct per-head weights ⇒ masks genuinely differ
        assert_ne!(masks[0], masks[1]);
        // replicated-W_S fan-out (single-head file split N ways) takes
        // the shared fast path and must equal per-head generation
        let single = Weights::synthetic(&cfg, 5);
        let split = MultiHeadWeights::split(&single, 4).unwrap();
        let shared = generate_heads(&x, &split, &cfg);
        assert_eq!(shared.len(), 4);
        for m in &shared {
            assert_eq!(m, &generate(&x, &single.w_s, &cfg));
        }
    }

    #[test]
    fn binarize_threshold_inclusive() {
        let p = Matrix::from_vec(1, 3, vec![0.1, 0.2, 0.3]);
        let m = binarize(&p, 0.2);
        assert!(!m.get(0, 0) && m.get(0, 1) && m.get(0, 2));
    }
}
