//! Attention calculation phase — Steps 2–4 (eq. 3) plus reference modes.
//!
//! All sparse kernels run off a [`DispatchPlan`]: the mask is scanned
//! once (by the caller, or implicitly by the compatibility wrappers) and
//! the ⟨α, βᵢ⟩ topology drives every dot product, exactly as the ReCAM
//! coordinate stream drives the crossbar SDDMM engine.
//!
//! The hot path is **fused** ([`super::fused`]): SDDMM → scale → softmax
//! → SpMM stream through one pass per query row, bit-identical to the
//! unfused four-pass chain that [`cpsaa_attention_unfused`] keeps as the
//! golden reference. Large intermediates come from a
//! [`KernelWorkspace`]; concurrent head/shard workers check workspaces
//! out of a shared [`WorkspacePool`] so the encoder stack stops
//! allocating per layer per head per shard.

use crate::config::ModelConfig;
use crate::runtime::executor::{self, Executor};
use crate::sparse::{CsrMatrix, CsrView, DispatchPlan, LayerImportance, MaskMatrix, PlanSet};
use crate::tensor::{simd, Matrix};

use super::fused::{self, dot};
use super::quant::{Precision, QuantizedRows};
use super::softmax;
use super::weights::MultiHeadWeights;
use super::workspace::{KernelWorkspace, WorkspacePool};

/// Plan-driven SDDMM straight into CSR: `S = plan ⊙ (A · B)` where `bt`
/// is B **already transposed** (row j of `bt` = column j of B). Values
/// land in plan order — no dense S round-trip. Row ranges are dispatched
/// onto the global [`Executor`] pool, balanced by nnz. (The unfused
/// building block; the fused hot path never materializes S at all.)
pub fn sddmm_csr(a: &Matrix, bt: &Matrix, plan: &DispatchPlan) -> CsrMatrix {
    let exec = executor::global();
    let workers = exec.workers_for(plan.nnz());
    sddmm_csr_in(&exec, a, bt, plan, workers)
}

/// [`sddmm_csr`] on an explicit executor with an explicit worker count.
/// The worker count never changes the values (every coordinate's dot
/// product is independent), only the dispatch.
fn sddmm_csr_in(
    exec: &Executor,
    a: &Matrix,
    bt: &Matrix,
    plan: &DispatchPlan,
    workers: usize,
) -> CsrMatrix {
    assert_eq!(a.cols(), bt.cols(), "inner dims");
    assert_eq!((plan.rows(), plan.cols()), (a.rows(), bt.rows()), "plan shape");
    let mut values = vec![0.0f32; plan.nnz()];
    let ranges = plan.partition_rows(workers.max(1));
    if ranges.len() <= 1 {
        for i in 0..plan.rows() {
            let arow = a.row(i);
            let base = plan.row_ptr()[i] as usize;
            for (k, &j) in plan.row_cols(i).iter().enumerate() {
                values[base + k] = dot(arow, bt.row(j as usize));
            }
        }
        return CsrMatrix::from_plan_values(plan, values);
    }
    // Contiguous row ranges own disjoint value slices; each pool task
    // fills its own (values worker-count invariant).
    let mut tasks: Vec<(std::ops::Range<usize>, &mut [f32])> = Vec::with_capacity(ranges.len());
    let mut tail: &mut [f32] = &mut values;
    let mut offset = 0usize;
    for range in ranges {
        let hi = plan.row_ptr()[range.end] as usize;
        let (head, rest) = std::mem::take(&mut tail).split_at_mut(hi - offset);
        tail = rest;
        offset = hi;
        tasks.push((range, head));
    }
    exec.map_consume(tasks, |(range, out)| {
        let base = plan.row_ptr()[range.start] as usize;
        for i in range {
            let arow = a.row(i);
            let lo = plan.row_ptr()[i] as usize;
            for (k, &j) in plan.row_cols(i).iter().enumerate() {
                out[lo + k - base] = dot(arow, bt.row(j as usize));
            }
        }
    });
    CsrMatrix::from_plan_values(plan, values)
}

/// The i8-storage / i32-accumulate twin of [`sddmm_csr`]: both operands
/// quantize row-wise to i8 ([`QuantizedRows`]), every masked coordinate
/// accumulates an integer dot, and each score dequantizes once —
/// `(Σ qₐ·q_b) / (γₐᵢ·γ_bⱼ)` — as it lands in the f32 value stream.
pub fn sddmm_csr_i8(a: &Matrix, bt: &Matrix, plan: &DispatchPlan) -> CsrMatrix {
    sddmm_csr_i8_quantized(&QuantizedRows::from_matrix(a), &QuantizedRows::from_matrix(bt), plan)
}

/// [`sddmm_csr_i8`] over pre-quantized operands — the form the bench
/// rung times, so the measurement is exactly the integer dispatch over
/// i8 storage (quantization itself happens once per batch, outside).
pub fn sddmm_csr_i8_quantized(
    qa: &QuantizedRows,
    qbt: &QuantizedRows,
    plan: &DispatchPlan,
) -> CsrMatrix {
    assert_eq!(qa.cols(), qbt.cols(), "inner dims");
    assert_eq!((plan.rows(), plan.cols()), (qa.rows(), qbt.rows()), "plan shape");
    let exec = executor::global();
    let workers = exec.workers_for(plan.nnz());
    let mut values = vec![0.0f32; plan.nnz()];
    let fill_rows = |range: std::ops::Range<usize>, out: &mut [f32], base: usize| {
        for i in range {
            let arow = qa.row(i);
            let ga = qa.scale(i);
            let lo = plan.row_ptr()[i] as usize;
            for (k, &j) in plan.row_cols(i).iter().enumerate() {
                let j = j as usize;
                out[lo + k - base] =
                    simd::dot_i8(arow, qbt.row(j)) as f32 / (ga * qbt.scale(j));
            }
        }
    };
    let ranges = plan.partition_rows(workers.max(1));
    if ranges.len() <= 1 {
        fill_rows(0..plan.rows(), &mut values, 0);
        return CsrMatrix::from_plan_values(plan, values);
    }
    let mut tasks: Vec<(std::ops::Range<usize>, &mut [f32])> = Vec::with_capacity(ranges.len());
    let mut tail: &mut [f32] = &mut values;
    let mut offset = 0usize;
    for range in ranges {
        let hi = plan.row_ptr()[range.end] as usize;
        let (head, rest) = std::mem::take(&mut tail).split_at_mut(hi - offset);
        tail = rest;
        offset = hi;
        tasks.push((range, head));
    }
    exec.map_consume(tasks, |(range, out)| {
        let base = plan.row_ptr()[range.start] as usize;
        fill_rows(range, out, base);
    });
    CsrMatrix::from_plan_values(plan, values)
}

/// Masked SDDMM: `mask ⊙ (a @ b)` as a dense matrix — the reference-mode
/// wrapper over [`sddmm_csr`] (builds a throwaway plan; hot paths use
/// the fused kernel with a shared plan).
pub fn masked_sddmm(a: &Matrix, b: &Matrix, mask: &MaskMatrix) -> Matrix {
    assert_eq!(a.cols(), b.rows());
    assert_eq!((mask.rows(), mask.cols()), (a.rows(), b.cols()));
    sddmm_csr(a, &b.transpose(), &mask.plan()).to_dense()
}

/// CPSAA attention (Steps 2–4): M = X·W_S, V = X·W_V,
/// S = mask ⊙ (M·Xᵀ)/√d_k, P = masked softmax, Z = P·V.
/// Scans the mask once; callers holding a plan (the coordinator batch
/// path) should use [`cpsaa_attention_planned`] to skip even that.
pub fn cpsaa_attention(x: &Matrix, w_s: &Matrix, w_v: &Matrix, mask: &MaskMatrix, cfg: &ModelConfig) -> Matrix {
    cpsaa_attention_planned(x, w_s, w_v, &mask.plan(), cfg)
}

/// [`cpsaa_attention`] over a prebuilt [`DispatchPlan`] — the plan-reuse
/// hot path, running the fused row-streaming kernel.
pub fn cpsaa_attention_planned(
    x: &Matrix,
    w_s: &Matrix,
    w_v: &Matrix,
    plan: &DispatchPlan,
    cfg: &ModelConfig,
) -> Matrix {
    cpsaa_attention_planned_ws(x, w_s, w_v, plan, cfg, &mut KernelWorkspace::new())
}

/// [`cpsaa_attention_planned`] drawing every large intermediate from a
/// caller-owned [`KernelWorkspace`] — beyond the returned output, the
/// only hot-path allocation left is the parallel dispatch's per-task
/// row scratch (see [`super::fused::attention_rows_into`]).
pub fn cpsaa_attention_planned_ws(
    x: &Matrix,
    w_s: &Matrix,
    w_v: &Matrix,
    plan: &DispatchPlan,
    cfg: &ModelConfig,
    ws: &mut KernelWorkspace,
) -> Matrix {
    cpsaa_attention_rows_fused(
        &executor::global(),
        x,
        x,
        w_s,
        w_v,
        plan,
        cfg,
        1,
        Precision::F32,
        ws,
        None,
    )
}

/// The unfused four-pass reference chain (SDDMM → scale → softmax →
/// SpMM as separate whole-matrix passes over an owned CSR). Kept as the
/// golden reference the fused kernel is property-tested against
/// bit-for-bit, and as the `unfused` hotpath bench rung.
pub fn cpsaa_attention_unfused(
    x: &Matrix,
    w_s: &Matrix,
    w_v: &Matrix,
    plan: &DispatchPlan,
    cfg: &ModelConfig,
) -> Matrix {
    let m = x.matmul(w_s);
    let v = x.matmul(w_v);
    let exec = executor::global();
    let workers = exec.workers_for(plan.nnz());
    // S = M·Xᵀ: B = Xᵀ, so Bᵀ = X — no transpose materialized.
    let mut p = sddmm_csr_in(&exec, &m, x, plan, workers);
    p.scale_values(1.0 / (cfg.d_k as f32).sqrt());
    p.softmax_rows();
    p.spmm(&v)
}

/// One head's fused attention for a Q-row block: `q_rows` is a
/// contiguous row slice of the packed batch, `kv` the full batch
/// (scores and values attend over every key row), and `plan` the head
/// plan sliced to the same rows. The SDDMM worker budget divides by
/// `budget_share` (sibling head × shard kernels sharing the machine);
/// the worker count never changes the computed values. Every op touches
/// only its own row, so with `q_rows == kv` this computes bit-for-bit
/// what the full-range kernel computes, and over a partition of the
/// rows the concatenated blocks are bit-identical to the unsharded
/// output.
///
/// At [`Precision::I8`] the score-side operands (M and the keys)
/// quantize row-wise to i8 after the projections and the integer fused
/// kernel runs instead; per-row γ makes the quantization row-slice
/// invariant, so the sharded i8 output is still bit-identical to the
/// unsharded i8 output.
#[allow(clippy::too_many_arguments)]
fn cpsaa_attention_rows_fused(
    exec: &Executor,
    q_rows: &Matrix,
    kv: &Matrix,
    w_s: &Matrix,
    w_v: &Matrix,
    plan: &DispatchPlan,
    cfg: &ModelConfig,
    budget_share: usize,
    precision: Precision,
    ws: &mut KernelWorkspace,
    probs: Option<&mut Vec<f32>>,
) -> Matrix {
    let KernelWorkspace { m, v, row, .. } = ws;
    q_rows.matmul_into(w_s, m);
    kv.matmul_into(w_v, v);
    let workers = (exec.workers_for(plan.nnz()) / budget_share.max(1)).max(1);
    let scale = 1.0 / (cfg.d_k as f32).sqrt();
    let mut out = Matrix::default();
    match precision {
        Precision::F32 => {
            fused::attention_rows_into(exec, m, kv, v, plan, scale, workers, row, &mut out, probs);
        }
        Precision::I8 => {
            let qm = QuantizedRows::from_matrix(m);
            let qkv = QuantizedRows::from_matrix(kv);
            fused::attention_rows_into_i8(
                exec, &qm, &qkv, v, plan, scale, workers, row, &mut out, probs,
            );
        }
    }
    out
}

/// Multi-head CPSAA attention over a prebuilt [`PlanSet`] — one plan
/// per head, heads executed concurrently on disjoint tile slices (one
/// pool task per head on the shared [`Executor`]; each head's fused
/// kernel keeps its own nnz-balanced `partition_rows` dispatch, and the
/// nested fan-out flattens into the one pool).
/// The per-head outputs concatenate column-wise in head order, then the
/// optional output projection W_O applies. With one head and no W_O
/// this computes bit-for-bit what [`cpsaa_attention_planned`] computes.
pub fn multi_head_attention_planned(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
) -> Matrix {
    multi_head_attention_planned_ws(x, w, plans, cfg, &WorkspacePool::new(), &executor::global())
}

/// [`multi_head_attention_planned`] with worker workspaces drawn from a
/// caller-owned [`WorkspacePool`] and dispatch on a caller-owned
/// [`Executor`] (the engine's long-lived pair).
pub fn multi_head_attention_planned_ws(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
) -> Matrix {
    // The single-shard instance of the shard kernel: Q rows = all rows,
    // full worker budget. One definition keeps the sharded/unsharded
    // bit-equivalence structural rather than maintained by hand.
    multi_head_attention_shard(exec, x, x, w, plans, cfg, 1, Precision::F32, pool, false).0
}

/// [`multi_head_attention_planned`] at an explicit [`Precision`] — the
/// serve-selectable arithmetic mode (`--precision i8`), and the entry
/// the i8-vs-f32 error-bound property test drives.
pub fn multi_head_attention_planned_prec(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
    precision: Precision,
) -> Matrix {
    multi_head_attention_shard(
        &executor::global(),
        x,
        x,
        w,
        plans,
        cfg,
        1,
        precision,
        &WorkspacePool::new(),
        false,
    )
    .0
}

/// One encoder layer with multi-head fan-out: the multi-head attention
/// over the plan set, then the same residual + RMS-norm + FC tail as
/// [`encoder_layer_planned`].
pub fn encoder_layer_heads(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
) -> Matrix {
    encoder_layer_heads_ws(x, w, plans, cfg, &WorkspacePool::new(), &executor::global())
}

/// [`encoder_layer_heads`] over a caller-owned [`WorkspacePool`] and
/// [`Executor`] — the encoder stack passes one pool across all layers,
/// so layer N reuses layer N−1's buffers.
pub fn encoder_layer_heads_ws(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
) -> Matrix {
    encoder_layer_heads_ws_prec(x, w, plans, cfg, pool, exec, Precision::F32)
}

/// [`encoder_layer_heads_ws`] at an explicit [`Precision`] — the engine's
/// entry once `serve --precision` has been threaded down to it. Only the
/// attention score dots change mode; the residual/norm/FC tail is always
/// f32.
pub fn encoder_layer_heads_ws_prec(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
    precision: Precision,
) -> Matrix {
    let z = multi_head_attention_shard(exec, x, x, w, plans, cfg, 1, precision, pool, false).0;
    pool.with(|ws| encoder_tail(x, &z, &w.w_fc1, &w.w_fc2, ws))
}

/// [`encoder_layer_heads_ws_prec`] that additionally reduces the layer's
/// retained softmax probabilities into a [`LayerImportance`] — the
/// cascade-narrowing feed (§dynamic sparsity). The hidden output is
/// bit-identical to the plain entry: retention copies values the fused
/// kernel already computed, it never changes them. The importance
/// reduction is serial and head-major, so it is worker-count invariant.
#[allow(clippy::too_many_arguments)]
pub fn encoder_layer_heads_importance(
    x: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
    precision: Precision,
) -> (Matrix, LayerImportance) {
    let (z, probs) =
        multi_head_attention_shard(exec, x, x, w, plans, cfg, 1, precision, pool, true);
    let probs = probs.expect("probs requested");
    let mut imp = LayerImportance::new(x.rows(), plans.heads());
    for (h, stream) in probs.iter().enumerate() {
        imp.add_rows(h, plans.plan(h), stream);
    }
    let out = pool.with(|ws| encoder_tail(x, &z, &w.w_fc1, &w.w_fc2, ws));
    (out, imp)
}

/// One shard's multi-head attention: Q rows `x_rows` (a contiguous row
/// slice of the packed batch `x`, or `x` itself for the full range)
/// against the full keys/values, over the matching (sliced) plan set.
/// Heads run one pool task each on the shared executor, drawing
/// workspaces from `pool`; the replicated-W_S fan-out (a single-head
/// weights file split N ways) scores, prunes, and softmaxes identically
/// per head, so the shared P is computed once (one fused
/// SDDMM+scale+softmax row pass into a zero-copy [`CsrView`]) and only
/// the per-head V-block SpMM fans out — bit-identical to running the
/// heads independently. Every row-wise op touches only the shard's
/// rows, so the assembled shard blocks are bit-identical to the
/// full-range kernel.
///
/// With `want_probs` the per-head plan-ordered softmax probability
/// streams are retained alongside the output (the cascade-narrowing
/// importance feed); retention copies values the kernel already
/// computed, so the hidden output is bit-identical either way.
#[allow(clippy::too_many_arguments)]
fn multi_head_attention_shard(
    exec: &Executor,
    x: &Matrix,
    x_rows: &Matrix,
    w: &MultiHeadWeights,
    plans: &PlanSet,
    cfg: &ModelConfig,
    concurrent_shards: usize,
    precision: Precision,
    pool: &WorkspacePool,
    want_probs: bool,
) -> (Matrix, Option<Vec<Vec<f32>>>) {
    assert_eq!(w.heads.len(), plans.heads(), "one plan per head");
    let heads = w.heads.len();
    // The shared-scores fast path is f32-only; at i8 every head runs the
    // quantized fused kernel so the precision mode is uniform end to end.
    let shared_scores = precision == Precision::F32
        && w.shared_w_s()
        && plans.plans().iter().skip(1).all(|p| p == plans.plan(0));
    let (zs, probs): (Vec<Matrix>, Option<Vec<Vec<f32>>>) = if shared_scores {
        let plan0 = plans.plan(0);
        let workers = (exec.workers_for(plan0.nnz()) / concurrent_shards.max(1)).max(1);
        let scale = 1.0 / (cfg.d_k as f32).sqrt();
        pool.with(|ws| {
            x_rows.matmul_into(&w.heads[0].w_s, &mut ws.m);
            let values = fused::scores_softmax(
                exec,
                &ws.m,
                x,
                plan0,
                scale,
                workers,
                std::mem::take(&mut ws.scores),
            );
            let p = CsrView::new(plan0, values);
            let zs = exec.map(&w.heads, |h| {
                pool.with(|hws| {
                    x.matmul_into(&h.w_v, &mut hws.v);
                    p.spmm(&hws.v)
                })
            });
            let values = p.into_values();
            // Every head shares the one probability stream.
            let probs = want_probs.then(|| vec![values.clone(); heads]);
            ws.scores = values;
            (zs, probs)
        })
    } else {
        let pairs: Vec<(&super::weights::HeadWeights, &DispatchPlan)> =
            w.heads.iter().zip(plans.plans()).collect();
        let results = exec.map(&pairs, |&(h, p)| {
            if p.nnz() == 0 {
                // A fully-pruned head contributes exactly the zero
                // block (no coordinates ⇒ no softmax mass ⇒ zero SpMM
                // rows), so skip its projections and row pass outright:
                // cascade head pruning sheds the head's dense work, not
                // just its coordinates. Bit-identical to running the
                // kernel over the empty plan.
                return (Matrix::zeros(x_rows.rows(), h.w_v.cols()), want_probs.then(Vec::new));
            }
            pool.with(|ws| {
                let mut buf = want_probs.then(Vec::new);
                let z = cpsaa_attention_rows_fused(
                    exec,
                    x_rows,
                    x,
                    &h.w_s,
                    &h.w_v,
                    p,
                    cfg,
                    heads * concurrent_shards.max(1),
                    precision,
                    ws,
                    buf.as_mut(),
                );
                (z, buf)
            })
        });
        let mut zs = Vec::with_capacity(results.len());
        let mut probs = want_probs.then(|| Vec::with_capacity(results.len()));
        for (z, buf) in results {
            zs.push(z);
            if let Some(ps) = probs.as_mut() {
                ps.push(buf.expect("probs requested"));
            }
        }
        (zs, probs)
    };
    let blocks: Vec<&Matrix> = zs.iter().collect();
    let z = Matrix::concat_cols(&blocks);
    let out = match &w.w_o {
        Some(o) => z.matmul(o),
        None => z,
    };
    (out, probs)
}

/// Batch-parallel multi-head attention over a sharded plan set: shard
/// `s` computes output rows `shards.range(s)` against the full keys (K
/// logical chips, one pool task per shard), and the blocks assemble
/// back in row order. Row-separability of every op makes the result
/// bit-identical to [`multi_head_attention_planned`] over the unsliced
/// set, at any shard count.
pub fn multi_head_attention_sharded(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
) -> Matrix {
    multi_head_attention_sharded_ws(x, w, shards, cfg, &WorkspacePool::new(), &executor::global())
}

/// [`multi_head_attention_sharded`] over a caller-owned pool and
/// executor.
pub fn multi_head_attention_sharded_ws(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
) -> Matrix {
    multi_head_attention_sharded_prec_ws(x, w, shards, cfg, pool, exec, Precision::F32)
}

/// [`multi_head_attention_sharded`] at an explicit [`Precision`].
pub fn multi_head_attention_sharded_prec(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
    precision: Precision,
) -> Matrix {
    multi_head_attention_sharded_prec_ws(
        x,
        w,
        shards,
        cfg,
        &WorkspacePool::new(),
        &executor::global(),
        precision,
    )
}

/// [`multi_head_attention_sharded_ws`] at an explicit [`Precision`].
pub fn multi_head_attention_sharded_prec_ws(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
    precision: Precision,
) -> Matrix {
    let k = shards.count();
    assert!(k > 0, "sharded attention needs at least one shard");
    let idx: Vec<usize> = (0..k).collect();
    let blocks = exec.map(&idx, |&s| {
        let r = shards.range(s);
        let x_rows = x.row_block(r.start, r.end);
        multi_head_attention_shard(exec, x, &x_rows, w, shards.set(s), cfg, k, precision, pool, false)
            .0
    });
    assemble_row_blocks(x.rows(), &blocks, shards)
}

/// Batch-parallel encoder layer: each shard runs its row slice of the
/// multi-head attention *and* the row-local residual + RMS-norm + FC
/// tail on its own worker, so the whole layer scales across the K
/// logical chips. Bit-identical to [`encoder_layer_heads`] over the
/// unsliced plan set.
pub fn encoder_layer_heads_sharded(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
) -> Matrix {
    encoder_layer_heads_sharded_ws(x, w, shards, cfg, &WorkspacePool::new(), &executor::global())
}

/// [`encoder_layer_heads_sharded`] over a caller-owned pool and
/// executor.
pub fn encoder_layer_heads_sharded_ws(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
) -> Matrix {
    encoder_layer_heads_sharded_ws_prec(x, w, shards, cfg, pool, exec, Precision::F32)
}

/// [`encoder_layer_heads_sharded_ws`] at an explicit [`Precision`].
pub fn encoder_layer_heads_sharded_ws_prec(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
    precision: Precision,
) -> Matrix {
    let k = shards.count();
    assert!(k > 0, "sharded encoder layer needs at least one shard");
    let idx: Vec<usize> = (0..k).collect();
    let blocks = exec.map(&idx, |&s| {
        let r = shards.range(s);
        let x_rows = x.row_block(r.start, r.end);
        let z = multi_head_attention_shard(
            exec,
            x,
            &x_rows,
            w,
            shards.set(s),
            cfg,
            k,
            precision,
            pool,
            false,
        )
        .0;
        pool.with(|ws| encoder_tail(&x_rows, &z, &w.w_fc1, &w.w_fc2, ws))
    });
    assemble_row_blocks(x.rows(), &blocks, shards)
}

/// [`encoder_layer_heads_sharded_ws_prec`] that additionally reduces the
/// layer's retained softmax probabilities into a [`LayerImportance`].
/// Each shard retains its own per-head plan-ordered streams; the
/// reduction then walks **head-major across the ordered shard slices**
/// (`for head { for shard { rows } }`), which reproduces the unsharded
/// `(head, row)` accumulation order exactly — the importance is
/// bit-identical at any shard, leader, or worker count.
#[allow(clippy::too_many_arguments)]
pub fn encoder_layer_heads_sharded_importance(
    x: &Matrix,
    w: &MultiHeadWeights,
    shards: &crate::sparse::ShardedPlans,
    cfg: &ModelConfig,
    pool: &WorkspacePool,
    exec: &Executor,
    precision: Precision,
) -> (Matrix, LayerImportance) {
    let k = shards.count();
    assert!(k > 0, "sharded encoder layer needs at least one shard");
    let idx: Vec<usize> = (0..k).collect();
    let results = exec.map(&idx, |&s| {
        let r = shards.range(s);
        let x_rows = x.row_block(r.start, r.end);
        let (z, probs) = multi_head_attention_shard(
            exec,
            x,
            &x_rows,
            w,
            shards.set(s),
            cfg,
            k,
            precision,
            pool,
            true,
        );
        let h = pool.with(|ws| encoder_tail(&x_rows, &z, &w.w_fc1, &w.w_fc2, ws));
        (h, probs.expect("probs requested"))
    });
    let mut blocks = Vec::with_capacity(k);
    let mut shard_probs = Vec::with_capacity(k);
    for (h, p) in results {
        blocks.push(h);
        shard_probs.push(p);
    }
    let heads = w.heads.len();
    let mut imp = LayerImportance::new(x.rows(), heads);
    for h in 0..heads {
        for (s, probs) in shard_probs.iter().enumerate() {
            imp.add_rows(h, shards.set(s).plan(h), &probs[h]);
        }
    }
    (assemble_row_blocks(x.rows(), &blocks, shards), imp)
}

/// Stitch per-shard row blocks back into one batch-shaped matrix.
fn assemble_row_blocks(
    rows: usize,
    blocks: &[Matrix],
    shards: &crate::sparse::ShardedPlans,
) -> Matrix {
    let cols = blocks[0].cols();
    let mut out = Matrix::zeros(rows, cols);
    for (s, block) in blocks.iter().enumerate() {
        let r = shards.range(s);
        assert_eq!(block.shape(), (r.len(), cols), "shard {s} block shape");
        out.data_mut()[r.start * cols..r.end * cols].copy_from_slice(block.data());
    }
    out
}

/// CPDAA: the dense calculation mode (all-ones mask) of Fig. 14.
pub fn dense_attention(x: &Matrix, w_s: &Matrix, w_v: &Matrix, cfg: &ModelConfig) -> Matrix {
    let s = x.matmul(w_s).matmul(&x.transpose()).scale(1.0 / (cfg.d_k as f32).sqrt());
    let p = softmax::softmax(&s);
    p.matmul(&x.matmul(w_v))
}

/// Vanilla attention (Fig. 1a) via explicit Q and K — used by tests to
/// prove the eq. 2 ≡ eq. 3 folding and by the ReBERT/ReTransformer
/// baseline cost models for their operation counts.
pub fn vanilla_attention(x: &Matrix, w_q: &Matrix, w_k: &Matrix, w_v: &Matrix, d_k: usize) -> Matrix {
    let q = x.matmul(w_q);
    let k = x.matmul(w_k);
    let s = q.matmul(&k.transpose()).scale(1.0 / (d_k as f32).sqrt());
    let p = softmax::softmax(&s);
    p.matmul(&x.matmul(w_v))
}

/// One encoder layer (§4.5): sparse attention + FC block with residual +
/// RMS norm, mirroring `model.encoder_layer`.
pub fn encoder_layer(
    x: &Matrix,
    w: &super::Weights,
    mask: &MaskMatrix,
    cfg: &ModelConfig,
) -> Matrix {
    encoder_layer_planned(x, w, &mask.plan(), cfg)
}

/// [`encoder_layer`] over a prebuilt [`DispatchPlan`] — the coordinator
/// builds the plan once per packed batch and reuses it across the stack.
/// Runs the fused attention kernel and the workspace encoder tail.
pub fn encoder_layer_planned(
    x: &Matrix,
    w: &super::Weights,
    plan: &DispatchPlan,
    cfg: &ModelConfig,
) -> Matrix {
    let mut ws = KernelWorkspace::new();
    let exec = executor::global();
    let z = cpsaa_attention_rows_fused(
        &exec,
        x,
        x,
        &w.w_s,
        &w.w_v,
        plan,
        cfg,
        1,
        Precision::F32,
        &mut ws,
        None,
    );
    encoder_tail(x, &z, &w.w_fc1, &w.w_fc2, &mut ws)
}

/// [`encoder_layer_planned`] through the unfused reference chain and
/// freshly-allocating tail — the fused/workspace path's bit-equivalence
/// oracle and the `unfused` encoder bench rung.
pub fn encoder_layer_unfused(
    x: &Matrix,
    w: &super::Weights,
    plan: &DispatchPlan,
    cfg: &ModelConfig,
) -> Matrix {
    let z = cpsaa_attention_unfused(x, &w.w_s, &w.w_v, plan, cfg);
    let h = rms_norm(&x.add(&z));
    let ff = h.matmul(&w.w_fc1).map(gelu).matmul(&w.w_fc2);
    rms_norm(&h.add(&ff))
}

/// Residual + RMS-norm + FC tail of one encoder layer, every
/// intermediate drawn from the workspace: t = x+z, h = rms(t),
/// ff = gelu(h·FC1)·FC2 (ping-ponging t/ff), out = rms(h+ff).
/// Bit-identical to the freshly-allocating chain in
/// [`encoder_layer_unfused`].
fn encoder_tail(
    x: &Matrix,
    z: &Matrix,
    w_fc1: &Matrix,
    w_fc2: &Matrix,
    ws: &mut KernelWorkspace,
) -> Matrix {
    let KernelWorkspace { t, h, ff, .. } = ws;
    x.add_into(z, t);
    rms_norm_into(t, h);
    h.matmul_into(w_fc1, ff);
    ff.map_inplace(gelu);
    ff.matmul_into(w_fc2, t);
    h.add_into(t, ff);
    rms_norm(ff)
}

fn gelu(x: f32) -> f32 {
    // tanh approximation, matching jax.nn.gelu's default
    let c = (2.0 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// RMS-normalize each row.
fn rms_norm(x: &Matrix) -> Matrix {
    let mut out = Matrix::default();
    rms_norm_into(x, &mut out);
    out
}

/// [`rms_norm`] into a caller-owned buffer, writing whole row slices
/// (no per-element index math) — the workspace tail's norm.
fn rms_norm_into(x: &Matrix, out: &mut Matrix) {
    out.reset(x.rows(), x.cols());
    let n = x.cols() as f32;
    for i in 0..x.rows() {
        let row = x.row(i);
        // sum of squares through the one laned reduction definition
        let ms = simd::dot(row, row) / n;
        let scale = 1.0 / (ms + 1e-6).sqrt();
        for (o, &v) in out.row_mut(i).iter_mut().zip(row) {
            *o = v * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{generate_mask, Weights};
    use crate::tensor::SeededRng;

    fn setup(seq: usize, d: usize) -> (Matrix, Weights, ModelConfig) {
        let cfg = ModelConfig { seq_len: seq, d_model: d, ..Default::default() };
        let w = Weights::synthetic(&cfg, 0);
        let x = SeededRng::new(9).normal_matrix(seq, d, 1.0);
        (x, w, cfg)
    }

    #[test]
    fn sddmm_matches_masked_matmul() {
        let mut rng = SeededRng::new(1);
        let a = rng.normal_matrix(16, 24, 1.0);
        let b = rng.normal_matrix(24, 16, 1.0);
        let mask = MaskMatrix::from_dense(&rng.mask_matrix(16, 16, 0.3));
        let got = masked_sddmm(&a, &b, &mask);
        let full = a.matmul(&b);
        for i in 0..16 {
            for j in 0..16 {
                let want = if mask.get(i, j) { full.get(i, j) } else { 0.0 };
                assert!((got.get(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn dense_mode_equals_full_mask_sparse_mode() {
        let (x, w, cfg) = setup(32, 64);
        let ones = MaskMatrix::ones(32, 32);
        let zd = dense_attention(&x, &w.w_s, &w.w_v, &cfg);
        let zs = cpsaa_attention(&x, &w.w_s, &w.w_v, &ones, &cfg);
        assert!(zd.rel_err(&zs) < 1e-4, "{}", zd.rel_err(&zs));
    }

    #[test]
    fn fused_bit_identical_to_unfused_reference() {
        let (x, w, cfg) = setup(48, 64);
        for density in [0.0, 0.1, 0.5, 1.0] {
            let mask =
                MaskMatrix::from_dense(&SeededRng::new(31).mask_matrix(48, 48, density));
            let plan = mask.plan();
            let fused = cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg);
            let unfused = cpsaa_attention_unfused(&x, &w.w_s, &w.w_v, &plan, &cfg);
            assert_eq!(fused, unfused, "fused diverged at density {density}");
            let ef = encoder_layer_planned(&x, &w, &plan, &cfg);
            let eu = encoder_layer_unfused(&x, &w, &plan, &cfg);
            assert_eq!(ef, eu, "encoder layer diverged at density {density}");
        }
    }

    #[test]
    fn workspace_reuse_is_bit_stable() {
        // The same workspace serving different plans/shapes back to back
        // must never leak state between calls.
        let (x, w, cfg) = setup(32, 64);
        let mut ws = KernelWorkspace::new();
        let mut rng = SeededRng::new(77);
        for density in [0.8, 0.05, 0.4] {
            let mask = MaskMatrix::from_dense(&rng.mask_matrix(32, 32, density));
            let plan = mask.plan();
            let fresh = cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg);
            let reused = cpsaa_attention_planned_ws(&x, &w.w_s, &w.w_v, &plan, &cfg, &mut ws);
            assert_eq!(fresh, reused, "stale workspace state leaked at density {density}");
        }
    }

    #[test]
    fn eq2_equals_eq3() {
        // vanilla attention with (w_q, w_k) == CPSAA mode with w_s = w_q w_k^T
        let cfg = ModelConfig { seq_len: 32, d_model: 48, d_k: 16, ..Default::default() };
        let mut rng = SeededRng::new(2);
        let w_q = rng.normal_matrix(48, 16, 0.3);
        let w_k = rng.normal_matrix(48, 16, 0.3);
        let w_v = rng.normal_matrix(48, 48, 0.3);
        let x = rng.normal_matrix(32, 48, 1.0);
        let w_s = w_q.matmul(&w_k.transpose());
        let z2 = vanilla_attention(&x, &w_q, &w_k, &w_v, 16);
        let z3 = dense_attention(&x, &w_s, &w_v, &cfg);
        assert!(z2.rel_err(&z3) < 1e-3, "{}", z2.rel_err(&z3));
    }

    #[test]
    fn sparse_close_to_dense_at_paper_sparsity() {
        let (x, w, cfg) = setup(64, 128);
        let mask = generate_mask(&x, &w.w_s, &cfg);
        let zs = cpsaa_attention(&x, &w.w_s, &w.w_v, &mask, &cfg);
        let zd = dense_attention(&x, &w.w_s, &w.w_v, &cfg);
        let rel = zs.rel_err(&zd);
        assert!(rel < 0.35, "mask fidelity {rel} (density {})", mask.density());
    }

    #[test]
    fn encoder_layer_finite_and_stackable() {
        let (x, w, cfg) = setup(32, 64);
        let mask = generate_mask(&x, &w.w_s, &cfg);
        let mut h = encoder_layer(&x, &w, &mask, &cfg);
        for _ in 0..3 {
            let m = generate_mask(&h, &w.w_s, &cfg);
            h = encoder_layer(&h, &w, &m, &cfg);
        }
        assert!(h.all_finite());
        assert_eq!(h.shape(), (32, 64));
    }

    #[test]
    fn empty_mask_attention_is_zero() {
        let (x, w, cfg) = setup(32, 64);
        let empty = MaskMatrix::zeros(32, 32);
        let z = cpsaa_attention(&x, &w.w_s, &w.w_v, &empty, &cfg);
        assert_eq!(z.norm(), 0.0);
    }

    #[test]
    fn one_head_fanout_is_bit_identical() {
        let (x, w, cfg) = setup(32, 64);
        let mask = generate_mask(&x, &w.w_s, &cfg);
        let plan = mask.plan();
        let mh = MultiHeadWeights::from_single(&w);
        let plans = PlanSet::single(plan.clone());
        let a = cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &plan, &cfg);
        let b = multi_head_attention_planned(&x, &mh, &plans, &cfg);
        assert_eq!(a, b, "1-head fan-out must not change a single bit");
        let ea = encoder_layer_planned(&x, &w, &plan, &cfg);
        let eb = encoder_layer_heads(&x, &mh, &plans, &cfg);
        assert_eq!(ea, eb);
    }

    #[test]
    fn split_heads_concat_to_single_head_output() {
        // Identical per-head masks (replicated W_S) + column-split W_V:
        // the concat of head outputs equals the single-head output, and
        // the accumulation order matches, so equality is exact.
        let (x, w, cfg) = setup(32, 64);
        let mask = generate_mask(&x, &w.w_s, &cfg);
        let mh = MultiHeadWeights::split(&w, 4).unwrap();
        let plans = PlanSet::from_plans(vec![mask.plan(); 4]);
        let single = cpsaa_attention_planned(&x, &w.w_s, &w.w_v, &mask.plan(), &cfg);
        let fanned = multi_head_attention_planned(&x, &mh, &plans, &cfg);
        assert_eq!(single, fanned);
    }

    #[test]
    fn sharded_attention_bit_identical_to_unsharded() {
        // Distinct per-head masks, several shard counts (including more
        // shards than fit): the assembled sharded output must not
        // differ in a single bit.
        let cfg = ModelConfig { seq_len: 32, d_model: 64, d_k: 8, d_ff: 128, heads: 4, ..Default::default() };
        let mh = MultiHeadWeights::synthetic(&cfg, 21);
        let x = SeededRng::new(22).normal_matrix(32, 64, 1.0);
        let masks = super::super::mask::generate_heads(&x, &mh, &cfg);
        let plans = PlanSet::build(&masks);
        let want_z = multi_head_attention_planned(&x, &mh, &plans, &cfg);
        let want_h = encoder_layer_heads(&x, &mh, &plans, &cfg);
        for shards in [1, 2, 3, 4, 7] {
            let sharded = plans.shard(shards);
            let z = multi_head_attention_sharded(&x, &mh, &sharded, &cfg);
            assert_eq!(z, want_z, "attention diverged at {shards} shards");
            let h = encoder_layer_heads_sharded(&x, &mh, &sharded, &cfg);
            assert_eq!(h, want_h, "encoder layer diverged at {shards} shards");
        }
    }

    #[test]
    fn sharded_shared_scores_path_bit_identical() {
        // Replicated-W_S fan-out (single-head file split 4 ways) takes
        // the shared-scores fast path on both sides.
        let (x, w, cfg) = setup(32, 64);
        let mask = generate_mask(&x, &w.w_s, &cfg);
        let mh = MultiHeadWeights::split(&w, 4).unwrap();
        let plans = PlanSet::from_plans(vec![mask.plan(); 4]);
        let want = multi_head_attention_planned(&x, &mh, &plans, &cfg);
        let got = multi_head_attention_sharded(&x, &mh, &plans.shard(3), &cfg);
        assert_eq!(got, want);
    }

    #[test]
    fn sharded_empty_mask_is_zero_attention() {
        let cfg = ModelConfig { seq_len: 16, d_model: 32, ..Default::default() };
        let w = Weights::synthetic(&cfg, 5);
        let mh = MultiHeadWeights::from_single(&w);
        let x = SeededRng::new(6).normal_matrix(16, 32, 1.0);
        let plans = PlanSet::single(MaskMatrix::zeros(16, 16).plan());
        // empty mask ⇒ one shard range covering everything
        let sharded = plans.shard(4);
        assert_eq!(sharded.count(), 1);
        let z = multi_head_attention_sharded(&x, &mh, &sharded, &cfg);
        assert_eq!(z.norm(), 0.0);
    }

    #[test]
    fn distinct_heads_finite_and_shaped() {
        let cfg = ModelConfig { seq_len: 32, d_model: 64, d_k: 8, d_ff: 128, heads: 4, ..Default::default() };
        let mh = MultiHeadWeights::synthetic(&cfg, 11);
        let x = SeededRng::new(12).normal_matrix(32, 64, 1.0);
        let masks = super::super::mask::generate_heads(&x, &mh, &cfg);
        let plans = PlanSet::build(&masks);
        let z = multi_head_attention_planned(&x, &mh, &plans, &cfg);
        assert_eq!(z.shape(), (32, 64));
        assert!(z.all_finite());
        let h = encoder_layer_heads(&x, &mh, &plans, &cfg);
        assert_eq!(h.shape(), (32, 64));
        assert!(h.all_finite());
    }

    #[test]
    fn rms_norm_matches_scalar_reference() {
        // The reference mean-square uses the shared simd::dot reduction
        // (bit-identical to its scalar fallback by construction), and the
        // per-row value is sanity-checked against a sequential f64 sum.
        let x = SeededRng::new(40).normal_matrix(7, 13, 2.0);
        let got = rms_norm(&x);
        for i in 0..7 {
            let row = x.row(i);
            let ms = simd::dot(row, row) / 13.0;
            let seq: f64 = row.iter().map(|&v| f64::from(v) * f64::from(v)).sum::<f64>() / 13.0;
            assert!((f64::from(ms) - seq).abs() < 1e-4, "row {i}: {ms} vs {seq}");
            let scale = 1.0 / (ms + 1e-6).sqrt();
            for j in 0..13 {
                assert_eq!(got.get(i, j), x.get(i, j) * scale, "({i},{j})");
            }
        }
        // into-variant overwrites stale larger buffers completely
        let mut out = Matrix::full(9, 20, 5.0);
        rms_norm_into(&x, &mut out);
        assert_eq!(out, got);
    }

    #[test]
    fn injected_executor_is_worker_count_invariant() {
        // The same kernels on a strictly serial pool, a narrow pool, and
        // the crate-wide default must not differ in a single bit — the
        // executor axis of the equivalence grid.
        let cfg = ModelConfig { seq_len: 32, d_model: 64, d_k: 8, d_ff: 128, heads: 4, ..Default::default() };
        let mh = MultiHeadWeights::synthetic(&cfg, 21);
        let x = SeededRng::new(22).normal_matrix(32, 64, 1.0);
        let masks = super::super::mask::generate_heads(&x, &mh, &cfg);
        let plans = PlanSet::build(&masks);
        let want = multi_head_attention_planned(&x, &mh, &plans, &cfg);
        let want_sharded = multi_head_attention_sharded(&x, &mh, &plans.shard(3), &cfg);
        assert_eq!(want, want_sharded);
        for workers in [1usize, 2, 5] {
            let exec = Executor::new(workers);
            let pool = WorkspacePool::new();
            let got = multi_head_attention_planned_ws(&x, &mh, &plans, &cfg, &pool, &exec);
            assert_eq!(got, want, "planned diverged at {workers} executor workers");
            let got_sharded =
                multi_head_attention_sharded_ws(&x, &mh, &plans.shard(3), &cfg, &pool, &exec);
            assert_eq!(got_sharded, want, "sharded diverged at {workers} executor workers");
            let h = encoder_layer_heads_ws(&x, &mh, &plans, &cfg, &pool, &exec);
            let h_want = encoder_layer_heads(&x, &mh, &plans, &cfg);
            assert_eq!(h, h_want, "encoder layer diverged at {workers} executor workers");
        }
    }
}
