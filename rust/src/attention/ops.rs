//! Attention calculation phase — Steps 2–4 (eq. 3) plus reference modes.

use crate::config::ModelConfig;
use crate::sparse::{CsrMatrix, MaskMatrix};
use crate::tensor::Matrix;

use super::softmax;

/// Masked SDDMM: `mask ⊙ (a @ b)` — Step 3's S = M·Xᵀ restricted to the
/// mask. Computed sparsely: only masked coordinates are evaluated, exactly
/// the work the crossbar SDDMM engine performs.
pub fn masked_sddmm(a: &Matrix, b: &Matrix, mask: &MaskMatrix) -> Matrix {
    assert_eq!(a.cols(), b.rows());
    assert_eq!((mask.rows(), mask.cols()), (a.rows(), b.cols()));
    let k = a.cols();
    let bt = b.transpose(); // stream b's columns as rows
    let mut out = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in mask.row_coords(i) {
            let brow = bt.row(j);
            let mut acc = 0.0;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            out.set(i, j, acc);
        }
    }
    out
}

/// CPSAA attention (Steps 2–4): M = X·W_S, V = X·W_V,
/// S = mask ⊙ (M·Xᵀ)/√d_k, P = masked softmax, Z = P·V.
pub fn cpsaa_attention(x: &Matrix, w_s: &Matrix, w_v: &Matrix, mask: &MaskMatrix, cfg: &ModelConfig) -> Matrix {
    let m = x.matmul(w_s);
    let v = x.matmul(w_v);
    let s = masked_sddmm(&m, &x.transpose(), mask).scale(1.0 / (cfg.d_k as f32).sqrt());
    let mut p = CsrMatrix::from_dense_masked(&s, mask);
    p.softmax_rows();
    p.spmm(&v)
}

/// CPDAA: the dense calculation mode (all-ones mask) of Fig. 14.
pub fn dense_attention(x: &Matrix, w_s: &Matrix, w_v: &Matrix, cfg: &ModelConfig) -> Matrix {
    let s = x.matmul(w_s).matmul(&x.transpose()).scale(1.0 / (cfg.d_k as f32).sqrt());
    let p = softmax::softmax(&s);
    p.matmul(&x.matmul(w_v))
}

/// Vanilla attention (Fig. 1a) via explicit Q and K — used by tests to
/// prove the eq. 2 ≡ eq. 3 folding and by the ReBERT/ReTransformer
/// baseline cost models for their operation counts.
pub fn vanilla_attention(x: &Matrix, w_q: &Matrix, w_k: &Matrix, w_v: &Matrix, d_k: usize) -> Matrix {
    let q = x.matmul(w_q);
    let k = x.matmul(w_k);
    let s = q.matmul(&k.transpose()).scale(1.0 / (d_k as f32).sqrt());
    let p = softmax::softmax(&s);
    p.matmul(&x.matmul(w_v))
}

/// One encoder layer (§4.5): sparse attention + FC block with residual +
/// RMS norm, mirroring `model.encoder_layer`.
pub fn encoder_layer(
    x: &Matrix,
    w: &super::Weights,
    mask: &MaskMatrix,
    cfg: &ModelConfig,
) -> Matrix {
    let z = cpsaa_attention(x, &w.w_s, &w.w_v, mask, cfg);
    let h = rms_norm(&x.add(&z));
    let ff = h.matmul(&w.w_fc1).map(gelu).matmul(&w.w_fc2);
    rms_norm(&h.add(&ff))
}

fn gelu(x: f32) -> f32 {
    // tanh approximation, matching jax.nn.gelu's default
    let c = (2.0 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn rms_norm(x: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), x.cols());
    let n = x.cols() as f32;
    for i in 0..x.rows() {
        let row = x.row(i);
        let ms = row.iter().map(|v| v * v).sum::<f32>() / n;
        let scale = 1.0 / (ms + 1e-6).sqrt();
        for (j, &v) in row.iter().enumerate() {
            out.set(i, j, v * scale);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{generate_mask, Weights};
    use crate::tensor::SeededRng;

    fn setup(seq: usize, d: usize) -> (Matrix, Weights, ModelConfig) {
        let cfg = ModelConfig { seq_len: seq, d_model: d, ..Default::default() };
        let w = Weights::synthetic(&cfg, 0);
        let x = SeededRng::new(9).normal_matrix(seq, d, 1.0);
        (x, w, cfg)
    }

    #[test]
    fn sddmm_matches_masked_matmul() {
        let mut rng = SeededRng::new(1);
        let a = rng.normal_matrix(16, 24, 1.0);
        let b = rng.normal_matrix(24, 16, 1.0);
        let mask = MaskMatrix::from_dense(&rng.mask_matrix(16, 16, 0.3));
        let got = masked_sddmm(&a, &b, &mask);
        let full = a.matmul(&b);
        for i in 0..16 {
            for j in 0..16 {
                let want = if mask.get(i, j) { full.get(i, j) } else { 0.0 };
                assert!((got.get(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn dense_mode_equals_full_mask_sparse_mode() {
        let (x, w, cfg) = setup(32, 64);
        let ones = MaskMatrix::ones(32, 32);
        let zd = dense_attention(&x, &w.w_s, &w.w_v, &cfg);
        let zs = cpsaa_attention(&x, &w.w_s, &w.w_v, &ones, &cfg);
        assert!(zd.rel_err(&zs) < 1e-4, "{}", zd.rel_err(&zs));
    }

    #[test]
    fn eq2_equals_eq3() {
        // vanilla attention with (w_q, w_k) == CPSAA mode with w_s = w_q w_k^T
        let cfg = ModelConfig { seq_len: 32, d_model: 48, d_k: 16, ..Default::default() };
        let mut rng = SeededRng::new(2);
        let w_q = rng.normal_matrix(48, 16, 0.3);
        let w_k = rng.normal_matrix(48, 16, 0.3);
        let w_v = rng.normal_matrix(48, 48, 0.3);
        let x = rng.normal_matrix(32, 48, 1.0);
        let w_s = w_q.matmul(&w_k.transpose());
        let z2 = vanilla_attention(&x, &w_q, &w_k, &w_v, 16);
        let z3 = dense_attention(&x, &w_s, &w_v, &cfg);
        assert!(z2.rel_err(&z3) < 1e-3, "{}", z2.rel_err(&z3));
    }

    #[test]
    fn sparse_close_to_dense_at_paper_sparsity() {
        let (x, w, cfg) = setup(64, 128);
        let mask = generate_mask(&x, &w.w_s, &cfg);
        let zs = cpsaa_attention(&x, &w.w_s, &w.w_v, &mask, &cfg);
        let zd = dense_attention(&x, &w.w_s, &w.w_v, &cfg);
        let rel = zs.rel_err(&zd);
        assert!(rel < 0.35, "mask fidelity {rel} (density {})", mask.density());
    }

    #[test]
    fn encoder_layer_finite_and_stackable() {
        let (x, w, cfg) = setup(32, 64);
        let mask = generate_mask(&x, &w.w_s, &cfg);
        let mut h = encoder_layer(&x, &w, &mask, &cfg);
        for _ in 0..3 {
            let m = generate_mask(&h, &w.w_s, &cfg);
            h = encoder_layer(&h, &w, &m, &cfg);
        }
        assert!(h.all_finite());
        assert_eq!(h.shape(), (32, 64));
    }

    #[test]
    fn empty_mask_attention_is_zero() {
        let (x, w, cfg) = setup(32, 64);
        let empty = MaskMatrix::zeros(32, 32);
        let z = cpsaa_attention(&x, &w.w_s, &w.w_v, &empty, &cfg);
        assert_eq!(z.norm(), 0.0);
    }
}
