//! Masked row softmax — the Softmax Unit (SU, Fig. 6b) semantics.

use crate::sparse::MaskMatrix;
use crate::tensor::Matrix;

/// Row softmax restricted to positions where `mask` is set; rows with no
/// active entry become all-zero (the SU skips them). Matches the L1
/// `masked_softmax` kernel and `ref.masked_softmax_ref`.
pub fn masked_softmax(s: &Matrix, mask: &MaskMatrix) -> Matrix {
    assert_eq!((s.rows(), s.cols()), (mask.rows(), mask.cols()));
    masked_softmax_planned(s, &mask.plan())
}

/// [`masked_softmax`] over a prebuilt dispatch plan (the SU walks the
/// same ⟨α, βᵢ⟩ stream the other engines consume).
pub fn masked_softmax_planned(s: &Matrix, plan: &crate::sparse::DispatchPlan) -> Matrix {
    assert_eq!((s.rows(), s.cols()), (plan.rows(), plan.cols()));
    let mut out = Matrix::zeros(s.rows(), s.cols());
    for i in 0..s.rows() {
        let coords = plan.row_cols(i);
        if coords.is_empty() {
            continue;
        }
        let max =
            coords.iter().map(|&j| s.get(i, j as usize)).fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0;
        for &j in coords {
            let e = (s.get(i, j as usize) - max).exp();
            out.set(i, j as usize, e);
            denom += e;
        }
        for &j in coords {
            out.set(i, j as usize, out.get(i, j as usize) / denom);
        }
    }
    out
}

/// Plain (unmasked) row softmax.
pub fn softmax(s: &Matrix) -> Matrix {
    masked_softmax(s, &MaskMatrix::ones(s.rows(), s.cols()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    #[test]
    fn rows_sum_to_one_or_zero() {
        let mut rng = SeededRng::new(0);
        let s = rng.normal_matrix(16, 16, 2.0);
        let mask = MaskMatrix::from_dense(&rng.mask_matrix(16, 16, 0.2));
        let p = masked_softmax(&s, &mask);
        for i in 0..16 {
            let sum: f32 = p.row(i).iter().sum();
            if mask.row_nnz(i) > 0 {
                assert!((sum - 1.0).abs() < 1e-5);
            } else {
                assert_eq!(sum, 0.0);
            }
        }
    }

    #[test]
    fn masked_positions_zero() {
        let mut rng = SeededRng::new(1);
        let s = rng.normal_matrix(8, 8, 1.0);
        let mask = MaskMatrix::from_dense(&rng.mask_matrix(8, 8, 0.3));
        let p = masked_softmax(&s, &mask);
        for i in 0..8 {
            for j in 0..8 {
                if !mask.get(i, j) {
                    assert_eq!(p.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn stable_under_large_logits() {
        let s = SeededRng::new(2).normal_matrix(8, 8, 1e4);
        let p = softmax(&s);
        assert!(p.all_finite());
    }

    #[test]
    fn shift_invariant() {
        let s = SeededRng::new(3).normal_matrix(8, 8, 1.0);
        let shifted = s.map(|v| v + 42.0);
        assert!(softmax(&s).max_abs_diff(&softmax(&shifted)) < 1e-5);
    }
}
