//! ReBERT and ReTransformer — the PIM dense-attention baselines (§3).
//!
//! Both share CPSAA's crossbar substrate (same Table 2 arrays, "apple-to-
//! apple", §5) but differ in **calculation mode** (Fig. 4):
//!
//! * **ReBERT** (write-then-calculate): Q, K, V computed concurrently
//!   (max VMM parallelism) but S = Q·Kᵀ *waits for the full Kᵀ write* —
//!   maximal W4W (Fig. 15: 1.94× ReTransformer).
//! * **ReTransformer** (serial folding): Q → R = Q·Xᵀ → S → P → Z with no
//!   K/V materialization — minimal writes but a strict dependency chain
//!   that serializes every VMM (worst parallelism: Fig. 15 baseline).
//!
//! The `S-` hybrids append the zero-gating SpMM of Fig. 9 for Z = P·V:
//! energy drops with density, cycles do not (Fig. 13).

use crate::config::{HardwareConfig, ModelConfig};
use crate::sim::cost::{self, VmmOp};
use crate::workload::BatchStats;

use super::{gops_from, Platform, PlatformReport};

/// Zero-gating SpMM option for the Z = P·V step (the `S-` variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpmmKind {
    /// Plain dense DDMM.
    Dense,
    /// Fig. 9 zero-gating: same cycles, energy scaled by density.
    ZeroGated,
}

/// ReBERT [22].
pub struct ReBert {
    pub hw: HardwareConfig,
    pub spmm: SpmmKind,
}

impl ReBert {
    pub fn new(hw: HardwareConfig) -> Self {
        Self { hw, spmm: SpmmKind::Dense }
    }

    /// The S-ReBERT hybrid of Fig. 13.
    pub fn with_sparse_spmm(hw: HardwareConfig) -> Self {
        Self { hw, spmm: SpmmKind::ZeroGated }
    }
}

/// ReTransformer [52].
pub struct ReTransformer {
    pub hw: HardwareConfig,
    pub spmm: SpmmKind,
}

impl ReTransformer {
    pub fn new(hw: HardwareConfig) -> Self {
        Self { hw, spmm: SpmmKind::Dense }
    }

    /// The S-ReTransformer hybrid of Fig. 13.
    pub fn with_sparse_spmm(hw: HardwareConfig) -> Self {
        Self { hw, spmm: SpmmKind::ZeroGated }
    }
}

/// Convert accumulated VMM energy into a report, shared by both PIM modes.
/// Adds the same static-power and on-chip-transfer shares the CPSAA
/// [`ChipSim`](crate::sim::ChipSim) charges, so energy comparisons are
/// apples-to-apples.
fn pim_report(
    name: &'static str,
    hw: &HardwareConfig,
    model: &ModelConfig,
    total_ns: f64,
    w4w_ns: f64,
    vmm_energy_pj: f64,
    peak_arrays: u64,
) -> PlatformReport {
    let gops = gops_from(model, total_ns);
    let area = crate::sim::area::AreaModel::build(hw);
    let n = model.seq_len;
    let d = model.d_model;
    let (_, xfer_pj) = cost::transfer(hw, ((n * d + n * model.d_k) * 4) as u64); // X in, Z out
    let static_pj = area.chip_power_mw * cost::STATIC_SHARE * total_ns;
    let energy_pj = vmm_energy_pj + xfer_pj + static_pj;
    let watts = energy_pj * 1e-12 / (total_ns * 1e-9).max(1e-12) + area.chip_power_w() * 0.10;
    PlatformReport {
        name,
        total_ns,
        energy_pj,
        gops,
        gops_per_watt: gops / watts.max(1e-9),
        wait_for_write_ns: w4w_ns,
        peak_parallel_arrays: peak_arrays,
        // PIM: no off-chip phases; mark all time as processor time.
        mage: (0.0, 0.0),
        atca: (0.0, total_ns),
    }
}

impl Platform for ReBert {
    fn name(&self) -> &'static str {
        if self.spmm == SpmmKind::ZeroGated { "S-ReBERT" } else { "ReBERT" }
    }

    fn run_batch(&self, model: &ModelConfig, stats: &BatchStats) -> PlatformReport {
        let hw = &self.hw;
        let n = model.seq_len;
        let d = model.d_model;
        let dk = model.d_k;
        let roa = cost::roa_arrays(hw);
        let wea = cost::wea_arrays(hw);

        // Q, K, V concurrently; ROA split proportionally to operand size.
        // ReBERT maps each weight matrix exactly once — operand
        // replication scheduling is a CPSAA (ReCAM/AIT) capability.
        let layout = |k: usize, m: usize| m as u64 * cost::segments_per_column(hw, k);
        let total_layout = 3 * layout(d, dk);
        let share = |l: u64| (roa * l / total_layout).max(1);
        let chain = |op, alloc| cost::vmm_cost_with_copies(hw, op, alloc, 1);
        let q = chain(VmmOp { n, k: d, m: dk }, share(layout(d, dk)));
        let k = chain(VmmOp { n, k: d, m: dk }, share(layout(d, dk)));
        let v = chain(VmmOp { n, k: d, m: dk }, share(layout(d, dk)));
        let t_qkv = q.ns.max(k.ns).max(v.ns);

        // Write-then-calculate: S waits for the complete Kᵀ write; the V
        // write follows on the same drivers before Z may run.
        let w_kt = cost::write_matrix_ns(hw, dk, n);
        let s = chain(VmmOp { n, k: dk, m: n }, wea / 2);
        let softmax_ns = (n as f64 / hw.tiles as f64 + 4.0) * hw.cycle_ns;
        let w_v = cost::write_matrix_ns(hw, n, dk);
        let z = chain(VmmOp { n, k: n, m: dk }, wea / 2);

        // Timeline: QKV → (wait Kᵀ write) → S → softmax → (wait V write) → Z.
        let t1 = t_qkv + w_kt; // S start (write-then-calculate)
        let t2 = t1 + s.ns + softmax_ns;
        let v_ready = t_qkv + w_kt + w_v; // V queued behind Kᵀ on the drivers
        let z_start = t2.max(v_ready);
        let total = z_start + z.ns;
        // Fig. 15 W4W: the write-then-calculate mode exposes both writes
        // (computes are ordered strictly behind the writes they consume).
        let w4w = w_kt + w_v;

        let z_pj = match self.spmm {
            SpmmKind::Dense => z.pj,
            SpmmKind::ZeroGated => z.pj * stats.mask_density.max(0.02),
        };
        let write_pj = cost::write_matrix_pj(hw, dk, n) + cost::write_matrix_pj(hw, n, d);
        let energy = q.pj + k.pj + v.pj + s.pj + z_pj + write_pj;

        // Peak parallelism: three concurrent VMMs — Q, K, V together
        // (Fig. 15: ≈2.88× ReTransformer's strictly serial chain).
        pim_report(self.name(), hw, model, total, w4w, energy, 3)
    }
}

impl Platform for ReTransformer {
    fn name(&self) -> &'static str {
        if self.spmm == SpmmKind::ZeroGated { "S-ReTransformer" } else { "ReTransformer" }
    }

    fn run_batch(&self, model: &ModelConfig, stats: &BatchStats) -> PlatformReport {
        let hw = &self.hw;
        let n = model.seq_len;
        let d = model.d_model;
        let dk = model.d_k;
        let roa = cost::roa_arrays(hw);
        let wea = cost::wea_arrays(hw);

        // Serial chain (Fig. 4b): Q → R = Q·Xᵀ → softmax → P = S·X → Z = P·W_V.
        // The strict dependency chain forbids replication/fan-out (each
        // op's input streams from the previous op in row order): worst
        // parallelism, minimal writes — exactly the paper's trade.
        let chain = |op, alloc| cost::vmm_cost_with_copies(hw, op, alloc, 1);
        let q = chain(VmmOp { n, k: d, m: dk }, roa);
        let w_xt = cost::write_matrix_ns(hw, d, n); // overlaps Q compute
        let r = chain(VmmOp { n, k: dk, m: n }, wea);
        let softmax_ns = (n as f64 / hw.tiles as f64 + 4.0) * hw.cycle_ns;
        let p = chain(VmmOp { n, k: n, m: d }, wea);
        let z = chain(VmmOp { n, k: d, m: dk }, roa);

        let w4w = (w_xt - q.ns).max(0.0); // only the overhang stalls
        let total = q.ns.max(w_xt) + r.ns + softmax_ns + p.ns + z.ns;

        let z_pj = match self.spmm {
            SpmmKind::Dense => p.pj, // P = S·X is the sparse-able product here
            SpmmKind::ZeroGated => p.pj * stats.mask_density.max(0.02),
        };
        let energy = q.pj + r.pj + z_pj + z.pj + cost::write_matrix_pj(hw, d, n);

        // Peak parallelism: one VMM at a time (the Fig. 15 baseline = 1).
        pim_report(self.name(), hw, model, total, w4w, energy, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (HardwareConfig, ModelConfig, BatchStats) {
        let hw = HardwareConfig::paper();
        let m = ModelConfig::paper();
        let s = BatchStats { seq_len: m.seq_len, d_model: m.d_model, mask_nnz: 10240, mask_density: 0.1 };
        (hw, m, s)
    }

    #[test]
    fn rebert_w4w_exceeds_retransformer() {
        // Fig. 15: ReBERT W4W ≈ 1.94× ReTransformer.
        let (hw, m, s) = setup();
        let rb = ReBert::new(hw.clone()).run_batch(&m, &s);
        let rt = ReTransformer::new(hw).run_batch(&m, &s);
        assert!(
            rb.wait_for_write_ns > rt.wait_for_write_ns,
            "rb {} rt {}",
            rb.wait_for_write_ns,
            rt.wait_for_write_ns
        );
    }

    #[test]
    fn rebert_parallelism_exceeds_retransformer() {
        // Fig. 15: ReBERT parallelism ≈ 2.88× ReTransformer.
        let (hw, m, s) = setup();
        let rb = ReBert::new(hw.clone()).run_batch(&m, &s);
        let rt = ReTransformer::new(hw).run_batch(&m, &s);
        assert!(rb.peak_parallel_arrays > rt.peak_parallel_arrays);
    }

    #[test]
    fn pim_beats_asic_and_gpu() {
        // Fig. 11 ordering: ReBERT/ReTransformer ≫ SANGER ≫ GPU.
        let (hw, m, s) = setup();
        let rb = ReBert::new(hw.clone()).run_batch(&m, &s);
        let sg = super::super::asic::Sanger::default().run_batch(&m, &s);
        let gpu = super::super::device::Gpu::default().run_batch(&m, &s);
        assert!(rb.gops > sg.gops, "rebert {} sanger {}", rb.gops, sg.gops);
        assert!(sg.gops > gpu.gops);
    }

    #[test]
    fn hybrids_save_energy_not_time() {
        // Fig. 13: S-variants reduce energy but not latency.
        let (hw, m, s) = setup();
        let rb = ReBert::new(hw.clone()).run_batch(&m, &s);
        let srb = ReBert::with_sparse_spmm(hw.clone()).run_batch(&m, &s);
        assert!((srb.total_ns - rb.total_ns).abs() < 1e-9);
        assert!(srb.energy_pj < rb.energy_pj);
        let rt = ReTransformer::new(hw.clone()).run_batch(&m, &s);
        let srt = ReTransformer::with_sparse_spmm(hw).run_batch(&m, &s);
        assert!((srt.total_ns - rt.total_ns).abs() < 1e-9);
        assert!(srt.energy_pj < rt.energy_pj);
    }

    #[test]
    fn gops_in_paper_range() {
        // Paper: ReBERT ≈ 2696 GOPS, ReTransformer ≈ 2381 GOPS.
        let (hw, m, s) = setup();
        let rb = ReBert::new(hw.clone()).run_batch(&m, &s);
        let rt = ReTransformer::new(hw).run_batch(&m, &s);
        assert!(rb.gops > 500.0 && rb.gops < 20_000.0, "rebert {}", rb.gops);
        assert!(rt.gops > 500.0 && rt.gops < 20_000.0, "retransformer {}", rt.gops);
    }
}
