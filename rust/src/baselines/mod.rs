//! Comparison platforms of the paper's evaluation (§5 / §6).
//!
//! Each baseline is a structural cost model exposing the same bottlenecks
//! the paper attributes to it (DESIGN.md substitution table):
//!
//! | platform | module | modeled bottleneck |
//! |---|---|---|
//! | GPU (TITAN RTX + BigBird) | [`device::Gpu`] | off-chip bandwidth, sparse-format conversion |
//! | FPGA [58] | [`device::Fpga`] | DSP peak, off-chip streaming |
//! | SANGER (ASIC) | [`asic::Sanger`] | software pruning traffic, split-and-pack control |
//! | DOTA (ASIC) | [`asic::Dota`] | detector pruning traffic |
//! | ReBERT (PIM) | [`pim::ReBert`] | write-then-compute W4W |
//! | ReTransformer (PIM) | [`pim::ReTransformer`] | serial dependency chain |
//! | S-ReBERT / S-ReTransformer | [`pim`] hybrids | zero-gating SpMM (energy only) |
//!
//! All implement [`Platform`] so the bench harness sweeps them uniformly.

pub mod asic;
pub mod device;
pub mod pim;

use crate::config::ModelConfig;
use crate::workload::BatchStats;

/// Uniform per-batch result across platforms.
#[derive(Clone, Debug)]
pub struct PlatformReport {
    pub name: &'static str,
    /// End-to-end batch latency (ns).
    pub total_ns: f64,
    /// Energy (pJ).
    pub energy_pj: f64,
    /// Dense-equivalent throughput (GOPS).
    pub gops: f64,
    /// Energy efficiency (GOPS/W).
    pub gops_per_watt: f64,
    /// Time stalled waiting for ReRAM writes (PIM platforms; else 0).
    pub wait_for_write_ns: f64,
    /// Peak parallel VMM arrays (PIM platforms; else 0).
    pub peak_parallel_arrays: u64,
    /// Mask-generation (pruning) phase split: (memory ns, processor ns).
    pub mage: (f64, f64),
    /// Attention-calculation phase split: (memory ns, processor ns).
    pub atca: (f64, f64),
}

impl PlatformReport {
    /// Response-time fractions for Fig. 3: (MA-GE-M, MA-GE-P, AT-CA-M, AT-CA-P).
    pub fn fig3_fractions(&self) -> [f64; 4] {
        let total = (self.mage.0 + self.mage.1 + self.atca.0 + self.atca.1).max(1e-12);
        [self.mage.0 / total, self.mage.1 / total, self.atca.0 / total, self.atca.1 / total]
    }
}

/// A platform that can process one batch of the attention workload.
pub trait Platform {
    fn name(&self) -> &'static str;
    /// Simulate one batch characterized by `stats` under `model` shapes.
    fn run_batch(&self, model: &ModelConfig, stats: &BatchStats) -> PlatformReport;
}

pub(crate) fn gops_from(model: &ModelConfig, total_ns: f64) -> f64 {
    model.attention_flops() as f64 / 1e9 / (total_ns * 1e-9).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_fractions_sum_to_one() {
        let r = PlatformReport {
            name: "x",
            total_ns: 1.0,
            energy_pj: 1.0,
            gops: 1.0,
            gops_per_watt: 1.0,
            wait_for_write_ns: 0.0,
            peak_parallel_arrays: 0,
            mage: (10.0, 2.0),
            atca: (60.0, 28.0),
        };
        let f = r.fig3_fractions();
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(f[0] > f[1]); // memory dominates pruning
    }
}
