//! SANGER and DOTA — ASIC sparse-attention accelerators (§2.4, Fig. 3).
//!
//! Both pair a software pruning phase (off-chip: Q/K fetched to the
//! processor, score predicted, mask emitted) with an on-chip sparse
//! attention engine. The models reproduce the paper's measured structure:
//!
//! * MA-GE ≈ 17.9% of response time for SANGER (14.3% DOTA), of which
//!   ≈ 94.6% (92.7%) is memory time;
//! * AT-CA memory share ≈ 71.2% (63.5%);
//! * SANGER's split-and-pack reconfiguration charges control time per
//!   scheduled row (the Fig. 16 CTRL-T gap vs. CPSAA's ReCAM scheduler).

use crate::config::ModelConfig;
use crate::workload::BatchStats;

use super::{gops_from, Platform, PlatformReport};

/// Shared ASIC substrate parameters.
#[derive(Clone, Debug)]
pub struct AsicParams {
    /// Sustained MAC throughput of the PE array (GFLOPs).
    pub pe_gflops: f64,
    /// Effective DRAM bandwidth of the pruning phase (GB/s) — Q/K streamed
    /// with quantization passes and row-granular access.
    pub mage_eff_gbps: f64,
    /// Effective DRAM bandwidth of the attention phase (GB/s) — the
    /// unstructured sparse S gathers cut deep into the HBM peak.
    pub atca_eff_gbps: f64,
    /// Chip power (W).
    pub power_w: f64,
    /// Pruning arithmetic precision speedup (4-bit ⇒ up to 16×).
    pub quant_speedup: f64,
    /// Control/reconfiguration time per scheduled score row (ns).
    pub ctrl_per_row_ns: f64,
}

/// SANGER [31]: prediction-based pruning + split-and-pack PEs.
pub struct Sanger(pub AsicParams);

impl Default for Sanger {
    fn default() -> Self {
        // Calibrated to the paper's measurements: 513 GOPS @ 22.4 GOPS/W,
        // MA-GE 17.9% of response time (94.6% memory), AT-CA 71.2% memory.
        Self(AsicParams {
            pe_gflops: 1850.0,
            mage_eff_gbps: 20.0,
            atca_eff_gbps: 6.6,
            power_w: 22.9,
            quant_speedup: 16.0,
            ctrl_per_row_ns: 180.0, // split-and-pack reconfiguration
        })
    }
}

/// DOTA [34]: weak-connection detector + lightweight scheduling.
pub struct Dota(pub AsicParams);

impl Default for Dota {
    fn default() -> Self {
        // Paper: MA-GE 14.3% (92.7% memory), AT-CA 63.5% memory.
        Self(AsicParams {
            pe_gflops: 2200.0,
            mage_eff_gbps: 24.0,
            atca_eff_gbps: 8.5,
            power_w: 24.0,
            quant_speedup: 16.0,
            ctrl_per_row_ns: 60.0, // cheaper scheduler than split-and-pack
        })
    }
}

/// Structural cost model shared by both ASICs.
pub(crate) fn asic_report(
    name: &'static str,
    p: &AsicParams,
    model: &ModelConfig,
    stats: &BatchStats,
) -> PlatformReport {
    let n = model.seq_len as f64;
    let d = model.d_model as f64;

    // ---- MA-GE: software pruning --------------------------------------------
    // Q and K fetched from DRAM, low-precision score computed, mask stored.
    let mage_bytes = (2.0 * n * d + n * n * 0.25 + 2.0 * d * d) * 4.0;
    let mage_mem = mage_bytes / p.mage_eff_gbps;
    // Low-precision prediction matmuls: Q·Kᵀ at quantized width,
    // plus the Q/K generation the paper counts against SANGER (VMM-N).
    let mage_flops = 2.0 * (n * d * d * 2.0 + n * n * d) / p.quant_speedup;
    let mage_proc = mage_flops / p.pe_gflops;

    // ---- AT-CA: sparse attention on the PE array -----------------------------
    let kept = stats.mask_density;
    // Useful flops: dense projections + masked score/context matmuls.
    let atca_flops = 2.0 * (2.0 * n * d * d + 2.0 * kept * n * n * d);
    let atca_proc = atca_flops / p.pe_gflops + n * p.ctrl_per_row_ns;
    // All operands round-trip DRAM (Q, K, V, dense-scored S streamed out
    // for packing + the packed sparse S back in with metadata, Z).
    let atca_bytes = (3.0 * n * d + n * n + 2.0 * kept * n * n * 1.5 + 2.0 * n * d) * 4.0;
    let atca_mem = atca_bytes / p.atca_eff_gbps;

    // Pruning runs *serially before* attention on both ASICs (the paper's
    // criticism); memory and compute within a phase overlap partially.
    let phase = |mem: f64, proc: f64| mem.max(proc) + 0.4 * mem.min(proc);
    let total_ns = phase(mage_mem, mage_proc) + phase(atca_mem, atca_proc);

    let gops = gops_from(model, total_ns);
    PlatformReport {
        name,
        total_ns,
        energy_pj: p.power_w * total_ns * 1000.0,
        gops,
        gops_per_watt: gops / p.power_w,
        wait_for_write_ns: 0.0,
        peak_parallel_arrays: 0,
        mage: (mage_mem, mage_proc),
        atca: (atca_mem, atca_proc),
    }
}

impl Platform for Sanger {
    fn name(&self) -> &'static str {
        "SANGER"
    }

    fn run_batch(&self, model: &ModelConfig, stats: &BatchStats) -> PlatformReport {
        asic_report(self.name(), &self.0, model, stats)
    }
}

impl Platform for Dota {
    fn name(&self) -> &'static str {
        "DOTA"
    }

    fn run_batch(&self, model: &ModelConfig, stats: &BatchStats) -> PlatformReport {
        asic_report(self.name(), &self.0, model, stats)
    }
}

/// SANGER pruning-phase detail for Fig. 16 (vs. CPSAA's PIM pruning).
pub struct SangerPruningDetail {
    pub pruning_ns: f64,
    pub vmm_ops: u64,
    pub ctrl_ns: f64,
}

impl Sanger {
    pub fn pruning_detail(&self, model: &ModelConfig) -> SangerPruningDetail {
        let n = model.seq_len as f64;
        let d = model.d_model as f64;
        let r = asic_report("SANGER", &self.0, model, &BatchStats {
            seq_len: model.seq_len,
            d_model: model.d_model,
            mask_nnz: 0,
            mask_density: 0.1,
        });
        // VMM operation count (Fig. 16 VMM-N): counted as *serial VMM
        // dispatch rounds*. SANGER's PE dataflow streams one score row per
        // round and must first generate Q and K row-by-row (3 passes over
        // the n rows); CPSAA's eq. 4 needs only its two in-memory matmuls,
        // whose dispatch rounds the pruning simulator reports.
        let _ = d;
        let vmm_ops = (3.0 * n) as u64;
        SangerPruningDetail {
            pruning_ns: r.mage.0 + r.mage.1,
            vmm_ops,
            ctrl_ns: n * self.0.ctrl_per_row_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(model: &ModelConfig, density: f64) -> BatchStats {
        BatchStats {
            seq_len: model.seq_len,
            d_model: model.d_model,
            mask_nnz: (density * (model.seq_len * model.seq_len) as f64) as usize,
            mask_density: density,
        }
    }

    #[test]
    fn sanger_near_paper_average() {
        let m = ModelConfig::paper();
        let r = Sanger::default().run_batch(&m, &stats(&m, 0.1));
        // Paper: 513 GOPS @ 22.4 GOPS/W.
        assert!(r.gops > 150.0 && r.gops < 1500.0, "gops {}", r.gops);
        assert!(r.gops_per_watt > 7.0 && r.gops_per_watt < 70.0, "gpw {}", r.gops_per_watt);
    }

    #[test]
    fn fig3_structure_sanger() {
        let m = ModelConfig::paper();
        let r = Sanger::default().run_batch(&m, &stats(&m, 0.1));
        let f = r.fig3_fractions();
        let mage = f[0] + f[1];
        // Paper: MA-GE ≈ 17.9%, memory-dominated (94.6%).
        assert!(mage > 0.05 && mage < 0.40, "MA-GE share {mage}");
        assert!(f[0] / mage > 0.7, "MA-GE memory share {}", f[0] / mage);
        // AT-CA memory share ≈ 71.2% (allow slack).
        let atca_mem_share = f[2] / (f[2] + f[3]);
        assert!(atca_mem_share > 0.35, "AT-CA mem share {atca_mem_share}");
    }

    #[test]
    fn dota_mage_share_smaller_than_sanger() {
        let m = ModelConfig::paper();
        let s = Sanger::default().run_batch(&m, &stats(&m, 0.1));
        let d = Dota::default().run_batch(&m, &stats(&m, 0.1));
        let share = |r: &PlatformReport| {
            let f = r.fig3_fractions();
            f[0] + f[1]
        };
        assert!(share(&d) < share(&s) + 0.02);
    }

    #[test]
    fn sanger_beats_gpu() {
        // Paper: SANGER ≈ 5.03× GPU.
        let m = ModelConfig::paper();
        let s = Sanger::default().run_batch(&m, &stats(&m, 0.1));
        let g = super::super::device::Gpu::default().run_batch(&m, &stats(&m, 0.1));
        let ratio = s.gops / g.gops;
        assert!(ratio > 1.5 && ratio < 20.0, "SANGER/GPU {ratio}");
    }

    #[test]
    fn pruning_detail_positive() {
        let d = Sanger::default().pruning_detail(&ModelConfig::paper());
        assert!(d.pruning_ns > 0.0 && d.vmm_ops > 0 && d.ctrl_ns > 0.0);
    }
}
