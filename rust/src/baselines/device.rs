//! GPU and FPGA baselines — von-Neumann platforms with off-chip weights.
//!
//! Both are roofline models: every operand round-trips DRAM, the
//! attention matmuls are bandwidth-bound at these shapes, and sparse
//! execution pays format-conversion overhead (the paper's cuSPARSE
//! discussion, §5). Constants are the §5 platform specs; the calibration
//! targets are the paper's measured averages (GPU ≈ 102 GOPS @ 0.63
//! GOPS/W, FPGA ≈ 284 GOPS @ 8.6 GOPS/W).

use crate::config::ModelConfig;
use crate::workload::BatchStats;

use super::{gops_from, Platform, PlatformReport};

/// NVIDIA TITAN RTX running BigBird-style sparse attention.
pub struct Gpu {
    /// DRAM bandwidth (GB/s) — 672 for TITAN RTX.
    pub dram_gbps: f64,
    /// Sustained FP32 throughput on attention-shaped GEMMs (GFLOPs).
    pub sustained_gflops: f64,
    /// Board power (W).
    pub tdp_w: f64,
    /// Kernel-launch + framework overhead per phase (ns).
    pub launch_ns: f64,
}

impl Default for Gpu {
    fn default() -> Self {
        Self {
            // Effective bandwidth for attention-shaped access: BigBird's
            // gather/scatter and short rows sustain ~10% of the 672 GB/s
            // peak.
            dram_gbps: 67.0,
            // TITAN RTX peaks at 16.3 TFLOPs FP32; attention-shaped GEMMs
            // at seq≈320 are occupancy/launch-bound and sustain ~1%
            // (calibrated to the paper's measured 102 GOPS average).
            sustained_gflops: 140.0,
            tdp_w: 280.0,
            launch_ns: 30_000.0,
        }
    }
}

impl Gpu {
    /// Bytes moved off-chip for one batch: X in; Q,K,V materialized;
    /// S (dense-scored then sparsified) out+in; Z out. BigBird's block
    /// pattern saves some S traffic proportional to density.
    fn bytes_moved(&self, model: &ModelConfig, stats: &BatchStats) -> f64 {
        let n = model.seq_len as f64;
        let d = model.d_model as f64;
        let dense_s = n * n * 4.0;
        let s_traffic = dense_s * (0.3 + stats.mask_density); // block pattern + metadata
        let qkv = 3.0 * n * d * 4.0;
        let x_z = 2.0 * n * d * 4.0;
        let weights = 2.0 * d * d * 4.0; // streamed per batch window
        x_z + qkv + 2.0 * s_traffic + weights
    }
}

impl Platform for Gpu {
    fn name(&self) -> &'static str {
        "GPU"
    }

    fn run_batch(&self, model: &ModelConfig, stats: &BatchStats) -> PlatformReport {
        let flops = model.attention_flops() as f64;
        let compute_ns = flops / self.sustained_gflops; // GFLOP/s == flop/ns
        let mem_ns = self.bytes_moved(model, stats) / self.dram_gbps;
        // Memory and compute partially overlap (CUDA streams): the longer
        // path dominates, the shorter contributes its non-overlapped 30%.
        let (long, short) = if mem_ns > compute_ns { (mem_ns, compute_ns) } else { (compute_ns, mem_ns) };
        let phase_ns = long + 0.3 * short;
        // Pruning (BigBird pattern construction) is host-side: one pass
        // over the score-shaped buffer plus launch overhead.
        let mage_mem = (model.seq_len * model.seq_len) as f64 * 4.0 / self.dram_gbps * 2.0;
        let mage_proc = self.launch_ns;
        let total_ns = phase_ns + mage_mem + mage_proc + 2.0 * self.launch_ns;
        let energy_pj = self.tdp_w * 0.6 * total_ns * 1000.0; // W×ns → pJ ×10³
        let gops = gops_from(model, total_ns);
        PlatformReport {
            name: self.name(),
            total_ns,
            energy_pj,
            gops,
            gops_per_watt: gops / (self.tdp_w * 0.6),
            wait_for_write_ns: 0.0,
            peak_parallel_arrays: 0,
            mage: (mage_mem, mage_proc),
            atca: (mem_ns, compute_ns),
        }
    }
}

/// FPGA accelerator of Zhang et al. [58] (structural pruning co-design).
pub struct Fpga {
    /// DSP-sustained GFLOPs.
    pub sustained_gflops: f64,
    /// Off-chip bandwidth (GB/s) — DDR4 on the eval board.
    pub dram_gbps: f64,
    /// Board power (W).
    pub power_w: f64,
}

impl Default for Fpga {
    fn default() -> Self {
        // Calibrated to [58]'s reported throughput class: ~284 GOPS at
        // ~33 W on a DDR4-attached mid-range part.
        Self { sustained_gflops: 190.0, dram_gbps: 19.2, power_w: 33.0 }
    }
}

impl Platform for Fpga {
    fn name(&self) -> &'static str {
        "FPGA"
    }

    fn run_batch(&self, model: &ModelConfig, stats: &BatchStats) -> PlatformReport {
        let n = model.seq_len as f64;
        let d = model.d_model as f64;
        // Static structured pruning ⇒ only the kept fraction computes, but
        // coarse granularity keeps ~3× the mask density.
        let kept = (3.0 * stats.mask_density).min(1.0);
        let flops = model.attention_flops() as f64 * (0.5 + 0.5 * kept);
        let compute_ns = flops / self.sustained_gflops;
        let bytes = (2.0 * n * d + 2.0 * d * d + kept * n * n) * 4.0;
        let mem_ns = bytes / self.dram_gbps;
        // Weights stay on-chip (BRAM) after the first tile: traffic and
        // compute pipeline tightly on FPGA dataflow designs.
        let phase_ns = compute_ns.max(mem_ns) + 0.15 * compute_ns.min(mem_ns);
        // Pruning is offline (static pattern): negligible MA-GE.
        let mage = (0.01 * phase_ns, 0.01 * phase_ns);
        let total_ns = phase_ns + mage.0 + mage.1;
        let gops = gops_from(model, total_ns);
        PlatformReport {
            name: self.name(),
            total_ns,
            energy_pj: self.power_w * total_ns * 1000.0,
            gops,
            gops_per_watt: gops / self.power_w,
            wait_for_write_ns: 0.0,
            peak_parallel_arrays: 0,
            mage,
            atca: (mem_ns, compute_ns),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(model: &ModelConfig, density: f64) -> BatchStats {
        BatchStats {
            seq_len: model.seq_len,
            d_model: model.d_model,
            mask_nnz: (density * (model.seq_len * model.seq_len) as f64) as usize,
            mask_density: density,
        }
    }

    #[test]
    fn gpu_near_paper_average() {
        let m = ModelConfig::paper();
        let r = Gpu::default().run_batch(&m, &stats(&m, 0.1));
        // Paper: 102 GOPS, 0.63 GOPS/W — same order of magnitude.
        assert!(r.gops > 30.0 && r.gops < 400.0, "gops {}", r.gops);
        assert!(r.gops_per_watt > 0.1 && r.gops_per_watt < 3.0, "gpw {}", r.gops_per_watt);
    }

    #[test]
    fn gpu_launch_and_compute_bound_at_short_sequences() {
        // seq≈320 attention on a GPU is occupancy/launch bound, not
        // bandwidth bound — that is exactly why its useful-op rate is two
        // orders below peak.
        let m = ModelConfig::paper();
        let r = Gpu::default().run_batch(&m, &stats(&m, 0.1));
        let (mem, proc) = r.atca;
        assert!(proc > mem, "compute path should dominate: {proc} vs {mem}");
    }

    #[test]
    fn fpga_near_paper_average() {
        let m = ModelConfig::paper();
        let r = Fpga::default().run_batch(&m, &stats(&m, 0.1));
        // Paper: 284 GOPS, 8.6 GOPS/W.
        assert!(r.gops > 80.0 && r.gops < 900.0, "gops {}", r.gops);
        assert!(r.gops_per_watt > 2.0 && r.gops_per_watt < 30.0, "gpw {}", r.gops_per_watt);
    }

    #[test]
    fn fpga_beats_gpu_in_efficiency() {
        let m = ModelConfig::paper();
        let g = Gpu::default().run_batch(&m, &stats(&m, 0.1));
        let f = Fpga::default().run_batch(&m, &stats(&m, 0.1));
        assert!(f.gops_per_watt > g.gops_per_watt);
    }

    #[test]
    fn gpu_memory_time_nonzero() {
        let m = ModelConfig::paper();
        let r = Gpu::default().run_batch(&m, &stats(&m, 0.1));
        let (mem, proc) = r.atca;
        assert!(mem > 0.0 && proc > 0.0);
    }

    #[test]
    fn denser_masks_slower() {
        let m = ModelConfig::paper();
        let lo = Gpu::default().run_batch(&m, &stats(&m, 0.05));
        let hi = Gpu::default().run_batch(&m, &stats(&m, 0.5));
        assert!(hi.total_ns > lo.total_ns);
    }
}
