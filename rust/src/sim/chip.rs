//! Chip-level simulation: batches → traces → GOPS / GOPS/W.

use crate::attention::Precision;
use crate::config::{HardwareConfig, ModelConfig};
use crate::sparse::{DispatchPlan, MaskMatrix, PlanSet, ShardedPlans};
use crate::workload::WorkloadTrace;

use super::area::AreaModel;
use super::pipeline::{self, Mode, PhaseBreakdown, PipelineReport, StageEvent};
use super::recam::RecamScheduler;

/// Cost of evolving a batch's plans between encoder layers, both ways
/// the hardware could do it: the cascade's O(nnz) coordinate-stream
/// narrowing vs the full ReCAM re-scan it replaces (re-program
/// rows×cols mask cells, then the row search). Narrowing touches only
/// the live coordinates — nnz ≪ rows×cols at serving densities, and it
/// skips the mask write entirely — which is the whole perf argument for
/// the cascade path.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanEvolutionCost {
    /// Narrowing latency (ns): stream the previous plan's coordinates
    /// through the ReCAM search logic, `recam_size` at a time. Max over
    /// heads (head slices filter concurrently).
    pub narrow_ns: f64,
    /// Narrowing energy (pJ), summed over heads.
    pub narrow_pj: f64,
    /// Re-scan latency (ns): mask re-program + row search. Max over
    /// heads.
    pub rescan_ns: f64,
    /// Re-scan energy (pJ), summed over heads.
    pub rescan_pj: f64,
}

/// Cost of one batch's pruning-stage ReCAM scan when the serving layer
/// prefetches it behind the previous batch's compute (CPSAA §3
/// overlapped mode). Instead of charging `scan + compute` serially, the
/// pipeline charges `max(scan, prior compute remainder)` — i.e. the
/// prior compute plus only the scan's *exposed* tail.
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapCost {
    /// Full pruning-stage scan latency (ns): mask program + row search,
    /// max over heads (head slices scan concurrently).
    pub scan_ns: f64,
    /// The part of the scan hidden behind the prior batch's compute
    /// (ns): `min(scan_ns, prior_compute_ns)`.
    pub hidden_ns: f64,
    /// The part still exposed past the prior compute (ns):
    /// `scan_ns - hidden_ns`.
    pub exposed_ns: f64,
}

/// One batch's simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub breakdown: PhaseBreakdown,
    pub energy_pj: f64,
    pub mask_density: f64,
    /// Dense-equivalent throughput over this batch (GOPS).
    pub gops: f64,
    /// Energy efficiency (GOPS/W) using dynamic energy + static power.
    pub gops_per_watt: f64,
    /// The Step 1–4 stage timeline behind the breakdown, start order.
    pub events: Vec<StageEvent>,
}

/// One labeled stage timeline of a simulated batch: the events of one
/// head's chip slice (and, under sharding, of one (shard, head) chip
/// slice). The `--trace` dump is a list of these per batch.
#[derive(Clone, Debug)]
pub struct SimTrace {
    /// Head index the timeline belongs to.
    pub head: usize,
    /// Shard (logical chip) index; `None` under unsharded serving.
    pub shard: Option<usize>,
    pub events: Vec<StageEvent>,
}

/// Multi-head cost attribution of one batch over a shared [`PlanSet`]
/// (§4.5): each head runs on a disjoint `tiles/heads` slice of the chip,
/// so wall time is the slowest head and energy is the sum over heads.
#[derive(Clone, Debug)]
pub struct HeadsSimReport {
    /// One per-slice report per head, head order.
    pub heads: Vec<SimReport>,
    /// Wall-clock of the batch: max over heads (heads run concurrently).
    pub total_ns: f64,
    /// Energy of the batch: sum over heads.
    pub energy_pj: f64,
    /// Mean mask density across heads.
    pub mean_density: f64,
}

/// Multi-chip cost attribution of one *sharded* batch: shard `s` runs
/// its sliced [`PlanSet`] on its own full chip (heads inside still on
/// `tiles/heads` slices). Chips process their row slices concurrently,
/// so batch wall time is the slowest shard and energy sums over shards
/// — the same max/sum law the head fan-out uses, one level up.
#[derive(Clone, Debug)]
pub struct ShardedSimReport {
    /// One multi-head report per shard, shard order.
    pub shards: Vec<HeadsSimReport>,
    /// Wall-clock of the batch: max over shards.
    pub total_ns: f64,
    /// Energy of the batch: sum over shards.
    pub energy_pj: f64,
}

impl HeadsSimReport {
    /// Latency of the quickest head slice (ns). A plain `f64::min` fold
    /// over an empty head list would return `f64::INFINITY` and poison
    /// any metric line it lands in; the degenerate case reports 0.0,
    /// matching the zeroed report [`aggregate_heads`] builds for it.
    pub fn fastest_head_ns(&self) -> f64 {
        if self.heads.is_empty() {
            return 0.0;
        }
        self.heads.iter().map(|h| h.breakdown.total_ns).fold(f64::INFINITY, f64::min)
    }

    /// One labeled stage timeline per head, head order.
    pub fn traces(&self) -> Vec<SimTrace> {
        self.heads
            .iter()
            .enumerate()
            .map(|(h, r)| SimTrace { head: h, shard: None, events: r.events.clone() })
            .collect()
    }
}

impl ShardedSimReport {
    /// Head `h`'s latency across the batch: max over shards (chips run
    /// concurrently, each hosting its slice of head `h`).
    pub fn head_ns(&self, h: usize) -> f64 {
        self.shards.iter().map(|s| s.heads[h].breakdown.total_ns).fold(0.0, f64::max)
    }

    /// Head `h`'s energy across the batch: sum over shards.
    pub fn head_pj(&self, h: usize) -> f64 {
        self.shards.iter().map(|s| s.heads[h].energy_pj).sum()
    }

    /// One labeled stage timeline per (shard, head) chip slice.
    pub fn traces(&self) -> Vec<SimTrace> {
        let mut out = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            for (h, r) in shard.heads.iter().enumerate() {
                out.push(SimTrace { head: h, shard: Some(s), events: r.events.clone() });
            }
        }
        out
    }
}

/// Fold per-head slice reports into the batch view: max-ns, sum-pJ.
/// An empty head list (a degenerate plan set) folds to an explicitly
/// zeroed report — never `INFINITY`/`NaN` from empty min/mean folds.
fn aggregate_heads(reports: Vec<SimReport>) -> HeadsSimReport {
    if reports.is_empty() {
        return HeadsSimReport {
            heads: Vec::new(),
            total_ns: 0.0,
            energy_pj: 0.0,
            mean_density: 0.0,
        };
    }
    let total_ns = reports.iter().map(|r| r.breakdown.total_ns).fold(0.0, f64::max);
    let energy_pj: f64 = reports.iter().map(|r| r.energy_pj).sum();
    let mean_density =
        reports.iter().map(|r| r.mask_density).sum::<f64>() / reports.len() as f64;
    HeadsSimReport { heads: reports, total_ns, energy_pj, mean_density }
}

/// Aggregate over a whole dataset trace.
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub dataset: String,
    pub batches: usize,
    pub total_ns: f64,
    pub total_energy_pj: f64,
    pub mean_gops: f64,
    pub mean_gops_per_watt: f64,
    pub mean_density: f64,
    pub breakdown: PhaseBreakdown,
}

/// The CPSAA chip simulator.
#[derive(Clone, Debug)]
pub struct ChipSim {
    pub hw: HardwareConfig,
    pub model: ModelConfig,
    pub mode: Mode,
    precision: Precision,
    area: AreaModel,
}

impl ChipSim {
    pub fn new(hw: HardwareConfig, model: ModelConfig) -> Self {
        let area = AreaModel::build(&hw);
        Self { hw, model, mode: Mode::Sparse, precision: Precision::F32, area }
    }

    pub fn dense(mut self) -> Self {
        self.mode = Mode::Dense;
        self
    }

    /// Cost the SDDMM score pass at `precision` (`I8` halves the Step-3
    /// bit-serial crossbar work; see
    /// [`pipeline::simulate_batch_planned_prec`]).
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn area(&self) -> &AreaModel {
        &self.area
    }

    /// Simulate a single batch with the given pruning mask.
    pub fn simulate_batch(&self, mask: &MaskMatrix) -> SimReport {
        let r: PipelineReport =
            pipeline::simulate_batch_prec(&self.hw, &self.model, mask, self.mode, self.precision);
        self.report_from(r)
    }

    /// Simulate a single batch over a prebuilt [`DispatchPlan`] — the
    /// coordinator's reuse path (one plan per packed batch, shared across
    /// every encoder layer). The plan must describe the mode's effective
    /// mask (for [`Mode::Dense`] that is the all-ones mask).
    pub fn simulate_batch_planned(&self, plan: &DispatchPlan) -> SimReport {
        let r = pipeline::simulate_batch_planned_prec(
            &self.hw,
            &self.model,
            plan,
            self.mode,
            self.precision,
        );
        self.report_from(r)
    }

    /// Simulate one batch with multi-head fan-out over a shared
    /// [`PlanSet`]: each head's plan is charged against a `tiles/heads`
    /// chip slice; wall time is max-over-heads, energy sum-over-heads
    /// (matching `sim::application`'s head accounting). One head over
    /// the full chip degenerates to [`ChipSim::simulate_batch_planned`].
    pub fn simulate_heads_planned(&self, plans: &PlanSet) -> HeadsSimReport {
        let head_sim = self.head_slice_sim(plans.heads());
        let reports: Vec<SimReport> =
            plans.plans().iter().map(|p| head_sim.simulate_batch_planned(p)).collect();
        aggregate_heads(reports)
    }

    /// [`ChipSim::simulate_heads_planned`] for `heads` heads that all
    /// share one plan (e.g. the application sim replicating a layer
    /// mask): the simulation is a pure function of the plan, so the
    /// `tiles/heads` slice is simulated once and the report replicated.
    pub fn simulate_heads_shared(&self, plan: &DispatchPlan, heads: usize) -> HeadsSimReport {
        let heads = heads.max(1);
        let head_sim = self.head_slice_sim(heads);
        aggregate_heads(vec![head_sim.simulate_batch_planned(plan); heads])
    }

    /// Simulate one sharded batch across K logical chips: each shard's
    /// sliced plan set is charged against a full chip of this
    /// configuration via [`ChipSim::simulate_heads_planned`]; the batch
    /// is then max-ns over shards (concurrent chips) and sum-pJ. One
    /// shard degenerates to `simulate_heads_planned` exactly (a
    /// full-range slice reproduces the plan set).
    ///
    /// Cost semantics mirror the functional fan-out: every chip ingests
    /// the *full* batch (keys/values replicate, so transfer-in, the
    /// Step-2 VMMs, and the Xᵀ/V writes are charged per chip at batch
    /// size), while the plan-driven engines — pruning dispatch, the
    /// SDDMM column queues, the SpMM replication — shrink to the
    /// shard's row slice. Sharding therefore accelerates the sparse
    /// attention engines and pays a replicated-preprocessing floor, the
    /// honest scale-out trade.
    pub fn simulate_sharded(&self, shards: &ShardedPlans) -> ShardedSimReport {
        let reports: Vec<HeadsSimReport> =
            shards.sets().iter().map(|s| self.simulate_heads_planned(s)).collect();
        let total_ns = reports.iter().map(|r| r.total_ns).fold(0.0, f64::max);
        let energy_pj = reports.iter().map(|r| r.energy_pj).sum();
        ShardedSimReport { shards: reports, total_ns, energy_pj }
    }

    /// Cost one cascade step over `prev` (the plans being narrowed):
    /// what the narrowing filter costs vs the full per-layer ReCAM
    /// re-scan the static path would pay. Heads evolve concurrently on
    /// their slices (max-ns), energy sums — the same law as every other
    /// head fan-out.
    pub fn plan_evolution_cost(&self, prev: &PlanSet) -> PlanEvolutionCost {
        let hw = &self.hw;
        let mut cost = PlanEvolutionCost::default();
        for p in prev.plans() {
            // Narrow: the live coordinate stream passes through the
            // ReCAM search logic recam_size entries per clock.
            let chunks = p.nnz().div_ceil(hw.recam_size.max(1)) as f64;
            let narrow_ns = chunks * hw.recam_search_ns;
            let narrow_pj = chunks * hw.recam_pj_per_row;
            // Re-scan: re-program the full mask, then the row search.
            let s = RecamScheduler::new(p);
            let pass = s.row_search(hw);
            let rescan_ns = s.program_ns(hw) + pass.search_ns;
            let rescan_pj = pass.search_pj;
            cost.narrow_ns = cost.narrow_ns.max(narrow_ns);
            cost.narrow_pj += narrow_pj;
            cost.rescan_ns = cost.rescan_ns.max(rescan_ns);
            cost.rescan_pj += rescan_pj;
        }
        cost
    }

    /// Cost one batch's pruning-stage scan against the compute still
    /// running from the previous batch: how much of the scan hides
    /// behind `prior_compute_ns` and how much stays exposed. With no
    /// prior compute (pipeline cold, first batch) nothing hides and the
    /// full scan is exposed — the serial charge.
    pub fn scan_overlap_cost(&self, plans: &PlanSet, prior_compute_ns: f64) -> OverlapCost {
        let hw = &self.hw;
        let mut scan_ns = 0.0f64;
        for p in plans.plans() {
            let s = RecamScheduler::new(p);
            scan_ns = scan_ns.max(s.program_ns(hw) + s.row_search(hw).search_ns);
        }
        let hidden_ns = scan_ns.min(prior_compute_ns.max(0.0));
        OverlapCost { scan_ns, hidden_ns, exposed_ns: scan_ns - hidden_ns }
    }

    /// A simulator for one head's `tiles/heads` chip slice.
    fn head_slice_sim(&self, heads: usize) -> ChipSim {
        let head_hw =
            HardwareConfig { tiles: (self.hw.tiles / heads.max(1)).max(1), ..self.hw.clone() };
        let mut head_sim = ChipSim::new(head_hw, self.model.clone());
        head_sim.mode = self.mode;
        head_sim.precision = self.precision;
        head_sim
    }

    fn report_from(&self, r: PipelineReport) -> SimReport {
        let flops = self.model.attention_flops() as f64;
        let seconds = r.breakdown.total_ns * 1e-9;
        let gops = flops / 1e9 / seconds.max(1e-12);
        // Power: dynamic energy over the window plus a static share of the
        // chip budget (clock, buffers — 10% of TDP, matching the ISAAC
        // accounting the paper inherits).
        let dynamic_w = r.energy.total_pj() * 1e-12 / seconds.max(1e-12);
        let static_w = self.area.chip_power_w() * 0.10;
        let watts = dynamic_w + static_w;
        SimReport {
            breakdown: r.breakdown,
            energy_pj: r.energy.total_pj(),
            mask_density: r.mask_density,
            gops,
            gops_per_watt: gops / watts.max(1e-9),
            events: r.events,
        }
    }

    /// Simulate a whole trace: batches run serially (§5 — embeddings in
    /// different batches are processed in serial).
    pub fn simulate_trace(&self, trace: &WorkloadTrace) -> TraceReport {
        let mut total_ns = 0.0;
        let mut total_pj = 0.0;
        let mut gops = 0.0;
        let mut gpw = 0.0;
        let mut density = 0.0;
        let mut agg = PhaseBreakdown::default();
        for batch in &trace.batches {
            let r = self.simulate_batch(&batch.mask);
            total_ns += r.breakdown.total_ns;
            total_pj += r.energy_pj;
            gops += r.gops;
            gpw += r.gops_per_watt;
            density += r.mask_density;
            agg.prune_ns += r.breakdown.prune_ns;
            agg.step2_ns += r.breakdown.step2_ns;
            agg.step3_ns += r.breakdown.step3_ns;
            agg.softmax_ns += r.breakdown.softmax_ns;
            agg.step4_ns += r.breakdown.step4_ns;
            agg.wait_for_write_ns += r.breakdown.wait_for_write_ns;
            agg.transfer_ns += r.breakdown.transfer_ns;
            agg.ctrl_ns += r.breakdown.ctrl_ns;
            agg.total_ns += r.breakdown.total_ns;
            agg.peak_parallel_arrays = agg.peak_parallel_arrays.max(r.breakdown.peak_parallel_arrays);
        }
        let n = trace.batches.len().max(1) as f64;
        TraceReport {
            dataset: trace.dataset.clone(),
            batches: trace.batches.len(),
            total_ns,
            total_energy_pj: total_pj,
            mean_gops: gops / n,
            mean_gops_per_watt: gpw / n,
            mean_density: density / n,
            breakdown: agg,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::tensor::SeededRng;
    use crate::workload::TraceGenerator;

    fn sim() -> ChipSim {
        ChipSim::new(HardwareConfig::paper(), ModelConfig::paper())
    }

    fn mask(density: f64) -> MaskMatrix {
        MaskMatrix::from_dense(&SeededRng::new(1).mask_matrix(320, 320, density))
    }

    #[test]
    fn gops_in_plausible_range() {
        // Paper: CPSAA ≈ 9142 GOPS average. Expect same order of magnitude.
        let r = sim().simulate_batch(&mask(0.1));
        assert!(r.gops > 1000.0 && r.gops < 100_000.0, "gops {}", r.gops);
    }

    #[test]
    fn gops_per_watt_in_plausible_range() {
        // Paper: 476 GOPS/W.
        let r = sim().simulate_batch(&mask(0.1));
        assert!(r.gops_per_watt > 20.0 && r.gops_per_watt < 10_000.0, "gpw {}", r.gops_per_watt);
    }

    #[test]
    fn dense_mode_slower_lower_gops() {
        let s = sim().simulate_batch(&mask(0.1));
        let d = sim().dense().simulate_batch(&mask(0.1));
        assert!(d.gops < s.gops);
    }

    #[test]
    fn heads_report_is_max_ns_sum_pj() {
        let mut rng = SeededRng::new(4);
        let masks: Vec<MaskMatrix> = (0..4)
            .map(|h| MaskMatrix::from_dense(&rng.mask_matrix(320, 320, 0.05 + 0.1 * h as f64)))
            .collect();
        let plans = PlanSet::build(&masks);
        let r = sim().simulate_heads_planned(&plans);
        assert_eq!(r.heads.len(), 4);
        let max_ns = r.heads.iter().map(|h| h.breakdown.total_ns).fold(0.0, f64::max);
        let sum_pj: f64 = r.heads.iter().map(|h| h.energy_pj).sum();
        assert_eq!(r.total_ns, max_ns, "wall time is the slowest head");
        assert!((r.energy_pj - sum_pj).abs() < 1e-6, "energy sums over heads");
        // distinct densities ⇒ per-head costs genuinely differ
        let fastest = r.fastest_head_ns();
        assert!(fastest.is_finite() && fastest > 0.0);
        assert!(max_ns > fastest, "heads with different masks cost differently");
    }

    #[test]
    fn empty_head_list_folds_to_zeroed_report() {
        // Degenerate plan set: the report must come back zeroed and
        // finite, not poisoned by empty-fold identities (min → +inf,
        // mean → NaN) leaking into metric lines.
        let r = aggregate_heads(Vec::new());
        assert!(r.heads.is_empty());
        assert_eq!(r.total_ns, 0.0);
        assert_eq!(r.energy_pj, 0.0);
        assert_eq!(r.mean_density, 0.0);
        assert_eq!(r.fastest_head_ns(), 0.0);
        assert!(
            r.total_ns.is_finite() && r.mean_density.is_finite() && r.fastest_head_ns().is_finite()
        );
        assert!(r.traces().is_empty());
    }

    #[test]
    fn sim_reports_carry_stage_events() {
        let r = sim().simulate_batch(&mask(0.1));
        assert!(!r.events.is_empty());
        assert_eq!(r.events.last().unwrap().end_ns, r.breakdown.total_ns);
        // Head fan-out: one timeline per head, labeled in head order.
        let plans = PlanSet::from_plans(vec![mask(0.1).plan(); 3]);
        let hs = sim().simulate_heads_planned(&plans);
        let traces = hs.traces();
        assert_eq!(traces.len(), 3);
        for (h, t) in traces.iter().enumerate() {
            assert_eq!(t.head, h);
            assert_eq!(t.shard, None);
            assert!(!t.events.is_empty());
        }
        // Sharded fan-out: one timeline per (shard, head).
        let sharded = sim().simulate_sharded(&plans.shard(2));
        let st = sharded.traces();
        assert_eq!(st.len(), sharded.shards.len() * 3);
        assert!(st.iter().all(|t| t.shard.is_some()));
    }

    #[test]
    fn one_head_set_matches_planned_batch() {
        let m = mask(0.1);
        let plan = m.plan();
        let single = sim().simulate_batch_planned(&plan);
        let set = sim().simulate_heads_planned(&PlanSet::single(plan));
        assert_eq!(set.heads.len(), 1);
        assert_eq!(set.total_ns, single.breakdown.total_ns);
        assert_eq!(set.energy_pj, single.energy_pj);
    }

    #[test]
    fn shared_plan_heads_match_replicated_set() {
        let plan = mask(0.1).plan();
        let a = sim().simulate_heads_shared(&plan, 4);
        let b = sim().simulate_heads_planned(&PlanSet::from_plans(vec![plan; 4]));
        assert_eq!(a.heads.len(), 4);
        assert_eq!(a.total_ns, b.total_ns);
        assert_eq!(a.energy_pj, b.energy_pj);
        assert_eq!(a.mean_density, b.mean_density);
    }

    #[test]
    fn sharded_report_is_max_ns_sum_pj_over_shards() {
        let mut rng = SeededRng::new(7);
        let masks: Vec<MaskMatrix> = (0..4)
            .map(|h| MaskMatrix::from_dense(&rng.mask_matrix(320, 320, 0.05 + 0.1 * h as f64)))
            .collect();
        let plans = PlanSet::build(&masks);
        let sharded = plans.shard(4);
        let r = sim().simulate_sharded(&sharded);
        assert_eq!(r.shards.len(), sharded.count());
        let max_ns = r.shards.iter().map(|s| s.total_ns).fold(0.0, f64::max);
        let sum_pj: f64 = r.shards.iter().map(|s| s.energy_pj).sum();
        assert_eq!(r.total_ns, max_ns, "wall time is the slowest chip");
        assert!((r.energy_pj - sum_pj).abs() < 1e-6, "energy sums over chips");
        // Per-head roll-ups agree with the shard-level aggregates.
        let head_max = (0..4).map(|h| r.head_ns(h)).fold(0.0, f64::max);
        assert_eq!(r.total_ns, head_max, "max over (shard, head) both ways");
        let head_pj: f64 = (0..4).map(|h| r.head_pj(h)).sum();
        assert!((r.energy_pj - head_pj).abs() < 1e-6 * r.energy_pj.max(1.0));
    }

    #[test]
    fn one_shard_degenerates_to_heads_report() {
        let mut rng = SeededRng::new(8);
        let masks: Vec<MaskMatrix> =
            (0..2).map(|_| MaskMatrix::from_dense(&rng.mask_matrix(320, 320, 0.1))).collect();
        let plans = PlanSet::build(&masks);
        let single = sim().simulate_heads_planned(&plans);
        let sharded = sim().simulate_sharded(&plans.shard(1));
        assert_eq!(sharded.shards.len(), 1);
        assert_eq!(sharded.total_ns, single.total_ns);
        assert_eq!(sharded.energy_pj, single.energy_pj);
    }

    #[test]
    fn four_chips_beat_one_on_a_balanced_batch() {
        // Batch parallelism must show: each chip sees ~1/4 of the rows
        // and coordinates, so the slowest shard finishes well before
        // the single-chip batch.
        let plans = PlanSet::single(mask(0.1).plan());
        let one = sim().simulate_sharded(&plans.shard(1));
        let four = sim().simulate_sharded(&plans.shard(4));
        assert_eq!(four.shards.len(), 4);
        assert!(
            four.total_ns < one.total_ns,
            "4 chips {} >= 1 chip {}",
            four.total_ns,
            one.total_ns
        );
    }

    #[test]
    fn i8_precision_cheapens_sim_including_head_slices() {
        let m = mask(0.1);
        let f = sim().simulate_batch(&m);
        let q = sim().with_precision(Precision::I8).simulate_batch(&m);
        assert!(q.breakdown.total_ns <= f.breakdown.total_ns);
        assert!(q.energy_pj < f.energy_pj, "i8 {} vs f32 {}", q.energy_pj, f.energy_pj);
        // head_slice_sim must carry the precision down to per-head
        // slices, or multi-head i8 serving silently costs f32.
        let plans = PlanSet::from_plans(vec![m.plan(); 4]);
        let fh = sim().simulate_heads_planned(&plans);
        let qh = sim().with_precision(Precision::I8).simulate_heads_planned(&plans);
        assert_eq!(sim().with_precision(Precision::I8).precision(), Precision::I8);
        assert!(qh.total_ns <= fh.total_ns);
        assert!(qh.energy_pj < fh.energy_pj, "head slices lost the precision knob");
    }

    #[test]
    fn narrowing_undercuts_rescan_at_serving_density() {
        // The cascade's bargain: filtering the live coordinate stream
        // must be much cheaper than re-programming and re-searching the
        // full mask (nnz ≪ rows×cols at paper density 0.1).
        let plans = PlanSet::from_plans(vec![mask(0.1).plan(); 4]);
        let c = sim().plan_evolution_cost(&plans);
        assert!(c.narrow_ns > 0.0 && c.rescan_ns > 0.0);
        assert!(
            c.narrow_ns < c.rescan_ns / 4.0,
            "narrow {} vs rescan {}",
            c.narrow_ns,
            c.rescan_ns
        );
        assert!(c.narrow_pj < c.rescan_pj, "narrow {} vs rescan {}", c.narrow_pj, c.rescan_pj);
        // Fewer coordinates ⇒ cheaper narrowing; the rescan floor is a
        // function of mask shape, not occupancy.
        let sparser = PlanSet::from_plans(vec![mask(0.01).plan(); 4]);
        let cs = sim().plan_evolution_cost(&sparser);
        assert!(cs.narrow_ns <= c.narrow_ns);
        assert_eq!(cs.rescan_ns, c.rescan_ns);
    }

    #[test]
    fn scan_overlap_splits_hidden_and_exposed() {
        let plans = PlanSet::from_plans(vec![mask(0.1).plan(); 4]);
        // Cold pipeline: nothing to hide behind — the serial charge.
        let cold = sim().scan_overlap_cost(&plans, 0.0);
        assert!(cold.scan_ns > 0.0);
        assert_eq!(cold.hidden_ns, 0.0);
        assert_eq!(cold.exposed_ns, cold.scan_ns);
        // Prior compute longer than the scan hides it entirely.
        let deep = sim().scan_overlap_cost(&plans, cold.scan_ns * 10.0);
        assert_eq!(deep.scan_ns, cold.scan_ns);
        assert_eq!(deep.hidden_ns, deep.scan_ns);
        assert_eq!(deep.exposed_ns, 0.0);
        // Partial overlap: hidden + exposed always reassemble the scan,
        // and the exposed tail is exactly what outlives the compute.
        let part = sim().scan_overlap_cost(&plans, cold.scan_ns * 0.25);
        assert_eq!(part.hidden_ns, cold.scan_ns * 0.25);
        assert!((part.hidden_ns + part.exposed_ns - part.scan_ns).abs() < 1e-9);
        // The full scan matches what plan_evolution_cost charges for a
        // rescan — same program + row-search arm, max over heads.
        let evo = sim().plan_evolution_cost(&plans);
        assert_eq!(cold.scan_ns, evo.rescan_ns);
    }

    #[test]
    fn trace_aggregates() {
        let gen = TraceGenerator::new(ModelConfig::paper(), 0).with_max_batches(2);
        let w = WorkloadConfig::paper();
        let trace = gen.generate(w.dataset("MRPC").unwrap());
        let r = sim().simulate_trace(&trace);
        assert_eq!(r.batches, 2);
        assert!(r.total_ns > 0.0 && r.mean_gops > 0.0);
    }

    #[test]
    fn throughput_stable_across_trace_size() {
        // Fig. 20a: GOPS stays stable as dataset size grows (serial batches).
        let w = WorkloadConfig::paper();
        let gen1 = TraceGenerator::new(ModelConfig::paper(), 0).with_max_batches(1);
        let gen4 = TraceGenerator::new(ModelConfig::paper(), 0).with_max_batches(4);
        let small = sim().simulate_trace(&gen1.generate(w.dataset("QQP").unwrap()));
        let large = sim().simulate_trace(&gen4.generate(w.dataset("QQP").unwrap()));
        let ratio = large.mean_gops / small.mean_gops;
        assert!(ratio > 0.7 && ratio < 1.3, "ratio {ratio}");
    }
}
