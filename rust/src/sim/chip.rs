//! Chip-level simulation: batches → traces → GOPS / GOPS/W.

use crate::config::{HardwareConfig, ModelConfig};
use crate::sparse::{DispatchPlan, MaskMatrix};
use crate::workload::WorkloadTrace;

use super::area::AreaModel;
use super::pipeline::{self, Mode, PhaseBreakdown, PipelineReport};

/// One batch's simulation outcome.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub breakdown: PhaseBreakdown,
    pub energy_pj: f64,
    pub mask_density: f64,
    /// Dense-equivalent throughput over this batch (GOPS).
    pub gops: f64,
    /// Energy efficiency (GOPS/W) using dynamic energy + static power.
    pub gops_per_watt: f64,
}

/// Aggregate over a whole dataset trace.
#[derive(Clone, Debug)]
pub struct TraceReport {
    pub dataset: String,
    pub batches: usize,
    pub total_ns: f64,
    pub total_energy_pj: f64,
    pub mean_gops: f64,
    pub mean_gops_per_watt: f64,
    pub mean_density: f64,
    pub breakdown: PhaseBreakdown,
}

/// The CPSAA chip simulator.
#[derive(Clone, Debug)]
pub struct ChipSim {
    pub hw: HardwareConfig,
    pub model: ModelConfig,
    pub mode: Mode,
    area: AreaModel,
}

impl ChipSim {
    pub fn new(hw: HardwareConfig, model: ModelConfig) -> Self {
        let area = AreaModel::build(&hw);
        Self { hw, model, mode: Mode::Sparse, area }
    }

    pub fn dense(mut self) -> Self {
        self.mode = Mode::Dense;
        self
    }

    pub fn area(&self) -> &AreaModel {
        &self.area
    }

    /// Simulate a single batch with the given pruning mask.
    pub fn simulate_batch(&self, mask: &MaskMatrix) -> SimReport {
        let r: PipelineReport = pipeline::simulate_batch(&self.hw, &self.model, mask, self.mode);
        self.report_from(r)
    }

    /// Simulate a single batch over a prebuilt [`DispatchPlan`] — the
    /// coordinator's reuse path (one plan per packed batch, shared across
    /// every encoder layer). The plan must describe the mode's effective
    /// mask (for [`Mode::Dense`] that is the all-ones mask).
    pub fn simulate_batch_planned(&self, plan: &DispatchPlan) -> SimReport {
        let r = pipeline::simulate_batch_planned(&self.hw, &self.model, plan, self.mode);
        self.report_from(r)
    }

    fn report_from(&self, r: PipelineReport) -> SimReport {
        let flops = self.model.attention_flops() as f64;
        let seconds = r.breakdown.total_ns * 1e-9;
        let gops = flops / 1e9 / seconds.max(1e-12);
        // Power: dynamic energy over the window plus a static share of the
        // chip budget (clock, buffers — 10% of TDP, matching the ISAAC
        // accounting the paper inherits).
        let dynamic_w = r.energy.total_pj() * 1e-12 / seconds.max(1e-12);
        let static_w = self.area.chip_power_w() * 0.10;
        let watts = dynamic_w + static_w;
        SimReport {
            breakdown: r.breakdown,
            energy_pj: r.energy.total_pj(),
            mask_density: r.mask_density,
            gops,
            gops_per_watt: gops / watts.max(1e-9),
        }
    }

    /// Simulate a whole trace: batches run serially (§5 — embeddings in
    /// different batches are processed in serial).
    pub fn simulate_trace(&self, trace: &WorkloadTrace) -> TraceReport {
        let mut total_ns = 0.0;
        let mut total_pj = 0.0;
        let mut gops = 0.0;
        let mut gpw = 0.0;
        let mut density = 0.0;
        let mut agg = PhaseBreakdown::default();
        for batch in &trace.batches {
            let r = self.simulate_batch(&batch.mask);
            total_ns += r.breakdown.total_ns;
            total_pj += r.energy_pj;
            gops += r.gops;
            gpw += r.gops_per_watt;
            density += r.mask_density;
            agg.prune_ns += r.breakdown.prune_ns;
            agg.step2_ns += r.breakdown.step2_ns;
            agg.step3_ns += r.breakdown.step3_ns;
            agg.softmax_ns += r.breakdown.softmax_ns;
            agg.step4_ns += r.breakdown.step4_ns;
            agg.wait_for_write_ns += r.breakdown.wait_for_write_ns;
            agg.transfer_ns += r.breakdown.transfer_ns;
            agg.ctrl_ns += r.breakdown.ctrl_ns;
            agg.total_ns += r.breakdown.total_ns;
            agg.peak_parallel_arrays = agg.peak_parallel_arrays.max(r.breakdown.peak_parallel_arrays);
        }
        let n = trace.batches.len().max(1) as f64;
        TraceReport {
            dataset: trace.dataset.clone(),
            batches: trace.batches.len(),
            total_ns,
            total_energy_pj: total_pj,
            mean_gops: gops / n,
            mean_gops_per_watt: gpw / n,
            mean_density: density / n,
            breakdown: agg,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::tensor::SeededRng;
    use crate::workload::TraceGenerator;

    fn sim() -> ChipSim {
        ChipSim::new(HardwareConfig::paper(), ModelConfig::paper())
    }

    fn mask(density: f64) -> MaskMatrix {
        MaskMatrix::from_dense(&SeededRng::new(1).mask_matrix(320, 320, density))
    }

    #[test]
    fn gops_in_plausible_range() {
        // Paper: CPSAA ≈ 9142 GOPS average. Expect same order of magnitude.
        let r = sim().simulate_batch(&mask(0.1));
        assert!(r.gops > 1000.0 && r.gops < 100_000.0, "gops {}", r.gops);
    }

    #[test]
    fn gops_per_watt_in_plausible_range() {
        // Paper: 476 GOPS/W.
        let r = sim().simulate_batch(&mask(0.1));
        assert!(r.gops_per_watt > 20.0 && r.gops_per_watt < 10_000.0, "gpw {}", r.gops_per_watt);
    }

    #[test]
    fn dense_mode_slower_lower_gops() {
        let s = sim().simulate_batch(&mask(0.1));
        let d = sim().dense().simulate_batch(&mask(0.1));
        assert!(d.gops < s.gops);
    }

    #[test]
    fn trace_aggregates() {
        let gen = TraceGenerator::new(ModelConfig::paper(), 0).with_max_batches(2);
        let w = WorkloadConfig::paper();
        let trace = gen.generate(w.dataset("MRPC").unwrap());
        let r = sim().simulate_trace(&trace);
        assert_eq!(r.batches, 2);
        assert!(r.total_ns > 0.0 && r.mean_gops > 0.0);
    }

    #[test]
    fn throughput_stable_across_trace_size() {
        // Fig. 20a: GOPS stays stable as dataset size grows (serial batches).
        let w = WorkloadConfig::paper();
        let gen1 = TraceGenerator::new(ModelConfig::paper(), 0).with_max_batches(1);
        let gen4 = TraceGenerator::new(ModelConfig::paper(), 0).with_max_batches(4);
        let small = sim().simulate_trace(&gen1.generate(w.dataset("QQP").unwrap()));
        let large = sim().simulate_trace(&gen4.generate(w.dataset("QQP").unwrap()));
        let ratio = large.mean_gops / small.mean_gops;
        assert!(ratio > 0.7 && ratio < 1.3, "ratio {ratio}");
    }
}
