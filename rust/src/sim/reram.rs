//! ReRAM crossbar array model: resident weights + activation bookkeeping.
//!
//! A functional-plus-timing model of one crossbar (Fig. 2b): it stores a
//! block of numbers, performs the analog VMM digitally (for functional
//! checks), and counts activations/writes for the cost model. The engines
//! operate on aggregate [`cost`](super::cost) formulas for speed; this
//! per-array model backs the unit tests that pin those formulas to a
//! concrete device.

use crate::config::HardwareConfig;
use crate::tensor::Matrix;

/// One crossbar array holding a `rows×cols` block of values.
#[derive(Clone, Debug)]
pub struct CrossbarArray {
    /// Resident weight block (numbers, not cells).
    weights: Matrix,
    /// Total VMM activations performed.
    pub activations: u64,
    /// Total row writes performed.
    pub row_writes: u64,
}

impl CrossbarArray {
    /// Program a weight block; counts the row writes (each number is one
    /// array row at the paper's 32-bit/SLC point).
    pub fn program(weights: Matrix) -> Self {
        let row_writes = (weights.rows() * weights.cols()) as u64;
        Self { weights, activations: 0, row_writes }
    }

    pub fn shape(&self) -> (usize, usize) {
        self.weights.shape()
    }

    /// Re-program (runtime write, WEA only).
    pub fn rewrite(&mut self, weights: Matrix) {
        self.row_writes += (weights.rows() * weights.cols()) as u64;
        self.weights = weights;
    }

    /// One VMM activation: input vector × resident block.
    /// Kirchhoff current law summation, modeled exactly in f32.
    pub fn vmm(&mut self, input: &[f32]) -> Vec<f32> {
        assert_eq!(input.len(), self.weights.rows(), "input length mismatch");
        self.activations += 1;
        let (k, m) = self.weights.shape();
        let mut out = vec![0.0f32; m];
        for p in 0..k {
            let x = input[p];
            if x == 0.0 {
                continue;
            }
            for (o, w) in out.iter_mut().zip(self.weights.row(p)) {
                *o += x * w;
            }
        }
        out
    }

    /// Latency of this array's lifetime activity under `hw` (ns): writes
    /// serial per row, activations serialized on the local ADC share.
    pub fn elapsed_ns(&self, hw: &HardwareConfig) -> f64 {
        let act_cycles = self.activations * super::cost::adc_cycles_per_activation(hw);
        self.row_writes as f64 * hw.write_row_ns() + act_cycles as f64 * hw.cycle_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    #[test]
    fn vmm_matches_matmul() {
        let w = SeededRng::new(0).normal_matrix(8, 8, 1.0);
        let mut xb = CrossbarArray::program(w.clone());
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let y = xb.vmm(&x);
        let want = Matrix::from_vec(1, 8, x).matmul(&w);
        for (a, b) in y.iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-5);
        }
        assert_eq!(xb.activations, 1);
    }

    #[test]
    fn write_accounting() {
        let w = Matrix::zeros(32, 1);
        let mut xb = CrossbarArray::program(w.clone());
        assert_eq!(xb.row_writes, 32);
        xb.rewrite(w);
        assert_eq!(xb.row_writes, 64);
    }

    #[test]
    fn elapsed_reflects_ideal_write_knob() {
        let mut hw = HardwareConfig::paper();
        let xb = CrossbarArray::program(Matrix::zeros(32, 1));
        let with_writes = xb.elapsed_ns(&hw);
        hw.ideal.no_write_latency = true;
        assert!(xb.elapsed_ns(&hw) < with_writes);
    }

    #[test]
    fn zero_input_skips_rows() {
        let mut xb = CrossbarArray::program(Matrix::full(4, 4, 1.0));
        let y = xb.vmm(&[0.0, 0.0, 0.0, 0.0]);
        assert!(y.iter().all(|&v| v == 0.0));
    }
}
