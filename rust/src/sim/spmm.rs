//! ReRAM SpMM engine (§4.4): mask-driven V-row replication.
//!
//! CPSAA's method: the ReCAM row-search finds, for every output row i,
//! the V rows selected by mask row i; those rows are *replicated* into
//! dedicated arrays so row i's whole reduction is a single VMM. All
//! output rows then fire simultaneously — trading replicated storage
//! (Fig. 19b: ~30× data replication) for ~300× fewer cycles than the
//! zero-gating baseline of Fig. 9, which keeps V resident once and feeds
//! S rows serially (saving energy on zero inputs but no cycles).

use crate::config::HardwareConfig;
use crate::sparse::{DispatchPlan, MaskMatrix};

use super::cost;
use super::recam::RecamScheduler;

/// Outcome of one SpMM `Z = S · V` dispatch.
#[derive(Clone, Copy, Debug)]
pub struct SpmmReport {
    /// Crossbar activations performed.
    pub activations: u64,
    /// Compute latency (ns).
    pub compute_ns: f64,
    /// ReCAM search + CTRL + V-row mapping (replication write) ns.
    pub schedule_ns: f64,
    /// Replication write latency (ns) — included in schedule_ns, kept
    /// separate for the pipeline's overlap accounting.
    pub replication_write_ns: f64,
    /// Dynamic energy (pJ) including replication writes.
    pub energy_pj: f64,
    /// Cycles of this method.
    pub cycles: u64,
    /// Cycles of the zero-gating baseline (Fig. 9) on the same mask.
    pub baseline_cycles: u64,
    /// Energy of the zero-gating baseline (pJ).
    pub baseline_pj: f64,
    /// V numbers stored by this method / V numbers stored once.
    pub replication_factor: f64,
    /// Fraction of mapped array rows doing useful work (vs. baseline's
    /// idle rows) — the runtime memory-utilization metric of Fig. 19b.
    pub memory_utilization: f64,
}

/// Simulate `Z = S · V` with S shaped by `mask` (n×m) and V dense (m×dv).
/// Convenience wrapper over [`simulate_plan`] (builds a throwaway plan).
pub fn simulate(hw: &HardwareConfig, mask: &MaskMatrix, dv: usize) -> SpmmReport {
    simulate_plan(hw, &mask.plan(), dv)
}

/// Simulate the SpMM dispatch over a prebuilt plan: per-row nnz (the
/// V-row replication factors) come from the plan's CSR topology.
pub fn simulate_plan(hw: &HardwareConfig, plan: &DispatchPlan, dv: usize) -> SpmmReport {
    let n = plan.rows();
    let m = plan.cols();
    let sched = RecamScheduler::new(plan);
    let pass = sched.row_search(hw);

    let per_array = cost::numbers_per_array(hw);

    // --- CPSAA replicated mapping -----------------------------------------
    // Output row i: weights are its row_nnz(i) selected V rows (an
    // nnz_i × dv stationary operand): dv output columns, each a column
    // vector of nnz_i numbers resident in ceil(nnz_i/per_array) arrays
    // (§4.4's "around 320×64 arrays" at the paper point).
    let mut total_arrays = 0u64;
    let mut activations = 0u64;
    let mut replicated_numbers = 0u64;
    for i in 0..n {
        let nnz = plan.row_nnz(i);
        if nnz == 0 {
            continue;
        }
        let tiles = cost::arrays_for_matrix(hw, nnz, dv);
        total_arrays += tiles;
        activations += tiles; // one input vector per output row
        replicated_numbers += (nnz * dv) as u64;
    }
    let avail = cost::wea_arrays(hw);
    let rounds = total_arrays.div_ceil(avail).max(1);
    let cost_c = cost::activation_cost(hw, activations, rounds, total_arrays.min(avail));

    // Replication writes: the selected V rows are *broadcast* into the
    // per-output-row arrays (one driver pulse programs every array whose
    // wordline holds that row — §4.4's mapping phase iterates rows of the
    // ReCAM, not copies). Latency and energy therefore scale with the
    // distinct rows of V written once, not with the replication factor.
    let rep_write_ns = cost::write_matrix_ns(hw, m, dv);
    let rep_write_pj = cost::write_matrix_pj(hw, m, dv);

    // CTRL dispatch per searched row.
    let ctrl_ns = n as f64 * hw.ctrl_latency_ns();

    // --- zero-gating baseline (Fig. 9) --------------------------------------
    // V resident exactly once (replication IS the CPSAA contribution the
    // baseline lacks); S rows stream serially: one VMM round per S row.
    // Cycles scale with n; energy only with nnz (zero inputs draw no
    // current).
    let v_tiles = cost::arrays_for_matrix(hw, m, dv);
    let baseline_activations = n as u64 * v_tiles;
    let baseline = cost::activation_cost(hw, baseline_activations, n as u64, v_tiles.min(avail));
    // Energy: only rows carrying non-zeros burn crossbar current.
    let nnz_total = plan.nnz() as u64;
    let active_fraction = if n * m == 0 { 0.0 } else { nnz_total as f64 / (n * m) as f64 };
    let baseline_pj = baseline.pj * active_fraction.max(1.0 / m as f64);

    // Memory utilization: fraction of mapped rows that are non-idle.
    // CPSAA maps exactly the selected rows (≈1.0 up to tile padding);
    // baseline activates all m rows per VMM but only nnz/n are useful.
    let cpsaa_util = if replicated_numbers == 0 {
        0.0
    } else {
        replicated_numbers as f64 / (total_arrays * per_array) as f64
    };
    let baseline_util = active_fraction;

    SpmmReport {
        activations,
        compute_ns: cost_c.ns,
        schedule_ns: pass.search_ns + ctrl_ns + rep_write_ns,
        replication_write_ns: rep_write_ns,
        energy_pj: cost_c.pj + pass.search_pj + rep_write_pj,
        cycles: cost_c.cycles,
        baseline_cycles: baseline.cycles,
        baseline_pj,
        replication_factor: if m == 0 { 0.0 } else { replicated_numbers as f64 / (m * dv) as f64 },
        memory_utilization: if baseline_util > 0.0 { cpsaa_util / baseline_util } else { 0.0 },
    }
}

impl SpmmReport {
    /// Throughput gain over the zero-gating baseline (Fig. 19b SpMM-T).
    pub fn throughput_vs_baseline(&self) -> f64 {
        if self.cycles == 0 {
            return 1.0;
        }
        self.baseline_cycles as f64 / self.cycles as f64
    }

    /// Total engine latency; replication writes overlap the preceding
    /// softmax/SDDMM stage in the pipeline, so outside the pipeline we
    /// report the max path.
    pub fn total_ns(&self) -> f64 {
        self.compute_ns.max(self.schedule_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn mask(n: usize, density: f64, seed: u64) -> MaskMatrix {
        MaskMatrix::from_dense(&SeededRng::new(seed).mask_matrix(n, n, density))
    }

    fn hw() -> HardwareConfig {
        HardwareConfig::paper()
    }

    #[test]
    fn paper_example_orders_of_magnitude() {
        // §4.4: 320×320 S at 0.1, V 320×64 → ~300× cycle saving for ~30×
        // replication.
        let r = simulate(&hw(), &mask(320, 0.1, 1), 64);
        assert!(r.throughput_vs_baseline() > 30.0, "T {}", r.throughput_vs_baseline());
        assert!(r.replication_factor > 5.0 && r.replication_factor < 60.0,
            "R {}", r.replication_factor);
    }

    #[test]
    fn replication_factor_matches_mask_nnz() {
        let m = mask(64, 0.2, 2);
        let r = simulate(&hw(), &m, 64);
        let want = m.nnz() as f64 / 64.0; // nnz×dv / (m×dv)
        assert!((r.replication_factor - want).abs() < 1e-9);
    }

    #[test]
    fn baseline_cycles_scale_with_rows() {
        let a = simulate(&hw(), &mask(64, 0.1, 3), 64);
        let b = simulate(&hw(), &mask(128, 0.1, 3), 64);
        assert!(b.baseline_cycles >= 2 * a.baseline_cycles);
    }

    #[test]
    fn baseline_energy_scales_with_density_not_cycles() {
        let lo = simulate(&hw(), &mask(128, 0.05, 4), 64);
        let hi = simulate(&hw(), &mask(128, 0.5, 4), 64);
        assert_eq!(lo.baseline_cycles, hi.baseline_cycles); // same cycles
        assert!(lo.baseline_pj < hi.baseline_pj); // less energy
    }

    #[test]
    fn empty_mask_trivial() {
        let r = simulate(&hw(), &MaskMatrix::zeros(32, 32), 64);
        assert_eq!(r.activations, 0);
        assert_eq!(r.replication_factor, 0.0);
    }

    #[test]
    fn memory_utilization_above_baseline() {
        // Fig. 19b: ~9× runtime memory-utilization improvement at 0.1.
        let r = simulate(&hw(), &mask(320, 0.1, 5), 64);
        assert!(r.memory_utilization > 2.0, "util {}", r.memory_utilization);
    }
}
