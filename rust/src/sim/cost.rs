//! Shared analytical cost primitives of the crossbar substrate.
//!
//! Modeling conventions (calibrated against the paper's reported ratios,
//! see rust/DESIGN.md §Substitutions):
//!
//! * A value is `value_bits` bits across `value_bits/cell_bits` SLC cells.
//!   One array **row** stores `c·cell_bits/value_bits` numbers, so a `c×c`
//!   crossbar holds `c²·cell_bits/value_bits` numbers — at the paper's
//!   32×32/SLC/32-bit point that is 32 numbers, "each row storing one
//!   number" (§4.3).
//! * One **activation** = one crossbar performing one VMM against one
//!   input vector, producing one 32-number dot-product group after S+A.
//!   The ADC reads 32 columns per 25 ns cycle, so an activation of a
//!   `c`-column array costs `ceil(c/32)` ADC cycles.
//! * Each AG's `adcs_per_ag` ADCs are shared by its `arrays_per_ag`
//!   crossbars. Input rows pipeline through the DAC/S+H stages, hiding
//!   most of that serialization; the residual stall is capped at 2×
//!   (`ADC_SHARING_STALL`), which reproduces Fig. 18c's ≈ +105% from
//!   infinite ADCs. `ideal.infinite_adcs` removes it entirely.
//! * For a stationary k×m weight operand, each output column j needs its
//!   k-number column vector resident in `ceil(k/numbers_per_array)`
//!   arrays; every input row activates all of them once.
//! * Writes are row-parallel: one array row per `write_row_ns`, one write
//!   port per tile (`WRITE_PORTS_PER_TILE`).
//! * On-chip movement costs `transfer_ns(bytes)` on the 1000 GB/s OCI and
//!   7 pJ/bit (§5).

use crate::config::HardwareConfig;

/// Residual ADC-sharing stall for 1 ADC per 12-array AG (pipelined).
pub const ADC_SHARING_STALL: f64 = 2.0;

/// Write ports per tile (WEA write-driver bound).
pub const WRITE_PORTS_PER_TILE: u64 = 1;

/// Fraction of the Table 2 chip power burned statically over any busy
/// window (clock trees, buffers, drivers). Charged uniformly to CPSAA and
/// the PIM baselines so energy comparisons reflect runtime differences.
pub const STATIC_SHARE: f64 = 0.3;

/// Number of ADC cycles one activation of a `c`-column crossbar costs.
pub fn adc_cycles_per_activation(hw: &HardwareConfig) -> u64 {
    (hw.crossbar_size as u64).div_ceil(32)
}

/// Numbers stored per crossbar (weight capacity).
pub fn numbers_per_array(hw: &HardwareConfig) -> u64 {
    let c = hw.crossbar_size as u64;
    (c * c * hw.cell_bits as u64 / hw.value_bits as u64).max(1)
}

/// Arrays needed to hold one k-number column vector of a stationary
/// operand (the per-column "segment" count of §4.3).
pub fn segments_per_column(hw: &HardwareConfig, k: usize) -> u64 {
    (k as u64).div_ceil(numbers_per_array(hw))
}

/// Arrays needed to hold an `rows × cols` stationary operand.
pub fn arrays_for_matrix(hw: &HardwareConfig, rows: usize, cols: usize) -> u64 {
    cols as u64 * segments_per_column(hw, rows)
}

/// Residual ADC stall multiplier.
pub fn adc_stall(hw: &HardwareConfig) -> f64 {
    if hw.ideal.infinite_adcs {
        1.0
    } else {
        (hw.arrays_per_ag as f64 / hw.adcs_per_ag.max(1) as f64).clamp(1.0, ADC_SHARING_STALL)
    }
}

/// Latency (ns) to write an `rows × cols` matrix into crossbar arrays.
///
/// Per-AG write-driver model: the matrix spreads over
/// `ceil(arrays/arrays_per_ag)` AGs, each with one driver writing its
/// arrays' rows serially (row-parallel within a row). The effective
/// per-row time is `write_row_ns × write_verify_factor` (SET/RESET plus
/// program-verify iterations — the calibration knob behind the Fig. 15
/// W4W and Fig. 18a ratios). Note the latency saturates at one full AG's
/// row count: wider matrices just occupy more AGs in parallel.
pub fn write_matrix_ns(hw: &HardwareConfig, rows: usize, cols: usize) -> f64 {
    if hw.ideal.no_write_latency {
        return 0.0;
    }
    let numbers = (rows * cols) as u64;
    let numbers_per_row = (hw.crossbar_size as u64 * hw.cell_bits as u64 / hw.value_bits as u64).max(1);
    let arrays = numbers.div_ceil(numbers_per_array(hw));
    let ags = arrays.div_ceil(hw.arrays_per_ag as u64).max(1);
    let rows_per_ag = numbers.div_ceil(ags).div_ceil(numbers_per_row);
    rows_per_ag as f64 * hw.write_row_ns() * hw.write_verify_factor
}

/// Energy (pJ) of writing an `rows × cols` f32 matrix.
pub fn write_matrix_pj(hw: &HardwareConfig, rows: usize, cols: usize) -> f64 {
    (rows * cols) as f64 * hw.value_bits as f64 * hw.write_pj_per_bit
}

/// A dense VMM workload: `n` input vectors against a resident `k×m`
/// weight matrix.
#[derive(Clone, Copy, Debug)]
pub struct VmmOp {
    pub n: usize,
    pub k: usize,
    pub m: usize,
}

/// Cost of a VMM op given `arrays` crossbars allocated to the operand.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VmmCost {
    /// Total crossbar activations.
    pub activations: u64,
    /// Total latency in ADC cycles (after parallelism).
    pub cycles: u64,
    /// Latency in ns.
    pub ns: f64,
    /// Dynamic energy in pJ (crossbar + ADC + DAC).
    pub pj: f64,
    /// Arrays the operand layout occupies.
    pub arrays_used: u64,
}

/// Dense (DDMM) VMM cost — the primitive for M = X·W_S, V = X·W_V, the
/// pruning matmuls, and the ReBERT/ReTransformer baselines.
///
/// `arrays_allocated` bounds how many arrays the operand may occupy. If
/// the layout exceeds it, tiles time-multiplex (rounds); if the
/// allocation exceeds the layout, the operand is **replicated** and
/// input rows fan out across copies (the paper pre-stores Q(W_S) in
/// several ROAs for exactly this).
pub fn vmm_cost(hw: &HardwareConfig, op: VmmOp, arrays_allocated: u64) -> VmmCost {
    vmm_cost_with_copies(hw, op, arrays_allocated, u64::MAX)
}

/// [`vmm_cost`] with an explicit replication cap (`max_copies = 1` models
/// a strictly serial scheduler such as ReTransformer's dependency chain).
pub fn vmm_cost_with_copies(
    hw: &HardwareConfig,
    op: VmmOp,
    arrays_allocated: u64,
    max_copies: u64,
) -> VmmCost {
    let segs = segments_per_column(hw, op.k);
    let layout = op.m as u64 * segs;
    let alloc = arrays_allocated.max(1);
    let activations = op.n as u64 * layout;
    let rounds = layout.div_ceil(alloc);
    let copies = (alloc / layout.max(1)).clamp(1, max_copies.min(op.n as u64).max(1));
    let arrays = (layout * copies).min(alloc);
    // Stationary weights: every input row passes through each resident
    // tile serially; replication splits the row stream across copies.
    let serial = (op.n as u64 * rounds).div_ceil(copies);
    activation_cost(hw, activations, serial, arrays)
}

/// Cost of a raw activation count.
///
/// `serial_per_array` is the depth of the longest per-array queue (an
/// array retires one activation per ADC pass); `arrays_allocated` bounds
/// spatial parallelism.
pub fn activation_cost(
    hw: &HardwareConfig,
    activations: u64,
    serial_per_array: u64,
    arrays_allocated: u64,
) -> VmmCost {
    let per_act_cycles = adc_cycles_per_activation(hw);
    let arrays = arrays_allocated.max(1);
    let stall = adc_stall(hw);
    let spatial = activations.div_ceil(arrays);
    let cycles = ((spatial.max(serial_per_array) * per_act_cycles) as f64 * stall).ceil() as u64;
    let ns = cycles as f64 * hw.cycle_ns;
    // Energy: every activation powers the crossbar + DAC share for one
    // cycle and the ADC for its read-out cycles. Table 2 powers are per-AG
    // totals over 12 arrays; divide accordingly.
    let per_array_mw = (hw.xb_mw + hw.dac_mw) / hw.arrays_per_ag as f64;
    let act_pj = per_array_mw * hw.cycle_ns
        + hw.adc_mw / hw.arrays_per_ag as f64 * hw.cycle_ns * per_act_cycles as f64;
    VmmCost { activations, cycles, ns, pj: activations as f64 * act_pj, arrays_used: arrays }
}

/// Total crossbar arrays the chip can dedicate to one operand class.
pub fn wea_arrays(hw: &HardwareConfig) -> u64 {
    (hw.tiles * hw.wea_per_tile * hw.arrays_per_ag) as u64
}

pub fn roa_arrays(hw: &HardwareConfig) -> u64 {
    (hw.tiles * hw.roa_per_tile * hw.arrays_per_ag) as u64
}

/// On-chip transfer cost of `bytes` (ns, pJ).
pub fn transfer(hw: &HardwareConfig, bytes: u64) -> (f64, f64) {
    (hw.transfer_ns(bytes), bytes as f64 * 8.0 * hw.transfer_pj_per_bit)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HardwareConfig {
        HardwareConfig::paper()
    }

    #[test]
    fn paper_point_numbers_per_array() {
        assert_eq!(numbers_per_array(&hw()), 32);
        assert_eq!(adc_cycles_per_activation(&hw()), 1);
        assert_eq!(segments_per_column(&hw(), 512), 16);
    }

    #[test]
    fn bigger_crossbars_store_more_cost_more_per_activation() {
        let big = HardwareConfig { crossbar_size: 128, ..hw() };
        assert_eq!(numbers_per_array(&big), 512);
        assert_eq!(adc_cycles_per_activation(&big), 4);
    }

    #[test]
    fn vmm_cost_scales_with_n() {
        let a = vmm_cost(&hw(), VmmOp { n: 64, k: 512, m: 512 }, 8192);
        let b = vmm_cost(&hw(), VmmOp { n: 128, k: 512, m: 512 }, 8192);
        assert_eq!(b.activations, 2 * a.activations);
        assert!(b.ns >= a.ns);
    }

    #[test]
    fn paper_scale_vmm_latency_plausible() {
        // M = X·W_S at the paper shape on half the ROA pool: tens of µs —
        // consistent with CPSAA's ~9 TOPS effective rate.
        let c = vmm_cost(&hw(), VmmOp { n: 320, k: 512, m: 512 }, roa_arrays(&hw()) / 2);
        assert!(c.ns > 5_000.0 && c.ns < 100_000.0, "ns {}", c.ns);
    }

    #[test]
    fn infinite_adcs_strictly_faster() {
        let op = VmmOp { n: 320, k: 512, m: 512 };
        let base = vmm_cost(&hw(), op, 4096);
        let mut ideal = hw();
        ideal.ideal.infinite_adcs = true;
        let fast = vmm_cost(&ideal, op, 4096);
        assert!(fast.cycles < base.cycles);
        assert_eq!(fast.activations, base.activations);
        // the stall model is ≈2×, matching Fig. 18c's +104.8%
        let ratio = base.cycles as f64 / fast.cycles as f64;
        assert!(ratio > 1.5 && ratio < 2.5, "ratio {ratio}");
    }

    #[test]
    fn more_arrays_not_slower() {
        let op = VmmOp { n: 320, k: 512, m: 512 };
        let few = vmm_cost(&hw(), op, 128);
        let many = vmm_cost(&hw(), op, 8192);
        assert!(many.cycles <= few.cycles);
    }

    #[test]
    fn write_latency_saturates_at_ag_depth() {
        // The per-AG driver model: wider matrices occupy more AGs in
        // parallel, so latency saturates at one AG's row count.
        let h = hw();
        let a = write_matrix_ns(&h, 320, 512);
        let b = write_matrix_ns(&h, 640, 512);
        assert!(a > 0.0 && (b - a).abs() / a < 0.05, "a {a} b {b}");
        // X^T at paper scale: microseconds (384 rows × ~20 ns effective)
        assert!(a > 1_000.0 && a < 100_000.0, "write ns {a}");
        // A tiny matrix writes faster than a full AG.
        let tiny = write_matrix_ns(&h, 4, 8);
        assert!(tiny < a);
    }

    #[test]
    fn write_ideal_zero() {
        let mut h = hw();
        h.ideal.no_write_latency = true;
        assert_eq!(write_matrix_ns(&h, 320, 512), 0.0);
        // energy still charged — Fig. 18a zeroes latency, not energy
        assert!(write_matrix_pj(&h, 320, 512) > 0.0);
    }

    #[test]
    fn transfer_costs() {
        let (ns, pj) = transfer(&hw(), 1000);
        assert!((ns - 1.0).abs() < 1e-9); // 1000 B at 1000 GB/s = 1 ns
        assert!((pj - 56000.0).abs() < 1e-6); // 8000 bits × 7 pJ
    }

    #[test]
    fn array_counts_match_table2_structure() {
        let h = hw();
        assert_eq!(wea_arrays(&h), 64 * 56 * 12);
        assert_eq!(roa_arrays(&h), 64 * 11 * 12);
    }

    #[test]
    fn quantized_values_cheaper() {
        // 4-bit pruning operands: 8× denser storage → fewer segments.
        let q = HardwareConfig { value_bits: 4, ..hw() };
        assert_eq!(numbers_per_array(&q), 256);
        assert_eq!(segments_per_column(&q, 512), 2);
    }
}
