//! PIM pruning engine — Step 1 of the dataflow (§4.2, eq. 4).
//!
//! mask = Bina(Soft(Q⁻¹( Q(X)·Q(W_S)·Q(Xᵀ) ) / √d))
//!
//! Everything runs in-memory: Q(W_S) is pre-stored in ROA, Q(Xᵀ) is
//! written to WEA at quantized width, the two VMMs run at `quant_bits`
//! precision (fewer bit-slices ⇒ proportionally fewer activations than
//! the full-precision attention VMMs), and the QU→DQU→SU→BU chain is a
//! per-row pipeline. The resulting mask is programmed into the ReCAM
//! scheduler.
//!
//! The decisive property (vs. SANGER's pruning): **no Q/K intermediates
//! and no off-chip traffic**, so Step 1 overlaps Step 2 entirely.

use crate::config::{HardwareConfig, ModelConfig};
use crate::sparse::DispatchPlan;

use super::cost::{self, VmmOp};

/// Timing/energy of one pruning pass over a batch.
#[derive(Clone, Copy, Debug)]
pub struct PruningReport {
    /// Quantized-VMM latency (both matmuls), ns.
    pub vmm_ns: f64,
    /// Q(Xᵀ) write latency, ns.
    pub write_ns: f64,
    /// QU/DQU/SU/BU pipeline latency, ns.
    pub unit_ns: f64,
    /// ReCAM mask programming latency, ns.
    pub recam_ns: f64,
    /// Total latency of the phase (write overlaps the first VMM).
    pub total_ns: f64,
    /// Total energy (pJ).
    pub energy_pj: f64,
    /// VMM activations.
    pub vmm_activations: u64,
    /// Serial VMM dispatch rounds (the Fig. 16 "VMM-N" metric: how many
    /// sequential crossbar invocations the pruning phase needs).
    pub vmm_rounds: u64,
}

/// Simulate the pruning phase for a batch of `seq_len` embeddings; the
/// produced mask is assumed square at `seq_len` (the paper's setup).
pub fn simulate(hw: &HardwareConfig, model: &ModelConfig) -> PruningReport {
    simulate_mask_cells(hw, model, model.seq_len * model.seq_len)
}

/// [`simulate`] with the actual produced-mask shape taken from the batch's
/// [`DispatchPlan`] — the ReCAM programming cost then reflects the true
/// mask the pipeline dispatches (it can differ from `seq_len²` when the
/// artifact shape and the model config diverge).
pub fn simulate_planned(hw: &HardwareConfig, model: &ModelConfig, plan: &DispatchPlan) -> PruningReport {
    simulate_mask_cells(hw, model, plan.rows() * plan.cols())
}

fn simulate_mask_cells(hw: &HardwareConfig, model: &ModelConfig, mask_cells: usize) -> PruningReport {
    let n = model.seq_len;
    let d = model.d_model;

    // Quantized VMMs use quant_bits-wide values: slices shrink.
    let qhw = HardwareConfig { value_bits: model.quant_bits.max(hw.cell_bits), ..hw.clone() };

    // VMM-1: Q(M) = Q(X)·Q(W_S)  (n×d×d) on ROA-resident Q(W_S).
    let v1 = cost::vmm_cost(&qhw, VmmOp { n, k: d, m: d }, cost::roa_arrays(hw) / 2);
    // VMM-2: Q(S) = Q(M)·Q(Xᵀ)  (n×d×n) on the WEA-resident Q(Xᵀ).
    let v2 = cost::vmm_cost(&qhw, VmmOp { n, k: d, m: n }, cost::wea_arrays(hw) / 4);

    // Q(Xᵀ) write (quantized width): overlaps VMM-1, which needs only
    // Q(X) and the pre-stored Q(W_S).
    let write_ns = cost::write_matrix_ns(&qhw, d, n);
    let write_pj =
        (d * n) as f64 * model.quant_bits as f64 * hw.write_pj_per_bit;

    // QU + DQU + SU + BU: row-pipelined, one unit set per tile (score
    // rows distribute across the 64 tiles).
    let unit_ns = (n as f64 / hw.tiles as f64 + 4.0) * hw.cycle_ns;
    let unit_pj = n as f64 * (1.134 + 0.121 + 0.382) * hw.cycle_ns; // SU+QU/DQU+CTRL mW

    // Program the produced mask into the ReCAM schedulers (recam_arrays
    // per tile, each holding its tile's mask slice; rows write in
    // parallel across schedulers).
    let recam_rows = mask_cells.div_ceil(hw.recam_size);
    let schedulers = (hw.tiles * hw.recam_arrays).max(1);
    let recam_ns = if hw.ideal.no_write_latency {
        0.0
    } else {
        recam_rows.div_ceil(schedulers) as f64 * hw.write_row_ns() * hw.write_verify_factor
    };
    let recam_pj = mask_cells as f64 * hw.write_pj_per_bit;

    // Phase critical path: VMM-2 needs both VMM-1 and the Q(Xᵀ) write.
    let total_ns = v1.ns.max(write_ns) + v2.ns + unit_ns + recam_ns;

    PruningReport {
        vmm_ns: v1.ns + v2.ns,
        write_ns,
        unit_ns,
        recam_ns,
        total_ns,
        energy_pj: v1.pj + v2.pj + write_pj + unit_pj + recam_pj,
        vmm_activations: v1.activations + v2.activations,
        vmm_rounds: v1.cycles + v2.cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (HardwareConfig, ModelConfig) {
        (HardwareConfig::paper(), ModelConfig::paper())
    }

    #[test]
    fn phase_has_positive_components() {
        let (hw, m) = setup();
        let r = simulate(&hw, &m);
        assert!(r.vmm_ns > 0.0 && r.write_ns > 0.0 && r.unit_ns > 0.0 && r.recam_ns > 0.0);
        assert!(r.total_ns >= r.vmm_ns.max(r.write_ns));
        assert!(r.energy_pj > 0.0);
    }

    #[test]
    fn quantization_cheaper_than_full_precision() {
        let (hw, m) = setup();
        let quant = simulate(&hw, &m);
        let full = simulate(&hw, &ModelConfig { quant_bits: 32, ..m });
        assert!(quant.total_ns < full.total_ns);
        assert!(quant.vmm_activations < full.vmm_activations);
    }

    #[test]
    fn activations_scale_with_quant_bits() {
        let (hw, m) = setup();
        let b4 = simulate(&hw, &ModelConfig { quant_bits: 4, ..m.clone() });
        let b8 = simulate(&hw, &ModelConfig { quant_bits: 8, ..m });
        assert_eq!(b8.vmm_activations, 2 * b4.vmm_activations);
    }

    #[test]
    fn ideal_write_removes_recam_and_write_latency() {
        let (mut hw, m) = setup();
        hw.ideal.no_write_latency = true;
        let r = simulate(&hw, &m);
        assert_eq!(r.write_ns, 0.0);
        assert_eq!(r.recam_ns, 0.0);
    }

    #[test]
    fn planned_variant_follows_mask_shape() {
        use crate::sparse::MaskMatrix;
        let (hw, m) = setup();
        // A plan matching seq_len² reproduces the default exactly.
        let square = MaskMatrix::ones(m.seq_len, m.seq_len).plan();
        let a = simulate(&hw, &m);
        let b = simulate_planned(&hw, &m, &square);
        assert_eq!(a.recam_ns, b.recam_ns);
        assert_eq!(a.total_ns, b.total_ns);
        // A smaller mask programs fewer ReCAM cells.
        let small = MaskMatrix::ones(64, 64).plan();
        let c = simulate_planned(&hw, &m, &small);
        assert!(c.recam_ns <= b.recam_ns);
        assert!(c.energy_pj < b.energy_pj);
    }

    #[test]
    fn scales_with_sequence_length() {
        let (hw, m) = setup();
        let short = simulate(&hw, &ModelConfig { seq_len: 128, ..m.clone() });
        let long = simulate(&hw, &ModelConfig { seq_len: 320, ..m });
        assert!(long.total_ns > short.total_ns);
    }
}
