//! ReRAM SDDMM engine (§4.3): vector-wise mapping + ReCAM-driven dispatch.
//!
//! Mapping: every column j of the resident Xᵀ (the K-side operand) is a
//! `d_model`-number vector stored across `d_model / 32` per-column
//! segment arrays ("all bits of one vector into the same ReRAM array",
//! Fig. 8c). The ReCAM row-search streams the mask's ⟨α, βᵢ⟩ coordinates;
//! each masked element (i, j) enqueues row i of M into column j's input
//! register. All column groups drain their queues in parallel, one
//! activation per cycle — so latency is the **maximum column queue
//! depth**, not the total element count (Fig. 8d: a 4×4 S at 0.5 density
//! finishes in 2 cycles).
//!
//! Crossbar-size effect (Fig. 19a): a `c×c` array stores
//! `c²/value_bits` numbers = `c²/(32·value_bits)` vector segments, so
//! larger arrays colocate several *columns* behind one ADC and their
//! queues serialize — vector-wise parallelism decays as c grows.

use crate::config::HardwareConfig;
use crate::sparse::{DispatchPlan, MaskMatrix};

use super::cost;
use super::recam::RecamScheduler;

/// Outcome of one SDDMM dispatch over a mask.
#[derive(Clone, Copy, Debug)]
pub struct SddmmReport {
    /// Masked elements computed (the useful work).
    pub elements: u64,
    /// Crossbar activations (elements × per-column segments).
    pub activations: u64,
    /// Compute latency in ns (queue-bound).
    pub compute_ns: f64,
    /// ReCAM search + control-signal latency in ns.
    pub schedule_ns: f64,
    /// Dynamic energy in pJ (crossbar + ADC + DAC + ReCAM + CTRL).
    pub energy_pj: f64,
    /// Dense-equivalent cycle count (what a DDMM of the same shape costs),
    /// for the Fig. 17 ratio.
    pub dense_cycles: u64,
    /// Actual cycle count.
    pub cycles: u64,
}

/// Simulate `S = mask ⊙ (M · Xᵀ)` — convenience wrapper that builds the
/// mask's plan first; hot paths hold a [`DispatchPlan`] and call
/// [`simulate_plan`].
pub fn simulate(hw: &HardwareConfig, mask: &MaskMatrix, d_model: usize) -> SddmmReport {
    simulate_plan(hw, &mask.plan(), d_model)
}

/// Simulate the SDDMM dispatch over a prebuilt plan: queue depths, block
/// occupancy, and element counts are read from the plan, never recomputed.
pub fn simulate_plan(hw: &HardwareConfig, plan: &DispatchPlan, d_model: usize) -> SddmmReport {
    let n = plan.rows();
    let m = plan.cols();
    let sched = RecamScheduler::new(plan);
    let pass = sched.row_search(hw);

    let elements = plan.nnz() as u64;

    // Segments (arrays) per column vector of d_model numbers (§4.3
    // mapping: all bits of one vector in the same array).
    let segs_per_col = cost::segments_per_column(hw, d_model);
    // Columns colocated per array (queue merging at large c).
    let coloc = (cost::numbers_per_array(hw) / 32).max(1) as usize;

    // Queue depth per array group = sum of colocated column queues —
    // the plan's per-column depths grouped by colocation (Fig. 8d bound).
    let max_queue = plan.grouped_max_queue(coloc);

    let activations = elements * segs_per_col;
    let layout = (m as u64).div_ceil(coloc as u64) * segs_per_col;
    let arrays_avail = cost::wea_arrays(hw);
    // Layout exceeding the WEA pool serializes in rounds. The runtime-
    // written Xᵀ is NOT replicated (replication is the §4.4 SpMM trick;
    // here the ReCAM queues provide the parallelism).
    let rounds = layout.div_ceil(arrays_avail).max(1);
    let arrays = layout.min(arrays_avail);
    let c = cost::activation_cost(hw, activations, max_queue * rounds, arrays);

    // Dense comparison (the ReRAM DDMM of Fig. 17/19a): every (i, j)
    // computed, but a dense pass amortizes one array activation over all
    // `coloc` colocated columns — each input row visits each array once.
    // The sparse dispatch pays a full activation per masked element (it
    // reads the whole array for one useful vector); that asymmetry is why
    // the SDDMM advantage decays as crossbars grow (Fig. 19a).
    let dense_elements = (n * m) as u64;
    let dense = cost::activation_cost(
        hw,
        dense_elements.div_ceil(coloc as u64) * segs_per_col,
        n as u64 * rounds,
        arrays,
    );

    // CTRL: one control batch per searched mask row.
    let ctrl_ns = n as f64 * hw.ctrl_latency_ns();
    let ctrl_pj = n as f64 * hw.ctrl_latency_ns() * 0.382; // CTRL power (Table 2, mW)

    SddmmReport {
        elements,
        activations,
        compute_ns: c.ns,
        schedule_ns: pass.search_ns + ctrl_ns,
        energy_pj: c.pj + pass.search_pj + ctrl_pj,
        dense_cycles: dense.cycles,
        cycles: c.cycles,
    }
}

impl SddmmReport {
    /// Latency ratio vs. the dense DDMM of the same shape (Fig. 17 metric).
    pub fn latency_vs_dense(&self) -> f64 {
        if self.dense_cycles == 0 {
            return 1.0;
        }
        self.cycles as f64 / self.dense_cycles as f64
    }

    /// Total engine latency (schedule is pipelined with compute: the
    /// ReCAM search of row i+1 overlaps the dispatch of row i, so only
    /// the longer of the two paths binds).
    pub fn total_ns(&self) -> f64 {
        self.compute_ns.max(self.schedule_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn mask(n: usize, density: f64, seed: u64) -> MaskMatrix {
        MaskMatrix::from_dense(&SeededRng::new(seed).mask_matrix(n, n, density))
    }

    fn hw() -> HardwareConfig {
        HardwareConfig::paper()
    }

    #[test]
    fn paper_fig8_example() {
        // 4×4 mask, density 0.5 (the exact Fig. 8 mask): every column has
        // queue depth 2 → two dispatch cycles × the residual ADC stall.
        let mut m = MaskMatrix::zeros(4, 4);
        for (i, j) in [(0, 0), (0, 2), (1, 1), (1, 3), (2, 0), (2, 1), (3, 2), (3, 3)] {
            m.set(i, j, true);
        }
        let r = simulate(&hw(), &m, 128);
        assert_eq!(r.elements, 8);
        let stall = super::super::cost::adc_stall(&hw());
        assert_eq!(r.cycles, (2.0 * stall).ceil() as u64);
    }

    #[test]
    fn sparsity_reduces_cycles_proportionally() {
        let full = simulate(&hw(), &MaskMatrix::ones(320, 320), 512);
        let sparse = simulate(&hw(), &mask(320, 0.1, 1), 512);
        let ratio = sparse.cycles as f64 / full.cycles as f64;
        // ~10× saving at 0.1 density (§4.3 "save up to 10× latency"),
        // slack for queue imbalance.
        assert!(ratio < 0.25, "ratio {ratio}");
        assert!(ratio > 0.05, "ratio {ratio}");
    }

    #[test]
    fn latency_vs_dense_below_paper_point() {
        // Fig. 17: SDDMM latency ≈ 17.5% of DDMM at ~0.1 density.
        let r = simulate(&hw(), &mask(320, 0.1, 2), 512);
        let f = r.latency_vs_dense();
        assert!(f > 0.03 && f < 0.4, "fraction {f}");
    }

    #[test]
    fn empty_mask_costs_schedule_only() {
        let r = simulate(&hw(), &MaskMatrix::zeros(64, 64), 512);
        assert_eq!(r.elements, 0);
        assert_eq!(r.cycles, 0);
        assert!(r.schedule_ns > 0.0);
    }

    #[test]
    fn bigger_crossbars_lose_vector_parallelism() {
        // Fig. 19a: speedup of SDDMM vs DDMM decays as crossbar grows.
        let m = mask(320, 0.1, 3);
        let mut prev_speedup = f64::INFINITY;
        for c in [32usize, 64, 128] {
            let h = HardwareConfig { crossbar_size: c, ..hw() };
            let r = simulate(&h, &m, 512);
            let speedup = 1.0 / r.latency_vs_dense();
            assert!(speedup <= prev_speedup + 1e-9, "c={c}: {speedup} vs {prev_speedup}");
            prev_speedup = speedup;
        }
    }

    #[test]
    fn activations_count_segments() {
        let m = mask(64, 0.2, 4);
        let r = simulate(&hw(), &m, 512);
        assert_eq!(r.activations, r.elements * (512 / 32));
    }
}
