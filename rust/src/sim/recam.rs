//! ReCAM scheduler model (Fig. 2c): the mask store + coordinate engine.
//!
//! The ReCAM array stores the binary mask and performs row-parallel
//! searches whose TAG matches stream out the ⟨α, βᵢ⟩ coordinates that
//! drive SDDMM dispatch (§4.3) and SpMM V-row mapping (§4.4). The search
//! itself costs one ReCAM clock per row scanned; every matched coordinate
//! then costs control-signal time in the CTRL (modeled by the engines).

use crate::config::HardwareConfig;
use crate::sparse::MaskMatrix;

/// A scheduler pass over the mask: coordinates plus timing/energy.
#[derive(Clone, Debug)]
pub struct SchedulePass {
    /// Per-row matched column coordinates (the ⟨α, βᵢ⟩ stream).
    pub coords: Vec<Vec<usize>>,
    /// Search latency (ns): row-by-row scan, rows searched in parallel
    /// across the ReCAM's width.
    pub search_ns: f64,
    /// Search energy (pJ).
    pub search_pj: f64,
}

/// ReCAM scheduler over one (borrowed) mask matrix — the engines run a
/// search pass per dispatch without copying the mask bits.
#[derive(Clone, Debug)]
pub struct RecamScheduler<'a> {
    mask: &'a MaskMatrix,
}

impl<'a> RecamScheduler<'a> {
    pub fn new(mask: &'a MaskMatrix) -> Self {
        Self { mask }
    }

    pub fn mask(&self) -> &MaskMatrix {
        self.mask
    }

    /// Capacity check: masks larger than the ReCAM fold across multiple
    /// logical passes — returns how many physical arrays one mask needs.
    pub fn arrays_needed(&self, hw: &HardwareConfig) -> usize {
        let per = hw.recam_size * hw.recam_size;
        (self.mask.rows() * self.mask.cols()).div_ceil(per)
    }

    /// Latency (ns) to write the mask into the ReCAM (row-parallel).
    pub fn program_ns(&self, hw: &HardwareConfig) -> f64 {
        if hw.ideal.no_write_latency {
            return 0.0;
        }
        // One ReCAM row (recam_size bits) per write_row latency; the mask
        // occupies rows×cols/recam_size rows.
        let rows = (self.mask.rows() * self.mask.cols()).div_ceil(hw.recam_size);
        rows as f64 * hw.write_row_ns()
    }

    /// Row-wise search pass (the colored arrows of Fig. 8a): one ReCAM
    /// clock per mask row, energy per activated row.
    pub fn row_search(&self, hw: &HardwareConfig) -> SchedulePass {
        let rows = self.mask.rows();
        let coords: Vec<Vec<usize>> = (0..rows).map(|i| self.mask.row_coords(i)).collect();
        SchedulePass {
            search_ns: rows as f64 * hw.recam_search_ns,
            search_pj: rows as f64 * hw.recam_pj_per_row,
            coords,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn mask_of(n: usize, density: f64) -> MaskMatrix {
        MaskMatrix::from_dense(&SeededRng::new(1).mask_matrix(n, n, density))
    }

    #[test]
    fn coords_match_mask() {
        let m = mask_of(64, 0.2);
        let s = RecamScheduler::new(&m);
        let pass = s.row_search(&HardwareConfig::paper());
        for (i, row) in pass.coords.iter().enumerate() {
            assert_eq!(row, &s.mask().row_coords(i));
        }
    }

    #[test]
    fn search_latency_linear_in_rows() {
        let hw = HardwareConfig::paper();
        let m64 = mask_of(64, 0.2);
        let m128 = mask_of(128, 0.2);
        let a = RecamScheduler::new(&m64).row_search(&hw);
        let b = RecamScheduler::new(&m128).row_search(&hw);
        assert!((b.search_ns - 2.0 * a.search_ns).abs() < 1e-9);
    }

    #[test]
    fn paper_mask_fits_one_array() {
        // 320×320 mask in a 512×512 ReCAM: one array (§4.4 example).
        let hw = HardwareConfig::paper();
        let m = mask_of(320, 0.1);
        assert_eq!(RecamScheduler::new(&m).arrays_needed(&hw), 1);
    }

    #[test]
    fn oversized_mask_folds() {
        let hw = HardwareConfig::paper();
        let m = mask_of(1024, 0.1);
        assert!(RecamScheduler::new(&m).arrays_needed(&hw) > 1);
    }

    #[test]
    fn program_cost_zero_when_ideal() {
        let mut hw = HardwareConfig::paper();
        let m = mask_of(64, 0.2);
        let s = RecamScheduler::new(&m);
        assert!(s.program_ns(&hw) > 0.0);
        hw.ideal.no_write_latency = true;
        assert_eq!(s.program_ns(&hw), 0.0);
    }
}
