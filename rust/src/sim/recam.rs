//! ReCAM scheduler model (Fig. 2c): the mask store + coordinate engine.
//!
//! The ReCAM array stores the binary mask and performs row-parallel
//! searches whose TAG matches stream out the ⟨α, βᵢ⟩ coordinates that
//! drive SDDMM dispatch (§4.3) and SpMM V-row mapping (§4.4). That
//! coordinate stream is materialized exactly once per mask as a
//! [`DispatchPlan`]; the scheduler here is the *timing/energy* model of
//! the search, layered over the shared plan rather than re-walking the
//! mask bits. The search costs one ReCAM clock per row scanned; every
//! matched coordinate then costs control-signal time in the CTRL
//! (modeled by the engines).

use crate::config::HardwareConfig;
use crate::sparse::DispatchPlan;

/// Timing/energy of one scheduler pass over the mask; the coordinates
/// themselves live in the shared [`DispatchPlan`].
#[derive(Clone, Copy, Debug)]
pub struct SchedulePass {
    /// Search latency (ns): row-by-row scan, rows searched in parallel
    /// across the ReCAM's width.
    pub search_ns: f64,
    /// Search energy (pJ).
    pub search_pj: f64,
}

/// ReCAM scheduler over one (borrowed) dispatch plan — the engines run a
/// search pass per dispatch without copying mask bits or coordinates.
#[derive(Clone, Debug)]
pub struct RecamScheduler<'a> {
    plan: &'a DispatchPlan,
}

impl<'a> RecamScheduler<'a> {
    pub fn new(plan: &'a DispatchPlan) -> Self {
        Self { plan }
    }

    pub fn plan(&self) -> &DispatchPlan {
        self.plan
    }

    /// Capacity check: masks larger than the ReCAM fold across multiple
    /// logical passes — returns how many physical arrays one mask needs.
    pub fn arrays_needed(&self, hw: &HardwareConfig) -> usize {
        let per = hw.recam_size * hw.recam_size;
        (self.plan.rows() * self.plan.cols()).div_ceil(per)
    }

    /// Latency (ns) to write the mask into the ReCAM (row-parallel).
    pub fn program_ns(&self, hw: &HardwareConfig) -> f64 {
        if hw.ideal.no_write_latency {
            return 0.0;
        }
        // One ReCAM row (recam_size bits) per write_row latency; the mask
        // occupies rows×cols/recam_size rows.
        let rows = (self.plan.rows() * self.plan.cols()).div_ceil(hw.recam_size);
        rows as f64 * hw.write_row_ns()
    }

    /// Row-wise search pass (the colored arrows of Fig. 8a): one ReCAM
    /// clock per mask row, energy per activated row. Coordinates come
    /// from the plan, paid for once at plan build.
    pub fn row_search(&self, hw: &HardwareConfig) -> SchedulePass {
        let rows = self.plan.rows();
        SchedulePass {
            search_ns: rows as f64 * hw.recam_search_ns,
            search_pj: rows as f64 * hw.recam_pj_per_row,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MaskMatrix;
    use crate::tensor::SeededRng;

    fn plan_of(n: usize, density: f64) -> DispatchPlan {
        MaskMatrix::from_dense(&SeededRng::new(1).mask_matrix(n, n, density)).plan()
    }

    #[test]
    fn plan_coords_drive_scheduler() {
        let m = MaskMatrix::from_dense(&SeededRng::new(2).mask_matrix(64, 64, 0.2));
        let p = m.plan();
        let s = RecamScheduler::new(&p);
        // The scheduler exposes the shared plan, whose stream matches the
        // mask bit-for-bit.
        for i in 0..64 {
            for &j in s.plan().row_cols(i) {
                assert!(m.get(i, j as usize));
            }
            assert_eq!(s.plan().row_nnz(i), m.row_nnz(i));
        }
    }

    #[test]
    fn search_latency_linear_in_rows() {
        let hw = HardwareConfig::paper();
        let p64 = plan_of(64, 0.2);
        let p128 = plan_of(128, 0.2);
        let a = RecamScheduler::new(&p64).row_search(&hw);
        let b = RecamScheduler::new(&p128).row_search(&hw);
        assert!((b.search_ns - 2.0 * a.search_ns).abs() < 1e-9);
    }

    #[test]
    fn paper_mask_fits_one_array() {
        // 320×320 mask in a 512×512 ReCAM: one array (§4.4 example).
        let hw = HardwareConfig::paper();
        let p = plan_of(320, 0.1);
        assert_eq!(RecamScheduler::new(&p).arrays_needed(&hw), 1);
    }

    #[test]
    fn oversized_mask_folds() {
        let hw = HardwareConfig::paper();
        let p = plan_of(1024, 0.1);
        assert!(RecamScheduler::new(&p).arrays_needed(&hw) > 1);
    }

    #[test]
    fn program_cost_zero_when_ideal() {
        let mut hw = HardwareConfig::paper();
        let p = plan_of(64, 0.2);
        let s = RecamScheduler::new(&p);
        assert!(s.program_ns(&hw) > 0.0);
        hw.ideal.no_write_latency = true;
        assert_eq!(s.program_ns(&hw), 0.0);
    }
}
