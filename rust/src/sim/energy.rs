//! Per-component energy accounting.

use std::fmt;

/// Energy consumers tracked by the simulator (match the Table 2 rows and
//  the Fig. 12 discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Component {
    Crossbar,
    Adc,
    Dac,
    Write,
    Transfer,
    Recam,
    Peripheral,
    Static,
}

pub const ALL_COMPONENTS: [Component; 8] = [
    Component::Crossbar,
    Component::Adc,
    Component::Dac,
    Component::Write,
    Component::Transfer,
    Component::Recam,
    Component::Peripheral,
    Component::Static,
];

/// Accumulating energy meter (pJ per component).
#[derive(Clone, Debug, Default)]
pub struct EnergyMeter {
    buckets: [f64; 8],
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    fn idx(c: Component) -> usize {
        ALL_COMPONENTS.iter().position(|&x| x == c).unwrap()
    }

    pub fn add(&mut self, c: Component, pj: f64) {
        debug_assert!(pj >= 0.0, "negative energy {pj} for {c:?}");
        self.buckets[Self::idx(c)] += pj;
    }

    pub fn get(&self, c: Component) -> f64 {
        self.buckets[Self::idx(c)]
    }

    /// Total energy in pJ.
    pub fn total_pj(&self) -> f64 {
        self.buckets.iter().sum()
    }

    /// Total in millijoules.
    pub fn total_mj(&self) -> f64 {
        self.total_pj() * 1e-9
    }

    /// Merge another meter into this one.
    pub fn merge(&mut self, other: &EnergyMeter) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }

    /// (component, pJ, fraction) rows, largest first.
    pub fn breakdown(&self) -> Vec<(Component, f64, f64)> {
        let total = self.total_pj().max(f64::MIN_POSITIVE);
        let mut rows: Vec<_> = ALL_COMPONENTS
            .iter()
            .map(|&c| (c, self.get(c), self.get(c) / total))
            .collect();
        rows.sort_by(|a, b| b.1.total_cmp(&a.1));
        rows
    }
}

impl fmt::Display for EnergyMeter {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (c, pj, frac) in self.breakdown() {
            if pj > 0.0 {
                writeln!(f, "{c:?}: {:.3e} pJ ({:.1}%)", pj, frac * 100.0)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_total() {
        let mut m = EnergyMeter::new();
        m.add(Component::Crossbar, 10.0);
        m.add(Component::Adc, 5.0);
        m.add(Component::Crossbar, 2.0);
        assert_eq!(m.get(Component::Crossbar), 12.0);
        assert_eq!(m.total_pj(), 17.0);
    }

    #[test]
    fn merge_sums() {
        let mut a = EnergyMeter::new();
        a.add(Component::Write, 3.0);
        let mut b = EnergyMeter::new();
        b.add(Component::Write, 4.0);
        b.add(Component::Static, 1.0);
        a.merge(&b);
        assert_eq!(a.get(Component::Write), 7.0);
        assert_eq!(a.total_pj(), 8.0);
    }

    #[test]
    fn breakdown_sorted_and_normalized() {
        let mut m = EnergyMeter::new();
        m.add(Component::Adc, 30.0);
        m.add(Component::Dac, 70.0);
        let rows = m.breakdown();
        assert_eq!(rows[0].0, Component::Dac);
        assert!((rows[0].2 - 0.7).abs() < 1e-12);
        let frac_sum: f64 = rows.iter().map(|r| r.2).sum();
        assert!((frac_sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_meter_zero() {
        assert_eq!(EnergyMeter::new().total_pj(), 0.0);
    }
}
