//! Application-level simulation (§4.5): encoders = attention + FC layer.
//!
//! Real NLP models chain encoders, each a CPSAA attention chip feeding an
//! ISAAC-style ReRAM FC block; the DTC moves activations between
//! encoders off-chip. This module costs the FC block and the full
//! multi-encoder inference so the end-to-end example and the Fig. 20b
//! sweep rest on the paper's application architecture rather than an
//! attention-only extrapolation.

use crate::config::{HardwareConfig, ModelConfig};
use crate::sparse::MaskMatrix;

use super::chip::{ChipSim, SimReport};
use super::cost::{self, VmmOp};

/// Cost of the FC tail of one encoder (two dense VMMs on ROA-resident
/// weights, ISAAC-style dot products).
#[derive(Clone, Copy, Debug)]
pub struct FcReport {
    pub total_ns: f64,
    pub energy_pj: f64,
}

/// FC block: h → GeLU(h·W1)·W2 with W1: d×d_ff, W2: d_ff×d.
pub fn simulate_fc(hw: &HardwareConfig, model: &ModelConfig) -> FcReport {
    let n = model.seq_len;
    let d = model.d_model;
    let ff = model.d_ff;
    // The FC encoder is its own ReRAM block (the paper pairs one CPSAA
    // chip with a ReRAM FC layer); give each matmul a chip-scale pool.
    let pool = cost::roa_arrays(hw) + cost::wea_arrays(hw);
    let fc1 = cost::vmm_cost(hw, VmmOp { n, k: d, m: ff }, pool / 2);
    let fc2 = cost::vmm_cost(hw, VmmOp { n, k: ff, m: d }, pool / 2);
    // GeLU unit: row-pipelined like the SU.
    let act_ns = (n as f64 / hw.tiles as f64 + 4.0) * hw.cycle_ns;
    FcReport { total_ns: fc1.ns + act_ns + fc2.ns, energy_pj: fc1.pj + fc2.pj }
}

/// One encoder = attention chip + FC block + DTC hop to the next encoder.
#[derive(Clone, Debug)]
pub struct EncoderReport {
    pub attention: SimReport,
    pub fc: FcReport,
    /// Off-chip transfer to the next encoder (DTC), ns.
    pub dtc_ns: f64,
    pub total_ns: f64,
    pub energy_pj: f64,
}

/// A full model inference: `layers` encoders in series (§4.5 dataflow).
#[derive(Clone, Debug)]
pub struct InferenceReport {
    pub encoders: Vec<EncoderReport>,
    pub total_ns: f64,
    pub total_energy_pj: f64,
    /// Dense-equivalent GOPS over attention + FC work.
    pub gops: f64,
}

/// Simulate a whole inference with per-layer masks.
///
/// Multi-head handling (`model.heads`): heads run concurrently on
/// disjoint tile groups (each head's mask drives its own ReCAM
/// scheduler), so per-layer attention latency is the slowest head on a
/// `tiles/heads` slice of the chip and energy sums over heads — the
/// same accounting the serving path charges per batch via
/// [`ChipSim::simulate_heads_planned`], here through the shared-plan
/// shortcut ([`ChipSim::simulate_heads_shared`]) since every head sees
/// the layer mask.
pub fn simulate_inference(
    hw: &HardwareConfig,
    model: &ModelConfig,
    masks: &[MaskMatrix],
) -> InferenceReport {
    let heads = model.heads.max(1);
    let sim = ChipSim::new(hw.clone(), model.clone());
    // DTC: activations leave the encoder at DDR-class bandwidth (the
    // paper keeps inter-encoder traffic off-chip, managed by the DTC).
    let dtc_bytes = (model.seq_len * model.d_model * 4) as u64;
    let dtc_gbps = 32.0; // DDR4-class channel behind the DTC
    let mut encoders = Vec::with_capacity(model.layers);
    let mut total_ns = 0.0;
    let mut total_pj = 0.0;
    // One scan and one shared-plan head simulation per *distinct* mask
    // the layer loop will actually reach (layers cycle over the masks,
    // so only the first `layers` entries matter) — the per-layer cost
    // is a pure function of the plan, so layers just cycle over the
    // precomputed reports.
    let head_reports: Vec<_> = masks[..masks.len().min(model.layers)]
        .iter()
        .map(|m| sim.simulate_heads_shared(&m.plan(), heads))
        .collect();
    for l in 0..model.layers {
        let hs = &head_reports[l % head_reports.len().max(1)];
        // wall time = slowest head, energy = all heads; keep the slice
        // report (identical masks ⇒ identical slices) with the summed
        // energy as the layer's attention line item.
        let mut attention = hs.heads[0].clone();
        attention.breakdown.total_ns = hs.total_ns;
        attention.energy_pj = hs.energy_pj;
        let fc = simulate_fc(hw, model);
        let dtc_ns = dtc_bytes as f64 / dtc_gbps;
        let dtc_pj = dtc_bytes as f64 * 8.0 * hw.transfer_pj_per_bit;
        let enc_ns = attention.breakdown.total_ns + fc.total_ns + dtc_ns;
        let enc_pj = attention.energy_pj + fc.energy_pj + dtc_pj;
        total_ns += enc_ns;
        total_pj += enc_pj;
        encoders.push(EncoderReport { attention, fc, dtc_ns, total_ns: enc_ns, energy_pj: enc_pj });
    }
    let flops = (model.attention_flops() * heads as u64 + model.fc_flops()) as f64
        * model.layers as f64;
    InferenceReport {
        encoders,
        total_ns,
        total_energy_pj: total_pj,
        gops: flops / 1e9 / (total_ns * 1e-9).max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn mask(density: f64) -> MaskMatrix {
        MaskMatrix::from_dense(&SeededRng::new(1).mask_matrix(320, 320, density))
    }

    #[test]
    fn fc_cost_positive_and_scales_with_dff() {
        let hw = HardwareConfig::paper();
        let m = ModelConfig::paper();
        let small = simulate_fc(&hw, &ModelConfig { d_ff: 1024, ..m.clone() });
        let big = simulate_fc(&hw, &ModelConfig { d_ff: 4096, ..m });
        assert!(small.total_ns > 0.0);
        assert!(big.total_ns > small.total_ns);
        assert!(big.energy_pj > small.energy_pj);
    }

    #[test]
    fn inference_chains_layers() {
        let hw = HardwareConfig::paper();
        let model = ModelConfig { layers: 4, ..ModelConfig::paper() };
        let r = simulate_inference(&hw, &model, &[mask(0.1)]);
        assert_eq!(r.encoders.len(), 4);
        let sum: f64 = r.encoders.iter().map(|e| e.total_ns).sum();
        assert!((sum - r.total_ns).abs() < 1e-6);
        assert!(r.gops > 0.0);
    }

    #[test]
    fn gops_stable_across_depth() {
        // Fig. 20b at application level: per-encoder cost is constant, so
        // GOPS stays flat with layer count.
        let hw = HardwareConfig::paper();
        let masks = [mask(0.1)];
        let shallow =
            simulate_inference(&hw, &ModelConfig { layers: 2, ..ModelConfig::paper() }, &masks);
        let deep =
            simulate_inference(&hw, &ModelConfig { layers: 32, ..ModelConfig::paper() }, &masks);
        let ratio = deep.gops / shallow.gops;
        assert!((0.9..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn multi_head_parallel_not_free() {
        // 8 heads on tile slices: more useful flops, some GOPS gain from
        // parallelism, but energy scales with head count.
        let hw = HardwareConfig::paper();
        let one = simulate_inference(
            &hw,
            &ModelConfig { layers: 2, heads: 1, ..ModelConfig::paper() },
            &[mask(0.1)],
        );
        let eight = simulate_inference(
            &hw,
            &ModelConfig { layers: 2, heads: 8, ..ModelConfig::paper() },
            &[mask(0.1)],
        );
        assert!(eight.total_energy_pj > one.total_energy_pj);
        assert!(eight.gops > one.gops, "8 heads {} vs 1 head {}", eight.gops, one.gops);
        // but not a free 8×: each head has 1/8 of the tiles
        assert!(eight.gops < one.gops * 8.0);
    }

    #[test]
    fn sparse_inference_cheaper_than_dense_masks() {
        let hw = HardwareConfig::paper();
        let model = ModelConfig { layers: 2, ..ModelConfig::paper() };
        let sparse = simulate_inference(&hw, &model, &[mask(0.1)]);
        let dense = simulate_inference(&hw, &model, &[MaskMatrix::ones(320, 320)]);
        assert!(sparse.total_ns < dense.total_ns);
    }
}
