//! Area/power model — Table 2 reproduction.
//!
//! Per-component area (mm²) and power (mW) constants from the paper's
//! SPICE/CACTI-6.5 characterization at 32 nm, with the structural roll-up
//! (AG → ROA/WEA → Tile → Chip) computed rather than copied, so changing
//! `HardwareConfig` (e.g. the Fig. 19a crossbar sweep) re-derives the
//! budget.

use crate::config::HardwareConfig;

/// One Table 2 row.
#[derive(Clone, Debug, PartialEq)]
pub struct ComponentRow {
    pub name: &'static str,
    pub area_mm2: f64,
    pub power_mw: f64,
    pub count: usize,
}

impl ComponentRow {
    pub fn total_area(&self) -> f64 {
        self.area_mm2 * self.count as f64
    }

    pub fn total_power(&self) -> f64 {
        self.power_mw * self.count as f64
    }
}

/// Full chip budget.
#[derive(Clone, Debug)]
pub struct AreaModel {
    pub pc_rows: Vec<ComponentRow>,
    pub ag_rows: Vec<ComponentRow>,
    pub chip_area_mm2: f64,
    pub chip_power_mw: f64,
    pub tile_area_mm2: f64,
    pub tile_power_mw: f64,
    pub ag_area_mm2: f64,
    pub ag_power_mw: f64,
}

/// Table 2 peripheral-component constants (per tile).
fn pc_rows() -> Vec<ComponentRow> {
    vec![
        ComponentRow { name: "ReCAM Scheduler", area_mm2: 0.0013, power_mw: 1.398, count: 2 },
        ComponentRow { name: "AIT", area_mm2: 0.0608, power_mw: 36.89, count: 1 },
        ComponentRow { name: "IB", area_mm2: 0.0302, power_mw: 18.47, count: 1 },
        ComponentRow { name: "CB", area_mm2: 0.1217, power_mw: 74.21, count: 1 },
        ComponentRow { name: "CTRL", area_mm2: 0.0015, power_mw: 0.382, count: 1 },
        ComponentRow { name: "SU", area_mm2: 0.0072, power_mw: 1.134, count: 1 },
        ComponentRow { name: "QU&DQU", area_mm2: 0.0016, power_mw: 0.121, count: 1 },
    ]
}

/// Table 2 arrays-group constants. The paper's AG rows are *per-AG
/// totals* (e.g. "XB Array, 0.581 mW, total 12" sums to the AG total of
/// 4.623 mW only if 0.581 covers all 12 arrays); counts here are 1 with
/// the totals scaled by the config's deviation from the Table 2 point.
fn ag_rows(hw: &HardwareConfig) -> Vec<ComponentRow> {
    // Crossbar cell count relative to the 32×32 reference point.
    let xb_scale = (hw.crossbar_size * hw.crossbar_size) as f64 / (32.0 * 32.0)
        * hw.arrays_per_ag as f64
        / 12.0;
    let adc_scale = hw.adcs_per_ag as f64;
    let dac_scale = hw.crossbar_size as f64 / 32.0 * hw.arrays_per_ag as f64 / 12.0;
    vec![
        ComponentRow { name: "ADC", area_mm2: 0.0015 * adc_scale, power_mw: 2.0 * adc_scale, count: 1 },
        ComponentRow {
            name: "XB Array",
            area_mm2: 4.78e-5 * xb_scale,
            power_mw: 0.581 * xb_scale,
            count: 1,
        },
        ComponentRow { name: "S/H", area_mm2: 4.69e-7, power_mw: 0.074, count: 1 },
        ComponentRow {
            name: "DAC",
            area_mm2: 6.38e-5 * dac_scale,
            power_mw: 1.513 * dac_scale,
            count: 1,
        },
        ComponentRow { name: "IR", area_mm2: 0.00049, power_mw: 0.294, count: 1 },
        ComponentRow { name: "OR", area_mm2: 0.00036, power_mw: 0.108, count: 1 },
        ComponentRow { name: "S+A", area_mm2: 0.00006, power_mw: 0.051, count: 1 },
    ]
}

/// DTC (Table 2): off-chip data-transfer controller.
const DTC_AREA: f64 = 2.26;
const DTC_POWER: f64 = 494.07;

impl AreaModel {
    pub fn build(hw: &HardwareConfig) -> Self {
        let pc = pc_rows();
        let ag = ag_rows(hw);
        let ag_area: f64 = ag.iter().map(ComponentRow::total_area).sum();
        let ag_power: f64 = ag.iter().map(ComponentRow::total_power).sum();
        let pc_area: f64 = pc.iter().map(ComponentRow::total_area).sum();
        let pc_power: f64 = pc.iter().map(ComponentRow::total_power).sum();
        let ags_per_tile = (hw.roa_per_tile + hw.wea_per_tile) as f64;
        let tile_area = pc_area + ag_area * ags_per_tile;
        let tile_power = pc_power + ag_power * ags_per_tile;
        let chip_area = tile_area * hw.tiles as f64 + DTC_AREA;
        let chip_power = tile_power * hw.tiles as f64 + DTC_POWER;
        Self {
            pc_rows: pc,
            ag_rows: ag,
            chip_area_mm2: chip_area,
            chip_power_mw: chip_power,
            tile_area_mm2: tile_area,
            tile_power_mw: tile_power,
            ag_area_mm2: ag_area,
            ag_power_mw: ag_power,
        }
    }

    /// Chip TDP in watts (used for GOPS/W alongside dynamic energy).
    pub fn chip_power_w(&self) -> f64 {
        self.chip_power_mw / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_table2_chip_totals() {
        // Table 2: CPSAA = 27.47 mm², 28.83 kW→ 28.83 *K mW* = 28.83 W.
        let m = AreaModel::build(&HardwareConfig::paper());
        assert!((m.chip_area_mm2 - 27.47).abs() / 27.47 < 0.12, "area {}", m.chip_area_mm2);
        assert!((m.chip_power_mw - 28_830.0).abs() / 28_830.0 < 0.12, "power {}", m.chip_power_mw);
    }

    #[test]
    fn matches_table2_ag_totals() {
        // Table 2: AG total = 0.00252 mm², 4.623 mW.
        let m = AreaModel::build(&HardwareConfig::paper());
        assert!((m.ag_area_mm2 - 0.00252).abs() / 0.00252 < 0.15, "ag area {}", m.ag_area_mm2);
        assert!((m.ag_power_mw - 4.623).abs() / 4.623 < 0.15, "ag power {}", m.ag_power_mw);
    }

    #[test]
    fn pc_total_matches_table2() {
        // Table 2: PC total = 0.2235 mm², 132.62 mW (per tile).
        let m = AreaModel::build(&HardwareConfig::paper());
        let pc_area: f64 = m.pc_rows.iter().map(ComponentRow::total_area).sum();
        let pc_power: f64 = m.pc_rows.iter().map(ComponentRow::total_power).sum();
        assert!((pc_area - 0.2235).abs() / 0.2235 < 0.05, "pc area {pc_area}");
        assert!((pc_power - 132.62).abs() / 132.62 < 0.05, "pc power {pc_power}");
    }

    #[test]
    fn bigger_crossbars_bigger_chip() {
        let small = AreaModel::build(&HardwareConfig::paper());
        let big = AreaModel::build(&HardwareConfig { crossbar_size: 128, ..HardwareConfig::paper() });
        assert!(big.chip_area_mm2 > small.chip_area_mm2);
        assert!(big.chip_power_mw > small.chip_power_mw);
    }
}
