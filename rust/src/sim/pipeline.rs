//! The Step 1–4 dataflow pipeline (Fig. 7) with write/compute overlap.
//!
//! Timeline construction (all times ns, per batch):
//!
//! ```text
//! t=0   X arrives in the Input Buffer (transfer-in)
//! Step1 pruning:  Q(X)→Q(Xᵀ) write ∥ VMM-1 → VMM-2 → SU/BU → ReCAM
//! Step2 ∥ Step1:  M = X·W_S (ROA) ∥ V = X·W_V (ROA) ∥ write Xᵀ (WEA)
//! Step3 after max(Step1, Step2): SDDMM S = mask⊙(M·Xᵀ) ∥ write V
//! Step4 after Step3 + softmax, and after V write lands: SpMM Z = S·V
//! ```
//!
//! The paper's central claims live here: Step1 ∥ Step2 (the W_S folding
//! removes the Q dependency), writes hidden behind compute (Fig. 4c), and
//! the wait-for-write accounting of Fig. 15.

use crate::attention::Precision;
use crate::config::{HardwareConfig, ModelConfig};
use crate::sparse::{DispatchPlan, MaskMatrix};

use super::cost::{self, VmmOp};
use super::energy::{Component, EnergyMeter};
use super::{pruning, sddmm, spmm};

/// Execution mode of the attention calculation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Full CPSAA: pruning + masked SDDMM/SpMM.
    Sparse,
    /// CPDAA (Fig. 14): same calculation mode, all-ones mask, no pruning.
    Dense,
}

/// Per-phase wall-clock + overlap accounting for one batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseBreakdown {
    /// Step 1 (pruning) duration; 0 in dense mode.
    pub prune_ns: f64,
    /// Step 2 compute (max of M and V VMMs).
    pub step2_ns: f64,
    /// Step 3 SDDMM compute.
    pub step3_ns: f64,
    /// Softmax-unit pass over S.
    pub softmax_ns: f64,
    /// Step 4 SpMM compute.
    pub step4_ns: f64,
    /// Time compute spent stalled on ReRAM writes (Fig. 15 W4W).
    pub wait_for_write_ns: f64,
    /// On-chip transfer time on the critical path (Fig. 18b component).
    pub transfer_ns: f64,
    /// Control/scheduling time on the critical path (Fig. 18d component).
    pub ctrl_ns: f64,
    /// End-to-end batch latency.
    pub total_ns: f64,
    /// Peak concurrent VMM operations — the Fig. 15 parallelism metric
    /// (CPSAA runs M ∥ V, plus the pruning VMMs in sparse mode).
    pub peak_parallel_arrays: u64,
}

/// One named interval on the Step 1–4 timeline of a simulated batch.
/// Events carry absolute timestamps (ns from batch arrival), so a dump
/// of them reconstructs the Fig. 7 overlap structure — which stages ran
/// concurrently, where the critical path sat — without re-deriving the
/// scheduling from the phase totals.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageEvent {
    /// Stable stage name (`transfer_in`, `prune`, `step2_vmm`,
    /// `write_xt`, `step3_sddmm`, `write_v`, `softmax`, `step4_spmm`,
    /// `transfer_out`).
    pub stage: &'static str,
    /// Start of the interval (ns since the batch hit the input buffer).
    pub start_ns: f64,
    /// End of the interval (ns).
    pub end_ns: f64,
}

/// Full pipeline result for one batch.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    pub breakdown: PhaseBreakdown,
    pub energy: EnergyMeter,
    pub mask_density: f64,
    /// The stage timeline behind the breakdown, in start order.
    pub events: Vec<StageEvent>,
}

/// Simulate one batch through the Step 1–4 pipeline. Builds the
/// effective mask's [`DispatchPlan`] once; callers already holding the
/// batch plan (the coordinator) use [`simulate_batch_planned`].
pub fn simulate_batch(
    hw: &HardwareConfig,
    model: &ModelConfig,
    mask: &MaskMatrix,
    mode: Mode,
) -> PipelineReport {
    simulate_batch_prec(hw, model, mask, mode, Precision::F32)
}

/// [`simulate_batch`] at an explicit kernel [`Precision`].
pub fn simulate_batch_prec(
    hw: &HardwareConfig,
    model: &ModelConfig,
    mask: &MaskMatrix,
    mode: Mode,
    precision: Precision,
) -> PipelineReport {
    let plan = match mode {
        Mode::Sparse => mask.plan(),
        // CPDAA (Fig. 14): same calculation mode over an all-ones mask.
        Mode::Dense => MaskMatrix::ones(mask.rows(), mask.cols()).plan(),
    };
    simulate_batch_planned_prec(hw, model, &plan, mode, precision)
}

/// Simulate one batch over a prebuilt plan. The plan must describe the
/// *effective* mask of the mode (all-ones for [`Mode::Dense`]); every
/// engine below reads its statistics from this one plan.
pub fn simulate_batch_planned(
    hw: &HardwareConfig,
    model: &ModelConfig,
    plan: &DispatchPlan,
    mode: Mode,
) -> PipelineReport {
    simulate_batch_planned_prec(hw, model, plan, mode, Precision::F32)
}

/// [`simulate_batch_planned`] at an explicit kernel [`Precision`]:
/// `I8` halves the Step-3 SDDMM crossbar pass (8-bit instead of 16-bit
/// bit-serial input streaming — half the DAC pulses, half the ADC
/// conversions per dot). Everything downstream of the dequantized
/// scores (softmax, the f32 SpMM over V) is unchanged, matching the
/// functional i8 kernel.
pub fn simulate_batch_planned_prec(
    hw: &HardwareConfig,
    model: &ModelConfig,
    plan: &DispatchPlan,
    mode: Mode,
    precision: Precision,
) -> PipelineReport {
    let n = model.seq_len;
    let d = model.d_model;
    // The chip simulates one attention head (§5: d_K = d_Q = 64): V and Z
    // are n×d_k. The functional golden model keeps the concatenated
    // full-width W_V; only the cost model is per-head.
    let dv = model.d_k;
    let mut energy = EnergyMeter::new();

    // ---- transfer in: X from the previous layer / DTC --------------------
    let (xfer_in_ns, xfer_in_pj) = cost::transfer(hw, (n * d * 4) as u64);
    energy.add(Component::Transfer, xfer_in_pj);
    let t0 = xfer_in_ns;

    // ---- Step 1: pruning (parallel with Step 2) ---------------------------
    let prune_end = if mode == Mode::Sparse {
        let p = pruning::simulate_planned(hw, model, plan);
        energy.add(Component::Crossbar, p.energy_pj * 0.6);
        energy.add(Component::Adc, p.energy_pj * 0.2);
        energy.add(Component::Write, p.energy_pj * 0.2);
        t0 + p.total_ns
    } else {
        t0
    };

    // ---- Step 2: M = X·W_S ∥ V = X·W_V ∥ write Xᵀ -------------------------
    // W_S (d×d) takes the bulk of the ROA; the small per-head W_V (d×d_k)
    // and Q(W_S) replicas share the rest. Read-only weights replicate
    // freely (pre-stored copies).
    let roa = cost::roa_arrays(hw);
    let m_cost = cost::vmm_cost(hw, VmmOp { n, k: d, m: d }, roa);
    let v_cost = cost::vmm_cost(hw, VmmOp { n, k: d, m: dv }, roa / 4);
    let step2_compute = m_cost.ns.max(v_cost.ns);
    add_vmm_energy(&mut energy, m_cost.pj + v_cost.pj);

    let xt_write = cost::write_matrix_ns(hw, d, n);
    energy.add(Component::Write, cost::write_matrix_pj(hw, d, n));

    // Step 3 needs M (compute) *and* Xᵀ (write): stall = write overhang.
    let step2_end = t0 + step2_compute.max(xt_write);
    let w4w_step2 = (xt_write - step2_compute).max(0.0);

    // ---- Step 3: SDDMM ∥ write V ------------------------------------------
    // M streams from the AG output registers to the SDDMM input registers
    // — an AIT-routed intra-tile move touching ~1/8 of the OCI distance.
    let (xfer_m_ns, xfer_m_pj) = cost::transfer(hw, (n * d * 4 / 8) as u64);
    energy.add(Component::Transfer, xfer_m_pj);

    let mut sd = sddmm::simulate_plan(hw, plan, d);
    if precision == Precision::I8 {
        sd.compute_ns *= 0.5;
        sd.energy_pj *= 0.5;
    }
    energy.add(Component::Crossbar, sd.energy_pj * 0.55);
    energy.add(Component::Adc, sd.energy_pj * 0.3);
    energy.add(Component::Recam, sd.energy_pj * 0.15);

    let step3_start = prune_end.max(step2_end) + xfer_m_ns;
    // ReCAM scheduling pipelines with dispatch; ctrl shows on the critical
    // path only for its non-overlapped fraction.
    let sd_total = sd.compute_ns.max(sd.schedule_ns);
    let step3_end = step3_start + sd_total;

    let v_write = cost::write_matrix_ns(hw, n, dv);
    energy.add(Component::Write, cost::write_matrix_pj(hw, n, dv));
    let v_write_end = step2_end + v_write; // starts as soon as V computed

    // ---- softmax ------------------------------------------------------------
    // One SU per tile; score rows are distributed across tiles, so the SU
    // pass pipelines n/tiles rows per unit.
    let softmax_ns = (n as f64 / hw.tiles as f64 + 4.0) * hw.cycle_ns;
    energy.add(Component::Peripheral, n as f64 * 1.134 * hw.cycle_ns);

    // ---- Step 4: SpMM --------------------------------------------------------
    // Dense mode degenerates to the resident-V streaming path (nothing to
    // select ⇒ replication buys nothing); sparse mode uses the §4.4
    // replicated mapping.
    let sp = spmm::simulate_plan(hw, plan, dv);
    let (sp_compute_ns, sp_schedule_ns, sp_pj) = match mode {
        Mode::Sparse => (sp.compute_ns, sp.schedule_ns, sp.energy_pj),
        Mode::Dense => (sp.baseline_cycles as f64 * hw.cycle_ns, 0.0, sp.baseline_pj),
    };
    energy.add(Component::Crossbar, sp_pj * 0.5);
    energy.add(Component::Adc, sp_pj * 0.25);
    energy.add(Component::Write, sp_pj * 0.25);

    let ready_for_spmm = step3_end + softmax_ns;
    // V replication mapping (schedule) overlaps SDDMM+softmax; only the
    // overhang stalls.
    let map_end = step3_start + sp_schedule_ns;
    let step4_start = ready_for_spmm.max(v_write_end).max(map_end);
    let w4w_step4 = (v_write_end - ready_for_spmm).max(0.0)
        + (map_end - ready_for_spmm.max(v_write_end)).max(0.0);
    let step4_end = step4_start + sp_compute_ns;

    // ---- transfer out: Z to the FC layer ------------------------------------
    let (xfer_out_ns, xfer_out_pj) = cost::transfer(hw, (n * dv * 4) as u64);
    energy.add(Component::Transfer, xfer_out_pj);
    let total_ns = step4_end + xfer_out_ns;

    // Static chip power over the batch window (STATIC_SHARE of the
    // Table 2 budget — clocks, buffers, drivers idle-burn).
    let chip_mw = crate::sim::area::AreaModel::build(hw).chip_power_mw;
    energy.add(Component::Static, chip_mw * cost::STATIC_SHARE * total_ns);

    // Peak concurrent VMM operations: M ∥ V in Step 2 (the calculation
    // mode's headline parallelism), plus the pruning VMM running
    // alongside in sparse mode.
    let peak = match mode {
        Mode::Sparse => 3,
        Mode::Dense => 2,
    };

    let ctrl_critical = if hw.ideal.no_ctrl_latency {
        0.0
    } else {
        (sd.schedule_ns - sd.compute_ns).max(0.0) + (sp_schedule_ns - sp_compute_ns).max(0.0)
    };

    // The absolute timeline the numbers above were derived from, for
    // `--trace` dumps: every interval at its scheduled start/end, so
    // overlaps (Step1 ∥ Step2, writes behind compute) stay visible.
    let mut events = vec![StageEvent { stage: "transfer_in", start_ns: 0.0, end_ns: t0 }];
    if mode == Mode::Sparse {
        events.push(StageEvent { stage: "prune", start_ns: t0, end_ns: prune_end });
    }
    events.push(StageEvent { stage: "step2_vmm", start_ns: t0, end_ns: t0 + step2_compute });
    events.push(StageEvent { stage: "write_xt", start_ns: t0, end_ns: t0 + xt_write });
    events.push(StageEvent { stage: "step3_sddmm", start_ns: step3_start, end_ns: step3_end });
    events.push(StageEvent { stage: "write_v", start_ns: step2_end, end_ns: v_write_end });
    events.push(StageEvent {
        stage: "softmax",
        start_ns: step3_end,
        end_ns: step3_end + softmax_ns,
    });
    events.push(StageEvent { stage: "step4_spmm", start_ns: step4_start, end_ns: step4_end });
    events.push(StageEvent { stage: "transfer_out", start_ns: step4_end, end_ns: total_ns });

    PipelineReport {
        breakdown: PhaseBreakdown {
            prune_ns: prune_end - t0,
            step2_ns: step2_compute,
            step3_ns: sd_total,
            softmax_ns,
            step4_ns: sp_compute_ns,
            wait_for_write_ns: w4w_step2 + w4w_step4,
            transfer_ns: xfer_in_ns + xfer_m_ns + xfer_out_ns,
            ctrl_ns: ctrl_critical,
            total_ns,
            peak_parallel_arrays: peak,
        },
        energy,
        mask_density: plan.density(),
        events,
    }
}

fn add_vmm_energy(energy: &mut EnergyMeter, pj: f64) {
    energy.add(Component::Crossbar, pj * 0.5);
    energy.add(Component::Adc, pj * 0.35);
    energy.add(Component::Dac, pj * 0.15);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn setup(density: f64) -> (HardwareConfig, ModelConfig, MaskMatrix) {
        let hw = HardwareConfig::paper();
        let model = ModelConfig::paper();
        let mask = MaskMatrix::from_dense(
            &SeededRng::new(1).mask_matrix(model.seq_len, model.seq_len, density),
        );
        (hw, model, mask)
    }

    #[test]
    fn sparse_faster_than_dense() {
        let (hw, model, mask) = setup(0.1);
        let s = simulate_batch(&hw, &model, &mask, Mode::Sparse);
        let d = simulate_batch(&hw, &model, &mask, Mode::Dense);
        assert!(
            s.breakdown.total_ns < d.breakdown.total_ns,
            "sparse {} dense {}",
            s.breakdown.total_ns,
            d.breakdown.total_ns
        );
    }

    #[test]
    fn pruning_overlaps_attention() {
        // Step 1 must not extend the critical path when it is shorter than
        // Step 2: total(sparse) - total(dense-without-mask-saving) stays
        // bounded by the SDDMM/SpMM savings, not inflated by prune_ns.
        let (hw, model, mask) = setup(0.1);
        let s = simulate_batch(&hw, &model, &mask, Mode::Sparse);
        assert!(s.breakdown.prune_ns > 0.0);
        // The prune phase and step2 overlap: the critical path contains
        // max(prune, step2), so total < serial sum of all phases.
        let serial: f64 = s.breakdown.prune_ns
            + s.breakdown.step2_ns
            + s.breakdown.step3_ns
            + s.breakdown.softmax_ns
            + s.breakdown.step4_ns
            + s.breakdown.transfer_ns
            + s.breakdown.wait_for_write_ns;
        assert!(s.breakdown.total_ns < serial);
    }

    #[test]
    fn total_at_least_each_phase() {
        let (hw, model, mask) = setup(0.1);
        let r = simulate_batch(&hw, &model, &mask, Mode::Sparse);
        let b = r.breakdown;
        for phase in [b.prune_ns, b.step2_ns, b.step3_ns, b.step4_ns] {
            assert!(b.total_ns >= phase);
        }
    }

    #[test]
    fn ideal_write_reduces_w4w_to_zero() {
        let (mut hw, model, mask) = setup(0.1);
        hw.ideal.no_write_latency = true;
        let r = simulate_batch(&hw, &model, &mask, Mode::Sparse);
        assert_eq!(r.breakdown.wait_for_write_ns, 0.0);
    }

    #[test]
    fn every_ideal_knob_helps() {
        let (hw, model, mask) = setup(0.1);
        let base = simulate_batch(&hw, &model, &mask, Mode::Sparse).breakdown.total_ns;
        for knob in 0..4 {
            let mut h = hw.clone();
            match knob {
                0 => h.ideal.no_write_latency = true,
                1 => h.ideal.no_transfer_latency = true,
                2 => h.ideal.infinite_adcs = true,
                _ => h.ideal.no_ctrl_latency = true,
            }
            let t = simulate_batch(&h, &model, &mask, Mode::Sparse).breakdown.total_ns;
            assert!(t <= base, "knob {knob}: {t} > {base}");
        }
    }

    #[test]
    fn denser_masks_cost_more() {
        let (hw, model, _) = setup(0.0);
        let mk = |d| {
            MaskMatrix::from_dense(
                &SeededRng::new(2).mask_matrix(model.seq_len, model.seq_len, d),
            )
        };
        let lo = simulate_batch(&hw, &model, &mk(0.05), Mode::Sparse);
        let hi = simulate_batch(&hw, &model, &mk(0.5), Mode::Sparse);
        assert!(hi.breakdown.total_ns > lo.breakdown.total_ns);
        assert!(hi.energy.total_pj() > lo.energy.total_pj());
    }

    #[test]
    fn i8_precision_cheapens_step3() {
        let (hw, model, mask) = setup(0.1);
        let f = simulate_batch(&hw, &model, &mask, Mode::Sparse);
        let q = simulate_batch_prec(&hw, &model, &mask, Mode::Sparse, Precision::I8);
        // Step-3 never lengthens (compute halves; ReCAM scheduling may
        // still dominate) and energy strictly drops.
        assert!(q.breakdown.step3_ns <= f.breakdown.step3_ns);
        assert!(q.breakdown.total_ns <= f.breakdown.total_ns);
        assert!(
            q.energy.total_pj() < f.energy.total_pj(),
            "i8 {} vs f32 {}",
            q.energy.total_pj(),
            f.energy.total_pj()
        );
        // F32 is the literal legacy path.
        let f2 = simulate_batch_prec(&hw, &model, &mask, Mode::Sparse, Precision::F32);
        assert_eq!(f.breakdown.total_ns, f2.breakdown.total_ns);
        assert_eq!(f.energy.total_pj(), f2.energy.total_pj());
    }

    #[test]
    fn stage_events_cover_the_breakdown_timeline() {
        let (hw, model, mask) = setup(0.1);
        for mode in [Mode::Sparse, Mode::Dense] {
            let r = simulate_batch(&hw, &model, &mask, mode);
            assert!(!r.events.is_empty());
            // Well-formed intervals inside the batch window.
            for e in &r.events {
                assert!(e.end_ns >= e.start_ns, "{}: inverted interval", e.stage);
                assert!(e.start_ns >= 0.0 && e.end_ns <= r.breakdown.total_ns + 1e-9);
            }
            // The dense timeline carries no prune stage; sparse does.
            let has_prune = r.events.iter().any(|e| e.stage == "prune");
            assert_eq!(has_prune, mode == Mode::Sparse);
            // Anchors: the timeline starts at transfer-in and its last
            // event ends exactly at the batch total.
            assert_eq!(r.events[0].stage, "transfer_in");
            assert_eq!(r.events[0].start_ns, 0.0);
            let last = r.events.last().unwrap();
            assert_eq!(last.stage, "transfer_out");
            assert_eq!(last.end_ns, r.breakdown.total_ns);
            // Stage totals agree with the breakdown the figures use.
            let ev = |s: &str| {
                r.events.iter().find(|e| e.stage == s).map(|e| e.end_ns - e.start_ns)
            };
            assert_eq!(ev("step3_sddmm"), Some(r.breakdown.step3_ns));
            assert_eq!(ev("softmax"), Some(r.breakdown.softmax_ns));
            assert_eq!(ev("step4_spmm"), Some(r.breakdown.step4_ns));
        }
    }

    #[test]
    fn energy_positive_all_modes() {
        let (hw, model, mask) = setup(0.1);
        for mode in [Mode::Sparse, Mode::Dense] {
            let r = simulate_batch(&hw, &model, &mask, mode);
            assert!(r.energy.total_pj() > 0.0);
        }
    }
}
