//! ReRAM write-endurance model (§5: "considering up to 10¹² ReRAM write
//! endurance, CPSAA can achieve hundreds of millions of inferences").
//!
//! Tracks how many times each runtime-written cell class is programmed
//! per inference and converts the paper's endurance rating into a chip
//! lifetime, with and without wear-leveling [47].

use crate::config::{HardwareConfig, ModelConfig};

/// Cell write endurance rating (cycles) — 10¹² per [56].
pub const ENDURANCE_CYCLES: f64 = 1e12;

/// Per-inference write traffic by destination.
#[derive(Clone, Copy, Debug)]
pub struct WriteTraffic {
    /// Xᵀ cells written per layer (full precision).
    pub xt_bits: u64,
    /// Q(Xᵀ) cells written per layer (quantized).
    pub qxt_bits: u64,
    /// V cells written per layer.
    pub v_bits: u64,
    /// SpMM replication cells written per layer (mask-dependent; uses the
    /// characterized density).
    pub replication_bits: u64,
    /// ReCAM mask cells written per layer.
    pub recam_bits: u64,
}

impl WriteTraffic {
    /// Traffic for one encoder layer at the given mask density.
    pub fn per_layer(model: &ModelConfig, density: f64) -> Self {
        let n = model.seq_len as u64;
        let d = model.d_model as u64;
        let dk = model.d_k as u64;
        let vb = 32u64;
        let nnz = (density * (n * n) as f64) as u64;
        Self {
            xt_bits: n * d * vb,
            qxt_bits: n * d * model.quant_bits as u64,
            v_bits: n * dk * vb,
            replication_bits: nnz * dk * vb,
            recam_bits: n * n,
        }
    }

    pub fn total_bits(&self) -> u64 {
        self.xt_bits + self.qxt_bits + self.v_bits + self.replication_bits + self.recam_bits
    }
}

/// Lifetime estimate for the write-enable array pool.
#[derive(Clone, Copy, Debug)]
pub struct LifetimeEstimate {
    /// Writes landing on the hottest cell per inference (no leveling).
    pub hot_cell_writes_per_inference: f64,
    /// Inferences until the hottest cell wears out (no leveling).
    pub inferences_unleveled: f64,
    /// Inferences with ideal wear-leveling (writes spread over the pool).
    pub inferences_leveled: f64,
}

/// Estimate chip lifetime for a `layers`-encoder model.
pub fn estimate(hw: &HardwareConfig, model: &ModelConfig, density: f64) -> LifetimeEstimate {
    let per_layer = WriteTraffic::per_layer(model, density);
    let per_inference_bits = per_layer.total_bits() as f64 * model.layers as f64;

    // Unleveled: the Xᵀ region is rewritten in place every batch — each
    // of its cells sees exactly one write per layer per inference.
    let hot_writes = model.layers as f64;
    let inferences_unleveled = ENDURANCE_CYCLES / hot_writes;

    // Leveled: writes rotate across every WEA cell [47].
    let wea_cells = (hw.tiles * hw.wea_per_tile * hw.arrays_per_ag) as f64
        * (hw.crossbar_size * hw.crossbar_size) as f64;
    let writes_per_cell = per_inference_bits / wea_cells;
    let inferences_leveled = ENDURANCE_CYCLES / writes_per_cell.max(f64::MIN_POSITIVE);

    LifetimeEstimate {
        hot_cell_writes_per_inference: hot_writes,
        inferences_unleveled,
        inferences_leveled,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (HardwareConfig, ModelConfig) {
        (HardwareConfig::paper(), ModelConfig::paper())
    }

    #[test]
    fn paper_claim_hundreds_of_millions() {
        // §5: "CPSAA can achieve hundreds of millions of inferences" —
        // even the unleveled bound clears 10⁸ for a 12-layer BERT.
        let (hw, m) = setup();
        let l = estimate(&hw, &m, 0.1);
        assert!(l.inferences_unleveled > 1e8, "unleveled {}", l.inferences_unleveled);
        assert!(l.inferences_leveled >= l.inferences_unleveled);
    }

    #[test]
    fn traffic_scales_with_density() {
        let (_, m) = setup();
        let lo = WriteTraffic::per_layer(&m, 0.05);
        let hi = WriteTraffic::per_layer(&m, 0.5);
        assert!(hi.replication_bits > lo.replication_bits);
        assert_eq!(hi.xt_bits, lo.xt_bits); // density-independent
    }

    #[test]
    fn more_layers_wear_faster() {
        let (hw, m) = setup();
        let short = estimate(&hw, &ModelConfig { layers: 2, ..m.clone() }, 0.1);
        let deep = estimate(&hw, &ModelConfig { layers: 24, ..m }, 0.1);
        assert!(deep.inferences_unleveled < short.inferences_unleveled);
    }

    #[test]
    fn quantized_traffic_smaller_than_full() {
        let (_, m) = setup();
        let t = WriteTraffic::per_layer(&m, 0.1);
        assert!(t.qxt_bits < t.xt_bits);
        assert_eq!(t.qxt_bits * 8, t.xt_bits); // 4-bit vs 32-bit
    }
}
