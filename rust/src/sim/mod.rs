//! Cycle-accurate CPSAA chip simulator — the paper's evaluation substrate.
//!
//! The paper evaluates CPSAA with "a Python cycle-accurate simulator"
//! (§5) plus SPICE/CACTI constants (Table 2). This module re-implements
//! that simulator in rust:
//!
//! * [`cost`] — the analytical crossbar cost primitives every engine
//!   shares: VMM activation counts, ADC serialization, write scheduling,
//!   on-chip transfers. All formulas live here, documented, so the
//!   calibration/perf pass touches one file.
//! * [`reram`] / [`recam`] — array-level models (VMM activations, ReCAM
//!   row-search coordinate streams).
//! * [`sddmm`] / [`spmm`] / [`pruning`] — the paper's three engine
//!   contributions (§4.3, §4.4, §4.2-Step1) as dispatch simulators over
//!   real masks.
//! * [`pipeline`] — the Step1–4 dataflow with write/compute overlap and
//!   the pruning ∥ attention parallelism (Fig. 7); produces per-phase
//!   breakdowns and wait-for-write accounting (Figs. 14/15/18).
//! * [`energy`] / [`area`] — Table 2 roll-ups and per-run energy meters.
//! * [`chip`] — top level: simulate one batch / one trace, report GOPS,
//!   GOPS/W, and component breakdowns.

pub mod application;
pub mod area;
pub mod chip;
pub mod cost;
pub mod endurance;
pub mod energy;
pub mod pipeline;
pub mod pruning;
pub mod recam;
pub mod reram;
pub mod sddmm;
pub mod spmm;

pub use chip::{
    ChipSim, HeadsSimReport, OverlapCost, PlanEvolutionCost, ShardedSimReport, SimReport, SimTrace,
    TraceReport,
};
pub use energy::EnergyMeter;
pub use pipeline::{PhaseBreakdown, StageEvent};
