//! Fig. 16 — CPSAA's PIM pruning vs SANGER's software pruning.
//!
//! Paper: SANGER/CPSAA = 85.1× Pruning-T, 18.7× Attention-T, 16.37×
//! VMM-N, 11.4× CTRL-T, and < 0.2% accuracy loss.

use crate::attention::{self, Weights};
use crate::baselines::asic::Sanger;
use crate::baselines::Platform;
use crate::config::{ModelConfig, SystemConfig};
use crate::sim::{pruning, sddmm, spmm, ChipSim};
use crate::tensor::SeededRng;
use crate::workload::TraceGenerator;

use super::Table;

pub fn run(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig16",
        "SANGER / CPSAA pruning comparison (ratios, SANGER over CPSAA)",
        &["Pruning-T", "Attention-T", "VMM-N", "CTRL-T", "Accuracy"],
    );
    let sanger = Sanger::default();
    let detail = sanger.pruning_detail(&cfg.model);
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let cpsaa = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());

    // Means over the five-dataset subset.
    let mut prune_ratio = 0.0;
    let mut att_ratio = 0.0;
    let mut ctrl_ratio = 0.0;
    let datasets = cfg.workload.five();
    for ds in &datasets {
        let trace = gen.generate(ds);
        let batch = &trace.batches[0];
        let stats = batch.stats();

        // CPSAA pruning phase.
        let p = pruning::simulate(&cfg.hardware, &cfg.model);
        prune_ratio += detail.pruning_ns / p.total_ns;

        // Attention phases.
        let s = sanger.run_batch(&cfg.model, &stats);
        let c = cpsaa.simulate_batch(&batch.mask);
        let c_att = c.breakdown.total_ns - c.breakdown.prune_ns.min(c.breakdown.total_ns * 0.5);
        att_ratio += (s.atca.0 + s.atca.1) / c_att;

        // CTRL: split-and-pack per-row reconfiguration vs ReCAM dispatch.
        let sd = sddmm::simulate(&cfg.hardware, &batch.mask, cfg.model.d_model);
        let sp = spmm::simulate(&cfg.hardware, &batch.mask, cfg.model.d_model);
        let cpsaa_ctrl =
            (sd.schedule_ns + sp.schedule_ns - sp.replication_write_ns).max(1e-9);
        ctrl_ratio += detail.ctrl_ns / cpsaa_ctrl;
    }
    let n = datasets.len() as f64;

    // VMM-N: serial VMM dispatch rounds — SANGER streams 3n row passes
    // (Q gen, K gen, Q·Kᵀ); CPSAA needs the two eq. 4 matmuls' rounds.
    let p = pruning::simulate(&cfg.hardware, &cfg.model);
    let vmm_ratio = detail.vmm_ops as f64 / (p.vmm_rounds as f64).max(1.0);

    // Accuracy: output fidelity of the quantized CPSAA mask vs SANGER's
    // full-precision prediction mask, measured on the golden model.
    let acc_ratio = accuracy_ratio(&cfg.model);

    t.push(
        "MEAN",
        vec![prune_ratio / n, att_ratio / n, vmm_ratio, ctrl_ratio / n, acc_ratio],
    );
    t.note("paper: 85.1x, 18.7x, 16.37x, 11.4x, accuracy loss < 0.2% (ratio ~= 1.0)");
    t
}

/// SANGER-mask accuracy over CPSAA-mask accuracy (≈ 1.0 when the quantized
/// pruning loses nothing). "Accuracy" proxy: 1 − relative output error vs
/// the dense full-precision attention.
fn accuracy_ratio(model: &ModelConfig) -> f64 {
    let small = ModelConfig { seq_len: 64, d_model: 128, ..model.clone() };
    let w = Weights::synthetic(&small, 0);
    let x = SeededRng::new(11).normal_matrix(small.seq_len, small.d_model, 1.0);
    let dense = attention::dense_attention(&x, &w.w_s, &w.w_v, &small);

    // CPSAA: quantized pruning (eq. 4).
    let mask_q = attention::generate_mask(&x, &w.w_s, &small);
    let z_q = attention::cpsaa_attention(&x, &w.w_s, &w.w_v, &mask_q, &small);

    // SANGER: full-precision prediction with the same threshold.
    let full_cfg = ModelConfig { quant_bits: 16, gamma: 64.0, ..small.clone() };
    let mask_fp = attention::generate_mask(&x, &w.w_s, &full_cfg);
    let z_fp = attention::cpsaa_attention(&x, &w.w_s, &w.w_v, &mask_fp, &small);

    let acc = |z: &crate::tensor::Matrix| 1.0 - f64::from(z.rel_err(&dense)).min(1.0);
    acc(&z_fp) / acc(&z_q).max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_favor_cpsaa() {
        let t = run(&SystemConfig::paper());
        for h in ["Pruning-T", "Attention-T", "VMM-N", "CTRL-T"] {
            let v = t.get("MEAN", h).unwrap();
            assert!(v > 1.0, "{h} = {v} should exceed 1 (SANGER worse)");
        }
    }

    #[test]
    fn pruning_speedup_large() {
        // Paper: 85.1×. Accept the right order of magnitude.
        let t = run(&SystemConfig::paper());
        let v = t.get("MEAN", "Pruning-T").unwrap();
        assert!(v > 10.0 && v < 1000.0, "Pruning-T {v}");
    }

    #[test]
    fn accuracy_close_to_one() {
        // Paper: < 0.2% accuracy loss.
        let t = run(&SystemConfig::paper());
        let v = t.get("MEAN", "Accuracy").unwrap();
        assert!(v > 0.85 && v < 1.3, "Accuracy ratio {v}");
    }
}
