//! Fig. 20 — scalability: (a) dataset size (WNLI fractions), (b) encoder
//! layer count vs the GPU baseline.
//!
//! Paper: CPSAA throughput stays flat in both sweeps; GPU throughput
//! declines as layers grow.

use crate::baselines::{device, Platform};
use crate::config::{DatasetSpec, ModelConfig, SystemConfig};
use crate::sim::ChipSim;
use crate::workload::TraceGenerator;

use super::Table;

/// Fig. 20a: throughput (GOPS) vs WNLI fraction, CPSAA and GPU.
pub fn run_a(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig20a",
        "throughput (GOPS) vs dataset fraction (WNLI)",
        &["CPSAA", "GPU"],
    );
    let wnli = cfg.workload.dataset("WNLI").expect("WNLI in suite").clone();
    let sim = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
    let gpu = device::Gpu::default();
    for denom in [16usize, 8, 4, 2, 1] {
        let ds = DatasetSpec { sequences: (wnli.sequences / denom).max(1), ..wnli.clone() };
        let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed)
            .with_max_batches(8.min(ds.sequences));
        let trace = gen.generate(&ds);
        let r = sim.simulate_trace(&trace);
        let g: f64 = trace
            .batches
            .iter()
            .map(|b| gpu.run_batch(&cfg.model, &b.stats()).gops)
            .sum::<f64>()
            / trace.batches.len() as f64;
        t.push(format!("1/{denom}"), vec![r.mean_gops, g]);
    }
    t.note("paper: CPSAA throughput stable across dataset sizes (batches serialize)");
    t
}

/// Fig. 20b: throughput vs encoder layers (2..32), CPSAA vs GPU.
pub fn run_b(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig20b",
        "throughput (GOPS) vs encoder layers (WNLI)",
        &["CPSAA", "GPU"],
    );
    let wnli = cfg.workload.dataset("WNLI").expect("WNLI in suite");
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let trace = gen.generate(wnli);
    let batch = &trace.batches[0];
    let gpu = device::Gpu::default();
    for layers in [2usize, 4, 8, 16, 32] {
        let model = ModelConfig { layers, ..cfg.model.clone() };
        // CPSAA: every layer adds in-memory compute; more layers map to
        // more tiles — per-layer time constant, GOPS flat.
        let sim = ChipSim::new(cfg.hardware.clone(), model.clone());
        let per_layer = sim.simulate_batch(&batch.mask);
        let cpsaa_gops = model.attention_flops() as f64 * layers as f64
            / 1e9
            / (per_layer.breakdown.total_ns * layers as f64 * 1e-9);
        // GPU: each extra layer adds intermediate tensors that spill to
        // DRAM; effective bandwidth per layer degrades with depth.
        let mut total_ns = 0.0;
        for l in 0..layers {
            let r = gpu.run_batch(&model, &batch.stats());
            let pressure = 1.0 + 0.025 * l as f64; // growing working set
            total_ns += r.total_ns * pressure;
        }
        let gpu_gops = model.attention_flops() as f64 * layers as f64 / 1e9 / (total_ns * 1e-9);
        t.push(format!("{layers}L"), vec![cpsaa_gops, gpu_gops]);
    }
    t.note("paper: CPSAA flat, GPU declines as layers increase");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig20a_cpsaa_stable() {
        let t = run_a(&SystemConfig::paper());
        let vals: Vec<f64> = t.rows.iter().map(|(_, v)| v[0]).collect();
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        let min = vals.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min < 1.5, "CPSAA not stable: {vals:?}");
    }

    #[test]
    fn fig20b_gpu_declines_cpsaa_flat() {
        let t = run_b(&SystemConfig::paper());
        let first_gpu = t.rows.first().unwrap().1[1];
        let last_gpu = t.rows.last().unwrap().1[1];
        assert!(last_gpu < first_gpu, "GPU should decline: {first_gpu} -> {last_gpu}");
        let first_c = t.rows.first().unwrap().1[0];
        let last_c = t.rows.last().unwrap().1[0];
        assert!((first_c / last_c - 1.0).abs() < 0.2, "CPSAA should stay flat");
    }

    #[test]
    fn cpsaa_above_gpu_everywhere() {
        let t = run_b(&SystemConfig::paper());
        for (label, v) in &t.rows {
            assert!(v[0] > v[1], "{label}: CPSAA {} <= GPU {}", v[0], v[1]);
        }
    }
}
