//! Fig. 18 — ideal-situation study.
//!
//! Paper: removing (a) write latency, (b) on-chip transfer latency,
//! (c) ADC limits, (d) control latency improves throughput by 32.7%,
//! 23.4%, 104.8%, 19.1% respectively.

use crate::config::{IdealKnobs, SystemConfig};
use crate::sim::ChipSim;
use crate::workload::TraceGenerator;

use super::Table;

const KNOBS: [(&str, fn(&mut IdealKnobs)); 4] = [
    ("no-write", |k| k.no_write_latency = true),
    ("no-transfer", |k| k.no_transfer_latency = true),
    ("infinite-ADC", |k| k.infinite_adcs = true),
    ("no-ctrl", |k| k.no_ctrl_latency = true),
];

pub fn run(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig18",
        "ideal situations: throughput improvement (%) over baseline CPSAA",
        &["no-write", "no-transfer", "infinite-ADC", "no-ctrl"],
    );
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let base_sim = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());

    let datasets = cfg.workload.five();
    let mut means = [0.0f64; 4];
    for ds in &datasets {
        let trace = gen.generate(ds);
        let mask = &trace.batches[0].mask;
        let base = base_sim.simulate_batch(mask).breakdown.total_ns;
        let mut vals = [0.0f64; 4];
        for (i, (_, set)) in KNOBS.iter().enumerate() {
            let mut hw = cfg.hardware.clone();
            set(&mut hw.ideal);
            let ideal = ChipSim::new(hw, cfg.model.clone()).simulate_batch(mask);
            vals[i] = 100.0 * (base / ideal.breakdown.total_ns - 1.0);
            means[i] += vals[i] / datasets.len() as f64;
        }
        t.push(ds.name.clone(), vals.to_vec());
    }
    t.push("MEAN", means.to_vec());
    t.note("paper: +32.7% (write), +23.4% (transfer), +104.8% (ADC), +19.1% (ctrl)");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_knobs_non_negative() {
        let t = run(&SystemConfig::paper());
        for h in ["no-write", "no-transfer", "infinite-ADC", "no-ctrl"] {
            let v = t.get("MEAN", h).unwrap();
            assert!(v >= -1e-9, "{h} = {v}");
        }
    }

    #[test]
    fn adc_is_the_biggest_lever() {
        // Paper ordering: ADC (104.8%) dominates all other knobs.
        let t = run(&SystemConfig::paper());
        let adc = t.get("MEAN", "infinite-ADC").unwrap();
        for h in ["no-write", "no-transfer", "no-ctrl"] {
            assert!(adc >= t.get("MEAN", h).unwrap(), "ADC should dominate {h}");
        }
        assert!(adc > 20.0, "ADC improvement {adc} too small");
    }
}
