//! Figs. 11 & 12 — execution time / energy across platforms, normalized
//! to CPSAA, over the nine GLUE/SQuAD datasets.
//!
//! Paper headline: CPSAA is 89.6× / 32.2× / 17.8× / 3.39× / 3.84× faster
//! than GPU / FPGA / SANGER / ReBERT / ReTransformer and saves 755.6× /
//! 55.3× / 21.3× / 5.7× / 4.9× energy.

use crate::baselines::{asic, device, pim, Platform};
use crate::config::SystemConfig;
use crate::sim::ChipSim;
use crate::workload::TraceGenerator;

use super::Table;

pub(crate) struct PlatformRun {
    pub dataset: String,
    /// (name, total_ns, energy_pj) per platform; first entry is CPSAA.
    pub results: Vec<(&'static str, f64, f64)>,
}

pub(crate) fn run_platforms(cfg: &SystemConfig) -> Vec<PlatformRun> {
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let cpsaa = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
    let platforms: Vec<Box<dyn Platform>> = vec![
        Box::new(device::Gpu::default()),
        Box::new(device::Fpga::default()),
        Box::new(asic::Sanger::default()),
        Box::new(pim::ReBert::new(cfg.hardware.clone())),
        Box::new(pim::ReTransformer::new(cfg.hardware.clone())),
    ];
    cfg.workload
        .datasets
        .iter()
        .map(|ds| {
            let trace = gen.generate(ds);
            let batch = &trace.batches[0];
            let stats = batch.stats();
            let c = cpsaa.simulate_batch(&batch.mask);
            let mut results = vec![("CPSAA", c.breakdown.total_ns, c.energy_pj)];
            for p in &platforms {
                let r = p.run_batch(&cfg.model, &stats);
                results.push((r.name, r.total_ns, r.energy_pj));
            }
            PlatformRun { dataset: ds.name.clone(), results }
        })
        .collect()
}

/// Fig. 11: execution time normalized to CPSAA (CPSAA = 1).
pub fn run_time(cfg: &SystemConfig) -> Table {
    build(cfg, "fig11", "execution time normalized to CPSAA", |ns, _| ns)
}

/// Fig. 12: consumed energy normalized to CPSAA (CPSAA = 1).
pub fn run_energy(cfg: &SystemConfig) -> Table {
    build(cfg, "fig12", "consumed energy normalized to CPSAA", |_, pj| pj)
}

fn build(cfg: &SystemConfig, id: &str, title: &str, metric: fn(f64, f64) -> f64) -> Table {
    let runs = run_platforms(cfg);
    let headers: Vec<&str> = runs[0].results.iter().map(|(n, _, _)| *n).collect();
    let mut t = Table::new(id, title, &headers);
    let mut means = vec![0.0; headers.len()];
    for run in &runs {
        let base = metric(run.results[0].1, run.results[0].2).max(1e-12);
        let vals: Vec<f64> =
            run.results.iter().map(|&(_, ns, pj)| metric(ns, pj) / base).collect();
        for (m, v) in means.iter_mut().zip(&vals) {
            *m += v / runs.len() as f64;
        }
        t.push(run.dataset.clone(), vals);
    }
    t.push("MEAN", means);
    t.note(if id == "fig11" {
        "paper means: GPU 89.6, FPGA 32.2, SANGER 17.8, ReBERT 3.39, ReTransformer 3.84"
    } else {
        "paper means: GPU 755.6, FPGA 55.3, SANGER 21.3, ReBERT 5.7, ReTransformer 4.9"
    });
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpsaa_wins_everywhere() {
        let cfg = SystemConfig::paper();
        for table in [run_time(&cfg), run_energy(&cfg)] {
            for (label, vals) in &table.rows {
                assert!((vals[0] - 1.0).abs() < 1e-9, "{label}: CPSAA not 1.0");
                for (h, v) in table.headers.iter().zip(vals).skip(1) {
                    assert!(*v > 1.0, "{}: {h} = {v} should exceed CPSAA", label);
                }
            }
        }
    }

    #[test]
    fn ordering_matches_paper() {
        // GPU slowest, then FPGA, then SANGER, then the PIM platforms.
        let t = run_time(&SystemConfig::paper());
        let mean = |h: &str| t.get("MEAN", h).unwrap();
        assert!(mean("GPU") > mean("FPGA"));
        assert!(mean("FPGA") > mean("SANGER"));
        assert!(mean("SANGER") > mean("ReBERT"));
        assert!(mean("SANGER") > mean("ReTransformer"));
    }

    #[test]
    fn factors_within_shape_tolerance() {
        // "shape" reproduction: each platform's mean within ~4× of the
        // paper's reported factor.
        let t = run_time(&SystemConfig::paper());
        for (h, want) in
            [("GPU", 89.6), ("FPGA", 32.2), ("SANGER", 17.8), ("ReBERT", 3.39), ("ReTransformer", 3.84)]
        {
            let got = t.get("MEAN", h).unwrap();
            assert!(got > want / 4.0 && got < want * 4.0, "{h}: {got} vs paper {want}");
        }
    }
}
