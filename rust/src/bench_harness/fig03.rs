//! Fig. 3 — response-time breakdown of SANGER and DOTA (the motivation).
//!
//! Paper result: MA-GE ≈ 17.9% (SANGER) / 14.3% (DOTA) of response time,
//! of which ≈ 94.6% / 92.7% is memory; AT-CA memory share ≈ 71.2% / 63.5%.

use crate::baselines::{asic, Platform};
use crate::config::SystemConfig;
use crate::workload::TraceGenerator;

use super::Table;

pub fn run(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig3",
        "SANGER/DOTA response-time breakdown (fractions)",
        &["MA-GE-M", "MA-GE-P", "AT-CA-M", "AT-CA-P"],
    );
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let sanger = asic::Sanger::default();
    let dota = asic::Dota::default();
    for ds in cfg.workload.five() {
        let trace = gen.generate(ds);
        let stats = trace.batches[0].stats();
        for (plat, tag) in [(&sanger as &dyn Platform, "SANGER"), (&dota, "DOTA")] {
            let r = plat.run_batch(&cfg.model, &stats);
            let f = r.fig3_fractions();
            t.push(format!("{}/{}", tag, ds.name), f.to_vec());
        }
    }
    t.note("paper: SANGER MA-GE 17.9% (94.6% mem), AT-CA 82.1% (71.2% mem); DOTA 14.3%/92.7%/63.5%");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one_per_row() {
        let t = run(&SystemConfig::paper());
        assert_eq!(t.rows.len(), 10); // 5 datasets × 2 platforms
        for (label, vals) in &t.rows {
            let s: f64 = vals.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "{label}: {s}");
        }
    }

    #[test]
    fn memory_dominates_mage() {
        let t = run(&SystemConfig::paper());
        for (label, vals) in &t.rows {
            assert!(vals[0] > vals[1], "{label}: MA-GE should be memory-bound");
        }
    }
}
