//! Fig. 19 — (a) SDDMM speedup vs crossbar size; (b) SpMM method vs the
//! zero-gating baseline (memory utilization / throughput / replication).
//!
//! Paper: (a) speedup decays as the crossbar grows (use arrays matching
//! the value precision); (b) 9.36× memory utilization, 298× throughput,
//! at 30.4× data replication.

use crate::config::{HardwareConfig, SystemConfig};
use crate::sim::{sddmm, spmm};
use crate::workload::TraceGenerator;

use super::Table;

/// Fig. 19a: mean SDDMM-vs-DDMM speedup across datasets per crossbar size.
pub fn run_a(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig19a",
        "SDDMM speedup vs ReRAM DDMM, by crossbar size",
        &["speedup"],
    );
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let datasets = cfg.workload.datasets.clone();
    for c in [32usize, 64, 128, 256] {
        let hw = HardwareConfig { crossbar_size: c, ..cfg.hardware.clone() };
        let mut mean = 0.0;
        for ds in &datasets {
            let trace = gen.generate(ds);
            let r = sddmm::simulate(&hw, &trace.batches[0].mask, cfg.model.d_model);
            mean += (1.0 / r.latency_vs_dense()) / datasets.len() as f64;
        }
        t.push(format!("{c}x{c}"), vec![mean]);
    }
    t.note("paper: speedup decreases with crossbar size; match array size to value precision");
    t
}

/// Fig. 19b: SpMM-M / SpMM-T / SpMM-R vs the Fig. 9 baseline (= 1).
pub fn run_b(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig19b",
        "CPSAA SpMM vs zero-gating baseline (SpMM-B = 1)",
        &["SpMM-M", "SpMM-T", "SpMM-R"],
    );
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let mut means = [0.0f64; 3];
    let datasets = cfg.workload.five();
    for ds in &datasets {
        let trace = gen.generate(ds);
        let r = spmm::simulate(&cfg.hardware, &trace.batches[0].mask, cfg.model.d_model);
        let vals = [r.memory_utilization, r.throughput_vs_baseline(), r.replication_factor];
        for (m, v) in means.iter_mut().zip(vals) {
            *m += v / datasets.len() as f64;
        }
        t.push(ds.name.clone(), vals.to_vec());
    }
    t.push("MEAN", means.to_vec());
    t.note("paper: 9.36x memory utilization, 298x throughput, 30.4x replication");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig19a_monotone_decay() {
        let t = run_a(&SystemConfig::paper());
        let speedups: Vec<f64> = t.rows.iter().map(|(_, v)| v[0]).collect();
        for w in speedups.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "speedup should decay: {speedups:?}");
        }
        assert!(speedups[0] > 2.0, "32x32 speedup {}", speedups[0]);
    }

    #[test]
    fn fig19b_tradeoff_shape() {
        let t = run_b(&SystemConfig::paper());
        let m = t.get("MEAN", "SpMM-M").unwrap();
        let tp = t.get("MEAN", "SpMM-T").unwrap();
        let r = t.get("MEAN", "SpMM-R").unwrap();
        assert!(m > 1.0, "memory utilization {m}");
        assert!(tp > 10.0, "throughput {tp}");
        assert!(r > 1.0, "replication {r}");
        assert!(tp > r, "throughput gain should exceed replication cost");
    }
}
