//! Figs. 13–15 — calculation-mode studies.
//!
//! * Fig. 13: CPSAA vs the S-ReBERT / S-ReTransformer hybrids (sparse
//!   SpMM retrofitted onto the dense PIM modes): hybrids save energy,
//!   not time.
//! * Fig. 14: CPDAA (dense-mode CPSAA) vs ReBERT / ReTransformer —
//!   paper: 1.31× / 1.64× time, 1.30× / 1.21× energy vs CPDAA.
//! * Fig. 15: wait-for-write (W4W) and VMM parallelism normalized to
//!   ReTransformer — paper: ReBERT 1.94× / 2.88×, CPDAA 1.48× / 2.03×.

use crate::baselines::{pim, Platform};
use crate::config::SystemConfig;
use crate::sim::ChipSim;
use crate::workload::TraceGenerator;

use super::Table;

fn mean_over_datasets(
    cfg: &SystemConfig,
    mut f: impl FnMut(&crate::workload::Batch) -> Vec<f64>,
) -> Vec<f64> {
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
    let datasets = cfg.workload.five();
    let mut acc: Option<Vec<f64>> = None;
    for ds in &datasets {
        let trace = gen.generate(ds);
        let vals = f(&trace.batches[0]);
        match &mut acc {
            None => acc = Some(vals),
            Some(a) => {
                for (x, v) in a.iter_mut().zip(vals) {
                    *x += v;
                }
            }
        }
    }
    let n = datasets.len() as f64;
    acc.unwrap().into_iter().map(|v| v / n).collect()
}

/// Fig. 13: time and energy of S-ReBERT / S-ReTransformer vs CPSAA (=1).
pub fn run_fig13(cfg: &SystemConfig) -> Table {
    let cpsaa = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
    let srb = pim::ReBert::with_sparse_spmm(cfg.hardware.clone());
    let srt = pim::ReTransformer::with_sparse_spmm(cfg.hardware.clone());
    let vals = mean_over_datasets(cfg, |batch| {
        let stats = batch.stats();
        let c = cpsaa.simulate_batch(&batch.mask);
        let a = srb.run_batch(&cfg.model, &stats);
        let b = srt.run_batch(&cfg.model, &stats);
        vec![
            a.total_ns / c.breakdown.total_ns,
            b.total_ns / c.breakdown.total_ns,
            a.energy_pj / c.energy_pj,
            b.energy_pj / c.energy_pj,
        ]
    });
    let mut t = Table::new(
        "fig13",
        "S-ReBERT / S-ReTransformer normalized to CPSAA",
        &["S-ReBERT-T", "S-ReTran-T", "S-ReBERT-E", "S-ReTran-E"],
    );
    t.push("MEAN", vals);
    t.note("paper: 3.39x / 3.84x time, 4.87x / 4.58x energy vs CPSAA");
    t
}

/// Fig. 14: ReBERT / ReTransformer vs CPDAA (dense CPSAA), CPDAA = 1.
pub fn run_fig14(cfg: &SystemConfig) -> Table {
    let cpdaa = ChipSim::new(cfg.hardware.clone(), cfg.model.clone()).dense();
    let rb = pim::ReBert::new(cfg.hardware.clone());
    let rt = pim::ReTransformer::new(cfg.hardware.clone());
    let vals = mean_over_datasets(cfg, |batch| {
        let stats = batch.stats();
        let c = cpdaa.simulate_batch(&batch.mask);
        let a = rb.run_batch(&cfg.model, &stats);
        let b = rt.run_batch(&cfg.model, &stats);
        vec![
            a.total_ns / c.breakdown.total_ns,
            b.total_ns / c.breakdown.total_ns,
            a.energy_pj / c.energy_pj,
            b.energy_pj / c.energy_pj,
        ]
    });
    let mut t = Table::new(
        "fig14",
        "ReBERT / ReTransformer normalized to CPDAA (dense CPSAA)",
        &["ReBERT-T", "ReTran-T", "ReBERT-E", "ReTran-E"],
    );
    t.push("MEAN", vals);
    t.note("paper: ReBERT 1.31x time / 1.30x energy, ReTransformer 1.64x / 1.21x vs CPDAA");
    t
}

/// Fig. 15: W4W and parallelism normalized to ReTransformer (=1).
pub fn run_fig15(cfg: &SystemConfig) -> Table {
    let cpdaa = ChipSim::new(cfg.hardware.clone(), cfg.model.clone()).dense();
    let rb = pim::ReBert::new(cfg.hardware.clone());
    let rt = pim::ReTransformer::new(cfg.hardware.clone());
    let vals = mean_over_datasets(cfg, |batch| {
        let stats = batch.stats();
        let c = cpdaa.simulate_batch(&batch.mask);
        let a = rb.run_batch(&cfg.model, &stats);
        let b = rt.run_batch(&cfg.model, &stats);
        // Guard: if the serial chain fully hides its one write, floor the
        // base at 2% of its runtime so the ratios stay meaningful.
        let w_base = b.wait_for_write_ns.max(0.02 * b.total_ns);
        let p_base = b.peak_parallel_arrays.max(1) as f64;
        vec![
            a.wait_for_write_ns / w_base,
            c.breakdown.wait_for_write_ns / w_base,
            a.peak_parallel_arrays as f64 / p_base,
            c.breakdown.peak_parallel_arrays as f64 / p_base,
        ]
    });
    let mut t = Table::new(
        "fig15",
        "wait-for-write / VMM parallelism normalized to ReTransformer",
        &["ReBERT-W4W", "CPDAA-W4W", "ReBERT-P", "CPDAA-P"],
    );
    t.push("MEAN", vals);
    t.note("paper: ReBERT 1.94x W4W / 2.88x P; CPDAA 1.48x W4W / 2.03x P");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_hybrids_slower_than_cpsaa() {
        let t = run_fig13(&SystemConfig::paper());
        for h in ["S-ReBERT-T", "S-ReTran-T", "S-ReBERT-E", "S-ReTran-E"] {
            let v = t.get("MEAN", h).unwrap();
            assert!(v > 1.0, "{h} = {v}");
        }
    }

    #[test]
    fn fig14_cpdaa_wins_dense_comparison() {
        let t = run_fig14(&SystemConfig::paper());
        for h in ["ReBERT-T", "ReTran-T"] {
            let v = t.get("MEAN", h).unwrap();
            assert!(v > 1.0 && v < 6.0, "{h} = {v}");
        }
    }

    #[test]
    fn fig15_orderings() {
        let t = run_fig15(&SystemConfig::paper());
        let rb_w = t.get("MEAN", "ReBERT-W4W").unwrap();
        let cp_w = t.get("MEAN", "CPDAA-W4W").unwrap();
        // Paper shape: ReBERT waits longest (write-then-calculate).
        assert!(rb_w > cp_w, "rb {rb_w} cpdaa {cp_w}");
        assert!(rb_w > 1.0, "ReBERT should exceed the ReTransformer base: {rb_w}");
        let rb_p = t.get("MEAN", "ReBERT-P").unwrap();
        let cp_p = t.get("MEAN", "CPDAA-P").unwrap();
        assert!(rb_p > 1.0 && cp_p > 1.0, "parallelism above ReTransformer");
        assert!(rb_p > cp_p, "ReBERT has max parallelism");
    }
}
