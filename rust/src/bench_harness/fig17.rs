//! Fig. 17 — SDDMM/SpMM methods vs the DDMM operations in ReBERT.
//!
//! Paper: SDDMM latency 17.5% / energy 32.9% of DDMM; SpMM latency 0.54%
//! / energy 25.2% (all normalized to DDMM = 100).

use crate::config::SystemConfig;
use crate::sim::cost::{self, VmmOp};
use crate::sim::{sddmm, spmm};
use crate::workload::TraceGenerator;

use super::Table;

pub fn run(cfg: &SystemConfig) -> Table {
    let mut t = Table::new(
        "fig17",
        "SDDMM/SpMM vs ReBERT DDMM (percent of DDMM = 100)",
        &["SDDMM-T", "SDDMM-E", "SpMM-T", "SpMM-E"],
    );
    let hw = &cfg.hardware;
    let model = &cfg.model;
    let gen = TraceGenerator::new(model.clone(), cfg.workload.seed).with_max_batches(1);
    let n = model.seq_len;
    let d = model.d_model;

    let mut means = [0.0f64; 4];
    let datasets = cfg.workload.five();
    for ds in &datasets {
        let trace = gen.generate(ds);
        let mask = &trace.batches[0].mask;

        // DDMM references on the same shapes: the ReBERT-style dense VMM
        // maps each operand once (no replication — that scheduling is the
        // CPSAA contribution being measured).
        let ddmm_s =
            cost::vmm_cost_with_copies(hw, VmmOp { n, k: d, m: n }, cost::wea_arrays(hw) / 2, 1);
        let ddmm_z =
            cost::vmm_cost_with_copies(hw, VmmOp { n, k: n, m: d }, cost::wea_arrays(hw) / 2, 1);

        let sd = sddmm::simulate(hw, mask, d);
        let sp = spmm::simulate(hw, mask, d);

        let vals = [
            100.0 * sd.compute_ns / ddmm_s.ns,
            100.0 * sd.energy_pj / ddmm_s.pj,
            100.0 * sp.compute_ns / ddmm_z.ns,
            100.0 * sp.energy_pj / ddmm_z.pj,
        ];
        for (m, v) in means.iter_mut().zip(vals) {
            *m += v / datasets.len() as f64;
        }
        t.push(ds.name.clone(), vals.to_vec());
    }
    t.push("MEAN", means.to_vec());
    t.note("paper: SDDMM 17.5%T / 32.9%E, SpMM 0.54%T / 25.2%E of DDMM");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_methods_beat_ddmm_latency() {
        let t = run(&SystemConfig::paper());
        assert!(t.get("MEAN", "SDDMM-T").unwrap() < 100.0);
        assert!(t.get("MEAN", "SpMM-T").unwrap() < 100.0);
    }

    #[test]
    fn spmm_is_far_faster_than_sddmm() {
        // Paper shape: SpMM-T (0.54) ≪ SDDMM-T (17.5).
        let t = run(&SystemConfig::paper());
        let sd = t.get("MEAN", "SDDMM-T").unwrap();
        let sp = t.get("MEAN", "SpMM-T").unwrap();
        assert!(sp < sd, "SpMM {sp} should be faster than SDDMM {sd}");
    }

    #[test]
    fn energy_savings_present() {
        let t = run(&SystemConfig::paper());
        assert!(t.get("MEAN", "SDDMM-E").unwrap() < 100.0);
        assert!(t.get("MEAN", "SpMM-E").unwrap() < 150.0); // replication costs energy
    }
}
