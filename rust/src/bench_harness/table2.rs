//! Table 2 — CPSAA configuration: per-component area and power roll-up.

use crate::config::SystemConfig;
use crate::sim::area::AreaModel;

use super::Table;

pub fn run(cfg: &SystemConfig) -> Table {
    let m = AreaModel::build(&cfg.hardware);
    let mut t = Table::new(
        "table2",
        "CPSAA configuration (area mm^2, power mW)",
        &["area_mm2", "power_mW", "count"],
    );
    for r in &m.pc_rows {
        t.push(r.name, vec![r.total_area(), r.total_power(), r.count as f64]);
    }
    t.push("PC Total", vec![
        m.pc_rows.iter().map(|r| r.total_area()).sum(),
        m.pc_rows.iter().map(|r| r.total_power()).sum(),
        1.0,
    ]);
    for r in &m.ag_rows {
        t.push(format!("AG/{}", r.name), vec![r.total_area(), r.total_power(), r.count as f64]);
    }
    t.push("AG Total", vec![m.ag_area_mm2, m.ag_power_mw, 1.0]);
    t.push("Tile", vec![m.tile_area_mm2, m.tile_power_mw, cfg.hardware.tiles as f64]);
    t.push("CPSAA", vec![m.chip_area_mm2, m.chip_power_mw, 1.0]);
    t.note("paper: PC 0.2235/132.62, AG 0.00252/4.623, chip 27.47 mm^2 / 28.83 W");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chip_totals_close_to_paper() {
        let t = run(&SystemConfig::paper());
        let area = t.get("CPSAA", "area_mm2").unwrap();
        let power = t.get("CPSAA", "power_mW").unwrap();
        assert!((area - 27.47).abs() / 27.47 < 0.15, "area {area}");
        assert!((power - 28_830.0).abs() / 28_830.0 < 0.15, "power {power}");
    }

    #[test]
    fn has_all_structural_rows() {
        let t = run(&SystemConfig::paper());
        for label in ["PC Total", "AG Total", "Tile", "CPSAA"] {
            assert!(t.rows.iter().any(|(l, _)| l == label), "missing {label}");
        }
    }
}
