//! Tabular result container with pretty-print and CSV export.

use std::fmt;
use std::path::Path;

use crate::util::error::{Context, Result};

/// One regenerated figure/table: headers plus numeric rows keyed by label.
#[derive(Clone, Debug)]
pub struct Table {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    /// The paper's reference values for the same cells, when quoted.
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Self {
        Self {
            id: id.into(),
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn push(&mut self, label: impl Into<String>, values: Vec<f64>) {
        let label = label.into();
        debug_assert_eq!(values.len(), self.headers.len(), "row {label} arity");
        self.rows.push((label, values));
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn get(&self, label: &str, header: &str) -> Option<f64> {
        let col = self.headers.iter().position(|h| h == header)?;
        let (_, vals) = self.rows.iter().find(|(l, _)| l == label)?;
        vals.get(col).copied()
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str("label");
        for h in &self.headers {
            s.push(',');
            s.push_str(h);
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            s.push_str(label);
            for v in vals {
                s.push_str(&format!(",{v:.6}"));
            }
            s.push('\n');
        }
        s
    }

    pub fn save_csv(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).context("creating results dir")?;
        let path = dir.join(format!("{}.csv", self.id));
        std::fs::write(&path, self.to_csv()).with_context(|| format!("writing {}", path.display()))
    }
}

impl fmt::Display for Table {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "== {} — {} ==", self.id, self.title)?;
        let w = self.rows.iter().map(|(l, _)| l.len()).max().unwrap_or(5).max(5);
        write!(f, "{:w$}", "", w = w + 2)?;
        for h in &self.headers {
            write!(f, "{h:>14}")?;
        }
        writeln!(f)?;
        for (label, vals) in &self.rows {
            write!(f, "{label:<w$}  ", w = w)?;
            for v in vals {
                if v.abs() >= 1000.0 {
                    write!(f, "{v:>14.1}")?;
                } else {
                    write!(f, "{v:>14.4}")?;
                }
            }
            writeln!(f)?;
        }
        for n in &self.notes {
            writeln!(f, "  note: {n}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_lookup() {
        let mut t = Table::new("figX", "demo", &["a", "b"]);
        t.push("row1", vec![1.0, 2.0]);
        assert_eq!(t.get("row1", "b"), Some(2.0));
        assert_eq!(t.get("row1", "c"), None);
        assert_eq!(t.get("nope", "a"), None);
    }

    #[test]
    fn csv_format() {
        let mut t = Table::new("figX", "demo", &["a"]);
        t.push("r", vec![0.5]);
        let csv = t.to_csv();
        assert!(csv.starts_with("label,a\n"));
        assert!(csv.contains("r,0.5"));
    }

    #[test]
    fn display_contains_title() {
        let t = Table::new("figX", "My Title", &["a"]);
        assert!(format!("{t}").contains("My Title"));
    }
}
