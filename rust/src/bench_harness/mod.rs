//! Figure/table regeneration harness.
//!
//! One module per paper artifact (DESIGN.md §4 experiment index). Every
//! `run(...)` returns a [`Table`] shaped like the paper's plot data —
//! same series, same normalization — printable and CSV-exportable via
//! `cpsaa bench-figure <id>`; criterion benches under `rust/benches/`
//! wrap the same entry points for timing.

pub mod fig03;
pub mod fig11_12;
pub mod fig13_15;
pub mod fig16;
pub mod fig17;
pub mod fig18;
pub mod fig19;
pub mod fig20;
pub mod table2;

mod table;

pub use table::Table;

use crate::config::SystemConfig;

/// Every figure id the harness can regenerate.
pub const ALL_FIGURES: [&str; 12] = [
    "fig3", "table2", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17", "fig18",
    "fig19a", "fig19b",
];

/// Run one figure by id (fig20 variants accepted too).
pub fn run_figure(id: &str, cfg: &SystemConfig) -> Option<Vec<Table>> {
    match id {
        "fig3" => Some(vec![fig03::run(cfg)]),
        "table2" => Some(vec![table2::run(cfg)]),
        "fig11" => Some(vec![fig11_12::run_time(cfg)]),
        "fig12" => Some(vec![fig11_12::run_energy(cfg)]),
        "fig13" => Some(vec![fig13_15::run_fig13(cfg)]),
        "fig14" => Some(vec![fig13_15::run_fig14(cfg)]),
        "fig15" => Some(vec![fig13_15::run_fig15(cfg)]),
        "fig16" => Some(vec![fig16::run(cfg)]),
        "fig17" => Some(vec![fig17::run(cfg)]),
        "fig18" => Some(vec![fig18::run(cfg)]),
        "fig19a" => Some(vec![fig19::run_a(cfg)]),
        "fig19b" => Some(vec![fig19::run_b(cfg)]),
        "fig20a" => Some(vec![fig20::run_a(cfg)]),
        "fig20b" => Some(vec![fig20::run_b(cfg)]),
        "all" => {
            let mut v = Vec::new();
            for id in ALL_FIGURES {
                v.extend(run_figure(id, cfg).unwrap());
            }
            v.extend(run_figure("fig20a", cfg).unwrap());
            v.extend(run_figure("fig20b", cfg).unwrap());
            Some(v)
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_figure_none() {
        assert!(run_figure("fig99", &SystemConfig::paper()).is_none());
    }
}
