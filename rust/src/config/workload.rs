//! Workload configuration: the paper's evaluation datasets, characterized.
//!
//! The paper runs eight GLUE tasks + SQuAD v2.0 through fine-tuned BERT.
//! Token identity never enters the evaluation — only sequence counts,
//! lengths, and the resulting attention sparsity — so each dataset is
//! described by those statistics (DESIGN.md substitution table). Length
//! statistics follow the published GLUE/SQuAD task descriptions.

use crate::util::error::Result;

use crate::util::tomlmini::{Section, Value};

/// One evaluation dataset's shape statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetSpec {
    pub name: String,
    /// Number of evaluation sequences (drives batch count).
    pub sequences: usize,
    /// Mean token length of a sequence.
    pub mean_len: usize,
    /// Std-dev of token length.
    pub std_len: usize,
    /// Typical attention mask density for this task (paper: ≈ 0.1).
    pub mask_density: f64,
}

impl DatasetSpec {
    fn new(name: &str, sequences: usize, mean_len: usize, std_len: usize, mask_density: f64) -> Self {
        Self { name: name.into(), sequences, mean_len, std_len, mask_density }
    }
}

impl DatasetSpec {
    /// Parse one `[[workload.datasets]]` entry.
    pub fn from_section(sec: &Section) -> Result<Self> {
        let mut d = Self::new("unnamed", 0, 32, 8, 0.1);
        for (k, v) in sec {
            match k.as_str() {
                "name" => d.name = v.as_str()?.to_string(),
                "sequences" => d.sequences = v.as_usize()?,
                "mean_len" => d.mean_len = v.as_usize()?,
                "std_len" => d.std_len = v.as_usize()?,
                "mask_density" => d.mask_density = v.as_f64()?,
                other => crate::bail!("unknown dataset key {other:?}"),
            }
        }
        Ok(d)
    }

    pub fn to_entries(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("name", Value::Str(self.name.clone())),
            ("sequences", Value::Num(self.sequences as f64)),
            ("mean_len", Value::Num(self.mean_len as f64)),
            ("std_len", Value::Num(self.std_len as f64)),
            ("mask_density", Value::Num(self.mask_density)),
        ]
    }
}

/// The evaluation suite (§5 Benchmarks).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    pub datasets: Vec<DatasetSpec>,
    /// Embeddings per in-memory batch (§5: 320, as in BERT/A³).
    pub batch_size: usize,
    /// Seed for synthetic embedding generation.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self { datasets: glue_suite(), batch_size: 320, seed: 0 }
    }
}

impl WorkloadConfig {
    pub fn paper() -> Self {
        Self::default()
    }

    pub fn dataset(&self, name: &str) -> Option<&DatasetSpec> {
        self.datasets.iter().find(|d| d.name.eq_ignore_ascii_case(name))
    }

    /// The five-dataset subset used by the motivation/kernels figures
    /// (Figs. 3, 17, 19b report five workloads).
    pub fn five(&self) -> Vec<&DatasetSpec> {
        ["CoLA", "SST-2", "MRPC", "QQP", "SQuAD"]
            .iter()
            .filter_map(|n| self.dataset(n))
            .collect()
    }

    /// Overlay a `[workload]` section and `[[workload.datasets]]` entries.
    pub fn from_sections(sec: Option<&Section>, datasets: &[Section]) -> Result<Self> {
        let mut w = Self::default();
        if let Some(sec) = sec {
            for (k, v) in sec {
                match k.as_str() {
                    "batch_size" => w.batch_size = v.as_usize()?,
                    "seed" => w.seed = v.as_usize()? as u64,
                    other => crate::bail!("unknown [workload] key {other:?}"),
                }
            }
        }
        if !datasets.is_empty() {
            w.datasets = datasets.iter().map(DatasetSpec::from_section).collect::<Result<_>>()?;
        }
        Ok(w)
    }
}

/// GLUE + SQuAD task statistics. Sequence counts are the dev-set sizes;
/// mean/std lengths follow the task descriptions (single sentences for
/// CoLA/SST-2, sentence pairs for the rest, long paragraphs for SQuAD).
pub fn glue_suite() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec::new("CoLA", 1043, 12, 5, 0.12),
        DatasetSpec::new("SST-2", 872, 25, 9, 0.11),
        DatasetSpec::new("MRPC", 408, 53, 15, 0.10),
        DatasetSpec::new("STS-B", 1500, 27, 11, 0.11),
        DatasetSpec::new("QQP", 40430, 30, 13, 0.10),
        DatasetSpec::new("MNLI", 9815, 39, 17, 0.09),
        DatasetSpec::new("WNLI", 71, 37, 12, 0.10),
        DatasetSpec::new("RTE", 277, 64, 28, 0.09),
        DatasetSpec::new("SQuAD", 11873, 152, 60, 0.08),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_nine_datasets() {
        assert_eq!(glue_suite().len(), 9);
    }

    #[test]
    fn lookup_case_insensitive() {
        let w = WorkloadConfig::paper();
        assert!(w.dataset("cola").is_some());
        assert!(w.dataset("SQUAD").is_some());
        assert!(w.dataset("nope").is_none());
    }

    #[test]
    fn five_subset() {
        assert_eq!(WorkloadConfig::paper().five().len(), 5);
    }

    #[test]
    fn densities_in_paper_regime() {
        for d in glue_suite() {
            assert!(d.mask_density > 0.05 && d.mask_density < 0.2, "{}", d.name);
        }
    }

    #[test]
    fn toml_roundtrip() {
        use crate::util::tomlmini::{write_section, Doc};
        let w = WorkloadConfig::paper();
        let mut s = String::new();
        write_section(
            &mut s,
            "workload",
            &[("batch_size", crate::util::tomlmini::Value::Num(w.batch_size as f64))],
        );
        for ds in &w.datasets {
            s.push_str("[[workload.datasets]]\n");
            let mut body = String::new();
            write_section(&mut body, "", &ds.to_entries());
            s.push_str(&body);
        }
        let doc = Doc::parse(&s).unwrap();
        let back = WorkloadConfig::from_sections(
            doc.section("workload"),
            doc.arrays.get("workload.datasets").map(|v| v.as_slice()).unwrap_or(&[]),
        )
        .unwrap();
        assert_eq!(back, w);
    }
}
