//! Typed configuration system: hardware (Table 2), model, and workload.
//!
//! Everything the simulator, baselines, and coordinator consume is plain
//! data defined here, loadable from TOML (`configs/*.toml`) and overridable
//! from the CLI. Defaults reproduce the paper's evaluation setup exactly.

mod hardware;
mod loader;
mod model;
mod workload;

pub use hardware::{HardwareConfig, IdealKnobs};
pub use loader::SystemConfig;
pub use model::ModelConfig;
pub use workload::{DatasetSpec, WorkloadConfig};
