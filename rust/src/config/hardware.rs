//! Hardware configuration — the constants of Table 2 and §5.
//!
//! Every latency/energy/area number the simulator uses lives here with its
//! provenance cited, so the ideal-situation study (Fig. 18) and the
//! crossbar-size sweep (Fig. 19a) are plain config edits.

use crate::util::error::Result;

use crate::util::tomlmini::{Section, Value};

/// Full CPSAA chip configuration (Table 2 defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct HardwareConfig {
    // ---- structure ----
    /// Tiles per chip (Table 2: 64).
    pub tiles: usize,
    /// Read-only array groups per tile (Table 2: 11).
    pub roa_per_tile: usize,
    /// Write-enable array groups per tile (Table 2: 56).
    pub wea_per_tile: usize,
    /// ReRAM crossbars per arrays-group (Table 2: 12).
    pub arrays_per_ag: usize,
    /// Crossbar edge (Table 2: 32×32; Fig. 19a sweeps this).
    pub crossbar_size: usize,
    /// ReCAM scheduler arrays per tile (Table 2: 2× 512×512).
    pub recam_arrays: usize,
    /// ReCAM array edge (512).
    pub recam_size: usize,
    /// Value precision in bits (§5: 32-bit fixed point via EB/FB).
    pub value_bits: u32,
    /// ReRAM cell bits (Table 2: SLC, 1 bit per cell).
    pub cell_bits: u32,
    /// ADCs per arrays-group (Table 2: 1).
    pub adcs_per_ag: usize,

    // ---- timing (ns) ----
    /// One "cycle": ADC processing 32 column signals = 25 ns (ISAAC [38]).
    pub cycle_ns: f64,
    /// SLC SET latency, row-parallel write (1.52 ns [48]).
    pub write_set_ns: f64,
    /// SLC RESET latency (2.11 ns [48]).
    pub write_reset_ns: f64,
    /// Program-verify iterations per effective row write (calibrated to
    /// the paper's wait-for-write ratios; raw SET/RESET alone underprices
    /// real ReRAM programming).
    pub write_verify_factor: f64,
    /// ReCAM search: one row-parallel compare per key (one cycle @533 MHz).
    pub recam_search_ns: f64,
    /// Control signal generation per dispatched coordinate batch.
    pub ctrl_ns: f64,

    // ---- bandwidth / energy ----
    /// On-chip interconnect bandwidth (1000 GB/s, TPUv4i OCI [20]).
    pub oci_gbps: f64,
    /// On-chip transfer energy (7 pJ/bit, HyGCN [50]).
    pub transfer_pj_per_bit: f64,
    /// Crossbar VMM energy per cycle per array (mW of XB Array × cycle).
    pub xb_mw: f64,
    /// ADC power (2.0 mW @ 8-bit 1.0 GS/s [25]).
    pub adc_mw: f64,
    /// DAC power per 32-lane group (1.513 mW total [37]).
    pub dac_mw: f64,
    /// ReRAM write energy per bit (pJ) — SLC SET/RESET average.
    pub write_pj_per_bit: f64,
    /// ReCAM search energy per activated row (pJ).
    pub recam_pj_per_row: f64,
    /// Peripheral (QU/DQU/SU/BU/CTRL/buffers) power per tile (Table 2 PC
    /// total: 132.62 mW).
    pub pc_mw: f64,

    // ---- ideal-situation knobs (Fig. 18) ----
    pub ideal: IdealKnobs,
}

/// Fig. 18 idealization switches: each zeroes one latency component.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IdealKnobs {
    /// (a) zero ReRAM write latency.
    pub no_write_latency: bool,
    /// (b) zero on-chip transmission latency.
    pub no_transfer_latency: bool,
    /// (c) infinite ADCs (no ADC serialization).
    pub infinite_adcs: bool,
    /// (d) zero control-signal scheduling latency.
    pub no_ctrl_latency: bool,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        Self {
            tiles: 64,
            roa_per_tile: 11,
            wea_per_tile: 56,
            arrays_per_ag: 12,
            crossbar_size: 32,
            recam_arrays: 2,
            recam_size: 512,
            value_bits: 32,
            cell_bits: 1,
            adcs_per_ag: 1,
            cycle_ns: 25.0,
            write_set_ns: 1.52,
            write_reset_ns: 2.11,
            write_verify_factor: 8.0,
            recam_search_ns: 1.0 / 0.533, // one 533 MHz clock
            ctrl_ns: 2.0,
            oci_gbps: 1000.0,
            transfer_pj_per_bit: 7.0,
            xb_mw: 0.581,
            adc_mw: 2.0,
            dac_mw: 1.513,
            write_pj_per_bit: 0.1, // SLC programming energy per cell-bit
            recam_pj_per_row: 1.1,
            pc_mw: 132.62,
            ideal: IdealKnobs::default(),
        }
    }
}

impl HardwareConfig {
    /// Paper configuration (Table 2).
    pub fn paper() -> Self {
        Self::default()
    }

    /// Total crossbar arrays in the chip.
    pub fn total_arrays(&self) -> usize {
        self.tiles * (self.roa_per_tile + self.wea_per_tile) * self.arrays_per_ag
    }

    /// Numbers a single crossbar stores when each row holds one value of
    /// `value_bits` bits across `cell_bits` cells (§4.3 mapping: one 32-bit
    /// number per row of a 32×32 SLC array).
    pub fn numbers_per_array(&self) -> usize {
        // Each row stores one value occupying value_bits/cell_bits cells.
        let cells_per_value = (self.value_bits / self.cell_bits) as usize;
        if cells_per_value <= self.crossbar_size {
            self.crossbar_size
        } else {
            // Values spill across multiple rows.
            self.crossbar_size * self.crossbar_size / cells_per_value
        }
    }

    /// ReRAM storage capacity of the chip in bytes (Table 2: 27.5 MB).
    pub fn capacity_bytes(&self) -> usize {
        self.total_arrays() * self.crossbar_size * self.crossbar_size * self.cell_bits as usize / 8
    }

    /// Average row-parallel write latency in ns (mix of SET and RESET).
    pub fn write_row_ns(&self) -> f64 {
        if self.ideal.no_write_latency {
            0.0
        } else {
            0.5 * (self.write_set_ns + self.write_reset_ns)
        }
    }

    /// On-chip transfer latency for `bytes` in ns.
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        if self.ideal.no_transfer_latency {
            0.0
        } else {
            bytes as f64 / self.oci_gbps // GB/s == bytes/ns
        }
    }

    /// Control-signal latency for one scheduled dispatch batch.
    pub fn ctrl_latency_ns(&self) -> f64 {
        if self.ideal.no_ctrl_latency {
            0.0
        } else {
            self.ctrl_ns
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.crossbar_size == 0 || !self.crossbar_size.is_power_of_two() {
            return Err(format!("crossbar_size {} not a power of two", self.crossbar_size));
        }
        if self.tiles == 0 || self.arrays_per_ag == 0 {
            return Err("empty chip".into());
        }
        if self.cell_bits == 0 || self.value_bits % self.cell_bits != 0 {
            return Err("value_bits must be a multiple of cell_bits".into());
        }
        Ok(())
    }

    /// Overlay a `[hardware]` section (plus optional `[hardware.ideal]`)
    /// onto defaults.
    pub fn from_sections(sec: &Section, ideal: Option<&Section>) -> Result<Self> {
        let mut c = Self::default();
        for (k, v) in sec {
            match k.as_str() {
                "tiles" => c.tiles = v.as_usize()?,
                "roa_per_tile" => c.roa_per_tile = v.as_usize()?,
                "wea_per_tile" => c.wea_per_tile = v.as_usize()?,
                "arrays_per_ag" => c.arrays_per_ag = v.as_usize()?,
                "crossbar_size" => c.crossbar_size = v.as_usize()?,
                "recam_arrays" => c.recam_arrays = v.as_usize()?,
                "recam_size" => c.recam_size = v.as_usize()?,
                "value_bits" => c.value_bits = v.as_usize()? as u32,
                "cell_bits" => c.cell_bits = v.as_usize()? as u32,
                "adcs_per_ag" => c.adcs_per_ag = v.as_usize()?,
                "cycle_ns" => c.cycle_ns = v.as_f64()?,
                "write_set_ns" => c.write_set_ns = v.as_f64()?,
                "write_reset_ns" => c.write_reset_ns = v.as_f64()?,
                "write_verify_factor" => c.write_verify_factor = v.as_f64()?,
                "recam_search_ns" => c.recam_search_ns = v.as_f64()?,
                "ctrl_ns" => c.ctrl_ns = v.as_f64()?,
                "oci_gbps" => c.oci_gbps = v.as_f64()?,
                "transfer_pj_per_bit" => c.transfer_pj_per_bit = v.as_f64()?,
                "xb_mw" => c.xb_mw = v.as_f64()?,
                "adc_mw" => c.adc_mw = v.as_f64()?,
                "dac_mw" => c.dac_mw = v.as_f64()?,
                "write_pj_per_bit" => c.write_pj_per_bit = v.as_f64()?,
                "recam_pj_per_row" => c.recam_pj_per_row = v.as_f64()?,
                "pc_mw" => c.pc_mw = v.as_f64()?,
                other => crate::bail!("unknown [hardware] key {other:?}"),
            }
        }
        if let Some(sec) = ideal {
            for (k, v) in sec {
                match k.as_str() {
                    "no_write_latency" => c.ideal.no_write_latency = v.as_bool()?,
                    "no_transfer_latency" => c.ideal.no_transfer_latency = v.as_bool()?,
                    "infinite_adcs" => c.ideal.infinite_adcs = v.as_bool()?,
                    "no_ctrl_latency" => c.ideal.no_ctrl_latency = v.as_bool()?,
                    other => crate::bail!("unknown [hardware.ideal] key {other:?}"),
                }
            }
        }
        Ok(c)
    }

    /// Serialize as `[hardware]` entries (ideal knobs separate).
    pub fn to_entries(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("tiles", Value::Num(self.tiles as f64)),
            ("roa_per_tile", Value::Num(self.roa_per_tile as f64)),
            ("wea_per_tile", Value::Num(self.wea_per_tile as f64)),
            ("arrays_per_ag", Value::Num(self.arrays_per_ag as f64)),
            ("crossbar_size", Value::Num(self.crossbar_size as f64)),
            ("recam_arrays", Value::Num(self.recam_arrays as f64)),
            ("recam_size", Value::Num(self.recam_size as f64)),
            ("value_bits", Value::Num(self.value_bits as f64)),
            ("cell_bits", Value::Num(self.cell_bits as f64)),
            ("adcs_per_ag", Value::Num(self.adcs_per_ag as f64)),
            ("cycle_ns", Value::Num(self.cycle_ns)),
            ("write_set_ns", Value::Num(self.write_set_ns)),
            ("write_reset_ns", Value::Num(self.write_reset_ns)),
            ("write_verify_factor", Value::Num(self.write_verify_factor)),
            ("recam_search_ns", Value::Num(self.recam_search_ns)),
            ("ctrl_ns", Value::Num(self.ctrl_ns)),
            ("oci_gbps", Value::Num(self.oci_gbps)),
            ("transfer_pj_per_bit", Value::Num(self.transfer_pj_per_bit)),
            ("xb_mw", Value::Num(self.xb_mw)),
            ("adc_mw", Value::Num(self.adc_mw)),
            ("dac_mw", Value::Num(self.dac_mw)),
            ("write_pj_per_bit", Value::Num(self.write_pj_per_bit)),
            ("recam_pj_per_row", Value::Num(self.recam_pj_per_row)),
            ("pc_mw", Value::Num(self.pc_mw)),
        ]
    }

    pub fn ideal_entries(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("no_write_latency", Value::Bool(self.ideal.no_write_latency)),
            ("no_transfer_latency", Value::Bool(self.ideal.no_transfer_latency)),
            ("infinite_adcs", Value::Bool(self.ideal.infinite_adcs)),
            ("no_ctrl_latency", Value::Bool(self.ideal.no_ctrl_latency)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_capacity() {
        // Table 2: 64 tiles × (11 + 56) AGs × 12 arrays × 32×32 cells ≈ 27.5 MB... in SLC bits:
        // 64*67*12*1024 bits = 6.6 MB of cells; the paper's "27.5MB" counts
        // logical capacity with peripheral registers — we assert our cell
        // count matches the structural product instead.
        let hw = HardwareConfig::paper();
        assert_eq!(hw.total_arrays(), 64 * 67 * 12);
        assert_eq!(hw.capacity_bytes(), 64 * 67 * 12 * 1024 / 8);
    }

    #[test]
    fn numbers_per_array_32bit() {
        let hw = HardwareConfig::paper();
        // §4.3: one 32×32 SLC array stores 32 32-bit numbers, one per row.
        assert_eq!(hw.numbers_per_array(), 32);
    }

    #[test]
    fn ideal_knobs_zero_latencies() {
        let mut hw = HardwareConfig::paper();
        assert!(hw.write_row_ns() > 0.0);
        assert!(hw.transfer_ns(1024) > 0.0);
        assert!(hw.ctrl_latency_ns() > 0.0);
        hw.ideal =
            IdealKnobs { no_write_latency: true, no_transfer_latency: true, infinite_adcs: true, no_ctrl_latency: true };
        assert_eq!(hw.write_row_ns(), 0.0);
        assert_eq!(hw.transfer_ns(1024), 0.0);
        assert_eq!(hw.ctrl_latency_ns(), 0.0);
    }

    #[test]
    fn transfer_latency_linear() {
        let hw = HardwareConfig::paper();
        assert!((hw.transfer_ns(2000) - 2.0 * hw.transfer_ns(1000)).abs() < 1e-9);
    }

    #[test]
    fn validate_rejects_odd_crossbar() {
        let hw = HardwareConfig { crossbar_size: 33, ..Default::default() };
        assert!(hw.validate().is_err());
    }

    #[test]
    fn toml_roundtrip() {
        use crate::util::tomlmini::{write_section, Doc};
        let mut hw = HardwareConfig::paper();
        hw.ideal.infinite_adcs = true;
        let mut s = String::new();
        write_section(&mut s, "hardware", &hw.to_entries());
        write_section(&mut s, "hardware.ideal", &hw.ideal_entries());
        let doc = Doc::parse(&s).unwrap();
        let back =
            HardwareConfig::from_sections(doc.section("hardware").unwrap(), doc.section("hardware.ideal"))
                .unwrap();
        assert_eq!(back, hw);
    }
}
