//! Top-level config file: `[hardware]`, `[model]`, `[workload]` sections.

use std::path::Path;

use crate::util::error::{Context, Result};

use crate::util::tomlmini::{write_section, Doc};

use super::{HardwareConfig, ModelConfig, WorkloadConfig};

/// Combined system configuration — what one `cpsaa` invocation runs with.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SystemConfig {
    pub hardware: HardwareConfig,
    pub model: ModelConfig,
    pub workload: WorkloadConfig,
}

impl SystemConfig {
    /// Paper evaluation defaults.
    pub fn paper() -> Self {
        Self {
            hardware: HardwareConfig::paper(),
            model: ModelConfig::paper(),
            workload: WorkloadConfig::paper(),
        }
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = Doc::parse(text).context("parsing TOML config")?;
        let empty = Default::default();
        let hardware = HardwareConfig::from_sections(
            doc.section("hardware").unwrap_or(&empty),
            doc.section("hardware.ideal"),
        )?;
        let model = ModelConfig::from_section(doc.section("model").unwrap_or(&empty))?;
        let workload = WorkloadConfig::from_sections(
            doc.section("workload"),
            doc.arrays.get("workload.datasets").map(|v| v.as_slice()).unwrap_or(&[]),
        )?;
        let cfg = Self { hardware, model, workload };
        cfg.validate().map_err(|e| crate::anyhow!(e))?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// Serialize to the TOML subset `from_toml_str` reads.
    pub fn to_toml_string(&self) -> String {
        let mut s = String::new();
        write_section(&mut s, "hardware", &self.hardware.to_entries());
        write_section(&mut s, "hardware.ideal", &self.hardware.ideal_entries());
        write_section(&mut s, "model", &self.model.to_entries());
        write_section(
            &mut s,
            "workload",
            &[
                ("batch_size", crate::util::tomlmini::Value::Num(self.workload.batch_size as f64)),
                ("seed", crate::util::tomlmini::Value::Num(self.workload.seed as f64)),
            ],
        );
        for ds in &self.workload.datasets {
            s.push_str("[[workload.datasets]]\n");
            let mut body = String::new();
            write_section(&mut body, "", &ds.to_entries());
            s.push_str(&body);
        }
        s
    }

    pub fn validate(&self) -> Result<(), String> {
        self.hardware.validate()?;
        self.model.validate()?;
        if self.workload.batch_size == 0 {
            return Err("batch_size must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_validates() {
        SystemConfig::paper().validate().unwrap();
    }

    #[test]
    fn partial_toml_fills_defaults() {
        let cfg = SystemConfig::from_toml_str("[model]\nseq_len = 64\n").unwrap();
        assert_eq!(cfg.model.seq_len, 64);
        assert_eq!(cfg.model.d_model, ModelConfig::default().d_model);
        assert_eq!(cfg.hardware, HardwareConfig::default());
    }

    #[test]
    fn full_roundtrip() {
        let mut cfg = SystemConfig::paper();
        cfg.hardware.crossbar_size = 64;
        cfg.model.theta = 0.02;
        let text = cfg.to_toml_string();
        let back = SystemConfig::from_toml_str(&text).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir().join(format!("cpsaa-cfg-{}.toml", std::process::id()));
        std::fs::write(&path, SystemConfig::paper().to_toml_string()).unwrap();
        let cfg = SystemConfig::from_toml_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(cfg, SystemConfig::paper());
    }

    #[test]
    fn bad_file_errors() {
        assert!(SystemConfig::from_toml_file(Path::new("/nonexistent.toml")).is_err());
        assert!(SystemConfig::from_toml_str("[model]\ntheta = 9.0\n").is_err());
    }
}
