//! Model-side configuration, mirroring `python/compile/model.py::ModelConfig`.

use crate::util::error::Result;

use crate::util::tomlmini::{Section, Value};

/// Shapes and pruning hyper-parameters of one attention layer.
///
/// Paper defaults: d_model = 512, d_k = d_q = 64, 320-embedding batches
/// (Transformer/BERT/A³/SANGER settings, §5). The AOT artifacts default to
/// a smaller (128, 256) head for compile time; the simulator accepts any
/// shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Tokens per sequence batch processed in-memory at once.
    pub seq_len: usize,
    /// Embedding dimension d_model.
    pub d_model: usize,
    /// Per-head query/key dimension (scaling factor of the score matrix).
    pub d_k: usize,
    /// FC hidden dimension of the encoder tail.
    pub d_ff: usize,
    /// Number of encoder layers (BERT = 12).
    pub layers: usize,
    /// Attention heads per layer (BERT-base: 8 at d_model=512/d_k=64).
    /// The chip-level figures model one head (the paper's setup); the
    /// serving path and the application-level simulator fan heads out
    /// across disjoint `tiles/heads` crossbar slices, one mask and one
    /// dispatch plan per head. The simulator accepts any head count;
    /// *serving* additionally requires heads to divide d_model (head
    /// outputs concat back to d_model), enforced when the weights fan
    /// out ([`MultiHeadWeights`][crate::attention::MultiHeadWeights]).
    pub heads: usize,
    /// Quantization scale γ of Q(·).
    pub gamma: f32,
    /// Quantizer width in bits (SANGER-style low-precision pruning).
    pub quant_bits: u32,
    /// Binarization threshold θ of eq. 1.
    pub theta: f32,
    /// Synthetic-weight attention-logit scale (DESIGN.md substitution).
    pub sharpness: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            seq_len: 320,
            d_model: 512,
            d_k: 64,
            d_ff: 2048,
            layers: 12,
            heads: 1,
            gamma: 4.0,
            quant_bits: 4,
            theta: 0.01,
            sharpness: 4.0,
        }
    }
}

impl ModelConfig {
    /// Paper evaluation shape (§5): 320×512, 12 encoders.
    pub fn paper() -> Self {
        Self::default()
    }

    /// Shape matching the default AOT artifacts (python side defaults).
    pub fn artifact_default() -> Self {
        Self { seq_len: 128, d_model: 256, d_ff: 512, ..Self::default() }
    }

    /// Dense-equivalent FLOPs of one sparse-attention layer on one batch —
    /// the paper's GOPS accounting is *useful operations per second*, so
    /// throughput is measured in dense-equivalent ops (2·n·m·k per matmul).
    pub fn attention_flops(&self) -> u64 {
        let n = self.seq_len as u64;
        let d = self.d_model as u64;
        let dk = self.d_k as u64;
        // One head: M = X W_S (n·d·d), V = X W_V (n·d·d_k),
        // S = M X^T (n·n·d), Z = S V (n·n·d_k)
        2 * (n * d * d + n * d * dk + n * n * d + n * n * dk)
    }

    /// FLOPs of the FC tail.
    pub fn fc_flops(&self) -> u64 {
        let n = self.seq_len as u64;
        2 * n * self.d_model as u64 * self.d_ff as u64 * 2
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.seq_len == 0 || self.d_model == 0 || self.d_k == 0 {
            return Err("zero dimension".into());
        }
        if self.heads == 0 || self.heads * self.d_k > self.d_model * 2 {
            return Err(format!("heads {} implausible for d_model {}", self.heads, self.d_model));
        }
        if !(0.0..1.0).contains(&self.theta) || self.theta <= 0.0 {
            return Err(format!("theta {} outside (0,1)", self.theta));
        }
        // A signed quantization grid needs at least a sign bit and one
        // magnitude bit: grid_bound computes 2^(bits-1) - 1, which
        // underflows at bits = 0 and collapses to 0 levels at bits = 1.
        if !(2..=16).contains(&self.quant_bits) {
            return Err(format!("quant_bits {} outside 2..=16", self.quant_bits));
        }
        Ok(())
    }

    /// Overlay values from a `[model]` TOML section onto defaults.
    pub fn from_section(sec: &Section) -> Result<Self> {
        let mut c = Self::default();
        for (k, v) in sec {
            match k.as_str() {
                "seq_len" => c.seq_len = v.as_usize()?,
                "d_model" => c.d_model = v.as_usize()?,
                "d_k" => c.d_k = v.as_usize()?,
                "d_ff" => c.d_ff = v.as_usize()?,
                "layers" => c.layers = v.as_usize()?,
                "heads" => c.heads = v.as_usize()?,
                "gamma" => c.gamma = v.as_f64()? as f32,
                "quant_bits" => c.quant_bits = v.as_usize()? as u32,
                "theta" => c.theta = v.as_f64()? as f32,
                "sharpness" => c.sharpness = v.as_f64()? as f32,
                other => crate::bail!("unknown [model] key {other:?}"),
            }
        }
        Ok(c)
    }

    /// Serialize as a `[model]` section.
    pub fn to_entries(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("seq_len", Value::Num(self.seq_len as f64)),
            ("d_model", Value::Num(self.d_model as f64)),
            ("d_k", Value::Num(self.d_k as f64)),
            ("d_ff", Value::Num(self.d_ff as f64)),
            ("layers", Value::Num(self.layers as f64)),
            ("heads", Value::Num(self.heads as f64)),
            ("gamma", Value::Num(self.gamma as f64)),
            ("quant_bits", Value::Num(self.quant_bits as f64)),
            ("theta", Value::Num(self.theta as f64)),
            ("sharpness", Value::Num(self.sharpness as f64)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tomlmini::{write_section, Doc};

    #[test]
    fn defaults_are_paper_setup() {
        let c = ModelConfig::paper();
        assert_eq!((c.seq_len, c.d_model, c.d_k, c.layers), (320, 512, 64, 12));
        c.validate().unwrap();
    }

    #[test]
    fn flops_positive_and_scale_quadratically_in_seq() {
        let a = ModelConfig { seq_len: 128, ..Default::default() };
        let b = ModelConfig { seq_len: 256, ..Default::default() };
        assert!(b.attention_flops() > a.attention_flops());
        // the n² terms dominate growth
        assert!(b.attention_flops() < 4 * a.attention_flops());
    }

    #[test]
    fn validate_rejects_bad() {
        assert!(ModelConfig { theta: 0.0, ..Default::default() }.validate().is_err());
        assert!(ModelConfig { seq_len: 0, ..Default::default() }.validate().is_err());
        // bits = 0 used to reach quant::grid_bound and underflow there;
        // bits = 1 has no magnitude bit — both must die at config load
        assert!(ModelConfig { quant_bits: 0, ..Default::default() }.validate().is_err());
        assert!(ModelConfig { quant_bits: 1, ..Default::default() }.validate().is_err());
        ModelConfig { quant_bits: 2, ..Default::default() }.validate().unwrap();
        assert!(ModelConfig { heads: 0, ..Default::default() }.validate().is_err());
        // non-dividing head counts are fine for the simulator (serving
        // enforces divisibility at the weights fan-out instead)
        ModelConfig { heads: 7, ..Default::default() }.validate().unwrap();
        ModelConfig { heads: 8, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let c = ModelConfig { theta: 0.02, seq_len: 64, ..ModelConfig::paper() };
        let mut s = String::new();
        write_section(&mut s, "model", &c.to_entries());
        let doc = Doc::parse(&s).unwrap();
        let back = ModelConfig::from_section(doc.section("model").unwrap()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = Doc::parse("[model]\nbogus = 1\n").unwrap();
        assert!(ModelConfig::from_section(doc.section("model").unwrap()).is_err());
    }
}
