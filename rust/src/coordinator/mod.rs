//! Layer-3 coordinator: batching, the encoder pipeline, and the serving
//! loop over the PJRT engine.
//!
//! CPSAA's system contribution is the in-memory dataflow; the coordinator
//! is the thin-but-real host layer around it (the paper's DTC + CTRL role
//! at application level, §4.5): it packs incoming sequences into
//! 320-embedding batches, drives the per-layer artifact executions, tracks
//! hardware-simulated cost alongside functional results, and reports
//! serving metrics (latency percentiles, GOPS).

mod batcher;
mod metrics;
mod pipeline;
mod service;

pub use batcher::{BatchPlan, Batcher, PackedRequest};
pub use metrics::{LatencyHistogram, ServeMetrics};
pub use pipeline::{EncoderStack, LayerOutput};
pub use service::{InferenceResponse, Service, ServiceConfig};
