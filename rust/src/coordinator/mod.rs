//! Layer-3 coordinator: batching, the encoder pipeline, and the serving
//! loop over the PJRT engine.
//!
//! CPSAA's system contribution is the in-memory dataflow; the coordinator
//! is the thin-but-real host layer around it (the paper's DTC + CTRL role
//! at application level, §4.5): its leader threads (one or several,
//! sharing one bounded admission queue and one batch-id source, all
//! feeding the one executor pool) pack incoming sequences into
//! 320-embedding batches, drive the per-layer multi-head executions
//! (one [`PlanSet`][crate::sparse::PlanSet] per batch, heads concurrent
//! on disjoint tile slices), fan each batch across K logical chips when
//! sharded ([`shard`]: nnz-balanced row partition from the plan set, one
//! sliced plan set per shard, max-ns/sum-pJ merge), track
//! hardware-simulated cost alongside functional results — per head, per
//! shard, and per batch — and report serving metrics (latency
//! percentiles, GOPS, head/shard/leader imbalance, batch-attributed
//! lines).

mod batcher;
mod metrics;
mod pipeline;
mod service;
pub mod shard;

pub use batcher::{BatchIds, BatchPlan, Batcher, PackedRequest};
pub use metrics::{
    HeadLine, HeadMetrics, LatencyHistogram, LeaderMetrics, PlanLine, ServeMetrics, ShardLine,
    ShardMetrics,
};
pub use pipeline::{EncoderStack, LayerOutput};
pub use service::{
    InferenceResponse, ServeError, ServeHooks, ServeResult, Service, ServiceConfig, ShedReason,
    SubmitOptions,
};
pub use shard::{ShardCost, ShardedBatchCost};
