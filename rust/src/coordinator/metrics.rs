//! Serving metrics: latency percentiles, throughput, batch accounting.

use std::time::Duration;

/// Fixed-bucket latency histogram (log-spaced, 1 µs … 100 s).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    bounds_ns: Vec<u64>,
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1µs, 2µs, 5µs, 10µs, ... decade ladder up to 100s
        let mut bounds = Vec::new();
        let mut base: u64 = 1_000;
        while base <= 100_000_000_000 {
            for m in [1, 2, 5] {
                bounds.push(base * m);
            }
            base *= 10;
        }
        Self { buckets: vec![0; bounds.len() + 1], bounds_ns: bounds, count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos() as u64;
        let idx = self.bounds_ns.partition_point(|&b| b < ns);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// Upper bound of the bucket containing quantile `q` (0 < q ≤ 1).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let ns = self.bounds_ns.get(i).copied().unwrap_or(self.max_ns);
                return Duration::from_nanos(ns.min(self.max_ns.max(1)));
            }
        }
        Duration::from_nanos(self.max_ns)
    }
}

/// Per-head serving accounting (index = head). Heads run concurrently
/// on disjoint tile slices, so batch wall time is the max over heads
/// while each head still burns its own energy — the per-head lines make
/// head imbalance (one dense head stalling the batch) visible.
#[derive(Clone, Copy, Debug, Default)]
pub struct HeadMetrics {
    /// Simulated per-head latency summed across batches (ns).
    pub sim_ns: f64,
    /// Simulated per-head energy summed across batches (pJ).
    pub sim_pj: f64,
    /// Sum of per-batch mask densities (divide by `batches` for mean).
    pub density_sum: f64,
}

/// Aggregate serving counters.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub used_rows: u64,
    pub latency: LatencyHistogram,
    /// Simulated accelerator time (ns) across batches (max over heads
    /// per batch, summed over batches).
    pub sim_ns: f64,
    /// Simulated accelerator energy (pJ), summed over heads and batches.
    pub sim_pj: f64,
    /// Per-head accounting, head order; sized on first recorded batch.
    pub heads: Vec<HeadMetrics>,
}

impl ServeMetrics {
    pub fn batch_utilization(&self) -> f64 {
        let total = self.used_rows + self.padded_rows;
        if total == 0 {
            0.0
        } else {
            self.used_rows as f64 / total as f64
        }
    }

    /// Fold one batch's per-head lines in (slices share head order).
    pub fn record_heads(&mut self, sim_ns: &[f64], sim_pj: &[f64], density: &[f64]) {
        if self.heads.len() < sim_ns.len() {
            self.heads.resize(sim_ns.len(), HeadMetrics::default());
        }
        for (h, m) in self.heads.iter_mut().enumerate() {
            m.sim_ns += sim_ns.get(h).copied().unwrap_or(0.0);
            m.sim_pj += sim_pj.get(h).copied().unwrap_or(0.0);
            m.density_sum += density.get(h).copied().unwrap_or(0.0);
        }
    }

    /// Mean per-head densities over the recorded batches.
    pub fn head_mean_densities(&self) -> Vec<f64> {
        let n = self.batches.max(1) as f64;
        self.heads.iter().map(|h| h.density_sum / n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 40, 50, 500, 5000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(1.0));
        assert!(h.max() >= Duration::from_micros(5000));
    }

    #[test]
    fn empty_histogram_zeroes() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn mean_reasonable() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(1));
        h.record(Duration::from_millis(3));
        let m = h.mean();
        assert!(m >= Duration::from_millis(1) && m <= Duration::from_millis(3));
    }

    #[test]
    fn head_metrics_accumulate() {
        let mut m = ServeMetrics::default();
        m.batches = 2;
        m.record_heads(&[10.0, 20.0], &[1.0, 2.0], &[0.1, 0.3]);
        m.record_heads(&[30.0, 40.0], &[3.0, 4.0], &[0.2, 0.4]);
        assert_eq!(m.heads.len(), 2);
        assert!((m.heads[0].sim_ns - 40.0).abs() < 1e-12);
        assert!((m.heads[1].sim_pj - 6.0).abs() < 1e-12);
        let means = m.head_mean_densities();
        assert!((means[0] - 0.15).abs() < 1e-12);
        assert!((means[1] - 0.35).abs() < 1e-12);
    }

    #[test]
    fn utilization() {
        let m = ServeMetrics { used_rows: 60, padded_rows: 40, ..Default::default() };
        assert!((m.batch_utilization() - 0.6).abs() < 1e-12);
        assert_eq!(ServeMetrics::default().batch_utilization(), 0.0);
    }
}
