//! Serving metrics: latency percentiles, throughput, batch accounting.

use std::time::Duration;

use crate::runtime::Lane;

/// Fixed-bucket latency histogram (log-spaced, 1 µs … 100 s).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    bounds_ns: Vec<u64>,
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1µs, 2µs, 5µs, 10µs, ... decade ladder up to 100s
        let mut bounds = Vec::new();
        let mut base: u64 = 1_000;
        while base <= 100_000_000_000 {
            for m in [1, 2, 5] {
                bounds.push(base * m);
            }
            base *= 10;
        }
        Self { buckets: vec![0; bounds.len() + 1], bounds_ns: bounds, count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos() as u64;
        let idx = self.bounds_ns.partition_point(|&b| b < ns);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns / self.count)
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// Upper bound of the bucket containing quantile `q` (0 < q ≤ 1).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                let ns = self.bounds_ns.get(i).copied().unwrap_or(self.max_ns);
                return Duration::from_nanos(ns.min(self.max_ns.max(1)));
            }
        }
        Duration::from_nanos(self.max_ns)
    }

    pub fn p50(&self) -> Duration {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.quantile(0.99)
    }

    /// The log-spaced bucket upper bounds (ns) — quantiles resolve to
    /// one of these, clamped to the observed max.
    pub fn bucket_bounds_ns(&self) -> &[u64] {
        &self.bounds_ns
    }
}

/// Per-head serving accounting (index = head). Heads run concurrently
/// on disjoint tile slices, so batch wall time is the max over heads
/// while each head still burns its own energy — the per-head lines make
/// head imbalance (one dense head stalling the batch) visible.
#[derive(Clone, Copy, Debug, Default)]
pub struct HeadMetrics {
    /// Simulated per-head latency summed across batches (ns).
    pub sim_ns: f64,
    /// Simulated per-head energy summed across batches (pJ).
    pub sim_pj: f64,
    /// Sum of per-batch mask densities (divide by `batches` for mean).
    pub density_sum: f64,
}

/// Per-shard serving accounting (index = shard / logical chip). Shards
/// process disjoint row slices of each batch concurrently, so batch
/// wall time is the slowest chip — the per-shard lines make shard
/// imbalance (one nnz-heavy slice stalling the batch) visible.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardMetrics {
    /// Simulated per-shard latency summed across batches (ns).
    pub sim_ns: f64,
    /// Simulated per-shard energy summed across batches (pJ).
    pub sim_pj: f64,
    /// Batch rows this shard owned, summed across batches.
    pub rows: u64,
    /// Masked coordinates this shard dispatched, summed across batches.
    pub nnz: u64,
}

/// One batch's per-head attribution line. Carries the batch id so that
/// when several packed batches are in flight (multi-leader serving,
/// interleaved logs) every head line remains attributable to exactly
/// one batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HeadLine {
    pub batch: u64,
    pub head: usize,
    pub sim_ns: f64,
    pub sim_pj: f64,
    pub density: f64,
}

/// One batch's per-shard attribution line (batch id carried for the
/// same reason as [`HeadLine`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardLine {
    pub batch: u64,
    pub shard: usize,
    pub rows: usize,
    pub nnz: usize,
    pub sim_ns: f64,
    pub sim_pj: f64,
}

/// One batch's per-layer plan-evolution line. Under cascade pruning the
/// dispatch plan shrinks between layers; these lines make the narrowing
/// observable per batch: how many coordinates each layer actually
/// dispatched, how many query rows / heads survived the previous
/// narrowing step, and what the narrowing cost versus a full ReCAM
/// re-scan would have been. Static serving records full-plan lines with
/// zero narrowing cost.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanLine {
    pub batch: u64,
    pub layer: usize,
    /// Masked coordinates the layer's plans dispatched (sum over heads).
    pub nnz: usize,
    /// Query rows still populated after the previous narrowing step.
    pub rows_kept: usize,
    /// Heads still populated after the previous narrowing step.
    pub heads_kept: usize,
    /// Simulated cost of deriving this layer's plans by narrowing (ns).
    pub narrow_ns: f64,
    /// Simulated cost a full ReCAM re-scan would have charged (ns).
    pub rescan_ns: f64,
}

/// Per-leader serving accounting (index = leader thread). Leaders run
/// independent batching loops feeding the one executor pool, so the
/// per-leader lines make leader imbalance (one leader starving while
/// another drains the queue) visible.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LeaderMetrics {
    /// Batches this leader sealed and executed.
    pub batches: u64,
    /// Requests this leader served.
    pub requests: u64,
    /// Simulated accelerator time attributed to this leader's batches
    /// (ns).
    pub sim_ns: f64,
}

/// Attribution lines kept per log; oldest drop first so a long-running
/// service holds bounded memory while recent batches stay inspectable.
const LINE_LOG_CAP: usize = 4096;

/// Aggregate serving counters.
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub batches: u64,
    pub padded_rows: u64,
    pub used_rows: u64,
    /// Submit-to-reply latency (queue wait + batching window + execution),
    /// all lanes combined.
    pub latency: LatencyHistogram,
    /// Submit-to-reply latency for batches executed on [`Lane::High`].
    pub latency_high: LatencyHistogram,
    /// Submit-to-reply latency for batches executed on [`Lane::Normal`].
    pub latency_normal: LatencyHistogram,
    /// Requests shed at admission because the bounded queue was full.
    pub shed_queue_full: u64,
    /// Requests shed because their deadline expired before a leader
    /// packed them into a window.
    pub shed_deadline: u64,
    /// Batches executed on the executor's high-priority lane.
    pub high_lane_batches: u64,
    /// Simulated accelerator time (ns) across batches (max over
    /// shards/heads per batch, summed over batches).
    pub sim_ns: f64,
    /// Simulated accelerator energy (pJ), summed over shards, heads and
    /// batches.
    pub sim_pj: f64,
    /// Per-head accounting, head order; sized on first recorded batch.
    pub heads: Vec<HeadMetrics>,
    /// Per-shard accounting, shard order; sized on first sharded batch
    /// (empty under unsharded serving).
    pub shards: Vec<ShardMetrics>,
    /// Recent per-batch head lines, each carrying its batch id.
    pub head_lines: Vec<HeadLine>,
    /// Recent per-batch shard lines, each carrying its batch id.
    pub shard_lines: Vec<ShardLine>,
    /// Recent per-batch per-layer plan-evolution lines.
    pub plan_lines: Vec<PlanLine>,
    /// Simulated plan-narrowing time across batches (ns); zero under
    /// static serving.
    pub narrow_ns: f64,
    /// Simulated time full ReCAM re-scans would have charged for the
    /// same plan derivations (ns); zero under static serving.
    pub rescan_ns: f64,
    /// Per-leader accounting, leader order; sized at service startup
    /// (len 1 under single-leader serving).
    pub leaders: Vec<LeaderMetrics>,
    /// Batches whose layer-0 plans were served from the plan cache —
    /// mask generation and the ReCAM scan were skipped entirely.
    pub plan_cache_hits: u64,
    /// Batches whose layer-0 plans had to be built (prefetched or
    /// inline) because no cached entry matched their payload.
    pub plan_cache_misses: u64,
    /// Simulated scan time (ns) hidden behind compute by the prefetch
    /// pipeline: for prefetch-built plans, the part of the scan that
    /// overlapped the previous batch's execution; for cache hits, the
    /// whole scan that was never run.
    pub prefetch_overlapped_ns: f64,
}

impl ServeMetrics {
    /// Total requests shed without executing (queue-full + deadline).
    pub fn shed(&self) -> u64 {
        self.shed_queue_full + self.shed_deadline
    }

    pub fn batch_utilization(&self) -> f64 {
        let total = self.used_rows + self.padded_rows;
        if total == 0 {
            0.0
        } else {
            self.used_rows as f64 / total as f64
        }
    }

    /// Fold one batch's per-head lines in (slices share head order).
    /// `batch` is the leader-assigned packed-batch id the lines are
    /// attributed to.
    pub fn record_heads(&mut self, batch: u64, sim_ns: &[f64], sim_pj: &[f64], density: &[f64]) {
        if self.heads.len() < sim_ns.len() {
            self.heads.resize(sim_ns.len(), HeadMetrics::default());
        }
        for (h, m) in self.heads.iter_mut().enumerate() {
            m.sim_ns += sim_ns.get(h).copied().unwrap_or(0.0);
            m.sim_pj += sim_pj.get(h).copied().unwrap_or(0.0);
            m.density_sum += density.get(h).copied().unwrap_or(0.0);
        }
        for h in 0..sim_ns.len() {
            self.head_lines.push(HeadLine {
                batch,
                head: h,
                sim_ns: sim_ns[h],
                sim_pj: sim_pj.get(h).copied().unwrap_or(0.0),
                density: density.get(h).copied().unwrap_or(0.0),
            });
        }
        trim_log(&mut self.head_lines);
    }

    /// Fold one batch's per-shard lines in (`rows`/`nnz`/`sim_ns`/
    /// `sim_pj` share shard order), attributed to `batch`.
    pub fn record_shards(
        &mut self,
        batch: u64,
        rows: &[usize],
        nnz: &[usize],
        sim_ns: &[f64],
        sim_pj: &[f64],
    ) {
        if self.shards.len() < sim_ns.len() {
            self.shards.resize(sim_ns.len(), ShardMetrics::default());
        }
        for (s, m) in self.shards.iter_mut().enumerate() {
            m.sim_ns += sim_ns.get(s).copied().unwrap_or(0.0);
            m.sim_pj += sim_pj.get(s).copied().unwrap_or(0.0);
            m.rows += rows.get(s).copied().unwrap_or(0) as u64;
            m.nnz += nnz.get(s).copied().unwrap_or(0) as u64;
        }
        for s in 0..sim_ns.len() {
            self.shard_lines.push(ShardLine {
                batch,
                shard: s,
                rows: rows.get(s).copied().unwrap_or(0),
                nnz: nnz.get(s).copied().unwrap_or(0),
                sim_ns: sim_ns[s],
                sim_pj: sim_pj.get(s).copied().unwrap_or(0.0),
            });
        }
        trim_log(&mut self.shard_lines);
    }

    /// Mean per-head densities over the recorded batches.
    pub fn head_mean_densities(&self) -> Vec<f64> {
        let n = self.batches.max(1) as f64;
        self.heads.iter().map(|h| h.density_sum / n).collect()
    }

    /// Record one request's submit-to-reply latency, attributed to the
    /// executor lane its batch ran on. Feeds both the combined
    /// histogram and the per-lane one so interactive (`Lane::High`)
    /// tail latency stays observable separately from batch traffic.
    pub fn record_latency(&mut self, lane: Lane, d: Duration) {
        self.latency.record(d);
        match lane {
            Lane::High => self.latency_high.record(d),
            Lane::Normal => self.latency_normal.record(d),
        }
    }

    /// Fold one batch's per-layer plan-evolution lines in. The slices
    /// share layer order; `narrow_ns`/`rescan_ns` fold into the
    /// service-wide narrowing totals.
    #[allow(clippy::too_many_arguments)]
    pub fn record_plans(
        &mut self,
        batch: u64,
        nnz: &[usize],
        rows_kept: &[usize],
        heads_kept: &[usize],
        narrow_ns: &[f64],
        rescan_ns: &[f64],
    ) {
        for layer in 0..nnz.len() {
            let narrow = narrow_ns.get(layer).copied().unwrap_or(0.0);
            let rescan = rescan_ns.get(layer).copied().unwrap_or(0.0);
            self.narrow_ns += narrow;
            self.rescan_ns += rescan;
            self.plan_lines.push(PlanLine {
                batch,
                layer,
                nnz: nnz[layer],
                rows_kept: rows_kept.get(layer).copied().unwrap_or(0),
                heads_kept: heads_kept.get(layer).copied().unwrap_or(0),
                narrow_ns: narrow,
                rescan_ns: rescan,
            });
        }
        trim_log(&mut self.plan_lines);
    }

    /// Fold one batch's plan-sourcing outcome in: whether its layer-0
    /// plans came from the cache (the whole scan skipped) or had to be
    /// built, and how much simulated scan time the prefetch pipeline
    /// hid behind the previous batch's compute.
    pub fn record_plan_source(&mut self, cache_hit: bool, overlapped_ns: f64) {
        if cache_hit {
            self.plan_cache_hits += 1;
        } else {
            self.plan_cache_misses += 1;
        }
        self.prefetch_overlapped_ns += overlapped_ns;
    }

    /// Fold one executed batch into leader `leader`'s line.
    pub fn record_leader(&mut self, leader: usize, requests: u64, sim_ns: f64) {
        if self.leaders.len() <= leader {
            self.leaders.resize(leader + 1, LeaderMetrics::default());
        }
        let m = &mut self.leaders[leader];
        m.batches += 1;
        m.requests += requests;
        m.sim_ns += sim_ns;
    }
}

/// Drop oldest lines beyond [`LINE_LOG_CAP`].
fn trim_log<T>(log: &mut Vec<T>) {
    if log.len() > LINE_LOG_CAP {
        log.drain(..log.len() - LINE_LOG_CAP);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 40, 50, 500, 5000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(1.0));
        assert!(h.max() >= Duration::from_micros(5000));
    }

    #[test]
    fn empty_histogram_zeroes() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.p50(), Duration::ZERO);
        assert_eq!(h.p95(), Duration::ZERO);
        assert_eq!(h.p99(), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.max(), Duration::ZERO);
    }

    #[test]
    fn bucket_bounds_are_the_125_decade_ladder() {
        let h = LatencyHistogram::new();
        let bounds = h.bucket_bounds_ns();
        // 9 decades × 3 mantissas = 27 bounds, strictly increasing,
        // starting 1/2/5 µs; the last decade starts at 100 s so the top
        // bound is 500 s.
        assert_eq!(bounds.len(), 27);
        assert_eq!(&bounds[..3], &[1_000, 2_000, 5_000]);
        assert_eq!(bounds[bounds.len() - 1], 500_000_000_000);
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn samples_land_in_their_boundary_bucket() {
        // A sample exactly on a bucket bound resolves to that bound: 1ms
        // recordings must report 1ms quantiles, not the next bucket up.
        let mut h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(Duration::from_millis(1));
        }
        assert_eq!(h.p50(), Duration::from_millis(1));
        assert_eq!(h.p99(), Duration::from_millis(1));
        // Just past the bound lands in the next bucket, clamped to the
        // observed max rather than rounding a 1.001ms run up to 2ms.
        let mut h = LatencyHistogram::new();
        for _ in 0..10 {
            h.record(Duration::from_nanos(1_000_001));
        }
        assert_eq!(h.p99(), Duration::from_nanos(1_000_001));
    }

    #[test]
    fn one_sample_dominates_every_quantile() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(3));
        // 3ms sits inside the (2ms, 5ms] bucket; the bound is clamped to
        // the observed max so every quantile reports the sample itself.
        for q in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Duration::from_millis(3), "q={q}");
        }
        assert_eq!(h.count(), 1);
        assert_eq!(h.mean(), Duration::from_millis(3));
    }

    #[test]
    fn known_bimodal_distribution_quantiles() {
        // 90 fast (10µs) + 10 slow (100ms) samples: p50 stays in the
        // fast mode, p95 and p99 land on the slow mode.
        let mut h = LatencyHistogram::new();
        for _ in 0..90 {
            h.record(Duration::from_micros(10));
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(100));
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.p50(), Duration::from_micros(10));
        assert_eq!(h.p95(), Duration::from_millis(100));
        assert_eq!(h.p99(), Duration::from_millis(100));
        assert_eq!(h.max(), Duration::from_millis(100));
    }

    #[test]
    fn shed_counters_total() {
        let m = ServeMetrics { shed_queue_full: 3, shed_deadline: 4, ..Default::default() };
        assert_eq!(m.shed(), 7);
        assert_eq!(ServeMetrics::default().shed(), 0);
    }

    #[test]
    fn mean_reasonable() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(1));
        h.record(Duration::from_millis(3));
        let m = h.mean();
        assert!(m >= Duration::from_millis(1) && m <= Duration::from_millis(3));
    }

    #[test]
    fn head_metrics_accumulate() {
        let mut m = ServeMetrics { batches: 2, ..Default::default() };
        m.record_heads(0, &[10.0, 20.0], &[1.0, 2.0], &[0.1, 0.3]);
        m.record_heads(1, &[30.0, 40.0], &[3.0, 4.0], &[0.2, 0.4]);
        assert_eq!(m.heads.len(), 2);
        assert!((m.heads[0].sim_ns - 40.0).abs() < 1e-12);
        assert!((m.heads[1].sim_pj - 6.0).abs() < 1e-12);
        let means = m.head_mean_densities();
        assert!((means[0] - 0.15).abs() < 1e-12);
        assert!((means[1] - 0.35).abs() < 1e-12);
    }

    #[test]
    fn head_lines_carry_batch_ids() {
        // Two batches interleaved in one log: every line still names its
        // batch, so per-batch attribution survives concurrency.
        let mut m = ServeMetrics::default();
        m.record_heads(7, &[10.0, 20.0], &[1.0, 2.0], &[0.1, 0.3]);
        m.record_heads(9, &[30.0, 40.0], &[3.0, 4.0], &[0.2, 0.4]);
        assert_eq!(m.head_lines.len(), 4);
        let batch7: Vec<_> = m.head_lines.iter().filter(|l| l.batch == 7).collect();
        let batch9: Vec<_> = m.head_lines.iter().filter(|l| l.batch == 9).collect();
        assert_eq!(batch7.len(), 2);
        assert_eq!(batch9.len(), 2);
        assert_eq!((batch7[0].head, batch7[1].head), (0, 1));
        assert!((batch7[1].sim_ns - 20.0).abs() < 1e-12);
        assert!((batch9[0].sim_ns - 30.0).abs() < 1e-12);
    }

    #[test]
    fn shard_metrics_accumulate_with_lines() {
        let mut m = ServeMetrics::default();
        m.record_shards(0, &[80, 80], &[1000, 900], &[5.0, 4.0], &[0.5, 0.4]);
        m.record_shards(1, &[70, 90], &[800, 1100], &[3.0, 6.0], &[0.3, 0.6]);
        assert_eq!(m.shards.len(), 2);
        assert!((m.shards[0].sim_ns - 8.0).abs() < 1e-12);
        assert!((m.shards[1].sim_pj - 1.0).abs() < 1e-12);
        assert_eq!(m.shards[0].rows, 150);
        assert_eq!(m.shards[1].nnz, 2000);
        assert_eq!(m.shard_lines.len(), 4);
        assert_eq!(
            m.shard_lines[3],
            ShardLine { batch: 1, shard: 1, rows: 90, nnz: 1100, sim_ns: 6.0, sim_pj: 0.6 }
        );
    }

    #[test]
    fn line_logs_stay_bounded() {
        let mut m = ServeMetrics::default();
        for b in 0..3000u64 {
            m.record_heads(b, &[1.0, 2.0], &[0.1, 0.2], &[0.5, 0.5]);
        }
        assert_eq!(m.head_lines.len(), 4096);
        // oldest dropped first: the newest batch is still present
        assert_eq!(m.head_lines.last().unwrap().batch, 2999);
        assert!(m.head_lines.first().unwrap().batch > 0);
    }

    #[test]
    fn leader_metrics_accumulate_per_leader() {
        let mut m = ServeMetrics::default();
        m.record_leader(0, 3, 100.0);
        m.record_leader(2, 1, 50.0);
        m.record_leader(0, 2, 25.0);
        assert_eq!(m.leaders.len(), 3);
        assert_eq!(m.leaders[0], LeaderMetrics { batches: 2, requests: 5, sim_ns: 125.0 });
        // leader 1 exists (sized by the highest index) but idle
        assert_eq!(m.leaders[1], LeaderMetrics::default());
        assert_eq!(m.leaders[2].batches, 1);
    }

    #[test]
    fn lane_latency_splits_and_combines() {
        let mut m = ServeMetrics::default();
        m.record_latency(Lane::High, Duration::from_micros(10));
        m.record_latency(Lane::Normal, Duration::from_millis(5));
        m.record_latency(Lane::Normal, Duration::from_millis(5));
        assert_eq!(m.latency.count(), 3);
        assert_eq!(m.latency_high.count(), 1);
        assert_eq!(m.latency_normal.count(), 2);
        // the high lane's tail is its own, not polluted by batch traffic
        assert_eq!(m.latency_high.p99(), Duration::from_micros(10));
        assert_eq!(m.latency_normal.p99(), Duration::from_millis(5));
    }

    #[test]
    fn plan_lines_accumulate_narrowing_totals() {
        let mut m = ServeMetrics::default();
        m.record_plans(3, &[900, 400], &[32, 16], &[4, 2], &[0.0, 12.5], &[0.0, 80.0]);
        assert_eq!(m.plan_lines.len(), 2);
        assert_eq!(
            m.plan_lines[1],
            PlanLine {
                batch: 3,
                layer: 1,
                nnz: 400,
                rows_kept: 16,
                heads_kept: 2,
                narrow_ns: 12.5,
                rescan_ns: 80.0,
            }
        );
        assert!((m.narrow_ns - 12.5).abs() < 1e-12);
        assert!((m.rescan_ns - 80.0).abs() < 1e-12);
        // static batches contribute zero narrowing cost
        m.record_plans(4, &[900], &[32], &[4], &[0.0], &[0.0]);
        assert!((m.narrow_ns - 12.5).abs() < 1e-12);
        assert_eq!(m.plan_lines.len(), 3);
    }

    #[test]
    fn plan_source_counters_accumulate() {
        let mut m = ServeMetrics::default();
        m.record_plan_source(false, 120.0);
        m.record_plan_source(true, 500.0);
        m.record_plan_source(true, 480.0);
        assert_eq!(m.plan_cache_hits, 2);
        assert_eq!(m.plan_cache_misses, 1);
        assert!((m.prefetch_overlapped_ns - 1100.0).abs() < 1e-12);
    }

    #[test]
    fn utilization() {
        let m = ServeMetrics { used_rows: 60, padded_rows: 40, ..Default::default() };
        assert!((m.batch_utilization() - 0.6).abs() < 1e-12);
        assert_eq!(ServeMetrics::default().batch_utilization(), 0.0);
    }
}
