//! Serving loop: requests in, batched multi-head encoder executions out.
//!
//! The engine is single-threaded by design (interior `RefCell` stats;
//! with a PJRT backend the client is `Rc`-based too) — exactly like the
//! physical CPSAA chip is one device. The service spawns `leaders`
//! **leader threads**, each owning its own engine instance; callers
//! submit requests over one shared mpsc channel and block on a reply
//! channel. Dynamic batching happens in whichever leader claims the
//! channel: it drains whatever arrived within `max_wait` (or until a
//! batch fills), releases the channel, packs with [`Batcher`], executes
//! the encoder stack once per batch — one
//! [`PlanSet`][crate::sparse::PlanSet] per batch (one ReCAM scan per
//! head mask), reused across all layers — and fans results back out.
//! While one leader executes, the next leader is already draining the
//! channel, so batch windows pipeline with batch executions.
//!
//! All leaders dispatch kernels onto the **one** crate-wide
//! [`executor`][crate::runtime::executor] pool (sized by
//! `max_kernel_workers`), and all draw batch ids from one shared
//! [`BatchIds`] source, so ids stay unique and every interleaved metric
//! line remains attributable. Per-leader metrics lines make leader
//! imbalance visible. `leaders == 1` is the historical single-leader
//! loop.
//!
//! `model.heads > 1` fans each layer across concurrent per-head
//! workers inside the stack (§4.5 tile slices); responses and metrics
//! carry the per-head latency/energy/density lines.
//!
//! `shards > 1` additionally fans each packed batch across K logical
//! chips: rows are partitioned by per-row nnz from the batch's plan set,
//! each shard runs its slice (own sliced `PlanSet`, own simulated chip)
//! concurrently, and costs merge as max-ns across chips / sum-pJ.
//! Responses and metrics gain per-shard lines. `shards == 1` is
//! bit-identical to unsharded serving.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::attention::{MultiHeadWeights, Precision};
use crate::config::{HardwareConfig, ModelConfig};
use crate::runtime::{ArtifactSet, Engine};
use crate::tensor::Matrix;
use crate::workload::capture::{
    BatchTraceRecord, CaptureRecorder, RecordedBatch, RecordedRequest, RecordedResponse, SimTracer,
};

use super::batcher::{BatchIds, Batcher};
use super::metrics::ServeMetrics;
use super::pipeline::EncoderStack;

/// One inference request: token embeddings (rows ≤ seq_len).
struct InferenceRequest {
    id: u64,
    x: Matrix,
    reply: mpsc::Sender<Result<InferenceResponse>>,
}

/// What travels over the shared request channel: a single request (the
/// live-traffic path, co-batched by time window), or a pre-composed
/// group whose members enter **one** batching window atomically, in
/// order — the deterministic ingest path replay uses to reproduce a
/// recorded batch composition independent of wall-clock timing.
enum Msg {
    One(InferenceRequest),
    Group(Vec<InferenceRequest>),
}

/// Optional observation hooks threaded into every leader loop.
#[derive(Clone, Default)]
pub struct ServeHooks {
    /// Capture each admitted batch (payloads + deterministic response
    /// fields, in packing order) for later replay.
    pub recorder: Option<CaptureRecorder>,
    /// Collect each batch's simulated per-stage timelines (`--trace`).
    pub tracer: Option<SimTracer>,
}

/// The response: final hidden state rows for this request.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub hidden: Matrix,
    pub latency: Duration,
    /// Mean pruning-mask density over heads for this request's batch.
    pub mask_density: f64,
    /// Simulated accelerator time attributed to this request's batch
    /// (ns): per layer the max over concurrent heads, summed over layers.
    pub sim_ns: f64,
    /// Simulated accelerator energy for the batch (pJ), summed over
    /// heads and layers.
    pub sim_pj: f64,
    /// Per-head simulated time across the stack (ns), head order;
    /// `sim_ns` is its max.
    pub head_sim_ns: Vec<f64>,
    /// Per-head simulated energy across the stack (pJ), head order;
    /// `sim_pj` is its sum.
    pub head_sim_pj: Vec<f64>,
    /// Per-head pruning-mask density, head order.
    pub head_density: Vec<f64>,
    /// Per-shard simulated time across the stack (ns), shard order;
    /// empty under unsharded serving, else `sim_ns` is its max.
    pub shard_sim_ns: Vec<f64>,
    /// Per-shard simulated energy across the stack (pJ), shard order;
    /// empty when unsharded, else `sim_pj` is its sum.
    pub shard_sim_pj: Vec<f64>,
    /// Rows each shard owned of this request's batch (nnz-balanced);
    /// empty when unsharded.
    pub shard_rows: Vec<usize>,
    /// The leader thread that batched and executed this request.
    pub leader: usize,
    /// Kernel arithmetic mode this request was served at.
    pub precision: Precision,
}

impl InferenceResponse {
    /// Heads the serving stack fanned this batch across.
    pub fn heads(&self) -> usize {
        self.head_sim_ns.len()
    }

    /// Logical chips this request's batch ran on (1 when unsharded).
    pub fn shards(&self) -> usize {
        self.shard_sim_ns.len().max(1)
    }
}

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub layers: usize,
    /// Maximum time a request may wait for co-batching.
    pub max_wait: Duration,
    /// Logical chips each packed batch fans out across (≥ 1; 1 =
    /// unsharded, bit-identical to the single-chip path).
    pub shards: usize,
    /// Leader threads batching in parallel (≥ 1; 1 = the historical
    /// single-leader loop). All leaders feed the one executor pool and
    /// share one monotonic batch-id source.
    pub leaders: usize,
    /// Width of the crate-wide kernel executor pool. `None` keeps the
    /// process default (the `CPSAA_MAX_KERNEL_WORKERS` env var, else 8,
    /// capped at machine parallelism); `Some(n)` rebuilds the global
    /// pool at `n` workers via
    /// [`executor::configure`][crate::runtime::executor::configure] at
    /// startup so big machines are not throttled at the historical cap.
    /// Worker counts never change computed values, only throughput.
    pub max_kernel_workers: Option<usize>,
    /// Kernel arithmetic mode: `F32` (default, the reference path) or
    /// `I8` (i8-storage / i32-accumulate SDDMM score dots, dequantized
    /// at the softmax boundary; V stays f32).
    pub precision: Precision,
    /// Force the bit-identical scalar twins of the `tensor::simd` row
    /// primitives for every kernel in this process (same switch as the
    /// `CPSAA_FORCE_SCALAR` env var). Diagnostics knob: values never
    /// change, only throughput.
    pub force_scalar: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            layers: 2,
            max_wait: Duration::from_millis(2),
            shards: 1,
            leaders: 1,
            max_kernel_workers: None,
            precision: Precision::F32,
            force_scalar: false,
        }
    }
}

/// The serving front end. Cloneable across caller threads.
#[derive(Clone)]
pub struct Service {
    tx: mpsc::Sender<Msg>,
    metrics: Arc<Mutex<ServeMetrics>>,
    model: ModelConfig,
}

impl Service {
    /// Spawn the leader threads: each opens the artifacts and builds its
    /// own engine *on its own thread* (the client is not `Send`). All
    /// leaders share one request channel, one batch-id source, and the
    /// one global executor pool.
    pub fn start(
        artifact_dir: std::path::PathBuf,
        hw: HardwareConfig,
        model_overlay: ModelConfig,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        Self::start_with_hooks(artifact_dir, hw, model_overlay, cfg, ServeHooks::default())
    }

    /// [`start`][Self::start] with capture/trace hooks attached to every
    /// leader.
    pub fn start_with_hooks(
        artifact_dir: std::path::PathBuf,
        hw: HardwareConfig,
        model_overlay: ModelConfig,
        cfg: ServiceConfig,
        hooks: ServeHooks,
    ) -> Result<Self> {
        if cfg.leaders == 0 {
            return Err(anyhow!("leaders must be >= 1"));
        }
        // Process-wide lane switch: only ever *set* it here (never clear
        // on false), so an env-forced scalar run stays scalar.
        if cfg.force_scalar {
            crate::tensor::simd::set_force_scalar(true);
        }
        // Size the one crate-wide pool every leader feeds, before any
        // leader starts dispatching onto it.
        match cfg.max_kernel_workers {
            Some(0) => return Err(anyhow!("max_kernel_workers must be >= 1")),
            Some(n) => crate::runtime::executor::configure(n)
                .map_err(|e| anyhow!("max_kernel_workers: {e}"))?,
            None => {}
        }
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        // Size the per-leader lines up front so an idle leader shows as
        // an explicit zero row instead of silently missing — leader
        // imbalance is exactly what these lines exist to expose.
        let metrics = Arc::new(Mutex::new(ServeMetrics {
            leaders: vec![super::metrics::LeaderMetrics::default(); cfg.leaders],
            ..Default::default()
        }));
        let ids = BatchIds::new();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<ModelConfig>>();
        for leader in 0..cfg.leaders {
            let artifact_dir = artifact_dir.clone();
            let hw = hw.clone();
            let model_overlay = model_overlay.clone();
            let cfg = cfg.clone();
            let rx = rx.clone();
            let metrics = metrics.clone();
            let ids = ids.clone();
            let ready_tx = ready_tx.clone();
            let hooks = hooks.clone();
            std::thread::Builder::new()
                .name(format!("cpsaa-leader-{leader}"))
                .spawn(move || {
                    leader_loop(
                        leader,
                        artifact_dir,
                        hw,
                        model_overlay,
                        cfg,
                        rx,
                        metrics,
                        ids,
                        ready_tx,
                        hooks,
                    )
                })
                .context("spawning leader thread")?;
        }
        // Only the leaders hold ready senders now: a leader dying before
        // reporting in surfaces as a recv error instead of a hang.
        drop(ready_tx);
        // Wait for every engine to come up (or fail fast).
        let mut resolved: Option<ModelConfig> = None;
        for _ in 0..cfg.leaders {
            match ready_rx.recv() {
                Ok(Ok(model)) => {
                    resolved.get_or_insert(model);
                }
                Ok(Err(e)) => return Err(e),
                Err(_) => return Err(anyhow!("leader thread died during startup")),
            }
        }
        let model = resolved.expect("leaders >= 1, so at least one reported in");
        Ok(Self { tx, metrics, model })
    }

    /// The resolved serving model — artifact shapes overlaid with the
    /// caller's heads/layers/sharpness — as every leader loaded it.
    pub fn model(&self) -> &ModelConfig {
        &self.model
    }

    /// Submit a request without blocking; the returned receiver yields
    /// the response once its batch completes.
    pub fn submit(&self, id: u64, x: Matrix) -> Result<mpsc::Receiver<Result<InferenceResponse>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::One(InferenceRequest { id, x, reply }))
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rx)
    }

    /// Submit a pre-composed batch group: every member enters a single
    /// batching window atomically, in order, regardless of wall-clock
    /// timing or leader scheduling. This is how replay reproduces a
    /// recorded batch composition — and with it the exact FP summation
    /// order — deterministically.
    pub fn submit_group(
        &self,
        reqs: Vec<(u64, Matrix)>,
    ) -> Result<Vec<mpsc::Receiver<Result<InferenceResponse>>>> {
        let mut rxs = Vec::with_capacity(reqs.len());
        let mut group = Vec::with_capacity(reqs.len());
        for (id, x) in reqs {
            let (reply, rx) = mpsc::channel();
            group.push(InferenceRequest { id, x, reply });
            rxs.push(rx);
        }
        self.tx.send(Msg::Group(group)).map_err(|_| anyhow!("service stopped"))?;
        Ok(rxs)
    }

    /// Submit a request and block until its response arrives.
    pub fn infer(&self, id: u64, x: Matrix) -> Result<InferenceResponse> {
        let rx = self.submit(id, x)?;
        rx.recv().map_err(|_| anyhow!("request {id} dropped"))?
    }

    pub fn metrics(&self) -> ServeMetrics {
        // A leader that panicked while holding the metrics lock poisons
        // it; the counters it was updating are monotonic aggregates, so
        // reading them is still sound — don't let one dead leader take
        // observability down with it.
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    leader: usize,
    artifact_dir: std::path::PathBuf,
    hw: HardwareConfig,
    model_overlay: ModelConfig,
    cfg: ServiceConfig,
    rx: Arc<Mutex<mpsc::Receiver<Msg>>>,
    metrics: Arc<Mutex<ServeMetrics>>,
    ids: BatchIds,
    ready: mpsc::Sender<Result<ModelConfig>>,
    hooks: ServeHooks,
) {
    // Build everything that must live on this thread.
    let setup = (|| -> Result<(Engine, MultiHeadWeights, ModelConfig)> {
        let set = ArtifactSet::open(&artifact_dir)?;
        let c = &set.manifest.config;
        // Shapes come from the artifacts; heads/layers/sharpness from the
        // caller's overlay (the manifest predates multi-head serving).
        let model = ModelConfig {
            seq_len: c.seq_len,
            d_model: c.d_model,
            d_k: c.d_k,
            d_ff: c.d_ff,
            gamma: c.gamma,
            quant_bits: c.quant_bits,
            theta: c.theta,
            ..model_overlay
        };
        model.validate().map_err(|e| anyhow!("invalid serving model config: {e}"))?;
        if cfg.layers == 0 {
            return Err(anyhow!("layers must be >= 1"));
        }
        if cfg.shards == 0 {
            return Err(anyhow!("shards must be >= 1"));
        }
        let weights = MultiHeadWeights::load(&set.dir.join("weights.json"), model.heads)?;
        weights.validate().map_err(|e| anyhow!("bad weights for {} heads: {e}", model.heads))?;
        let engine = Engine::load(&set)?;
        Ok((engine, weights, model))
    })();
    let (engine, weights, model) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(v.2.clone()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let stack = EncoderStack::new(&engine, weights, hw, model.clone(), cfg.layers)
        .with_shards(cfg.shards)
        .with_precision(cfg.precision);
    // One batcher per leader, all drawing from the service's shared
    // monotonic id source: every per-head/per-shard metric line stays
    // keyed to exactly one batch even with several leaders in flight.
    let mut batcher = Batcher::with_ids(model.seq_len, model.d_model, ids);

    loop {
        // Claim the shared channel for one batching window; competing
        // leaders block here while this one drains, then take over the
        // channel the moment this leader moves on to execution.
        let window = {
            // A leader that panicked while holding this lock poisons
            // it, but the receiver inside stays sound — surviving
            // leaders keep claiming windows instead of shutting the
            // whole service down.
            let channel = rx.lock().unwrap_or_else(|e| e.into_inner());
            let Ok(first) = channel.recv() else { return };
            match first {
                // A pre-composed group seals its window immediately:
                // its composition was decided by the sender (replay),
                // not by arrival timing.
                Msg::Group(group) => group,
                Msg::One(first) => {
                    let mut window = vec![first];
                    let mut rows = window[0].x.rows();
                    let deadline = Instant::now() + cfg.max_wait;
                    while rows < model.seq_len {
                        let remaining = deadline.saturating_duration_since(Instant::now());
                        if remaining.is_zero() {
                            break;
                        }
                        match channel.recv_timeout(remaining) {
                            Ok(Msg::One(req)) => {
                                rows += req.x.rows();
                                window.push(req);
                            }
                            // A group arriving mid-window joins it
                            // whole (members stay contiguous and in
                            // order) and seals it.
                            Ok(Msg::Group(group)) => {
                                window.extend(group);
                                break;
                            }
                            Err(_) => break,
                        }
                    }
                    window
                }
            }
        };

        let mut replies = std::collections::HashMap::new();
        let arrival = Instant::now();
        for req in window {
            match batcher.push(req.id, req.x) {
                Ok(()) => {
                    replies.insert(req.id, req.reply);
                }
                Err(e) => {
                    let _ = req.reply.send(Err(anyhow!("rejected: {e}")));
                }
            }
        }

        for plan in batcher.drain() {
            match stack.forward_traced(&plan.x) {
                Ok((outs, traces)) => {
                    if let Some(tracer) = &hooks.tracer {
                        tracer.record(BatchTraceRecord { batch: plan.batch, leader, traces });
                    }
                    let last = outs.last().expect("≥1 layer");
                    let sim_ns: f64 = outs.iter().map(|o| o.sim_ns).sum();
                    let sim_pj: f64 = outs.iter().map(|o| o.sim_pj).sum();
                    let density =
                        outs.iter().map(|o| o.mask_density).sum::<f64>() / outs.len() as f64;
                    // Per-head and per-shard lines across the whole
                    // stack, summed per layer exactly like sim_ns so
                    // sim_ns == max(head_ns) == max(shard_ns) holds to
                    // the bit (sim_pj == Σ lines up to summation-order
                    // rounding).
                    let heads_n = outs[0].head_sim_ns.len();
                    let mut head_ns = vec![0.0f64; heads_n];
                    let mut head_pj = vec![0.0f64; heads_n];
                    let shards_n = outs[0].shard_sim_ns.len();
                    let mut shard_ns = vec![0.0f64; shards_n];
                    let mut shard_pj = vec![0.0f64; shards_n];
                    for o in &outs {
                        for (acc, v) in head_ns.iter_mut().zip(&o.head_sim_ns) {
                            *acc += v;
                        }
                        for (acc, v) in head_pj.iter_mut().zip(&o.head_sim_pj) {
                            *acc += v;
                        }
                        for (acc, v) in shard_ns.iter_mut().zip(&o.shard_sim_ns) {
                            *acc += v;
                        }
                        for (acc, v) in shard_pj.iter_mut().zip(&o.shard_sim_pj) {
                            *acc += v;
                        }
                    }
                    let head_density = outs[0].head_density.clone();
                    // Shard row/nnz ownership comes from the first
                    // layer's partition (the batch's plan set).
                    let shard_rows = outs[0].shard_rows.clone();
                    let shard_nnz = outs[0].shard_nnz.clone();
                    // Poison recovery mirrors `Service::metrics`: the
                    // aggregates stay sound, so a dead leader must not
                    // kill the survivors' recording path.
                    let mut m = metrics.lock().unwrap_or_else(|e| e.into_inner());
                    m.batches += 1;
                    m.used_rows += plan.used_rows as u64;
                    m.padded_rows += (model.seq_len - plan.used_rows) as u64;
                    m.sim_ns += sim_ns;
                    m.sim_pj += sim_pj;
                    m.record_heads(plan.batch, &head_ns, &head_pj, &head_density);
                    if !shard_ns.is_empty() {
                        m.record_shards(plan.batch, &shard_rows, &shard_nnz, &shard_ns, &shard_pj);
                    }
                    m.record_leader(leader, plan.entries.len() as u64, sim_ns);
                    let mut captured: Vec<RecordedRequest> = Vec::new();
                    for entry in &plan.entries {
                        let hidden = plan.extract(&last.hidden, entry);
                        let latency = arrival.elapsed();
                        m.requests += 1;
                        m.latency.record(latency);
                        if hooks.recorder.is_some() {
                            captured.push(RecordedRequest {
                                id: entry.id,
                                // The request's payload rows, sliced
                                // back out of the packed batch bitwise.
                                x: plan.extract(&plan.x, entry),
                                response: RecordedResponse {
                                    hidden: hidden.clone(),
                                    mask_density: density,
                                    sim_ns,
                                    sim_pj,
                                    head_sim_ns: head_ns.clone(),
                                    head_sim_pj: head_pj.clone(),
                                    head_density: head_density.clone(),
                                    shard_sim_ns: shard_ns.clone(),
                                    shard_sim_pj: shard_pj.clone(),
                                    shard_rows: shard_rows.clone(),
                                },
                            });
                        }
                        if let Some(reply) = replies.remove(&entry.id) {
                            let _ = reply.send(Ok(InferenceResponse {
                                id: entry.id,
                                hidden,
                                latency,
                                mask_density: density,
                                sim_ns,
                                sim_pj,
                                head_sim_ns: head_ns.clone(),
                                head_sim_pj: head_pj.clone(),
                                head_density: head_density.clone(),
                                shard_sim_ns: shard_ns.clone(),
                                shard_sim_pj: shard_pj.clone(),
                                shard_rows: shard_rows.clone(),
                                leader,
                                precision: cfg.precision,
                            }));
                        }
                    }
                    drop(m);
                    if let Some(recorder) = &hooks.recorder {
                        if !captured.is_empty() {
                            recorder.record(RecordedBatch { batch: plan.batch, requests: captured });
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("batch failed: {e:#}");
                    for entry in &plan.entries {
                        if let Some(reply) = replies.remove(&entry.id) {
                            let _ = reply.send(Err(anyhow!("{msg}")));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;
    use std::path::PathBuf;

    #[test]
    fn serve_roundtrip() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let svc = Service::start(
            dir,
            HardwareConfig::paper(),
            ModelConfig::paper(),
            ServiceConfig { layers: 1, ..Default::default() },
        )
        .unwrap();
        let mut rng = SeededRng::new(3);
        // d_model comes from the manifest; read it indirectly by probing a
        // valid request shape (the artifact default is 256).
        let x = rng.normal_matrix(24, 256, 1.0);
        let resp = svc.infer(42, x).unwrap();
        assert_eq!(resp.id, 42);
        assert_eq!(resp.hidden.shape(), (24, 256));
        assert!(resp.hidden.all_finite());
        assert!(resp.sim_ns > 0.0);
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
    }

    #[test]
    fn concurrent_callers_batch_together() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let svc = Service::start(
            dir,
            HardwareConfig::paper(),
            ModelConfig::paper(),
            ServiceConfig { layers: 1, max_wait: Duration::from_millis(50), ..Default::default() },
        )
        .unwrap();
        let mut handles = Vec::new();
        for id in 0..4u64 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = SeededRng::new(id);
                let x = rng.normal_matrix(16, 256, 1.0);
                svc.infer(id, x).unwrap()
            }));
        }
        let mut ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap().id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        let m = svc.metrics();
        assert_eq!(m.requests, 4);
        // 4 × 16 = 64 rows fit in one 128-row batch if they co-arrived;
        // allow up to 4 batches under scheduling jitter.
        assert!(m.batches <= 4);
    }

    #[test]
    fn zero_shards_rejected_at_startup() {
        let dir = std::env::temp_dir()
            .join(format!("cpsaa-svc-shards0-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 2).unwrap();
        let err = match Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig { shards: 0, ..Default::default() },
        ) {
            Ok(_) => panic!("shards = 0 must be rejected at startup"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("shards"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_leaders_rejected_at_startup() {
        let dir = std::env::temp_dir()
            .join(format!("cpsaa-svc-leaders0-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 2).unwrap();
        let err = match Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig { leaders: 0, ..Default::default() },
        ) {
            Ok(_) => panic!("leaders = 0 must be rejected at startup"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("leaders"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_leader_serves_all_requests_with_unique_batch_ids() {
        let dir = std::env::temp_dir()
            .join(format!("cpsaa-svc-leaders3-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 7).unwrap();
        let svc = Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig {
                layers: 1,
                leaders: 3,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let mut handles = Vec::new();
        for id in 0..6u64 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = SeededRng::new(id);
                let x = rng.normal_matrix(16, 32, 1.0);
                svc.infer(id, x).unwrap()
            }));
        }
        let resps: Vec<InferenceResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mut got: Vec<u64> = resps.iter().map(|r| r.id).collect();
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<u64>>());
        assert!(resps.iter().all(|r| r.leader < 3), "leader index out of range");
        let m = svc.metrics();
        assert_eq!(m.requests, 6);
        // Every batch was attributed to exactly one leader...
        let leader_batches: u64 = m.leaders.iter().map(|l| l.batches).sum();
        assert_eq!(leader_batches, m.batches);
        let leader_requests: u64 = m.leaders.iter().map(|l| l.requests).sum();
        assert_eq!(leader_requests, m.requests);
        // ...and head lines never reused a batch id across leaders.
        let mut batch_ids: Vec<u64> = m.head_lines.iter().map(|l| l.batch).collect();
        batch_ids.sort_unstable();
        batch_ids.dedup();
        assert_eq!(batch_ids.len() as u64, m.batches, "batch ids must be unique");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn i8_precision_serves_finite_responses() {
        let dir = std::env::temp_dir().join(format!("cpsaa-svc-i8-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 9).unwrap();
        let svc = Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig { layers: 1, precision: Precision::I8, ..Default::default() },
        )
        .unwrap();
        let x = SeededRng::new(6).normal_matrix(16, 32, 1.0);
        let resp = svc.infer(7, x).unwrap();
        assert_eq!(resp.precision, Precision::I8);
        assert_eq!(resp.hidden.shape(), (16, 32));
        assert!(resp.hidden.all_finite());
        assert!(resp.sim_ns > 0.0 && resp.sim_pj > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn synth_service(tag: &str, seed: u64, cfg: ServiceConfig) -> (PathBuf, Service) {
        let dir = std::env::temp_dir().join(format!("cpsaa-svc-{tag}-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, seed).unwrap();
        let svc = Service::start(dir.clone(), HardwareConfig::paper(), model, cfg).unwrap();
        (dir, svc)
    }

    #[test]
    fn group_submission_seals_one_window() {
        let (dir, svc) = synth_service(
            "group",
            21,
            ServiceConfig { layers: 1, max_wait: Duration::from_millis(0), ..Default::default() },
        );
        assert_eq!(svc.model().seq_len, 16);
        let mut rng = SeededRng::new(9);
        let reqs: Vec<(u64, Matrix)> =
            (0..2).map(|id| (id, rng.normal_matrix(8, 32, 1.0))).collect();
        let rxs = svc.submit_group(reqs).unwrap();
        let resps: Vec<InferenceResponse> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        assert_eq!(resps[0].id, 0);
        assert_eq!(resps[1].id, 1);
        // Both members were co-batched despite a zero batching window —
        // the group arrived atomically.
        let m = svc.metrics();
        assert_eq!(m.requests, 2);
        assert_eq!(m.batches, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_survive_a_poisoned_lock() {
        let (dir, svc) = synth_service("poison", 23, ServiceConfig { layers: 1, ..Default::default() });
        // A thread dying while holding the metrics lock poisons it...
        let m = svc.metrics.clone();
        let died = std::thread::spawn(move || {
            let _guard = m.lock().unwrap();
            panic!("die holding the metrics lock");
        })
        .join();
        assert!(died.is_err());
        // ...but serving continues: the leader records through the
        // poisoned lock and the front end still reads it.
        let x = SeededRng::new(4).normal_matrix(8, 32, 1.0);
        let resp = svc.infer(5, x).unwrap();
        assert_eq!(resp.id, 5);
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_request_rejected() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let svc = Service::start(
            dir,
            HardwareConfig::paper(),
            ModelConfig::paper(),
            ServiceConfig { layers: 1, ..Default::default() },
        )
        .unwrap();
        // wrong d_model
        let bad = Matrix::zeros(8, 7);
        assert!(svc.infer(1, bad).is_err());
    }
}
