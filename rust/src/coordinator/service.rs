//! Serving loop: requests in, batched multi-head encoder executions out.
//!
//! The engine is single-threaded by design (interior `RefCell` stats;
//! with a PJRT backend the client is `Rc`-based too) — exactly like the
//! physical CPSAA chip is one device. The service spawns `leaders`
//! **leader threads**, each owning its own engine instance; callers
//! submit requests into one shared **bounded admission queue**
//! ([`AdmissionQueue`]) and block on a reply channel.
//!
//! ## Continuous batching, admission control, priority
//!
//! Batching is *continuous*: admission appends to the queue under its
//! own lock, which no leader holds while executing, so new requests
//! keep flowing in — and are picked up by the next window — while every
//! leader is busy on a batch. One leader at a time holds the window
//! token to form a window (arrival order decides composition exactly as
//! before); it drains whatever arrived within `max_wait` (or until a
//! batch fills), releases the token, packs with [`Batcher`], executes
//! the encoder stack once per batch — one
//! [`PlanSet`][crate::sparse::PlanSet] per batch (one ReCAM scan per
//! head mask), reused across all layers — and fans results back out.
//! While one leader executes, the next leader is already forming the
//! next window from requests that arrived mid-execution.
//!
//! The queue is bounded (`ServiceConfig::queue_cap`): live submissions
//! beyond the bound are shed immediately with
//! [`ServeError::Shed`]`(`[`ShedReason::QueueFull`]`)` instead of
//! growing memory without limit under overload. Requests may carry a
//! deadline ([`SubmitOptions::deadline`]); a request whose deadline
//! expires before a leader packs it into a window is shed with
//! [`ShedReason::DeadlineExpired`]. Both outcomes are **distinct typed
//! statuses** on the reply channel, not generic errors, and both count
//! in [`ServeMetrics`] (`shed_queue_full` / `shed_deadline`) next to
//! the p50/p95/p99 latency histogram (submit→reply, queue wait
//! included). Requests may also mark themselves interactive
//! ([`SubmitOptions::lane`]): a window containing any high-lane request
//! executes on the executor's high-priority lane, so small interactive
//! batches are not starved behind bulk fan-outs.
//!
//! All leaders dispatch kernels onto the **one** crate-wide
//! [`executor`][crate::runtime::executor] pool (sized by
//! `max_kernel_workers`), and all draw batch ids from one shared
//! [`BatchIds`] source, so ids stay unique and every interleaved metric
//! line remains attributable. Per-leader metrics lines make leader
//! imbalance visible. `leaders == 1` is the historical single-leader
//! loop.
//!
//! `model.heads > 1` fans each layer across concurrent per-head
//! workers inside the stack (§4.5 tile slices); responses and metrics
//! carry the per-head latency/energy/density lines.
//!
//! `shards > 1` additionally fans each packed batch across K logical
//! chips: rows are partitioned by per-row nnz from the batch's plan set,
//! each shard runs its slice (own sliced `PlanSet`, own simulated chip)
//! concurrently, and costs merge as max-ns across chips / sum-pJ.
//! Responses and metrics gain per-shard lines. `shards == 1` is
//! bit-identical to unsharded serving.
//!
//! ## Stage-overlapped serving: plan prefetch + plan cache
//!
//! With `ServiceConfig::prefetch` on (the default), each leader runs a
//! two-stage software pipeline over its batches (CPSAA §3 overlapped
//! mode): as soon as a window is sealed, a detached `Lane::Normal`
//! executor job generates the batch's head masks and builds its layer-0
//! [`PlanSet`][crate::sparse::PlanSet] while the *previous* batch's
//! encoder stack is still executing — batch N+1's ReCAM scan hides
//! behind batch N's compute. A bounded content-addressed LRU
//! ([`PlanCache`], shared across leaders) short-circuits the build
//! entirely for repeated payloads: a hit returns the shared
//! `Arc<PlanSet>` and the batch skips mask generation and the scan.
//! Plans are a pure function of (payload bits, frozen weights, model
//! config), so prefetched, cached, and inline-built plans are bitwise
//! equal and every response stays bit-identical with prefetch on or
//! off — the overlap surfaces only in the `plan_cache_hits` /
//! `plan_cache_misses` / `prefetch_overlapped_ns` metrics. Window
//! composition is preserved: the pipeline seals the next window early
//! only when doing so cannot change what the blocking path would have
//! packed (a full window's rows already queued, a group boundary, or a
//! closed queue).

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::util::error::{Context, Result};

use crate::attention::{MultiHeadWeights, Precision};
use crate::config::{HardwareConfig, ModelConfig};
use crate::runtime::executor::{self, JoinHandle, Lane};
use crate::runtime::{ArtifactSet, Engine};
use crate::sim::ChipSim;
use crate::sparse::{PlanCache, PlanKey, PlanSet, PruneConfig};
use crate::tensor::Matrix;
use crate::workload::capture::{
    BatchTraceRecord, CaptureRecorder, RecordedBatch, RecordedRequest, RecordedResponse, SimTracer,
};

use super::batcher::{BatchIds, Batcher};
use super::metrics::ServeMetrics;
use super::pipeline::EncoderStack;

/// Why a request was shed without executing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The bounded admission queue was at capacity when the request
    /// arrived (backpressure under overload).
    QueueFull,
    /// The request's deadline expired before a leader packed it into a
    /// batching window.
    DeadlineExpired,
}

impl ShedReason {
    pub fn as_str(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue full",
            ShedReason::DeadlineExpired => "deadline expired",
        }
    }
}

/// Typed per-request serving failure, delivered over the reply channel.
/// Shedding is a *distinct status* from malformed input or execution
/// failure so callers (and the load generator) can tell backpressure —
/// retry later — from requests that must not be retried as-is.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Load-shed without executing (queue full or deadline expired).
    Shed(ShedReason),
    /// Malformed request (bad shape); retrying the same payload can
    /// never succeed.
    Rejected(String),
    /// The batch execution itself failed.
    Failed(String),
}

impl ServeError {
    /// The shed reason, when this is backpressure rather than failure.
    pub fn shed_reason(&self) -> Option<ShedReason> {
        match self {
            ServeError::Shed(r) => Some(*r),
            _ => None,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Shed(r) => write!(f, "shed: {}", r.as_str()),
            ServeError::Rejected(m) => write!(f, "rejected: {m}"),
            ServeError::Failed(m) => write!(f, "batch failed: {m}"),
        }
    }
}

// `?` and `.context(...)` lift a `ServeError` into the crate-wide
// string error through the blanket std-error conversion.
impl std::error::Error for ServeError {}

/// What a reply channel yields: the response, or a typed serving error.
pub type ServeResult = std::result::Result<InferenceResponse, ServeError>;

/// Per-request submission options (see [`Service::submit_with`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    /// Shed the request ([`ShedReason::DeadlineExpired`]) if no leader
    /// has packed it into a window within this budget of submission.
    /// `None` waits indefinitely (bounded in practice by the queue cap).
    pub deadline: Option<Duration>,
    /// Executor lane the request's batch executes on; `Lane::High`
    /// marks interactive traffic that must not starve behind bulk work.
    pub lane: Lane,
}

/// One inference request: token embeddings (rows ≤ seq_len).
struct InferenceRequest {
    id: u64,
    x: Matrix,
    /// When `submit` accepted the request — the latency histogram
    /// measures submit→reply, queue wait included.
    submitted: Instant,
    /// Pack-by deadline; checked when a leader pulls the request while
    /// forming a window.
    deadline: Option<Instant>,
    lane: Lane,
    reply: mpsc::Sender<ServeResult>,
}

/// What sits in the admission queue: a single live request (co-batched
/// by time window), or a pre-composed group whose members enter **one**
/// batching window atomically, in order — the deterministic ingest path
/// replay uses to reproduce a recorded batch composition independent of
/// wall-clock timing. Groups are never shed and never merge with live
/// traffic: their composition is a recorded fact, not a load decision.
enum Admitted {
    One(InferenceRequest),
    Group(Vec<InferenceRequest>),
}

struct AdmState {
    items: VecDeque<Admitted>,
    /// Queued individual requests (group members counted) — the value
    /// the admission bound compares against.
    depth: usize,
    /// Set when the last front-end handle drops; leaders drain the
    /// backlog and exit.
    closed: bool,
}

/// The bounded buffer between the front end and the leaders. Submission
/// holds only `state`, never the window token, and no leader holds
/// `state` while executing — which is exactly what makes batching
/// continuous. Lock order where both are held: `window` → `state`
/// (leaders); `state` → metrics (leaders, shedding); never the reverse.
struct AdmissionQueue {
    state: Mutex<AdmState>,
    /// Signals arrivals and closure to a leader forming a window.
    arrived: Condvar,
    /// Held by the one leader currently forming a window, so window
    /// composition stays serial in arrival order while admission and
    /// batch execution proceed concurrently.
    window: Mutex<()>,
    /// Depth bound: `One` submissions at or beyond it shed immediately.
    cap: usize,
}

impl AdmissionQueue {
    /// Poison-recovering state lock: the queue's invariants are plain
    /// counters, sound to read and advance even after a leader died
    /// mid-update.
    fn lock_state(&self) -> MutexGuard<'_, AdmState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn close(&self) {
        self.lock_state().closed = true;
        self.arrived.notify_all();
    }
}

/// Closes the admission queue when the last front-end [`Service`] clone
/// drops, so leader threads finish the backlog and exit instead of
/// waiting forever.
struct FrontGuard {
    queue: Arc<AdmissionQueue>,
}

impl Drop for FrontGuard {
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Optional observation hooks threaded into every leader loop.
#[derive(Clone, Default)]
pub struct ServeHooks {
    /// Capture each admitted batch (payloads + deterministic response
    /// fields, in packing order) for later replay.
    pub recorder: Option<CaptureRecorder>,
    /// Collect each batch's simulated per-stage timelines (`--trace`).
    pub tracer: Option<SimTracer>,
}

/// The response: final hidden state rows for this request.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub hidden: Matrix,
    pub latency: Duration,
    /// Mean pruning-mask density over heads for this request's batch.
    pub mask_density: f64,
    /// Simulated accelerator time attributed to this request's batch
    /// (ns): per layer the max over concurrent heads, summed over layers.
    pub sim_ns: f64,
    /// Simulated accelerator energy for the batch (pJ), summed over
    /// heads and layers.
    pub sim_pj: f64,
    /// Per-head simulated time across the stack (ns), head order;
    /// `sim_ns` is its max.
    pub head_sim_ns: Vec<f64>,
    /// Per-head simulated energy across the stack (pJ), head order;
    /// `sim_pj` is its sum.
    pub head_sim_pj: Vec<f64>,
    /// Per-head pruning-mask density, head order.
    pub head_density: Vec<f64>,
    /// Per-shard simulated time across the stack (ns), shard order;
    /// empty under unsharded serving, else `sim_ns` is its max.
    pub shard_sim_ns: Vec<f64>,
    /// Per-shard simulated energy across the stack (pJ), shard order;
    /// empty when unsharded, else `sim_pj` is its sum.
    pub shard_sim_pj: Vec<f64>,
    /// Rows each shard owned of this request's batch (nnz-balanced);
    /// empty when unsharded.
    pub shard_rows: Vec<usize>,
    /// Coordinates each layer's plans dispatched (sum over heads),
    /// layer order. Constant across layers under static serving;
    /// shrinking under cascade narrowing.
    pub layer_nnz: Vec<usize>,
    /// Query rows populated at each layer (full count at layer 0; the
    /// cascade's survivors at deeper layers), layer order.
    pub layer_rows_kept: Vec<usize>,
    /// Heads populated at each layer, layer order.
    pub layer_heads_kept: Vec<usize>,
    /// Simulated plan-narrowing time across the stack (ns); zero under
    /// static serving.
    pub narrow_ns: f64,
    /// Simulated time full ReCAM re-scans would have charged for the
    /// same plan derivations (ns); zero under static serving.
    pub rescan_ns: f64,
    /// Plan-evolution mode this request's batch was served under.
    pub prune: PruneConfig,
    /// The leader thread that batched and executed this request.
    pub leader: usize,
    /// Kernel arithmetic mode this request was served at.
    pub precision: Precision,
}

impl InferenceResponse {
    /// Heads the serving stack fanned this batch across.
    pub fn heads(&self) -> usize {
        self.head_sim_ns.len()
    }

    /// Logical chips this request's batch ran on (1 when unsharded).
    pub fn shards(&self) -> usize {
        self.shard_sim_ns.len().max(1)
    }
}

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub layers: usize,
    /// Maximum time a request may wait for co-batching.
    pub max_wait: Duration,
    /// Logical chips each packed batch fans out across (≥ 1; 1 =
    /// unsharded, bit-identical to the single-chip path).
    pub shards: usize,
    /// Leader threads batching in parallel (≥ 1; 1 = the historical
    /// single-leader loop). All leaders feed the one executor pool and
    /// share one monotonic batch-id source.
    pub leaders: usize,
    /// Width of the crate-wide kernel executor pool. `None` keeps the
    /// process default (the `CPSAA_MAX_KERNEL_WORKERS` env var, else 8,
    /// capped at machine parallelism); `Some(n)` rebuilds the global
    /// pool at `n` workers via
    /// [`executor::configure`][crate::runtime::executor::configure] at
    /// startup so big machines are not throttled at the historical cap.
    /// Worker counts never change computed values, only throughput.
    pub max_kernel_workers: Option<usize>,
    /// Kernel arithmetic mode: `F32` (default, the reference path) or
    /// `I8` (i8-storage / i32-accumulate SDDMM score dots, dequantized
    /// at the softmax boundary; V stays f32).
    pub precision: Precision,
    /// How each batch's dispatch plans evolve across encoder layers:
    /// `Static` regenerates masks per layer (today's path);
    /// `Cascade { keeps }` scans once at layer 0 and derives every
    /// deeper layer's plans by top-k narrowing the previous layer's
    /// coordinate stream, applying the per-layer keep schedule (last
    /// entry repeats once the schedule runs out). A schedule of all
    /// `1.0` short-circuits to the static path (bit-identical by
    /// construction).
    pub prune: PruneConfig,
    /// Force the bit-identical scalar twins of the `tensor::simd` row
    /// primitives for every kernel in this process (same switch as the
    /// `CPSAA_FORCE_SCALAR` env var). Diagnostics knob: values never
    /// change, only throughput.
    pub force_scalar: bool,
    /// Bound on queued-but-unpacked requests. Live submissions at or
    /// beyond it are shed with `ServeError::Shed(ShedReason::QueueFull)`
    /// instead of growing memory without limit under overload. Groups
    /// (the replay ingest path) bypass the cap. `0` is legal and sheds
    /// every live submission — a drain/drill mode.
    pub queue_cap: usize,
    /// Stage-overlapped serving (default on): prefetch each sealed
    /// batch's layer-0 plan build behind the previous batch's
    /// execution, and serve repeated payloads from the plan cache.
    /// Responses are bit-identical either way; `false` builds plans
    /// inline exactly as the historical path did.
    pub prefetch: bool,
    /// Entries in the content-addressed plan cache shared across
    /// leaders (`0` disables caching while keeping the prefetch
    /// pipeline). Ignored when `prefetch` is off.
    pub plan_cache: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            layers: 2,
            max_wait: Duration::from_millis(2),
            shards: 1,
            leaders: 1,
            max_kernel_workers: None,
            precision: Precision::F32,
            prune: PruneConfig::Static,
            force_scalar: false,
            queue_cap: 1024,
            prefetch: true,
            plan_cache: 32,
        }
    }
}

/// The serving front end. Cloneable across caller threads.
#[derive(Clone)]
pub struct Service {
    queue: Arc<AdmissionQueue>,
    /// Never read — exists so the last front-end clone's drop closes
    /// the admission queue and the leaders exit.
    _front: Arc<FrontGuard>,
    metrics: Arc<Mutex<ServeMetrics>>,
    model: ModelConfig,
}

impl Service {
    /// Spawn the leader threads: each opens the artifacts and builds its
    /// own engine *on its own thread* (the client is not `Send`). All
    /// leaders share one request channel, one batch-id source, and the
    /// one global executor pool.
    pub fn start(
        artifact_dir: std::path::PathBuf,
        hw: HardwareConfig,
        model_overlay: ModelConfig,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        Self::start_with_hooks(artifact_dir, hw, model_overlay, cfg, ServeHooks::default())
    }

    /// [`start`][Self::start] with capture/trace hooks attached to every
    /// leader.
    pub fn start_with_hooks(
        artifact_dir: std::path::PathBuf,
        hw: HardwareConfig,
        model_overlay: ModelConfig,
        cfg: ServiceConfig,
        hooks: ServeHooks,
    ) -> Result<Self> {
        if cfg.leaders == 0 {
            return Err(anyhow!("leaders must be >= 1"));
        }
        // Process-wide lane switch: only ever *set* it here (never clear
        // on false), so an env-forced scalar run stays scalar.
        if cfg.force_scalar {
            crate::tensor::simd::set_force_scalar(true);
        }
        // Size the one crate-wide pool every leader feeds, before any
        // leader starts dispatching onto it.
        match cfg.max_kernel_workers {
            Some(0) => return Err(anyhow!("max_kernel_workers must be >= 1")),
            Some(n) => crate::runtime::executor::configure(n)
                .map_err(|e| anyhow!("max_kernel_workers: {e}"))?,
            None => {}
        }
        let queue = Arc::new(AdmissionQueue {
            state: Mutex::new(AdmState { items: VecDeque::new(), depth: 0, closed: false }),
            arrived: Condvar::new(),
            window: Mutex::new(()),
            cap: cfg.queue_cap,
        });
        // Created before any early return below: dropping it on a
        // startup failure closes the queue, so leaders that did come up
        // drain and exit instead of waiting forever.
        let front = FrontGuard { queue: queue.clone() };
        // Size the per-leader lines up front so an idle leader shows as
        // an explicit zero row instead of silently missing — leader
        // imbalance is exactly what these lines exist to expose.
        let metrics = Arc::new(Mutex::new(ServeMetrics {
            leaders: vec![super::metrics::LeaderMetrics::default(); cfg.leaders],
            ..Default::default()
        }));
        let ids = BatchIds::new();
        // One content-addressed plan cache shared by every leader, so a
        // payload one leader scanned hits for all of them. Sized 0 when
        // prefetch is off: the historical inline path never consults it.
        let plan_cache = Arc::new(Mutex::new(PlanCache::new(if cfg.prefetch {
            cfg.plan_cache
        } else {
            0
        })));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<ModelConfig>>();
        for leader in 0..cfg.leaders {
            let artifact_dir = artifact_dir.clone();
            let hw = hw.clone();
            let model_overlay = model_overlay.clone();
            let cfg = cfg.clone();
            let queue = queue.clone();
            let metrics = metrics.clone();
            let ids = ids.clone();
            let plan_cache = plan_cache.clone();
            let ready_tx = ready_tx.clone();
            let hooks = hooks.clone();
            std::thread::Builder::new()
                .name(format!("cpsaa-leader-{leader}"))
                .spawn(move || {
                    leader_loop(
                        leader,
                        artifact_dir,
                        hw,
                        model_overlay,
                        cfg,
                        queue,
                        metrics,
                        ids,
                        plan_cache,
                        ready_tx,
                        hooks,
                    )
                })
                .context("spawning leader thread")?;
        }
        // Only the leaders hold ready senders now: a leader dying before
        // reporting in surfaces as a recv error instead of a hang.
        drop(ready_tx);
        // Wait for every engine to come up (or fail fast).
        let mut resolved: Option<ModelConfig> = None;
        for _ in 0..cfg.leaders {
            match ready_rx.recv() {
                Ok(Ok(model)) => {
                    resolved.get_or_insert(model);
                }
                Ok(Err(e)) => return Err(e),
                Err(_) => return Err(anyhow!("leader thread died during startup")),
            }
        }
        let model = resolved.expect("leaders >= 1, so at least one reported in");
        Ok(Self { queue, _front: Arc::new(front), metrics, model })
    }

    /// The resolved serving model — artifact shapes overlaid with the
    /// caller's heads/layers/sharpness — as every leader loaded it.
    pub fn model(&self) -> &ModelConfig {
        &self.model
    }

    /// Submit a request without blocking; the returned receiver yields
    /// the response once its batch completes. Default options: no
    /// deadline, normal lane.
    pub fn submit(&self, id: u64, x: Matrix) -> Result<mpsc::Receiver<ServeResult>> {
        self.submit_with(id, x, SubmitOptions::default())
    }

    /// [`submit`][Self::submit] with per-request deadline and lane.
    /// Returns `Err` only if the service has stopped; backpressure is
    /// delivered *through the receiver* as [`ServeError::Shed`] — a
    /// queue-full shed is already waiting in the channel on return — so
    /// callers always distinguish shed from failed.
    pub fn submit_with(
        &self,
        id: u64,
        x: Matrix,
        opts: SubmitOptions,
    ) -> Result<mpsc::Receiver<ServeResult>> {
        let (reply, rx) = mpsc::channel();
        let submitted = Instant::now();
        let req = InferenceRequest {
            id,
            x,
            submitted,
            // An unrepresentable deadline (astronomical budget) means
            // no deadline.
            deadline: opts.deadline.and_then(|d| submitted.checked_add(d)),
            lane: opts.lane,
            reply,
        };
        let mut state = self.queue.lock_state();
        if state.closed {
            return Err(anyhow!("service stopped"));
        }
        if state.depth >= self.queue.cap {
            drop(state);
            let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
            m.shed_queue_full += 1;
            drop(m);
            let _ = req.reply.send(Err(ServeError::Shed(ShedReason::QueueFull)));
            return Ok(rx);
        }
        state.items.push_back(Admitted::One(req));
        state.depth += 1;
        drop(state);
        self.queue.arrived.notify_all();
        Ok(rx)
    }

    /// Submit a pre-composed batch group: every member enters a single
    /// batching window atomically, in order, regardless of wall-clock
    /// timing or leader scheduling. This is how replay reproduces a
    /// recorded batch composition — and with it the exact FP summation
    /// order — deterministically. Groups bypass the admission bound and
    /// carry no deadline: a recorded composition must never be shed.
    pub fn submit_group(
        &self,
        reqs: Vec<(u64, Matrix)>,
    ) -> Result<Vec<mpsc::Receiver<ServeResult>>> {
        let submitted = Instant::now();
        let mut rxs = Vec::with_capacity(reqs.len());
        let mut group = Vec::with_capacity(reqs.len());
        for (id, x) in reqs {
            let (reply, rx) = mpsc::channel();
            group.push(InferenceRequest {
                id,
                x,
                submitted,
                deadline: None,
                lane: Lane::Normal,
                reply,
            });
            rxs.push(rx);
        }
        let n = group.len();
        let mut state = self.queue.lock_state();
        if state.closed {
            return Err(anyhow!("service stopped"));
        }
        state.items.push_back(Admitted::Group(group));
        state.depth += n;
        drop(state);
        self.queue.arrived.notify_all();
        Ok(rxs)
    }

    /// Submit a request and block until its response arrives.
    pub fn infer(&self, id: u64, x: Matrix) -> Result<InferenceResponse> {
        let rx = self.submit(id, x)?;
        let resp = rx.recv().map_err(|_| anyhow!("request {id} dropped"))?;
        Ok(resp?)
    }

    pub fn metrics(&self) -> ServeMetrics {
        // A leader that panicked while holding the metrics lock poisons
        // it; the counters it was updating are monotonic aggregates, so
        // reading them is still sound — don't let one dead leader take
        // observability down with it.
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

/// How one pending batch will get its layer-0 plans.
enum PlanTicket {
    /// Served from the content-addressed cache — the scan never runs.
    Cached(Arc<PlanSet>),
    /// Being built by a detached `Lane::Normal` executor job while
    /// earlier batches execute; inserted under its key on join.
    Built(JoinHandle<Arc<PlanSet>>, PlanKey),
}

/// A sealed window waiting its turn in the leader's two-stage pipeline:
/// packed batches (each with its plan ticket already in flight) plus
/// the reply routes for its members.
struct PendingWindow {
    lane: Lane,
    batches: Vec<(super::batcher::BatchPlan, Option<PlanTicket>)>,
    replies: HashMap<u64, (mpsc::Sender<ServeResult>, Instant)>,
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    leader: usize,
    artifact_dir: std::path::PathBuf,
    hw: HardwareConfig,
    model_overlay: ModelConfig,
    cfg: ServiceConfig,
    queue: Arc<AdmissionQueue>,
    metrics: Arc<Mutex<ServeMetrics>>,
    ids: BatchIds,
    plan_cache: Arc<Mutex<PlanCache>>,
    ready: mpsc::Sender<Result<ModelConfig>>,
    hooks: ServeHooks,
) {
    // Build everything that must live on this thread.
    let setup = (|| -> Result<(Engine, MultiHeadWeights, ModelConfig)> {
        let set = ArtifactSet::open(&artifact_dir)?;
        let c = &set.manifest.config;
        // Shapes come from the artifacts; heads/layers/sharpness from the
        // caller's overlay (the manifest predates multi-head serving).
        let model = ModelConfig {
            seq_len: c.seq_len,
            d_model: c.d_model,
            d_k: c.d_k,
            d_ff: c.d_ff,
            gamma: c.gamma,
            quant_bits: c.quant_bits,
            theta: c.theta,
            ..model_overlay
        };
        model.validate().map_err(|e| anyhow!("invalid serving model config: {e}"))?;
        if cfg.layers == 0 {
            return Err(anyhow!("layers must be >= 1"));
        }
        if cfg.shards == 0 {
            return Err(anyhow!("shards must be >= 1"));
        }
        cfg.prune.validate().map_err(|e| anyhow!("prune: {e}"))?;
        let weights = MultiHeadWeights::load(&set.dir.join("weights.json"), model.heads)?;
        weights.validate().map_err(|e| anyhow!("bad weights for {} heads: {e}", model.heads))?;
        let engine = Engine::load(&set)?;
        Ok((engine, weights, model))
    })();
    let (engine, weights, model) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(v.2.clone()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // Everything the detached prefetch job needs: it cannot borrow the
    // engine (interior `RefCell` stats make it `!Sync`), so it captures
    // the pool, the weights, and the model and runs the same static
    // build the engine would ([`Engine::build_plans_in`]).
    let exec_pool = executor::global();
    let prefetch_weights = Arc::new(weights.clone());
    // Costs the pruning-stage scan the pipeline hides (or the cache
    // skips) — feeds only the `prefetch_overlapped_ns` counter.
    let chip = ChipSim::new(hw.clone(), model.clone());
    let stack = EncoderStack::new(&engine, weights, hw, model.clone(), cfg.layers)
        .with_shards(cfg.shards)
        .with_precision(cfg.precision)
        .with_prune(cfg.prune.clone());
    // One batcher per leader, all drawing from the service's shared
    // monotonic id source: every per-head/per-shard metric line stays
    // keyed to exactly one batch even with several leaders in flight.
    let mut batcher = Batcher::with_ids(model.seq_len, model.d_model, ids);

    // Shed one expired request: typed status on the reply channel plus
    // the metrics counter. mpsc sends never block, so doing this under
    // the admission state lock is safe (and keeps the state→metrics
    // lock order documented on `AdmissionQueue`).
    let shed_expired = |req: InferenceRequest| {
        let mut m = metrics.lock().unwrap_or_else(|e| e.into_inner());
        m.shed_deadline += 1;
        drop(m);
        let _ = req.reply.send(Err(ServeError::Shed(ShedReason::DeadlineExpired)));
    };

    // Form one batching window by claiming the window token; competing
    // leaders block (or, non-blocking, skip) while one forms a window,
    // then take over the moment it moves on to execution. Admission
    // never takes this lock — requests keep arriving while every leader
    // executes, and the next window picks them up (continuous
    // batching). `block = true` is the historical path: wait for a
    // first member, co-batch within `max_wait`; `None` means the queue
    // closed and drained. `block = false` never waits and seals only
    // when composition is already decided — a group boundary, a full
    // window of queued rows, or a closed queue — so the prefetch
    // pipeline cannot change what the blocking path would have packed.
    let form = |block: bool| -> Option<Vec<InferenceRequest>> {
        // A leader that panicked while holding the token poisons it,
        // but the queue it guards stays sound — surviving leaders keep
        // claiming windows instead of shutting the whole service down.
        let _forming = if block {
            queue.window.lock().unwrap_or_else(|e| e.into_inner())
        } else {
            match queue.window.try_lock() {
                Ok(g) => g,
                Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
                Err(std::sync::TryLockError::WouldBlock) => return None,
            }
        };
        let mut state = queue.lock_state();
        if !block {
            let mut rows = 0usize;
            let mut sealable = state.closed && !state.items.is_empty();
            for item in state.items.iter() {
                match item {
                    Admitted::Group(_) => {
                        sealable = true;
                        break;
                    }
                    Admitted::One(r) => {
                        rows += r.x.rows();
                        if rows >= model.seq_len {
                            sealable = true;
                            break;
                        }
                    }
                }
            }
            if !sealable {
                return None;
            }
        }
        // Wait for the first window member, shedding any expired
        // request that surfaces; exit once closed and drained.
        let first = loop {
            match state.items.pop_front() {
                // A pre-composed group seals its window immediately:
                // its composition was decided by the sender (replay),
                // not by arrival timing.
                Some(Admitted::Group(group)) => {
                    state.depth -= group.len();
                    return Some(group);
                }
                Some(Admitted::One(req)) => {
                    state.depth -= 1;
                    if req.deadline.is_some_and(|d| Instant::now() >= d) {
                        shed_expired(req);
                        continue;
                    }
                    break req;
                }
                None => {
                    if !block || state.closed {
                        return None;
                    }
                    state = queue.arrived.wait(state).unwrap_or_else(|e| e.into_inner());
                }
            }
        };
        let mut window = vec![first];
        let mut rows = window[0].x.rows();
        let seal_at = Instant::now() + cfg.max_wait;
        while rows < model.seq_len {
            match state.items.front() {
                // Live requests join the open window (expired ones shed
                // at the moment of packing).
                Some(Admitted::One(_)) => {
                    let Some(Admitted::One(req)) = state.items.pop_front() else {
                        unreachable!("front() said One");
                    };
                    state.depth -= 1;
                    if req.deadline.is_some_and(|d| Instant::now() >= d) {
                        shed_expired(req);
                        continue;
                    }
                    rows += req.x.rows();
                    window.push(req);
                }
                // A group never merges with live traffic: seal this
                // window; the group forms the next.
                Some(Admitted::Group(_)) => break,
                None => {
                    if !block || state.closed {
                        break;
                    }
                    let remaining = seal_at.saturating_duration_since(Instant::now());
                    if remaining.is_zero() {
                        break;
                    }
                    let (guard, _timeout) = queue
                        .arrived
                        .wait_timeout(state, remaining)
                        .unwrap_or_else(|e| e.into_inner());
                    state = guard;
                }
            }
        }
        Some(window)
    };

    // Seal a window into the pipeline: pack its batches and start each
    // batch's plan ticket — a cache probe, else a detached build job
    // whose mask generation + ReCAM scan run while earlier batches
    // execute.
    let mut prepare = |window: Vec<InferenceRequest>| -> PendingWindow {
        // One interactive member lifts the whole window onto the
        // executor's high lane: its co-batched neighbors ride along.
        let lane = if window.iter().any(|r| r.lane == Lane::High) {
            Lane::High
        } else {
            Lane::Normal
        };
        let mut replies = HashMap::new();
        for req in window {
            match batcher.push(req.id, req.x) {
                Ok(()) => {
                    replies.insert(req.id, (req.reply, req.submitted));
                }
                Err(e) => {
                    let _ = req.reply.send(Err(ServeError::Rejected(e.to_string())));
                }
            }
        }
        let batches = batcher
            .drain()
            .into_iter()
            .map(|plan| {
                let ticket = cfg.prefetch.then(|| {
                    let key = PlanKey::for_batch(&plan.x, model.heads.max(1), &cfg.prune);
                    let cached = plan_cache.lock().unwrap_or_else(|e| e.into_inner()).get(&key);
                    match cached {
                        Some(plans) => PlanTicket::Cached(plans),
                        None => {
                            let exec = exec_pool.clone();
                            let w = prefetch_weights.clone();
                            let mcfg = model.clone();
                            let x = plan.x.clone();
                            let handle = executor::with_lane(Lane::Normal, || {
                                exec_pool
                                    .spawn(move || Engine::build_plans_in(&exec, &x, &w, &mcfg))
                            });
                            PlanTicket::Built(handle, key)
                        }
                    }
                });
                (plan, ticket)
            })
            .collect();
        PendingWindow { lane, batches, replies }
    };

    // Stage-2 state: windows sealed early (their plan builds already in
    // flight) wait here for their turn to execute.
    let mut pending: VecDeque<PendingWindow> = VecDeque::new();
    // Simulated compute of the previously executed batch — what the
    // next batch's prefetched scan hides behind.
    let mut prev_sim_ns = 0.0f64;

    loop {
        let PendingWindow { lane: window_lane, batches, mut replies } =
            match pending.pop_front() {
                Some(w) => w,
                None => match form(true) {
                    Some(w) => prepare(w),
                    None => return,
                },
            };

        for (plan, ticket) in batches {
            // Overlap point: while this batch is about to execute, seal
            // the next window (if its composition is already decided)
            // so its plan scan runs behind this batch's compute.
            if cfg.prefetch && pending.is_empty() {
                if let Some(w) = form(false) {
                    pending.push_back(prepare(w));
                }
            }
            // Resolve this batch's plans: a cache hit skipped the scan
            // entirely; a prefetched build overlapped it with the
            // previous batch's compute; `None` builds inline (prefetch
            // off) exactly as the historical path did.
            let prebuilt = match ticket {
                None => None,
                Some(PlanTicket::Cached(plans)) => {
                    let scan_ns = chip.scan_overlap_cost(&plans, 0.0).scan_ns;
                    let mut m = metrics.lock().unwrap_or_else(|e| e.into_inner());
                    m.record_plan_source(true, scan_ns);
                    drop(m);
                    Some(plans)
                }
                Some(PlanTicket::Built(handle, key)) => {
                    let plans = handle.join();
                    plan_cache
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .insert(key, plans.clone());
                    let oc = chip.scan_overlap_cost(&plans, prev_sim_ns);
                    let mut m = metrics.lock().unwrap_or_else(|e| e.into_inner());
                    m.record_plan_source(false, oc.hidden_ns);
                    drop(m);
                    Some(plans)
                }
            };
            // The lane is scoped around the whole execution: every
            // nested fan-out the stack submits (shards → heads → row
            // ranges) inherits it. Lanes reorder scheduling only, so
            // outputs stay bit-identical either way.
            let executed = executor::with_lane(window_lane, || {
                stack.forward_traced_prefetched(&plan.x, prebuilt)
            });
            match executed {
                Ok((outs, traces)) => {
                    if let Some(tracer) = &hooks.tracer {
                        tracer.record(BatchTraceRecord { batch: plan.batch, leader, traces });
                    }
                    let last = outs.last().expect("≥1 layer");
                    let sim_ns: f64 = outs.iter().map(|o| o.sim_ns).sum();
                    let sim_pj: f64 = outs.iter().map(|o| o.sim_pj).sum();
                    prev_sim_ns = sim_ns;
                    let density =
                        outs.iter().map(|o| o.mask_density).sum::<f64>() / outs.len() as f64;
                    // Per-head and per-shard lines across the whole
                    // stack, summed per layer exactly like sim_ns so
                    // sim_ns == max(head_ns) == max(shard_ns) holds to
                    // the bit (sim_pj == Σ lines up to summation-order
                    // rounding).
                    let heads_n = outs[0].head_sim_ns.len();
                    let mut head_ns = vec![0.0f64; heads_n];
                    let mut head_pj = vec![0.0f64; heads_n];
                    let shards_n = outs[0].shard_sim_ns.len();
                    let mut shard_ns = vec![0.0f64; shards_n];
                    let mut shard_pj = vec![0.0f64; shards_n];
                    for o in &outs {
                        for (acc, v) in head_ns.iter_mut().zip(&o.head_sim_ns) {
                            *acc += v;
                        }
                        for (acc, v) in head_pj.iter_mut().zip(&o.head_sim_pj) {
                            *acc += v;
                        }
                        for (acc, v) in shard_ns.iter_mut().zip(&o.shard_sim_ns) {
                            *acc += v;
                        }
                        for (acc, v) in shard_pj.iter_mut().zip(&o.shard_sim_pj) {
                            *acc += v;
                        }
                    }
                    let head_density = outs[0].head_density.clone();
                    // Shard row/nnz ownership comes from the first
                    // layer's partition (the batch's plan set).
                    let shard_rows = outs[0].shard_rows.clone();
                    let shard_nnz = outs[0].shard_nnz.clone();
                    // Per-layer plan evolution: constant under static
                    // serving, shrinking under cascade narrowing.
                    let layer_nnz: Vec<usize> = outs.iter().map(|o| o.plan_nnz).collect();
                    let layer_rows_kept: Vec<usize> =
                        outs.iter().map(|o| o.rows_kept).collect();
                    let layer_heads_kept: Vec<usize> =
                        outs.iter().map(|o| o.heads_kept).collect();
                    let layer_narrow_ns: Vec<f64> = outs.iter().map(|o| o.narrow_ns).collect();
                    let layer_rescan_ns: Vec<f64> = outs.iter().map(|o| o.rescan_ns).collect();
                    let narrow_ns: f64 = layer_narrow_ns.iter().sum();
                    let rescan_ns: f64 = layer_rescan_ns.iter().sum();
                    // Poison recovery mirrors `Service::metrics`: the
                    // aggregates stay sound, so a dead leader must not
                    // kill the survivors' recording path.
                    let mut m = metrics.lock().unwrap_or_else(|e| e.into_inner());
                    m.batches += 1;
                    if window_lane == Lane::High {
                        m.high_lane_batches += 1;
                    }
                    m.used_rows += plan.used_rows as u64;
                    m.padded_rows += (model.seq_len - plan.used_rows) as u64;
                    m.sim_ns += sim_ns;
                    m.sim_pj += sim_pj;
                    m.record_heads(plan.batch, &head_ns, &head_pj, &head_density);
                    if !shard_ns.is_empty() {
                        m.record_shards(plan.batch, &shard_rows, &shard_nnz, &shard_ns, &shard_pj);
                    }
                    m.record_plans(
                        plan.batch,
                        &layer_nnz,
                        &layer_rows_kept,
                        &layer_heads_kept,
                        &layer_narrow_ns,
                        &layer_rescan_ns,
                    );
                    m.record_leader(leader, plan.entries.len() as u64, sim_ns);
                    let mut captured: Vec<RecordedRequest> = Vec::new();
                    for entry in &plan.entries {
                        let hidden = plan.extract(&last.hidden, entry);
                        m.requests += 1;
                        if hooks.recorder.is_some() {
                            captured.push(RecordedRequest {
                                id: entry.id,
                                // The request's payload rows, sliced
                                // back out of the packed batch bitwise.
                                x: plan.extract(&plan.x, entry),
                                response: RecordedResponse {
                                    hidden: hidden.clone(),
                                    mask_density: density,
                                    sim_ns,
                                    sim_pj,
                                    head_sim_ns: head_ns.clone(),
                                    head_sim_pj: head_pj.clone(),
                                    head_density: head_density.clone(),
                                    shard_sim_ns: shard_ns.clone(),
                                    shard_sim_pj: shard_pj.clone(),
                                    shard_rows: shard_rows.clone(),
                                    layer_nnz: layer_nnz.clone(),
                                    layer_rows_kept: layer_rows_kept.clone(),
                                    layer_heads_kept: layer_heads_kept.clone(),
                                    narrow_ns,
                                    rescan_ns,
                                },
                            });
                        }
                        if let Some((reply, submitted)) = replies.remove(&entry.id) {
                            // Submit→reply: queue wait, window wait and
                            // execution all count against the SLO.
                            let latency = submitted.elapsed();
                            m.record_latency(window_lane, latency);
                            let _ = reply.send(Ok(InferenceResponse {
                                id: entry.id,
                                hidden,
                                latency,
                                mask_density: density,
                                sim_ns,
                                sim_pj,
                                head_sim_ns: head_ns.clone(),
                                head_sim_pj: head_pj.clone(),
                                head_density: head_density.clone(),
                                shard_sim_ns: shard_ns.clone(),
                                shard_sim_pj: shard_pj.clone(),
                                shard_rows: shard_rows.clone(),
                                layer_nnz: layer_nnz.clone(),
                                layer_rows_kept: layer_rows_kept.clone(),
                                layer_heads_kept: layer_heads_kept.clone(),
                                narrow_ns,
                                rescan_ns,
                                prune: cfg.prune.clone(),
                                leader,
                                precision: cfg.precision,
                            }));
                        }
                    }
                    drop(m);
                    if let Some(recorder) = &hooks.recorder {
                        if !captured.is_empty() {
                            recorder.record(RecordedBatch { batch: plan.batch, requests: captured });
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for entry in &plan.entries {
                        if let Some((reply, _submitted)) = replies.remove(&entry.id) {
                            let _ = reply.send(Err(ServeError::Failed(msg.clone())));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;
    use std::path::PathBuf;

    #[test]
    fn serve_roundtrip() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let svc = Service::start(
            dir,
            HardwareConfig::paper(),
            ModelConfig::paper(),
            ServiceConfig { layers: 1, ..Default::default() },
        )
        .unwrap();
        let mut rng = SeededRng::new(3);
        // d_model comes from the manifest; read it indirectly by probing a
        // valid request shape (the artifact default is 256).
        let x = rng.normal_matrix(24, 256, 1.0);
        let resp = svc.infer(42, x).unwrap();
        assert_eq!(resp.id, 42);
        assert_eq!(resp.hidden.shape(), (24, 256));
        assert!(resp.hidden.all_finite());
        assert!(resp.sim_ns > 0.0);
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
    }

    #[test]
    fn concurrent_callers_batch_together() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let svc = Service::start(
            dir,
            HardwareConfig::paper(),
            ModelConfig::paper(),
            ServiceConfig { layers: 1, max_wait: Duration::from_millis(50), ..Default::default() },
        )
        .unwrap();
        let mut handles = Vec::new();
        for id in 0..4u64 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = SeededRng::new(id);
                let x = rng.normal_matrix(16, 256, 1.0);
                svc.infer(id, x).unwrap()
            }));
        }
        let mut ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap().id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        let m = svc.metrics();
        assert_eq!(m.requests, 4);
        // 4 × 16 = 64 rows fit in one 128-row batch if they co-arrived;
        // allow up to 4 batches under scheduling jitter.
        assert!(m.batches <= 4);
    }

    #[test]
    fn zero_shards_rejected_at_startup() {
        let dir = std::env::temp_dir()
            .join(format!("cpsaa-svc-shards0-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 2).unwrap();
        let err = match Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig { shards: 0, ..Default::default() },
        ) {
            Ok(_) => panic!("shards = 0 must be rejected at startup"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("shards"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_leaders_rejected_at_startup() {
        let dir = std::env::temp_dir()
            .join(format!("cpsaa-svc-leaders0-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 2).unwrap();
        let err = match Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig { leaders: 0, ..Default::default() },
        ) {
            Ok(_) => panic!("leaders = 0 must be rejected at startup"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("leaders"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn multi_leader_serves_all_requests_with_unique_batch_ids() {
        let dir = std::env::temp_dir()
            .join(format!("cpsaa-svc-leaders3-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 7).unwrap();
        let svc = Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig {
                layers: 1,
                leaders: 3,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let mut handles = Vec::new();
        for id in 0..6u64 {
            let svc = svc.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = SeededRng::new(id);
                let x = rng.normal_matrix(16, 32, 1.0);
                svc.infer(id, x).unwrap()
            }));
        }
        let resps: Vec<InferenceResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mut got: Vec<u64> = resps.iter().map(|r| r.id).collect();
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<u64>>());
        assert!(resps.iter().all(|r| r.leader < 3), "leader index out of range");
        let m = svc.metrics();
        assert_eq!(m.requests, 6);
        // Every batch was attributed to exactly one leader...
        let leader_batches: u64 = m.leaders.iter().map(|l| l.batches).sum();
        assert_eq!(leader_batches, m.batches);
        let leader_requests: u64 = m.leaders.iter().map(|l| l.requests).sum();
        assert_eq!(leader_requests, m.requests);
        // ...and head lines never reused a batch id across leaders.
        let mut batch_ids: Vec<u64> = m.head_lines.iter().map(|l| l.batch).collect();
        batch_ids.sort_unstable();
        batch_ids.dedup();
        assert_eq!(batch_ids.len() as u64, m.batches, "batch ids must be unique");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn i8_precision_serves_finite_responses() {
        let dir = std::env::temp_dir().join(format!("cpsaa-svc-i8-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 9).unwrap();
        let svc = Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig { layers: 1, precision: Precision::I8, ..Default::default() },
        )
        .unwrap();
        let x = SeededRng::new(6).normal_matrix(16, 32, 1.0);
        let resp = svc.infer(7, x).unwrap();
        assert_eq!(resp.precision, Precision::I8);
        assert_eq!(resp.hidden.shape(), (16, 32));
        assert!(resp.hidden.all_finite());
        assert!(resp.sim_ns > 0.0 && resp.sim_pj > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn synth_service(tag: &str, seed: u64, cfg: ServiceConfig) -> (PathBuf, Service) {
        let dir = std::env::temp_dir().join(format!("cpsaa-svc-{tag}-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, seed).unwrap();
        let svc = Service::start(dir.clone(), HardwareConfig::paper(), model, cfg).unwrap();
        (dir, svc)
    }

    #[test]
    fn group_submission_seals_one_window() {
        let (dir, svc) = synth_service(
            "group",
            21,
            ServiceConfig { layers: 1, max_wait: Duration::from_millis(0), ..Default::default() },
        );
        assert_eq!(svc.model().seq_len, 16);
        let mut rng = SeededRng::new(9);
        let reqs: Vec<(u64, Matrix)> =
            (0..2).map(|id| (id, rng.normal_matrix(8, 32, 1.0))).collect();
        let rxs = svc.submit_group(reqs).unwrap();
        let resps: Vec<InferenceResponse> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        assert_eq!(resps[0].id, 0);
        assert_eq!(resps[1].id, 1);
        // Both members were co-batched despite a zero batching window —
        // the group arrived atomically.
        let m = svc.metrics();
        assert_eq!(m.requests, 2);
        assert_eq!(m.batches, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_survive_a_poisoned_lock() {
        let (dir, svc) = synth_service("poison", 23, ServiceConfig { layers: 1, ..Default::default() });
        // A thread dying while holding the metrics lock poisons it...
        let m = svc.metrics.clone();
        let died = std::thread::spawn(move || {
            let _guard = m.lock().unwrap();
            panic!("die holding the metrics lock");
        })
        .join();
        assert!(died.is_err());
        // ...but serving continues: the leader records through the
        // poisoned lock and the front end still reads it.
        let x = SeededRng::new(4).normal_matrix(8, 32, 1.0);
        let resp = svc.infer(5, x).unwrap();
        assert_eq!(resp.id, 5);
        let m = svc.metrics();
        assert_eq!(m.requests, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_queue_cap_sheds_live_traffic_but_groups_bypass() {
        // cap = 0 is the deterministic drill mode: every live
        // submission sheds with the typed queue-full status...
        let (dir, svc) = synth_service(
            "qcap0",
            31,
            ServiceConfig { layers: 1, queue_cap: 0, ..Default::default() },
        );
        let mut rng = SeededRng::new(2);
        let rx = svc.submit(1, rng.normal_matrix(8, 32, 1.0)).unwrap();
        let got = rx.recv().expect("shed status must be delivered");
        assert_eq!(got.unwrap_err(), ServeError::Shed(ShedReason::QueueFull));
        // ...while the replay ingest path is exempt: a recorded batch
        // composition is a fact, not a load decision.
        let reqs: Vec<(u64, Matrix)> =
            (0..2).map(|id| (id, rng.normal_matrix(8, 32, 1.0))).collect();
        let rxs = svc.submit_group(reqs).unwrap();
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let m = svc.metrics();
        assert_eq!(m.shed_queue_full, 1);
        assert_eq!(m.shed_deadline, 0);
        assert_eq!(m.shed(), 1);
        assert_eq!(m.requests, 2, "group members executed, shed request did not");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn expired_deadline_sheds_with_typed_status() {
        let (dir, svc) =
            synth_service("deadline", 33, ServiceConfig { layers: 1, ..Default::default() });
        let mut rng = SeededRng::new(5);
        // A zero budget has always expired by the time a leader packs
        // the request — deterministic shed.
        let rx = svc
            .submit_with(
                9,
                rng.normal_matrix(8, 32, 1.0),
                SubmitOptions { deadline: Some(Duration::ZERO), ..Default::default() },
            )
            .unwrap();
        let got = rx.recv().expect("shed status must be delivered");
        let err = got.unwrap_err();
        assert_eq!(err, ServeError::Shed(ShedReason::DeadlineExpired));
        assert_eq!(err.shed_reason(), Some(ShedReason::DeadlineExpired));
        assert_eq!(err.to_string(), "shed: deadline expired");
        // A generous deadline serves normally.
        let rx = svc
            .submit_with(
                10,
                rng.normal_matrix(8, 32, 1.0),
                SubmitOptions { deadline: Some(Duration::from_secs(60)), ..Default::default() },
            )
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 10);
        let m = svc.metrics();
        assert_eq!(m.shed_deadline, 1);
        assert_eq!(m.requests, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn high_lane_requests_mark_their_batches() {
        let (dir, svc) =
            synth_service("lane", 35, ServiceConfig { layers: 1, ..Default::default() });
        let mut rng = SeededRng::new(8);
        let rx = svc
            .submit_with(
                1,
                rng.normal_matrix(8, 32, 1.0),
                SubmitOptions { lane: crate::runtime::Lane::High, ..Default::default() },
            )
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 1);
        // A normal-lane request afterwards does not bump the counter.
        let resp = svc.infer(2, rng.normal_matrix(8, 32, 1.0)).unwrap();
        assert_eq!(resp.id, 2);
        let m = svc.metrics();
        assert_eq!(m.high_lane_batches, 1);
        assert_eq!(m.batches, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_serving_reports_plan_narrowing() {
        let (dir, svc) = synth_service(
            "cascade",
            37,
            ServiceConfig {
                layers: 3,
                prune: crate::sparse::PruneConfig::cascade(0.5),
                ..Default::default()
            },
        );
        let mut rng = SeededRng::new(12);
        let resp = svc.infer(7, rng.normal_matrix(8, 32, 1.0)).unwrap();
        assert_eq!(resp.prune, crate::sparse::PruneConfig::cascade(0.5));
        assert!(resp.hidden.all_finite());
        // 8 packed rows: layer 0 runs the full scan, layers 1–2 run on
        // the top-⌈0.5·8⌉ = 4 surviving tokens (cumulative, so flat
        // after the first narrowing).
        assert_eq!(resp.layer_rows_kept, vec![8, 4, 4]);
        assert_eq!(resp.layer_heads_kept, vec![1, 1, 1]);
        assert_eq!(resp.layer_nnz.len(), 3);
        assert!(resp.layer_nnz[1] <= resp.layer_nnz[0]);
        assert!(resp.narrow_ns > 0.0, "narrowing must be charged");
        assert!(resp.narrow_ns < resp.rescan_ns, "narrowing must undercut the re-scan");
        // The same stats land in the serve metrics as per-layer lines.
        let m = svc.metrics();
        assert_eq!(m.plan_lines.len(), 3);
        assert_eq!(
            m.plan_lines.iter().map(|l| l.layer).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert_eq!(m.plan_lines[1].rows_kept, 4);
        assert!(m.narrow_ns > 0.0 && m.narrow_ns < m.rescan_ns);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_keep_one_serves_bit_identical_to_static_across_topologies() {
        // The exactness contract at the service layer: cascade:1.0 does
        // not narrow, so its responses — functional output *and* plan
        // stats — match the static path to the bit, at a different
        // leader/shard topology on top.
        let mut rng = SeededRng::new(14);
        let x = rng.normal_matrix(8, 32, 1.0);
        let (dir_a, svc_a) = synth_service(
            "keep1-static",
            39,
            ServiceConfig { layers: 2, leaders: 1, shards: 1, ..Default::default() },
        );
        let a = svc_a.infer(1, x.clone()).unwrap();
        drop(svc_a);
        let (dir_b, svc_b) = synth_service(
            "keep1-cascade",
            39,
            ServiceConfig {
                layers: 2,
                leaders: 2,
                shards: 2,
                prune: crate::sparse::PruneConfig::cascade(1.0),
                ..Default::default()
            },
        );
        let b = svc_b.infer(1, x).unwrap();
        assert_eq!(a.hidden, b.hidden, "keep=1.0 must be bit-identical to static");
        assert_eq!(a.layer_nnz, b.layer_nnz);
        assert_eq!(a.layer_rows_kept, b.layer_rows_kept);
        assert_eq!(a.layer_heads_kept, b.layer_heads_kept);
        assert_eq!((b.narrow_ns, b.rescan_ns), (0.0, 0.0));
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }

    #[test]
    fn cascade_zero_keep_rejected_at_startup() {
        let dir = std::env::temp_dir().join(format!("cpsaa-svc-prune0-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 2).unwrap();
        let err = match Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig {
                prune: crate::sparse::PruneConfig::cascade(0.0),
                ..Default::default()
            },
        ) {
            Ok(_) => panic!("cascade keep = 0 must be rejected at startup"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("prune"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_schedule_bad_entry_rejected_at_startup() {
        // A per-layer keep schedule is validated entry-by-entry at
        // startup, not discovered mid-serve: `cascade:0.5,0.0` must be
        // refused before any leader accepts traffic.
        let dir = std::env::temp_dir()
            .join(format!("cpsaa-svc-sched0-{}", std::process::id()));
        let model = crate::config::ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..crate::config::ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 2).unwrap();
        let err = match Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig {
                prune: crate::sparse::PruneConfig::cascade_schedule(vec![0.5, 0.0]),
                ..Default::default()
            },
        ) {
            Ok(_) => panic!("cascade schedule with a 0.0 entry must be rejected at startup"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("prune"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefetch_off_serves_bit_identical_to_prefetch_on() {
        // The tentpole exactness contract at the service layer: plans
        // are a pure function of (payload bits, weights, config), so
        // prefetched/cached plans change only *when* the scan runs,
        // never what it produces.
        let mut rng = SeededRng::new(18);
        let x = rng.normal_matrix(8, 32, 1.0);
        let (dir_on, svc_on) = synth_service(
            "prefetch-on",
            41,
            ServiceConfig { layers: 2, prefetch: true, ..Default::default() },
        );
        let on_first = svc_on.infer(1, x.clone()).unwrap();
        let on_repeat = svc_on.infer(2, x.clone()).unwrap();
        let m_on = svc_on.metrics();
        drop(svc_on);
        let (dir_off, svc_off) = synth_service(
            "prefetch-off",
            41,
            ServiceConfig { layers: 2, prefetch: false, ..Default::default() },
        );
        let off = svc_off.infer(1, x).unwrap();
        let m_off = svc_off.metrics();
        assert_eq!(on_first.hidden, off.hidden, "prefetch must be bit-invisible");
        assert_eq!(on_repeat.hidden, off.hidden, "a cache hit must be bit-invisible");
        assert_eq!(on_first.layer_nnz, off.layer_nnz);
        assert_eq!(on_first.layer_rows_kept, off.layer_rows_kept);
        // The win is visible only in the counters: the repeated payload
        // hit the cache (skipping its whole scan), the first one's
        // build was prefetched; the off service never touched either.
        assert_eq!((m_on.plan_cache_hits, m_on.plan_cache_misses), (1, 1));
        assert!(m_on.prefetch_overlapped_ns > 0.0, "a hit banks the whole scan");
        assert_eq!((m_off.plan_cache_hits, m_off.plan_cache_misses), (0, 0));
        assert_eq!(m_off.prefetch_overlapped_ns, 0.0);
        std::fs::remove_dir_all(&dir_on).ok();
        std::fs::remove_dir_all(&dir_off).ok();
    }

    #[test]
    fn plan_cache_eviction_rebuilds_bitwise_equal_plans() {
        // cap = 1: payload B evicts payload A; A's rebuilt plans must
        // reproduce its first response to the bit, and the re-repeat
        // must hit the cache again.
        let (dir, svc) = synth_service(
            "evict",
            43,
            ServiceConfig { layers: 1, plan_cache: 1, ..Default::default() },
        );
        let mut rng = SeededRng::new(20);
        let a = rng.normal_matrix(8, 32, 1.0);
        let b = rng.normal_matrix(8, 32, 1.0);
        let first = svc.infer(1, a.clone()).unwrap();
        let _evict = svc.infer(2, b).unwrap();
        let rebuilt = svc.infer(3, a.clone()).unwrap();
        let hit = svc.infer(4, a).unwrap();
        assert_eq!(first.hidden, rebuilt.hidden, "evicted shape must rebuild bitwise equal");
        assert_eq!(rebuilt.hidden, hit.hidden);
        assert_eq!(first.layer_nnz, rebuilt.layer_nnz);
        let m = svc.metrics();
        assert_eq!((m.plan_cache_hits, m.plan_cache_misses), (1, 3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_request_rejected() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let svc = Service::start(
            dir,
            HardwareConfig::paper(),
            ModelConfig::paper(),
            ServiceConfig { layers: 1, ..Default::default() },
        )
        .unwrap();
        // wrong d_model
        let bad = Matrix::zeros(8, 7);
        assert!(svc.infer(1, bad).is_err());
    }
}
