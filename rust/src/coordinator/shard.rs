//! Batch-parallel shard accounting: K logical chips per packed batch.
//!
//! The serving tentpole of the heavy-traffic north star: one packed
//! batch is partitioned across `shards` logical chips along the
//! sparsity structure — contiguous row ranges balanced by per-row nnz
//! from the batch's [`PlanSet`] (summed over heads), not by naive row
//! counts — and every shard gets its own sliced plan set
//! ([`PlanSet::shard`]). The functional fan-out lives in
//! [`ops::encoder_layer_heads_sharded`][crate::attention::ops::encoder_layer_heads_sharded]
//! (one executor pool task per shard,
//! bit-identical assembly); this module owns the *cost and metrics*
//! side: simulate each shard's chip, merge max-ns / sum-pJ across
//! chips, and attribute per-shard and per-head lines back to one batch.

use crate::sim::{ChipSim, SimTrace};
use crate::sparse::{PlanSet, ShardedPlans};

/// One shard's cost line for a served batch.
#[derive(Clone, Debug)]
pub struct ShardCost {
    /// Batch rows this shard owns (contiguous, nnz-balanced).
    pub rows: usize,
    /// Masked coordinates this shard dispatches (summed over heads).
    pub nnz: usize,
    /// Simulated latency of this shard's chip (ns).
    pub sim_ns: f64,
    /// Simulated energy of this shard's chip (pJ).
    pub sim_pj: f64,
}

/// The merged multi-chip accounting of one batch: per-shard lines plus
/// the batch roll-up (max-ns over concurrent chips, sum-pJ) and the
/// per-head lines re-aggregated across shards (head latency = max over
/// shards, head energy = sum over shards) so head imbalance stays
/// visible under sharding.
#[derive(Clone, Debug)]
pub struct ShardedBatchCost {
    pub shards: Vec<ShardCost>,
    /// Batch latency: max over shards (== max over heads' `head_ns`).
    pub sim_ns: f64,
    /// Batch energy: sum over shards.
    pub sim_pj: f64,
    /// Per-head latency across shards (ns), head order.
    pub head_ns: Vec<f64>,
    /// Per-head energy across shards (pJ), head order.
    pub head_pj: Vec<f64>,
    /// One stage timeline per (shard, head) chip slice — the `--trace`
    /// payload of a sharded batch.
    pub traces: Vec<SimTrace>,
}

/// Simulate each shard of a prebuilt partition (normally the one the
/// engine executed, via
/// [`EncoderHeadsExec::sharded`][crate::runtime::EncoderHeadsExec]) and
/// merge — the coordinator's one-call bridge from a batch's shard
/// partition to its serving cost lines. Build a partition explicitly
/// with [`PlanSet::shard`] when no executed one is at hand.
pub fn attribute(sim: &ChipSim, sharded: &ShardedPlans) -> ShardedBatchCost {
    let report = sim.simulate_sharded(sharded);
    let heads = sharded.sets().first().map(PlanSet::heads).unwrap_or(0);
    let shard_costs = report
        .shards
        .iter()
        .enumerate()
        .map(|(s, r)| ShardCost {
            rows: sharded.range(s).len(),
            nnz: sharded.set(s).total_nnz(),
            sim_ns: r.total_ns,
            sim_pj: r.energy_pj,
        })
        .collect();
    ShardedBatchCost {
        shards: shard_costs,
        sim_ns: report.total_ns,
        sim_pj: report.energy_pj,
        head_ns: (0..heads).map(|h| report.head_ns(h)).collect(),
        head_pj: (0..heads).map(|h| report.head_pj(h)).collect(),
        traces: report.traces(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::sparse::MaskMatrix;
    use crate::tensor::SeededRng;

    fn plans(heads: usize, n: usize, seed: u64) -> PlanSet {
        let mut rng = SeededRng::new(seed);
        let masks: Vec<MaskMatrix> = (0..heads)
            .map(|h| MaskMatrix::from_dense(&rng.mask_matrix(n, n, 0.08 + 0.06 * h as f64)))
            .collect();
        PlanSet::build(&masks)
    }

    #[test]
    fn attribution_invariants() {
        let sim = ChipSim::new(HardwareConfig::paper(), ModelConfig::paper());
        let set = plans(4, 320, 3);
        let cost = attribute(&sim, &set.shard(4));
        assert!(!cost.shards.is_empty() && cost.shards.len() <= 4);
        // shard rows/nnz tile the batch
        assert_eq!(cost.shards.iter().map(|s| s.rows).sum::<usize>(), 320);
        assert_eq!(cost.shards.iter().map(|s| s.nnz).sum::<usize>(), set.total_nnz());
        // batch latency = slowest chip = slowest head line
        let max_shard = cost.shards.iter().map(|s| s.sim_ns).fold(0.0, f64::max);
        assert_eq!(cost.sim_ns, max_shard);
        let max_head = cost.head_ns.iter().copied().fold(0.0, f64::max);
        assert_eq!(cost.sim_ns, max_head);
        // batch energy sums both ways
        let shard_pj: f64 = cost.shards.iter().map(|s| s.sim_pj).sum();
        assert!((cost.sim_pj - shard_pj).abs() < 1e-6 * cost.sim_pj.max(1.0));
        let head_pj: f64 = cost.head_pj.iter().sum();
        assert!((cost.sim_pj - head_pj).abs() < 1e-6 * cost.sim_pj.max(1.0));
    }

    #[test]
    fn one_shard_matches_heads_accounting() {
        let sim = ChipSim::new(HardwareConfig::paper(), ModelConfig::paper());
        let set = plans(2, 320, 4);
        let cost = attribute(&sim, &set.shard(1));
        let hs = sim.simulate_heads_planned(&set);
        assert_eq!(cost.shards.len(), 1);
        assert_eq!(cost.sim_ns, hs.total_ns);
        assert_eq!(cost.sim_pj, hs.energy_pj);
        for h in 0..2 {
            assert_eq!(cost.head_ns[h], hs.heads[h].breakdown.total_ns, "head {h}");
            assert_eq!(cost.head_pj[h], hs.heads[h].energy_pj, "head {h}");
        }
    }
}
