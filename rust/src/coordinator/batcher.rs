//! Dynamic batcher: pack variable-length requests into fixed-shape batches.
//!
//! The AOT artifacts have a fixed (seq_len × d_model) input shape — the
//! hardware analogue of a fixed crossbar allocation. Incoming requests
//! carry `len ≤ seq_len` token rows; the batcher packs as many requests as
//! fit into one batch (first-fit in arrival order, preserving FIFO
//! fairness), zero-padding the tail. Invariants (property-tested):
//! every request lands in exactly one batch, offsets never overlap, and
//! no batch exceeds capacity.
//!
//! Each packed batch downstream gets exactly one pruning mask **per
//! head** and one [`PlanSet`][crate::sparse::PlanSet] (a
//! [`DispatchPlan`][crate::sparse::DispatchPlan] per head), built by
//! [`EncoderStack::forward`][super::EncoderStack::forward] and shared
//! across every encoder layer; the packing itself is head-agnostic —
//! all heads see the same packed X.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::tensor::Matrix;

/// Shared monotonic batch-id source. Every leader's [`Batcher`] draws
/// from one `BatchIds`, so batch ids stay unique and attributable
/// across *all* leaders of a service — two leaders can never seal the
/// same id, and interleaved metric lines from concurrent leaders keep
/// pointing at exactly one batch.
#[derive(Clone, Debug, Default)]
pub struct BatchIds(Arc<AtomicU64>);

impl BatchIds {
    pub fn new() -> Self {
        Self::default()
    }

    /// Claim the next batch id (monotonic for this source's lifetime).
    fn next(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed)
    }

    /// Batches sealed so far across every batcher sharing this source.
    pub fn sealed(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A request occupying `rows` leading rows of its embedding matrix.
#[derive(Clone, Debug)]
pub struct PackedRequest {
    pub id: u64,
    /// Row offset within the batch.
    pub offset: usize,
    /// Number of token rows.
    pub rows: usize,
}

/// One planned batch: the packed X matrix plus request placements.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    /// Monotonic id assigned at seal time, unique for the batcher's
    /// lifetime — the key that makes interleaved per-head/per-shard
    /// metric lines attributable when several batches are in flight.
    pub batch: u64,
    pub x: Matrix,
    pub entries: Vec<PackedRequest>,
    /// Rows actually occupied.
    pub used_rows: usize,
}

/// FIFO first-fit batcher.
pub struct Batcher {
    seq_len: usize,
    d_model: usize,
    queue: Vec<(u64, Matrix)>,
    /// Batch-id source — private to this batcher, or shared across the
    /// leaders of one service ([`Batcher::with_ids`]).
    ids: BatchIds,
}

impl Batcher {
    pub fn new(seq_len: usize, d_model: usize) -> Self {
        Self::with_ids(seq_len, d_model, BatchIds::new())
    }

    /// A batcher drawing batch ids from a shared source — one source
    /// per service, one batcher per leader.
    pub fn with_ids(seq_len: usize, d_model: usize, ids: BatchIds) -> Self {
        Self { seq_len, d_model, queue: Vec::new(), ids }
    }

    /// Enqueue one request. Returns `Err` if the request alone exceeds a
    /// batch (callers should chunk long documents upstream).
    pub fn push(&mut self, id: u64, x: Matrix) -> Result<(), String> {
        if x.rows() == 0 {
            return Err("empty request".into());
        }
        if x.rows() > self.seq_len {
            return Err(format!("request rows {} > batch capacity {}", x.rows(), self.seq_len));
        }
        if x.cols() != self.d_model {
            return Err(format!("request d_model {} != {}", x.cols(), self.d_model));
        }
        self.queue.push((id, x));
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue into batch plans (FIFO; a batch closes when the
    /// next request no longer fits). Each plan carries the next
    /// monotonic batch id.
    pub fn drain(&mut self) -> Vec<BatchPlan> {
        let mut plans = Vec::new();
        let mut current: Vec<(u64, Matrix)> = Vec::new();
        let mut used = 0usize;
        let queue = std::mem::take(&mut self.queue);
        for (id, x) in queue {
            if used + x.rows() > self.seq_len {
                if !current.is_empty() {
                    plans.push(self.seal(std::mem::take(&mut current)));
                }
                used = 0;
            }
            used += x.rows();
            current.push((id, x));
        }
        if !current.is_empty() {
            plans.push(self.seal(current));
        }
        plans
    }

    fn seal(&mut self, items: Vec<(u64, Matrix)>) -> BatchPlan {
        let mut x = Matrix::zeros(self.seq_len, self.d_model);
        let mut entries = Vec::with_capacity(items.len());
        let mut offset = 0;
        for (id, m) in items {
            let rows = m.rows();
            for r in 0..rows {
                let dst = (offset + r) * self.d_model;
                x.data_mut()[dst..dst + self.d_model].copy_from_slice(m.row(r));
            }
            entries.push(PackedRequest { id, offset, rows });
            offset += rows;
        }
        BatchPlan { batch: self.ids.next(), x, entries, used_rows: offset }
    }
}

impl BatchPlan {
    /// Slice one request's rows out of a batch-shaped output matrix.
    pub fn extract(&self, output: &Matrix, entry: &PackedRequest) -> Matrix {
        let d = output.cols();
        let mut m = Matrix::zeros(entry.rows, d);
        for r in 0..entry.rows {
            let src = (entry.offset + r) * d;
            m.data_mut()[r * d..(r + 1) * d].copy_from_slice(&output.data()[src..src + d]);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn req(rng: &mut SeededRng, rows: usize, d: usize) -> Matrix {
        rng.normal_matrix(rows, d, 1.0)
    }

    #[test]
    fn packs_fifo_no_overlap() {
        let mut b = Batcher::new(16, 8);
        let mut rng = SeededRng::new(0);
        for (i, rows) in [4usize, 6, 5, 8, 3].iter().enumerate() {
            b.push(i as u64, req(&mut rng, *rows, 8)).unwrap();
        }
        let plans = b.drain();
        let total: usize = plans.iter().map(|p| p.entries.len()).sum();
        assert_eq!(total, 5);
        for p in &plans {
            assert!(p.used_rows <= 16);
            let mut cursor = 0;
            for e in &p.entries {
                assert_eq!(e.offset, cursor, "entries must be contiguous FIFO");
                cursor += e.rows;
            }
        }
        // FIFO: ids appear in order across plans
        let ids: Vec<u64> = plans.iter().flat_map(|p| p.entries.iter().map(|e| e.id)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn extract_roundtrip() {
        let mut b = Batcher::new(8, 4);
        let mut rng = SeededRng::new(1);
        let m0 = req(&mut rng, 3, 4);
        let m1 = req(&mut rng, 5, 4);
        b.push(0, m0.clone()).unwrap();
        b.push(1, m1.clone()).unwrap();
        let plans = b.drain();
        assert_eq!(plans.len(), 1);
        let p = &plans[0];
        assert_eq!(p.extract(&p.x, &p.entries[0]), m0);
        assert_eq!(p.extract(&p.x, &p.entries[1]), m1);
    }

    #[test]
    fn oversized_rejected() {
        let mut b = Batcher::new(8, 4);
        assert!(b.push(0, Matrix::zeros(9, 4)).is_err());
        assert!(b.push(0, Matrix::zeros(0, 4)).is_err());
        assert!(b.push(0, Matrix::zeros(4, 5)).is_err());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn padding_is_zero() {
        let mut b = Batcher::new(8, 4);
        b.push(7, Matrix::full(2, 4, 1.0)).unwrap();
        let p = &b.drain()[0];
        assert_eq!(p.used_rows, 2);
        assert!(p.x.data()[2 * 4..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn exact_fill_starts_new_batch() {
        let mut b = Batcher::new(8, 2);
        b.push(0, Matrix::zeros(8, 2)).unwrap();
        b.push(1, Matrix::zeros(1, 2)).unwrap();
        let plans = b.drain();
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].used_rows, 8);
        assert_eq!(plans[1].used_rows, 1);
    }

    #[test]
    fn batch_ids_monotonic_across_drains() {
        let mut b = Batcher::new(8, 2);
        b.push(0, Matrix::zeros(8, 2)).unwrap();
        b.push(1, Matrix::zeros(8, 2)).unwrap();
        let first = b.drain();
        assert_eq!(first.iter().map(|p| p.batch).collect::<Vec<u64>>(), vec![0, 1]);
        b.push(2, Matrix::zeros(3, 2)).unwrap();
        let second = b.drain();
        assert_eq!(second.len(), 1);
        // ids keep counting across windows — the attribution key never
        // repeats for this batcher's lifetime
        assert_eq!(second[0].batch, 2);
    }

    #[test]
    fn shared_id_source_never_repeats_across_batchers() {
        // Two batchers (two leaders) on one source: every sealed batch
        // gets a unique id, and the source counts all of them.
        let ids = BatchIds::new();
        let mut a = Batcher::with_ids(8, 2, ids.clone());
        let mut b = Batcher::with_ids(8, 2, ids.clone());
        a.push(0, Matrix::zeros(8, 2)).unwrap();
        b.push(1, Matrix::zeros(8, 2)).unwrap();
        a.push(2, Matrix::zeros(8, 2)).unwrap();
        let mut seen: Vec<u64> = a
            .drain()
            .into_iter()
            .chain(b.drain())
            .map(|p| p.batch)
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2], "ids must be unique across leaders");
        assert_eq!(ids.sealed(), 3);
    }
}
