//! Encoder-stack pipeline: functional execution + hardware accounting.
//!
//! Each layer executes one multi-head encoder step on the engine
//! (functional result) and, in parallel bookkeeping, feeds the batch's
//! per-head dispatch plans into the cycle simulator so every served
//! batch carries both the *numbers* (Z) and the *cost* the CPSAA chip
//! would have incurred (ns, pJ) — the equivalent of the paper's
//! per-benchmark GOPS accounting.
//!
//! The batch's [`PlanSet`] — one [`DispatchPlan`][crate::sparse::DispatchPlan]
//! per head, one ReCAM scan per head mask — is taken from the **first
//! layer's** execution and shared by the simulator across every layer of
//! the stack: the scan cost is paid once per batch instead of once per
//! kernel per layer (the CPSAA §4.2 design point). Heads execute
//! concurrently on disjoint `tiles/heads` slices (§4.5), so each layer
//! is charged max-over-heads wall time and sum-over-heads energy.
//!
//! With `shards > 1` the stack runs batch-parallel: every layer's rows
//! are partitioned across K logical chips by per-row nnz from the plan
//! set ([`PlanSet::shard`][crate::sparse::PlanSet::shard]), executed
//! concurrently, and charged max-over-shards wall time / sum-over-shards
//! energy ([`shard::attribute`][super::shard::attribute]). `shards == 1`
//! runs the exact unsharded code path.

use std::sync::Arc;

use crate::util::error::Result;

use crate::attention::{MultiHeadWeights, Precision};
use crate::config::{HardwareConfig, ModelConfig};
use crate::runtime::{Engine, EncoderHeadsExec};
use crate::sim::{ChipSim, SimTrace};
use crate::sparse::{PlanSet, PruneConfig};
use crate::tensor::Matrix;

use super::shard;

/// Output of one layer over one batch.
#[derive(Clone, Debug)]
pub struct LayerOutput {
    pub hidden: Matrix,
    /// Mean pruning-mask density across heads.
    pub mask_density: f64,
    /// Simulated accelerator latency for this layer-batch (ns) —
    /// max over heads (heads run concurrently on tile slices); under
    /// sharding, max over shards (chips run concurrently).
    pub sim_ns: f64,
    /// Simulated accelerator energy (pJ) — sum over heads (and shards).
    pub sim_pj: f64,
    /// Per-head latency on a `tiles/heads` chip slice (ns), head order.
    pub head_sim_ns: Vec<f64>,
    /// Per-head energy (pJ), head order.
    pub head_sim_pj: Vec<f64>,
    /// Per-head pruning-mask density, head order.
    pub head_density: Vec<f64>,
    /// Per-shard latency (ns), shard order; empty under unsharded
    /// serving.
    pub shard_sim_ns: Vec<f64>,
    /// Per-shard energy (pJ), shard order; empty when unsharded.
    pub shard_sim_pj: Vec<f64>,
    /// Rows each shard owned (nnz-balanced partition); empty when
    /// unsharded.
    pub shard_rows: Vec<usize>,
    /// Masked coordinates each shard dispatched; empty when unsharded.
    pub shard_nnz: Vec<usize>,
    /// Coordinates in the plan set that drove this layer's kernels
    /// (summed over heads) — under cascade pruning this shrinks layer
    /// over layer; static serving reports each layer's scanned set.
    pub plan_nnz: usize,
    /// Tokens alive in this layer's plans (= seq rows when not pruned).
    pub rows_kept: usize,
    /// Heads that still own coordinates (= all heads when not pruned).
    pub heads_kept: usize,
    /// Simulated cost (ns) of deriving this layer's plans by narrowing
    /// the previous layer's coordinate stream; 0.0 for layer 0 and for
    /// static serving.
    pub narrow_ns: f64,
    /// What the full per-layer ReCAM re-scan this narrowing replaced
    /// would have cost (ns); 0.0 when nothing was narrowed.
    pub rescan_ns: f64,
}

/// A stack of identical encoder layers (§4.5: encoders chain serially).
pub struct EncoderStack<'e> {
    engine: &'e Engine,
    weights: MultiHeadWeights,
    sim: ChipSim,
    layers: usize,
    shards: usize,
    precision: Precision,
    prune: PruneConfig,
}

impl<'e> EncoderStack<'e> {
    pub fn new(
        engine: &'e Engine,
        weights: MultiHeadWeights,
        hw: HardwareConfig,
        model: ModelConfig,
        layers: usize,
    ) -> Self {
        assert_eq!(
            weights.heads(),
            model.heads.max(1),
            "weights fan-out must match model.heads"
        );
        let sim = ChipSim::new(hw, model);
        Self {
            engine,
            weights,
            sim,
            layers,
            shards: 1,
            precision: Precision::F32,
            prune: PruneConfig::Static,
        }
    }

    /// Fan every batch out across `shards` logical chips (≥ 1). One
    /// shard keeps the exact unsharded path.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Run the stack's kernels (and cost them) at `precision`: `F32` is
    /// the reference path; `I8` quantizes the SDDMM score operands to
    /// i8 storage / i32 accumulation and cheapens the simulated Step-3
    /// crossbar pass to match the narrower bit-serial inputs.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self.sim = self.sim.with_precision(precision);
        self
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn heads(&self) -> usize {
        self.weights.heads()
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Evolve each batch's plans across layers per `prune`.
    /// [`PruneConfig::Cascade`] at keep-ratio 1.0 does not narrow
    /// ([`PruneConfig::narrows`]), so it runs the literal static path —
    /// bit-identity at keep = 1.0 holds by construction, at any
    /// worker/leader/shard count.
    pub fn with_prune(mut self, prune: PruneConfig) -> Self {
        self.prune = prune;
        self
    }

    pub fn prune(&self) -> &PruneConfig {
        &self.prune
    }

    /// Run one batch through every layer. Returns per-layer outputs
    /// (last entry is the final hidden state).
    ///
    /// The per-head plan set is taken from the first layer's execution
    /// (derived from the packed batch input), and the per-layer hardware
    /// accounting — a pure function of (hw, model, plan set) — is
    /// simulated once and reused for every layer: the coordinator never
    /// re-scans a mask or re-runs the pipeline model.
    pub fn forward(&self, x: &Matrix) -> Result<Vec<LayerOutput>> {
        Ok(self.forward_traced(x)?.0)
    }

    /// [`EncoderStack::forward`] plus the batch's per-chip-slice stage
    /// timelines (one [`SimTrace`] per head, or per (shard, head) under
    /// sharding) — the payload `serve --trace` / `replay --trace` dump.
    /// The timelines describe the batch's one simulated execution, the
    /// same one every layer's cost lines reuse.
    pub fn forward_traced(&self, x: &Matrix) -> Result<(Vec<LayerOutput>, Vec<SimTrace>)> {
        self.forward_traced_prefetched(x, None)
    }

    /// [`EncoderStack::forward_traced`] accepting the batch's layer-0
    /// plan set prebuilt elsewhere — by the serving layer's prefetch
    /// pipeline (scanned while the previous batch was still executing)
    /// or its content-addressed plan cache. The plans must be exactly
    /// what layer 0 would have scanned from `x` (they are a pure
    /// function of the payload bits), so the output is bit-identical to
    /// the unprefetched path; `None` builds them inline as always.
    /// Only layer 0 is prefetchable: deeper static layers scan their
    /// own input (the previous hidden state), and the cascade derives
    /// deeper plans by narrowing.
    pub fn forward_traced_prefetched(
        &self,
        x: &Matrix,
        l0_plans: Option<Arc<PlanSet>>,
    ) -> Result<(Vec<LayerOutput>, Vec<SimTrace>)> {
        if self.prune.narrows() {
            return self.forward_cascade(x, l0_plans);
        }
        let mut outs: Vec<LayerOutput> = Vec::with_capacity(self.layers);
        let mut batch_cost: Option<BatchCost> = None;
        let mut prebuilt = l0_plans;
        for layer in 0..self.layers {
            // Layer N reads layer N−1's hidden state in place — no
            // input clone; kernel scratch comes from the engine's
            // workspace pool, so the stack allocates nothing per layer
            // beyond the hidden states it returns.
            let input = if layer == 0 { x } else { &outs[layer - 1].hidden };
            let exec = match prebuilt.take().filter(|_| layer == 0) {
                Some(plans) => self.engine.execute_encoder_heads_preplanned_prec(
                    input,
                    &self.weights,
                    plans,
                    self.shards,
                    self.precision,
                )?,
                None => self.engine.execute_encoder_heads_sharded_prec(
                    input,
                    &self.weights,
                    self.shards,
                    self.precision,
                )?,
            };
            let cost = batch_cost.get_or_insert_with(|| self.cost_of(&exec));
            outs.push(layer_output(
                exec.hidden,
                cost,
                PlanStats {
                    plan_nnz: exec.plans.total_nnz(),
                    rows_kept: exec.plans.rows(),
                    heads_kept: exec.plans.heads(),
                    narrow_ns: 0.0,
                    rescan_ns: 0.0,
                },
            ));
        }
        let traces = batch_cost.map(|c| c.traces).unwrap_or_default();
        Ok((outs, traces))
    }

    /// The cascade path: layer 0 scans masks and builds plans as today;
    /// every deeper layer's plans are derived by top-k narrowing the
    /// previous layer's coordinate stream ([`PlanSet::narrow_cascade`])
    /// — no mask generation, no ReCAM re-scan. Each layer is costed on
    /// the plans it actually ran (they shrink layer over layer), plus
    /// the narrowing charge; the re-scan cost it replaced rides along
    /// for observability.
    fn forward_cascade(
        &self,
        x: &Matrix,
        l0_plans: Option<Arc<PlanSet>>,
    ) -> Result<(Vec<LayerOutput>, Vec<SimTrace>)> {
        let mut outs: Vec<LayerOutput> = Vec::with_capacity(self.layers);
        let mut traces: Vec<SimTrace> = Vec::new();
        // Plans for the layer about to run (None = scan from the input;
        // layer 0 may arrive prebuilt from the prefetch pipeline), and
        // the stats/cost of the narrowing step that produced them.
        let mut planned: Option<Arc<PlanSet>> = l0_plans;
        let mut step: Option<(usize, usize, f64, f64)> = None;
        for layer in 0..self.layers {
            let input = if layer == 0 { x } else { &outs[layer - 1].hidden };
            let (exec, imp) = match planned.take() {
                None => self.engine.execute_encoder_heads_importance(
                    input,
                    &self.weights,
                    self.shards,
                    self.precision,
                )?,
                Some(plans) => self.engine.execute_encoder_heads_planned_importance(
                    input,
                    &self.weights,
                    plans,
                    self.shards,
                    self.precision,
                )?,
            };
            let cost = self.cost_of(&exec);
            if layer == 0 {
                traces = cost.traces.clone();
            }
            let (rows_kept, heads_kept, narrow_ns, rescan_ns) = step.take().unwrap_or((
                exec.plans.rows(),
                exec.plans.heads(),
                0.0,
                0.0,
            ));
            if layer + 1 < self.layers {
                // Narrowing step `layer` derives layer `layer + 1`'s
                // plans at that step's keep-ratio (schedules clamp to
                // their last entry).
                let keep = self
                    .prune
                    .keep_at(layer)
                    .expect("narrowing implies a cascade keep schedule");
                if keep < 1.0 {
                    let evo = self.sim.plan_evolution_cost(&exec.plans);
                    let (next, stats) = exec.plans.narrow_cascade(&imp, keep);
                    step =
                        Some((stats.rows_kept, stats.heads_kept, evo.narrow_ns, evo.rescan_ns));
                    planned = Some(Arc::new(next));
                } else {
                    // A keep-1.0 step retains everything: reuse the
                    // plans untouched (no filter pass to charge) and
                    // carry the last narrowing step's keep counts
                    // forward — the live-token set did not change.
                    step = Some((rows_kept, heads_kept, 0.0, 0.0));
                    planned = Some(exec.plans.clone());
                }
            }
            outs.push(layer_output(
                exec.hidden,
                &cost,
                PlanStats {
                    plan_nnz: exec.plans.total_nnz(),
                    rows_kept,
                    heads_kept,
                    narrow_ns,
                    rescan_ns,
                },
            ));
        }
        Ok((outs, traces))
    }

    /// Cost one executed layer on the plans (and partition) it actually
    /// ran — the static path calls this once per batch and reuses it;
    /// the cascade path calls it per layer (its plans shrink).
    fn cost_of(&self, exec: &EncoderHeadsExec) -> BatchCost {
        if self.shards <= 1 {
            let hs = self.sim.simulate_heads_planned(&exec.plans);
            BatchCost {
                density: hs.mean_density,
                ns: hs.total_ns,
                pj: hs.energy_pj,
                head_ns: hs.heads.iter().map(|r| r.breakdown.total_ns).collect(),
                head_pj: hs.heads.iter().map(|r| r.energy_pj).collect(),
                head_density: exec.plans.densities(),
                shard_ns: Vec::new(),
                shard_pj: Vec::new(),
                shard_rows: Vec::new(),
                shard_nnz: Vec::new(),
                traces: hs.traces(),
            }
        } else {
            // Cost the partition the engine actually executed.
            let sharded = exec
                .sharded
                .as_ref()
                .expect("sharded execution must carry its partition");
            let sc = shard::attribute(&self.sim, sharded);
            BatchCost {
                // Batch density stays the full plan set's (the
                // mask is a batch property, not a shard's).
                density: exec.plans.mean_density(),
                ns: sc.sim_ns,
                pj: sc.sim_pj,
                head_ns: sc.head_ns,
                head_pj: sc.head_pj,
                head_density: exec.plans.densities(),
                shard_ns: sc.shards.iter().map(|s| s.sim_ns).collect(),
                shard_pj: sc.shards.iter().map(|s| s.sim_pj).collect(),
                shard_rows: sc.shards.iter().map(|s| s.rows).collect(),
                shard_nnz: sc.shards.iter().map(|s| s.nnz).collect(),
                traces: sc.traces,
            }
        }
    }
}

/// Per-layer plan-evolution stats riding on a [`LayerOutput`].
struct PlanStats {
    plan_nnz: usize,
    rows_kept: usize,
    heads_kept: usize,
    narrow_ns: f64,
    rescan_ns: f64,
}

fn layer_output(hidden: Matrix, cost: &BatchCost, stats: PlanStats) -> LayerOutput {
    LayerOutput {
        hidden,
        mask_density: cost.density,
        sim_ns: cost.ns,
        sim_pj: cost.pj,
        head_sim_ns: cost.head_ns.clone(),
        head_sim_pj: cost.head_pj.clone(),
        head_density: cost.head_density.clone(),
        shard_sim_ns: cost.shard_ns.clone(),
        shard_sim_pj: cost.shard_pj.clone(),
        shard_rows: cost.shard_rows.clone(),
        shard_nnz: cost.shard_nnz.clone(),
        plan_nnz: stats.plan_nnz,
        rows_kept: stats.rows_kept,
        heads_kept: stats.heads_kept,
        narrow_ns: stats.narrow_ns,
        rescan_ns: stats.rescan_ns,
    }
}

/// The first layer's simulated cost, reused across the stack.
struct BatchCost {
    density: f64,
    ns: f64,
    pj: f64,
    head_ns: Vec<f64>,
    head_pj: Vec<f64>,
    head_density: Vec<f64>,
    shard_ns: Vec<f64>,
    shard_pj: Vec<f64>,
    shard_rows: Vec<usize>,
    shard_nnz: Vec<usize>,
    /// Per-chip-slice stage timelines of the batch's one simulation.
    traces: Vec<SimTrace>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactSet;
    use std::path::PathBuf;

    fn setup() -> Option<(ArtifactSet, Engine)> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let set = ArtifactSet::open(&dir).ok()?;
        let engine = Engine::load(&set).ok()?;
        Some((set, engine))
    }

    #[test]
    fn forward_two_layers() {
        let Some((set, engine)) = setup() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let cfg = &set.manifest.config;
        let model = ModelConfig {
            seq_len: cfg.seq_len,
            d_model: cfg.d_model,
            d_k: cfg.d_k,
            d_ff: cfg.d_ff,
            ..ModelConfig::default()
        };
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 1).unwrap();
        let stack = EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 2);
        let fix = set.fixtures().unwrap();
        let outs = stack.forward(&fix.x).unwrap();
        assert_eq!(outs.len(), 2);
        for o in &outs {
            assert!(o.hidden.all_finite());
            assert!(o.sim_ns > 0.0 && o.sim_pj > 0.0);
            assert!(o.mask_density > 0.0 && o.mask_density < 1.0);
            assert_eq!(o.head_sim_ns.len(), 1);
        }
        // first layer must reproduce the encoder fixture exactly
        let want = &fix.outputs["encoder"][0];
        assert!(outs[0].hidden.rel_err(want) < 1e-4);
    }

    #[test]
    fn sharded_stack_bit_identical_with_shard_cost_lines() {
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-shards-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 4,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 33).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 4).unwrap();
        let x = crate::tensor::SeededRng::new(5).normal_matrix(32, 64, 1.0);
        let plain = EncoderStack::new(&engine, w.clone(), HardwareConfig::paper(), model.clone(), 2);
        let sharded =
            EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 2).with_shards(4);
        assert_eq!(sharded.shards(), 4);
        let a = plain.forward(&x).unwrap();
        let b = sharded.forward(&x).unwrap();
        assert_eq!(a.len(), b.len());
        for (la, lb) in a.iter().zip(&b) {
            // functional output must not differ in a single bit
            assert_eq!(la.hidden, lb.hidden, "sharded hidden state diverged");
            // unsharded layers carry no shard lines; sharded ones do
            assert!(la.shard_sim_ns.is_empty());
            assert!(!lb.shard_sim_ns.is_empty() && lb.shard_sim_ns.len() <= 4);
            assert_eq!(lb.shard_sim_ns.len(), lb.shard_rows.len());
            assert_eq!(lb.shard_rows.iter().sum::<usize>(), 32, "shards must tile the batch");
            // batch cost = slowest chip; per-head lines still roll up
            let max_shard = lb.shard_sim_ns.iter().copied().fold(0.0, f64::max);
            assert_eq!(lb.sim_ns, max_shard);
            let max_head = lb.head_sim_ns.iter().copied().fold(0.0, f64::max);
            assert_eq!(lb.sim_ns, max_head);
            let shard_pj: f64 = lb.shard_sim_pj.iter().sum();
            assert!((lb.sim_pj - shard_pj).abs() < 1e-6 * lb.sim_pj.max(1.0));
            // densities are batch properties — identical across modes
            assert_eq!(la.head_density, lb.head_density);
            assert!((la.mask_density - lb.mask_density).abs() < 1e-12);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn forward_traced_labels_one_timeline_per_chip_slice() {
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-traced-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 2,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 55).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 2).unwrap();
        let x = crate::tensor::SeededRng::new(9).normal_matrix(32, 64, 1.0);
        let plain =
            EncoderStack::new(&engine, w.clone(), HardwareConfig::paper(), model.clone(), 2);
        let (outs, traces) = plain.forward_traced(&x).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(traces.len(), 2, "one timeline per head");
        for (h, t) in traces.iter().enumerate() {
            assert_eq!((t.head, t.shard), (h, None));
            assert!(!t.events.is_empty());
            // the timeline's end is the head's charged latency
            let end = t.events.last().unwrap().end_ns;
            assert_eq!(end, outs[0].head_sim_ns[h]);
        }
        let sharded =
            EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 1).with_shards(2);
        let (outs, traces) = sharded.forward_traced(&x).unwrap();
        let shards = outs[0].shard_sim_ns.len();
        assert_eq!(traces.len(), shards * 2, "one timeline per (shard, head)");
        assert!(traces.iter().all(|t| t.shard.is_some()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn i8_stack_serves_finite_hidden_at_lower_cost() {
        let dir = std::env::temp_dir().join(format!("cpsaa-pipe-i8-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 2,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 44).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 2).unwrap();
        let x = crate::tensor::SeededRng::new(7).normal_matrix(32, 64, 1.0);
        let f32_stack =
            EncoderStack::new(&engine, w.clone(), HardwareConfig::paper(), model.clone(), 1);
        let i8_stack = EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 1)
            .with_precision(Precision::I8);
        assert_eq!(i8_stack.precision(), Precision::I8);
        assert_eq!(f32_stack.precision(), Precision::F32);
        let a = f32_stack.forward(&x).unwrap();
        let b = i8_stack.forward(&x).unwrap();
        assert!(b[0].hidden.all_finite());
        assert_eq!(b[0].hidden.shape(), a[0].hidden.shape());
        // i8 narrows the Step-3 bit-serial inputs: never slower, and
        // strictly cheaper in energy.
        assert!(b[0].sim_ns <= a[0].sim_ns, "i8 {} vs f32 {}", b[0].sim_ns, a[0].sim_ns);
        assert!(b[0].sim_pj < a[0].sim_pj, "i8 {} vs f32 {}", b[0].sim_pj, a[0].sim_pj);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_stack_narrows_plans_and_charges_narrowing() {
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-cascade-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 4,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 77).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 4).unwrap();
        let x = crate::tensor::SeededRng::new(11).normal_matrix(32, 64, 1.0);
        let stack = EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 4)
            .with_prune(PruneConfig::cascade(0.5));
        assert_eq!(*stack.prune(), PruneConfig::cascade(0.5));
        let outs = stack.forward(&x).unwrap();
        assert_eq!(outs.len(), 4);
        // Layer 0 runs the full scanned plans and pays no narrowing.
        assert_eq!(outs[0].rows_kept, 32);
        assert_eq!(outs[0].heads_kept, 4);
        assert_eq!(outs[0].narrow_ns, 0.0);
        assert_eq!(outs[0].rescan_ns, 0.0);
        assert!(outs[0].plan_nnz > 0);
        // Every deeper layer runs on a narrowed coordinate stream:
        // top-k over 32 tokens at keep 0.5 is 16 rows, over 4 heads is
        // 2 heads, cumulative thereafter (narrowing only removes).
        assert_eq!(outs[1].rows_kept, 16);
        assert_eq!(outs[1].heads_kept, 2);
        assert!(outs[1].plan_nnz < outs[0].plan_nnz, "narrowing must shed coordinates");
        for pair in outs.windows(2).skip(1) {
            assert!(pair[1].plan_nnz <= pair[0].plan_nnz);
            assert!(pair[1].rows_kept <= pair[0].rows_kept);
            assert!(pair[1].heads_kept <= pair[0].heads_kept);
        }
        for o in &outs[1..] {
            assert!(o.hidden.all_finite());
            // The narrowing charge is real and undercuts the ReCAM
            // re-scan it replaced — the cascade's whole bargain.
            assert!(o.narrow_ns > 0.0);
            assert!(o.narrow_ns < o.rescan_ns, "narrow {} vs rescan {}", o.narrow_ns, o.rescan_ns);
        }
        // Fewer coordinates ⇒ the simulated layer itself got cheaper.
        assert!(
            outs.last().unwrap().sim_ns <= outs[0].sim_ns,
            "narrowed layer costed more than the full one"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_schedule_applies_per_layer_keeps_and_clamps_to_the_last() {
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-sched-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 4,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 66).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 4).unwrap();
        let x = crate::tensor::SeededRng::new(17).normal_matrix(32, 64, 1.0);
        // Narrowing step 0 runs at 0.5; steps 1 and 2 clamp to the
        // schedule's last entry (1.0), so the coordinate stream stops
        // shrinking after layer 1.
        let stack = EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 4)
            .with_prune(PruneConfig::cascade_schedule(vec![0.5, 1.0]));
        let outs = stack.forward(&x).unwrap();
        assert_eq!(outs.len(), 4);
        assert_eq!(outs[0].rows_kept, 32);
        assert_eq!(outs[0].heads_kept, 4);
        assert_eq!(outs[1].rows_kept, 16);
        assert_eq!(outs[1].heads_kept, 2);
        assert!(outs[1].plan_nnz < outs[0].plan_nnz);
        for o in &outs[2..] {
            assert_eq!(o.rows_kept, outs[1].rows_kept, "keep 1.0 steps must not narrow");
            assert_eq!(o.heads_kept, outs[1].heads_kept);
            assert_eq!(o.plan_nnz, outs[1].plan_nnz);
            assert!(o.hidden.all_finite());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefetched_layer0_plans_serve_bit_identically() {
        // The prefetch pipeline's whole contract: handing the stack a
        // plan set built elsewhere (detached executor job or the plan
        // cache) changes nothing about the outputs — static or cascade,
        // unsharded or sharded.
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-prefetch-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 4,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 49).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 4).unwrap();
        let x = crate::tensor::SeededRng::new(23).normal_matrix(32, 64, 1.0);
        for prune in [PruneConfig::Static, PruneConfig::cascade(0.5)] {
            for shards in [1usize, 3] {
                let stack = EncoderStack::new(
                    &engine,
                    w.clone(),
                    HardwareConfig::paper(),
                    model.clone(),
                    3,
                )
                .with_shards(shards)
                .with_prune(prune.clone());
                let plans = engine.prepare_plans(&x, &w).unwrap();
                let (inline, t_inline) = stack.forward_traced(&x).unwrap();
                let (pre, t_pre) =
                    stack.forward_traced_prefetched(&x, Some(plans)).unwrap();
                assert_eq!(inline.len(), pre.len());
                for (a, b) in inline.iter().zip(&pre) {
                    assert_eq!(a.hidden, b.hidden, "prefetched hidden diverged ({prune})");
                    assert_eq!(a.plan_nnz, b.plan_nnz);
                    assert_eq!(a.sim_ns, b.sim_ns);
                    assert_eq!(a.sim_pj, b.sim_pj);
                }
                assert_eq!(t_inline.len(), t_pre.len());
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_keep_one_bit_identical_to_static_at_any_shard_count() {
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-keep1-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 2,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 88).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 2).unwrap();
        let x = crate::tensor::SeededRng::new(13).normal_matrix(32, 64, 1.0);
        // keep = 1.0 does not narrow, so it takes the literal static
        // path — the exactness contract, checked unsharded and sharded.
        assert!(!PruneConfig::cascade(1.0).narrows());
        for shards in [1usize, 3] {
            let stat =
                EncoderStack::new(&engine, w.clone(), HardwareConfig::paper(), model.clone(), 2)
                    .with_shards(shards);
            let casc =
                EncoderStack::new(&engine, w.clone(), HardwareConfig::paper(), model.clone(), 2)
                    .with_shards(shards)
                    .with_prune(PruneConfig::cascade(1.0));
            let a = stat.forward(&x).unwrap();
            let b = casc.forward(&x).unwrap();
            assert_eq!(a.len(), b.len());
            for (la, lb) in a.iter().zip(&b) {
                assert_eq!(la.hidden, lb.hidden, "keep=1.0 diverged at shards={shards}");
                assert_eq!(la.plan_nnz, lb.plan_nnz);
                assert_eq!(la.rows_kept, lb.rows_kept);
                assert_eq!(la.heads_kept, lb.heads_kept);
                assert_eq!(lb.narrow_ns, 0.0);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cascade_error_bounded_and_shrinks_as_keep_rises() {
        // The quality leg of the bench gate: against the unpruned
        // oracle, the cascade's final hidden state stays correlated at
        // aggressive keep-ratios and (on average over seeds) gets
        // closer as the keep-ratio rises toward the exact 1.0 endpoint.
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-errbound-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 4,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 99).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 4).unwrap();
        let stack_at = |keep: f64| {
            let s = EncoderStack::new(&engine, w.clone(), HardwareConfig::paper(), model.clone(), 3);
            if keep < 1.0 {
                s.with_prune(PruneConfig::cascade(keep))
            } else {
                s
            }
        };
        let (mut err_low, mut err_high) = (0.0f64, 0.0f64);
        for seed in 0..6u64 {
            let x = crate::tensor::SeededRng::new(200 + seed).normal_matrix(32, 64, 1.0);
            let oracle = stack_at(1.0).forward(&x).unwrap().pop().unwrap().hidden;
            let low = stack_at(0.6).forward(&x).unwrap().pop().unwrap().hidden;
            let high = stack_at(0.95).forward(&x).unwrap().pop().unwrap().hidden;
            assert!(low.all_finite() && high.all_finite());
            let (e_low, e_high) = (low.rel_err(&oracle) as f64, high.rel_err(&oracle) as f64);
            // Pruned output must stay in the oracle's neighborhood:
            // keep=0.95 perturbs a single token of 32, keep=0.6 drops
            // 12 tokens and one head yet the residual path keeps the
            // diff well under the uncorrelated-outputs bound (√2).
            assert!(e_low < 1.25, "seed {seed}: keep=0.6 rel_err {e_low}");
            assert!(e_high < 0.75, "seed {seed}: keep=0.95 rel_err {e_high}");
            err_low += e_low;
            err_high += e_high;
        }
        assert!(
            err_high <= err_low,
            "mean error did not shrink as keep rose: keep=0.95 {} vs keep=0.6 {}",
            err_high / 6.0,
            err_low / 6.0
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn forward_heads_charges_max_ns_sum_pj() {
        // Synthesized artifacts: runs with no `make artifacts`.
        let dir =
            std::env::temp_dir().join(format!("cpsaa-pipe-heads-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 32,
            d_model: 64,
            d_k: 8,
            d_ff: 128,
            heads: 4,
            ..ModelConfig::default()
        };
        let set = ArtifactSet::synthesize(&dir, &model, 21).unwrap();
        let engine = Engine::load(&set).unwrap();
        let w = MultiHeadWeights::load(&set.dir.join("weights.json"), 4).unwrap();
        let stack = EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 2);
        let x = crate::tensor::SeededRng::new(3).normal_matrix(32, 64, 1.0);
        let outs = stack.forward(&x).unwrap();
        assert_eq!(outs.len(), 2);
        for o in &outs {
            assert_eq!(o.head_sim_ns.len(), 4);
            assert_eq!(o.head_sim_pj.len(), 4);
            assert_eq!(o.head_density.len(), 4);
            let max_ns = o.head_sim_ns.iter().copied().fold(0.0, f64::max);
            let sum_pj: f64 = o.head_sim_pj.iter().sum();
            assert_eq!(o.sim_ns, max_ns, "layer latency is max over heads");
            assert!((o.sim_pj - sum_pj).abs() < 1e-6, "layer energy sums over heads");
            let mean: f64 = o.head_density.iter().sum::<f64>() / 4.0;
            assert!((o.mask_density - mean).abs() < 1e-12);
            assert!(o.hidden.all_finite());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
