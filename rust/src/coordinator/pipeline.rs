//! Encoder-stack pipeline: functional execution + hardware accounting.
//!
//! Each layer executes the `encoder` artifact (functional result) and, in
//! parallel bookkeeping, feeds the batch's pruning mask into the cycle
//! simulator so every served batch carries both the *numbers* (Z) and the
//! *cost* the CPSAA chip would have incurred (ns, pJ) — the equivalent of
//! the paper's per-benchmark GOPS accounting.
//!
//! The mask's [`DispatchPlan`] is built **once per packed batch**, from
//! the first layer's pruning output, and shared by the simulator across
//! every layer of the stack: the ReCAM scan cost is paid once per batch
//! instead of once per kernel per layer (the CPSAA §4.2 design point).

use crate::util::error::Result;

use crate::attention::Weights;
use crate::config::{HardwareConfig, ModelConfig};
use crate::runtime::Engine;
use crate::sim::ChipSim;
use crate::sparse::MaskMatrix;
use crate::tensor::Matrix;

/// Output of one layer over one batch.
#[derive(Clone, Debug)]
pub struct LayerOutput {
    pub hidden: Matrix,
    pub mask_density: f64,
    /// Simulated accelerator latency for this layer-batch (ns).
    pub sim_ns: f64,
    /// Simulated accelerator energy (pJ).
    pub sim_pj: f64,
}

/// A stack of identical encoder layers (§4.5: encoders chain serially).
pub struct EncoderStack<'e> {
    engine: &'e Engine,
    weights: Weights,
    sim: ChipSim,
    layers: usize,
}

impl<'e> EncoderStack<'e> {
    pub fn new(
        engine: &'e Engine,
        weights: Weights,
        hw: HardwareConfig,
        model: ModelConfig,
        layers: usize,
    ) -> Self {
        let sim = ChipSim::new(hw, model);
        Self { engine, weights, sim, layers }
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Run one batch through every layer. Returns per-layer outputs
    /// (last entry is the final hidden state).
    ///
    /// The dispatch plan is built once, from the first layer's pruning
    /// mask (derived from the packed batch input), and the per-layer
    /// hardware accounting — a pure function of (hw, model, plan) — is
    /// simulated once and reused for every layer: the coordinator never
    /// re-scans the mask or re-runs the pipeline model.
    pub fn forward(&self, x: &Matrix) -> Result<Vec<LayerOutput>> {
        let mut h = x.clone();
        let mut outs = Vec::with_capacity(self.layers);
        let mut batch_cost: Option<(f64, f64, f64)> = None; // (density, ns, pj)
        for _ in 0..self.layers {
            let res = self.engine.execute(
                "encoder",
                &[&h, &self.weights.w_s, &self.weights.w_v, &self.weights.w_fc1, &self.weights.w_fc2],
            )?;
            let hidden = res[0].clone();
            let (mask_density, sim_ns, sim_pj) = *batch_cost.get_or_insert_with(|| {
                let plan = MaskMatrix::from_dense(&res[1]).plan();
                let sim = self.sim.simulate_batch_planned(&plan);
                (plan.density(), sim.breakdown.total_ns, sim.energy_pj)
            });
            outs.push(LayerOutput { hidden: hidden.clone(), mask_density, sim_ns, sim_pj });
            h = hidden;
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactSet;
    use std::path::PathBuf;

    fn setup() -> Option<(ArtifactSet, Engine)> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let set = ArtifactSet::open(&dir).ok()?;
        let engine = Engine::load(&set).ok()?;
        Some((set, engine))
    }

    #[test]
    fn forward_two_layers() {
        let Some((set, engine)) = setup() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let cfg = &set.manifest.config;
        let model = ModelConfig {
            seq_len: cfg.seq_len,
            d_model: cfg.d_model,
            d_k: cfg.d_k,
            d_ff: cfg.d_ff,
            ..ModelConfig::default()
        };
        let w = Weights::from_json_file(&set.dir.join("weights.json")).unwrap();
        let stack = EncoderStack::new(&engine, w, HardwareConfig::paper(), model, 2);
        let fix = set.fixtures().unwrap();
        let outs = stack.forward(&fix.x).unwrap();
        assert_eq!(outs.len(), 2);
        for o in &outs {
            assert!(o.hidden.all_finite());
            assert!(o.sim_ns > 0.0 && o.sim_pj > 0.0);
            assert!(o.mask_density > 0.0 && o.mask_density < 1.0);
        }
        // first layer must reproduce the encoder fixture exactly
        let want = &fix.outputs["encoder"][0];
        assert!(outs[0].hidden.rel_err(want) < 1e-4);
    }
}
