//! Deterministic RNG for synthetic weights/workloads.
//!
//! splitmix64-seeded xoshiro256++ — no external crates (offline build),
//! bit-reproducible run-to-run. The paper's experiments fix datasets; ours
//! fix seeds.

use super::Matrix;

/// Seeded random source producing matrices with the distributions used by
/// the synthetic-BERT substitution (DESIGN.md).
pub struct SeededRng {
    state: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl SeededRng {
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        Self { state: [splitmix64(&mut s), splitmix64(&mut s), splitmix64(&mut s), splitmix64(&mut s)] }
    }

    /// xoshiro256++ next.
    fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.state;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.state = s;
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(f32::EPSILON);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Uniform integer in [lo, hi).
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Matrix of iid N(0, scale²).
    pub fn normal_matrix(&mut self, rows: usize, cols: usize, scale: f32) -> Matrix {
        let data = (0..rows * cols).map(|_| self.normal() * scale).collect();
        Matrix::from_vec(rows, cols, data)
    }

    /// Binary {0,1} matrix with the given density of ones.
    pub fn mask_matrix(&mut self, rows: usize, cols: usize, density: f64) -> Matrix {
        let data = (0..rows * cols)
            .map(|_| if (self.uniform() as f64) < density { 1.0 } else { 0.0 })
            .collect();
        Matrix::from_vec(rows, cols, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = SeededRng::new(7).normal_matrix(8, 8, 1.0);
        let b = SeededRng::new(7).normal_matrix(8, 8, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SeededRng::new(1).normal_matrix(8, 8, 1.0);
        let b = SeededRng::new(2).normal_matrix(8, 8, 1.0);
        assert!(a.max_abs_diff(&b) > 0.0);
    }

    #[test]
    fn normal_moments() {
        let m = SeededRng::new(3).normal_matrix(128, 128, 1.0);
        let mean: f32 = m.data().iter().sum::<f32>() / m.data().len() as f32;
        let var: f32 =
            m.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / m.data().len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn mask_density_close() {
        let m = SeededRng::new(4).mask_matrix(128, 128, 0.1);
        assert!((m.density() - 0.1).abs() < 0.02);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = SeededRng::new(5);
        for _ in 0..1000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = SeededRng::new(6);
        for _ in 0..1000 {
            let v = rng.gen_range_usize(3, 9);
            assert!((3..9).contains(&v));
        }
    }
}
