//! Row-major f32 matrix.

use std::fmt;

/// Dense row-major f32 matrix.
///
/// The whole reproduction deals with matrices small enough (≤ 512×512 per
/// attention head) that a plain `Vec<f32>` with cache-friendly loops is the
/// right tool; see `benches/hotpath.rs` for the measured matmul roofline.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Default for Matrix {
    /// The 0×0 matrix — the empty state of a workspace buffer before its
    /// first [`Matrix::reset`].
    fn default() -> Self {
        Self::zeros(0, 0)
    }
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer; panics on length mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer {} != {rows}x{cols}", data.len());
        Self { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Reshape to `rows`×`cols`, zero-filled, reusing the existing
    /// allocation when it is large enough — the workspace buffers cycle
    /// through shapes across layers/heads without reallocating.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// `self @ other` — blocked ikj with 4-way k-unrolling so the inner
    /// loops stay in L1 and auto-vectorize (the hot path of the golden
    /// model; measured in benches/hotpath.rs).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::default();
        self.matmul_into(other, &mut out);
        out
    }

    /// [`Matrix::matmul`] writing into a caller-owned buffer (reshaped and
    /// zeroed in place) — the workspace path's allocation-free matmul.
    /// Identical numerics to `matmul`.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul {:?} x {:?}", self.shape(), other.shape());
        let (n, k, m) = (self.rows, self.cols, other.cols);
        out.reset(n, m);
        const KB: usize = 64; // k-panel kept hot in L1
        let mut p0 = 0;
        while p0 < k {
            let p1 = (p0 + KB).min(k);
            for i in 0..n {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * m..(i + 1) * m];
                let mut p = p0;
                // 4-way unroll over k: one pass over the output row per
                // 4 B-rows quarters the write traffic.
                while p + 4 <= p1 {
                    let (a0, a1, a2, a3) =
                        (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
                    if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                        let b0 = &other.data[p * m..p * m + m];
                        let b1 = &other.data[(p + 1) * m..(p + 1) * m + m];
                        let b2 = &other.data[(p + 2) * m..(p + 2) * m + m];
                        let b3 = &other.data[(p + 3) * m..(p + 3) * m + m];
                        for j in 0..m {
                            orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                        }
                    }
                    p += 4;
                }
                while p < p1 {
                    let a = arow[p];
                    if a != 0.0 {
                        let brow = &other.data[p * m..p * m + m];
                        for (o, b) in orow.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                    }
                    p += 1;
                }
            }
            p0 = p1;
        }
    }

    /// Columns `[lo, hi)` as a new matrix (the per-head V slice).
    pub fn col_block(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo < hi && hi <= self.cols, "col_block {lo}..{hi} of {} cols", self.cols);
        let w = hi - lo;
        let mut out = Matrix::zeros(self.rows, w);
        for i in 0..self.rows {
            out.data[i * w..(i + 1) * w].copy_from_slice(&self.row(i)[lo..hi]);
        }
        out
    }

    /// Rows `[lo, hi)` as a new matrix (contiguous copy).
    pub fn row_block(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo < hi && hi <= self.rows, "row_block {lo}..{hi} of {} rows", self.rows);
        Matrix::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    /// Horizontal concatenation, left to right (the multi-head output
    /// concat); panics on row-count mismatch or an empty block list.
    pub fn concat_cols(blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty(), "concat of no blocks");
        let rows = blocks[0].rows;
        let cols: usize = blocks.iter().map(|b| b.cols).sum();
        let mut out = Matrix::zeros(rows, cols);
        let mut off = 0;
        for b in blocks {
            assert_eq!(b.rows, rows, "concat row mismatch");
            for i in 0..rows {
                let dst = i * cols + off;
                out.data[dst..dst + b.cols].copy_from_slice(b.row(i));
            }
            off += b.cols;
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise map in place (the workspace path's allocation-free
    /// [`Matrix::map`]; identical numerics).
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise combine; panics on shape mismatch.
    pub fn zip(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a + b)
    }

    /// `self + other` into a caller-owned buffer (reshaped in place) —
    /// identical numerics to [`Matrix::add`].
    pub fn add_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.shape(), other.shape());
        out.reset(self.rows, self.cols);
        for ((o, &a), &b) in out.data.iter_mut().zip(&self.data).zip(&other.data) {
            *o = a + b;
        }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        self.map(|x| x * s)
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Relative Frobenius distance `|a-b| / |b|` (0 when both are zero).
    pub fn rel_err(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        let denom = other.norm();
        let num = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        if denom == 0.0 {
            num
        } else {
            num / denom
        }
    }

    /// Max |a-b| over all elements.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Fraction of non-zero entries.
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x != 0.0).count() as f64 / self.data.len() as f64
    }

    /// Assert every element is finite (tests / debug).
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matmul(&Matrix::eye(2)), a);
        assert_eq!(Matrix::eye(2).matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::full(2, 2, 1.0);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[4.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(2, 1), 6.0);
    }

    #[test]
    fn transpose_matmul_identity() {
        // (A B)^T == B^T A^T
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let lhs = a.matmul(&b).transpose();
        let rhs = b.transpose().matmul(&a.transpose());
        assert!(lhs.max_abs_diff(&rhs) < 1e-6);
    }

    #[test]
    fn blocks_and_concat_roundtrip() {
        let m = Matrix::from_vec(2, 4, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let left = m.col_block(0, 2);
        let right = m.col_block(2, 4);
        assert_eq!(left.data(), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(right.data(), &[3.0, 4.0, 7.0, 8.0]);
        assert_eq!(Matrix::concat_cols(&[&left, &right]), m);
        let top = m.row_block(0, 1);
        assert_eq!(top.data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row_block(1, 2).data(), &[5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn density_counts() {
        let m = Matrix::from_vec(2, 2, vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(m.density(), 0.5);
    }

    #[test]
    fn rel_err_zero_for_equal() {
        let a = Matrix::full(3, 3, 2.5);
        assert_eq!(a.rel_err(&a), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_length_checked() {
        Matrix::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn into_variants_match_allocating_ops() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        // A stale, larger buffer must be fully overwritten by reset.
        let mut out = Matrix::full(4, 4, 9.9);
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
        let c = Matrix::full(2, 3, 0.5);
        a.add_into(&c, &mut out);
        assert_eq!(out, a.add(&c));
        let mut d = a.clone();
        d.map_inplace(|x| x * 2.0);
        assert_eq!(d, a.map(|x| x * 2.0));
    }

    #[test]
    fn reset_reuses_allocation() {
        let mut m = Matrix::full(8, 8, 3.0);
        let cap = m.data.capacity();
        m.reset(4, 4);
        assert_eq!(m.shape(), (4, 4));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert_eq!(m.data.capacity(), cap);
        assert_eq!(m.row_mut(1).len(), 4);
    }
}
