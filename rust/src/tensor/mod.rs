//! Minimal dense f32 matrix type shared by the golden model, the
//! simulator's functional checks, and the PJRT literal bridge.
//!
//! Deliberately tiny: row-major storage, the operations the CPSAA
//! dataflow needs (matmul, transpose, row softmax), and deterministic
//! random constructors seeded per use so fixtures are reproducible.

mod matrix;
mod rng;
pub mod simd;

pub use matrix::Matrix;
pub use rng::SeededRng;
