//! Fixed-width SIMD-lane row primitives for the fused attention hot path.
//!
//! Every row kernel in the crate (SDDMM dots, softmax reductions, SpMM
//! axpy, RMS-norm sums) funnels through these primitives, so the
//! bit-identity contract between CSR flavors survives vectorization:
//! there is exactly one accumulation-order definition per reduction.
//!
//! The laned form keeps [`LANES`] independent accumulators per chunk and
//! folds them with a fixed pairwise tree; the plain 8-wide inner loop is
//! what the compiler maps onto vector units. The scalar fallback —
//! selected at runtime via the `CPSAA_FORCE_SCALAR` environment variable
//! or [`set_force_scalar`] (the `serve --force-scalar` hook) — executes
//! the *same* operation sequence: same chunking, same lane accumulators,
//! same reduction tree, same sequential tail. It differs only in pinning
//! every element update through `std::hint::black_box`, which is
//! value-transparent but forces each update to be observable, blocking
//! vectorization. Identical floating-point operation DAG ⇒ identical
//! results to the last bit; the two modes differ only in speed.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// f32 lane width. 8 matches 256-bit vector units and divides every
/// d_k / d_model in the tree, so tails are rare on real shapes.
pub const LANES: usize = 8;

fn force_flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| AtomicBool::new(env_force_scalar()))
}

/// The `CPSAA_FORCE_SCALAR` environment default: set and non-`0` means
/// the scalar fallback.
pub fn env_force_scalar() -> bool {
    std::env::var("CPSAA_FORCE_SCALAR").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Select the scalar fallback (`true`) or the laned path (`false`) for
/// all subsequent primitive calls, overriding the environment default.
pub fn set_force_scalar(on: bool) {
    force_flag().store(on, Ordering::Relaxed);
}

/// True when the scalar fallback is active.
pub fn scalar_forced() -> bool {
    force_flag().load(Ordering::Relaxed)
}

/// The one pairwise add tree shared by both modes: (0+4, 1+5, 2+6, 3+7)
/// then (a0+a2, a1+a3) then the final add.
#[inline(always)]
fn fold_add(acc: [f32; LANES]) -> f32 {
    let a = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
    let b = [a[0] + a[2], a[1] + a[3]];
    b[0] + b[1]
}

/// The pairwise max tree, mirroring [`fold_add`].
#[inline(always)]
fn fold_max(acc: [f32; LANES]) -> f32 {
    let a = [acc[0].max(acc[4]), acc[1].max(acc[5]), acc[2].max(acc[6]), acc[3].max(acc[7])];
    let b = [a[0].max(a[2]), a[1].max(a[3])];
    b[0].max(b[1])
}

/// Dot product `Σ x[i]·y[i]` over the common prefix of `x` and `y`.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    if scalar_forced() {
        dot_scalar(x, y)
    } else {
        dot_lanes(x, y)
    }
}

#[inline(always)]
fn dot_lanes(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let mut acc = [0.0f32; LANES];
    let mut xs = x[..n].chunks_exact(LANES);
    let mut ys = y[..n].chunks_exact(LANES);
    for (cx, cy) in xs.by_ref().zip(ys.by_ref()) {
        for (a, (&px, &py)) in acc.iter_mut().zip(cx.iter().zip(cy)) {
            *a += px * py;
        }
    }
    let mut s = fold_add(acc);
    for (&px, &py) in xs.remainder().iter().zip(ys.remainder()) {
        s += px * py;
    }
    s
}

fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let mut acc = [0.0f32; LANES];
    let mut xs = x[..n].chunks_exact(LANES);
    let mut ys = y[..n].chunks_exact(LANES);
    for (cx, cy) in xs.by_ref().zip(ys.by_ref()) {
        for (a, (&px, &py)) in acc.iter_mut().zip(cx.iter().zip(cy)) {
            *a += px * py;
            std::hint::black_box(a);
        }
    }
    let mut s = fold_add(acc);
    for (&px, &py) in xs.remainder().iter().zip(ys.remainder()) {
        s += px * py;
        std::hint::black_box(&mut s);
    }
    s
}

/// `out[i] += a·x[i]` over the common prefix (the SpMM row update).
pub fn axpy(a: f32, x: &[f32], out: &mut [f32]) {
    if scalar_forced() {
        axpy_scalar(a, x, out)
    } else {
        axpy_lanes(a, x, out)
    }
}

#[inline(always)]
fn axpy_lanes(a: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len().min(out.len());
    let mut xs = x[..n].chunks_exact(LANES);
    let mut os = out[..n].chunks_exact_mut(LANES);
    for (cx, co) in xs.by_ref().zip(os.by_ref()) {
        for (o, &v) in co.iter_mut().zip(cx) {
            *o += a * v;
        }
    }
    for (o, &v) in os.into_remainder().iter_mut().zip(xs.remainder()) {
        *o += a * v;
    }
}

fn axpy_scalar(a: f32, x: &[f32], out: &mut [f32]) {
    let n = x.len().min(out.len());
    let mut xs = x[..n].chunks_exact(LANES);
    let mut os = out[..n].chunks_exact_mut(LANES);
    for (cx, co) in xs.by_ref().zip(os.by_ref()) {
        for (o, &v) in co.iter_mut().zip(cx) {
            *o += a * v;
            std::hint::black_box(o);
        }
    }
    for (o, &v) in os.into_remainder().iter_mut().zip(xs.remainder()) {
        *o += a * v;
        std::hint::black_box(o);
    }
}

/// `x[i] *= a` in place (the 1/√d_k score scaling). Elementwise, so the
/// two modes are trivially bit-identical.
pub fn scale(x: &mut [f32], a: f32) {
    if scalar_forced() {
        for v in x.iter_mut() {
            *v *= a;
            std::hint::black_box(v);
        }
    } else {
        for v in x.iter_mut() {
            *v *= a;
        }
    }
}

/// Max-reduce with the `f32::max` NaN-ignoring semantics of the old
/// sequential fold; `NEG_INFINITY` on an empty slice.
pub fn max_reduce(x: &[f32]) -> f32 {
    if scalar_forced() {
        max_scalar(x)
    } else {
        max_lanes(x)
    }
}

#[inline(always)]
fn max_lanes(x: &[f32]) -> f32 {
    let mut acc = [f32::NEG_INFINITY; LANES];
    let mut xs = x.chunks_exact(LANES);
    for cx in xs.by_ref() {
        for (a, &v) in acc.iter_mut().zip(cx) {
            *a = a.max(v);
        }
    }
    let mut m = fold_max(acc);
    for &v in xs.remainder() {
        m = m.max(v);
    }
    m
}

fn max_scalar(x: &[f32]) -> f32 {
    let mut acc = [f32::NEG_INFINITY; LANES];
    let mut xs = x.chunks_exact(LANES);
    for cx in xs.by_ref() {
        for (a, &v) in acc.iter_mut().zip(cx) {
            *a = a.max(v);
            std::hint::black_box(a);
        }
    }
    let mut m = fold_max(acc);
    for &v in xs.remainder() {
        m = m.max(v);
        std::hint::black_box(&mut m);
    }
    m
}

/// Sum-reduce (the softmax denominator).
pub fn sum(x: &[f32]) -> f32 {
    if scalar_forced() {
        sum_scalar(x)
    } else {
        sum_lanes(x)
    }
}

#[inline(always)]
fn sum_lanes(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut xs = x.chunks_exact(LANES);
    for cx in xs.by_ref() {
        for (a, &v) in acc.iter_mut().zip(cx) {
            *a += v;
        }
    }
    let mut s = fold_add(acc);
    for &v in xs.remainder() {
        s += v;
    }
    s
}

fn sum_scalar(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut xs = x.chunks_exact(LANES);
    for cx in xs.by_ref() {
        for (a, &v) in acc.iter_mut().zip(cx) {
            *a += v;
            std::hint::black_box(a);
        }
    }
    let mut s = fold_add(acc);
    for &v in xs.remainder() {
        s += v;
        std::hint::black_box(&mut s);
    }
    s
}

/// i8-storage / i32-accumulate dot product over the common prefix (the
/// quantized SDDMM inner product). Integer addition is exactly
/// associative, so lane order cannot change the result; |x·y| ≤
/// 127²·len stays far below `i32::MAX` for every model shape in the
/// tree (len < 16k), so the accumulation never wraps.
pub fn dot_i8(x: &[i8], y: &[i8]) -> i32 {
    if scalar_forced() {
        dot_i8_scalar(x, y)
    } else {
        dot_i8_lanes(x, y)
    }
}

#[inline(always)]
fn dot_i8_lanes(x: &[i8], y: &[i8]) -> i32 {
    let n = x.len().min(y.len());
    let mut acc = [0i32; LANES];
    let mut xs = x[..n].chunks_exact(LANES);
    let mut ys = y[..n].chunks_exact(LANES);
    for (cx, cy) in xs.by_ref().zip(ys.by_ref()) {
        for (a, (&px, &py)) in acc.iter_mut().zip(cx.iter().zip(cy)) {
            *a += i32::from(px) * i32::from(py);
        }
    }
    let mut s: i32 = acc.iter().sum();
    for (&px, &py) in xs.remainder().iter().zip(ys.remainder()) {
        s += i32::from(px) * i32::from(py);
    }
    s
}

fn dot_i8_scalar(x: &[i8], y: &[i8]) -> i32 {
    let n = x.len().min(y.len());
    let mut acc = [0i32; LANES];
    let mut xs = x[..n].chunks_exact(LANES);
    let mut ys = y[..n].chunks_exact(LANES);
    for (cx, cy) in xs.by_ref().zip(ys.by_ref()) {
        for (a, (&px, &py)) in acc.iter_mut().zip(cx.iter().zip(cy)) {
            *a += i32::from(px) * i32::from(py);
            std::hint::black_box(a);
        }
    }
    let mut s: i32 = acc.iter().sum();
    for (&px, &py) in xs.remainder().iter().zip(ys.remainder()) {
        s += i32::from(px) * i32::from(py);
        std::hint::black_box(&mut s);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    /// Lengths hitting no-chunk, exact-chunk, and every tail residue.
    const SIZES: [usize; 13] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 63, 100, 512];

    fn vec_f32(rng: &mut SeededRng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn vec_i8(rng: &mut SeededRng, n: usize) -> Vec<i8> {
        (0..n)
            .map(|_| (rng.gen_range_usize(0, 255) as i32 - 127) as i8)
            .collect()
    }

    #[test]
    fn laned_and_scalar_twins_bit_identical() {
        let mut rng = SeededRng::new(7);
        for n in SIZES {
            let x = vec_f32(&mut rng, n);
            let y = vec_f32(&mut rng, n);
            assert_eq!(dot_lanes(&x, &y).to_bits(), dot_scalar(&x, &y).to_bits(), "dot n={n}");
            assert_eq!(sum_lanes(&x).to_bits(), sum_scalar(&x).to_bits(), "sum n={n}");
            assert_eq!(max_lanes(&x).to_bits(), max_scalar(&x).to_bits(), "max n={n}");
            let mut a = y.clone();
            let mut b = y.clone();
            axpy_lanes(0.37, &x, &mut a);
            axpy_scalar(0.37, &x, &mut b);
            assert!(
                a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()),
                "axpy n={n}"
            );
            let xi = vec_i8(&mut rng, n);
            let yi = vec_i8(&mut rng, n);
            assert_eq!(dot_i8_lanes(&xi, &yi), dot_i8_scalar(&xi, &yi), "dot_i8 n={n}");
        }
    }

    #[test]
    fn dot_matches_sequential_reference() {
        let mut rng = SeededRng::new(11);
        for n in SIZES {
            let x = vec_f32(&mut rng, n);
            let y = vec_f32(&mut rng, n);
            let mut want = 0.0f64;
            for (&a, &b) in x.iter().zip(&y) {
                want += f64::from(a) * f64::from(b);
            }
            let got = f64::from(dot_lanes(&x, &y));
            assert!((got - want).abs() < 1e-3 * want.abs().max(1.0), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn max_matches_sequential_fold() {
        let mut rng = SeededRng::new(13);
        for n in SIZES {
            let x = vec_f32(&mut rng, n);
            let want = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            assert_eq!(max_lanes(&x).to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn axpy_and_scale_match_reference() {
        let mut rng = SeededRng::new(17);
        for n in SIZES {
            let x = vec_f32(&mut rng, n);
            let base = vec_f32(&mut rng, n);
            let mut got = base.clone();
            axpy(2.5, &x, &mut got);
            for i in 0..n {
                let want = base[i] + 2.5 * x[i];
                assert_eq!(got[i].to_bits(), want.to_bits(), "axpy n={n} i={i}");
            }
            let mut s = x.clone();
            scale(&mut s, 0.125);
            for i in 0..n {
                assert_eq!(s[i].to_bits(), (x[i] * 0.125).to_bits(), "scale n={n} i={i}");
            }
        }
    }

    #[test]
    fn dot_i8_matches_wide_reference() {
        let mut rng = SeededRng::new(19);
        for n in SIZES {
            let x = vec_i8(&mut rng, n);
            let y = vec_i8(&mut rng, n);
            let mut want = 0i64;
            for (&a, &b) in x.iter().zip(&y) {
                want += i64::from(a) * i64::from(b);
            }
            assert_eq!(i64::from(dot_i8_lanes(&x, &y)), want, "n={n}");
        }
    }

    #[test]
    fn unequal_lengths_use_common_prefix() {
        // dot and axpy zip to the shorter operand, matching the old
        // `iter().zip()` kernels they replaced.
        let x = [1.0f32, 2.0, 3.0];
        let y = [10.0f32, 20.0];
        assert_eq!(dot_lanes(&x, &y), 50.0);
        let mut out = [0.0f32; 2];
        axpy_lanes(1.0, &x, &mut out);
        assert_eq!(out, [1.0, 2.0]);
    }

    #[test]
    fn force_scalar_toggle_roundtrips() {
        let prior = scalar_forced();
        set_force_scalar(true);
        assert!(scalar_forced());
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let forced = dot(&x, &x);
        set_force_scalar(false);
        assert!(!scalar_forced());
        assert_eq!(dot(&x, &x).to_bits(), forced.to_bits());
        set_force_scalar(prior);
    }
}
