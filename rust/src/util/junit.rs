//! Minimal JUnit XML writer — the in-tree replacement for a reporting
//! crate in this offline, zero-dependency build.
//!
//! Emits the single-suite subset every CI system understands
//! (`<testsuite>` with `<testcase>` children, failures as `<failure>`
//! elements), so gates like the loadgen SLO smoke can publish a
//! machine-readable verdict via `actions/upload-artifact` next to their
//! human-readable logs.

use std::path::Path;

use crate::util::error::{Context, Result};

/// One test case: a named check with an optional failure message.
#[derive(Clone, Debug)]
pub struct JunitCase {
    /// Case name, e.g. `p99_slo`.
    pub name: String,
    /// Grouping label rendered as the JUnit `classname`.
    pub classname: String,
    /// Wall-clock seconds the check took (0.0 when not meaningful).
    pub time_s: f64,
    /// `Some(message)` marks the case failed.
    pub failure: Option<String>,
}

impl JunitCase {
    pub fn passed(name: impl Into<String>, classname: impl Into<String>, time_s: f64) -> Self {
        Self { name: name.into(), classname: classname.into(), time_s, failure: None }
    }

    pub fn failed(
        name: impl Into<String>,
        classname: impl Into<String>,
        time_s: f64,
        message: impl Into<String>,
    ) -> Self {
        Self {
            name: name.into(),
            classname: classname.into(),
            time_s,
            failure: Some(message.into()),
        }
    }
}

/// One `<testsuite>` of cases.
#[derive(Clone, Debug)]
pub struct JunitSuite {
    pub name: String,
    pub cases: Vec<JunitCase>,
}

impl JunitSuite {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), cases: Vec::new() }
    }

    pub fn push(&mut self, case: JunitCase) {
        self.cases.push(case);
    }

    /// Failed cases in the suite.
    pub fn failures(&self) -> usize {
        self.cases.iter().filter(|c| c.failure.is_some()).count()
    }

    /// Render the suite as a standalone JUnit XML document.
    pub fn to_xml(&self) -> String {
        let mut out = String::from("<?xml version=\"1.0\" encoding=\"UTF-8\"?>\n");
        out.push_str(&format!(
            "<testsuite name=\"{}\" tests=\"{}\" failures=\"{}\" errors=\"0\" skipped=\"0\">\n",
            escape(&self.name),
            self.cases.len(),
            self.failures(),
        ));
        for case in &self.cases {
            out.push_str(&format!(
                "  <testcase name=\"{}\" classname=\"{}\" time=\"{:.6}\"",
                escape(&case.name),
                escape(&case.classname),
                case.time_s,
            ));
            match &case.failure {
                None => out.push_str("/>\n"),
                Some(msg) => {
                    out.push_str(&format!(
                        ">\n    <failure message=\"{}\">{}</failure>\n  </testcase>\n",
                        escape(msg),
                        escape(msg),
                    ));
                }
            }
        }
        out.push_str("</testsuite>\n");
        out
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_xml())
            .with_context(|| format!("writing junit xml {}", path.display()))
    }
}

/// Escape the five XML-special characters (used in both attribute and
/// text position, so quotes are escaped too).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            '\'' => out.push_str("&apos;"),
            _ => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_passing_suite() {
        let mut suite = JunitSuite::new("slo-smoke");
        suite.push(JunitCase::passed("p99_slo", "loadgen", 1.25));
        suite.push(JunitCase::passed("shed_rate", "loadgen", 0.0));
        let xml = suite.to_xml();
        assert!(xml.starts_with("<?xml version=\"1.0\""), "{xml}");
        assert!(xml.contains("<testsuite name=\"slo-smoke\" tests=\"2\" failures=\"0\""), "{xml}");
        assert!(xml.contains("<testcase name=\"p99_slo\""), "{xml}");
        assert!(xml.contains("classname=\"loadgen\" time=\"1.250000\"/>"), "{xml}");
        assert!(!xml.contains("<failure"));
        assert!(xml.trim_end().ends_with("</testsuite>"));
    }

    #[test]
    fn failure_carries_message_and_count() {
        let mut suite = JunitSuite::new("slo-smoke");
        suite.push(JunitCase::failed("p99_slo", "loadgen", 2.0, "p99 81ms > SLO 50ms"));
        assert_eq!(suite.failures(), 1);
        let xml = suite.to_xml();
        assert!(xml.contains("failures=\"1\""), "{xml}");
        assert!(xml.contains("<failure message=\"p99 81ms &gt; SLO 50ms\">"), "{xml}");
        assert!(xml.contains("</testcase>"));
    }

    #[test]
    fn xml_specials_escaped_everywhere() {
        let mut suite = JunitSuite::new("a<b>&\"c\"'d'");
        suite.push(JunitCase::failed("n<&>", "c\"lass", 0.0, "<&\"'>"));
        let xml = suite.to_xml();
        assert!(xml.contains("name=\"a&lt;b&gt;&amp;&quot;c&quot;&apos;d&apos;\""), "{xml}");
        assert!(xml.contains("message=\"&lt;&amp;&quot;&apos;&gt;\""), "{xml}");
        assert!(!xml.contains("<&"), "raw specials must not survive: {xml}");
    }

    #[test]
    fn save_round_trips_through_disk() {
        let path = std::env::temp_dir().join(format!("cpsaa-junit-{}.xml", std::process::id()));
        let mut suite = JunitSuite::new("disk");
        suite.push(JunitCase::passed("case", "class", 0.5));
        suite.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, suite.to_xml());
        std::fs::remove_file(&path).ok();
    }
}
