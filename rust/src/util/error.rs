//! Minimal error plumbing — the in-tree replacement for `anyhow` in this
//! offline, zero-dependency build.
//!
//! Provides the same surface the crate uses: a string-backed [`Error`], a
//! defaulted [`Result`] alias, the [`Context`] extension trait for
//! `Result`/`Option`, and the crate-root `anyhow!` / `bail!` macros.

use std::fmt;

/// A boxed-free, message-carrying error. Context wraps prepend their
/// message, so chains render as `outer: inner` (the `{:#}` and `{}`
/// renderings are identical).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Self { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug renders the message too, so `unwrap()` panics and `fn main() ->
// Result<()>` exits stay readable.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`. `Error` itself does not implement
// `std::error::Error`, which is what keeps this blanket impl coherent
// (the same trick anyhow uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, as `anyhow::Context` does.
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(ctx))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] (the `anyhow!` of this build). Accepts a format
/// literal (with inline captures), a bare displayable expression, or a
/// format string plus arguments — the same three shapes as `anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "), "{e}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn macros_format() {
        let e = crate::anyhow!("bad value {}", 42);
        assert_eq!(format!("{e}"), "bad value 42");
        assert_eq!(format!("{e:?}"), "bad value 42");
        let n = 5;
        let captured = crate::anyhow!("n is {n}");
        assert_eq!(captured.to_string(), "n is 5");
        let plain = crate::anyhow!(String::from("plain message"));
        assert_eq!(plain.to_string(), "plain message");
        fn bails() -> Result<()> {
            crate::bail!("nope {}", 7)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 7");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert!(e.to_string().starts_with("step 3: "));
    }
}
