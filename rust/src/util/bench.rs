//! Bench timing harness (the `cargo bench` backend, criterion-style).
//!
//! Each `[[bench]]` target is a plain `main()` that calls [`Bencher::run`]
//! per measurement: warm-up, N timed iterations, median/mean/min reporting,
//! and a machine-readable line per benchmark for EXPERIMENTS.md capture.

use std::time::{Duration, Instant};

/// One benchmark group (one `[[bench]]` binary).
pub struct Bencher {
    group: &'static str,
    /// Timed iterations per measurement.
    pub iters: usize,
    /// Warm-up iterations.
    pub warmup: usize,
    results: Vec<(String, Duration)>,
}

impl Bencher {
    pub fn new(group: &'static str) -> Self {
        // Keep benches fast by default; BENCH_ITERS overrides.
        let iters = std::env::var("BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
        Self { group, iters, warmup: 2, results: Vec::new() }
    }

    /// Time `f`, report, and return its median duration.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Duration {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let min = samples[0];
        let max = *samples.last().unwrap();
        println!(
            "bench {:<40} median {:>12?}  min {:>12?}  max {:>12?}  ({} iters)",
            format!("{}/{}", self.group, name),
            median,
            min,
            max,
            self.iters
        );
        self.results.push((name.to_string(), median));
        median
    }

    /// Summary footer (total + per-bench medians as CSV-ish lines).
    pub fn finish(&self) {
        println!("-- {} done: {} benchmarks --", self.group, self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("test");
        b.iters = 3;
        b.warmup = 1;
        let d = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(d > Duration::ZERO);
        b.finish();
    }

    #[test]
    fn records_results() {
        let mut b = Bencher::new("test");
        b.iters = 1;
        b.warmup = 0;
        b.run("a", || 1);
        b.run("b", || 2);
        assert_eq!(b.results.len(), 2);
    }
}
