//! Bench timing harness (the `cargo bench` backend, criterion-style).
//!
//! Each `[[bench]]` target is a plain `main()` that calls [`Bencher::run`]
//! per measurement: warm-up, N timed iterations, median/mean/min reporting.
//! [`Bencher::finish`] additionally dumps every measurement as JSON under
//! `target/bench/<group>.json` (override the directory with `BENCH_JSON_DIR`)
//! so CI and EXPERIMENTS-style capture can diff numbers across commits.

use std::time::{Duration, Instant};

/// One measurement's summary.
#[derive(Clone, Debug)]
struct Sample {
    name: String,
    median: Duration,
    min: Duration,
    max: Duration,
}

/// One benchmark group (one `[[bench]]` binary).
pub struct Bencher {
    group: &'static str,
    /// Timed iterations per measurement.
    pub iters: usize,
    /// Warm-up iterations.
    pub warmup: usize,
    results: Vec<Sample>,
}

impl Bencher {
    pub fn new(group: &'static str) -> Self {
        // Keep benches fast by default; BENCH_ITERS overrides.
        let iters = std::env::var("BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
        Self { group, iters, warmup: 2, results: Vec::new() }
    }

    /// Time `f`, report, and return its median duration.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Duration {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let min = samples[0];
        let max = *samples.last().unwrap();
        println!(
            "bench {:<40} median {:>12?}  min {:>12?}  max {:>12?}  ({} iters)",
            format!("{}/{}", self.group, name),
            median,
            min,
            max,
            self.iters
        );
        self.results.push(Sample { name: name.to_string(), median, min, max });
        median
    }

    /// Median of a previously run measurement (post-hoc comparisons).
    pub fn median_of(&self, name: &str) -> Option<Duration> {
        self.results.iter().find(|s| s.name == name).map(|s| s.median)
    }

    /// Summary footer plus the JSON dump.
    pub fn finish(&self) {
        println!("-- {} done: {} benchmarks --", self.group, self.results.len());
        if let Err(e) = self.write_json() {
            eprintln!("(bench JSON not written: {e})");
        }
    }

    fn json_string(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"group\": {:?},\n", self.group));
        s.push_str(&format!("  \"iters\": {},\n", self.iters));
        s.push_str("  \"benchmarks\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {:?}, \"median_ns\": {}, \"min_ns\": {}, \"max_ns\": {}}}{}\n",
                r.name,
                r.median.as_nanos(),
                r.min.as_nanos(),
                r.max.as_nanos(),
                if i + 1 < self.results.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    fn write_json(&self) -> std::io::Result<()> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| "target/bench".to_string());
        std::fs::create_dir_all(&dir)?;
        let path = std::path::Path::new(&dir).join(format!("{}.json", self.group));
        std::fs::write(&path, self.json_string())?;
        println!("bench JSON: {}", path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("test");
        b.iters = 3;
        b.warmup = 1;
        let d = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(d > Duration::ZERO);
        assert_eq!(b.median_of("spin"), Some(d));
    }

    #[test]
    fn records_results() {
        let mut b = Bencher::new("test");
        b.iters = 1;
        b.warmup = 0;
        b.run("a", || 1);
        b.run("b", || 2);
        assert_eq!(b.results.len(), 2);
    }

    #[test]
    fn json_lists_every_benchmark() {
        let mut b = Bencher::new("jsontest");
        b.iters = 1;
        b.warmup = 0;
        b.run("first", || 1);
        b.run("second", || 2);
        let j = b.json_string();
        assert!(j.contains("\"group\": \"jsontest\""), "{j}");
        assert!(j.contains("\"first\"") && j.contains("\"second\""), "{j}");
        assert!(j.contains("median_ns"), "{j}");
        // valid for the in-tree JSON parser
        crate::util::json::Json::parse(&j).unwrap();
    }
}
