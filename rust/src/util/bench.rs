//! Bench timing harness (the `cargo bench` backend, criterion-style).
//!
//! Each `[[bench]]` target is a plain `main()` that calls [`Bencher::run`]
//! per measurement: warm-up, N timed iterations, median/mean/min reporting.
//! [`Bencher::finish`] additionally dumps every measurement as JSON under
//! `target/bench/<group>.json` (override the directory with `BENCH_JSON_DIR`)
//! so CI and EXPERIMENTS-style capture can diff numbers across commits.
//!
//! [`BenchComparison`] is the diff side: per-rung median ratios between
//! two such dumps, with a regression tolerance — the engine behind the
//! `cpsaa bench-compare` CI gate.

use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// One measurement's summary.
#[derive(Clone, Debug)]
struct Sample {
    name: String,
    median: Duration,
    min: Duration,
    max: Duration,
}

/// One benchmark group (one `[[bench]]` binary).
pub struct Bencher {
    group: &'static str,
    /// Timed iterations per measurement.
    pub iters: usize,
    /// Warm-up iterations.
    pub warmup: usize,
    results: Vec<Sample>,
}

impl Bencher {
    pub fn new(group: &'static str) -> Self {
        // Keep benches fast by default; BENCH_ITERS overrides.
        let iters = std::env::var("BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
        Self { group, iters, warmup: 2, results: Vec::new() }
    }

    /// Time `f`, report, and return its median duration.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Duration {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let min = samples[0];
        let max = *samples.last().unwrap();
        println!(
            "bench {:<40} median {:>12?}  min {:>12?}  max {:>12?}  ({} iters)",
            format!("{}/{}", self.group, name),
            median,
            min,
            max,
            self.iters
        );
        self.results.push(Sample { name: name.to_string(), median, min, max });
        median
    }

    /// Median of a previously run measurement (post-hoc comparisons).
    pub fn median_of(&self, name: &str) -> Option<Duration> {
        self.results.iter().find(|s| s.name == name).map(|s| s.median)
    }

    /// Summary footer plus the JSON dump.
    pub fn finish(&self) {
        println!("-- {} done: {} benchmarks --", self.group, self.results.len());
        if let Err(e) = self.write_json() {
            eprintln!("(bench JSON not written: {e})");
        }
    }

    fn json_string(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"group\": {:?},\n", self.group));
        s.push_str(&format!("  \"iters\": {},\n", self.iters));
        s.push_str("  \"benchmarks\": [\n");
        for (i, r) in self.results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": {:?}, \"median_ns\": {}, \"min_ns\": {}, \"max_ns\": {}}}{}\n",
                r.name,
                r.median.as_nanos(),
                r.min.as_nanos(),
                r.max.as_nanos(),
                if i + 1 < self.results.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    fn write_json(&self) -> std::io::Result<()> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| "target/bench".to_string());
        std::fs::create_dir_all(&dir)?;
        let path = std::path::Path::new(&dir).join(format!("{}.json", self.group));
        std::fs::write(&path, self.json_string())?;
        println!("bench JSON: {}", path.display());
        Ok(())
    }
}

/// One rung's baseline-vs-current comparison.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    /// Baseline median: `None` when the rung is new (absent from the
    /// baseline), `Some(0)` for a *seeded* entry (committed placeholder
    /// recorded before any CI run) — both listed, neither compared.
    pub baseline_ns: Option<u64>,
    pub current_ns: u64,
    /// `current / baseline`; `None` for seeded or new rungs.
    pub ratio: Option<f64>,
    pub regressed: bool,
}

/// The per-rung diff of two bench JSON dumps. Rules:
///
/// * rung in both, baseline > 0 → ratio compared against `tolerance`
///   (fail when `current > tolerance × baseline`);
/// * baseline median 0 → "seed" (pass; the committed cold-start
///   baseline has no machine-specific numbers to hold against);
/// * rung only in current → "new" (pass);
/// * rung only in baseline → listed in `missing` (warned, not failed —
///   renames would otherwise block the PR that makes them).
#[derive(Clone, Debug)]
pub struct BenchComparison {
    pub deltas: Vec<BenchDelta>,
    /// Rungs present in the baseline but absent from the current dump.
    pub missing: Vec<String>,
    pub tolerance: f64,
}

impl BenchComparison {
    /// Compare two dump files produced by [`Bencher::finish`] (or a
    /// committed baseline in the same format).
    pub fn from_files(
        baseline: &std::path::Path,
        current: &std::path::Path,
        tolerance: f64,
    ) -> Result<Self> {
        let base = std::fs::read_to_string(baseline)
            .with_context(|| format!("reading baseline {}", baseline.display()))?;
        let cur = std::fs::read_to_string(current)
            .with_context(|| format!("reading current {}", current.display()))?;
        Self::from_json(&base, &cur, tolerance)
    }

    /// Compare two dump strings.
    pub fn from_json(baseline: &str, current: &str, tolerance: f64) -> Result<Self> {
        if !tolerance.is_finite() || tolerance <= 0.0 {
            crate::bail!("tolerance must be positive, got {tolerance}");
        }
        let base = parse_medians(baseline).context("parsing baseline bench JSON")?;
        let cur = parse_medians(current).context("parsing current bench JSON")?;
        let mut deltas = Vec::with_capacity(cur.len());
        for (name, current_ns) in &cur {
            let baseline_ns = base.iter().find(|(n, _)| n == name).map(|(_, m)| *m);
            let ratio = match baseline_ns {
                Some(b) if b > 0 => Some(*current_ns as f64 / b as f64),
                _ => None,
            };
            deltas.push(BenchDelta {
                name: name.clone(),
                baseline_ns,
                current_ns: *current_ns,
                ratio,
                regressed: ratio.is_some_and(|r| r > tolerance),
            });
        }
        let missing = base
            .iter()
            .filter(|(n, _)| !cur.iter().any(|(c, _)| c == n))
            .map(|(n, _)| n.clone())
            .collect();
        Ok(Self { deltas, missing, tolerance })
    }

    /// Rungs that regressed beyond the tolerance.
    pub fn regressions(&self) -> Vec<&BenchDelta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// The comparison as a markdown table (lands in the CI job summary).
    pub fn markdown(&self) -> String {
        let mut s = String::from("### Bench regression gate\n\n");
        s.push_str(&format!("tolerance: fail when current > {}× baseline\n\n", self.tolerance));
        s.push_str("| rung | baseline | current | ratio | status |\n");
        s.push_str("|---|---:|---:|---:|---|\n");
        for d in &self.deltas {
            let (ratio, status) = match (d.ratio, d.baseline_ns) {
                (Some(r), _) if d.regressed => (format!("{r:.2}x"), "**REGRESSED**"),
                (Some(r), _) => (format!("{r:.2}x"), "ok"),
                (None, Some(_)) => ("–".to_string(), "seed"),
                (None, None) => ("–".to_string(), "new"),
            };
            s.push_str(&format!(
                "| {} | {} | {} | {ratio} | {status} |\n",
                d.name,
                fmt_ns(d.baseline_ns.unwrap_or(0)),
                fmt_ns(d.current_ns),
            ));
        }
        for name in &self.missing {
            s.push_str(&format!("| {name} | – | – | – | missing from current |\n"));
        }
        s
    }
}

fn fmt_ns(ns: u64) -> String {
    if ns == 0 {
        "–".into()
    } else if ns >= 1_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// The outcome of one same-run rung ordering check
/// ([`assert_faster`]) — the engine of the `cpsaa bench-assert-faster`
/// CI gate (e.g. the fused rung must beat the unfused rung).
#[derive(Clone, Debug)]
pub struct FasterCheck {
    pub fast: String,
    pub slow: String,
    pub fast_ns: u64,
    pub slow_ns: u64,
}

impl FasterCheck {
    /// `slow / fast` speedup (∞-safe: 0-ns medians compare as-is).
    pub fn speedup(&self) -> f64 {
        self.slow_ns as f64 / (self.fast_ns as f64).max(1.0)
    }

    /// Strict ordering: `fast` median below `slow` median.
    pub fn holds(&self) -> bool {
        self.holds_within(1.0)
    }

    /// Ordering with a noise margin: passes while `fast < slow ×
    /// margin`. A margin slightly above 1.0 keeps the gate robust on
    /// rungs whose two sides share a large common cost (e.g. the dense
    /// projections of an encoder layer) and differ by only a few
    /// percent — runner jitter must not fail an unrelated PR.
    pub fn holds_within(&self, margin: f64) -> bool {
        (self.fast_ns as f64) < self.slow_ns as f64 * margin
    }
}

/// Compare two rungs of one bench JSON dump: `fast` must have a
/// strictly smaller median than `slow`. Unlike [`BenchComparison`] this
/// is a *same-machine, same-run* comparison, so no tolerance applies —
/// an optimization that cannot beat its own baseline in its own run has
/// regressed.
pub fn assert_faster(json: &str, fast: &str, slow: &str) -> Result<FasterCheck> {
    let medians = parse_medians(json).context("parsing bench JSON")?;
    let find = |name: &str| -> Result<u64> {
        medians
            .iter()
            .find(|(n, _)| n.as_str() == name)
            .map(|&(_, m)| m)
            .ok_or_else(|| crate::anyhow!("rung {name:?} not in dump"))
    };
    Ok(FasterCheck {
        fast: fast.to_string(),
        slow: slow.to_string(),
        fast_ns: find(fast)?,
        slow_ns: find(slow)?,
    })
}

/// Pull `(name, median_ns)` pairs out of a [`Bencher::finish`]-format
/// dump, dump order preserved.
fn parse_medians(text: &str) -> Result<Vec<(String, u64)>> {
    let root = Json::parse(text)?;
    let benches = root.get("benchmarks")?.as_arr()?;
    let mut out = Vec::with_capacity(benches.len());
    for b in benches {
        let name = b.get("name")?.as_str()?.to_string();
        let median = b.get("median_ns")?.as_usize()? as u64;
        out.push((name, median));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("test");
        b.iters = 3;
        b.warmup = 1;
        let d = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(d > Duration::ZERO);
        assert_eq!(b.median_of("spin"), Some(d));
    }

    #[test]
    fn records_results() {
        let mut b = Bencher::new("test");
        b.iters = 1;
        b.warmup = 0;
        b.run("a", || 1);
        b.run("b", || 2);
        assert_eq!(b.results.len(), 2);
    }

    fn dump(entries: &[(&str, u64)]) -> String {
        let mut b = String::from("{\"group\": \"t\", \"iters\": 3, \"benchmarks\": [");
        for (i, (name, median)) in entries.iter().enumerate() {
            if i > 0 {
                b.push(',');
            }
            b.push_str(&format!("{{\"name\": {name:?}, \"median_ns\": {median}}}"));
        }
        b.push_str("]}");
        b
    }

    #[test]
    fn assert_faster_orders_rungs() {
        let cur = dump(&[("fused", 1000), ("unfused", 2500)]);
        let ok = assert_faster(&cur, "fused", "unfused").unwrap();
        assert!(ok.holds());
        assert!((ok.speedup() - 2.5).abs() < 1e-9);
        let bad = assert_faster(&cur, "unfused", "fused").unwrap();
        assert!(!bad.holds());
        assert!(assert_faster(&cur, "fused", "nope").is_err());
        // margin absorbs a small inversion, strict does not
        let close = dump(&[("a", 1010), ("b", 1000)]);
        let c = assert_faster(&close, "a", "b").unwrap();
        assert!(!c.holds());
        assert!(c.holds_within(1.02));
        assert!(!c.holds_within(1.005));
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = dump(&[("a", 1000), ("b", 2000)]);
        let cur = dump(&[("a", 1200), ("b", 1900)]);
        let cmp = BenchComparison::from_json(&base, &cur, 1.25).unwrap();
        assert!(cmp.regressions().is_empty(), "{:?}", cmp.deltas);
        assert_eq!(cmp.deltas.len(), 2);
        assert!((cmp.deltas[0].ratio.unwrap() - 1.2).abs() < 1e-9);
        assert!(cmp.markdown().contains("| a |"));
        assert!(cmp.markdown().contains("ok"));
    }

    #[test]
    fn compare_fails_beyond_tolerance() {
        let base = dump(&[("fast", 1000), ("slow", 1000)]);
        let cur = dump(&[("fast", 1100), ("slow", 1500)]);
        let cmp = BenchComparison::from_json(&base, &cur, 1.25).unwrap();
        let regs = cmp.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "slow");
        assert!(cmp.markdown().contains("REGRESSED"), "{}", cmp.markdown());
    }

    #[test]
    fn seeded_and_new_rungs_pass() {
        // A committed cold-start baseline seeds every rung at 0; a new
        // rung is absent entirely. Neither may fail the gate.
        let base = dump(&[("seeded", 0), ("gone", 500)]);
        let cur = dump(&[("seeded", 123456), ("fresh", 999)]);
        let cmp = BenchComparison::from_json(&base, &cur, 1.25).unwrap();
        assert!(cmp.regressions().is_empty());
        assert_eq!(cmp.missing, vec!["gone".to_string()]);
        let md = cmp.markdown();
        assert!(md.contains("seed"), "{md}");
        assert!(md.contains("new"), "{md}");
        assert!(md.contains("missing from current"), "{md}");
    }

    #[test]
    fn compare_round_trips_real_dump_format() {
        // The comparison must parse exactly what Bencher::finish writes.
        let mut b = Bencher::new("rt");
        b.iters = 1;
        b.warmup = 0;
        b.run("x", || 1);
        let j = b.json_string();
        let cmp = BenchComparison::from_json(&j, &j, 1.25).unwrap();
        assert_eq!(cmp.deltas.len(), 1);
        // identical dumps: either ratio 1.0 or seeded (a 0 ns median on
        // a fast machine)
        let d = &cmp.deltas[0];
        assert!(!d.regressed);
        if let Some(r) = d.ratio {
            assert!((r - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn bad_tolerance_rejected() {
        let base = dump(&[("a", 1)]);
        assert!(BenchComparison::from_json(&base, &base, 0.0).is_err());
        assert!(BenchComparison::from_json(&base, &base, f64::NAN).is_err());
        assert!(BenchComparison::from_json("not json", &base, 1.25).is_err());
    }

    #[test]
    fn json_lists_every_benchmark() {
        let mut b = Bencher::new("jsontest");
        b.iters = 1;
        b.warmup = 0;
        b.run("first", || 1);
        b.run("second", || 2);
        let j = b.json_string();
        assert!(j.contains("\"group\": \"jsontest\""), "{j}");
        assert!(j.contains("\"first\"") && j.contains("\"second\""), "{j}");
        assert!(j.contains("median_ns"), "{j}");
        // valid for the in-tree JSON parser
        crate::util::json::Json::parse(&j).unwrap();
    }
}
