//! The TOML subset used by `configs/*.toml`: `[section]` / `[[array]]`
//! headers, `key = value` with string / number / boolean values, `#`
//! comments. No dotted keys, no inline tables, no multi-line strings.

use std::collections::BTreeMap;

use crate::bail;
use crate::util::error::Result;

/// A scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a boolean: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }
}

/// One `key = value` table.
pub type Section = BTreeMap<String, Value>;

/// A parsed document: top-level keys in `""`, `[name]` sections, and
/// repeated `[[name]]` array-of-table entries.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, Section>,
    pub arrays: BTreeMap<String, Vec<Section>>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        enum Target {
            Plain(String),
            Array(String),
        }
        let mut current = Target::Plain(String::new());
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                doc.arrays.entry(name.clone()).or_default().push(Section::new());
                current = Target::Array(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                current = Target::Plain(name.trim().to_string());
            } else if let Some((key, val)) = line.split_once('=') {
                let key = key.trim().to_string();
                let value = parse_value(val.trim())
                    .map_err(|e| crate::anyhow!("line {}: {e}", lineno + 1))?;
                match &current {
                    Target::Plain(name) => {
                        doc.sections.entry(name.clone()).or_default().insert(key, value);
                    }
                    Target::Array(name) => {
                        doc.arrays
                            .get_mut(name)
                            .and_then(|v| v.last_mut())
                            .expect("array entry exists")
                            .insert(key, value);
                    }
                }
            } else {
                bail!("line {}: cannot parse {raw:?}", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.get(name)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(n) = s.parse::<f64>() {
        return Ok(Value::Num(n));
    }
    bail!("bad value {s:?}")
}

/// Serializer helper: write one section.
pub fn write_section(out: &mut String, name: &str, entries: &[(&str, Value)]) {
    if !name.is_empty() {
        out.push_str(&format!("[{name}]\n"));
    }
    for (k, v) in entries {
        match v {
            Value::Str(s) => out.push_str(&format!("{k} = \"{s}\"\n")),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{k} = {}\n", *n as i64));
                } else {
                    out.push_str(&format!("{k} = {n}\n"));
                }
            }
            Value::Bool(b) => out.push_str(&format!("{k} = {b}\n")),
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_arrays() {
        let doc = Doc::parse(
            r#"
# comment
top = 1

[hardware]
tiles = 64        # trailing comment
cycle_ns = 25.0
name = "paper"
ideal = false

[[workload.datasets]]
name = "CoLA"
sequences = 1043

[[workload.datasets]]
name = "SST-2"
sequences = 872
"#,
        )
        .unwrap();
        assert_eq!(doc.section("").unwrap()["top"], Value::Num(1.0));
        let hw = doc.section("hardware").unwrap();
        assert_eq!(hw["tiles"].as_usize().unwrap(), 64);
        assert_eq!(hw["cycle_ns"].as_f64().unwrap(), 25.0);
        assert_eq!(hw["name"].as_str().unwrap(), "paper");
        assert!(!hw["ideal"].as_bool().unwrap());
        let ds = &doc.arrays["workload.datasets"];
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[1]["name"].as_str().unwrap(), "SST-2");
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Doc::parse("[s]\nk = \"a#b\"\n").unwrap();
        assert_eq!(doc.section("s").unwrap()["k"].as_str().unwrap(), "a#b");
    }

    #[test]
    fn bad_lines_error() {
        assert!(Doc::parse("[s]\nnonsense line\n").is_err());
        assert!(Doc::parse("[s]\nk = @@\n").is_err());
    }

    #[test]
    fn write_then_parse() {
        let mut s = String::new();
        write_section(
            &mut s,
            "model",
            &[("seq_len", Value::Num(320.0)), ("theta", Value::Num(0.01)), ("name", Value::Str("x".into()))],
        );
        let doc = Doc::parse(&s).unwrap();
        assert_eq!(doc.section("model").unwrap()["seq_len"].as_usize().unwrap(), 320);
    }
}
