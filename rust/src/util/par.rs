//! Scoped parallel map — the one fan-out primitive the head-parallel
//! paths share (per-head mask scans, per-head pruning, per-head
//! attention kernels).
//!
//! One scoped worker per item, order-preserving. A single item runs on
//! the calling thread, so 1-item maps are bit- and schedule-identical
//! to a plain serial call — the invariant the heads = 1 equivalence
//! tests rely on. Item counts here are head counts (≤ ~16), so one
//! thread per item is the right granularity; the kernels inside each
//! worker do their own nnz-balanced splitting.

/// Map `f` over `items` with one scoped thread per item (serial when
/// `items.len() <= 1`), preserving order. Propagates worker panics.
pub fn par_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    if items.len() <= 1 {
        return items.iter().map(f).collect();
    }
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = items.iter().map(|it| scope.spawn(move || f(it))).collect();
        handles.into_iter().map(|h| h.join().expect("par_map worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<usize> = (0..8).collect();
        let out = par_map(&items, |&x| x * x);
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn single_item_runs_serially() {
        let out = par_map(&[7usize], |&x| x + 1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn empty_is_empty() {
        let items: [u32; 0] = [];
        assert!(par_map(&items, |&x| x).is_empty());
    }

    #[test]
    #[should_panic(expected = "par_map worker panicked")]
    fn worker_panic_propagates() {
        par_map(&[1, 2], |_| panic!("boom"));
    }
}
