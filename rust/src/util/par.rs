//! Scoped parallel map — now a thin shim over the crate-wide persistent
//! [`Executor`][crate::runtime::executor::Executor] pool.
//!
//! Historically this spawned one scoped OS thread per item at every
//! call; the executor runtime replaced that model with one long-lived
//! worker pool and a flat task queue (see `runtime::executor`), so this
//! shim exists only to keep the familiar call shape for head-parallel
//! paths (per-head mask scans, per-head pruning, per-head attention
//! kernels).
//!
//! The serial contract is unchanged: a single item runs on the calling
//! thread, so 1-item maps are bit- and schedule-identical to a plain
//! serial call — the invariant the heads = 1 equivalence tests rely on.
//! Larger maps claim tasks from the shared pool (the submitting thread
//! participates), and nested maps flatten into the same pool instead of
//! multiplying threads.

/// Map `f` over `items` on the global executor pool (serial when
/// `items.len() <= 1`), preserving order. Propagates task panics with
/// the claiming worker's index in the message.
pub fn par_map<T: Sync, R: Send>(items: &[T], f: impl Fn(&T) -> R + Sync) -> Vec<R> {
    crate::runtime::executor::global().map(items, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<usize> = (0..8).collect();
        let out = par_map(&items, |&x| x * x);
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn single_item_runs_serially() {
        let out = par_map(&[7usize], |&x| x + 1);
        assert_eq!(out, vec![8]);
    }

    #[test]
    fn empty_is_empty() {
        let items: [u32; 0] = [];
        assert!(par_map(&items, |&x| x).is_empty());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        // The executor wraps parallel-path panics with the worker index;
        // the serial path (a 1-worker global pool, e.g. under
        // CPSAA_MAX_KERNEL_WORKERS=1) re-raises the payload as-is.
        // Either way the original message survives.
        par_map(&[1, 2], |_| panic!("boom"));
    }
}
