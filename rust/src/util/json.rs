//! Minimal JSON: enough to read aot.py's manifest/weights/fixtures and to
//! write result files. Full string escaping, f64 numbers, no streaming.

use std::collections::BTreeMap;
use std::fmt;

use crate::util::error::Result;
use crate::{anyhow, bail};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Parse an array of numbers into f32s (the weights/fixture payloads).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {} (found {:?})", b as char, self.pos, self.peek().map(|c| c as char))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow!("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            self.pos += 4;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("unknown escape \\{}", other as char),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] (found {:?})", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} (found {:?})", other.map(|c| c as char)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\n", "c": {"d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\n");
        assert_eq!(v.get("c").unwrap().get("d").unwrap(), &Json::Bool(true));
        // serialize → parse → same
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("3.25").unwrap().as_f64().unwrap(), 3.25);
        assert_eq!(Json::parse("-7").unwrap().as_f64().unwrap(), -7.0);
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn f32_vec() {
        let v = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
