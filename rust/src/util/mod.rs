//! In-tree replacements for the usual ecosystem crates.
//!
//! The build environment is fully offline (only the image-vendored crates
//! resolve), so the small amounts of infrastructure the coordinator needs
//! are implemented here:
//!
//! * [`json`] — minimal JSON parser/serializer for the artifact manifest,
//!   weights, and fixtures (`aot.py` emits plain JSON).
//! * [`tomlmini`] — the TOML subset the config files use (tables,
//!   key = value scalars, inline arrays of tables are not needed).
//! * [`bench`] — the timing harness behind `cargo bench` (median-of-runs
//!   with warm-up, criterion-style output).
//! * [`prop`] — a tiny property-testing driver over the deterministic RNG
//!   (N random cases + failure seed reporting).

pub mod bench;
pub mod json;
pub mod prop;
pub mod tomlmini;
