//! In-tree replacements for the usual ecosystem crates.
//!
//! The build environment is fully offline (no crates.io), so the small
//! amounts of infrastructure the coordinator needs are implemented here:
//!
//! * [`error`] — `anyhow`-shaped error/result plumbing (`Error`,
//!   `Result`, `Context`, and the crate-root `anyhow!`/`bail!` macros).
//! * [`json`] — minimal JSON parser/serializer for the artifact manifest,
//!   weights, and fixtures (`aot.py` emits plain JSON).
//! * [`tomlmini`] — the TOML subset the config files use (tables,
//!   key = value scalars, inline arrays of tables are not needed).
//! * [`bench`] — the timing harness behind `cargo bench` (median-of-runs
//!   with warm-up, criterion-style output plus a machine-readable JSON
//!   dump under `target/bench/`).
//! * [`prop`] — a tiny property-testing driver over the deterministic RNG
//!   (N random cases + failure seed reporting).
//! * [`par`] — scoped parallel map (one worker per item) shared by the
//!   per-head fan-out paths.
//! * [`junit`] — minimal JUnit XML writer so CI gates (the loadgen SLO
//!   smoke) publish machine-readable pass/fail artifacts.

pub mod bench;
pub mod error;
pub mod json;
pub mod junit;
pub mod par;
pub mod prop;
pub mod tomlmini;
