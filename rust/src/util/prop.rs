//! Tiny property-testing driver: N seeded random cases, first-failure
//! seed reported so a case can be replayed deterministically.

use crate::tensor::SeededRng;

/// Number of cases per property (PROP_CASES env overrides).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// Run `prop` over `cases` seeds; panics with the failing seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut SeededRng) -> Result<(), String>) {
    for seed in 0..cases {
        let mut rng = SeededRng::new(0x9e3779b97f4a7c15 ^ seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed at seed {seed}: {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("always-true", 16, |rng| {
            let x = rng.uniform();
            if (0.0..=1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property demo failed")]
    fn failing_property_panics_with_seed() {
        check("demo", 4, |_| Err("boom".into()));
    }
}
