//! `cpsaa` — CLI entrypoint of the CPSAA reproduction.
//!
//! Subcommands:
//! * `info`          — chip configuration, area/power budget, artifact status
//! * `simulate`      — run the cycle simulator over GLUE/SQuAD traces
//! * `bench-figure`  — regenerate any paper figure/table (or `all`)
//! * `serve`         — demo serving loop over the artifact engine
//! * `check`         — load artifacts and verify engine numerics vs fixtures
//!
//! Argument parsing is hand-rolled (offline build, no clap): global flags
//! `--config <toml>` and `--artifacts <dir>` precede the subcommand.

use std::path::{Path, PathBuf};

use cpsaa::util::error::Result;
use cpsaa::{anyhow, bail};

use cpsaa::attention::{Precision, Weights};
use cpsaa::bench_harness;
use cpsaa::config::{ModelConfig, SystemConfig};
use cpsaa::coordinator::{ServeHooks, Service, ServiceConfig};
use cpsaa::runtime::{ArtifactSet, Engine};
use cpsaa::sim::area::AreaModel;
use cpsaa::sim::ChipSim;
use cpsaa::sparse::PruneConfig;
use cpsaa::tensor::SeededRng;
use cpsaa::workload::capture::{Capture, CaptureConfig, CaptureRecorder, ReplayOverrides, SimTracer};
use cpsaa::workload::TraceGenerator;

const USAGE: &str = "\
cpsaa — CPSAA crossbar-PIM sparse attention accelerator (reproduction)

USAGE: cpsaa [--config FILE] [--artifacts DIR] <command> [args]

COMMANDS:
  info                              chip configuration + Table 2 budget
  simulate [DATASET] [--batches N] [--exact-masks]
                                    cycle-simulate GLUE/SQuAD traces (default: all)
  bench-figure ID [--out-dir DIR]   regenerate a paper figure/table
                                    (fig3, table2, fig11..fig18, fig19a/b, fig20a/b, all)
  serve [--requests N] [--layers N] [--heads N] [--shards N] [--leaders N]
        [--max-workers N] [--queue-cap N] [--precision f32|i8]
        [--prune static|cascade:K1,K2,...] [--force-scalar]
        [--prefetch on|off] [--record FILE] [--trace FILE]
                                    demo serving loop over the artifact engine
                                    (multi-head fan-out across tile slices;
                                    --shards N fans each batch across N logical
                                    chips, rows nnz-balanced from the plan set;
                                    --leaders N batches in N parallel leader
                                    threads feeding one executor pool;
                                    --precision i8 quantizes the SDDMM score
                                    dots to i8 storage / i32 accumulation;
                                    --prune cascade:K1,K2,... scans masks once
                                    at layer 0 and derives deeper layers' plans
                                    by score-driven top-k narrowing, applying
                                    the per-layer keep schedule (the last entry
                                    repeats for deeper layers; a single K
                                    applies everywhere; cascade:1.0 == static,
                                    bit-identical);
                                    --prefetch on|off (default on) overlaps
                                    each sealed batch's mask generation + plan
                                    scan with the previous batch's execution
                                    and serves repeated payloads from a
                                    content-addressed plan cache — responses
                                    are bit-identical either way;
                                    --force-scalar pins the scalar twins of
                                    the SIMD row primitives, like the
                                    CPSAA_FORCE_SCALAR env var;
                                    --queue-cap N bounds the admission queue
                                    (excess live requests shed, default 1024);
                                    --record FILE captures every admitted batch
                                    + the full serving config for `replay`;
                                    --trace FILE dumps per-batch simulated
                                    stage timelines as JSON)
  loadgen [--seed N] [--rps R] [--duration S] [--deadline-ms MS]
          [--interactive F] [--concurrency N] [--layers N] [--heads N]
          [--shards N] [--leaders N] [--max-workers N] [--queue-cap N]
          [--prune static|cascade:K1,K2,...] [--prefetch on|off]
          [--slo-p99-ms MS] [--json] [--junit FILE]
                                    seeded load generator over the artifact
                                    engine. Open loop by default: Poisson
                                    arrivals at R rps for S seconds (same
                                    --seed, same schedule); --concurrency N
                                    switches to closed loop — the same seeded
                                    request stream with a fixed N requests in
                                    flight instead of a fixed offered rate.
                                    --interactive F marks that fraction of
                                    requests high-lane, --deadline-ms sheds
                                    requests not packed in time; per-request
                                    CSV to stdout (one JSON document instead
                                    with --json), progress + summary to
                                    stderr; --junit FILE writes a JUnit XML
                                    verdict; exits nonzero if p99 exceeds
                                    --slo-p99-ms or any request fails
  replay FILE [--max-workers N] [--leaders N] [--shards N]
              [--prefetch on|off] [--trace FILE]
                                    re-serve a `serve --record` capture and
                                    assert byte-identical responses; topology
                                    and prefetch overrides exercise the
                                    determinism contract (outputs must not
                                    change by a bit at any worker/leader/
                                    shard count, prefetch on or off)
  synth-artifacts DIR [--seed N]    synthesize a serving artifact set from the
                                    [model] config (no Python/JAX needed)
  inference [DATASET] [--layers N] [--heads N]
                                    application-level sim: encoders = attention
                                    + FC (+ DTC hops) + endurance estimate
  sweep PARAM V1 V2 ...             sweep one hardware knob over `simulate`
                                    (crossbar_size | tiles | adcs_per_ag | wea_per_tile)
  check                             verify artifacts reproduce the JAX fixtures
  bench-compare BASELINE CURRENT [--tolerance R]
                                    compare two bench JSON dumps by per-rung
                                    median; exit nonzero on > R regression
                                    (default 1.25; the CI regression gate)
  bench-assert-faster JSON FAST SLOW [--margin R]
                                    assert rung FAST's median beats rung SLOW
                                    in one dump (same-run ordering gate, e.g.
                                    fused vs unfused; pass while FAST < R x
                                    SLOW, default R = 1.0 i.e. strict)
";

struct Args {
    config: Option<PathBuf>,
    artifacts: PathBuf,
    cmd: Vec<String>,
}

fn parse_args() -> Result<Args> {
    let mut config = None;
    let mut artifacts = PathBuf::from("artifacts");
    let mut cmd = Vec::new();
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--config" => config = Some(PathBuf::from(it.next().ok_or_else(|| anyhow!("--config needs a value"))?)),
            "--artifacts" => {
                artifacts = PathBuf::from(it.next().ok_or_else(|| anyhow!("--artifacts needs a value"))?)
            }
            "-h" | "--help" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            _ => cmd.push(a),
        }
    }
    Ok(Args { config, artifacts, cmd })
}

/// Pull `--flag value` out of a subcommand arg list.
fn take_flag(args: &mut Vec<String>, flag: &str) -> Option<String> {
    let idx = args.iter().position(|a| a == flag)?;
    if idx + 1 < args.len() {
        let v = args.remove(idx + 1);
        args.remove(idx);
        Some(v)
    } else {
        args.remove(idx);
        None
    }
}

/// Pull a boolean `--flag` out of a subcommand arg list.
fn take_switch(args: &mut Vec<String>, flag: &str) -> bool {
    if let Some(idx) = args.iter().position(|a| a == flag) {
        args.remove(idx);
        true
    } else {
        false
    }
}

/// Pull `--prefetch on|off` out of a subcommand arg list.
fn take_prefetch(args: &mut Vec<String>) -> Result<Option<bool>> {
    match take_flag(args, "--prefetch") {
        None => Ok(None),
        Some(s) => match s.as_str() {
            "on" => Ok(Some(true)),
            "off" => Ok(Some(false)),
            other => Err(anyhow!("--prefetch must be on or off, got {other:?}")),
        },
    }
}

fn main() -> Result<()> {
    let args = parse_args()?;
    let cfg = match &args.config {
        Some(p) => SystemConfig::from_toml_file(p)?,
        None => SystemConfig::paper(),
    };
    let mut cmd = args.cmd.clone();
    if cmd.is_empty() {
        print!("{USAGE}");
        bail!("no command given");
    }
    let verb = cmd.remove(0);
    match verb.as_str() {
        "info" => info(&cfg, &args.artifacts),
        "simulate" => {
            let batches = take_flag(&mut cmd, "--batches")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(2);
            let exact = take_switch(&mut cmd, "--exact-masks");
            let dataset = cmd.first().cloned().unwrap_or_else(|| "all".into());
            simulate(&cfg, &dataset, batches, exact)
        }
        "bench-figure" => {
            let out_dir = take_flag(&mut cmd, "--out-dir").map(PathBuf::from);
            let id = cmd.first().cloned().ok_or_else(|| anyhow!("bench-figure needs an id"))?;
            bench_figure(&cfg, &id, out_dir.as_deref())
        }
        "serve" => {
            let requests = take_flag(&mut cmd, "--requests")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(32);
            let layers = take_flag(&mut cmd, "--layers")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(2);
            let heads = take_flag(&mut cmd, "--heads")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(cfg.model.heads);
            let shards = take_flag(&mut cmd, "--shards")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(1);
            let leaders = take_flag(&mut cmd, "--leaders")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(1);
            let max_workers = take_flag(&mut cmd, "--max-workers")
                .map(|s| s.parse::<usize>())
                .transpose()?;
            let precision = match take_flag(&mut cmd, "--precision") {
                Some(s) => s
                    .parse::<Precision>()
                    .map_err(|e| anyhow!("--precision: {e}"))?,
                None => Precision::F32,
            };
            let queue_cap = take_flag(&mut cmd, "--queue-cap")
                .map(|s| s.parse::<usize>())
                .transpose()?;
            let prune = match take_flag(&mut cmd, "--prune") {
                Some(s) => s
                    .parse::<PruneConfig>()
                    .map_err(|e| anyhow!("--prune: {e}"))?,
                None => PruneConfig::Static,
            };
            let force_scalar = take_switch(&mut cmd, "--force-scalar");
            let prefetch = take_prefetch(&mut cmd)?;
            let record = take_flag(&mut cmd, "--record").map(PathBuf::from);
            let trace = take_flag(&mut cmd, "--trace").map(PathBuf::from);
            serve(
                &cfg,
                &args.artifacts,
                requests,
                layers,
                heads,
                shards,
                leaders,
                max_workers,
                queue_cap,
                precision,
                prune,
                force_scalar,
                prefetch,
                record,
                trace,
            )
        }
        "loadgen" => {
            let opts = LoadgenCli {
                seed: take_flag(&mut cmd, "--seed")
                    .map(|s| s.parse::<u64>())
                    .transpose()?
                    .unwrap_or(7),
                rps: take_flag(&mut cmd, "--rps")
                    .map(|s| s.parse::<f64>())
                    .transpose()?
                    .unwrap_or(200.0),
                duration_s: take_flag(&mut cmd, "--duration")
                    .map(|s| s.parse::<f64>())
                    .transpose()?
                    .unwrap_or(2.0),
                deadline_ms: take_flag(&mut cmd, "--deadline-ms")
                    .map(|s| s.parse::<u64>())
                    .transpose()?,
                interactive: take_flag(&mut cmd, "--interactive")
                    .map(|s| s.parse::<f64>())
                    .transpose()?
                    .unwrap_or(0.0),
                concurrency: take_flag(&mut cmd, "--concurrency")
                    .map(|s| s.parse::<usize>())
                    .transpose()?,
                layers: take_flag(&mut cmd, "--layers")
                    .map(|s| s.parse::<usize>())
                    .transpose()?
                    .unwrap_or(2),
                heads: take_flag(&mut cmd, "--heads")
                    .map(|s| s.parse::<usize>())
                    .transpose()?
                    .unwrap_or(cfg.model.heads),
                shards: take_flag(&mut cmd, "--shards")
                    .map(|s| s.parse::<usize>())
                    .transpose()?
                    .unwrap_or(1),
                leaders: take_flag(&mut cmd, "--leaders")
                    .map(|s| s.parse::<usize>())
                    .transpose()?
                    .unwrap_or(1),
                max_workers: take_flag(&mut cmd, "--max-workers")
                    .map(|s| s.parse::<usize>())
                    .transpose()?,
                queue_cap: take_flag(&mut cmd, "--queue-cap")
                    .map(|s| s.parse::<usize>())
                    .transpose()?,
                prune: match take_flag(&mut cmd, "--prune") {
                    Some(s) => s
                        .parse::<PruneConfig>()
                        .map_err(|e| anyhow!("--prune: {e}"))?,
                    None => PruneConfig::Static,
                },
                slo_p99_ms: take_flag(&mut cmd, "--slo-p99-ms")
                    .map(|s| s.parse::<f64>())
                    .transpose()?,
                prefetch: take_prefetch(&mut cmd)?,
                json: take_switch(&mut cmd, "--json"),
                junit: take_flag(&mut cmd, "--junit").map(PathBuf::from),
            };
            loadgen(&cfg, &args.artifacts, opts)
        }
        "replay" => {
            let overrides = ReplayOverrides {
                max_workers: take_flag(&mut cmd, "--max-workers")
                    .map(|s| s.parse::<usize>())
                    .transpose()?,
                leaders: take_flag(&mut cmd, "--leaders")
                    .map(|s| s.parse::<usize>())
                    .transpose()?,
                shards: take_flag(&mut cmd, "--shards")
                    .map(|s| s.parse::<usize>())
                    .transpose()?,
                prefetch: take_prefetch(&mut cmd)?,
            };
            let trace = take_flag(&mut cmd, "--trace").map(PathBuf::from);
            let capture =
                cmd.first().cloned().ok_or_else(|| anyhow!("replay needs a capture file"))?;
            replay_cmd(&args.artifacts, &PathBuf::from(capture), overrides, trace)
        }
        "synth-artifacts" => {
            let seed = take_flag(&mut cmd, "--seed")
                .map(|s| s.parse::<u64>())
                .transpose()?
                .unwrap_or(0);
            let dir =
                cmd.first().cloned().ok_or_else(|| anyhow!("synth-artifacts needs a directory"))?;
            synth_artifacts(&cfg, &PathBuf::from(dir), seed)
        }
        "inference" => {
            let layers = take_flag(&mut cmd, "--layers")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(cfg.model.layers);
            let heads = take_flag(&mut cmd, "--heads")
                .map(|s| s.parse::<usize>())
                .transpose()?
                .unwrap_or(cfg.model.heads);
            let dataset = cmd.first().cloned().unwrap_or_else(|| "SQuAD".into());
            inference(&cfg, &dataset, layers, heads)
        }
        "sweep" => {
            let param = cmd.first().cloned().ok_or_else(|| anyhow!("sweep needs a parameter"))?;
            let values: Vec<usize> =
                cmd[1..].iter().map(|v| v.parse()).collect::<Result<_, _>>()?;
            if values.is_empty() {
                bail!("sweep needs at least one value");
            }
            sweep(&cfg, &param, &values)
        }
        "check" => check(&args.artifacts),
        "bench-compare" => {
            let tolerance = take_flag(&mut cmd, "--tolerance")
                .map(|s| s.parse::<f64>())
                .transpose()?
                .unwrap_or(1.25);
            if cmd.len() != 2 {
                bail!("bench-compare needs BASELINE and CURRENT json paths");
            }
            bench_compare(&PathBuf::from(&cmd[0]), &PathBuf::from(&cmd[1]), tolerance)
        }
        "bench-assert-faster" => {
            let margin = take_flag(&mut cmd, "--margin")
                .map(|s| s.parse::<f64>())
                .transpose()?
                .unwrap_or(1.0);
            if cmd.len() != 3 {
                bail!("bench-assert-faster needs JSON FAST SLOW");
            }
            bench_assert_faster(&PathBuf::from(&cmd[0]), &cmd[1], &cmd[2], margin)
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command {other:?}")
        }
    }
}

fn info(cfg: &SystemConfig, artifacts: &Path) -> Result<()> {
    let hw = &cfg.hardware;
    println!(
        "CPSAA chip: {} tiles, {} ROA + {} WEA AGs/tile, {}x{} crossbars",
        hw.tiles, hw.roa_per_tile, hw.wea_per_tile, hw.crossbar_size, hw.crossbar_size
    );
    println!(
        "capacity: {:.1} MB of cells, {} arrays",
        hw.capacity_bytes() as f64 / 1e6,
        hw.total_arrays()
    );
    let area = AreaModel::build(hw);
    println!(
        "area: {:.2} mm^2   power: {:.2} W (Table 2: 27.47 / 28.83)",
        area.chip_area_mm2,
        area.chip_power_w()
    );
    match ArtifactSet::open(artifacts) {
        Ok(set) => {
            println!("artifacts: {} compiled graphs in {}", set.names().len(), set.dir.display());
            for n in set.names() {
                println!("  - {n}");
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn simulate(cfg: &SystemConfig, dataset: &str, batches: usize, exact: bool) -> Result<()> {
    let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed)
        .with_max_batches(batches)
        .with_exact_masks(exact);
    let sim = ChipSim::new(cfg.hardware.clone(), cfg.model.clone());
    let selected: Vec<_> = if dataset == "all" {
        cfg.workload.datasets.iter().collect()
    } else {
        vec![cfg.workload.dataset(dataset).ok_or_else(|| anyhow!("unknown dataset {dataset}"))?]
    };
    println!(
        "{:<8} {:>8} {:>12} {:>12} {:>10} {:>10}",
        "dataset", "batches", "GOPS", "GOPS/W", "ms", "density"
    );
    for ds in selected {
        let trace = gen.generate(ds);
        let r = sim.simulate_trace(&trace);
        println!(
            "{:<8} {:>8} {:>12.0} {:>12.1} {:>10.3} {:>10.3}",
            r.dataset,
            r.batches,
            r.mean_gops,
            r.mean_gops_per_watt,
            r.total_ns / 1e6,
            r.mean_density
        );
    }
    Ok(())
}

fn bench_figure(cfg: &SystemConfig, id: &str, out_dir: Option<&std::path::Path>) -> Result<()> {
    let tables =
        bench_harness::run_figure(id, cfg).ok_or_else(|| anyhow!("unknown figure id {id}"))?;
    for t in &tables {
        println!("{t}");
        if let Some(dir) = out_dir {
            t.save_csv(dir)?;
        }
    }
    if let Some(dir) = out_dir {
        println!("CSVs written to {}", dir.display());
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn serve(
    cfg: &SystemConfig,
    artifacts: &Path,
    requests: usize,
    layers: usize,
    heads: usize,
    shards: usize,
    leaders: usize,
    max_workers: Option<usize>,
    queue_cap: Option<usize>,
    precision: Precision,
    prune: PruneConfig,
    force_scalar: bool,
    prefetch: Option<bool>,
    record: Option<PathBuf>,
    trace: Option<PathBuf>,
) -> Result<()> {
    // Probe the manifest for the artifact shapes before spawning.
    let set = ArtifactSet::open(artifacts)?;
    let d_model = set.manifest.config.d_model;
    let seq_len = set.manifest.config.seq_len;
    let artifact_seed = set.manifest.config.seed;
    drop(set);

    let recorder = record.as_ref().map(|_| CaptureRecorder::new());
    let tracer = trace.as_ref().map(|_| SimTracer::new());
    let mut svc_cfg = ServiceConfig {
        layers,
        shards,
        leaders,
        max_kernel_workers: max_workers,
        precision,
        prune: prune.clone(),
        force_scalar,
        ..Default::default()
    };
    if let Some(cap) = queue_cap {
        svc_cfg.queue_cap = cap;
    }
    if let Some(on) = prefetch {
        svc_cfg.prefetch = on;
    }
    let svc = Service::start_with_hooks(
        artifacts.to_path_buf(),
        cfg.hardware.clone(),
        ModelConfig { heads, ..cfg.model.clone() },
        svc_cfg,
        ServeHooks { recorder: recorder.clone(), tracer: tracer.clone() },
    )?;
    println!(
        "service up (artifact shape {seq_len}x{d_model}, {layers} layers, {heads} heads, {shards} shards, {leaders} leaders, {precision} precision, {prune} plans{})",
        if force_scalar { ", scalar lanes" } else { "" }
    );

    let start = std::time::Instant::now();
    let mut handles = Vec::new();
    for id in 0..requests as u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = SeededRng::new(id + 1000);
            let rows = 8 + rng.gen_range_usize(0, seq_len - 8);
            let x = rng.normal_matrix(rows, d_model, 1.0);
            svc.infer(id, x)
        }));
    }
    for h in handles {
        let resp = h.join().map_err(|_| anyhow!("caller thread panicked"))??;
        assert!(resp.hidden.all_finite());
    }
    let elapsed = start.elapsed();
    let m = svc.metrics();
    println!(
        "served {} requests in {} batches over {:.2?} (utilization {:.1}%)",
        m.requests,
        m.batches,
        elapsed,
        m.batch_utilization() * 100.0
    );
    println!(
        "latency: mean {:.2?}  p50 {:.2?}  p99 {:.2?}  max {:.2?}",
        m.latency.mean(),
        m.latency.quantile(0.5),
        m.latency.quantile(0.99),
        m.latency.max()
    );
    for (lane, h) in [("high", &m.latency_high), ("normal", &m.latency_normal)] {
        if h.count() > 0 {
            println!(
                "  lane {lane}: {} requests, p50 {:.2?}  p95 {:.2?}  p99 {:.2?}",
                h.count(),
                h.p50(),
                h.p95(),
                h.p99()
            );
        }
    }
    println!(
        "simulated accelerator time {:.3} ms, energy {:.3} mJ ({precision} precision)",
        m.sim_ns / 1e6,
        m.sim_pj * 1e-9
    );
    println!(
        "plan pipeline: {} cache hits / {} misses, {:.3} ms of scan hidden or skipped",
        m.plan_cache_hits,
        m.plan_cache_misses,
        m.prefetch_overlapped_ns / 1e6
    );
    if m.leaders.len() > 1 {
        for (l, lm) in m.leaders.iter().enumerate() {
            println!(
                "  leader {l}: {} batches, {} requests, {:.3} ms",
                lm.batches,
                lm.requests,
                lm.sim_ns / 1e6
            );
        }
    }
    if m.heads.len() > 1 {
        let dens = m.head_mean_densities();
        for (h, hm) in m.heads.iter().enumerate() {
            println!(
                "  head {h}: {:.3} ms, {:.3} mJ, mean density {:.3}",
                hm.sim_ns / 1e6,
                hm.sim_pj * 1e-9,
                dens[h]
            );
        }
    }
    if !m.shards.is_empty() {
        for (s, sm) in m.shards.iter().enumerate() {
            println!(
                "  shard {s}: {:.3} ms, {:.3} mJ, {} rows, {} nnz",
                sm.sim_ns / 1e6,
                sm.sim_pj * 1e-9,
                sm.rows,
                sm.nnz
            );
        }
        // The last batch's attributed lines: window by the trailing
        // batch id, not a fixed width — the final batch may have cut
        // fewer shards than earlier ones.
        let last_batch = m.shard_lines.last().map(|l| l.batch);
        for line in m.shard_lines.iter().filter(|l| Some(l.batch) == last_batch) {
            println!(
                "  batch {} shard {}: {:.3} ms, {} rows, {} nnz",
                line.batch,
                line.shard,
                line.sim_ns / 1e6,
                line.rows,
                line.nnz
            );
        }
    }
    if prune.narrows() {
        println!(
            "plan narrowing: {:.3} ms spent vs {:.3} ms a full re-scan would have charged",
            m.narrow_ns / 1e6,
            m.rescan_ns / 1e6
        );
        // The last batch's per-layer plan evolution.
        let last_batch = m.plan_lines.last().map(|l| l.batch);
        for line in m.plan_lines.iter().filter(|l| Some(l.batch) == last_batch) {
            println!(
                "  batch {} layer {}: {} nnz, {} rows, {} heads kept",
                line.batch,
                line.layer,
                line.nnz,
                line.rows_kept,
                line.heads_kept
            );
        }
    }
    if let Some(path) = &record {
        let recorder = recorder.expect("recorder exists when --record is set");
        let capture = recorder.into_capture(CaptureConfig {
            model: svc.model().clone(),
            layers,
            shards,
            leaders,
            max_kernel_workers: max_workers,
            precision,
            prune,
            force_scalar,
            artifact_seed,
            system_toml: cfg.to_toml_string(),
        });
        capture.save(path)?;
        println!(
            "recorded {} batches / {} requests to {}",
            capture.batches.len(),
            capture.requests(),
            path.display()
        );
    }
    if let Some(path) = &trace {
        let tracer = tracer.expect("tracer exists when --trace is set");
        tracer.save(path)?;
        println!("wrote {} batch timelines to {}", tracer.batches_recorded(), path.display());
    }
    Ok(())
}

/// Parsed `loadgen` options (one struct so the runner stays readable).
struct LoadgenCli {
    seed: u64,
    rps: f64,
    duration_s: f64,
    deadline_ms: Option<u64>,
    interactive: f64,
    /// `Some(n)` switches to closed-loop pacing: n requests in flight,
    /// the next submission gated on the oldest reply.
    concurrency: Option<usize>,
    layers: usize,
    heads: usize,
    shards: usize,
    leaders: usize,
    max_workers: Option<usize>,
    queue_cap: Option<usize>,
    prune: PruneConfig,
    slo_p99_ms: Option<f64>,
    /// `--prefetch on|off`; `None` keeps the service default (on).
    prefetch: Option<bool>,
    json: bool,
    junit: Option<PathBuf>,
}

/// Seeded open-loop load generation against an in-process service.
/// Machine-readable output (CSV, or one JSON document with `--json`)
/// goes to stdout; progress and the human summary go to stderr, so the
/// data stream stays clean under redirection. Exits nonzero when the
/// measured p99 exceeds `--slo-p99-ms` or any request fails outright
/// (sheds are an expected overload outcome, not a failure).
fn loadgen(cfg: &SystemConfig, artifacts: &Path, o: LoadgenCli) -> Result<()> {
    use cpsaa::util::json::Json;
    use cpsaa::util::junit::{JunitCase, JunitSuite};
    use cpsaa::workload::loadgen as lg;

    if !o.rps.is_finite() || o.rps <= 0.0 {
        bail!("--rps must be a positive number, got {}", o.rps);
    }
    if !o.duration_s.is_finite() || o.duration_s <= 0.0 {
        bail!("--duration must be positive seconds, got {}", o.duration_s);
    }
    if !(0.0..=1.0).contains(&o.interactive) {
        bail!("--interactive must be a fraction in [0, 1], got {}", o.interactive);
    }
    if o.concurrency == Some(0) {
        bail!("--concurrency must be >= 1");
    }
    let mut svc_cfg = ServiceConfig {
        layers: o.layers,
        shards: o.shards,
        leaders: o.leaders,
        max_kernel_workers: o.max_workers,
        prune: o.prune.clone(),
        ..Default::default()
    };
    if let Some(cap) = o.queue_cap {
        svc_cfg.queue_cap = cap;
    }
    if let Some(on) = o.prefetch {
        svc_cfg.prefetch = on;
    }
    let svc = Service::start(
        artifacts.to_path_buf(),
        cfg.hardware.clone(),
        ModelConfig { heads: o.heads, ..cfg.model.clone() },
        svc_cfg,
    )?;
    let gen_cfg = cpsaa::workload::LoadgenConfig {
        seed: o.seed,
        rps: o.rps,
        duration: std::time::Duration::from_secs_f64(o.duration_s),
        deadline: o.deadline_ms.map(std::time::Duration::from_millis),
        interactive: o.interactive,
    };
    eprintln!(
        "loadgen: seed {} rps {} duration {}s deadline {} interactive {} pacing {} \
         ({} layers, {} heads, {} shards, {} leaders, {} plans)",
        o.seed,
        o.rps,
        o.duration_s,
        o.deadline_ms.map(|ms| format!("{ms}ms")).unwrap_or_else(|| "none".into()),
        o.interactive,
        o.concurrency
            .map(|n| format!("closed-loop x{n}"))
            .unwrap_or_else(|| "open-loop".into()),
        o.layers,
        o.heads,
        o.shards,
        o.leaders,
        o.prune,
    );
    let report = match o.concurrency {
        Some(n) => lg::run_closed(&svc, &gen_cfg, n, |line| eprintln!("loadgen: {line}"))?,
        None => lg::run(&svc, &gen_cfg, |line| eprintln!("loadgen: {line}"))?,
    };
    // The plan-pipeline counters live on the service, not the
    // generator's per-request outcomes (they are per-batch facts).
    let sm = svc.metrics();

    let p50_ms = report.latency.p50().as_secs_f64() * 1e3;
    let p95_ms = report.latency.p95().as_secs_f64() * 1e3;
    let p99_ms = report.latency.p99().as_secs_f64() * 1e3;
    let mean_ms = report.latency.mean().as_secs_f64() * 1e3;
    let max_ms = report.latency.max().as_secs_f64() * 1e3;
    let slo_ok = o.slo_p99_ms.is_none_or(|slo| p99_ms <= slo);
    let hard_failures = report.rejected + report.failed;

    if o.json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("seed".to_string(), Json::Num(o.seed as f64));
        obj.insert("rps".to_string(), Json::Num(o.rps));
        obj.insert("duration_s".to_string(), Json::Num(o.duration_s));
        obj.insert("offered".to_string(), Json::Num(report.offered as f64));
        obj.insert("completed".to_string(), Json::Num(report.completed as f64));
        obj.insert("shed_queue_full".to_string(), Json::Num(report.shed_queue_full as f64));
        obj.insert("shed_deadline".to_string(), Json::Num(report.shed_deadline as f64));
        obj.insert("rejected".to_string(), Json::Num(report.rejected as f64));
        obj.insert("failed".to_string(), Json::Num(report.failed as f64));
        obj.insert("wall_s".to_string(), Json::Num(report.wall.as_secs_f64()));
        obj.insert("achieved_rps".to_string(), Json::Num(report.achieved_rps()));
        obj.insert("p50_ms".to_string(), Json::Num(p50_ms));
        obj.insert("p95_ms".to_string(), Json::Num(p95_ms));
        obj.insert("p99_ms".to_string(), Json::Num(p99_ms));
        obj.insert("mean_ms".to_string(), Json::Num(mean_ms));
        obj.insert("max_ms".to_string(), Json::Num(max_ms));
        obj.insert(
            "concurrency".to_string(),
            o.concurrency.map(|n| Json::Num(n as f64)).unwrap_or(Json::Null),
        );
        obj.insert(
            "completed_high".to_string(),
            Json::Num(report.latency_high.count() as f64),
        );
        obj.insert(
            "completed_normal".to_string(),
            Json::Num(report.latency_normal.count() as f64),
        );
        obj.insert(
            "p99_high_ms".to_string(),
            Json::Num(report.latency_high.p99().as_secs_f64() * 1e3),
        );
        obj.insert(
            "p99_normal_ms".to_string(),
            Json::Num(report.latency_normal.p99().as_secs_f64() * 1e3),
        );
        obj.insert(
            "slo_p99_ms".to_string(),
            o.slo_p99_ms.map(Json::Num).unwrap_or(Json::Null),
        );
        obj.insert("slo_ok".to_string(), Json::Bool(slo_ok));
        obj.insert("plan_cache_hits".to_string(), Json::Num(sm.plan_cache_hits as f64));
        obj.insert(
            "plan_cache_misses".to_string(),
            Json::Num(sm.plan_cache_misses as f64),
        );
        obj.insert(
            "prefetch_overlapped_ms".to_string(),
            Json::Num(sm.prefetch_overlapped_ns / 1e6),
        );
        println!("{}", Json::Obj(obj));
    } else {
        println!("{}", lg::csv_header());
        for row in &report.outcomes {
            println!("{}", row.csv_row());
        }
    }
    eprintln!(
        "loadgen: offered {} completed {} shed {} (queue-full {} deadline {}) \
         rejected {} failed {} over {:.2?} ({:.1} rps achieved)",
        report.offered,
        report.completed,
        report.shed(),
        report.shed_queue_full,
        report.shed_deadline,
        report.rejected,
        report.failed,
        report.wall,
        report.achieved_rps(),
    );
    eprintln!(
        "loadgen: latency mean {mean_ms:.3} ms  p50 {p50_ms:.3}  p95 {p95_ms:.3}  \
         p99 {p99_ms:.3}  max {max_ms:.3}"
    );
    eprintln!(
        "loadgen: plan pipeline {} cache hits / {} misses, {:.3} ms of scan hidden or skipped",
        sm.plan_cache_hits,
        sm.plan_cache_misses,
        sm.prefetch_overlapped_ns / 1e6,
    );
    for (lane, h) in
        [("high", &report.latency_high), ("normal", &report.latency_normal)]
    {
        if h.count() > 0 {
            eprintln!(
                "loadgen: lane {lane}: {} requests  p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms",
                h.count(),
                h.p50().as_secs_f64() * 1e3,
                h.p95().as_secs_f64() * 1e3,
                h.p99().as_secs_f64() * 1e3,
            );
        }
    }

    if let Some(path) = &o.junit {
        let wall = report.wall.as_secs_f64();
        let mut suite = JunitSuite::new("loadgen-slo-smoke");
        suite.push(match o.slo_p99_ms {
            Some(slo) if p99_ms > slo => JunitCase::failed(
                "p99_slo",
                "loadgen",
                wall,
                format!("p99 {p99_ms:.3} ms > SLO {slo:.3} ms"),
            ),
            _ => JunitCase::passed("p99_slo", "loadgen", wall),
        });
        suite.push(if hard_failures > 0 {
            JunitCase::failed(
                "all_requests_resolve",
                "loadgen",
                wall,
                format!("{hard_failures} request(s) rejected or failed"),
            )
        } else {
            JunitCase::passed("all_requests_resolve", "loadgen", wall)
        });
        suite.save(path)?;
        eprintln!("loadgen: junit verdict written to {}", path.display());
    }
    if hard_failures > 0 {
        bail!("{hard_failures} request(s) rejected or failed — see the outcome table");
    }
    if let Some(slo) = o.slo_p99_ms {
        if p99_ms > slo {
            bail!("p99 {p99_ms:.3} ms exceeds the SLO {slo:.3} ms");
        }
        eprintln!("loadgen: p99 {p99_ms:.3} ms within SLO {slo:.3} ms");
    }
    Ok(())
}

/// Re-serve a capture and hold it to the bit-identity contract; exits
/// nonzero on the first diverging response field (or a corrupt file).
fn replay_cmd(
    artifacts: &Path,
    capture_path: &Path,
    overrides: ReplayOverrides,
    trace: Option<PathBuf>,
) -> Result<()> {
    let capture = Capture::load(capture_path)?;
    println!(
        "capture: {} batches / {} requests, recorded at {} leaders x {} shards ({} precision)",
        capture.batches.len(),
        capture.requests(),
        capture.config.leaders,
        capture.config.shards,
        capture.config.precision
    );
    let tracer = trace.as_ref().map(|_| SimTracer::new());
    let report = cpsaa::workload::capture::replay(&capture, artifacts, overrides, tracer.clone())?;
    if let Some(path) = &trace {
        let tracer = tracer.expect("tracer exists when --trace is set");
        tracer.save(path)?;
        println!("wrote {} batch timelines to {}", tracer.batches_recorded(), path.display());
    }
    println!(
        "replay OK: {} batches / {} requests bit-identical at {} leaders x {} shards ({})",
        report.batches,
        report.requests,
        report.leaders,
        report.shards,
        if report.strict_sim {
            "sim costs compared"
        } else {
            "sim costs skipped: shard topology changed"
        }
    );
    Ok(())
}

/// Synthesize a serving artifact set from the `[model]` config — the
/// CI/offline path to a servable directory without Python or JAX.
fn synth_artifacts(cfg: &SystemConfig, dir: &Path, seed: u64) -> Result<()> {
    let set = ArtifactSet::synthesize(dir, &cfg.model, seed)?;
    println!(
        "synthesized artifacts: {}x{} ({} heads, seed {seed}) in {}",
        cfg.model.seq_len,
        cfg.model.d_model,
        cfg.model.heads,
        set.dir.display()
    );
    Ok(())
}

/// Compare two bench JSON dumps (the CI regression gate): per-rung
/// current-vs-baseline median ratio, markdown table to stdout, nonzero
/// exit when any rung regresses beyond the tolerance.
fn bench_compare(baseline: &Path, current: &Path, tolerance: f64) -> Result<()> {
    let cmp = cpsaa::util::bench::BenchComparison::from_files(baseline, current, tolerance)?;
    print!("{}", cmp.markdown());
    let regressions = cmp.regressions();
    if !regressions.is_empty() {
        let names: Vec<&str> = regressions.iter().map(|d| d.name.as_str()).collect();
        bail!(
            "{} rung(s) regressed beyond {tolerance}x: {}",
            names.len(),
            names.join(", ")
        );
    }
    println!(
        "bench-compare OK: {} rungs checked against {} (tolerance {tolerance}x)",
        cmp.deltas.len(),
        baseline.display()
    );
    Ok(())
}

/// Same-run rung ordering gate: rung `fast` must have a smaller median
/// than rung `slow` in one dump (e.g. the fused kernel must beat the
/// unfused reference on the machine that ran both). `margin` > 1.0
/// tolerates runner jitter on rungs dominated by shared cost.
fn bench_assert_faster(json: &Path, fast: &str, slow: &str, margin: f64) -> Result<()> {
    if !margin.is_finite() || margin <= 0.0 {
        bail!("margin must be positive, got {margin}");
    }
    let text = std::fs::read_to_string(json)
        .map_err(|e| anyhow!("reading {}: {e}", json.display()))?;
    let check = cpsaa::util::bench::assert_faster(&text, fast, slow)?;
    println!(
        "{}: {} ns vs {}: {} ns ({:.2}x)",
        check.fast,
        check.fast_ns,
        check.slow,
        check.slow_ns,
        check.speedup()
    );
    if !check.holds_within(margin) {
        bail!(
            "rung {fast:?} ({} ns) did not beat {slow:?} ({} ns, margin {margin}x)",
            check.fast_ns,
            check.slow_ns
        );
    }
    println!("bench-assert-faster OK: {fast} beats {slow} (margin {margin}x)");
    Ok(())
}

fn inference(cfg: &SystemConfig, dataset: &str, layers: usize, heads: usize) -> Result<()> {
    use cpsaa::sim::{application, endurance};
    let ds = cfg
        .workload
        .dataset(dataset)
        .ok_or_else(|| anyhow!("unknown dataset {dataset}"))?;
    let model = cpsaa::config::ModelConfig { layers, heads, ..cfg.model.clone() };
    model.validate().map_err(|e| anyhow!(e))?;
    let gen = TraceGenerator::new(model.clone(), cfg.workload.seed).with_max_batches(1);
    let trace = gen.generate(ds);
    let masks: Vec<_> = trace.batches.iter().map(|b| b.mask.clone()).collect();
    let r = application::simulate_inference(&cfg.hardware, &model, &masks);
    println!(
        "{dataset}: {layers}-encoder x {heads}-head inference = {:.3} ms, {:.3} mJ, {:.0} GOPS (attention+FC)",
        r.total_ns / 1e6,
        r.total_energy_pj * 1e-9,
        r.gops
    );
    let e0 = &r.encoders[0];
    println!(
        "per encoder: attention {:.2} us + FC {:.2} us + DTC {:.2} us",
        e0.attention.breakdown.total_ns / 1e3,
        e0.fc.total_ns / 1e3,
        e0.dtc_ns / 1e3
    );
    let life = endurance::estimate(&cfg.hardware, &model, trace.mean_density());
    println!(
        "endurance (10^12 cycles): {:.1e} inferences unleveled, {:.1e} with wear-leveling",
        life.inferences_unleveled, life.inferences_leveled
    );
    Ok(())
}

fn sweep(cfg: &SystemConfig, param: &str, values: &[usize]) -> Result<()> {
    println!("{:<14} {:>12} {:>12} {:>12} {:>12}", param, "GOPS", "GOPS/W", "us/batch", "area_mm2");
    for &v in values {
        let mut hw = cfg.hardware.clone();
        match param {
            "crossbar_size" => hw.crossbar_size = v,
            "tiles" => hw.tiles = v,
            "adcs_per_ag" => hw.adcs_per_ag = v,
            "wea_per_tile" => hw.wea_per_tile = v,
            other => bail!("unknown sweep parameter {other:?}"),
        }
        hw.validate().map_err(|e| anyhow!(e))?;
        let gen = TraceGenerator::new(cfg.model.clone(), cfg.workload.seed).with_max_batches(1);
        let ds = cfg.workload.dataset("QQP").expect("QQP in suite");
        let trace = gen.generate(ds);
        let sim = ChipSim::new(hw.clone(), cfg.model.clone());
        let r = sim.simulate_batch(&trace.batches[0].mask);
        let area = AreaModel::build(&hw);
        println!(
            "{:<14} {:>12.0} {:>12.1} {:>12.2} {:>12.2}",
            v,
            r.gops,
            r.gops_per_watt,
            r.breakdown.total_ns / 1e3,
            area.chip_area_mm2
        );
    }
    Ok(())
}

fn check(artifacts: &Path) -> Result<()> {
    let set = ArtifactSet::open(artifacts)?;
    let engine = Engine::load(&set)?;
    let fix = set.fixtures()?;
    let weights = Weights::from_json_file(&set.dir.join("weights.json"))?;
    println!("platform: {}", engine.platform());
    let out = engine.execute("sparse_attention", &[&fix.x, &weights.w_s, &weights.w_v])?;
    let want = &fix.outputs["sparse_attention"];
    let z_err = out[0].rel_err(&want[0]);
    let mask_err = out[1].max_abs_diff(&want[1]);
    println!("sparse_attention: z rel_err={z_err:.2e} mask max_diff={mask_err}");
    if z_err > 1e-4 || mask_err != 0.0 {
        bail!("engine output does not match JAX fixtures");
    }
    let enc = engine.execute(
        "encoder",
        &[&fix.x, &weights.w_s, &weights.w_v, &weights.w_fc1, &weights.w_fc2],
    )?;
    let enc_err = enc[0].rel_err(&fix.outputs["encoder"][0]);
    println!("encoder: rel_err={enc_err:.2e}");
    if enc_err > 1e-4 {
        bail!("encoder mismatch");
    }
    println!("check OK — all artifacts reproduce the JAX fixtures");
    let _ = ModelConfig::artifact_default(); // keep the helper exercised
    Ok(())
}
