//! One in-memory batch: the unit CPSAA processes without off-chip traffic.

use crate::sparse::MaskMatrix;
use crate::tensor::Matrix;

/// A batch of embeddings plus its pruning mask.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Batch index within the trace.
    pub id: usize,
    /// Embedding matrix X (seq_len × d_model).
    pub x: Matrix,
    /// Pruning mask over token pairs (seq_len × seq_len).
    pub mask: MaskMatrix,
}

impl Batch {
    pub fn seq_len(&self) -> usize {
        self.x.rows()
    }

    pub fn d_model(&self) -> usize {
        self.x.cols()
    }

    pub fn stats(&self) -> BatchStats {
        BatchStats {
            seq_len: self.seq_len(),
            d_model: self.d_model(),
            mask_nnz: self.mask.nnz(),
            mask_density: self.mask.density(),
        }
    }
}

/// Summary statistics of one batch (drives the simulators).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchStats {
    pub seq_len: usize,
    pub d_model: usize,
    pub mask_nnz: usize,
    pub mask_density: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    #[test]
    fn stats_consistent() {
        let mut rng = SeededRng::new(0);
        let b = Batch {
            id: 0,
            x: rng.normal_matrix(32, 64, 1.0),
            mask: MaskMatrix::from_dense(&rng.mask_matrix(32, 32, 0.25)),
        };
        let s = b.stats();
        assert_eq!(s.seq_len, 32);
        assert_eq!(s.d_model, 64);
        assert_eq!(s.mask_nnz, b.mask.nnz());
        assert!((s.mask_density - s.mask_nnz as f64 / 1024.0).abs() < 1e-12);
    }
}
