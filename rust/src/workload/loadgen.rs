//! Seeded open-loop load generator for the serving stack.
//!
//! [`schedule`] expands a seed into a deterministic arrival plan — a
//! Poisson process at the configured offered rate, per-request payload
//! sizes, and interactive (high-lane) marks — entirely from
//! [`SeededRng`], so the same seed always produces the same offered
//! load, byte for byte. [`run`] then paces that plan against an
//! in-process [`Service`] in open-loop fashion (submissions happen at
//! their scheduled instants whether or not earlier replies have
//! arrived: exactly the regime that exercises continuous batching,
//! deadline shedding, and queue-full backpressure) and collects every
//! typed outcome into a [`LoadgenReport`]. [`run_closed`] drives the
//! same request stream in closed-loop fashion instead: a fixed number
//! of requests in flight, the next submission gated on the oldest
//! outstanding reply — the regime that measures sustainable throughput
//! rather than behavior under a fixed offered rate.
//!
//! Latencies in the report are the server-measured submit→reply
//! durations ([`InferenceResponse::latency`]), the same quantity the
//! service's own histogram tracks — the CI SLO smoke gates on the p99
//! of this distribution.
//!
//! [`InferenceResponse::latency`]: crate::coordinator::InferenceResponse

use std::time::{Duration, Instant};

use crate::coordinator::{
    LatencyHistogram, ServeError, ServeResult, Service, ShedReason, SubmitOptions,
};
use crate::runtime::Lane;
use crate::tensor::SeededRng;
use crate::util::error::Result;

/// Load-generation parameters. Everything observable about the offered
/// load derives from these fields alone.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Master seed: drives arrivals, payload sizes, lane marks, and the
    /// per-request payload contents.
    pub seed: u64,
    /// Offered load — the rate of the Poisson arrival process, in
    /// requests per second.
    pub rps: f64,
    /// Horizon of the arrival schedule (arrivals land strictly before
    /// it; the run itself also waits for every reply).
    pub duration: Duration,
    /// Per-request deadline forwarded to [`SubmitOptions::deadline`];
    /// `None` submits without deadlines.
    pub deadline: Option<Duration>,
    /// Fraction of requests marked interactive ([`Lane::High`]),
    /// clamped to [0, 1] by construction of the uniform draw.
    pub interactive: f64,
}

/// One planned request: when it is submitted, how big it is, and on
/// which lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScheduledRequest {
    pub id: u64,
    /// Offset from the start of the run at which this request submits.
    pub at: Duration,
    /// Payload rows, uniform in `[1, seq_len]`.
    pub rows: usize,
    pub lane: Lane,
}

/// What happened to one scheduled request, in schedule order.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    /// Scheduled submit offset (not the wall-clock submit instant).
    pub at: Duration,
    pub rows: usize,
    pub lane: Lane,
    /// `ok`, `shed-queue-full`, `shed-deadline`, `rejected`, `failed`,
    /// or `dropped` (reply channel died with the serving side).
    pub outcome: &'static str,
    /// Server-measured submit→reply latency; `Some` only for `ok`.
    pub latency: Option<Duration>,
    /// Leader that executed the request's batch; `Some` only for `ok`.
    pub leader: Option<usize>,
}

/// Header matching [`RequestOutcome::csv_row`].
pub fn csv_header() -> &'static str {
    "id,at_ms,rows,lane,outcome,latency_ms,leader"
}

impl RequestOutcome {
    /// One CSV line; empty cells where the outcome carries no latency
    /// or leader.
    pub fn csv_row(&self) -> String {
        let latency = self
            .latency
            .map(|d| format!("{:.3}", d.as_secs_f64() * 1e3))
            .unwrap_or_default();
        let leader = self.leader.map(|l| l.to_string()).unwrap_or_default();
        format!(
            "{},{:.3},{},{},{},{latency},{leader}",
            self.id,
            self.at.as_secs_f64() * 1e3,
            self.rows,
            self.lane.as_str(),
            self.outcome,
        )
    }
}

/// Everything a run observed: per-outcome counters, the completed
/// requests' latency distribution, and the full per-request outcome
/// table (schedule order) for the CSV dump.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// Requests the schedule offered (== `outcomes.len()`).
    pub offered: usize,
    pub completed: usize,
    pub shed_queue_full: usize,
    pub shed_deadline: usize,
    pub rejected: usize,
    pub failed: usize,
    /// Submit of the first request to reply of the last.
    pub wall: Duration,
    /// Server-measured submit→reply latencies of completed requests,
    /// all lanes combined.
    pub latency: LatencyHistogram,
    /// Latencies of completed [`Lane::High`] requests.
    pub latency_high: LatencyHistogram,
    /// Latencies of completed [`Lane::Normal`] requests.
    pub latency_normal: LatencyHistogram,
    pub outcomes: Vec<RequestOutcome>,
}

impl LoadgenReport {
    /// Requests shed for backpressure (queue full or deadline expired).
    pub fn shed(&self) -> usize {
        self.shed_queue_full + self.shed_deadline
    }

    /// Completed-request throughput over the whole run.
    pub fn achieved_rps(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Expand the config into its deterministic arrival plan. Pure: the
/// same `(cfg, seq_len)` always yields the same schedule, and the
/// schedule never depends on wall-clock time or service behavior.
pub fn schedule(cfg: &LoadgenConfig, seq_len: usize) -> Vec<ScheduledRequest> {
    assert!(cfg.rps.is_finite() && cfg.rps > 0.0, "rps must be positive, got {}", cfg.rps);
    assert!(seq_len > 0, "seq_len must be >= 1");
    let mut rng = SeededRng::new(cfg.seed);
    let horizon = cfg.duration.as_secs_f64();
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Exponential inter-arrival via inverse CDF: `uniform()` is in
        // [0, 1), so `1 - u` is in (0, 1] and the log stays finite.
        let u = rng.uniform() as f64;
        t += -(1.0 - u).ln() / cfg.rps;
        if t >= horizon {
            break;
        }
        let rows = 1 + rng.gen_range_usize(0, seq_len);
        let lane =
            if (rng.uniform() as f64) < cfg.interactive { Lane::High } else { Lane::Normal };
        out.push(ScheduledRequest {
            id: out.len() as u64,
            at: Duration::from_secs_f64(t),
            rows,
            lane,
        });
    }
    out
}

/// Per-request payload stream, decorrelated from the schedule stream so
/// neither perturbs the other as the generator evolves.
fn payload_seed(seed: u64, id: u64) -> u64 {
    seed ^ id.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

/// Outcome accumulator shared by the open- and closed-loop runners:
/// classifies each reply, splits completed latencies by lane, and keeps
/// the per-request outcome table in schedule order.
#[derive(Default)]
struct Tally {
    completed: usize,
    shed_queue_full: usize,
    shed_deadline: usize,
    rejected: usize,
    failed: usize,
    latency: LatencyHistogram,
    latency_high: LatencyHistogram,
    latency_normal: LatencyHistogram,
    outcomes: Vec<RequestOutcome>,
}

impl Tally {
    fn absorb(
        &mut self,
        s: &ScheduledRequest,
        got: std::result::Result<ServeResult, std::sync::mpsc::RecvError>,
    ) {
        let (outcome, lat, leader) = match got {
            Ok(Ok(resp)) => {
                self.completed += 1;
                self.latency.record(resp.latency);
                match s.lane {
                    Lane::High => self.latency_high.record(resp.latency),
                    Lane::Normal => self.latency_normal.record(resp.latency),
                }
                ("ok", Some(resp.latency), Some(resp.leader))
            }
            Ok(Err(ServeError::Shed(ShedReason::QueueFull))) => {
                self.shed_queue_full += 1;
                ("shed-queue-full", None, None)
            }
            Ok(Err(ServeError::Shed(ShedReason::DeadlineExpired))) => {
                self.shed_deadline += 1;
                ("shed-deadline", None, None)
            }
            Ok(Err(ServeError::Rejected(_))) => {
                self.rejected += 1;
                ("rejected", None, None)
            }
            Ok(Err(ServeError::Failed(_))) => {
                self.failed += 1;
                ("failed", None, None)
            }
            // The reply sender dropped without a verdict: the serving
            // side died out from under the request.
            Err(_) => {
                self.failed += 1;
                ("dropped", None, None)
            }
        };
        self.outcomes.push(RequestOutcome {
            id: s.id,
            at: s.at,
            rows: s.rows,
            lane: s.lane,
            outcome,
            latency: lat,
            leader,
        });
    }

    fn into_report(self, offered: usize, wall: Duration) -> LoadgenReport {
        LoadgenReport {
            offered,
            completed: self.completed,
            shed_queue_full: self.shed_queue_full,
            shed_deadline: self.shed_deadline,
            rejected: self.rejected,
            failed: self.failed,
            wall,
            latency: self.latency,
            latency_high: self.latency_high,
            latency_normal: self.latency_normal,
            outcomes: self.outcomes,
        }
    }
}

/// Pace the seed's schedule against `svc` and collect every outcome.
/// Open loop: each request submits at its scheduled instant (or as soon
/// after as the pacing thread can manage), and replies are collected
/// only after the last submission — reply channels buffer, so late
/// collection never throttles the offered load. `progress` receives a
/// short status line roughly once a second of pacing.
pub fn run(
    svc: &Service,
    cfg: &LoadgenConfig,
    mut progress: impl FnMut(String),
) -> Result<LoadgenReport> {
    let (seq_len, d_model) = (svc.model().seq_len, svc.model().d_model);
    let sched = schedule(cfg, seq_len);
    let start = Instant::now();
    let mut pending: Vec<std::sync::mpsc::Receiver<ServeResult>> =
        Vec::with_capacity(sched.len());
    let mut last_tick = 0u64;
    for s in &sched {
        let now = start.elapsed();
        if s.at > now {
            std::thread::sleep(s.at - now);
        }
        let x = SeededRng::new(payload_seed(cfg.seed, s.id)).normal_matrix(s.rows, d_model, 1.0);
        let opts = SubmitOptions { deadline: cfg.deadline, lane: s.lane };
        pending.push(svc.submit_with(s.id, x, opts)?);
        let tick = start.elapsed().as_secs();
        if tick > last_tick {
            last_tick = tick;
            progress(format!("t={tick}s: {}/{} submitted", pending.len(), sched.len()));
        }
    }
    let mut tally = Tally::default();
    for (s, rx) in sched.iter().zip(pending) {
        tally.absorb(s, rx.recv());
    }
    Ok(tally.into_report(sched.len(), start.elapsed()))
}

/// Drive the seed's request stream closed-loop: at most `concurrency`
/// requests in flight, the next submission gated on the oldest
/// outstanding reply. The request *stream* (ids, payload sizes, lanes,
/// payload contents) is the same deterministic expansion [`run`] uses;
/// only the pacing differs — scheduled arrival instants are ignored, so
/// the achieved rate measures what the service sustains at that
/// concurrency instead of how it copes with a fixed offered rate.
pub fn run_closed(
    svc: &Service,
    cfg: &LoadgenConfig,
    concurrency: usize,
    mut progress: impl FnMut(String),
) -> Result<LoadgenReport> {
    if concurrency == 0 {
        crate::bail!("concurrency must be >= 1");
    }
    let (seq_len, d_model) = (svc.model().seq_len, svc.model().d_model);
    let sched = schedule(cfg, seq_len);
    let start = Instant::now();
    let mut tally = Tally::default();
    let mut window: std::collections::VecDeque<(usize, std::sync::mpsc::Receiver<ServeResult>)> =
        std::collections::VecDeque::with_capacity(concurrency);
    let mut last_tick = 0u64;
    for (i, s) in sched.iter().enumerate() {
        // Replies resolve in submission order per request; waiting on
        // the oldest outstanding one bounds in-flight at `concurrency`.
        if window.len() == concurrency {
            let (j, rx) = window.pop_front().expect("window non-empty at capacity");
            tally.absorb(&sched[j], rx.recv());
        }
        let x = SeededRng::new(payload_seed(cfg.seed, s.id)).normal_matrix(s.rows, d_model, 1.0);
        let opts = SubmitOptions { deadline: cfg.deadline, lane: s.lane };
        window.push_back((i, svc.submit_with(s.id, x, opts)?));
        let tick = start.elapsed().as_secs();
        if tick > last_tick {
            last_tick = tick;
            progress(format!("t={tick}s: {}/{} submitted", i + 1, sched.len()));
        }
    }
    while let Some((j, rx)) = window.pop_front() {
        tally.absorb(&sched[j], rx.recv());
    }
    Ok(tally.into_report(sched.len(), start.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::coordinator::ServiceConfig;

    fn cfg(seed: u64) -> LoadgenConfig {
        LoadgenConfig {
            seed,
            rps: 500.0,
            duration: Duration::from_secs(2),
            deadline: None,
            interactive: 0.25,
        }
    }

    #[test]
    fn same_seed_reproduces_the_schedule() {
        let a = schedule(&cfg(7), 320);
        let b = schedule(&cfg(7), 320);
        assert!(!a.is_empty());
        assert_eq!(a, b);
    }

    #[test]
    fn different_seed_changes_the_schedule() {
        let a = schedule(&cfg(7), 320);
        let b = schedule(&cfg(8), 320);
        assert_ne!(a, b);
    }

    #[test]
    fn schedule_stays_inside_its_contract() {
        let c = cfg(11);
        let sched = schedule(&c, 320);
        // Poisson at 500 rps over 2 s: ~1000 arrivals; even a very
        // unlucky seed stays in a wide band around the mean.
        assert!(sched.len() > 500 && sched.len() < 1500, "{}", sched.len());
        let mut prev = Duration::ZERO;
        for (i, s) in sched.iter().enumerate() {
            assert_eq!(s.id, i as u64);
            assert!(s.at >= prev, "arrivals must be time-ordered");
            assert!(s.at < c.duration, "arrival past the horizon");
            assert!((1..=320).contains(&s.rows), "rows {} out of range", s.rows);
            prev = s.at;
        }
        let high = sched.iter().filter(|s| s.lane == Lane::High).count();
        assert!(high > 0 && high < sched.len(), "interactive=0.25 must mix lanes");
        let none = LoadgenConfig { interactive: 0.0, ..c.clone() };
        assert!(schedule(&none, 320).iter().all(|s| s.lane == Lane::Normal));
        let all = LoadgenConfig { interactive: 1.0, ..c };
        assert!(schedule(&all, 320).iter().all(|s| s.lane == Lane::High));
    }

    #[test]
    fn csv_rows_match_the_header_column_count() {
        let cols = csv_header().split(',').count();
        let ok = RequestOutcome {
            id: 3,
            at: Duration::from_millis(12),
            rows: 17,
            lane: Lane::High,
            outcome: "ok",
            latency: Some(Duration::from_micros(2500)),
            leader: Some(1),
        };
        let row = ok.csv_row();
        assert_eq!(row.split(',').count(), cols, "{row}");
        assert_eq!(row, "3,12.000,17,high,ok,2.500,1");
        let shed = RequestOutcome {
            outcome: "shed-queue-full",
            latency: None,
            leader: None,
            lane: Lane::Normal,
            ..ok
        };
        let row = shed.csv_row();
        assert_eq!(row.split(',').count(), cols, "{row}");
        assert_eq!(row, "3,12.000,17,normal,shed-queue-full,,");
    }

    #[test]
    fn run_accounts_for_every_scheduled_request() {
        let dir = std::env::temp_dir().join(format!("cpsaa-loadgen-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 5).unwrap();
        let svc = Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig {
                layers: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
        )
        .unwrap();
        let lg = LoadgenConfig {
            seed: 13,
            rps: 400.0,
            duration: Duration::from_millis(150),
            deadline: None,
            interactive: 0.5,
        };
        let mut lines = Vec::new();
        let report = run(&svc, &lg, |l| lines.push(l)).unwrap();
        assert_eq!(report.offered, report.outcomes.len());
        assert!(report.offered > 0);
        let accounted = report.completed
            + report.shed_queue_full
            + report.shed_deadline
            + report.rejected
            + report.failed;
        assert_eq!(accounted, report.offered, "every request gets exactly one outcome");
        // No deadline and a deep queue: nothing sheds, everything lands.
        assert_eq!(report.completed, report.offered);
        assert_eq!(report.latency.count(), report.completed as u64);
        assert!(report.latency.p99() >= report.latency.p50());
        assert!(report.achieved_rps() > 0.0);
        // Per-lane histograms partition the combined one.
        assert_eq!(
            report.latency_high.count() + report.latency_normal.count(),
            report.latency.count()
        );
        assert!(report.latency_high.count() > 0, "interactive=0.5 must land high-lane requests");
        drop(svc);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn closed_loop_accounts_for_every_request_and_bounds_inflight() {
        let dir =
            std::env::temp_dir().join(format!("cpsaa-loadgen-closed-{}", std::process::id()));
        let model = ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            ..ModelConfig::default()
        };
        crate::runtime::ArtifactSet::synthesize(&dir, &model, 6).unwrap();
        let svc = Service::start(
            dir.clone(),
            HardwareConfig::paper(),
            model,
            ServiceConfig {
                layers: 1,
                max_wait: Duration::from_millis(1),
                // A tight queue would shed an open-loop burst; closed
                // loop never exceeds its concurrency, so nothing sheds.
                queue_cap: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let lg = LoadgenConfig {
            seed: 17,
            rps: 400.0,
            duration: Duration::from_millis(120),
            deadline: None,
            interactive: 0.25,
        };
        let report = run_closed(&svc, &lg, 3, |_| {}).unwrap();
        assert!(report.offered > 0);
        assert_eq!(report.offered, report.outcomes.len());
        // in-flight never exceeded 3 <= queue_cap: zero sheds
        assert_eq!(report.completed, report.offered);
        assert_eq!(report.shed(), 0);
        // outcome table stays in schedule order
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(o.id, i as u64);
        }
        // the stream expansion is shared with the open-loop runner
        let sched = schedule(&lg, 16);
        assert_eq!(report.offered, sched.len());
        assert!(run_closed(&svc, &lg, 0, |_| {}).is_err(), "concurrency 0 must be rejected");
        drop(svc);
        std::fs::remove_dir_all(&dir).ok();
    }
}
