//! Deterministic capture/replay for the serving path.
//!
//! `serve --record` snapshots a live request stream — every admitted
//! request's payload, its batch composition, and the full serving
//! configuration — into a self-describing JSON capture file; `cpsaa
//! replay` re-serves that capture through a fresh [`Service`] and
//! asserts byte-identical [`InferenceResponse`]s. Because PRs 3–6
//! established that functional outputs are bit-identical at any worker
//! count, leader count, shard count, and under the forced-scalar lane
//! twins, a capture recorded under one `{workers, leaders, shards}`
//! topology must replay cleanly under any other — replay *is* the
//! determinism contract, executable against real traffic instead of
//! hand-written property grids.
//!
//! ## What gets recorded
//!
//! Responses depend on the whole packed batch (cross-request attention
//! through the batch mask, row-packing order), so the capture records
//! **batch groups**: which requests were packed together and in what
//! order. Replay submits each group atomically through
//! [`Service::submit_group`], which seals one batching window per group
//! — reproducing the recorded composition exactly, independent of
//! wall-clock timing.
//!
//! ## Bit-exact payloads
//!
//! f32 matrix payloads are serialized as `u32` bit patterns (integers,
//! exact in f64 well below 2^53), so round-trips are bit-exact and
//! non-finite values survive; f64 scalars rely on Rust's
//! shortest-round-trip float formatting, which the in-tree JSON parser
//! reads back to the identical bits.
//!
//! ## Comparison contract
//!
//! Always compared bit-exactly: `hidden`, `mask_density`,
//! `head_density`, `precision`, response ids, and the per-layer plan
//! evolution (`layer_nnz`/`layer_rows_kept`/`layer_heads_kept`,
//! `narrow_ns`/`rescan_ns`) — cascade narrowing decisions are functions
//! of the request stream, not the topology, so a pruned capture must
//! narrow identically at any worker/leader/shard count. The
//! simulated-cost fields (`sim_ns`/`sim_pj`, per-head and per-shard
//! lines) are a function of the shard topology, so they are compared
//! bit-exactly only when the replay runs at the recorded shard count
//! and skipped otherwise.

use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};

use crate::attention::Precision;
use crate::config::{ModelConfig, SystemConfig};
use crate::coordinator::{InferenceResponse, ServeHooks, Service, ServiceConfig};
use crate::sim::SimTrace;
use crate::sparse::PruneConfig;
use crate::tensor::Matrix;

/// Format marker of the capture file (`"format"` key).
pub const FORMAT: &str = "cpsaa-capture";
/// Capture schema version this build reads and writes.
pub const VERSION: u64 = 1;
/// Format marker of the `--trace` dump.
pub const TRACE_FORMAT: &str = "cpsaa-sim-trace";

/// The serving configuration a capture was recorded under — enough to
/// rebuild an equivalent [`Service`] without the original command line.
#[derive(Clone, Debug, PartialEq)]
pub struct CaptureConfig {
    /// The resolved serving model (artifact shapes + serving overlay),
    /// as the leaders loaded it.
    pub model: ModelConfig,
    /// Encoder layers per request.
    pub layers: usize,
    /// Logical chips each batch fanned across at record time.
    pub shards: usize,
    /// Leader threads at record time.
    pub leaders: usize,
    /// Explicit kernel-pool width, if one was set.
    pub max_kernel_workers: Option<usize>,
    /// Kernel arithmetic mode (recorded and honored at replay —
    /// precision changes values, so it is part of the contract, not an
    /// override axis).
    pub precision: Precision,
    /// Plan-evolution mode at record time (recorded and honored at
    /// replay — narrowing changes outputs, so it is part of the
    /// contract, not an override axis). Captures written before cascade
    /// narrowing existed read back as `Static`.
    pub prune: PruneConfig,
    /// Whether the scalar lane twins were forced.
    pub force_scalar: bool,
    /// Seed of the artifact set served against (replay refuses to run
    /// against different artifacts).
    pub artifact_seed: u64,
    /// Full system TOML of the recording run (hardware knobs drive the
    /// simulated-cost fields).
    pub system_toml: String,
}

/// The response fields replay asserts on (everything deterministic in
/// [`InferenceResponse`] — wall-clock latency is excluded).
#[derive(Clone, Debug, PartialEq)]
pub struct RecordedResponse {
    pub hidden: Matrix,
    pub mask_density: f64,
    pub sim_ns: f64,
    pub sim_pj: f64,
    pub head_sim_ns: Vec<f64>,
    pub head_sim_pj: Vec<f64>,
    pub head_density: Vec<f64>,
    pub shard_sim_ns: Vec<f64>,
    pub shard_sim_pj: Vec<f64>,
    pub shard_rows: Vec<usize>,
    /// Coordinates each layer's plans dispatched, layer order (compared
    /// always — plan evolution is topology-independent). Empty on
    /// captures written before cascade narrowing existed.
    pub layer_nnz: Vec<usize>,
    /// Query rows populated at each layer, layer order.
    pub layer_rows_kept: Vec<usize>,
    /// Heads populated at each layer, layer order.
    pub layer_heads_kept: Vec<usize>,
    /// Simulated plan-narrowing time across the stack (ns).
    pub narrow_ns: f64,
    /// Simulated full-rescan time the narrowing avoided (ns).
    pub rescan_ns: f64,
}

/// One admitted request: payload in packing order plus the response it
/// received at record time.
#[derive(Clone, Debug, PartialEq)]
pub struct RecordedRequest {
    pub id: u64,
    pub x: Matrix,
    pub response: RecordedResponse,
}

/// One packed batch: its monotonic id and its requests in packing
/// (offset) order.
#[derive(Clone, Debug, PartialEq)]
pub struct RecordedBatch {
    pub batch: u64,
    pub requests: Vec<RecordedRequest>,
}

/// A full serving capture: config block plus the batch-grouped request
/// stream, batch-id order.
#[derive(Clone, Debug, PartialEq)]
pub struct Capture {
    pub config: CaptureConfig,
    pub batches: Vec<RecordedBatch>,
}

impl Capture {
    /// Total requests across all recorded batches.
    pub fn requests(&self) -> usize {
        self.batches.iter().map(|b| b.requests.len()).sum()
    }

    pub fn to_json(&self) -> Json {
        let c = &self.config;
        let batches: Vec<Json> = self
            .batches
            .iter()
            .map(|b| {
                let requests: Vec<Json> = b
                    .requests
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("id", num(r.id as f64)),
                            ("x", matrix_to_json(&r.x)),
                            ("response", response_to_json(&r.response)),
                        ])
                    })
                    .collect();
                obj(vec![("batch", num(b.batch as f64)), ("requests", Json::Arr(requests))])
            })
            .collect();
        obj(vec![
            ("format", Json::Str(FORMAT.into())),
            ("version", num(VERSION as f64)),
            (
                "config",
                obj(vec![
                    ("model", model_to_json(&c.model)),
                    ("layers", num(c.layers as f64)),
                    ("shards", num(c.shards as f64)),
                    ("leaders", num(c.leaders as f64)),
                    (
                        "max_kernel_workers",
                        match c.max_kernel_workers {
                            Some(n) => num(n as f64),
                            None => Json::Null,
                        },
                    ),
                    ("precision", Json::Str(c.precision.to_string())),
                    ("prune", Json::Str(c.prune.to_string())),
                    ("force_scalar", Json::Bool(c.force_scalar)),
                    ("artifact_seed", num(c.artifact_seed as f64)),
                    ("system_toml", Json::Str(c.system_toml.clone())),
                ]),
            ),
            ("batches", Json::Arr(batches)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Capture> {
        let format = j.get("format")?.as_str()?;
        if format != FORMAT {
            bail!("not a capture file (format {format:?}, expected {FORMAT:?})");
        }
        let version = j.get("version")?.as_usize()? as u64;
        if version != VERSION {
            bail!("unsupported capture version {version} (this build reads version {VERSION})");
        }
        let c = j.get("config")?;
        let mkw = match c.get("max_kernel_workers")? {
            Json::Null => None,
            v => Some(v.as_usize()?),
        };
        let config = CaptureConfig {
            model: model_from_json(c.get("model")?)?,
            layers: c.get("layers")?.as_usize()?,
            shards: c.get("shards")?.as_usize()?,
            leaders: c.get("leaders")?.as_usize()?,
            max_kernel_workers: mkw,
            precision: c
                .get("precision")?
                .as_str()?
                .parse::<Precision>()
                .map_err(|e| anyhow!("capture precision: {e}"))?,
            // Absent on captures recorded before cascade narrowing:
            // those ran the static path.
            prune: match c.get("prune") {
                Ok(v) => v
                    .as_str()?
                    .parse::<PruneConfig>()
                    .map_err(|e| anyhow!("capture prune: {e}"))?,
                Err(_) => PruneConfig::Static,
            },
            force_scalar: match c.get("force_scalar")? {
                Json::Bool(b) => *b,
                other => bail!("force_scalar must be a bool, got {other:?}"),
            },
            artifact_seed: c.get("artifact_seed")?.as_usize()? as u64,
            system_toml: c.get("system_toml")?.as_str()?.to_string(),
        };
        let mut batches = Vec::new();
        for b in j.get("batches")?.as_arr()? {
            let mut requests = Vec::new();
            for r in b.get("requests")?.as_arr()? {
                requests.push(RecordedRequest {
                    id: r.get("id")?.as_usize()? as u64,
                    x: matrix_from_json(r.get("x")?)?,
                    response: response_from_json(r.get("response")?)?,
                });
            }
            batches.push(RecordedBatch { batch: b.get("batch")?.as_usize()? as u64, requests });
        }
        Ok(Capture { config, batches })
    }

    /// Parse a capture file's text; any structural defect (bad JSON,
    /// wrong format marker, unknown version, malformed payload) is a
    /// hard error — a corrupted capture must never half-replay.
    pub fn parse(text: &str) -> Result<Capture> {
        let j = Json::parse(text).context("parsing capture file")?;
        Self::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing capture {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Capture> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading capture {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("loading capture {}", path.display()))
    }
}

/// Shared recording sink the leader loops push admitted batches into
/// (cloneable handle, poison-recovering lock).
#[derive(Clone, Default)]
pub struct CaptureRecorder {
    batches: Arc<Mutex<Vec<RecordedBatch>>>,
}

impl CaptureRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, batch: RecordedBatch) {
        self.batches.lock().unwrap_or_else(|e| e.into_inner()).push(batch);
    }

    pub fn batches_recorded(&self) -> usize {
        self.batches.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Seal the recording into a capture: batches sorted by their
    /// monotonic id, so multi-leader interleavings serialize into one
    /// canonical stream.
    pub fn into_capture(self, config: CaptureConfig) -> Capture {
        let mut batches =
            std::mem::take(&mut *self.batches.lock().unwrap_or_else(|e| e.into_inner()));
        batches.sort_by_key(|b| b.batch);
        Capture { config, batches }
    }
}

/// One batch's simulated stage timelines, as recorded by a leader.
#[derive(Clone, Debug)]
pub struct BatchTraceRecord {
    pub batch: u64,
    pub leader: usize,
    pub traces: Vec<SimTrace>,
}

/// Shared sink for per-batch sim stage timelines (the `--trace` dump).
#[derive(Clone, Default)]
pub struct SimTracer {
    batches: Arc<Mutex<Vec<BatchTraceRecord>>>,
}

impl SimTracer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, rec: BatchTraceRecord) {
        self.batches.lock().unwrap_or_else(|e| e.into_inner()).push(rec);
    }

    pub fn batches_recorded(&self) -> usize {
        self.batches.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Serialize all recorded timelines, batch-id order.
    pub fn to_json(&self) -> Json {
        let mut recs = self.batches.lock().unwrap_or_else(|e| e.into_inner()).clone();
        recs.sort_by_key(|r| r.batch);
        let batches: Vec<Json> = recs
            .iter()
            .map(|r| {
                let timelines: Vec<Json> = r
                    .traces
                    .iter()
                    .map(|t| {
                        let events: Vec<Json> = t
                            .events
                            .iter()
                            .map(|e| {
                                obj(vec![
                                    ("stage", Json::Str(e.stage.to_string())),
                                    ("start_ns", num(e.start_ns)),
                                    ("end_ns", num(e.end_ns)),
                                ])
                            })
                            .collect();
                        obj(vec![
                            ("head", num(t.head as f64)),
                            (
                                "shard",
                                match t.shard {
                                    Some(s) => num(s as f64),
                                    None => Json::Null,
                                },
                            ),
                            ("events", Json::Arr(events)),
                        ])
                    })
                    .collect();
                obj(vec![
                    ("batch", num(r.batch as f64)),
                    ("leader", num(r.leader as f64)),
                    ("timelines", Json::Arr(timelines)),
                ])
            })
            .collect();
        obj(vec![
            ("format", Json::Str(TRACE_FORMAT.into())),
            ("version", num(VERSION as f64)),
            ("batches", Json::Arr(batches)),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing trace {}", path.display()))
    }
}

/// Topology overrides for a replay run. Axes the determinism contract
/// guarantees are value-invariant; `None` keeps the recorded setting.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplayOverrides {
    pub max_workers: Option<usize>,
    pub leaders: Option<usize>,
    pub shards: Option<usize>,
    /// Stage-overlapped serving during the replay run. Prefetch and the
    /// plan cache change only *when* plans are built, never their bits,
    /// so — like topology — it is an override axis, not part of the
    /// recorded contract. `None` keeps the service default (on).
    pub prefetch: Option<bool>,
}

/// Outcome of a successful replay.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub batches: usize,
    pub requests: usize,
    /// Whether the simulated-cost fields were compared bit-exactly
    /// (true iff the replay ran at the recorded shard count).
    pub strict_sim: bool,
    pub recorded_leaders: usize,
    pub recorded_shards: usize,
    pub leaders: usize,
    pub shards: usize,
}

/// Re-serve `capture` through a fresh [`Service`] and assert every
/// response is byte-identical to the recording. Batch groups are
/// submitted atomically in recorded order, so the packed compositions
/// — and therefore the FP summation orders — reproduce exactly;
/// everything else (worker count, leader count, shard count) may be
/// overridden and must not change a single output bit.
pub fn replay(
    capture: &Capture,
    artifact_dir: &Path,
    overrides: ReplayOverrides,
    tracer: Option<SimTracer>,
) -> Result<ReplayReport> {
    let c = &capture.config;
    let sys = SystemConfig::from_toml_str(&c.system_toml)
        .context("parsing the capture's recorded system config")?;
    // Replay only makes sense against the artifacts the capture was
    // recorded with — different weights would fail every comparison
    // with an unhelpful "hidden diverged".
    let set = crate::runtime::ArtifactSet::open(artifact_dir)?;
    let mc = &set.manifest.config;
    if (mc.seq_len, mc.d_model) != (c.model.seq_len, c.model.d_model)
        || mc.seed != c.artifact_seed
    {
        bail!(
            "artifact mismatch: capture was recorded against {}x{} (seed {}), {} holds {}x{} (seed {})",
            c.model.seq_len,
            c.model.d_model,
            c.artifact_seed,
            artifact_dir.display(),
            mc.seq_len,
            mc.d_model,
            mc.seed
        );
    }
    drop(set);

    let shards = overrides.shards.unwrap_or(c.shards);
    let leaders = overrides.leaders.unwrap_or(c.leaders);
    let max_kernel_workers = overrides.max_workers.or(c.max_kernel_workers);
    let defaults = ServiceConfig::default();
    let svc = Service::start_with_hooks(
        artifact_dir.to_path_buf(),
        sys.hardware.clone(),
        c.model.clone(),
        ServiceConfig {
            layers: c.layers,
            shards,
            leaders,
            max_kernel_workers,
            precision: c.precision,
            prune: c.prune.clone(),
            force_scalar: c.force_scalar,
            prefetch: overrides.prefetch.unwrap_or(defaults.prefetch),
            ..defaults
        },
        ServeHooks { recorder: None, tracer },
    )?;

    let strict_sim = shards == c.shards;
    let mut requests = 0usize;
    for b in &capture.batches {
        let subs: Vec<(u64, Matrix)> = b.requests.iter().map(|r| (r.id, r.x.clone())).collect();
        let rxs = svc.submit_group(subs)?;
        for (rx, rec) in rxs.into_iter().zip(&b.requests) {
            let resp = rx
                .recv()
                .map_err(|_| anyhow!("request {} dropped during replay", rec.id))?
                .with_context(|| format!("replaying batch {} request {}", b.batch, rec.id))?;
            compare_response(b.batch, rec, &resp, c.precision, strict_sim)?;
            requests += 1;
        }
    }
    Ok(ReplayReport {
        batches: capture.batches.len(),
        requests,
        strict_sim,
        recorded_leaders: c.leaders,
        recorded_shards: c.shards,
        leaders,
        shards,
    })
}

/// Assert one replayed response matches its recording bit for bit (sim
/// fields only under `strict_sim` — they are shard-topology functions).
fn compare_response(
    batch: u64,
    rec: &RecordedRequest,
    got: &InferenceResponse,
    precision: Precision,
    strict_sim: bool,
) -> Result<()> {
    let want = &rec.response;
    if got.id != rec.id {
        bail!("batch {batch}: response id {} != recorded {}", got.id, rec.id);
    }
    if got.precision != precision {
        bail!(
            "batch {batch} request {}: served at {} but recorded at {precision}",
            rec.id,
            got.precision
        );
    }
    ensure_matrix(batch, rec.id, "hidden", &want.hidden, &got.hidden)?;
    ensure_f64(batch, rec.id, "mask_density", want.mask_density, got.mask_density)?;
    ensure_f64s(batch, rec.id, "head_density", &want.head_density, &got.head_density)?;
    // Plan evolution is a function of the request stream, not the
    // topology: a cascade-pruned capture must narrow identically at any
    // worker/leader/shard count. Skipped only for pre-cascade captures
    // (no plan lines recorded).
    if !want.layer_nnz.is_empty() {
        ensure_usizes(batch, rec.id, "layer_nnz", &want.layer_nnz, &got.layer_nnz)?;
        ensure_usizes(
            batch,
            rec.id,
            "layer_rows_kept",
            &want.layer_rows_kept,
            &got.layer_rows_kept,
        )?;
        ensure_usizes(
            batch,
            rec.id,
            "layer_heads_kept",
            &want.layer_heads_kept,
            &got.layer_heads_kept,
        )?;
        ensure_f64(batch, rec.id, "narrow_ns", want.narrow_ns, got.narrow_ns)?;
        ensure_f64(batch, rec.id, "rescan_ns", want.rescan_ns, got.rescan_ns)?;
    }
    if strict_sim {
        ensure_f64(batch, rec.id, "sim_ns", want.sim_ns, got.sim_ns)?;
        ensure_f64(batch, rec.id, "sim_pj", want.sim_pj, got.sim_pj)?;
        ensure_f64s(batch, rec.id, "head_sim_ns", &want.head_sim_ns, &got.head_sim_ns)?;
        ensure_f64s(batch, rec.id, "head_sim_pj", &want.head_sim_pj, &got.head_sim_pj)?;
        ensure_f64s(batch, rec.id, "shard_sim_ns", &want.shard_sim_ns, &got.shard_sim_ns)?;
        ensure_f64s(batch, rec.id, "shard_sim_pj", &want.shard_sim_pj, &got.shard_sim_pj)?;
        if want.shard_rows != got.shard_rows {
            bail!(
                "batch {batch} request {}: shard_rows {:?} != recorded {:?}",
                rec.id,
                got.shard_rows,
                want.shard_rows
            );
        }
    }
    Ok(())
}

fn ensure_matrix(batch: u64, id: u64, field: &str, want: &Matrix, got: &Matrix) -> Result<()> {
    if want.shape() != got.shape() {
        bail!(
            "batch {batch} request {id}: {field} shape {:?} != recorded {:?}",
            got.shape(),
            want.shape()
        );
    }
    for (i, (w, g)) in want.data().iter().zip(got.data()).enumerate() {
        if w.to_bits() != g.to_bits() {
            bail!(
                "batch {batch} request {id}: {field} diverged at element {i} \
                 (recorded {w:?} [{:#010x}], replayed {g:?} [{:#010x}])",
                w.to_bits(),
                g.to_bits()
            );
        }
    }
    Ok(())
}

fn ensure_f64(batch: u64, id: u64, field: &str, want: f64, got: f64) -> Result<()> {
    if want.to_bits() != got.to_bits() {
        bail!("batch {batch} request {id}: {field} diverged (recorded {want:?}, replayed {got:?})");
    }
    Ok(())
}

fn ensure_usizes(batch: u64, id: u64, field: &str, want: &[usize], got: &[usize]) -> Result<()> {
    if want != got {
        bail!("batch {batch} request {id}: {field} {got:?} != recorded {want:?}");
    }
    Ok(())
}

fn ensure_f64s(batch: u64, id: u64, field: &str, want: &[f64], got: &[f64]) -> Result<()> {
    if want.len() != got.len() {
        bail!(
            "batch {batch} request {id}: {field} has {} entries, recorded {}",
            got.len(),
            want.len()
        );
    }
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        if w.to_bits() != g.to_bits() {
            bail!(
                "batch {batch} request {id}: {field}[{i}] diverged (recorded {w:?}, replayed {g:?})"
            );
        }
    }
    Ok(())
}

// ---- JSON helpers --------------------------------------------------------

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn nums(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn usizes(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn f64s_from(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()?.iter().map(|v| v.as_f64()).collect()
}

fn usizes_from(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?.iter().map(|v| v.as_usize()).collect()
}

/// f32 payloads as u32 bit patterns: exact in f64, immune to decimal
/// round-trip drift and to non-finite serialization hazards.
fn matrix_to_json(m: &Matrix) -> Json {
    let bits: Vec<Json> = m.data().iter().map(|v| Json::Num(v.to_bits() as f64)).collect();
    obj(vec![
        ("rows", num(m.rows() as f64)),
        ("cols", num(m.cols() as f64)),
        ("bits_f32", Json::Arr(bits)),
    ])
}

fn matrix_from_json(j: &Json) -> Result<Matrix> {
    let rows = j.get("rows")?.as_usize()?;
    let cols = j.get("cols")?.as_usize()?;
    let arr = j.get("bits_f32")?.as_arr()?;
    if arr.len() != rows * cols {
        bail!("matrix payload holds {} values, shape says {rows}x{cols}", arr.len());
    }
    let mut data = Vec::with_capacity(arr.len());
    for v in arr {
        let n = v.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
            bail!("bad f32 bit pattern {n}");
        }
        data.push(f32::from_bits(n as u32));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

fn model_to_json(m: &ModelConfig) -> Json {
    obj(vec![
        ("seq_len", num(m.seq_len as f64)),
        ("d_model", num(m.d_model as f64)),
        ("d_k", num(m.d_k as f64)),
        ("d_ff", num(m.d_ff as f64)),
        ("layers", num(m.layers as f64)),
        ("heads", num(m.heads as f64)),
        ("gamma", num(m.gamma as f64)),
        ("quant_bits", num(m.quant_bits as f64)),
        ("theta", num(m.theta as f64)),
        ("sharpness", num(m.sharpness as f64)),
    ])
}

fn model_from_json(j: &Json) -> Result<ModelConfig> {
    let model = ModelConfig {
        seq_len: j.get("seq_len")?.as_usize()?,
        d_model: j.get("d_model")?.as_usize()?,
        d_k: j.get("d_k")?.as_usize()?,
        d_ff: j.get("d_ff")?.as_usize()?,
        layers: j.get("layers")?.as_usize()?,
        heads: j.get("heads")?.as_usize()?,
        gamma: j.get("gamma")?.as_f64()? as f32,
        quant_bits: j.get("quant_bits")?.as_usize()? as u32,
        theta: j.get("theta")?.as_f64()? as f32,
        sharpness: j.get("sharpness")?.as_f64()? as f32,
    };
    model.validate().map_err(|e| anyhow!("capture model config: {e}"))?;
    Ok(model)
}

fn response_to_json(r: &RecordedResponse) -> Json {
    obj(vec![
        ("hidden", matrix_to_json(&r.hidden)),
        ("mask_density", num(r.mask_density)),
        ("sim_ns", num(r.sim_ns)),
        ("sim_pj", num(r.sim_pj)),
        ("head_sim_ns", nums(&r.head_sim_ns)),
        ("head_sim_pj", nums(&r.head_sim_pj)),
        ("head_density", nums(&r.head_density)),
        ("shard_sim_ns", nums(&r.shard_sim_ns)),
        ("shard_sim_pj", nums(&r.shard_sim_pj)),
        ("shard_rows", usizes(&r.shard_rows)),
        ("layer_nnz", usizes(&r.layer_nnz)),
        ("layer_rows_kept", usizes(&r.layer_rows_kept)),
        ("layer_heads_kept", usizes(&r.layer_heads_kept)),
        ("narrow_ns", num(r.narrow_ns)),
        ("rescan_ns", num(r.rescan_ns)),
    ])
}

fn response_from_json(j: &Json) -> Result<RecordedResponse> {
    Ok(RecordedResponse {
        hidden: matrix_from_json(j.get("hidden")?)?,
        mask_density: j.get("mask_density")?.as_f64()?,
        sim_ns: j.get("sim_ns")?.as_f64()?,
        sim_pj: j.get("sim_pj")?.as_f64()?,
        head_sim_ns: f64s_from(j.get("head_sim_ns")?)?,
        head_sim_pj: f64s_from(j.get("head_sim_pj")?)?,
        head_density: f64s_from(j.get("head_density")?)?,
        shard_sim_ns: f64s_from(j.get("shard_sim_ns")?)?,
        shard_sim_pj: f64s_from(j.get("shard_sim_pj")?)?,
        shard_rows: usizes_from(j.get("shard_rows")?)?,
        // Absent on pre-cascade captures: empty/zero, which the replay
        // comparison treats as "no plan lines recorded".
        layer_nnz: match j.get("layer_nnz") {
            Ok(v) => usizes_from(v)?,
            Err(_) => Vec::new(),
        },
        layer_rows_kept: match j.get("layer_rows_kept") {
            Ok(v) => usizes_from(v)?,
            Err(_) => Vec::new(),
        },
        layer_heads_kept: match j.get("layer_heads_kept") {
            Ok(v) => usizes_from(v)?,
            Err(_) => Vec::new(),
        },
        narrow_ns: match j.get("narrow_ns") {
            Ok(v) => v.as_f64()?,
            Err(_) => 0.0,
        },
        rescan_ns: match j.get("rescan_ns") {
            Ok(v) => v.as_f64()?,
            Err(_) => 0.0,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::SeededRng;

    fn sample_capture() -> Capture {
        let mut rng = SeededRng::new(5);
        let model = ModelConfig {
            seq_len: 16,
            d_model: 32,
            d_k: 8,
            d_ff: 64,
            heads: 2,
            ..ModelConfig::default()
        };
        let x = rng.normal_matrix(6, 32, 1.0);
        let hidden = rng.normal_matrix(6, 32, 1.0);
        Capture {
            config: CaptureConfig {
                model,
                layers: 1,
                shards: 2,
                leaders: 1,
                max_kernel_workers: Some(3),
                precision: Precision::I8,
                prune: PruneConfig::cascade(0.5),
                force_scalar: false,
                artifact_seed: 7,
                system_toml: SystemConfig::paper().to_toml_string(),
            },
            batches: vec![RecordedBatch {
                batch: 0,
                requests: vec![RecordedRequest {
                    id: 42,
                    x,
                    response: RecordedResponse {
                        hidden,
                        mask_density: 0.123456789,
                        sim_ns: 98765.4321,
                        sim_pj: 1.25e7,
                        head_sim_ns: vec![90000.5, 98765.4321],
                        head_sim_pj: vec![6.0e6, 6.5e6],
                        head_density: vec![0.1, 0.15],
                        shard_sim_ns: vec![5.0e4, 4.5e4],
                        shard_sim_pj: vec![6.25e6, 6.25e6],
                        shard_rows: vec![3, 3],
                        layer_nnz: vec![120, 48],
                        layer_rows_kept: vec![16, 8],
                        layer_heads_kept: vec![2, 1],
                        narrow_ns: 321.5,
                        rescan_ns: 2048.0,
                    },
                }],
            }],
        }
    }

    #[test]
    fn capture_roundtrips_bit_exactly() {
        let cap = sample_capture();
        let text = cap.to_json().to_string();
        let back = Capture::parse(&text).unwrap();
        assert_eq!(back, cap);
        // f32 payloads survive to the bit
        let a = &cap.batches[0].requests[0].x;
        let b = &back.batches[0].requests[0].x;
        assert!(a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn matrix_bits_roundtrip_nonfinite_and_signed_zero() {
        let m = Matrix::from_vec(
            1,
            5,
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1.5e-42],
        );
        let back = matrix_from_json(&matrix_to_json(&m)).unwrap();
        assert_eq!(back.shape(), (1, 5));
        for (a, b) in m.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pre_cascade_captures_read_back_with_static_defaults() {
        // Strip the keys this PR added from a serialized capture; the
        // parser must read it like a capture recorded before cascade
        // narrowing existed.
        fn strip(j: &mut Json, keys: &[&str]) {
            match j {
                Json::Obj(m) => {
                    m.retain(|k, _| !keys.contains(&k.as_str()));
                    for (_, v) in m.iter_mut() {
                        strip(v, keys);
                    }
                }
                Json::Arr(a) => {
                    for v in a.iter_mut() {
                        strip(v, keys);
                    }
                }
                _ => {}
            }
        }
        let mut j = sample_capture().to_json();
        strip(
            &mut j,
            &["prune", "layer_nnz", "layer_rows_kept", "layer_heads_kept", "narrow_ns", "rescan_ns"],
        );
        let back = Capture::parse(&j.to_string()).unwrap();
        assert_eq!(back.config.prune, PruneConfig::Static);
        let r = &back.batches[0].requests[0].response;
        assert!(r.layer_nnz.is_empty());
        assert!(r.layer_rows_kept.is_empty());
        assert!(r.layer_heads_kept.is_empty());
        assert_eq!(r.narrow_ns, 0.0);
        assert_eq!(r.rescan_ns, 0.0);
        // the untouched fields still round-trip
        assert_eq!(back.config.precision, Precision::I8);
        assert_eq!(back.batches[0].requests[0].id, 42);
    }

    #[test]
    fn corrupted_captures_rejected() {
        let cap = sample_capture();
        let text = cap.to_json().to_string();
        // truncated file
        assert!(Capture::parse(&text[..text.len() / 2]).is_err());
        // not JSON at all
        assert!(Capture::parse("definitely not json").is_err());
        // wrong format marker
        let other = text.replace("cpsaa-capture", "other-format");
        assert!(Capture::parse(&other).is_err());
        // future version
        let versioned = text.replace("\"version\":1", "\"version\":999");
        let err = Capture::parse(&versioned).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // payload length mismatch
        let j = cap.to_json().to_string().replace("\"rows\":6", "\"rows\":5");
        assert!(Capture::parse(&j).is_err());
    }

    #[test]
    fn recorder_sorts_batches_by_id() {
        let rec = CaptureRecorder::new();
        let sample = sample_capture();
        for id in [2u64, 0, 1] {
            rec.record(RecordedBatch { batch: id, requests: Vec::new() });
        }
        assert_eq!(rec.batches_recorded(), 3);
        let cap = rec.into_capture(sample.config);
        let ids: Vec<u64> = cap.batches.iter().map(|b| b.batch).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn tracer_serializes_sorted_timelines() {
        use crate::sim::StageEvent;
        let tracer = SimTracer::new();
        for batch in [1u64, 0] {
            tracer.record(BatchTraceRecord {
                batch,
                leader: 0,
                traces: vec![SimTrace {
                    head: 0,
                    shard: None,
                    events: vec![StageEvent {
                        stage: "step2_vmm",
                        start_ns: 1.0,
                        end_ns: 2.5,
                    }],
                }],
            });
        }
        let j = tracer.to_json();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), TRACE_FORMAT);
        let batches = j.get("batches").unwrap().as_arr().unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].get("batch").unwrap().as_usize().unwrap(), 0);
        let tl = batches[0].get("timelines").unwrap().as_arr().unwrap();
        assert_eq!(tl.len(), 1);
        let ev = tl[0].get("events").unwrap().as_arr().unwrap();
        assert_eq!(ev[0].get("stage").unwrap().as_str().unwrap(), "step2_vmm");
        // round-trips as valid JSON
        Json::parse(&j.to_string()).unwrap();
    }
}
