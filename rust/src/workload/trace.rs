//! Trace generation from dataset statistics.

use crate::attention;
use crate::config::{DatasetSpec, ModelConfig};
use crate::sparse::MaskMatrix;
use crate::tensor::{Matrix, SeededRng};

use super::Batch;

/// A full dataset trace: the ordered batches CPSAA processes serially.
#[derive(Clone, Debug)]
pub struct WorkloadTrace {
    pub dataset: String,
    pub batches: Vec<Batch>,
    /// Total embeddings represented (== dataset.sequences when not capped).
    pub total_sequences: usize,
}

impl WorkloadTrace {
    pub fn total_mask_nnz(&self) -> usize {
        self.batches.iter().map(|b| b.mask.nnz()).sum()
    }

    pub fn mean_density(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches.iter().map(|b| b.mask.density()).sum::<f64>() / self.batches.len() as f64
    }
}

/// Builds [`WorkloadTrace`]s from [`DatasetSpec`]s.
pub struct TraceGenerator {
    model: ModelConfig,
    seed: u64,
    /// Cap on generated batches (figures need trace *shape*, not volume;
    /// the simulator extrapolates per-batch results over the true count).
    pub max_batches: usize,
    /// When true, masks come from the golden pruning model on the actual
    /// embeddings; when false, from the dataset's characterized density
    /// (fast path for large sweeps).
    pub exact_masks: bool,
}

impl TraceGenerator {
    pub fn new(model: ModelConfig, seed: u64) -> Self {
        Self { model, seed, max_batches: 4, exact_masks: false }
    }

    pub fn with_exact_masks(mut self, exact: bool) -> Self {
        self.exact_masks = exact;
        self
    }

    pub fn with_max_batches(mut self, n: usize) -> Self {
        self.max_batches = n.max(1);
        self
    }

    /// Generate the trace for one dataset. Results are memoized process-
    /// wide (the figure harness re-requests identical traces dozens of
    /// times; see rust/DESIGN.md).
    pub fn generate(&self, ds: &DatasetSpec) -> WorkloadTrace {
        let key = format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}",
            ds.name,
            ds.sequences,
            ds.mean_len,
            ds.mask_density,
            self.model.seq_len,
            self.model.d_model,
            self.seed,
            self.max_batches,
            self.exact_masks,
        );
        // Poison recovery: the cache is insert-only memoization — a
        // thread that died holding the lock left, at worst, a complete
        // earlier insertion; dropping the whole process-wide cache for
        // that would cascade one panic into every later figure.
        {
            let cache = trace_cache().lock().unwrap_or_else(|e| e.into_inner());
            if let Some(t) = cache.get(&key) {
                return t.clone();
            }
        }
        let t = self.generate_uncached(ds);
        trace_cache().lock().unwrap_or_else(|e| e.into_inner()).insert(key, t.clone());
        t
    }

    fn generate_uncached(&self, ds: &DatasetSpec) -> WorkloadTrace {
        let n = self.model.seq_len;
        let d = self.model.d_model;
        // Each batch holds `batch tokens / mean_len` sequences packed to
        // seq_len tokens; batch count = ceil(sequences / per_batch).
        let seqs_per_batch = (n / ds.mean_len.max(1)).max(1);
        let num_batches = ds.sequences.div_ceil(seqs_per_batch).min(self.max_batches);

        let mut rng = SeededRng::new(self.seed ^ fxhash(&ds.name));
        // Weights are only needed for golden-model masks; synthesizing
        // them costs a d×d matmul, so stay lazy on the fast path.
        let weights = self
            .exact_masks
            .then(|| attention::Weights::synthetic(&self.model, self.seed));
        let mut batches = Vec::with_capacity(num_batches);
        for id in 0..num_batches {
            let x = rng.normal_matrix(n, d, 1.0);
            let mask = match &weights {
                Some(w) => attention::generate_mask(&x, &w.w_s, &self.model),
                None => characterized_mask(&mut rng, n, ds.mask_density),
            };
            batches.push(Batch { id, x, mask });
        }
        WorkloadTrace { dataset: ds.name.clone(), batches, total_sequences: ds.sequences }
    }
}

fn trace_cache() -> &'static std::sync::Mutex<std::collections::HashMap<String, WorkloadTrace>> {
    static CACHE: std::sync::OnceLock<
        std::sync::Mutex<std::collections::HashMap<String, WorkloadTrace>>,
    > = std::sync::OnceLock::new();
    CACHE.get_or_init(Default::default)
}

/// Mask with the dataset's characterized density and attention-like
/// structure: a guaranteed diagonal (tokens attend to themselves), plus
/// random unstructured off-diagonal entries — the paper stresses that
/// dynamic sparsity is *unstructured*, which is what breaks the vector-wise
/// schedulers of DOTA/SANGER (§4.3).
fn characterized_mask(rng: &mut SeededRng, n: usize, density: f64) -> MaskMatrix {
    let mut dense = Matrix::zeros(n, n);
    for i in 0..n {
        dense.set(i, i, 1.0);
    }
    let extra = ((density * (n * n) as f64) as usize).saturating_sub(n);
    for _ in 0..extra {
        let i = rng.gen_range_usize(0, n);
        let j = rng.gen_range_usize(0, n);
        dense.set(i, j, 1.0);
    }
    MaskMatrix::from_dense(&dense)
}

fn fxhash(s: &str) -> u64 {
    // Tiny deterministic string hash for per-dataset seeds.
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn gen() -> TraceGenerator {
        TraceGenerator::new(ModelConfig { seq_len: 64, d_model: 64, ..Default::default() }, 0)
    }

    #[test]
    fn trace_shapes() {
        let w = WorkloadConfig::paper();
        let t = gen().generate(w.dataset("MRPC").unwrap());
        assert!(!t.batches.is_empty());
        for b in &t.batches {
            assert_eq!(b.x.shape(), (64, 64));
            assert_eq!((b.mask.rows(), b.mask.cols()), (64, 64));
        }
    }

    #[test]
    fn deterministic() {
        let w = WorkloadConfig::paper();
        let a = gen().generate(w.dataset("CoLA").unwrap());
        let b = gen().generate(w.dataset("CoLA").unwrap());
        assert_eq!(a.batches[0].x, b.batches[0].x);
        assert_eq!(a.batches[0].mask, b.batches[0].mask);
    }

    #[test]
    fn datasets_get_distinct_data() {
        let w = WorkloadConfig::paper();
        let a = gen().generate(w.dataset("CoLA").unwrap());
        let b = gen().generate(w.dataset("SST-2").unwrap());
        assert!(a.batches[0].x.max_abs_diff(&b.batches[0].x) > 0.0);
    }

    #[test]
    fn characterized_density_close() {
        let w = WorkloadConfig::paper();
        let ds = w.dataset("QQP").unwrap();
        let t = gen().generate(ds);
        let d = t.mean_density();
        assert!((d - ds.mask_density).abs() < 0.05, "density {d} vs {}", ds.mask_density);
    }

    #[test]
    fn diagonal_always_present() {
        let w = WorkloadConfig::paper();
        let t = gen().generate(w.dataset("RTE").unwrap());
        for b in &t.batches {
            for i in 0..b.mask.rows() {
                assert!(b.mask.get(i, i));
            }
        }
    }

    #[test]
    fn exact_masks_use_golden_model() {
        let w = WorkloadConfig::paper();
        let t = gen().with_exact_masks(true).with_max_batches(1).generate(w.dataset("WNLI").unwrap());
        // exact masks are whatever the pruning model yields; just sanity-check density
        let d = t.mean_density();
        assert!(d > 0.0 && d < 1.0);
    }

    #[test]
    fn max_batches_respected() {
        let w = WorkloadConfig::paper();
        let t = gen().with_max_batches(2).generate(w.dataset("QQP").unwrap());
        assert!(t.batches.len() <= 2);
    }
}
