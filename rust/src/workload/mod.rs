//! Workload system: GLUE/SQuAD-shaped synthetic traces.
//!
//! The paper batches each dataset into groups of 320 embeddings processed
//! fully in-memory, with batches serialized behind small off-chip
//! transfers (§5). [`TraceGenerator`] reproduces that structure: per-batch
//! sequence lengths drawn from the dataset's length statistics, embeddings
//! from the seeded RNG, and a pruning mask whose density matches the
//! dataset's characterization (or, in `exact` mode, the mask the golden
//! model actually generates).
//!
//! [`capture`] records and replays served batches bit-identically;
//! [`loadgen`] expands a seed into a deterministic open-loop arrival
//! schedule and drives the serving stack at a fixed offered load (the
//! CI p99 SLO smoke runs on it).

mod batch;
pub mod capture;
pub mod loadgen;
mod trace;

pub use batch::{Batch, BatchStats};
pub use loadgen::{LoadgenConfig, LoadgenReport, RequestOutcome, ScheduledRequest};
pub use capture::{
    BatchTraceRecord, Capture, CaptureConfig, CaptureRecorder, RecordedBatch, RecordedRequest,
    RecordedResponse, ReplayOverrides, ReplayReport, SimTracer,
};
pub use trace::{TraceGenerator, WorkloadTrace};
