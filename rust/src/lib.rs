//! # CPSAA — crossbar-based PIM sparse attention accelerator (reproduction)
//!
//! Full-system reproduction of *CPSAA: Accelerating Sparse Attention using
//! Crossbar-based Processing-In-Memory Architecture* (cs.AR 2022).
//!
//! The crate is the Layer-3 of a three-layer stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels (masked SDDMM,
//!   SpMM, softmax, quantization) mirroring the paper's 32×32 crossbar
//!   dispatch, lowered under `interpret=True`.
//! * **L2** (`python/compile/model.py`) — the CPSAA calculation mode
//!   (`W_S = W_Q·W_Kᵀ` folding, eq. 3) and PIM pruning (eq. 4) as JAX
//!   graphs, AOT-lowered to HLO text artifacts.
//! * **L3** (this crate) — the coordinator that loads and executes those
//!   artifacts ([`runtime`]), the cycle-accurate CPSAA chip simulator
//!   ([`sim`]), the comparison platforms ([`baselines`]), the workload
//!   system ([`workload`]), and the bench harness that regenerates every
//!   table and figure of the paper's evaluation ([`bench_harness`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `cpsaa` binary is self-contained.
//!
//! The hot-path spine of the crate is [`sparse::DispatchPlan`]: one ReCAM
//! scan per pruning mask, whose topology and statistics drive the
//! attention kernels, every simulator engine, and the coordinator's
//! per-batch accounting. Multi-head batches scale that spine to a
//! [`sparse::PlanSet`] — one plan per head, heads executed and costed
//! concurrently on disjoint crossbar-tile slices (§4.5).
//!
//! See `rust/DESIGN.md` for the layer contracts, the `DispatchPlan`
//! dataflow, and the experiment index.

pub mod attention;
pub mod baselines;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod tensor;
pub mod util;
pub mod workload;

pub use config::{HardwareConfig, ModelConfig, WorkloadConfig};
