//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The AOT bridge of the three-layer stack. `python/compile/aot.py`
//! lowers every L2 graph to HLO **text** (xla_extension 0.5.1 rejects the
//! 64-bit-id protos jax ≥ 0.5 serializes); [`Engine`] parses, compiles on
//! the PJRT CPU client once at startup, and executes from the coordinator
//! hot path with zero Python anywhere.

mod artifact;
mod engine;

pub use artifact::{ArtifactSet, Fixtures, Manifest};
pub use engine::Engine;
