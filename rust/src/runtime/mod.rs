//! Runtime: load AOT artifacts (manifest + HLO text) and execute them.
//!
//! The AOT bridge of the three-layer stack. `python/compile/aot.py`
//! lowers every L2 graph to HLO **text** plus a JSON manifest of shapes;
//! [`Engine`] resolves each graph name against its native golden-model
//! implementation at load time and executes from the coordinator hot path
//! with zero Python anywhere. See `rust/DESIGN.md` §Runtime for the
//! artifact contract and the PJRT-backend substitution note.

mod artifact;
mod engine;
pub mod executor;

pub use artifact::{ArtifactSet, Fixtures, Manifest};
pub use engine::{EncoderHeadsExec, Engine, EngineStats};
pub use executor::{Executor, Lane};
