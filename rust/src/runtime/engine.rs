//! PJRT execution engine: compile once, execute many.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::tensor::Matrix;

use super::artifact::ArtifactSet;

/// One compiled artifact plus its expected parameter shapes.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    params: Vec<Vec<usize>>,
}

/// Execution statistics of one engine lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub executions: u64,
    pub total_exec_ns: u64,
}

/// The PJRT engine: a CPU client with every artifact compiled ahead of
/// time. `execute` is the only thing the request path calls.
pub struct Engine {
    client: xla::PjRtClient,
    compiled: HashMap<String, Compiled>,
    stats: std::cell::RefCell<EngineStats>,
}

impl Engine {
    /// Compile every artifact in the set on the PJRT CPU client.
    pub fn load(artifacts: &ArtifactSet) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let mut compiled = HashMap::new();
        for name in artifacts.names() {
            let path = artifacts.hlo_path(name)?;
            let exe = Self::compile_file(&client, &path)
                .with_context(|| format!("compiling artifact {name}"))?;
            let params = artifacts.manifest.artifacts[name].params.clone();
            compiled.insert(name.to_string(), Compiled { exe, params });
        }
        Ok(Self { client, compiled, stats: Default::default() })
    }

    /// Load a single HLO text file (used by tools and tests).
    pub fn load_single(path: &Path, params: Vec<Vec<usize>>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let exe = Self::compile_file(&client, path)?;
        let mut compiled = HashMap::new();
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("module").to_string();
        compiled.insert(name, Compiled { exe, params });
        Ok(Self { client, compiled, stats: Default::default() })
    }

    fn compile_file(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client.compile(&comp).map_err(|e| anyhow!("XLA compile: {e:?}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.compiled.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    /// Execute artifact `name` with matrix inputs; returns the output
    /// tuple as matrices (row-major f32).
    pub fn execute(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name} (have: {:?})", self.names()))?;
        if inputs.len() != c.params.len() {
            return Err(anyhow!("{name}: {} inputs given, {} expected", inputs.len(), c.params.len()));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (m, want) in inputs.iter().zip(&c.params) {
            let (r, cl) = m.shape();
            if &vec![r, cl] != want {
                return Err(anyhow!("{name}: input shape {:?} != expected {:?}", (r, cl), want));
            }
            let lit = xla::Literal::vec1(m.data())
                .reshape(&[r as i64, cl as i64])
                .map_err(|e| anyhow!("literal reshape: {e:?}"))?;
            literals.push(lit);
        }
        let start = Instant::now();
        let out = c
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let root = out[0][0].to_literal_sync().map_err(|e| anyhow!("fetch result: {e:?}"))?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.total_exec_ns += start.elapsed().as_nanos() as u64;
        }
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = match shape {
                    xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                    other => return Err(anyhow!("non-array output: {other:?}")),
                };
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                match dims.len() {
                    2 => Ok(Matrix::from_vec(dims[0], dims[1], data)),
                    1 => Ok(Matrix::from_vec(1, dims[0], data)),
                    _ => Err(anyhow!("unsupported output rank {dims:?}")),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<ArtifactSet> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ArtifactSet::open(&dir).ok()
    }

    #[test]
    fn load_and_execute_all_artifacts() {
        let Some(set) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&set).unwrap();
        assert_eq!(engine.names().len(), 5);
        let fix = set.fixtures().unwrap();
        let cfg = &set.manifest.config;
        let w = crate::attention::Weights::from_json_file(&set.dir.join("weights.json")).unwrap();

        // sparse_attention(x, w_s, w_v) must reproduce the JAX fixture.
        let out = engine.execute("sparse_attention", &[&fix.x, &w.w_s, &w.w_v]).unwrap();
        assert_eq!(out.len(), 2);
        let want = &fix.outputs["sparse_attention"];
        assert!(out[0].rel_err(&want[0]) < 1e-4, "z err {}", out[0].rel_err(&want[0]));
        assert_eq!(out[1].max_abs_diff(&want[1]), 0.0, "mask mismatch");
        assert_eq!(out[0].shape(), (cfg.seq_len, cfg.d_model));
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(set) = artifacts() else { return };
        let engine = Engine::load(&set).unwrap();
        let bad = Matrix::zeros(3, 3);
        assert!(engine.execute("mask_gen", &[&bad, &bad]).is_err());
        assert!(engine.execute("nope", &[]).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let Some(set) = artifacts() else { return };
        let engine = Engine::load(&set).unwrap();
        let fix = set.fixtures().unwrap();
        let w = crate::attention::Weights::from_json_file(&set.dir.join("weights.json")).unwrap();
        assert_eq!(engine.stats().executions, 0);
        engine.execute("mask_gen", &[&fix.x, &w.w_s]).unwrap();
        assert_eq!(engine.stats().executions, 1);
        assert!(engine.stats().total_exec_ns > 0);
    }
}
