//! Artifact execution engine: load once, execute many.
//!
//! The offline build has no PJRT/XLA runtime, so the engine interprets the
//! AOT artifact graphs natively: every graph name in the manifest maps to
//! the pure-rust golden model (`crate::attention`), which mirrors
//! `python/compile/model.py` op-for-op. The HLO text files stay the
//! artifact interchange format (shapes are validated from the manifest);
//! when a PJRT backend is available the fixtures pin both implementations
//! to the same JAX numerics.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::anyhow;
use crate::attention::{self, MultiHeadWeights, Precision, Weights, WorkspacePool};
use crate::config::ModelConfig;
use crate::sparse::{LayerImportance, MaskMatrix, PlanSet, ShardedPlans};
use crate::tensor::Matrix;
use crate::util::error::Result;

use super::artifact::ArtifactSet;
use super::executor::{self, Executor};

/// Graph names the native interpreter implements.
const KNOWN_GRAPHS: [&str; 5] =
    ["mask_gen", "attention", "sparse_attention", "dense_attention", "encoder"];

/// One multi-head encoder-layer execution: the functional hidden state
/// plus the per-head dispatch plans (one ReCAM scan per head mask) that
/// drove the kernels — the coordinator reuses the first layer's set for
/// the batch's hardware accounting instead of re-scanning. Plans are
/// `Arc`-shared: the serving layer's plan cache and prefetch stage hand
/// the same scan to many consumers (kernels, cost attribution, cache
/// entries) without cloning the coordinate streams.
pub struct EncoderHeadsExec {
    pub hidden: Matrix,
    pub plans: Arc<PlanSet>,
    /// The shard partition that drove a sharded execution (`None` on
    /// the unsharded path) — the coordinator reuses it for the batch's
    /// multi-chip cost attribution instead of re-partitioning.
    pub sharded: Option<ShardedPlans>,
}

/// Execution statistics of one engine lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub executions: u64,
    pub total_exec_ns: u64,
}

/// The execution engine: artifact graphs resolved to golden-model
/// implementations at load time. `execute` is the only thing the request
/// path calls.
pub struct Engine {
    model: ModelConfig,
    /// Expected parameter shapes per graph, in call order (manifest).
    params: HashMap<String, Vec<Vec<usize>>>,
    stats: std::cell::RefCell<EngineStats>,
    /// Long-lived kernel scratch: per-head / per-shard workers check
    /// [`attention::KernelWorkspace`]s out of this pool, so the encoder
    /// stack stops allocating fresh buffers per layer per head per
    /// shard (steady state after the first batch).
    workspaces: WorkspacePool,
    /// The worker pool every fan-out under this engine dispatches onto
    /// (mask scans, plan builds, head/shard/row-range kernels). Defaults
    /// to the crate-wide [`executor::global`] pool — all engines, and
    /// all leader threads, share the one pool — and is injectable for
    /// tests via [`Engine::with_executor`].
    exec: Arc<Executor>,
}

impl Engine {
    /// Resolve every artifact in the set against the native interpreter.
    pub fn load(artifacts: &ArtifactSet) -> Result<Self> {
        let c = &artifacts.manifest.config;
        let model = ModelConfig {
            seq_len: c.seq_len,
            d_model: c.d_model,
            d_k: c.d_k,
            d_ff: c.d_ff,
            gamma: c.gamma,
            quant_bits: c.quant_bits,
            theta: c.theta,
            ..ModelConfig::default()
        };
        let mut params = HashMap::new();
        for name in artifacts.names() {
            if !KNOWN_GRAPHS.contains(&name) {
                return Err(anyhow!("artifact {name} has no native implementation"));
            }
            params.insert(name.to_string(), artifacts.manifest.artifacts[name].params.clone());
        }
        Ok(Self {
            model,
            params,
            stats: Default::default(),
            workspaces: WorkspacePool::new(),
            exec: executor::global(),
        })
    }

    /// Replace the engine's dispatch pool (tests pin worker counts with
    /// this; serving keeps the shared global pool).
    pub fn with_executor(mut self, exec: Arc<Executor>) -> Self {
        self.exec = exec;
        self
    }

    /// The worker pool this engine dispatches onto.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    pub fn platform(&self) -> String {
        "native-golden".to_string()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.params.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    /// The model shapes the artifacts were lowered with.
    pub fn model(&self) -> &ModelConfig {
        &self.model
    }

    /// The engine's long-lived kernel workspace pool (introspection).
    pub fn workspaces(&self) -> &WorkspacePool {
        &self.workspaces
    }

    /// Execute graph `name` with matrix inputs; returns the output tuple
    /// as matrices (row-major f32), matching the PJRT calling convention
    /// (`aot.py` lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let want = self
            .params
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name} (have: {:?})", self.names()))?;
        if inputs.len() != want.len() {
            return Err(anyhow!("{name}: {} inputs given, {} expected", inputs.len(), want.len()));
        }
        for (m, w) in inputs.iter().zip(want) {
            let (r, c) = m.shape();
            if &vec![r, c] != w {
                return Err(anyhow!("{name}: input shape {:?} != expected {:?}", (r, c), w));
            }
        }
        let start = Instant::now();
        let out = self.run_graph(name, inputs)?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.total_exec_ns += start.elapsed().as_nanos() as u64;
        Ok(out)
    }

    /// Execute one encoder layer with multi-head fan-out: per-head
    /// pruning masks (concurrent, §4.5), one [`PlanSet`] scan, per-head
    /// attention kernels on the plan set, concat + optional W_O + FC
    /// tail. This is the native-interpreter generalization of the
    /// `encoder` graph — with one head it computes the same bits; a
    /// future PJRT backend lowers it as `heads` parallel `encoder`
    /// slices pinned by the same fixtures.
    pub fn execute_encoder_heads(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
    ) -> Result<EncoderHeadsExec> {
        self.execute_encoder_heads_sharded(x, w, 1)
    }

    /// [`Engine::execute_encoder_heads`] with batch-parallel sharding:
    /// the per-head plan set is still built once (one ReCAM scan per
    /// head mask), then partitioned into at most `shards` nnz-balanced
    /// row ranges and sliced per shard; each shard executes its Q-row
    /// slice against the full keys/values on its own worker (K logical
    /// chips). `shards <= 1` runs the unsharded kernel — same code,
    /// same schedule as before sharding existed — and any shard count
    /// produces bit-identical hidden states (row-separable kernels;
    /// property-tested). Sharded executions return their partition in
    /// [`EncoderHeadsExec::sharded`] for cost-attribution reuse.
    pub fn execute_encoder_heads_sharded(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
        shards: usize,
    ) -> Result<EncoderHeadsExec> {
        self.execute_encoder_heads_sharded_prec(x, w, shards, Precision::F32)
    }

    /// [`Engine::execute_encoder_heads_sharded`] with a kernel
    /// [`Precision`]: `F32` is the reference path; `I8` runs the
    /// quantized SDDMM score kernels (i8 storage / i32 accumulate,
    /// dequantize at softmax). Mask generation, plan building, and the
    /// sharding partition are precision-independent, so the same plans
    /// drive both modes.
    pub fn execute_encoder_heads_sharded_prec(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
        shards: usize,
        precision: Precision,
    ) -> Result<EncoderHeadsExec> {
        self.validate_encoder_heads_input(x, w)?;
        let start = Instant::now();
        let plans = self.build_plans(x, w);
        self.run_heads_planned(x, w, plans, shards, precision, start)
    }

    /// [`Engine::execute_encoder_heads_sharded_prec`] over a *provided*
    /// plan set — the prefetch/cache path: the serving layer built (or
    /// cached) the batch's layer-0 plans ahead of time, so this entry
    /// skips mask generation and the ReCAM scan. Because plans are a
    /// pure function of the payload bits and the frozen weights, the
    /// result is bit-identical to the self-scanning entry whenever the
    /// provided set came from [`Engine::prepare_plans`] on the same
    /// inputs.
    pub fn execute_encoder_heads_preplanned_prec(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
        plans: Arc<PlanSet>,
        shards: usize,
        precision: Precision,
    ) -> Result<EncoderHeadsExec> {
        self.validate_encoder_heads_input(x, w)?;
        self.validate_plans(&plans, x, w)?;
        let start = Instant::now();
        self.run_heads_planned(x, w, plans, shards, precision, start)
    }

    /// Build the layer-0 plan set for a batch without executing it —
    /// the prefetch stage (mask generation + one ReCAM scan per head),
    /// runnable ahead of the kernels. The same computation the
    /// self-scanning entries perform, so the result is bit-identical to
    /// what execution would have built.
    pub fn prepare_plans(&self, x: &Matrix, w: &MultiHeadWeights) -> Result<Arc<PlanSet>> {
        self.validate_encoder_heads_input(x, w)?;
        Ok(self.build_plans(x, w))
    }

    /// [`Engine::prepare_plans`] without the engine: mask generation +
    /// plan scan on an explicit pool — the form the detached prefetch
    /// job uses (it cannot borrow the leader's engine across threads).
    /// Must stay the exact computation [`Engine::build_plans`] performs.
    pub fn build_plans_in(
        exec: &Executor,
        x: &Matrix,
        w: &MultiHeadWeights,
        cfg: &ModelConfig,
    ) -> Arc<PlanSet> {
        let masks = attention::mask::generate_heads_in(exec, x, w, cfg);
        Arc::new(PlanSet::build_in(exec, &masks))
    }

    fn build_plans(&self, x: &Matrix, w: &MultiHeadWeights) -> Arc<PlanSet> {
        Self::build_plans_in(&self.exec, x, w, &self.model)
    }

    fn validate_plans(&self, plans: &PlanSet, x: &Matrix, w: &MultiHeadWeights) -> Result<()> {
        if plans.heads() != w.heads.len() {
            return Err(anyhow!("plan set has {} heads, weights {}", plans.heads(), w.heads.len()));
        }
        if plans.rows() != x.rows() {
            return Err(anyhow!("plan set has {} rows, input {}", plans.rows(), x.rows()));
        }
        Ok(())
    }

    fn run_heads_planned(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
        plans: Arc<PlanSet>,
        shards: usize,
        precision: Precision,
        start: Instant,
    ) -> Result<EncoderHeadsExec> {
        let cfg = &self.model;
        let (hidden, sharded) = if shards <= 1 {
            let hidden = attention::ops::encoder_layer_heads_ws_prec(
                x,
                w,
                &plans,
                cfg,
                &self.workspaces,
                &self.exec,
                precision,
            );
            (hidden, None)
        } else {
            let sharded = plans.shard(shards);
            let hidden = attention::ops::encoder_layer_heads_sharded_ws_prec(
                x,
                w,
                &sharded,
                cfg,
                &self.workspaces,
                &self.exec,
                precision,
            );
            (hidden, Some(sharded))
        };
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.total_exec_ns += start.elapsed().as_nanos() as u64;
        Ok(EncoderHeadsExec { hidden, plans, sharded })
    }

    /// [`Engine::execute_encoder_heads_sharded_prec`] that additionally
    /// reduces the layer's softmax probabilities into a
    /// [`LayerImportance`] — the cascade-narrowing feed. The hidden
    /// state is bit-identical to the plain entry (retention copies
    /// values the kernels already computed).
    pub fn execute_encoder_heads_importance(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
        shards: usize,
        precision: Precision,
    ) -> Result<(EncoderHeadsExec, LayerImportance)> {
        self.validate_encoder_heads_input(x, w)?;
        let start = Instant::now();
        let plans = self.build_plans(x, w);
        self.run_heads_importance(x, w, plans, shards, precision, start)
    }

    /// Execute one encoder layer over a *provided* plan set — the
    /// cascade path for layers past the first (the coordinator narrows
    /// the previous layer's plans, an O(nnz) coordinate-stream filter)
    /// and for a prefetched/cached layer 0; either way this entry skips
    /// mask generation and the ReCAM scan entirely. The plan set is
    /// re-partitioned for sharding (its nnz distribution changed under
    /// narrowing).
    pub fn execute_encoder_heads_planned_importance(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
        plans: Arc<PlanSet>,
        shards: usize,
        precision: Precision,
    ) -> Result<(EncoderHeadsExec, LayerImportance)> {
        self.validate_encoder_heads_input(x, w)?;
        self.validate_plans(&plans, x, w)?;
        let start = Instant::now();
        self.run_heads_importance(x, w, plans, shards, precision, start)
    }

    fn run_heads_importance(
        &self,
        x: &Matrix,
        w: &MultiHeadWeights,
        plans: Arc<PlanSet>,
        shards: usize,
        precision: Precision,
        start: Instant,
    ) -> Result<(EncoderHeadsExec, LayerImportance)> {
        let cfg = &self.model;
        let (hidden, imp, sharded) = if shards <= 1 {
            let (hidden, imp) = attention::ops::encoder_layer_heads_importance(
                x,
                w,
                &plans,
                cfg,
                &self.workspaces,
                &self.exec,
                precision,
            );
            (hidden, imp, None)
        } else {
            let sharded = plans.shard(shards);
            let (hidden, imp) = attention::ops::encoder_layer_heads_sharded_importance(
                x,
                w,
                &sharded,
                cfg,
                &self.workspaces,
                &self.exec,
                precision,
            );
            (hidden, imp, Some(sharded))
        };
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.total_exec_ns += start.elapsed().as_nanos() as u64;
        Ok((EncoderHeadsExec { hidden, plans, sharded }, imp))
    }

    fn validate_encoder_heads_input(&self, x: &Matrix, w: &MultiHeadWeights) -> Result<()> {
        let cfg = &self.model;
        if x.shape() != (cfg.seq_len, cfg.d_model) {
            return Err(anyhow!(
                "encoder input shape {:?} != ({}, {})",
                x.shape(),
                cfg.seq_len,
                cfg.d_model
            ));
        }
        w.validate().map_err(|e| anyhow!("bad multi-head weights: {e}"))?;
        if w.d_model() != cfg.d_model {
            return Err(anyhow!("weights d_model {} != artifact {}", w.d_model(), cfg.d_model));
        }
        Ok(())
    }

    fn run_graph(&self, name: &str, inputs: &[&Matrix]) -> Result<Vec<Matrix>> {
        let cfg = &self.model;
        match name {
            // mask_gen(x, w_s) -> (mask,)
            "mask_gen" => {
                let mask = attention::generate_mask(inputs[0], inputs[1], cfg);
                Ok(vec![mask.to_dense()])
            }
            // attention(x, w_s, w_v, mask) -> (z,)
            "attention" => {
                let mask = MaskMatrix::from_dense(inputs[3]);
                let z = attention::cpsaa_attention(inputs[0], inputs[1], inputs[2], &mask, cfg);
                Ok(vec![z])
            }
            // sparse_attention(x, w_s, w_v) -> (z, mask)
            "sparse_attention" => {
                let mask = attention::generate_mask(inputs[0], inputs[1], cfg);
                let z = attention::cpsaa_attention(inputs[0], inputs[1], inputs[2], &mask, cfg);
                Ok(vec![z, mask.to_dense()])
            }
            // dense_attention(x, w_s, w_v) -> (z,)
            "dense_attention" => {
                Ok(vec![attention::dense_attention(inputs[0], inputs[1], inputs[2], cfg)])
            }
            // encoder(x, w_s, w_v, w_fc1, w_fc2) -> (hidden, mask)
            "encoder" => {
                let w = Weights {
                    w_s: inputs[1].clone(),
                    w_v: inputs[2].clone(),
                    w_fc1: inputs[3].clone(),
                    w_fc2: inputs[4].clone(),
                };
                let mask = attention::generate_mask(inputs[0], &w.w_s, cfg);
                let h = attention::ops::encoder_layer(inputs[0], &w, &mask, cfg);
                Ok(vec![h, mask.to_dense()])
            }
            other => Err(anyhow!("artifact {other} has no native implementation")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    fn artifacts() -> Option<ArtifactSet> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ArtifactSet::open(&dir).ok()
    }

    /// A manifest-only artifact set — the native interpreter needs no
    /// compiled files, so the engine can be exercised without `make
    /// artifacts`.
    fn synthetic_set() -> ArtifactSet {
        let text = r#"{
            "config": {"seq_len": 16, "d_model": 32, "d_k": 8, "d_ff": 64,
                       "gamma": 4.0, "quant_bits": 4, "theta": 0.01, "block": 32, "seed": 0},
            "artifacts": {
                "mask_gen": {"file": "mask_gen.hlo.txt", "params": [[16, 32], [32, 32]]},
                "sparse_attention": {"file": "sa.hlo.txt", "params": [[16, 32], [32, 32], [32, 32]]},
                "encoder": {"file": "enc.hlo.txt",
                            "params": [[16, 32], [32, 32], [32, 32], [32, 64], [64, 32]]}
            }
        }"#;
        ArtifactSet { dir: PathBuf::from("."), manifest: Manifest::parse(text).unwrap() }
    }

    fn small_model() -> ModelConfig {
        ModelConfig { seq_len: 16, d_model: 32, d_k: 8, d_ff: 64, ..ModelConfig::default() }
    }

    #[test]
    fn native_engine_matches_golden_model() {
        let engine = Engine::load(&synthetic_set()).unwrap();
        let cfg = small_model();
        let w = Weights::synthetic(&cfg, 3);
        let x = crate::tensor::SeededRng::new(11).normal_matrix(16, 32, 1.0);

        let mask_out = engine.execute("mask_gen", &[&x, &w.w_s]).unwrap();
        let golden_mask = attention::generate_mask(&x, &w.w_s, &cfg);
        assert_eq!(MaskMatrix::from_dense(&mask_out[0]), golden_mask);

        let out = engine.execute("sparse_attention", &[&x, &w.w_s, &w.w_v]).unwrap();
        assert_eq!(out.len(), 2);
        let golden_z = attention::cpsaa_attention(&x, &w.w_s, &w.w_v, &golden_mask, &cfg);
        assert!(out[0].rel_err(&golden_z) < 1e-5);
    }

    #[test]
    fn encoder_heads_one_head_matches_encoder_graph() {
        let engine = Engine::load(&synthetic_set()).unwrap();
        let cfg = small_model();
        let w = Weights::synthetic(&cfg, 3);
        let x = crate::tensor::SeededRng::new(11).normal_matrix(16, 32, 1.0);
        let graph = engine
            .execute("encoder", &[&x, &w.w_s, &w.w_v, &w.w_fc1, &w.w_fc2])
            .unwrap();
        let mh = MultiHeadWeights::from_single(&w);
        let fanout = engine.execute_encoder_heads(&x, &mh).unwrap();
        assert_eq!(fanout.hidden, graph[0], "1-head fan-out != encoder graph");
        assert_eq!(fanout.plans.heads(), 1);
        assert_eq!(
            fanout.plans.plan(0).nnz(),
            MaskMatrix::from_dense(&graph[1]).nnz(),
            "plan must describe the same pruning mask"
        );
    }

    #[test]
    fn encoder_heads_validates_inputs() {
        let engine = Engine::load(&synthetic_set()).unwrap();
        let cfg = small_model();
        let mh = MultiHeadWeights::synthetic(&ModelConfig { heads: 4, ..cfg.clone() }, 0);
        // wrong input shape
        assert!(engine.execute_encoder_heads(&Matrix::zeros(3, 3), &mh).is_err());
        // wrong d_model
        let other = MultiHeadWeights::synthetic(
            &ModelConfig { d_model: 64, d_k: 8, heads: 4, ..ModelConfig::default() },
            0,
        );
        assert!(engine.execute_encoder_heads(&Matrix::zeros(16, 32), &other).is_err());
        // valid 4-head execution runs and counts stats
        let x = crate::tensor::SeededRng::new(2).normal_matrix(16, 32, 1.0);
        let before = engine.stats().executions;
        let out = engine.execute_encoder_heads(&x, &mh).unwrap();
        assert_eq!(out.hidden.shape(), (16, 32));
        assert!(out.hidden.all_finite());
        assert_eq!(out.plans.heads(), 4);
        assert_eq!(engine.stats().executions, before + 1);
    }

    #[test]
    fn encoder_heads_sharded_bit_identical_any_shard_count() {
        let engine = Engine::load(&synthetic_set()).unwrap();
        let cfg = ModelConfig { heads: 4, ..small_model() };
        let mh = MultiHeadWeights::synthetic(&cfg, 8);
        let x = crate::tensor::SeededRng::new(14).normal_matrix(16, 32, 1.0);
        let want = engine.execute_encoder_heads(&x, &mh).unwrap();
        for shards in [1, 2, 4, 6] {
            let got = engine.execute_encoder_heads_sharded(&x, &mh, shards).unwrap();
            assert_eq!(got.hidden, want.hidden, "{shards} shards diverged");
            assert_eq!(got.plans, want.plans, "{shards} shards changed the plan set");
        }
        // validation still applies on the sharded path
        assert!(engine
            .execute_encoder_heads_sharded(&Matrix::zeros(3, 3), &mh, 4)
            .is_err());
    }

    #[test]
    fn encoder_heads_i8_precision_shard_invariant() {
        // i8 differs from f32 (it is an approximation) but must be
        // bit-identical across shard counts: per-row γ quantization is
        // row-slice invariant.
        let engine = Engine::load(&synthetic_set()).unwrap();
        let cfg = ModelConfig { heads: 4, ..small_model() };
        let mh = MultiHeadWeights::synthetic(&cfg, 8);
        let x = crate::tensor::SeededRng::new(14).normal_matrix(16, 32, 1.0);
        let f32_out = engine.execute_encoder_heads(&x, &mh).unwrap();
        let i8_out = engine
            .execute_encoder_heads_sharded_prec(&x, &mh, 1, Precision::I8)
            .unwrap();
        assert!(i8_out.hidden.all_finite());
        assert_eq!(i8_out.hidden.shape(), f32_out.hidden.shape());
        assert_eq!(i8_out.plans, f32_out.plans, "plans are precision-independent");
        for shards in [2, 4] {
            let got = engine
                .execute_encoder_heads_sharded_prec(&x, &mh, shards, Precision::I8)
                .unwrap();
            assert_eq!(got.hidden, i8_out.hidden, "i8 diverged at {shards} shards");
        }
    }

    #[test]
    fn injected_serial_executor_matches_default_engine() {
        // The executor axis at the engine level: a strictly serial pool
        // and a narrow pool must reproduce the shared-pool results to
        // the bit, sharded or not.
        let cfg = ModelConfig { heads: 4, ..small_model() };
        let mh = MultiHeadWeights::synthetic(&cfg, 8);
        let x = crate::tensor::SeededRng::new(14).normal_matrix(16, 32, 1.0);
        let default_engine = Engine::load(&synthetic_set()).unwrap();
        let want = default_engine.execute_encoder_heads(&x, &mh).unwrap();
        for workers in [1usize, 3] {
            let engine = Engine::load(&synthetic_set())
                .unwrap()
                .with_executor(Arc::new(Executor::new(workers)));
            assert_eq!(engine.executor().workers(), workers);
            let got = engine.execute_encoder_heads(&x, &mh).unwrap();
            assert_eq!(got.hidden, want.hidden, "{workers}-worker engine diverged");
            let sharded = engine.execute_encoder_heads_sharded(&x, &mh, 3).unwrap();
            assert_eq!(sharded.hidden, want.hidden, "{workers}-worker sharded engine diverged");
        }
    }

    #[test]
    fn workspace_pool_reaches_steady_state() {
        let engine = Engine::load(&synthetic_set()).unwrap();
        let cfg = ModelConfig { heads: 4, ..small_model() };
        let mh = MultiHeadWeights::synthetic(&cfg, 8);
        let x = crate::tensor::SeededRng::new(14).normal_matrix(16, 32, 1.0);
        let first = engine.execute_encoder_heads(&x, &mh).unwrap();
        let high_water = engine.workspaces().idle();
        assert!(high_water >= 1, "execution must seed the pool");
        // Repeat executions recycle workspaces; the pool never grows
        // past the worker high-water mark (4 concurrent head workers).
        for _ in 0..3 {
            let again = engine.execute_encoder_heads(&x, &mh).unwrap();
            assert_eq!(again.hidden, first.hidden, "workspace reuse changed bits");
        }
        let settled = engine.workspaces().idle();
        assert!(
            settled >= high_water && settled <= 4,
            "pool at {settled} (high water {high_water})"
        );
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let engine = Engine::load(&synthetic_set()).unwrap();
        let bad = Matrix::zeros(3, 3);
        assert!(engine.execute("mask_gen", &[&bad, &bad]).is_err());
        assert!(engine.execute("nope", &[]).is_err());
        assert!(engine.execute("mask_gen", &[&bad]).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let engine = Engine::load(&synthetic_set()).unwrap();
        let cfg = small_model();
        let w = Weights::synthetic(&cfg, 0);
        let x = crate::tensor::SeededRng::new(1).normal_matrix(16, 32, 1.0);
        assert_eq!(engine.stats().executions, 0);
        engine.execute("mask_gen", &[&x, &w.w_s]).unwrap();
        assert_eq!(engine.stats().executions, 1);
        assert!(engine.stats().total_exec_ns > 0);
    }

    #[test]
    fn load_and_execute_all_artifacts() {
        // Full five-graph check when an artifact directory is present.
        let Some(set) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&set).unwrap();
        assert_eq!(engine.names().len(), 5);
    }
}
